"""AOT pipeline tests: HLO emission, manifests, probe reproducibility."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_op_histogram_parses():
    text = """HloModule m
ENTRY main {
  %p0 = f32[2,2] parameter(0)
  %p1 = f32[2,2] parameter(1)
  %d = f32[2,2] dot(%p0, %p1)
  ROOT %a = f32[2,2] add(%d, %d)
}
"""
    hist = aot.hlo_op_histogram(text)
    assert hist.get("dot") == 1
    assert hist.get("add") == 1
    assert hist.get("parameter") == 2


@pytest.mark.skipif(not os.path.isdir(ART), reason="run `make artifacts` first")
class TestArtifacts:
    def _manifest(self, name):
        path = os.path.join(ART, f"{name}.manifest")
        entries = {"input": [], "param": []}
        meta = {}
        with open(path) as f:
            for line in f:
                parts = line.split()
                if not parts:
                    continue
                if parts[0] in ("input", "param"):
                    nm, dtype, shape, file = parts[1], parts[2], parts[3], parts[4]
                    shape = tuple(int(d) for d in shape.split(","))
                    entries[parts[0]].append((nm, dtype, shape, file))
                else:
                    meta[parts[0]] = parts[1]
        return meta, entries

    @pytest.mark.parametrize(
        "name",
        [
            "mlp_analog_b1", "mlp_digital_b1", "mlp_analog_b8", "mlp_digital_b8",
            "lstm256_analog", "lstm256_digital",
            "cnn_tiny_analog", "cnn_tiny_digital",
        ],
    )
    def test_bundle_complete(self, name):
        meta, entries = self._manifest(name)
        assert meta["model"] == name
        hlo = open(os.path.join(ART, meta["hlo"])).read()
        assert hlo.startswith("HloModule")
        assert "parameter" in hlo
        n_params = len(entries["input"]) + len(entries["param"])
        hist = aot.hlo_op_histogram(hlo)
        assert hist.get("parameter") == n_params, (hist.get("parameter"), n_params)
        # Every referenced tensor file exists and has the declared size.
        for nm, dtype, shape, file in entries["input"] + entries["param"]:
            sz = os.path.getsize(os.path.join(ART, file))
            assert sz == 4 * int(np.prod(shape)), (name, nm)
        probe = np.fromfile(os.path.join(ART, meta["probe_out"]), dtype="<f4")
        assert probe.size > 0 and np.all(np.isfinite(probe))

    def test_analog_and_digital_probe_outputs_agree(self):
        """End-to-end iso-behaviour: ANA vs DIG MLP agree within tolerance."""
        a = np.fromfile(os.path.join(ART, "mlp_analog_b1.probe_out.bin"), "<f4")
        d = np.fromfile(os.path.join(ART, "mlp_digital_b1.probe_out.bin"), "<f4")
        assert a.shape == d.shape
        rel = np.linalg.norm(a - d) / (np.linalg.norm(d) + 1e-9)
        assert rel < 0.25, rel

    def test_lstm_probe_is_distribution(self):
        y = np.fromfile(os.path.join(ART, "lstm256_analog.probe_out.bin"), "<f4")
        assert y.size == 50
        assert y.min() >= 0.0 and abs(y.sum() - 1.0) < 1e-4

    def test_batch_variants_consistent(self):
        """Row 0 of the b8 probe input equals... each batch is independent,
        so re-running aot must be deterministic: compare manifests exist."""
        m1, e1 = self._manifest("mlp_analog_b1")
        m8, e8 = self._manifest("mlp_analog_b8")
        # Same weight files are shared between batch variants.
        assert [p[3] for p in e1["param"]] == [p[3] for p in e8["param"]]

    def test_index_lists_all(self):
        idx = open(os.path.join(ART, "INDEX")).read().split()
        assert "mlp_analog_b1" in idx and "cnn_tiny_digital" in idx


def test_quick_mode_smoke(tmp_path):
    """--quick rebuilds only the MLP b1 bundle, deterministically."""
    env = dict(os.environ)
    cwd = os.path.join(os.path.dirname(__file__), "..")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--quick"],
        cwd=cwd, env=env, check=True, capture_output=True,
    )
    assert (tmp_path / "mlp_analog_b1.hlo.txt").exists()
    if os.path.isdir(ART):
        a = np.fromfile(tmp_path / "mlp_analog_b1.probe_out.bin", "<f4")
        b = np.fromfile(os.path.join(ART, "mlp_analog_b1.probe_out.bin"), "<f4")
        np.testing.assert_array_equal(a, b)
