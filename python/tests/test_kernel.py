"""Layer-1 correctness: the Pallas AIMC kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: `aimc_mvm` (Pallas,
interpret=True) must agree *bit-exactly* with `aimc_mvm_ref` for every
shape/tile/scale combination, because the Rust-side `aimclib::checker`
re-implements the oracle's formulas and the PJRT-executed artifacts are
validated against it transitively.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aimc_mvm as K
from compile.kernels import ref as R


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


def _mk(batch, m, n, tile_rows, tile_cols, sigma, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (batch, m))
    w = _rand(k2, (m, n), 0.1)
    w_q, _ = K.quantize_weights(w)
    w_prog = K.program_weights(w_q, sigma, k3)
    spec = K.calibrate_spec(x, w, tile_rows=tile_rows, tile_cols=tile_cols)
    return x, w, w_prog, spec


# ---------------------------------------------------------------------------
# Kernel == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "batch,m,n,tm,tn",
    [
        (1, 256, 256, 256, 256),   # exactly one crossbar
        (1, 1024, 1024, 256, 256), # 4x4 crossbars (the MLP layer)
        (4, 300, 520, 128, 256),   # ragged: padding on both axes
        (2, 50, 50, 256, 256),     # smaller than one tile
        (1, 306, 1024, 306, 256),  # the LSTM cell tile (one row-block)
        (8, 512, 64, 64, 64),      # many row blocks
    ],
)
def test_kernel_matches_ref(batch, m, n, tm, tn):
    x, _, w_prog, spec = _mk(batch, m, n, tm, tn, sigma=0.01, seed=7)
    y_kernel = K.aimc_mvm(x, w_prog, spec)
    y_ref = R.aimc_mvm_ref(x, w_prog, spec)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_ref))


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 4),
    m=st.integers(1, 200),
    n=st.integers(1, 160),
    tm=st.sampled_from([32, 64, 128, 256]),
    tn=st.sampled_from([32, 64, 128, 256]),
    sigma=st.sampled_from([0.0, 0.01, 0.05]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_hypothesis(batch, m, n, tm, tn, sigma, seed):
    """Hypothesis sweep over shapes, tiles and noise levels."""
    x, _, w_prog, spec = _mk(batch, m, n, tm, tn, sigma, seed)
    y_kernel = K.aimc_mvm(x, w_prog, spec)
    y_ref = R.aimc_mvm_ref(x, w_prog, spec)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_ref))


def test_kernel_rejects_bad_shapes():
    x = jnp.zeros((2, 8))
    w = jnp.zeros((9, 4))
    spec = K.AimcSpec(1.0, 1.0, 1.0, 8, 8)
    with pytest.raises(ValueError):
        K.aimc_mvm(x, w, spec)


# ---------------------------------------------------------------------------
# Physical-model properties
# ---------------------------------------------------------------------------


def test_zero_input_zero_output():
    _, _, w_prog, spec = _mk(2, 128, 64, 64, 64, 0.02, 3)
    y = R.aimc_mvm_ref(jnp.zeros((2, 128)), w_prog, spec)
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_noiseless_analog_close_to_exact():
    """Without programming noise the only error is DAC/ADC quantization."""
    x, w, w_prog, spec = _mk(4, 256, 256, 256, 256, sigma=0.0, seed=11)
    y = R.aimc_mvm_ref(x, w_prog, spec)
    y_true = x @ w
    rel = float(jnp.linalg.norm(y - y_true) / jnp.linalg.norm(y_true))
    assert rel < 0.05, rel


def test_noise_increases_error_monotonically_on_average():
    errs = []
    for sigma in (0.0, 0.02, 0.1):
        x, w, w_prog, spec = _mk(8, 256, 128, 256, 128, sigma, seed=5)
        y = R.aimc_mvm_ref(x, w_prog, spec)
        y_true = x @ w
        errs.append(float(jnp.linalg.norm(y - y_true) / jnp.linalg.norm(y_true)))
    assert errs[0] < errs[1] < errs[2], errs


def test_adc_saturation_clips():
    """Driving the tile beyond the calibrated range must saturate, not wrap."""
    x, w, w_prog, spec = _mk(1, 64, 32, 64, 32, 0.0, 9)
    y_sat = R.aimc_mvm_ref(x * 100.0, w_prog, spec)
    # Saturated output is bounded by full-scale ADC on every tile
    # (negative rail is -128 in two's complement).
    bound = 128.0 * spec.adc_scale * spec.in_scale * spec.w_scale * 1.0001
    assert float(jnp.max(jnp.abs(y_sat))) <= bound


def test_dac_quantization_bounds():
    x = jnp.array([[1e9, -1e9, 0.3, -0.49]])
    q = jnp.clip(jnp.round(x / 1.0), K.DAC_MIN, K.DAC_MAX)
    assert q.tolist() == [[127.0, -128.0, 0.0, -0.0]]


def test_quantize_weights_symmetric_range():
    w = jnp.array([[2.0, -4.0], [1.0, 0.5]])
    w_q, scale = K.quantize_weights(w)
    assert float(jnp.max(jnp.abs(w_q))) <= 127.0
    assert scale == pytest.approx(4.0 / 127.0)
    # Dequantized weights approximate the originals to half an LSB.
    np.testing.assert_allclose(
        np.asarray(w_q) * scale, np.asarray(w), atol=scale / 2 + 1e-9
    )


def test_quantize_weights_zero_matrix():
    w_q, scale = K.quantize_weights(jnp.zeros((4, 4)))
    assert scale == 1.0
    np.testing.assert_array_equal(np.asarray(w_q), 0.0)


def test_program_weights_deterministic_per_key():
    w_q, _ = K.quantize_weights(_rand(jax.random.PRNGKey(0), (32, 32)))
    key = jax.random.PRNGKey(42)
    a = K.program_weights(w_q, 0.02, key)
    b = K.program_weights(w_q, 0.02, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_program_weights_no_noise_identity():
    w_q, _ = K.quantize_weights(_rand(jax.random.PRNGKey(1), (16, 8)))
    np.testing.assert_array_equal(
        np.asarray(K.program_weights(w_q, 0.0, jax.random.PRNGKey(3))),
        np.asarray(w_q),
    )


def test_row_block_adc_differs_from_single_tile():
    """Per-tile ADC quantization is *not* equivalent to one big tile.

    This is the physical effect a naive quantize-at-the-end model misses
    (DESIGN.md §5); assert the two mappings genuinely differ.
    """
    x, w, w_prog, _ = _mk(4, 512, 64, 256, 64, 0.0, 13)
    spec_small = K.calibrate_spec(x, w, tile_rows=128, tile_cols=64)
    spec_big = K.calibrate_spec(x, w, tile_rows=512, tile_cols=64)
    y_small = R.aimc_mvm_ref(x, w_prog, spec_small)
    y_big = R.aimc_mvm_ref(x, w_prog, spec_big)
    assert not np.allclose(np.asarray(y_small), np.asarray(y_big))


def test_digital_ref_more_accurate_than_analog():
    x, w, w_prog, spec = _mk(8, 512, 256, 256, 256, sigma=0.02, seed=21)
    y_true = x @ w
    y_ana = R.aimc_mvm_ref(x, w_prog, spec)
    y_dig = R.digital_mvm_ref(x, w, spec.in_scale)
    err_ana = float(jnp.linalg.norm(y_ana - y_true))
    err_dig = float(jnp.linalg.norm(y_dig - y_true))
    assert err_dig < err_ana


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), batch=st.integers(1, 4))
def test_linearity_in_batch(seed, batch):
    """Rows of a batch are independent: per-row results equal batched run."""
    x, _, w_prog, spec = _mk(batch, 96, 64, 32, 64, 0.01, seed)
    y_full = R.aimc_mvm_ref(x, w_prog, spec)
    for i in range(batch):
        y_i = R.aimc_mvm_ref(x[i : i + 1], w_prog, spec)
        np.testing.assert_array_equal(np.asarray(y_full[i : i + 1]), np.asarray(y_i))
