"""Layer-2 model tests: shapes, analog-vs-digital agreement, structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import aimc_mvm as K
from compile.kernels import ref as R


def _mlp_setup(batch=2, d=256, sigma=0.01, seed=0):
    """A scaled-down MLP so tests stay fast; same code path as d=1024."""
    kw1, kw2, kx, kn1, kn2 = jax.random.split(jax.random.PRNGKey(seed), 5)
    w1 = jax.random.normal(kw1, (d, d)) / jnp.sqrt(d)
    w2 = jax.random.normal(kw2, (d, d)) / jnp.sqrt(d)
    x = jax.random.normal(kx, (batch, d))
    w1_q, ws1 = K.quantize_weights(w1)
    w2_q, ws2 = K.quantize_weights(w2)
    w1_p = K.program_weights(w1_q, sigma, kn1)
    w2_p = K.program_weights(w2_q, sigma, kn2)
    spec1 = K.calibrate_spec(x, w1, tile_rows=128, tile_cols=128)
    h = M.relu(R.aimc_mvm_ref(x, w1_p, spec1))
    spec2 = K.calibrate_spec(h, w2, tile_rows=128, tile_cols=128)
    return x, w1, w2, w1_q, ws1, w2_q, ws2, w1_p, w2_p, spec1, spec2


class TestMlp:
    def test_shapes(self):
        x, *_, w1_p, w2_p, spec1, spec2 = _mlp_setup()
        y = M.mlp_analog(x, w1_p, w2_p, spec1=spec1, spec2=spec2)
        assert y.shape == x.shape

    def test_analog_tracks_digital(self):
        (x, w1, w2, w1_q, ws1, w2_q, ws2, w1_p, w2_p, spec1, spec2) = _mlp_setup()
        y_a = M.mlp_analog(x, w1_p, w2_p, spec1=spec1, spec2=spec2)
        y_d = M.mlp_digital(
            x, w1_q, w2_q,
            in_scale1=spec1.in_scale, w_scale1=ws1,
            in_scale2=spec2.in_scale, w_scale2=ws2,
        )
        rel = float(jnp.linalg.norm(y_a - y_d) / (jnp.linalg.norm(y_d) + 1e-9))
        assert rel < 0.25, rel

    def test_relu_nonnegative(self):
        x, *_, w1_p, w2_p, spec1, spec2 = _mlp_setup()
        y = M.mlp_analog(x, w1_p, w2_p, spec1=spec1, spec2=spec2)
        assert float(jnp.min(y)) >= 0.0

    def test_jit_lowers(self):
        x, *_, w1_p, w2_p, spec1, spec2 = _mlp_setup(batch=1, d=128)
        fn = jax.jit(lambda x, a, b: M.mlp_analog(x, a, b, spec1=spec1, spec2=spec2))
        lowered = fn.lower(x, w1_p, w2_p)
        assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))


class TestLstmDims:
    """Table II-A parameter counts."""

    @pytest.mark.parametrize("n_h", [256, 512, 750])
    def test_total_params_formula(self, n_h):
        dims = M.LstmDims(n_h=n_h)
        # cell: (n_h + 50) * 4*n_h ; dense: n_h * 50
        expect = (n_h + 50) * 4 * n_h + n_h * 50
        assert dims.total_params == expect

    def test_paper_param_totals_same_order(self):
        """Table II-A reports 377.3k / 1.28M / 2.6M; our weight-only count
        is within ~15% (the paper's totals include per-gate biases and
        bookkeeping we don't model). The Rust nn::lstm module carries the
        paper's literal values for the Table II bench."""
        for n_h, paper in [(256, 377_300), (512, 1_280_000), (750, 2_600_000)]:
            ours = M.LstmDims(n_h=n_h).total_params
            assert abs(ours - paper) / paper < 0.15, (n_h, ours, paper)

    def test_cell_geometry(self):
        dims = M.LstmDims(n_h=256)
        assert dims.cell_rows == 306
        assert dims.cell_cols == 1024


class TestLstmStep:
    def _setup(self, n_h=64, sigma=0.01, seed=1):
        dims = M.LstmDims(n_h=n_h)
        kc, kd, kx, kh, kcc, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 7)
        w_cell = jax.random.normal(kc, (dims.cell_rows, dims.cell_cols)) / jnp.sqrt(
            dims.cell_rows
        )
        w_dense = jax.random.normal(kd, (dims.n_h, dims.y)) / jnp.sqrt(dims.n_h)
        x = jax.random.normal(kx, (1, dims.x))
        h = jnp.tanh(jax.random.normal(kh, (1, dims.n_h)))
        c = jnp.tanh(jax.random.normal(kcc, (1, dims.n_h)))
        wc_q, wcs = K.quantize_weights(w_cell)
        wd_q, wds = K.quantize_weights(w_dense)
        wc_p = K.program_weights(wc_q, sigma, k1)
        wd_p = K.program_weights(wd_q, sigma, k2)
        hx = jnp.concatenate([h, x], axis=-1)
        cell_spec = K.calibrate_spec(hx, w_cell, tile_rows=dims.cell_rows)
        gates = R.aimc_mvm_ref(hx, wc_p, cell_spec)
        h2, _ = M.lstm_cell_math(gates, c, dims.n_h)
        dense_spec = K.calibrate_spec(h2, w_dense, tile_rows=dims.n_h)
        return dims, x, h, c, wc_q, wcs, wd_q, wds, wc_p, wd_p, cell_spec, dense_spec

    def test_shapes_and_probability_output(self):
        dims, x, h, c, *_, wc_p, wd_p, cell_spec, dense_spec = self._setup()
        y, h2, c2 = M.lstm_step_analog(
            x, h, c, wc_p, wd_p, dims=dims, cell_spec=cell_spec, dense_spec=dense_spec
        )
        assert y.shape == (1, dims.y)
        assert h2.shape == (1, dims.n_h) and c2.shape == (1, dims.n_h)
        np.testing.assert_allclose(float(jnp.sum(y)), 1.0, rtol=1e-5)
        assert float(jnp.min(y)) >= 0.0

    def test_state_bounded(self):
        """|h| <= 1 always (tanh(c) * sigmoid(o)); c bounded by recurrence."""
        dims, x, h, c, *_, wc_p, wd_p, cell_spec, dense_spec = self._setup()
        for _ in range(5):
            _, h, c = M.lstm_step_analog(
                x, h, c, wc_p, wd_p,
                dims=dims, cell_spec=cell_spec, dense_spec=dense_spec,
            )
        assert float(jnp.max(jnp.abs(h))) <= 1.0 + 1e-6

    def test_analog_tracks_digital_distribution(self):
        (dims, x, h, c, wc_q, wcs, wd_q, wds, wc_p, wd_p,
         cell_spec, dense_spec) = self._setup()
        y_a, *_ = M.lstm_step_analog(
            x, h, c, wc_p, wd_p, dims=dims, cell_spec=cell_spec, dense_spec=dense_spec
        )
        y_d, *_ = M.lstm_step_digital(
            x, h, c, wc_q, wd_q,
            dims=dims,
            cell_in_scale=cell_spec.in_scale, cell_w_scale=wcs,
            dense_in_scale=dense_spec.in_scale, dense_w_scale=wds,
        )
        # Output distributions over the 50-char alphabet stay close.
        tv = 0.5 * float(jnp.sum(jnp.abs(y_a - y_d)))
        assert tv < 0.2, tv

    def test_single_process_call_covers_all_gates(self):
        """The cell MVM output width is exactly 4*n_h: one CM_PROCESS."""
        dims, x, h, c, *_, wc_p, wd_p, cell_spec, dense_spec = self._setup()
        hx = jnp.concatenate([h, x], axis=-1)
        gates = R.aimc_mvm_ref(hx, wc_p, cell_spec)
        assert gates.shape == (1, 4 * dims.n_h)


class TestTinyCnn:
    def test_im2col_matches_conv(self):
        """im2col @ flattened-HWIO kernels == lax.conv (the §IX.A mapping)."""
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (2, 8, 8, 3))
        w = jax.random.normal(k2, (3, 3, 3, 5))  # HWIO
        cols = M._im2col(x, 3, 3)
        y_gemm = (cols @ w.reshape(-1, 5)).reshape(2, 8, 8, 5)
        y_conv = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(
            np.asarray(y_gemm), np.asarray(y_conv), rtol=1e-4, atol=1e-4
        )

    def test_maxpool(self):
        x = jnp.arange(16.0).reshape(1, 4, 4, 1)
        p = M._maxpool2(x)
        assert p.shape == (1, 2, 2, 1)
        assert p[0, 0, 0, 0] == 5.0 and p[0, 1, 1, 0] == 15.0

    def test_forward_shapes_and_softmax(self):
        dims = M.TinyCnnDims(image=16, c1=4, c2=8, classes=10)
        keys = jax.random.split(jax.random.PRNGKey(2), 6)
        w1 = jax.random.normal(keys[0], (dims.k1, dims.c1)) / jnp.sqrt(dims.k1)
        w2 = jax.random.normal(keys[1], (dims.k2, dims.c2)) / jnp.sqrt(dims.k2)
        wd = jax.random.normal(keys[2], (dims.dense_rows, dims.classes))
        x = jax.random.uniform(keys[3], (1, 16, 16, 3))
        w1_q, ws1 = K.quantize_weights(w1)
        w2_q, ws2 = K.quantize_weights(w2)
        wd_q, wsd = K.quantize_weights(wd)
        y = M.cnn_tiny_digital(
            x, w1_q, w2_q, wd_q,
            dims=dims,
            in_scale1=0.01, w_scale1=ws1,
            in_scale2=0.05, w_scale2=ws2,
            dense_in_scale=0.05, dense_w_scale=wsd,
        )
        assert y.shape == (1, dims.classes)
        np.testing.assert_allclose(float(jnp.sum(y)), 1.0, rtol=1e-5)
