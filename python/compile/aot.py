"""AOT compile path: lower Layer-2 JAX models to HLO text + weight bundles.

This is the *only* place Python runs: `make artifacts` invokes it once, it
writes everything the Rust runtime needs into `artifacts/`, and the Rust
binary is self-contained afterwards.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model the bundle is:
    <name>.hlo.txt        the lowered computation (params: input(s), weights)
    <name>.manifest       line-based description (inputs, params, probes)
    <shared>.bin          f32 little-endian weight tensors (row-major)
    <name>.probe_out.bin  expected output for the probe input, so the Rust
                          integration tests can verify PJRT numerics exactly.

Weights are generated deterministically (seeded), quantized, and — for the
analog variants — programmed with PCM conductance noise, mirroring the
one-time CM_INITIALIZE cost in the paper. Scales are calibrated on probe
data and baked as static constants (§III.B fixed input scaling).

Usage: cd python && python -m compile.aot --out ../artifacts [--stats]
"""

from __future__ import annotations

import argparse
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import aimc_mvm as K
from .kernels import ref as R

# Programming-noise sigma relative to full conductance range. Effective 1%
# models a differential PCM pair after iterative program-and-verify (refs
# [16],[30]; raw single-device sigma is ~2-3%, program-verify + averaging
# bring the *effective* weight error down). Our networks are not
# noise-aware-trained, so we model the verified effective error.
PROG_NOISE_SIGMA = 0.01

SEED = 20221230  # the paper's DOI year + a stable suffix; fixed forever.


# ---------------------------------------------------------------------------
# Lowering helper (the gen_hlo.py recipe)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def hlo_op_histogram(hlo_text: str, entry_only: bool = True) -> dict[str, int]:
    """Crude op histogram for --stats (L2 optimization sanity checks).

    With entry_only, counts ops in the ENTRY computation only — nested
    computations (reduce bodies, fusions) have their own parameter(...)
    lines that would otherwise pollute e.g. the parameter count.
    """
    hist: dict[str, int] = {}
    in_entry = not entry_only
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if entry_only:
            if stripped.startswith("ENTRY"):
                in_entry = True
                continue
            if in_entry and stripped == "}":
                in_entry = False
            if not in_entry:
                continue
        if " = " in stripped:
            rhs = stripped.split(" = ", 1)[1]
            # e.g. "f32[1,1024]{1,0} dot(..." -> "dot"
            parts = rhs.split(" ", 1)
            if len(parts) == 2:
                op = parts[1].split("(", 1)[0].strip()
                if op and op.replace("-", "").isalnum():
                    hist[op] = hist.get(op, 0) + 1
    return hist


# ---------------------------------------------------------------------------
# Artifact bundle writer
# ---------------------------------------------------------------------------


@dataclass
class Tensor:
    name: str
    array: np.ndarray
    file: str  # relative path within artifacts/


class Bundle:
    """One model artifact: HLO + manifest + binary tensors."""

    def __init__(self, out_dir: str, name: str):
        self.out = out_dir
        self.name = name
        self.inputs: list[Tensor] = []
        self.params: list[Tensor] = []
        self.probe_out: np.ndarray | None = None
        self.hlo_text: str | None = None

    def add_input(self, name: str, probe: jax.Array) -> None:
        arr = np.asarray(probe, dtype=np.float32)
        self.inputs.append(Tensor(name, arr, f"{self.name}.{name}.bin"))

    def add_param(self, name: str, value: jax.Array, file: str | None = None) -> None:
        arr = np.asarray(value, dtype=np.float32)
        self.params.append(Tensor(name, arr, file or f"{self.name}.{name}.bin"))

    def _write_bin(self, t: Tensor) -> None:
        path = os.path.join(self.out, t.file)
        if not os.path.exists(path):
            t.array.astype("<f4").tofile(path)

    def write(self) -> None:
        assert self.hlo_text is not None and self.probe_out is not None
        with open(os.path.join(self.out, f"{self.name}.hlo.txt"), "w") as f:
            f.write(self.hlo_text)
        for t in self.inputs + self.params:
            self._write_bin(t)
        probe_file = f"{self.name}.probe_out.bin"
        np.asarray(self.probe_out, dtype="<f4").tofile(
            os.path.join(self.out, probe_file)
        )
        lines = [f"model {self.name}", f"hlo {self.name}.hlo.txt"]
        for t in self.inputs:
            shape = ",".join(str(d) for d in t.array.shape)
            lines.append(f"input {t.name} f32 {shape} {t.file}")
        for t in self.params:
            shape = ",".join(str(d) for d in t.array.shape)
            lines.append(f"param {t.name} f32 {shape} {t.file}")
        lines.append(f"probe_out {probe_file}")
        with open(os.path.join(self.out, f"{self.name}.manifest"), "w") as f:
            f.write("\n".join(lines) + "\n")


# ---------------------------------------------------------------------------
# Model builders
# ---------------------------------------------------------------------------


def _keys(n: int) -> list[jax.Array]:
    return list(jax.random.split(jax.random.PRNGKey(SEED), n))


def build_mlp(out_dir: str, batch: int, stats: bool) -> list[Bundle]:
    """MLP 1024x1024x2 (Fig. 6a), analog + digital variants."""
    kw1, kw2, kx, kn1, kn2 = _keys(5)
    d = M.MLP_DIM
    # He-ish init scaled down so activations stay in a sane int8 range.
    w1 = jax.random.normal(kw1, (d, d)) * (1.0 / jnp.sqrt(d))
    w2 = jax.random.normal(kw2, (d, d)) * (1.0 / jnp.sqrt(d))
    probe = jax.random.normal(kx, (batch, d))

    w1_q, ws1 = K.quantize_weights(w1)
    w2_q, ws2 = K.quantize_weights(w2)
    w1_prog = K.program_weights(w1_q, PROG_NOISE_SIGMA, kn1)
    w2_prog = K.program_weights(w2_q, PROG_NOISE_SIGMA, kn2)

    spec1 = K.calibrate_spec(probe, w1)
    h_probe = M.relu(R.aimc_mvm_ref(probe, w1_prog, spec1))
    spec2 = K.calibrate_spec(h_probe, w2)

    bundles = []

    # -- analog ------------------------------------------------------------
    name = f"mlp_analog_b{batch}"
    b = Bundle(out_dir, name)

    def fwd_analog(x, w1p, w2p):
        return (M.mlp_analog(x, w1p, w2p, spec1=spec1, spec2=spec2),)

    b.hlo_text = to_hlo_text(
        jax.jit(fwd_analog).lower(
            jax.ShapeDtypeStruct((batch, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        )
    )
    b.add_input("x", probe)
    b.add_param("w1_prog", w1_prog, "mlp.w1_prog.bin")
    b.add_param("w2_prog", w2_prog, "mlp.w2_prog.bin")
    b.probe_out = fwd_analog(probe, w1_prog, w2_prog)[0]
    b.write()
    bundles.append(b)
    if stats:
        print(f"[stats] {name}: {hlo_op_histogram(b.hlo_text)}")

    # -- digital -----------------------------------------------------------
    name = f"mlp_digital_b{batch}"
    b = Bundle(out_dir, name)

    def fwd_digital(x, w1q, w2q):
        return (
            M.mlp_digital(
                x, w1q, w2q,
                in_scale1=spec1.in_scale, w_scale1=ws1,
                in_scale2=spec2.in_scale, w_scale2=ws2,
            ),
        )

    b.hlo_text = to_hlo_text(
        jax.jit(fwd_digital).lower(
            jax.ShapeDtypeStruct((batch, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        )
    )
    b.add_input("x", probe)
    b.add_param("w1_q", w1_q, "mlp.w1_q.bin")
    b.add_param("w2_q", w2_q, "mlp.w2_q.bin")
    b.probe_out = fwd_digital(probe, w1_q, w2_q)[0]
    b.write()
    bundles.append(b)
    return bundles


def build_lstm(out_dir: str, n_h: int, stats: bool) -> list[Bundle]:
    """LSTM cell + dense (Fig. 9a), one step, analog + digital variants."""
    dims = M.LstmDims(n_h=n_h)
    kc, kd, kx, kh, kcc, kn1, kn2 = _keys(7)
    w_cell = jax.random.normal(kc, (dims.cell_rows, dims.cell_cols)) * (
        1.0 / jnp.sqrt(dims.cell_rows)
    )
    w_dense = jax.random.normal(kd, (dims.n_h, dims.y)) * (
        1.0 / jnp.sqrt(dims.n_h)
    )
    # Probe state: one-hot-ish char input, bounded h/c.
    x = jax.random.normal(kx, (1, dims.x))
    h = jnp.tanh(jax.random.normal(kh, (1, dims.n_h)))
    c = jnp.tanh(jax.random.normal(kcc, (1, dims.n_h)))

    wc_q, wcs = K.quantize_weights(w_cell)
    wd_q, wds = K.quantize_weights(w_dense)
    wc_prog = K.program_weights(wc_q, PROG_NOISE_SIGMA, kn1)
    wd_prog = K.program_weights(wd_q, PROG_NOISE_SIGMA, kn2)

    hx = jnp.concatenate([h, x], axis=-1)
    # One large tile per layer, as in the paper's single-core cases: the
    # whole [h,x] row fits in the crossbar rows, so tile_rows covers it.
    cell_tile = K.AimcSpec(
        in_scale=1.0, w_scale=1.0, adc_scale=1.0,
        tile_rows=_ceil_mult(dims.cell_rows, 2), tile_cols=K.DEFAULT_TILE_COLS,
    )
    cell_spec = K.calibrate_spec(hx, w_cell, tile_rows=cell_tile.tile_rows)
    gates = R.aimc_mvm_ref(hx, wc_prog, cell_spec)
    h2, _ = M.lstm_cell_math(gates, c, dims.n_h)
    dense_spec = K.calibrate_spec(
        h2, w_dense, tile_rows=_ceil_mult(dims.n_h, 2)
    )

    shapes = dict(
        x=jax.ShapeDtypeStruct((1, dims.x), jnp.float32),
        h=jax.ShapeDtypeStruct((1, dims.n_h), jnp.float32),
        c=jax.ShapeDtypeStruct((1, dims.n_h), jnp.float32),
        wc=jax.ShapeDtypeStruct((dims.cell_rows, dims.cell_cols), jnp.float32),
        wd=jax.ShapeDtypeStruct((dims.n_h, dims.y), jnp.float32),
    )

    bundles = []

    name = f"lstm{n_h}_analog"
    b = Bundle(out_dir, name)

    def fwd_analog(x, h, c, wc, wd):
        return M.lstm_step_analog(
            x, h, c, wc, wd,
            dims=dims, cell_spec=cell_spec, dense_spec=dense_spec,
        )

    b.hlo_text = to_hlo_text(
        jax.jit(fwd_analog).lower(
            shapes["x"], shapes["h"], shapes["c"], shapes["wc"], shapes["wd"]
        )
    )
    b.add_input("x", x)
    b.add_input("h", h)
    b.add_input("c", c)
    b.add_param("wc_prog", wc_prog, f"lstm{n_h}.wc_prog.bin")
    b.add_param("wd_prog", wd_prog, f"lstm{n_h}.wd_prog.bin")
    b.probe_out = fwd_analog(x, h, c, wc_prog, wd_prog)[0]
    b.write()
    bundles.append(b)
    if stats:
        print(f"[stats] {name}: {hlo_op_histogram(b.hlo_text)}")

    name = f"lstm{n_h}_digital"
    b = Bundle(out_dir, name)

    def fwd_digital(x, h, c, wcq, wdq):
        return M.lstm_step_digital(
            x, h, c, wcq, wdq,
            dims=dims,
            cell_in_scale=cell_spec.in_scale, cell_w_scale=wcs,
            dense_in_scale=dense_spec.in_scale, dense_w_scale=wds,
        )

    b.hlo_text = to_hlo_text(
        jax.jit(fwd_digital).lower(
            shapes["x"], shapes["h"], shapes["c"], shapes["wc"], shapes["wd"]
        )
    )
    b.add_input("x", x)
    b.add_input("h", h)
    b.add_input("c", c)
    b.add_param("wc_q", wc_q, f"lstm{n_h}.wc_q.bin")
    b.add_param("wd_q", wd_q, f"lstm{n_h}.wd_q.bin")
    b.probe_out = fwd_digital(x, h, c, wc_q, wd_q)[0]
    b.write()
    bundles.append(b)
    return bundles


def build_cnn_tiny(out_dir: str, stats: bool) -> list[Bundle]:
    """Tiny CNN (functional path; CNN-F/M/S timing models are Rust-side)."""
    dims = M.TinyCnnDims()
    kw1, kw2, kwd, kx, kn1, kn2 = _keys(6)
    w1 = jax.random.normal(kw1, (dims.k1, dims.c1)) * (1.0 / jnp.sqrt(dims.k1))
    w2 = jax.random.normal(kw2, (dims.k2, dims.c2)) * (1.0 / jnp.sqrt(dims.k2))
    wd = jax.random.normal(kwd, (dims.dense_rows, dims.classes)) * (
        1.0 / jnp.sqrt(dims.dense_rows)
    )
    probe = jax.random.uniform(kx, (1, dims.image, dims.image, 3))

    w1_q, ws1 = K.quantize_weights(w1)
    w2_q, ws2 = K.quantize_weights(w2)
    wd_q, wsd = K.quantize_weights(wd)
    w1_prog = K.program_weights(w1_q, PROG_NOISE_SIGMA, kn1)
    w2_prog = K.program_weights(w2_q, PROG_NOISE_SIGMA, kn2)

    cols1 = M._im2col(probe, 3, 3)
    spec1 = K.calibrate_spec(cols1, w1, tile_rows=_ceil_mult(dims.k1, 2))
    h1 = M._maxpool2(
        M.relu(R.aimc_mvm_ref(cols1, w1_prog, spec1).reshape(1, 32, 32, dims.c1))
    )
    cols2 = M._im2col(h1, 3, 3)
    spec2 = K.calibrate_spec(cols2, w2, tile_rows=_ceil_mult(dims.k2, 2))
    h2 = M._maxpool2(
        M.relu(R.aimc_mvm_ref(cols2, w2_prog, spec2).reshape(1, 16, 16, dims.c2))
    )
    flat = h2.reshape(1, -1)
    dense_in_scale = float(jnp.max(jnp.abs(flat))) / 127.0 or 1.0

    shapes = (
        jax.ShapeDtypeStruct((1, dims.image, dims.image, 3), jnp.float32),
        jax.ShapeDtypeStruct((dims.k1, dims.c1), jnp.float32),
        jax.ShapeDtypeStruct((dims.k2, dims.c2), jnp.float32),
        jax.ShapeDtypeStruct((dims.dense_rows, dims.classes), jnp.float32),
    )

    bundles = []

    name = "cnn_tiny_analog"
    b = Bundle(out_dir, name)

    def fwd_analog(x, w1p, w2p, wdq):
        return (
            M.cnn_tiny_analog(
                x, w1p, w2p, wdq,
                dims=dims, spec1=spec1, spec2=spec2,
                dense_in_scale=dense_in_scale, dense_w_scale=wsd,
            ),
        )

    b.hlo_text = to_hlo_text(jax.jit(fwd_analog).lower(*shapes))
    b.add_input("x", probe)
    b.add_param("w1_prog", w1_prog, "cnn_tiny.w1_prog.bin")
    b.add_param("w2_prog", w2_prog, "cnn_tiny.w2_prog.bin")
    b.add_param("wd_q", wd_q, "cnn_tiny.wd_q.bin")
    b.probe_out = fwd_analog(probe, w1_prog, w2_prog, wd_q)[0]
    b.write()
    bundles.append(b)
    if stats:
        print(f"[stats] {name}: {hlo_op_histogram(b.hlo_text)}")

    name = "cnn_tiny_digital"
    b = Bundle(out_dir, name)

    def fwd_digital(x, w1q, w2q, wdq):
        return (
            M.cnn_tiny_digital(
                x, w1q, w2q, wdq,
                dims=dims,
                in_scale1=spec1.in_scale, w_scale1=ws1,
                in_scale2=spec2.in_scale, w_scale2=ws2,
                dense_in_scale=dense_in_scale, dense_w_scale=wsd,
            ),
        )

    b.hlo_text = to_hlo_text(jax.jit(fwd_digital).lower(*shapes))
    b.add_input("x", probe)
    b.add_param("w1_q", w1_q, "cnn_tiny.w1_q.bin")
    b.add_param("w2_q", w2_q, "cnn_tiny.w2_q.bin")
    b.add_param("wd_q", wd_q, "cnn_tiny.wd_q.bin")
    b.probe_out = fwd_digital(probe, w1_q, w2_q, wd_q)[0]
    b.write()
    bundles.append(b)
    return bundles


def _ceil_mult(n: int, align: int) -> int:
    return ((n + align - 1) // align) * align


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--stats", action="store_true", help="print HLO op histograms")
    ap.add_argument(
        "--quick", action="store_true",
        help="only build the MLP b1 bundle (CI smoke)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    bundles: list[Bundle] = []
    bundles += build_mlp(args.out, batch=1, stats=args.stats)
    if not args.quick:
        bundles += build_mlp(args.out, batch=8, stats=args.stats)
        bundles += build_lstm(args.out, n_h=256, stats=args.stats)
        bundles += build_cnn_tiny(args.out, stats=args.stats)

    index = [b.name for b in bundles]
    with open(os.path.join(args.out, "INDEX"), "w") as f:
        f.write("\n".join(index) + "\n")
    print(f"wrote {len(bundles)} bundles to {args.out}: {', '.join(index)}")


if __name__ == "__main__":
    main()
