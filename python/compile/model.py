"""Layer-2 JAX models: the paper's three evaluation workloads.

Each workload exists in two variants, mirroring the paper's ANA vs DIG
comparison (§VI.C):

  *analog*  — every MVM that the paper maps to AIMC tiles goes through the
              Layer-1 Pallas kernel (`kernels.aimc_mvm`), i.e. DAC → PCM
              crossbar (with programming noise) → per-tile ADC → digital
              accumulation.
  *digital* — the SIMD CPU reference: int8 weights/activations with fp32
              accumulation (`kernels.ref.digital_mvm_ref`).

Activation functions (ReLU / sigmoid / tanh / softmax) always run in fp32
"on the CPU" — in the paper these are digital operations executed by the
cores, never by the tile (§VIII: "all activation functions are performed in
the CPU cores").

Workloads:
  MLP  — two dense 1024x1024 layers + ReLU (Fig. 6a).
  LSTM — one LSTM cell layer (n_h) + one dense layer + softmax, input/output
         width 50 (PTB character model, Fig. 9a). The analog variant tiles
         the four gate matrices side-by-side in one logical crossbar and
         computes all four gate MVMs with a single process call (§VIII.D).
  CNN  — convolutions mapped to crossbars by flattening kernels into columns
         (im2col, §IX.A refs [43],[16]); dense layers stay digital. The AOT
         artifact uses a CIFAR-sized "tiny" CNN so the functional path stays
         tractable; the full CNN-F/M/S *timing* models live in the Rust
         simulator (rust/src/nn/cnn.rs), which needs no HLO.

These functions are lowered once by `aot.py` (build time) and executed from
Rust via PJRT; Python never runs on the request path.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.aimc_mvm import AimcSpec, aimc_mvm
from .kernels.ref import digital_mvm_q

# ---------------------------------------------------------------------------
# Shared digital ops (always CPU-side in the paper)
# ---------------------------------------------------------------------------


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def softmax(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)


# ---------------------------------------------------------------------------
# MLP (Exploration One, §VII): dense(1024) → ReLU → dense(1024) → ReLU
# ---------------------------------------------------------------------------

MLP_DIM = 1024


def mlp_analog(
    x: jax.Array,
    w1_prog: jax.Array,
    w2_prog: jax.Array,
    *,
    spec1: AimcSpec,
    spec2: AimcSpec,
) -> jax.Array:
    """Analog MLP: both dense layers on AIMC tiles (Fig. 6b, cases 1-4)."""
    h = relu(aimc_mvm(x, w1_prog, spec1))
    return relu(aimc_mvm(h, w2_prog, spec2))


def mlp_digital(
    x: jax.Array,
    w1_q: jax.Array,
    w2_q: jax.Array,
    *,
    in_scale1: float,
    w_scale1: float,
    in_scale2: float,
    w_scale2: float,
) -> jax.Array:
    """Digital int8 SIMD reference MLP (pre-quantized weights)."""
    h = relu(digital_mvm_q(x, w1_q, in_scale1, w_scale1))
    return relu(digital_mvm_q(h, w2_q, in_scale2, w_scale2))


# ---------------------------------------------------------------------------
# LSTM (Exploration Two, §VIII): cell layer + dense layer, x = y = 50
# ---------------------------------------------------------------------------

LSTM_IO = 50  # input / output width (PTB character alphabet size)


@dataclass(frozen=True)
class LstmDims:
    """Dimensions of the paper's LSTM (Table II-A)."""

    x: int = LSTM_IO
    n_h: int = 256
    y: int = LSTM_IO

    @property
    def cell_rows(self) -> int:
        return self.n_h + self.x

    @property
    def cell_cols(self) -> int:
        return 4 * self.n_h

    @property
    def total_params(self) -> int:
        return self.cell_rows * self.cell_cols + self.n_h * self.y


def lstm_cell_math(
    gates: jax.Array, c: jax.Array, n_h: int
) -> tuple[jax.Array, jax.Array]:
    """Digital gate combination: the part the CPU always does (§VIII.C)."""
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_step_analog(
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    w_cell_prog: jax.Array,
    w_dense_prog: jax.Array,
    *,
    dims: LstmDims,
    cell_spec: AimcSpec,
    dense_spec: AimcSpec,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One analog LSTM inference step.

    The concatenated [h, x] is queued once; the four gate matrices
    (W_i | W_f | W_g | W_o) are tiled side by side in the crossbar, so a
    single CM_PROCESS yields all four gate pre-activations (§VIII.D).
    Returns (y, h_new, c_new).
    """
    hx = jnp.concatenate([h, x], axis=-1)
    gates = aimc_mvm(hx, w_cell_prog, cell_spec)
    h_new, c_new = lstm_cell_math(gates, c, dims.n_h)
    y = softmax(aimc_mvm(h_new, w_dense_prog, dense_spec))
    return y, h_new, c_new


def lstm_step_digital(
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    w_cell_q: jax.Array,
    w_dense_q: jax.Array,
    *,
    dims: LstmDims,
    cell_in_scale: float,
    cell_w_scale: float,
    dense_in_scale: float,
    dense_w_scale: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One digital-reference LSTM inference step."""
    hx = jnp.concatenate([h, x], axis=-1)
    gates = digital_mvm_q(hx, w_cell_q, cell_in_scale, cell_w_scale)
    h_new, c_new = lstm_cell_math(gates, c, dims.n_h)
    y = softmax(digital_mvm_q(h_new, w_dense_q, dense_in_scale, dense_w_scale))
    return y, h_new, c_new


# ---------------------------------------------------------------------------
# CNN (Exploration Three, §IX) — tiny functional variant for the AOT path
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TinyCnnDims:
    """CIFAR-sized CNN used for the functional (PJRT) path.

    conv1: 3x3x3 -> c1, ReLU, 2x2 maxpool
    conv2: 3x3xc1 -> c2, ReLU, 2x2 maxpool
    dense: (8*8*c2) -> classes, softmax (digital, as in §IX.A)
    """

    image: int = 32
    c1: int = 16
    c2: int = 32
    classes: int = 10

    @property
    def k1(self) -> int:  # im2col rows of conv1
        return 3 * 3 * 3

    @property
    def k2(self) -> int:  # im2col rows of conv2
        return 3 * 3 * self.c1

    @property
    def dense_rows(self) -> int:
        return (self.image // 4) * (self.image // 4) * self.c2


def _im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """NHWC 'same' 3x3 patches -> (B*OH*OW, kh*kw*C) matrix.

    This is exactly the kernel-flattening mapping the paper uses to place
    convolutions on crossbars (§IX.A): feature-map patches become input
    vectors, flattened kernels become crossbar columns.
    """
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches returns channels ordered as (C, kh, kw);
    # reorder to (kh, kw, C) to match HWIO-flattened weights.
    patches = patches.reshape(b, h, w, c, kh * kw)
    patches = jnp.moveaxis(patches, 3, 4).reshape(b * h * w, kh * kw * c)
    return patches


def _maxpool2(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def _conv_layer(x: jax.Array, mvm) -> jax.Array:
    """Convolution as im2col + (analog or digital) MVM + reshape."""
    b, h, w, _ = x.shape
    cols = _im2col(x, 3, 3)
    out = mvm(cols)
    return out.reshape(b, h, w, -1)


def cnn_tiny_analog(
    x: jax.Array,
    w1_prog: jax.Array,
    w2_prog: jax.Array,
    wd_q: jax.Array,
    *,
    dims: TinyCnnDims,
    spec1: AimcSpec,
    spec2: AimcSpec,
    dense_in_scale: float,
    dense_w_scale: float,
) -> jax.Array:
    """Tiny CNN, convolutions on AIMC tiles, dense layer digital (§IX.A)."""
    h1 = _maxpool2(relu(_conv_layer(x, lambda c: aimc_mvm(c, w1_prog, spec1))))
    h2 = _maxpool2(relu(_conv_layer(h1, lambda c: aimc_mvm(c, w2_prog, spec2))))
    flat = h2.reshape(x.shape[0], -1)
    return softmax(digital_mvm_q(flat, wd_q, dense_in_scale, dense_w_scale))


def cnn_tiny_digital(
    x: jax.Array,
    w1_q: jax.Array,
    w2_q: jax.Array,
    wd_q: jax.Array,
    *,
    dims: TinyCnnDims,
    in_scale1: float,
    w_scale1: float,
    in_scale2: float,
    w_scale2: float,
    dense_in_scale: float,
    dense_w_scale: float,
) -> jax.Array:
    """Tiny CNN, all layers digital int8 (reference)."""
    h1 = _maxpool2(relu(_conv_layer(x, lambda c: digital_mvm_q(c, w1_q, in_scale1, w_scale1))))
    h2 = _maxpool2(relu(_conv_layer(h1, lambda c: digital_mvm_q(c, w2_q, in_scale2, w_scale2))))
    flat = h2.reshape(x.shape[0], -1)
    return softmax(digital_mvm_q(flat, wd_q, dense_in_scale, dense_w_scale))
