"""Layer-1 Pallas kernel: the AIMC crossbar matrix-vector multiply.

This kernel is the compute hot-spot of ALPINE: the analog in-memory MVM
performed by a PCM crossbar tile (paper §III). It models the *physical*
signal chain of one AIMC tile per grid step:

    DAC: the digital input vector is quantized to signed 8-bit
         (fixed input scale, as in paper §III.B: "the input signal is
         scaled and quantized in digital prior to its transfer").
    crossbar: the analog MVM against PCM conductances. Conductances carry
         programming noise (applied by the caller at weight-programming
         time via `program_weights`, matching the one-time CM_INITIALIZE
         cost in the paper); the multiply-accumulate itself is ideal
         (Ohm + Kirchhoff), which is the standard surrogate model.
    ADC: each crossbar tile digitizes its own bit-line outputs to signed
         8-bit *before* anything leaves the tile. When a logical matrix is
         larger than one physical crossbar, AIMClib tiles it across
         multiple crossbars and the partial sums are accumulated
         *digitally*, i.e. after per-tile ADC quantization. The kernel is
         faithful to that: quantization happens per row-block, then the
         int8 outputs accumulate across blocks.

Hardware adaptation (DESIGN.md §5): one grid step == one physical crossbar
tile. BlockSpec carves the logical (M, N) weight matrix into crossbar-sized
VMEM blocks exactly like AIMClib's `map_matrix` carves physical crossbars.
On a real TPU the (256, 256) block maps onto the MXU systolic array; here we
lower with interpret=True (CPU PJRT cannot execute Mosaic custom-calls).

Scales are static (baked at AOT time): the paper fixes the input scaling
factor "to avoid dynamic scaling".
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Signed 8-bit rails of the DAC (inputs) and ADC (outputs). Weights use the
# symmetric [-127, 127] range so that +w and -w are both representable by a
# PCM device pair (G+ - G-).
DAC_MIN, DAC_MAX = -128.0, 127.0
ADC_MIN, ADC_MAX = -128.0, 127.0
WEIGHT_LEVELS = 127.0

# Physical crossbar dimensions of the modeled tile (paper Table I-C uses a
# 256x256 tile for the energy-efficiency figure).
DEFAULT_TILE_ROWS = 256
DEFAULT_TILE_COLS = 256


@dataclass(frozen=True)
class AimcSpec:
    """Static configuration of an AIMC tile stack for one logical matrix.

    in_scale:  digital input LSB (x_q = round(x / in_scale)).
    w_scale:   weight LSB (w_q = round(w / w_scale), |w_q| <= 127).
    adc_scale: ADC LSB in units of (x_q * w_q) counts.
    tile_rows/tile_cols: physical crossbar dimensions.
    """

    in_scale: float
    w_scale: float
    adc_scale: float
    tile_rows: int = DEFAULT_TILE_ROWS
    tile_cols: int = DEFAULT_TILE_COLS


def quantize_weights(w: jax.Array) -> tuple[jax.Array, float]:
    """Symmetric int8 weight quantization: returns (w_q float-coded, w_scale)."""
    w_scale = float(jnp.max(jnp.abs(w))) / WEIGHT_LEVELS
    if w_scale == 0.0:
        w_scale = 1.0
    w_q = jnp.clip(jnp.round(w / w_scale), -WEIGHT_LEVELS, WEIGHT_LEVELS)
    return w_q.astype(jnp.float32), w_scale


def program_weights(
    w_q: jax.Array, sigma: float, key: jax.Array | None
) -> jax.Array:
    """Program quantized weights onto PCM devices with conductance noise.

    sigma is the programming-noise std-dev relative to the full conductance
    range (paper refs [16], [30]: Gaussian perturbation of the target
    conductance). The result is the *analog* conductance matrix, a float
    array — analog storage is continuous (Fig. 1a).
    """
    if sigma <= 0.0 or key is None:
        return w_q.astype(jnp.float32)
    noise = sigma * WEIGHT_LEVELS * jax.random.normal(key, w_q.shape)
    return (w_q + noise).astype(jnp.float32)


def _dac(x: jax.Array, in_scale: float) -> jax.Array:
    return jnp.clip(jnp.round(x / in_scale), DAC_MIN, DAC_MAX)


def _adc(p: jax.Array, adc_scale: float) -> jax.Array:
    return jnp.clip(jnp.round(p / adc_scale), ADC_MIN, ADC_MAX)


def _aimc_tile_kernel(x_ref, w_ref, o_ref, *, spec: AimcSpec, n_row_blocks: int):
    """One grid step == one physical crossbar tile (see module docstring)."""
    j = pl.program_id(1)

    # DAC conversion of this tile's slice of the input vector(s).
    x_q = _dac(x_ref[...], spec.in_scale)

    # Analog MVM on the crossbar: Ohm's law + Kirchhoff current summation.
    partial = jnp.dot(x_q, w_ref[...], preferred_element_type=jnp.float32)

    # Per-tile ADC: digitize *this tile's* bit-line integrals.
    y_q = _adc(partial, spec.adc_scale)

    # Digital accumulation across row-block tiles (done by the CPU / the
    # tile-local digital logic in multi-crossbar mappings).
    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += y_q

    # Final dequantization back to real units.
    @pl.when(j == n_row_blocks - 1)
    def _dequant():
        o_ref[...] *= spec.adc_scale * spec.in_scale * spec.w_scale


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("spec",))
def aimc_mvm(x: jax.Array, w_prog: jax.Array, spec: AimcSpec) -> jax.Array:
    """Analog in-memory MVM: y = dequant(sum_tiles ADC(DAC(x) @ G_tile)).

    x:      f32[B, M] digital activations (real units).
    w_prog: f32[M, N] programmed conductances, from
            program_weights(quantize_weights(w)[0], sigma, key).
    Returns f32[B, N] in real units.
    """
    if x.ndim != 2 or w_prog.ndim != 2 or x.shape[1] != w_prog.shape[0]:
        raise ValueError(f"shape mismatch: x{x.shape} @ w{w_prog.shape}")
    batch, m = x.shape
    n = w_prog.shape[1]

    tm, tn = spec.tile_rows, spec.tile_cols
    xp = _pad_to(x, 1, tm)
    wp = _pad_to(_pad_to(w_prog, 0, tm), 1, tn)
    n_row_blocks = xp.shape[1] // tm
    n_col_blocks = wp.shape[1] // tn

    kernel = functools.partial(
        _aimc_tile_kernel, spec=spec, n_row_blocks=n_row_blocks
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_col_blocks, n_row_blocks),
        in_specs=[
            pl.BlockSpec((batch, tm), lambda i, j: (0, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((batch, tn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, wp.shape[1]), jnp.float32),
        interpret=True,  # CPU-PJRT target; Mosaic lowering is TPU-only.
    )(xp, wp)
    return out[:, :n]


def calibrate_spec(
    x_sample: jax.Array,
    w: jax.Array,
    tile_rows: int = DEFAULT_TILE_ROWS,
    tile_cols: int = DEFAULT_TILE_COLS,
) -> AimcSpec:
    """Pick static scales from calibration data (AOT-time, paper §III.B).

    in_scale covers the sample activation range; adc_scale covers the
    maximum per-tile dot-product magnitude so the ADC does not saturate on
    calibration data.
    """
    in_scale = float(jnp.max(jnp.abs(x_sample))) / DAC_MAX
    if in_scale == 0.0:
        in_scale = 1.0
    w_q, w_scale = quantize_weights(w)
    x_q = _dac(x_sample, in_scale)

    xp = _pad_to(x_q, 1, tile_rows)
    wp = _pad_to(w_q, 0, tile_rows)
    blocks = xp.shape[1] // tile_rows
    xb = xp.reshape(x_sample.shape[0], blocks, tile_rows)
    wb = wp.reshape(blocks, tile_rows, w.shape[1])
    partials = jnp.einsum("bkt,ktn->kbn", xb, wb)
    peak = float(jnp.max(jnp.abs(partials)))
    adc_scale = max(peak / ADC_MAX, 1.0)
    return AimcSpec(
        in_scale=in_scale,
        w_scale=w_scale,
        adc_scale=adc_scale,
        tile_rows=tile_rows,
        tile_cols=tile_cols,
    )
