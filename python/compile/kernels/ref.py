"""Pure-jnp oracles for the AIMC Pallas kernel and the digital baseline.

`aimc_mvm_ref` implements *exactly* the semantics of
`aimc_mvm.py::aimc_mvm` without Pallas: DAC int8 quantization, per-row-block
analog MVM, per-tile ADC int8 quantization, digital accumulation across
row blocks, dequantization. This is the correctness signal for the kernel
(pytest asserts allclose) and the contract for the Rust-side
`aimclib::checker` (integration tests compare the PJRT-executed artifact
against Rust's re-implementation of these formulas).

`digital_mvm_ref` is the paper's *digital reference*: int8 weights and
activations with fp32 accumulation and no ADC bottleneck (§VI.C: "similar
precision across all applications, int8_t with fp32 accumulation").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .aimc_mvm import (
    ADC_MAX,
    ADC_MIN,
    DAC_MAX,
    DAC_MIN,
    AimcSpec,
    quantize_weights,
)


def _pad_rows(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    rem = (-a.shape[axis]) % multiple
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


def aimc_mvm_ref(x: jax.Array, w_prog: jax.Array, spec: AimcSpec) -> jax.Array:
    """Oracle for aimc_mvm: identical math, no pallas_call."""
    batch, m = x.shape
    n = w_prog.shape[1]
    tm = spec.tile_rows

    x_q = jnp.clip(jnp.round(x / spec.in_scale), DAC_MIN, DAC_MAX)

    xp = _pad_rows(x_q, 1, tm)
    wp = _pad_rows(w_prog, 0, tm)
    blocks = xp.shape[1] // tm
    xb = xp.reshape(batch, blocks, tm)
    wb = wp.reshape(blocks, tm, n)

    # Analog partial product per crossbar row-block, ADC-quantized per tile.
    partials = jnp.einsum("bkt,ktn->kbn", xb, wb)
    partials_q = jnp.clip(jnp.round(partials / spec.adc_scale), ADC_MIN, ADC_MAX)

    acc = jnp.sum(partials_q, axis=0)
    return acc * (spec.adc_scale * spec.in_scale * spec.w_scale)


def digital_mvm_q(
    x: jax.Array, w_q: jax.Array, in_scale: float, w_scale: float
) -> jax.Array:
    """Digital int8 MVM with fp32 accumulation, pre-quantized weights.

    jit-safe (scales are static floats); this is the form the Layer-2
    digital models lower through.
    """
    x_q = jnp.clip(jnp.round(x / in_scale), DAC_MIN, DAC_MAX)
    acc = jnp.dot(x_q, w_q, preferred_element_type=jnp.float32)
    return acc * (in_scale * w_scale)


def digital_mvm_ref(x: jax.Array, w: jax.Array, in_scale: float) -> jax.Array:
    """Eager convenience wrapper: quantizes w on the fly (tests only)."""
    w_q, w_scale = quantize_weights(w)
    return digital_mvm_q(x, w_q, in_scale, w_scale)
