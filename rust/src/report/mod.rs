//! Result renderers: turn `CaseResult` rows into the tables underlying
//! the paper's figures (time / memory intensity / energy triplets,
//! sub-ROI percentage stacks, per-core utilization).

use crate::coordinator::CaseResult;
use crate::stats::RoiKind;
use crate::util::table::{fmt_energy, fmt_time, Table};

/// Fig. 7 / Fig. 10 / Fig. 13-style aggregate table.
pub fn aggregate_table(title: &str, rows: &[CaseResult]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "system", "case", "time/inf", "LLC MPKI", "energy/inf", "DRAM acc", "insts",
        ],
    );
    for r in rows {
        t.row(vec![
            r.system.name().to_string(),
            r.label.clone(),
            fmt_time(r.time_per_inference_s),
            format!("{:.3}", r.llc_mpki),
            fmt_energy(r.energy_per_inference_j()),
            r.dram_accesses.to_string(),
            r.total_insts.to_string(),
        ]);
    }
    t
}

/// Fig. 8 / Fig. 11-style sub-ROI percentage table.
pub fn roi_table(title: &str, rows: &[CaseResult]) -> Table {
    let kinds: Vec<RoiKind> = RoiKind::ALL
        .iter()
        .copied()
        .filter(|k| rows.iter().any(|r| r.roi.get(*k) > 0))
        .collect();
    let mut header: Vec<String> = vec!["system".into(), "case".into()];
    header.extend(kinds.iter().map(|k| k.name().to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &header_refs);
    for r in rows {
        let mut cells = vec![r.system.name().to_string(), r.label.clone()];
        cells.extend(
            kinds
                .iter()
                .map(|k| format!("{:.1}%", 100.0 * r.roi.fraction(*k))),
        );
        t.row(cells);
    }
    t
}

/// Fig. 14-style per-core utilization table.
pub fn utilization_table(title: &str, rows: &[CaseResult]) -> Table {
    let cores = rows.iter().map(|r| r.per_core_ipc.len()).max().unwrap_or(0);
    let mut header: Vec<String> = vec!["case".into(), "metric".into()];
    header.extend((0..cores).map(|c| format!("core{c}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &header_refs);
    for r in rows {
        let mut idle = vec![r.label.clone(), "idle%".into()];
        idle.extend(r.per_core_idle.iter().map(|v| format!("{:.1}", 100.0 * v)));
        idle.resize(2 + cores, "-".into());
        t.row(idle);
        let mut wfm = vec![r.label.clone(), "wfm%".into()];
        wfm.extend(r.per_core_wfm.iter().map(|v| format!("{:.1}", 100.0 * v)));
        wfm.resize(2 + cores, "-".into());
        t.row(wfm);
        let mut ipc = vec![r.label.clone(), "IPC".into()];
        ipc.extend(r.per_core_ipc.iter().map(|v| format!("{:.3}", v)));
        ipc.resize(2 + cores, "-".into());
        t.row(ipc);
    }
    t
}

/// Automap validation table: analytic estimate vs simulation per
/// candidate, speedup over the all-digital baseline, Pareto-front mark.
pub fn automap_table(title: &str, report: &crate::coordinator::automap::AutomapReport) -> Table {
    let mut t = Table::new(
        title,
        &["mapping", "est cyc/inf", "time/inf", "energy/inf", "speedup", "front"],
    );
    let base_time = report.baseline_row().result.time_s;
    for row in &report.rows {
        t.row(vec![
            format!("{}{}", row.desc, if row.baseline { " (baseline)" } else { "" }),
            format!("{:.3e}", row.est_cycles),
            fmt_time(row.result.time_per_inference_s),
            fmt_energy(row.result.energy_per_inference_j()),
            format!("{:.2}x", base_time / row.result.time_s),
            if row.pareto { "*".to_string() } else { String::new() },
        ]);
    }
    t
}

/// Speedup/energy-gain summary vs a baseline predicate.
pub fn gains_table(
    title: &str,
    rows: &[CaseResult],
    is_baseline: impl Fn(&CaseResult) -> bool,
) -> Table {
    let mut t = Table::new(title, &["system", "case", "speedup", "energy gain"]);
    for sys in crate::config::SystemKind::ALL {
        let base = rows.iter().find(|r| r.system == sys && is_baseline(r));
        let Some(base) = base else { continue };
        for r in rows.iter().filter(|r| r.system == sys) {
            t.row(vec![
                sys.name().to_string(),
                r.label.clone(),
                format!("{:.2}x", base.time_s / r.time_s),
                format!("{:.2}x", base.energy.total_j() / r.energy.total_j()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;
    use crate::energy::EnergyBreakdown;
    use crate::stats::RoiTimes;

    fn fake(label: &str, time: f64) -> CaseResult {
        let mut roi = RoiTimes::default();
        roi.add(RoiKind::DigitalMvm, 80);
        roi.add(RoiKind::Activation, 20);
        CaseResult {
            label: label.into(),
            system: SystemKind::HighPower,
            inferences: 2,
            time_s: time,
            time_per_inference_s: time / 2.0,
            llc_mpki: 1.5,
            energy: EnergyBreakdown { core_active_j: 1e-6, ..Default::default() },
            total_insts: 1000,
            dram_accesses: 10,
            aimc_processes: 0,
            roi,
            per_core_ipc: vec![0.9, 0.5],
            per_core_idle: vec![0.1, 0.6],
            per_core_wfm: vec![0.0, 0.0],
        }
    }

    #[test]
    fn aggregate_renders() {
        let t = aggregate_table("x", &[fake("a", 1.0), fake("b", 0.5)]);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("LLC MPKI"));
    }

    #[test]
    fn roi_percentages_sum_to_100() {
        let t = roi_table("x", &[fake("a", 1.0)]);
        let row = &t.rows[0];
        assert!(row.iter().any(|c| c == "80.0%"));
        assert!(row.iter().any(|c| c == "20.0%"));
    }

    #[test]
    fn gains_relative_to_baseline() {
        let rows = [fake("DIG", 1.0), fake("ANA", 0.25)];
        let t = gains_table("g", &rows, |r| r.label == "DIG");
        let ana_row = t.rows.iter().find(|r| r[1] == "ANA").unwrap();
        assert_eq!(ana_row[2], "4.00x");
    }

    #[test]
    fn utilization_has_three_rows_per_case() {
        let t = utilization_table("u", &[fake("a", 1.0)]);
        assert_eq!(t.rows.len(), 3);
    }
}
