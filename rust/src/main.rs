//! The ALPINE CLI — leader entrypoint of the Layer-3 coordinator.
//!
//! Subcommands map to the paper's evaluation artifacts:
//!   list-configs          Table I
//!   run                   one workload case on one system
//!   fig7 | fig8 | fig10 | fig11 | fig13 | fig14 | loose
//!                         regenerate a figure's underlying table
//!   validate              PJRT probe checks of every AOT artifact
//!
//! (Hand-rolled argument parsing: clap is not in the offline vendor set.)

use alpine::config::{SystemConfig, SystemKind};
use alpine::coordinator::automap::{self as automap_driver, AutomapOptions};
use alpine::coordinator::faults::{self as faults_driver, FaultScenarioOptions};
use alpine::coordinator::reliability::{self as reliability_driver, ReliabilityOptions};
use alpine::coordinator::serving::{
    self as serving_driver, ArrivalProcess, RouterPolicy, ServeBenchOptions,
};
use alpine::coordinator::{experiments, run_workload, RunOptions};
use alpine::nn::{CnnVariant, LayerGraph};
use alpine::report;
use alpine::runtime::{default_artifacts_dir, Runtime};
use alpine::util::parallel;
use alpine::util::table::Table;
use alpine::workload::automap::{CostModel, TopologyBudget};
use alpine::workload::cnn::{self, CnnCase};
use alpine::workload::lstm::{self, LstmCase};
use alpine::workload::mlp::{self, CustomMlpMapping, MlpCase, MlpShape};
use alpine::workload::transformer::TransformerShape;
use anyhow::{bail, Context, Result};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("alpine: error: {e:#}");
        std::process::exit(1);
    }
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn opt_u32(args: &[String], name: &str, default: u32) -> Result<u32> {
    match opt(args, name) {
        None => Ok(default),
        Some(v) => v.parse().with_context(|| format!("{name} expects a number")),
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    // Global sweep-parallelism knob: `--jobs N` (or the ALPINE_JOBS env
    // var; default: all cores). Row order/content is identical at any N.
    // The pair is stripped so the flag works in any position, including
    // before the subcommand.
    let mut args: Vec<String> = args.to_vec();
    while let Some(i) = args.iter().position(|a| a == "--jobs") {
        // Strip every occurrence; the last one wins, as is conventional.
        let n: usize = args
            .get(i + 1)
            .context("--jobs expects a number >= 1")?
            .parse()
            .context("--jobs expects a number >= 1")?;
        if n == 0 {
            bail!("--jobs expects a number >= 1");
        }
        parallel::set_jobs(n);
        args.drain(i..=i + 1);
    }
    // Global simulator knob: `--no-nested-ff` disables hierarchical
    // steady-state fast-forward (full replay of every loop iteration)
    // for every run this invocation performs — the A/B switch behind
    // the nested-ff equivalence gates.
    while let Some(i) = args.iter().position(|a| a == "--no-nested-ff") {
        alpine::sim::machine::set_nested_fast_forward_default(false);
        args.remove(i);
    }
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list-configs" => list_configs(),
        "run" => cmd_run(&args[1..]),
        "custom" => cmd_custom(&args[1..]),
        "automap" => cmd_automap(&args[1..]),
        "resnet" => cmd_resnet(&args[1..]),
        "moe" => cmd_moe(&args[1..]),
        "transformer" => cmd_transformer(&args[1..]),
        "faults" => cmd_faults(&args[1..]),
        "serve-bench" => cmd_serve_bench(&args[1..]),
        "reliability" => cmd_reliability(&args[1..]),
        "fig7" => {
            let rows = experiments::fig7_mlp(opt_u32(&args[1..], "--inferences", experiments::MLP_INFERENCES)?)?;
            report::aggregate_table("Fig. 7 — MLP aggregate", &rows).print();
            report::gains_table("Fig. 7 — gains vs DIG-1core", &rows, |r| {
                r.label.contains("DIG-1core")
            })
            .print();
            Ok(())
        }
        "fig8" => {
            let rows = experiments::fig8_mlp_breakdown(opt_u32(&args[1..], "--inferences", experiments::MLP_INFERENCES)?)?;
            report::roi_table("Fig. 8 — MLP sub-ROI breakdown", &rows).print();
            Ok(())
        }
        "loose" => {
            let rows = experiments::loose_vs_tight(opt_u32(&args[1..], "--inferences", experiments::MLP_INFERENCES)?)?;
            report::aggregate_table("§VII.B — loose vs tight coupling", &rows).print();
            report::gains_table("§VII.B — gains vs DIG-1core", &rows, |r| {
                r.label.contains("DIG-1core")
            })
            .print();
            Ok(())
        }
        "fig10" => {
            let rows = experiments::fig10_lstm(opt_u32(&args[1..], "--inferences", experiments::LSTM_INFERENCES)?)?;
            report::aggregate_table("Fig. 10 — LSTM aggregate", &rows).print();
            Ok(())
        }
        "fig11" => {
            let rows = experiments::fig11_lstm_breakdown(opt_u32(&args[1..], "--inferences", experiments::LSTM_INFERENCES)?)?;
            report::roi_table("Fig. 11 — LSTM sub-ROI breakdown", &rows).print();
            Ok(())
        }
        "fig13" => {
            let rows = experiments::fig13_cnn(opt_u32(&args[1..], "--inferences", experiments::CNN_INFERENCES)?)?;
            report::aggregate_table("Fig. 13 — CNN aggregate", &rows).print();
            report::gains_table("Fig. 13 — gains vs DIG", &rows, |r| r.label.ends_with("DIG"))
                .print();
            Ok(())
        }
        "fig14" => {
            let rows = experiments::fig14_cnn_utilization(opt_u32(&args[1..], "--inferences", experiments::CNN_INFERENCES)?)?;
            report::utilization_table("Fig. 14 — CNN-S per-core utilization (high-power)", &rows)
                .print();
            Ok(())
        }
        "validate" => validate(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `alpine help`)"),
    }
}

fn print_help() {
    println!(
        "ALPINE — analog in-memory acceleration full-system simulator\n\
         \n\
         usage: alpine <command> [options]\n\
         \n\
         commands:\n\
         \x20 list-configs             print Table I system configurations\n\
         \x20 run --workload mlp|lstm|cnn --case <case> [--system hp|lp]\n\
         \x20     [--nh 256|512|750] [--variant f|m|s] [--inferences N]\n\
         \x20 custom --shape 784x512x512x10 [--tiles N] [--pipeline]\n\
         \x20     [--system hp|lp] [--inferences N]\n\
         \x20                          compile + run a custom MLP mapping\n\
         \x20                          (no --tiles/--pipeline: sweep the\n\
         \x20                          default mappings on both systems)\n\
         \x20 automap --shape AxBxC | --d-model N [--heads N] [--seq N]\n\
         \x20     [--layers N] [--d-ff N] [--cores N] [--tiles N]\n\
         \x20     [--tile-dims RxC] [--channels N] [--top K]\n\
         \x20     [--depth N] [--max-replica N] [--cap N]\n\
         \x20     [--cost-model compositional|compiled] [--no-compile-cache]\n\
         \x20     [--system hp|lp] [--inferences N]\n\
         \x20                          search the mapping space (lazy\n\
         \x20                          branch-and-bound, uncapped unless\n\
         \x20                          --cap), validate the top-K by\n\
         \x20                          simulation, print the Pareto front\n\
         \x20                          on (cycles, energy)\n\
         \x20 resnet [--hw N] [--ch N] [--classes N] [--cores N]\n\
         \x20     [--tiles N] [--tile-dims RxC] [--channels N] [--top K]\n\
         \x20     [--depth N] [--system hp|lp] [--inferences N]\n\
         \x20                          automap + simulate a residual block\n\
         \x20                          (fork/join DAG: conv-conv vs identity\n\
         \x20                          skip, elementwise-add join)\n\
         \x20 moe [--d-in N] [--d-model N] [--experts N] [--top-k K]\n\
         \x20     [--classes N] [--cores N] [--tiles N] [--tile-dims RxC]\n\
         \x20     [--channels N] [--top K] [--depth N] [--system hp|lp]\n\
         \x20     [--inferences N]\n\
         \x20                          automap + simulate a top-k mixture\n\
         \x20                          of experts (replicas double as\n\
         \x20                          expert parallelism)\n\
         \x20 transformer [--d-model N] [--heads N] [--seq N] [--layers N]\n\
         \x20     [--d-ff N] [--system hp|lp] [--inferences N]\n\
         \x20                          sweep the transformer-encoder hand\n\
         \x20                          mappings (digital vs packed analog)\n\
         \x20 faults [--seed S] [--noise SIGMA] [--drift SECONDS]\n\
         \x20     [--stuck RATE] [--steps N] [--fail-tile T@CYCLE]\n\
         \x20     [--system hp|lp] [--inferences N] [--out FILE]\n\
         \x20                          sweep fault intensity 0..1 (device\n\
         \x20                          noise/drift/stuck lines + transient\n\
         \x20                          tile stalls), print the degradation\n\
         \x20                          curve and write BENCH_faults.json;\n\
         \x20                          --fail-tile injects a hard failure\n\
         \x20                          and reruns with the digital-fallback\n\
         \x20                          remap instead of crashing\n\
         \x20 serve-bench [--requests N] [--replicas N] [--max-batch N]\n\
         \x20     [--queue-cap N] [--deadline-us X] [--batch-wait-us X]\n\
         \x20     [--retries N] [--backoff-us X] [--repair-us X]\n\
         \x20     [--policy rr|least-loaded|affinity]\n\
         \x20     [--arrival uniform|poisson|bursty|diurnal]\n\
         \x20     [--burst-x X] [--period-us X] [--duty F] [--amplitude F]\n\
         \x20     [--load-points 0.2,0.6,...] [--fail-replica R@mid|R@F]\n\
         \x20     [--seed S] [--shape AxBxC] [--system hp|lp] [--out FILE]\n\
         \x20                          sweep offered load against model\n\
         \x20                          replicas sharded across simulated\n\
         \x20                          ALPINE chips (SLO-aware batching,\n\
         \x20                          admission control, bounded retries,\n\
         \x20                          failover + degraded rejoin); print\n\
         \x20                          the latency-vs-load curve and write\n\
         \x20                          BENCH_serving.json\n\
         \x20 reliability [--horizons 1e6,1e8] [--horizon-short]\n\
         \x20     [--steps N] [--requests N] [--replicas N] [--max-batch N]\n\
         \x20     [--queue-cap N] [--nu X] [--nu-sigma X] [--slo P]\n\
         \x20     [--threshold P] [--fixed-period SECONDS]\n\
         \x20     [--check-period SECONDS] [--sensitive-permille N]\n\
         \x20     [--timeline N] [--seed S] [--shape AxBxC]\n\
         \x20     [--system hp|lp] [--out FILE]\n\
         \x20                          sweep virtual horizon x recal policy\n\
         \x20                          (never|fixed|threshold) under device\n\
         \x20                          drift: accuracy-proxy timeline,\n\
         \x20                          accuracy-SLO sheds, staggered recal\n\
         \x20                          availability floor, throughput cost;\n\
         \x20                          write BENCH_reliability.json\n\
         \x20 fig7|fig8|fig10|fig11|fig13|fig14|loose   regenerate a figure\n\
         \x20 validate                 PJRT probe-check all AOT artifacts\n\
         \n\
         options:\n\
         \x20 --jobs N                 sweep worker threads (default: all\n\
         \x20                          cores; ALPINE_JOBS env also works).\n\
         \x20                          Rows are identical at any N.\n\
         \x20 --no-nested-ff           disable hierarchical steady-state\n\
         \x20                          fast-forward (replay every loop\n\
         \x20                          iteration; results are identical,\n\
         \x20                          only slower)\n\
         \x20 --no-compile-cache       (automap) compile every oracle\n\
         \x20                          candidate from scratch instead of\n\
         \x20                          splicing cached step fragments\n\
         \n\
         case syntax: dig1 dig2 dig4 dig5 ana1 ana2 ana3 ana4 loose (per workload)"
    );
}

fn list_configs() -> Result<()> {
    let mut t = Table::new(
        "Table I-A — system configurations",
        &["parameter", "low-power", "high-power"],
    );
    let lp = SystemConfig::low_power();
    let hp = SystemConfig::high_power();
    let rows: Vec<(&str, String, String)> = vec![
        ("cores", lp.num_cores.to_string(), hp.num_cores.to_string()),
        ("freq", format!("{:.1} GHz", lp.freq_hz / 1e9), format!("{:.1} GHz", hp.freq_hz / 1e9)),
        ("VDD", format!("{} V", lp.vdd), format!("{} V", hp.vdd)),
        ("L1D", format!("{} kB", lp.l1d.size_bytes / 1024), format!("{} kB", hp.l1d.size_bytes / 1024)),
        ("LLC", format!("{} kB", lp.llc.size_bytes / 1024), format!("{} kB", hp.llc.size_bytes / 1024)),
        ("AIMC process", "100 ns".into(), "100 ns".into()),
        ("AIMC IO", "4 GB/s".into(), "4 GB/s".into()),
        ("AIMC power scale", format!("{}x", lp.aimc.node_power_scale), format!("{}x", hp.aimc.node_power_scale)),
    ];
    for (p, l, h) in rows {
        t.row(vec![p.to_string(), l, h]);
    }
    t.print();
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let cfg = SystemConfig::for_kind(system);
    let workload = opt(args, "--workload").unwrap_or_else(|| "mlp".into());
    let case = opt(args, "--case").unwrap_or_else(|| "ana1".into());
    let w = match workload.as_str() {
        "mlp" => {
            let n = opt_u32(args, "--inferences", experiments::MLP_INFERENCES)?;
            mlp::generate(parse_mlp_case(&case)?, &cfg, n)?
        }
        "lstm" => {
            let n = opt_u32(args, "--inferences", experiments::LSTM_INFERENCES)?;
            let nh: u64 = opt(args, "--nh").unwrap_or_else(|| "256".into()).parse()?;
            lstm::generate(parse_lstm_case(&case)?, nh, &cfg, n)?
        }
        "cnn" => {
            let n = opt_u32(args, "--inferences", experiments::CNN_INFERENCES)?;
            let v = CnnVariant::parse(&opt(args, "--variant").unwrap_or_else(|| "f".into()))
                .context("bad --variant (f|m|s)")?;
            let c = match case.as_str() {
                "dig" | "dig8" => CnnCase::Digital,
                "ana" | "ana8" => CnnCase::Analog,
                other => bail!("bad cnn case {other:?} (dig|ana)"),
            };
            cnn::generate(c, v, &cfg, n)?
        }
        other => bail!("unknown workload {other:?}"),
    };
    let r = run_workload(system, w, &RunOptions::default())?;
    report::aggregate_table("run", std::slice::from_ref(&r)).print();
    report::roi_table("sub-ROI breakdown", std::slice::from_ref(&r)).print();
    Ok(())
}

/// Case strings parse structurally (`dig<N>` / `ana<N>`); whether the
/// case table supports the configuration is decided by `generate`, which
/// returns a clean `WorkloadError` instead of panicking.
fn parse_mlp_case(s: &str) -> Result<MlpCase> {
    if s == "loose" {
        return Ok(MlpCase::AnalogLoose);
    }
    if let Some(n) = s.strip_prefix("dig") {
        return Ok(MlpCase::Digital { cores: n.parse().with_context(|| format!("bad mlp case {s:?}"))? });
    }
    if let Some(n) = s.strip_prefix("ana") {
        return Ok(MlpCase::Analog { case: n.parse().with_context(|| format!("bad mlp case {s:?}"))? });
    }
    bail!("bad mlp case {s:?} (digN | anaN | loose)")
}

fn parse_lstm_case(s: &str) -> Result<LstmCase> {
    if let Some(n) = s.strip_prefix("dig") {
        return Ok(LstmCase::Digital { cores: n.parse().with_context(|| format!("bad lstm case {s:?}"))? });
    }
    if let Some(n) = s.strip_prefix("ana") {
        return Ok(LstmCase::Analog { case: n.parse().with_context(|| format!("bad lstm case {s:?}"))? });
    }
    bail!("bad lstm case {s:?} (digN | anaN)")
}

/// `custom` — compile + run arbitrary MLP shapes through the mapping
/// compiler: `alpine custom --shape 784x512x512x10 [--tiles N]
/// [--pipeline] [--system hp|lp] [--inferences N]`. Without
/// --tiles/--pipeline, sweeps the default mapping set on both systems.
fn cmd_custom(args: &[String]) -> Result<()> {
    let shape_s = opt(args, "--shape")
        .or_else(|| opt(args, "--mlp-shape"))
        .context("--shape is required (e.g. --shape 784x512x512x10)")?;
    let shape = MlpShape::parse(&shape_s)?;
    let n = opt_u32(args, "--inferences", experiments::MLP_INFERENCES)?;
    let pipeline = args.iter().any(|a| a == "--pipeline");
    let tiles = opt(args, "--tiles");

    if pipeline || tiles.is_some() {
        // One explicit analog mapping on one system.
        let t: usize = match tiles {
            Some(v) => v.parse().context("--tiles expects a number >= 1")?,
            None => shape.layers(),
        };
        let mapping = CustomMlpMapping::Analog { tiles: t, pipeline };
        let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
            .context("bad --system (hp|lp)")?;
        let w = mlp::generate_custom(shape, mapping, n)?;
        let r = run_workload(system, w, &RunOptions::default())?;
        report::aggregate_table(&format!("custom MLP {shape}"), std::slice::from_ref(&r)).print();
        report::roi_table("sub-ROI breakdown", std::slice::from_ref(&r)).print();
    } else {
        // Validate each default mapping (no trace emission), then fan
        // out on the sweep engine — both systems, or just --system.
        for m in experiments::custom_mlp_mappings(shape) {
            let (graph, mapping) = mlp::custom_table(shape, m)?;
            alpine::workload::compile::validate(&graph, &mapping)?;
        }
        let mut cases = experiments::custom_mlp_cases(shape);
        if let Some(sys) = opt(args, "--system") {
            let sys = SystemKind::parse(&sys).context("bad --system (hp|lp)")?;
            cases.retain(|c| matches!(c, experiments::SweepCase::CustomMlp { kind, .. } if *kind == sys));
        }
        let rows = experiments::run_cases(&cases, n, parallel::jobs())?;
        report::aggregate_table(&format!("custom MLP {shape} — default mappings"), &rows).print();
        report::gains_table("gains vs DIG-1core", &rows, |r| r.label.contains("DIG-1core")).print();
    }
    Ok(())
}

/// Transformer shape from `--d-model/--heads/--seq/--layers/--d-ff`
/// (defaults: a small 2-layer encoder, d_model 256 / heads 4 / seq 64 /
/// d_ff 1024).
fn parse_transformer_shape(args: &[String]) -> Result<TransformerShape> {
    let get = |name: &str, default: u64| -> Result<u64> {
        match opt(args, name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("{name} expects a number")),
        }
    };
    Ok(TransformerShape::new(
        get("--d-model", 256)?,
        get("--heads", 4)?,
        get("--seq", 64)?,
        get("--layers", 2)?,
        get("--d-ff", 1024)?,
    )?)
}

/// Topology budget from `--cores/--tiles/--channels/--tile-dims`,
/// defaulting to the system's own configuration.
fn parse_budget(args: &[String], cfg: &SystemConfig) -> Result<TopologyBudget> {
    let mut budget = TopologyBudget::for_config(cfg);
    if let Some(v) = opt(args, "--cores") {
        budget.cores = v.parse().context("--cores expects a number >= 1")?;
    }
    if let Some(v) = opt(args, "--tiles") {
        budget.tiles = v.parse().context("--tiles expects a number")?;
    }
    if let Some(v) = opt(args, "--channels") {
        budget.channels = v.parse().context("--channels expects a number")?;
    }
    if let Some(v) = opt(args, "--tile-dims") {
        let (r, c) = v
            .split_once('x')
            .and_then(|(r, c)| Some((r.trim().parse().ok()?, c.trim().parse().ok()?)))
            .context("--tile-dims expects RxC, e.g. 1024x1024")?;
        budget.tile_rows = r;
        budget.tile_cols = c;
    }
    if budget.cores == 0 {
        bail!("--cores expects a number >= 1");
    }
    Ok(budget)
}

/// `automap` — search the mapping space of an MLP or transformer chain
/// under a topology budget, validate the top-K candidates on the
/// simulator, and print the Pareto front on (cycles, energy).
fn cmd_automap(args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let cfg = SystemConfig::for_kind(system);
    let graph: LayerGraph = if let Some(shape_s) = opt(args, "--shape") {
        let shape = MlpShape::parse(&shape_s)?;
        LayerGraph::mlp(shape.dims())
    } else if opt(args, "--d-model").is_some() {
        parse_transformer_shape(args)?.graph()
    } else {
        bail!("automap needs --shape AxBxC (MLP) or --d-model N [...] (transformer)");
    };

    let budget = parse_budget(args, &cfg)?;

    let model = match opt(args, "--cost-model").as_deref() {
        None | Some("compositional") => CostModel::Compositional,
        Some("compiled") => CostModel::Compiled,
        Some(other) => bail!("bad --cost-model {other:?} (compositional|compiled)"),
    };
    let cap = match opt(args, "--cap") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => bail!("--cap expects a number >= 1"),
        },
        None => None,
    };
    let opts = AutomapOptions {
        top_k: opt_u32(args, "--top", 8)? as usize,
        n_inf: opt_u32(args, "--inferences", 5)?,
        jobs: parallel::jobs(),
        model,
        cap,
        depth: opt_u32(args, "--depth", 8)? as usize,
        max_replica: opt_u32(args, "--max-replica", 8)? as usize,
        compile_cache: !args.iter().any(|a| a == "--no-compile-cache"),
    };
    println!(
        "automap: searching {} (depth 1..{}, replication <= {}, {} cost model, {}) ...",
        graph.name,
        opts.depth,
        opts.max_replica,
        match opts.model {
            CostModel::Compositional => "compositional",
            CostModel::Compiled => "compiled-oracle",
        },
        match opts.cap {
            Some(c) => format!("capped at {c}"),
            None => "branch-and-bound, uncapped".into(),
        },
    );
    let rep = automap_driver::run_search(&graph, &budget, system, opts)?;
    println!(
        "automap: {} candidates enumerated / {} pruned by bounds / {} scored feasible{}; {} simulated on {}",
        rep.enumerated,
        rep.pruned,
        rep.feasible,
        if rep.truncated { " (space truncated)" } else { "" },
        rep.rows.len(),
        system.name(),
    );
    let cache_line = |tag: &str, s: &alpine::workload::compile::cache::CompileCacheStats| {
        println!(
            "automap: {tag} compile cache: {} hits / {} misses, {:.1} KiB fragment arena",
            s.hits,
            s.misses,
            s.arena_bytes as f64 / 1024.0,
        );
    };
    if let Some(s) = &rep.search_cache {
        cache_line("search", s);
    }
    if let Some(s) = &rep.validate_cache {
        cache_line("validate", s);
    }
    report::automap_table(&format!("automap — {}", graph.name), &rep).print();
    println!(
        "best: {} — {:.2}x vs the all-digital single-core baseline; {} mapping(s) on the Pareto front",
        rep.best_row().desc,
        rep.speedup_vs_baseline(),
        rep.front().count(),
    );
    Ok(())
}

/// Shared driver of the DAG deliverable subcommands (`resnet`, `moe`):
/// automap the fork/join graph under the budget, validate the winners
/// end-to-end on the trace machine (nested fast-forward intact), and
/// print the Pareto front.
fn run_dag_search(graph: LayerGraph, args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let cfg = SystemConfig::for_kind(system);
    let budget = parse_budget(args, &cfg)?;
    let opts = AutomapOptions {
        top_k: opt_u32(args, "--top", 4)? as usize,
        n_inf: opt_u32(args, "--inferences", 5)?,
        jobs: parallel::jobs(),
        depth: opt_u32(args, "--depth", 4)? as usize,
        ..AutomapOptions::default()
    };
    println!("{}: searching {} (depth 1..{}) ...", args_cmd_name(&graph), graph.name, opts.depth);
    let rep = automap_driver::run_search(&graph, &budget, system, opts)?;
    report::automap_table(&format!("automap — {}", graph.name), &rep).print();
    println!(
        "best: {} — {:.2}x vs the all-digital single-core baseline; {} mapping(s) on the Pareto front",
        rep.best_row().desc,
        rep.speedup_vs_baseline(),
        rep.front().count(),
    );
    Ok(())
}

/// Subcommand tag for progress lines (derived from the graph family).
fn args_cmd_name(graph: &LayerGraph) -> &'static str {
    if graph.name.starts_with("moe") {
        "moe"
    } else if graph.name.starts_with("resnet") {
        "resnet"
    } else {
        "dag"
    }
}

/// `resnet` — a residual block (two 3x3 convolutions forked around an
/// identity skip, joined by an elementwise add) + classifier head,
/// automapped and simulated end-to-end.
fn cmd_resnet(args: &[String]) -> Result<()> {
    let hw = opt_u32(args, "--hw", 8)? as u64;
    let ch = opt_u32(args, "--ch", 4)? as u64;
    let classes = opt_u32(args, "--classes", 10)? as u64;
    if hw < 3 || ch < 1 || classes < 1 {
        bail!("resnet needs --hw >= 3, --ch >= 1, --classes >= 1");
    }
    if (hw * hw * ch) % 4 != 0 {
        bail!("resnet needs hw*hw*ch divisible by 4 (got {hw}x{hw}x{ch})");
    }
    run_dag_search(LayerGraph::resnet_block(hw, ch, classes), args)
}

/// `moe` — a top-k mixture-of-experts layer (router + expert bank, the
/// replica axis doubling as expert parallelism) + classifier head,
/// automapped and simulated end-to-end.
fn cmd_moe(args: &[String]) -> Result<()> {
    let d_in = opt_u32(args, "--d-in", 64)? as u64;
    let d_model = opt_u32(args, "--d-model", 32)? as u64;
    let experts = opt_u32(args, "--experts", 4)? as u64;
    let top_k = opt_u32(args, "--top-k", 2)? as u64;
    let classes = opt_u32(args, "--classes", 10)? as u64;
    if experts < 1 || top_k < 1 || top_k > experts {
        bail!("moe needs --experts >= 1 and --top-k in 1..=experts");
    }
    if d_in < 4 || d_in % 4 != 0 {
        bail!("moe needs --d-in to be a multiple of 4");
    }
    run_dag_search(LayerGraph::moe(d_in, d_model, experts, top_k, classes), args)
}

/// `transformer` — sweep the hand-written transformer-encoder mappings
/// (digital reference vs packed analog) through the parallel engine.
fn cmd_transformer(args: &[String]) -> Result<()> {
    let shape = parse_transformer_shape(args)?;
    let n = opt_u32(args, "--inferences", experiments::TRANSFORMER_INFERENCES)?;
    let mut cases = experiments::transformer_cases(shape);
    if let Some(sys) = opt(args, "--system") {
        let sys = SystemKind::parse(&sys).context("bad --system (hp|lp)")?;
        cases.retain(|c| matches!(c, experiments::SweepCase::Transformer { kind, .. } if *kind == sys));
    }
    let rows = experiments::run_cases(&cases, n, parallel::jobs())?;
    report::aggregate_table(&format!("transformer {shape} — hand mappings"), &rows).print();
    report::gains_table("gains vs DIG-1core", &rows, |r| r.label.ends_with("DIG-1core")).print();
    println!("hint: `alpine automap --d-model {}` searches beyond these hand mappings", shape.d_model);
    Ok(())
}

/// `faults` — sweep fault intensity and report graceful degradation
/// (§IV.C non-idealities + hard tile failure with digital-fallback
/// remapping). Writes the machine-readable curve to `--out`
/// (default BENCH_faults.json).
fn cmd_faults(args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let mut opts =
        FaultScenarioOptions { system, jobs: parallel::jobs(), ..FaultScenarioOptions::default() };
    if let Some(v) = opt(args, "--seed") {
        opts.seed = v.parse().context("--seed expects a number")?;
    }
    if let Some(v) = opt(args, "--noise") {
        opts.max_noise_sigma = v.parse().context("--noise expects a sigma, e.g. 0.1")?;
    }
    if let Some(v) = opt(args, "--drift") {
        opts.max_drift_t_s = v.parse().context("--drift expects seconds, e.g. 1e6")?;
    }
    if let Some(v) = opt(args, "--stuck") {
        opts.max_stuck_rate = v.parse().context("--stuck expects a rate in [0, 1]")?;
    }
    opts.steps = opt_u32(args, "--steps", opts.steps as u32)? as usize;
    opts.n_inf = opt_u32(args, "--inferences", opts.n_inf)?;
    if let Some(v) = opt(args, "--fail-tile") {
        let (t, c) = v
            .split_once('@')
            .and_then(|(t, c)| Some((t.trim().parse().ok()?, c.trim().parse().ok()?)))
            .context("--fail-tile expects T@CYCLE, e.g. 0@50000")?;
        opts.fail_tile = Some((t, c));
    }

    let rep = faults_driver::run_scenario(&opts)?;
    println!(
        "faults: {} on {} ({} tile(s)), seed {}",
        rep.desc,
        rep.system.name(),
        rep.tiles,
        opts.seed
    );
    let mut t = Table::new(
        "fault-intensity degradation curve",
        &["intensity", "sigma", "drift [s]", "stall [ns]", "mse", "top-1", "time [us]", "energy [uJ]"],
    );
    for p in &rep.curve {
        t.row(vec![
            format!("{:.2}", p.intensity),
            format!("{:.4}", p.plan.noise_sigma),
            format!("{:.1}", p.plan.drift_t_s),
            format!("{:.1}", p.stall_ps as f64 / 1e3),
            format!("{:.3e}", p.mse),
            format!("{:.3}", p.top1_agreement),
            format!("{:.3}", p.time_s * 1e6),
            format!("{:.3}", p.energy_j * 1e6),
        ]);
    }
    t.print();
    if let Some(f) = &rep.failure {
        match &f.error {
            Some(e) => println!("hard failure of tile {} at {} ps: {e}", f.tile, f.fail_at_ps),
            None => println!(
                "hard failure of tile {} at {} ps: run completed before touching the tile",
                f.tile, f.fail_at_ps
            ),
        }
        println!(
            "degraded remap: {} ({} anchor(s) to digital CPU) — {:.2}x slowdown ({:.3} us -> {:.3} us)",
            f.degraded_desc,
            f.remapped_anchors.len(),
            f.slowdown(),
            f.healthy.time_s * 1e6,
            f.degraded.time_s * 1e6,
        );
    }
    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_faults.json".into());
    faults_driver::write_report(&rep, &out)?;
    Ok(())
}

/// `serve-bench` — the ISSUE-9 serving deliverable: sweep offered load
/// against a cluster of model replicas sharded across simulated ALPINE
/// chips (SLO-aware dynamic batching, admission control + backpressure,
/// per-request deadlines, bounded retries, replica failover with
/// degraded-cost rejoin), print the latency-vs-offered-load curve, and
/// write it to `--out` (default BENCH_serving.json). Deterministic:
/// same seed => byte-identical JSON at any `--jobs N`.
fn cmd_serve_bench(args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let mut opts =
        ServeBenchOptions { system, jobs: parallel::jobs(), ..ServeBenchOptions::default() };
    if let Some(v) = opt(args, "--seed") {
        opts.seed = v.parse().context("--seed expects a number")?;
    }
    opts.requests = opt_u32(args, "--requests", opts.requests as u32)? as u64;
    opts.replicas = opt_u32(args, "--replicas", opts.replicas as u32)? as usize;
    opts.max_batch = opt_u32(args, "--max-batch", opts.max_batch as u32)? as usize;
    opts.queue_cap = opt_u32(args, "--queue-cap", opts.queue_cap as u32)? as usize;
    opts.max_retries = opt_u32(args, "--retries", opts.max_retries)?;
    let us_knob = |name: &str| -> Result<Option<u64>> {
        match opt(args, name) {
            None => Ok(None),
            Some(v) => {
                let x: f64 = v.parse().with_context(|| format!("{name} expects microseconds"))?;
                if !x.is_finite() || x <= 0.0 {
                    bail!("{name} expects microseconds > 0");
                }
                Ok(Some((x * 1e6).round() as u64))
            }
        }
    };
    if let Some(v) = us_knob("--deadline-us")? {
        opts.deadline_ps = Some(v);
    }
    if let Some(v) = us_knob("--batch-wait-us")? {
        opts.batch_wait_ps = Some(v);
    }
    if let Some(v) = us_knob("--backoff-us")? {
        opts.backoff_base_ps = Some(v);
    }
    if let Some(v) = us_knob("--repair-us")? {
        opts.repair_ps = Some(v);
    }
    if let Some(v) = opt(args, "--policy") {
        opts.policy = RouterPolicy::parse(&v)
            .with_context(|| format!("bad --policy {v:?} (rr|least-loaded|affinity)"))?;
    }
    if let Some(v) = opt(args, "--arrival") {
        opts.arrival = ArrivalProcess::parse(&v)
            .with_context(|| format!("bad --arrival {v:?} (uniform|poisson|bursty|diurnal)"))?;
    }
    // Shape knobs of the non-homogeneous arrival processes.
    match &mut opts.arrival {
        ArrivalProcess::Bursty { burst_x, period_s, duty, .. } => {
            if let Some(v) = opt(args, "--burst-x") {
                *burst_x = v.parse().context("--burst-x expects a multiplier >= 1")?;
            }
            if let Some(v) = opt(args, "--period-us") {
                *period_s =
                    v.parse::<f64>().context("--period-us expects microseconds")? * 1e-6;
            }
            if let Some(v) = opt(args, "--duty") {
                *duty = v.parse().context("--duty expects a fraction in (0, 1)")?;
            }
        }
        ArrivalProcess::Diurnal { amplitude, period_s, .. } => {
            if let Some(v) = opt(args, "--amplitude") {
                *amplitude = v.parse().context("--amplitude expects a fraction in [0, 1]")?;
            }
            if let Some(v) = opt(args, "--period-us") {
                *period_s =
                    v.parse::<f64>().context("--period-us expects microseconds")? * 1e-6;
            }
        }
        _ => {}
    }
    if let Some(v) = opt(args, "--load-points") {
        opts.load_fracs = v
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--load-points: bad fraction {p:?}"))
            })
            .collect::<Result<Vec<f64>>>()?;
    }
    if let Some(v) = opt(args, "--fail-replica") {
        let (r, frac) = v
            .split_once('@')
            .and_then(|(r, f)| {
                let r = r.trim().parse().ok()?;
                let f = if f.trim() == "mid" { 0.5 } else { f.trim().parse().ok()? };
                Some((r, f))
            })
            .context("--fail-replica expects R@FRAC, e.g. 1@mid or 1@0.75")?;
        opts.fail_replica = Some((r, frac));
    }
    if let Some(v) = opt(args, "--shape") {
        opts.shape = MlpShape::parse(&v)?.dims().to_vec();
    }

    println!(
        "serve-bench: {} replica(s) x batch {} on {}, policy {}, arrival {}, seed {:#x} ...",
        opts.replicas,
        opts.max_batch,
        system.name(),
        opts.policy.name(),
        opts.arrival.desc(),
        opts.seed,
    );
    let rep = serving_driver::run_serve_bench(&opts)?;
    println!(
        "backend: {} — batch {} in {:.3} us healthy / {:.3} us degraded{}",
        rep.backend_desc,
        rep.max_batch,
        *rep.service_ps.last().unwrap() as f64 / 1e6,
        *rep.degraded_service_ps.last().unwrap() as f64 / 1e6,
        match &rep.degraded_desc {
            Some(d) => format!(" ({d})"),
            None => String::new(),
        },
    );
    let mut t = Table::new(
        "latency vs offered load",
        &[
            "load", "offered [rps]", "served", "shed", "t/out", "slo-x", "retry", "f/over",
            "batch", "p50 [us]", "p95 [us]", "p99 [us]", "achieved [rps]",
        ],
    );
    for p in &rep.points {
        t.row(vec![
            format!("{:.2}x", p.load_frac),
            format!("{:.3e}", p.offered_rps),
            p.counters.served.to_string(),
            p.counters.shed().to_string(),
            p.counters.timed_out.to_string(),
            p.counters.slo_violations.to_string(),
            p.counters.retries.to_string(),
            p.counters.failovers.to_string(),
            format!("{:.1}", p.mean_batch),
            format!("{:.3}", p.p50_ps as f64 / 1e6),
            format!("{:.3}", p.p95_ps as f64 / 1e6),
            format!("{:.3}", p.p99_ps as f64 / 1e6),
            format!("{:.3e}", p.achieved_rps),
        ]);
    }
    t.print();
    println!(
        "saturation: {:.3e} rps estimated / {:.3e} rps measured{}",
        rep.saturation_rps_est,
        rep.saturation_rps_measured,
        match rep.knee_frac {
            Some(f) => format!("; p99 knee at {f:.2}x offered load"),
            None => "; no p99 knee inside the sweep".into(),
        },
    );
    if let Some((r, f)) = rep.fail_replica {
        let failovers: u64 = rep.points.iter().map(|p| p.counters.failovers).sum();
        let fo_served: u64 = rep.points.iter().map(|p| p.counters.failover_served).sum();
        let fo_slo_ok: u64 = rep.points.iter().map(|p| p.counters.failover_slo_ok).sum();
        println!(
            "failure plan: replica {r} hard-fails at {f:.2} of each point's span — \
             {failovers} failover(s); {fo_served} failed-over request(s) served, \
             {fo_slo_ok} within SLO"
        );
    }
    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_serving.json".into());
    serving_driver::write_report(&rep, &out)?;
    Ok(())
}

/// `reliability` — the ISSUE-10 drift-aware serving deliverable: sweep
/// virtual horizon x recalibration policy (never | fixed | threshold)
/// over the automap-best pipeline under PCM conductance drift, print
/// the policy comparison, and write `--out` (default
/// BENCH_reliability.json). Deterministic: same seed => byte-identical
/// JSON at any `--jobs N`.
fn cmd_reliability(args: &[String]) -> Result<()> {
    let system = SystemKind::parse(&opt(args, "--system").unwrap_or_else(|| "hp".into()))
        .context("bad --system (hp|lp)")?;
    let mut opts =
        ReliabilityOptions { system, jobs: parallel::jobs(), ..ReliabilityOptions::default() };
    if let Some(v) = opt(args, "--seed") {
        opts.seed = v.parse().context("--seed expects a number")?;
    }
    opts.steps = opt_u32(args, "--steps", opts.steps as u32)? as usize;
    opts.requests = opt_u32(args, "--requests", opts.requests as u32)? as u64;
    opts.replicas = opt_u32(args, "--replicas", opts.replicas as u32)? as usize;
    opts.max_batch = opt_u32(args, "--max-batch", opts.max_batch as u32)? as usize;
    opts.queue_cap = opt_u32(args, "--queue-cap", opts.queue_cap as u32)? as usize;
    opts.sensitive_permille =
        opt_u32(args, "--sensitive-permille", opts.sensitive_permille)?;
    opts.timeline = opt_u32(args, "--timeline", opts.timeline as u32)? as usize;
    let f64_knob = |name: &str| -> Result<Option<f64>> {
        match opt(args, name) {
            None => Ok(None),
            Some(v) => {
                let x: f64 =
                    v.parse().with_context(|| format!("{name} expects a number"))?;
                if !x.is_finite() {
                    bail!("{name} expects a finite number");
                }
                Ok(Some(x))
            }
        }
    };
    if let Some(v) = f64_knob("--nu")? {
        opts.nu = v;
    }
    if let Some(v) = f64_knob("--nu-sigma")? {
        opts.nu_sigma = v;
    }
    opts.slo = f64_knob("--slo")?.or(opts.slo);
    opts.threshold = f64_knob("--threshold")?.or(opts.threshold);
    opts.fixed_period_s = f64_knob("--fixed-period")?.or(opts.fixed_period_s);
    opts.check_period_s = f64_knob("--check-period")?.or(opts.check_period_s);
    if let Some(v) = opt(args, "--horizons") {
        opts.horizons_s = v
            .split(',')
            .map(|h| {
                h.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--horizons: bad seconds value {h:?}"))
            })
            .collect::<Result<Vec<f64>>>()?;
    }
    if args.iter().any(|a| a == "--horizon-short") {
        // CI-smoke scale: one short horizon (still long enough for the
        // log-time dispersion to bite).
        opts.horizons_s = vec![1.0e5];
    }
    if let Some(v) = opt(args, "--shape") {
        opts.shape = MlpShape::parse(&v)?.dims().to_vec();
    }

    println!(
        "reliability: {} replica(s) on {}, nu {:.3} / nu-sigma {:.3}, horizons {:?} s, seed {:#x} ...",
        opts.replicas,
        system.name(),
        opts.nu,
        opts.nu_sigma,
        opts.horizons_s,
        opts.seed,
    );
    let rep = reliability_driver::run_reliability(&opts)?;
    println!(
        "backend: {} — accuracy SLO {:.4} (degrade at {:.4}, threshold trigger {:.4}), \
         SLO-crossing age {:.3e} s, reprogram {:.3} us/window",
        rep.backend_desc,
        rep.slo,
        rep.degrade_at,
        rep.threshold_trigger,
        rep.slo_cross_ps as f64 / 1e12,
        rep.reprogram_ps as f64 / 1e6,
    );
    let mut t = Table::new(
        "recalibration policy comparison",
        &[
            "policy", "horizon [s]", "served", "shed-acc", "stale", "recals",
            "downtime [s]", "min-avail", "slo-ok", "achieved [rps]",
        ],
    );
    for c in &rep.cells {
        t.row(vec![
            c.policy.name().to_string(),
            format!("{:.1e}", c.horizon_s),
            c.counters.served.to_string(),
            c.counters.shed_accuracy_slo.to_string(),
            c.counters.served_below_slo.to_string(),
            c.counters.recals.to_string(),
            format!("{:.3}", c.counters.recal_downtime_ps as f64 / 1e12),
            c.min_available_replicas.to_string(),
            if c.slo_ok { "yes" } else { "NO" }.to_string(),
            format!("{:.3e}", c.achieved_rps),
        ]);
    }
    t.print();
    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_reliability.json".into());
    reliability_driver::write_report(&rep, &out)?;
    Ok(())
}

fn validate() -> Result<()> {
    let rt = Runtime::new(&default_artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());
    let mut t = Table::new("artifact probe checks", &["model", "max_abs_err", "rel_l2_err", "status"]);
    for name in rt.available_models()? {
        let model = rt.load(&name)?;
        let (max_abs, rel) = model.probe_check()?;
        let ok = rel < 1e-5;
        t.row(vec![
            name,
            format!("{max_abs:.3e}"),
            format!("{rel:.3e}"),
            if ok { "OK" } else { "FAIL" }.into(),
        ]);
        if !ok {
            bail!("probe check failed");
        }
    }
    t.print();
    Ok(())
}
