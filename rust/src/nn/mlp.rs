//! The MLP of Exploration One (§VII): two dense (1024, 1024) layers with
//! ReLU activations (Fig. 6a).

/// MLP architecture: `layers` dense layers of `dim x dim` weights.
#[derive(Clone, Copy, Debug)]
pub struct MlpModel {
    pub dim: u64,
    pub layers: u64,
}

impl MlpModel {
    /// The paper's instance: two 1024x1024 layers.
    pub fn paper() -> MlpModel {
        MlpModel { dim: 1024, layers: 2 }
    }

    pub fn weight_bytes_per_layer(&self) -> u64 {
        self.dim * self.dim // int8
    }

    pub fn total_weight_bytes(&self) -> u64 {
        self.layers * self.weight_bytes_per_layer()
    }

    /// MACs per inference (digital reference).
    pub fn macs_per_inference(&self) -> u64 {
        self.layers * self.dim * self.dim
    }

    /// §VII.E digital working set: 2W + x + l1 + y = 2n^2 + 3n bytes
    /// (weights + input + intermediate + output, all int8).
    pub fn working_set_digital(&self) -> u64 {
        self.total_weight_bytes() + (self.layers + 1) * self.dim
    }

    /// §VII.E analog working set: weights stay in the tiles; x + l1 + y =
    /// 3n bytes.
    pub fn working_set_analog(&self) -> u64 {
        (self.layers + 1) * self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let m = MlpModel::paper();
        assert_eq!(m.total_weight_bytes(), 2 * 1024 * 1024);
        assert_eq!(m.macs_per_inference(), 2 * 1024 * 1024);
    }

    #[test]
    fn working_set_digital_matches_paper_2_1mb() {
        // §VII.E: "2*n^2 + 3n ≈ 2.1 MB for n = 1024".
        let ws = MlpModel::paper().working_set_digital();
        assert_eq!(ws, 2 * 1024 * 1024 + 3 * 1024);
        assert!((ws as f64 - 2.1e6).abs() / 2.1e6 < 0.02);
    }

    #[test]
    fn working_set_analog_matches_paper_3kb() {
        // §VII.E: "x + l1 + y = 3n ≈ 3 kB".
        assert_eq!(MlpModel::paper().working_set_analog(), 3 * 1024);
    }

    #[test]
    fn digital_working_set_exceeds_all_paper_caches() {
        let ws = MlpModel::paper().working_set_digital();
        assert!(ws > 1024 * 1024, "exceeds HP LLC");
        assert!(MlpModel::paper().working_set_analog() < 32 * 1024, "fits LP L1");
    }
}
