//! The CNNs of Exploration Three (§IX, Fig. 12): the CNN-F(ast),
//! CNN-M(edium) and CNN-S(low) variants of Chatfield et al. [42],
//! 224x224x3 input, 5 convolutional layers (AIMC-mapped) + 3 dense
//! layers (CPU-side), ReLU everywhere, softmax at the end.

/// The three variants of Fig. 12(b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CnnVariant {
    Fast,
    Medium,
    Slow,
}

impl CnnVariant {
    pub const ALL: [CnnVariant; 3] = [CnnVariant::Fast, CnnVariant::Medium, CnnVariant::Slow];

    pub fn name(&self) -> &'static str {
        match self {
            CnnVariant::Fast => "CNN-F",
            CnnVariant::Medium => "CNN-M",
            CnnVariant::Slow => "CNN-S",
        }
    }

    pub fn parse(s: &str) -> Option<CnnVariant> {
        match s.to_ascii_lowercase().as_str() {
            "f" | "fast" | "cnn-f" => Some(CnnVariant::Fast),
            "m" | "medium" | "cnn-m" => Some(CnnVariant::Medium),
            "s" | "slow" | "cnn-s" => Some(CnnVariant::Slow),
            _ => None,
        }
    }

    /// Fig. 12(b): total AIMC-mapped (convolutional) parameters.
    pub fn paper_aimc_params(&self) -> f64 {
        match self {
            CnnVariant::Fast => 1.7e6,
            CnnVariant::Medium => 5.6e6,
            CnnVariant::Slow => 5.5e6,
        }
    }
}

/// One convolutional layer with its post-ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CnnLayer {
    pub name: &'static str,
    pub in_hw: u64,
    pub in_ch: u64,
    pub kernel: u64,
    pub out_ch: u64,
    pub stride: u64,
    pub pad: u64,
    /// Max-pool window after the layer (1 = none; the paper's "x2"/"x3").
    pub pool: u64,
    /// Max-pool stride (Chatfield [42]: 2 for most layers, 3 for the
    /// aggressive CNN-S conv1/conv5 pools).
    pub pool_stride: u64,
    /// Local response normalization after the layer.
    pub lrn: bool,
}

impl CnnLayer {
    pub fn out_hw(&self) -> u64 {
        (self.in_hw + 2 * self.pad - self.kernel) / self.stride + 1
    }

    /// Spatial size after pooling. The paper's "x2"/"x3" notation is the
    /// pool *window* (Chatfield et al. [42]); CNN-S's 3x3 windows more
    /// than double the pooling compute per output ("increases the
    /// computational requirements of CNN-S significantly", §IX.A).
    pub fn pooled_hw(&self) -> u64 {
        if self.pool <= 1 {
            self.out_hw()
        } else {
            (self.out_hw() - self.pool) / self.pool_stride + 1
        }
    }

    /// im2col geometry: K rows (flattened kernel), out_ch columns.
    pub fn im2col_rows(&self) -> u64 {
        self.kernel * self.kernel * self.in_ch
    }

    pub fn weight_params(&self) -> u64 {
        self.im2col_rows() * self.out_ch
    }

    pub fn output_pixels(&self) -> u64 {
        self.out_hw() * self.out_hw()
    }

    pub fn macs(&self) -> u64 {
        self.output_pixels() * self.im2col_rows() * self.out_ch
    }

    /// Elements the post-ops (ReLU/LRN/pool) touch.
    pub fn post_elems(&self) -> u64 {
        self.output_pixels() * self.out_ch
    }
}

/// A full CNN: conv stack + dense widths.
#[derive(Clone, Debug)]
pub struct CnnModel {
    pub variant: CnnVariant,
    pub convs: Vec<CnnLayer>,
    pub dense: [u64; 3],
}

impl CnnModel {
    /// Fig. 12(b) + Chatfield et al. [42], row by row. Spatial chaining
    /// uses each layer's pooled output as the next layer's input; the
    /// conv2 stride and pool windows/strides follow [42] per variant so
    /// the dense-layer fan-in stays at its published 6x6-scale size.
    pub fn paper(variant: CnnVariant) -> CnnModel {
        use CnnVariant::*;
        // (kernel, out_ch, stride, pad, pool_window, pool_stride, lrn)
        let rows: [(u64, u64, u64, u64, u64, u64, bool); 5] = match variant {
            Fast => [
                (11, 64, 4, 0, 2, 2, true),
                (5, 256, 1, 2, 2, 2, true),
                (3, 256, 1, 1, 1, 1, false),
                (3, 256, 1, 1, 1, 1, false),
                (3, 256, 1, 1, 2, 2, false),
            ],
            Medium => [
                (7, 96, 2, 0, 3, 2, true),
                (5, 256, 2, 1, 2, 2, true),
                (3, 512, 1, 1, 1, 1, false),
                (3, 512, 1, 1, 1, 1, false),
                (3, 512, 1, 1, 2, 2, false),
            ],
            Slow => [
                (7, 96, 2, 0, 3, 3, true),
                (5, 256, 1, 1, 2, 2, false),
                (3, 512, 1, 1, 1, 1, false),
                (3, 512, 1, 1, 1, 1, false),
                (3, 512, 1, 1, 3, 3, false),
            ],
        };
        let names = ["conv1", "conv2", "conv3", "conv4", "conv5"];
        let mut convs: Vec<CnnLayer> = Vec::new();
        let mut in_hw = 224;
        let mut in_ch = 3;
        for (i, (k, n, s, p, pw, ps, lrn)) in rows.into_iter().enumerate() {
            let layer = CnnLayer {
                name: names[i],
                in_hw,
                in_ch,
                kernel: k,
                out_ch: n,
                stride: s,
                pad: p,
                pool: pw,
                pool_stride: ps,
                lrn,
            };
            in_hw = layer.pooled_hw();
            in_ch = n;
            convs.push(layer);
        }
        CnnModel { variant, convs, dense: [4096, 4096, 1000] }
    }

    pub fn aimc_params(&self) -> u64 {
        self.convs.iter().map(|l| l.weight_params()).sum()
    }

    pub fn dense_inputs(&self) -> u64 {
        let last = self.convs.last().unwrap();
        last.pooled_hw() * last.pooled_hw() * last.out_ch
    }

    pub fn dense_params(&self) -> u64 {
        let d0 = self.dense_inputs() * self.dense[0];
        let d1 = self.dense[0] * self.dense[1];
        let d2 = self.dense[1] * self.dense[2];
        d0 + d1 + d2
    }

    pub fn conv_macs(&self) -> u64 {
        self.convs.iter().map(|l| l.macs()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_geometry_matches_chatfield() {
        let f = CnnModel::paper(CnnVariant::Fast);
        assert_eq!(f.convs[0].out_hw(), 54); // (224-11)/4+1
        assert_eq!(f.convs[0].pooled_hw(), 27);
        let s = CnnModel::paper(CnnVariant::Slow);
        assert_eq!(s.convs[0].out_hw(), 109); // (224-7)/2+1
        assert_eq!(s.convs[0].pooled_hw(), 36); // 3x3 window, stride 3
    }

    #[test]
    fn aimc_params_same_order_as_paper() {
        // Fig. 12(b): 1.7M / 5.6M / 5.5M AIMC params. Our weight-only
        // count (no grouping/bias bookkeeping) is within ~40%.
        for v in CnnVariant::ALL {
            let ours = CnnModel::paper(v).aimc_params() as f64;
            let paper = v.paper_aimc_params();
            let rel = (ours - paper).abs() / paper;
            assert!(rel < 0.45, "{}: ours {ours} vs paper {paper}", v.name());
        }
    }

    #[test]
    fn slow_variant_has_more_pooling_work_than_medium() {
        let m = CnnModel::paper(CnnVariant::Medium);
        let s = CnnModel::paper(CnnVariant::Slow);
        // Bigger pool windows/strides on S (x3 vs x2 at conv5), and S's
        // conv1 pool keeps LRN-scale maps longer (stride 3 vs M's 2).
        assert_eq!(s.convs[0].pool, 3);
        assert_eq!(s.convs[0].pool_stride, 3);
        assert_eq!(m.convs[0].pool_stride, 2);
        assert_eq!(s.convs[4].pool, 3);
        assert_eq!(m.convs[4].pool, 2);
    }

    #[test]
    fn five_convs_three_dense() {
        for v in CnnVariant::ALL {
            let m = CnnModel::paper(v);
            assert_eq!(m.convs.len(), 5);
            assert_eq!(m.dense[2], 1000);
            assert!(m.dense_inputs() > 0);
        }
    }

    #[test]
    fn conv_macs_dominated_by_conv2_plus() {
        let f = CnnModel::paper(CnnVariant::Fast);
        let conv1 = f.convs[0].macs();
        let rest: u64 = f.convs[1..].iter().map(|l| l.macs()).sum();
        assert!(rest > 2 * conv1);
    }

    #[test]
    fn variant_parsing() {
        assert_eq!(CnnVariant::parse("s"), Some(CnnVariant::Slow));
        assert_eq!(CnnVariant::parse("CNN-F"), Some(CnnVariant::Fast));
        assert_eq!(CnnVariant::parse("zzz"), None);
    }
}
