//! The LSTM of Exploration Two (§VIII, Fig. 9, Table II): one LSTM cell
//! layer of width `n_h` plus one dense layer, input/output width 50 (PTB
//! character model).

/// LSTM architecture parameters (Table II-A).
#[derive(Clone, Copy, Debug)]
pub struct LstmModel {
    pub x: u64,
    pub n_h: u64,
    pub y: u64,
}

/// Table II-B: the paper's AIMC tile dimensions per case (rows, cols).
/// Carried verbatim for the Table II bench; our own layouts are computed
/// by `cell_rows`/`cell_cols` and differ slightly (the paper's totals
/// include bias rows we do not model — see DESIGN.md).
pub const PAPER_TILE_DIMS: [(u64, [(u64, u64); 4]); 3] = [
    (256, [(612, 1074), (356, 1074), (356, 1024), (356, 256)]),
    (512, [(1124, 2098), (612, 2098), (612, 2048), (612, 512)]),
    (750, [(1600, 3050), (850, 3050), (850, 3000), (850, 750)]),
];

/// Table II-A: the paper's total parameter counts.
pub const PAPER_TOTAL_PARAMS: [(u64, f64); 3] =
    [(256, 377.3e3), (512, 1.28e6), (750, 2.6e6)];

impl LstmModel {
    pub fn paper(n_h: u64) -> LstmModel {
        LstmModel { x: 50, n_h, y: 50 }
    }

    /// Rows of the cell weight matrix: the concatenated [h, x] input.
    pub fn cell_rows(&self) -> u64 {
        self.n_h + self.x
    }

    /// Columns: the four gate matrices side by side (§VIII.D).
    pub fn cell_cols(&self) -> u64 {
        4 * self.n_h
    }

    pub fn dense_rows(&self) -> u64 {
        self.n_h
    }

    pub fn dense_cols(&self) -> u64 {
        self.y
    }

    pub fn total_params(&self) -> u64 {
        self.cell_rows() * self.cell_cols() + self.dense_rows() * self.dense_cols()
    }

    /// MACs per inference step (4 gate MVMs + dense MVM).
    pub fn macs_per_inference(&self) -> u64 {
        self.cell_rows() * self.cell_cols() + self.n_h * self.y
    }

    /// §VIII.E digital working set (bytes, int8):
    /// (x + n_h) + 4(n_h^2 + n_h x) + n_h + n_h y + y.
    pub fn working_set_digital(&self) -> u64 {
        (self.x + self.n_h)
            + 4 * (self.n_h * self.n_h + self.n_h * self.x)
            + self.n_h
            + self.n_h * self.y
            + self.y
    }

    /// §VIII.E analog working set: (x + n_h) + n_h + y.
    pub fn working_set_analog(&self) -> u64 {
        (self.x + self.n_h) + self.n_h + self.y
    }

    /// Paper tile dims for (n_h, case 1..=4), if published.
    pub fn paper_tile_dims(n_h: u64, case: usize) -> Option<(u64, u64)> {
        assert!((1..=4).contains(&case));
        PAPER_TILE_DIMS
            .iter()
            .find(|(nh, _)| *nh == n_h)
            .map(|(_, dims)| dims[case - 1])
    }

    /// Linear-complexity digital element ops per step (sigmoid/tanh on
    /// gates, elementwise combines, softmax): used for complexity tests.
    pub fn linear_ops_per_inference(&self) -> u64 {
        // 3 sigmoid(n_h) + 2 tanh(n_h) + 4 elementwise(n_h) + softmax(y)
        9 * self.n_h + 2 * self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_nh256() {
        let m = LstmModel::paper(256);
        assert_eq!(m.cell_rows(), 306);
        assert_eq!(m.cell_cols(), 1024);
        assert_eq!(m.dense_rows(), 256);
        assert_eq!(m.dense_cols(), 50);
    }

    #[test]
    fn total_params_near_paper() {
        for (n_h, paper) in PAPER_TOTAL_PARAMS {
            let ours = LstmModel::paper(n_h).total_params() as f64;
            let rel = (ours - paper).abs() / paper;
            assert!(rel < 0.15, "n_h={n_h}: ours {ours} vs paper {paper}");
        }
    }

    #[test]
    fn working_sets_match_paper_section_8e() {
        // §VIII.E reports 378 kB / 1.28 MB / 2.59 MB digital; our
        // weight-only formula (no per-gate biases) runs ~3-14% lower,
        // same as the Table II parameter-count delta.
        let cases = [(256u64, 378e3), (512, 1.28e6), (750, 2.59e6)];
        for (n_h, paper) in cases {
            let ws = LstmModel::paper(n_h).working_set_digital() as f64;
            assert!((ws - paper).abs() / paper < 0.16, "n_h={n_h}: {ws}");
        }
        // Exact values of our formula (regression guard).
        assert_eq!(LstmModel::paper(256).working_set_digital(), 326_756);
        assert_eq!(LstmModel::paper(512).working_set_digital(), 1_177_700);
        assert_eq!(LstmModel::paper(750).working_set_digital(), 2_439_100);
        // §VIII.E analog: 0.66 kB / 1.17 kB / 1.65 kB — ours runs a
        // constant 50 B (one y-vector of bookkeeping) lower.
        let ana = [(256u64, 662.0), (512, 1174.0), (750, 1650.0)];
        for (n_h, expect) in ana {
            let ws = LstmModel::paper(n_h).working_set_analog() as f64;
            assert!((ws - expect).abs() / expect < 0.12, "n_h={n_h}: {ws}");
        }
        assert_eq!(LstmModel::paper(256).working_set_analog(), 612);
        assert_eq!(LstmModel::paper(512).working_set_analog(), 1124);
        assert_eq!(LstmModel::paper(750).working_set_analog(), 1600);
    }

    #[test]
    fn paper_tile_dims_table() {
        assert_eq!(LstmModel::paper_tile_dims(256, 1), Some((612, 1074)));
        assert_eq!(LstmModel::paper_tile_dims(750, 4), Some((850, 750)));
        assert_eq!(LstmModel::paper_tile_dims(512, 3), Some((612, 2048)));
        assert_eq!(LstmModel::paper_tile_dims(123, 1), None);
    }

    #[test]
    fn analog_ws_fits_l1_for_all_sizes() {
        for n_h in [256, 512, 750] {
            assert!(LstmModel::paper(n_h).working_set_analog() < 32 * 1024);
        }
    }

    #[test]
    fn digital_ws_exceeds_private_caches_for_512_up() {
        assert!(LstmModel::paper(512).working_set_digital() > 1024 * 1024);
        assert!(LstmModel::paper(750).working_set_digital() > 2 * 1024 * 1024);
    }
}
