//! Neural-network architecture models for the paper's three explorations:
//! parameter counts, computational complexity and working-set analysis
//! (§VII.D/E, §VIII.D/E, Fig. 12). These drive the workload generators
//! and are asserted against the paper's published numbers in tests.

pub mod cnn;
pub mod graph;
pub mod lstm;
pub mod mlp;

pub use cnn::{CnnLayer, CnnModel, CnnVariant};
pub use graph::{
    ActKind, GraphBuilder, GraphError, LayerGraph, LayerKind, LayerNode, MergeOp, NodeId,
    PendingNode,
};
pub use lstm::LstmModel;
pub use mlp::MlpModel;
