//! The layer-graph workload IR.
//!
//! A [`LayerGraph`] is a pure *model description*: a DAG of typed layer
//! nodes with shapes, independent of how (or where) each layer executes.
//! The paper's three explorations are instances of it (`LayerGraph::mlp`
//! / `lstm` / `cnn`), and arbitrary graphs can be built for new
//! workloads. Execution placement — which core runs a layer, whether its
//! MVM goes to the SIMD pipeline or an AIMC tile, how stages pipeline —
//! lives in `workload::compile::Mapping`; the pair is lowered to per-core
//! traces by `workload::compile::compile`.
//!
//! This mirrors the mapping flow of end-to-end AIMC compilers (Bruschi
//! et al., Garofalo et al.): network description first, placement second,
//! code generation last.

use crate::nn::cnn::CnnLayer;
use crate::nn::{CnnModel, LstmModel, MlpModel};

/// Index of a node in `LayerGraph::nodes`.
pub type NodeId = usize;

/// Digital activation flavours with distinct lowering costs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    Softmax,
}

/// One typed layer of the graph, with everything the mapping compiler
/// needs to cost it (shapes in elements, weight region slot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// fp32 source vector/image: a cold `bytes`-byte stream per inference
    /// plus `marshal_insts` of AIMClib input marshalling. `raw_bytes` is
    /// the int8 size of the same input (what replicated followers re-read
    /// from the LLC, and the unit of conv row-slice streaming).
    Input { bytes: u64, marshal_insts: u64, raw_bytes: u64 },

    /// Dense `rows x cols` int8 weight matrix at `addr::weights(slot)`.
    Dense { rows: u64, cols: u64, weight_slot: usize },

    /// One convolutional layer (with fused ReLU/LRN/pool post-ops, as in
    /// the paper's pipeline stages, §IX).
    Conv2d { layer: CnnLayer, weight_slot: usize },

    /// LSTM cell layer: the `(n_h + x) x 4n_h` four-gate MVM plus the
    /// digital gate activations and c/h elementwise combination (§VIII.D
    /// executes all four gates in one CM_PROCESS).
    LstmCell { x: u64, n_h: u64, weight_slot: usize },

    /// Elementwise digital activation over `elems` values.
    Activation { kind: ActKind, elems: u64 },

    /// Standalone max-pool over `elems` values with a `window`^2 kernel
    /// (the paper's CNN fuses pooling into Conv2d; this exists for custom
    /// graphs).
    Pool { elems: u64, window: u64 },

    /// Generic elementwise stage (e.g. residual add, scale) with explicit
    /// SIMD / scalar-FP instruction budgets.
    Elementwise { simd_insts: u64, fp_insts: u64 },

    /// Multi-head self-attention for one token step against a cached
    /// sequence of `seq` keys/values (transformer-encoder workloads).
    /// The four `d_model x d_model` projection matrices (Wq|Wk|Wv|Wo)
    /// are weight-stationary — AIMC-mappable — and live packed at
    /// `addr::weights(weight_slot)`; the score/softmax/context GEMVs run
    /// against the *dynamic* K/V caches (`addr::kv(weight_slot)`) and
    /// therefore always lower digitally (a PCM crossbar cannot be
    /// re-programmed per token).
    Attention { d_model: u64, heads: u64, seq: u64, weight_slot: usize },

    /// Layer normalization over `elems` values (mean/variance reduction
    /// plus per-element normalize, scale and shift).
    LayerNorm { elems: u64 },

    /// Result sink: `bytes` written back per inference.
    Output { bytes: u64 },
}

impl LayerKind {
    /// Input-vector length of the layer's MVM, if it has one (the number
    /// of elements queued into an AIMC tile mapped to this layer).
    /// `Attention` deliberately returns `None`: it is four MVMs plus a
    /// digital score block, placed through `Place::AttentionTiles`.
    pub fn mvm_rows(&self) -> Option<u64> {
        match self {
            LayerKind::Dense { rows, .. } => Some(*rows),
            LayerKind::Conv2d { layer, .. } => Some(layer.im2col_rows()),
            LayerKind::LstmCell { x, n_h, .. } => Some(n_h + x),
            _ => None,
        }
    }

    /// Output-vector length of the layer's MVM, if it has one.
    pub fn mvm_cols(&self) -> Option<u64> {
        match self {
            LayerKind::Dense { cols, .. } => Some(*cols),
            LayerKind::Conv2d { layer, .. } => Some(layer.out_ch),
            LayerKind::LstmCell { n_h, .. } => Some(4 * n_h),
            _ => None,
        }
    }
}

/// A node of the layer graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerNode {
    pub id: NodeId,
    pub kind: LayerKind,
}

/// The workload IR: typed layer nodes plus dataflow edges.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerGraph {
    pub name: String,
    pub nodes: Vec<LayerNode>,
    /// Dataflow edges `(producer, consumer)`.
    pub edges: Vec<(NodeId, NodeId)>,
}

impl LayerGraph {
    pub fn new(name: impl Into<String>) -> LayerGraph {
        LayerGraph { name: name.into(), nodes: Vec::new(), edges: Vec::new() }
    }

    /// Append a node, returning its id.
    pub fn add(&mut self, kind: LayerKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(LayerNode { id, kind });
        id
    }

    /// Append a node chained after `prev`.
    pub fn chain(&mut self, prev: NodeId, kind: LayerKind) -> NodeId {
        let id = self.add(kind);
        self.edges.push((prev, id));
        id
    }

    pub fn node(&self, id: NodeId) -> Option<&LayerNode> {
        self.nodes.get(id)
    }

    /// An MLP as a linear chain: `dims = [in, h1, .., out]` gives
    /// `dims.len() - 1` Dense+ReLU layers. `mlp(&[1024, 1024, 1024])` is
    /// the paper's Fig. 6(a) network.
    pub fn mlp(dims: &[u64]) -> LayerGraph {
        assert!(dims.len() >= 2, "an MLP needs at least [in, out] dims");
        let mut g = LayerGraph::new(format!("mlp[{}]", join_dims(dims)));
        let mut prev = g.add(LayerKind::Input {
            bytes: 4 * dims[0],
            marshal_insts: dims[0] / 4 + 40,
            raw_bytes: dims[0],
        });
        for l in 0..dims.len() - 1 {
            prev = g.chain(prev, LayerKind::Dense {
                rows: dims[l],
                cols: dims[l + 1],
                weight_slot: l,
            });
            prev = g.chain(prev, LayerKind::Activation {
                kind: ActKind::Relu,
                elems: dims[l + 1],
            });
        }
        g.chain(prev, LayerKind::Output { bytes: 4 * dims[dims.len() - 1] });
        g
    }

    /// The paper's MLP (§VII): two 1024x1024 Dense+ReLU layers.
    pub fn mlp_paper(m: &MlpModel) -> LayerGraph {
        let mut dims = vec![m.dim];
        dims.extend(std::iter::repeat(m.dim).take(m.layers as usize));
        LayerGraph::mlp(&dims)
    }

    /// The paper's LSTM (§VIII): cell layer + dense + softmax. Node ids:
    /// 0 input, 1 cell, 2 dense, 3 softmax, 4 output.
    pub fn lstm(m: &LstmModel) -> LayerGraph {
        let mut g = LayerGraph::new(format!("lstm{}", m.n_h));
        let input = g.add(LayerKind::Input {
            bytes: 4 * m.x,
            marshal_insts: (m.n_h + m.x) / 4 + 30,
            raw_bytes: m.x,
        });
        let cell = g.chain(input, LayerKind::LstmCell { x: m.x, n_h: m.n_h, weight_slot: 0 });
        let dense = g.chain(cell, LayerKind::Dense {
            rows: m.dense_rows(),
            cols: m.dense_cols(),
            weight_slot: 1,
        });
        let sm = g.chain(dense, LayerKind::Activation { kind: ActKind::Softmax, elems: m.y });
        g.chain(sm, LayerKind::Output { bytes: m.y });
        g
    }

    /// A pre-norm transformer encoder running one token step against a
    /// `seq`-deep KV cache — a workload class the paper never evaluated.
    /// Per encoder layer: LayerNorm -> Attention -> residual ->
    /// LayerNorm -> Dense(d_model x d_ff) + ReLU -> Dense(d_ff x
    /// d_model) -> residual; a final LayerNorm precedes the output.
    /// Weight slots: layer `l` uses `3l` (packed Wq|Wk|Wv|Wo), `3l + 1`
    /// (FFN up) and `3l + 2` (FFN down).
    pub fn transformer(d_model: u64, heads: u64, seq: u64, layers: u64, d_ff: u64) -> LayerGraph {
        assert!(layers >= 1, "a transformer needs at least one encoder layer");
        assert!(heads >= 1 && d_model % heads == 0, "heads must divide d_model");
        let mut g = LayerGraph::new(format!(
            "transformer[d{d_model}h{heads}s{seq}l{layers}f{d_ff}]"
        ));
        let mut prev = g.add(LayerKind::Input {
            bytes: 4 * d_model,
            marshal_insts: d_model / 4 + 40,
            raw_bytes: d_model,
        });
        let residual = LayerKind::Elementwise { simd_insts: d_model / 4 + 4, fp_insts: 0 };
        for l in 0..layers as usize {
            prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
            prev = g.chain(prev, LayerKind::Attention { d_model, heads, seq, weight_slot: 3 * l });
            prev = g.chain(prev, residual);
            prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
            prev = g.chain(prev, LayerKind::Dense { rows: d_model, cols: d_ff, weight_slot: 3 * l + 1 });
            prev = g.chain(prev, LayerKind::Activation { kind: ActKind::Relu, elems: d_ff });
            prev = g.chain(prev, LayerKind::Dense { rows: d_ff, cols: d_model, weight_slot: 3 * l + 2 });
            prev = g.chain(prev, residual);
        }
        prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
        g.chain(prev, LayerKind::Output { bytes: 4 * d_model });
        g
    }

    /// The paper's CNNs (§IX): 5 conv layers (fused post-ops) + 3 dense
    /// layers + softmax. Node ids: 0 input, 1..=5 convs, then
    /// (dense, act) pairs, last node output.
    pub fn cnn(m: &CnnModel) -> LayerGraph {
        let mut g = LayerGraph::new(format!("cnn-{}", m.variant.name()));
        let c0 = &m.convs[0];
        let image_bytes = c0.in_hw * c0.in_hw * c0.in_ch;
        let mut prev = g.add(LayerKind::Input {
            bytes: image_bytes,
            marshal_insts: 0,
            raw_bytes: image_bytes,
        });
        for (k, l) in m.convs.iter().enumerate() {
            prev = g.chain(prev, LayerKind::Conv2d { layer: *l, weight_slot: k });
        }
        let dims = [
            (m.dense_inputs(), m.dense[0]),
            (m.dense[0], m.dense[1]),
            (m.dense[1], m.dense[2]),
        ];
        for (d, (rows, cols)) in dims.into_iter().enumerate() {
            prev = g.chain(prev, LayerKind::Dense { rows, cols, weight_slot: 8 + d });
            let kind = if d == 2 { ActKind::Softmax } else { ActKind::Relu };
            prev = g.chain(prev, LayerKind::Activation { kind, elems: cols });
        }
        g.chain(prev, LayerKind::Output { bytes: m.dense[2] });
        g
    }
}

fn join_dims(dims: &[u64]) -> String {
    dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_graph_shape() {
        let g = LayerGraph::mlp(&[784, 512, 512, 10]);
        // input + 3x(dense, relu) + output
        assert_eq!(g.nodes.len(), 8);
        assert_eq!(g.edges.len(), 7);
        assert!(matches!(g.nodes[1].kind, LayerKind::Dense { rows: 784, cols: 512, weight_slot: 0 }));
        assert!(matches!(g.nodes[7].kind, LayerKind::Output { bytes: 40 }));
        assert_eq!(g.name, "mlp[784x512x512x10]");
    }

    #[test]
    fn paper_mlp_matches_model() {
        let g = LayerGraph::mlp_paper(&MlpModel::paper());
        assert_eq!(g.nodes.len(), 6);
        assert!(matches!(g.nodes[3].kind, LayerKind::Dense { rows: 1024, cols: 1024, weight_slot: 1 }));
    }

    #[test]
    fn lstm_graph_shape() {
        let m = LstmModel::paper(256);
        let g = LayerGraph::lstm(&m);
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.nodes[1].kind.mvm_rows(), Some(306));
        assert_eq!(g.nodes[1].kind.mvm_cols(), Some(1024));
        assert!(matches!(g.nodes[3].kind, LayerKind::Activation { kind: ActKind::Softmax, elems: 50 }));
    }

    #[test]
    fn cnn_graph_shape() {
        let m = CnnModel::paper(crate::nn::CnnVariant::Fast);
        let g = LayerGraph::cnn(&m);
        // input + 5 convs + 3x(dense, act) + output
        assert_eq!(g.nodes.len(), 13);
        assert!(matches!(g.nodes[0].kind, LayerKind::Input { bytes, .. } if bytes == 224 * 224 * 3));
        assert!(matches!(g.nodes[12].kind, LayerKind::Output { bytes: 1000 }));
    }

    #[test]
    fn transformer_graph_shape() {
        let g = LayerGraph::transformer(256, 4, 64, 2, 1024);
        // input + 2 x 8 encoder nodes + final LN + output
        assert_eq!(g.nodes.len(), 2 * 8 + 3);
        assert_eq!(g.edges.len(), g.nodes.len() - 1);
        assert!(matches!(
            g.nodes[2].kind,
            LayerKind::Attention { d_model: 256, heads: 4, seq: 64, weight_slot: 0 }
        ));
        assert!(matches!(g.nodes[5].kind, LayerKind::Dense { rows: 256, cols: 1024, weight_slot: 1 }));
        assert!(matches!(g.nodes[10].kind, LayerKind::Attention { weight_slot: 3, .. }));
        assert!(matches!(g.nodes[17].kind, LayerKind::LayerNorm { elems: 256 }));
        assert!(matches!(g.nodes[18].kind, LayerKind::Output { bytes: 1024 }));
        // Attention is not a single MVM: placed via AttentionTiles, not Tile.
        assert_eq!(g.nodes[2].kind.mvm_rows(), None);
    }

    #[test]
    #[should_panic(expected = "heads must divide d_model")]
    fn transformer_rejects_bad_heads() {
        let _ = LayerGraph::transformer(100, 3, 8, 1, 64);
    }

    #[test]
    fn chain_edges_connect() {
        let g = LayerGraph::mlp(&[8, 4]);
        for (i, (a, b)) in g.edges.iter().enumerate() {
            assert_eq!(*a, i);
            assert_eq!(*b, i + 1);
        }
    }
}
