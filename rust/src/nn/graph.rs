//! The layer-graph workload IR.
//!
//! A [`LayerGraph`] is a pure *model description*: a DAG of typed layer
//! nodes with shapes, independent of how (or where) each layer executes.
//! The paper's three explorations are instances of it (`LayerGraph::mlp`
//! / `lstm` / `cnn`), and arbitrary graphs can be built for new
//! workloads — including true multi-branch dataflow: residual blocks
//! ([`LayerGraph::resnet_block`]), transformers with genuinely parallel
//! attention-head branches ([`LayerGraph::transformer_parallel`]) and
//! mixture-of-experts layers ([`LayerGraph::moe`]). Execution placement
//! — which core runs a layer, whether its MVM goes to the SIMD pipeline
//! or an AIMC tile, how stages pipeline — lives in
//! `workload::compile::Mapping`; the pair is lowered to per-core traces
//! by `workload::compile::compile`.
//!
//! Graphs are built either through the chain helpers (`add` / `chain`,
//! kept for the legacy constructors) or the fluent [`GraphBuilder`]:
//!
//! ```
//! use alpine::nn::{GraphBuilder, LayerKind, MergeOp};
//! let mut b = GraphBuilder::new("residual");
//! let x = b.input(256, 68, 64);
//! let d = b.layer(LayerKind::Dense { rows: 64, cols: 64, weight_slot: 0 }).after(&[x]);
//! let m = b.layer(LayerKind::Merge { op: MergeOp::Add, elems: 64 }).after(&[d, x]);
//! b.layer(LayerKind::Output { bytes: 256 }).after(&[m]);
//! let g = b.finish().unwrap();
//! assert_eq!(g.nodes.len(), 4);
//! ```
//!
//! This mirrors the mapping flow of end-to-end AIMC compilers (Bruschi
//! et al., Garofalo et al.): network description first, placement second,
//! code generation last.

use crate::nn::cnn::CnnLayer;
use crate::nn::{CnnModel, LstmModel, MlpModel};
use std::collections::BTreeSet;
use std::fmt;

/// Index of a node in `LayerGraph::nodes`.
pub type NodeId = usize;

/// Digital activation flavours with distinct lowering costs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    Softmax,
}

/// How a multi-input [`LayerKind::Merge`] node combines its branches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeOp {
    /// Elementwise sum of equally-shaped branches (residual add).
    Add,
    /// Concatenation of branch activations (multi-head joins); the
    /// predecessor widths must sum to the node's `elems`.
    Concat,
}

/// One typed layer of the graph, with everything the mapping compiler
/// needs to cost it (shapes in elements, weight region slot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// fp32 source vector/image: a cold `bytes`-byte stream per inference
    /// plus `marshal_insts` of AIMClib input marshalling. `raw_bytes` is
    /// the int8 size of the same input (what replicated followers re-read
    /// from the LLC, and the unit of conv row-slice streaming).
    Input { bytes: u64, marshal_insts: u64, raw_bytes: u64 },

    /// Dense `rows x cols` int8 weight matrix at `addr::weights(slot)`.
    Dense { rows: u64, cols: u64, weight_slot: usize },

    /// One convolutional layer (with fused ReLU/LRN/pool post-ops, as in
    /// the paper's pipeline stages, §IX).
    Conv2d { layer: CnnLayer, weight_slot: usize },

    /// LSTM cell layer: the `(n_h + x) x 4n_h` four-gate MVM plus the
    /// digital gate activations and c/h elementwise combination (§VIII.D
    /// executes all four gates in one CM_PROCESS).
    LstmCell { x: u64, n_h: u64, weight_slot: usize },

    /// Elementwise digital activation over `elems` values.
    Activation { kind: ActKind, elems: u64 },

    /// Standalone max-pool over `elems` values with a `window`^2 kernel
    /// (the paper's CNN fuses pooling into Conv2d; this exists for custom
    /// graphs).
    Pool { elems: u64, window: u64 },

    /// Generic elementwise stage (e.g. residual add, scale) with explicit
    /// SIMD / scalar-FP instruction budgets.
    Elementwise { simd_insts: u64, fp_insts: u64 },

    /// Fork/join merge point of a DAG: combines every predecessor branch
    /// into one `elems`-wide activation. `Add` requires every branch to
    /// produce exactly `elems`; `Concat` requires the branch widths to
    /// sum to `elems` (validated by [`LayerGraph::validate`]).
    Merge { op: MergeOp, elems: u64 },

    /// Multi-head self-attention for one token step against a cached
    /// sequence of `seq` keys/values (transformer-encoder workloads).
    /// The four `d_model x d_model` projection matrices (Wq|Wk|Wv|Wo)
    /// are weight-stationary — AIMC-mappable — and live packed at
    /// `addr::weights(weight_slot)`; the score/softmax/context GEMVs run
    /// against the *dynamic* K/V caches (`addr::kv(weight_slot)`) and
    /// therefore always lower digitally (a PCM crossbar cannot be
    /// re-programmed per token).
    Attention { d_model: u64, heads: u64, seq: u64, weight_slot: usize },

    /// One attention head's score/softmax/context block against a
    /// `seq`-deep K/V cache at `addr::kv(kv_slot)` — the per-branch
    /// counterpart of the fused `Attention` node, used when heads are
    /// genuinely parallel graph branches (one QKV `Dense` + one
    /// `AttnHead` per branch, joined by a `Merge::Concat`). Always
    /// lowers digitally, like the score block of `Attention`.
    AttnHead { d_head: u64, seq: u64, kv_slot: usize },

    /// Mixture-of-experts layer: `experts` dense expert matrices of
    /// `rows x cols` each, a `rows x experts` digital router, and a
    /// digital top-`top_k` combine. Only the `top_k` routed experts run
    /// per inference. Under automap column replication the layer becomes
    /// expert-parallel: every replica holds a `cols / r` column slice of
    /// *all* experts (one `rows x (experts * cols / r)` AIMC region),
    /// routes redundantly and computes its slice of the routed experts.
    MoE { rows: u64, cols: u64, experts: u64, top_k: u64, weight_slot: usize },

    /// Layer normalization over `elems` values (mean/variance reduction
    /// plus per-element normalize, scale and shift).
    LayerNorm { elems: u64 },

    /// Result sink: `bytes` written back per inference.
    Output { bytes: u64 },
}

impl LayerKind {
    /// Input-vector length of the layer's MVM, if it has one (the number
    /// of elements queued into an AIMC tile mapped to this layer).
    /// `Attention` deliberately returns `None`: it is four MVMs plus a
    /// digital score block, placed through `Place::AttentionTiles`.
    /// `MoE` also returns `None`: its expert bank is placed through the
    /// dedicated MoE lowering, not the generic single-matrix path.
    pub fn mvm_rows(&self) -> Option<u64> {
        match self {
            LayerKind::Dense { rows, .. } => Some(*rows),
            LayerKind::Conv2d { layer, .. } => Some(layer.im2col_rows()),
            LayerKind::LstmCell { x, n_h, .. } => Some(n_h + x),
            _ => None,
        }
    }

    /// Output-vector length of the layer's MVM, if it has one.
    pub fn mvm_cols(&self) -> Option<u64> {
        match self {
            LayerKind::Dense { cols, .. } => Some(*cols),
            LayerKind::Conv2d { layer, .. } => Some(layer.out_ch),
            LayerKind::LstmCell { n_h, .. } => Some(4 * n_h),
            _ => None,
        }
    }

    /// Activation width (in 4-byte words) flowing out of this layer,
    /// given the width flowing in. The single width rule shared by graph
    /// validation (join shape agreement) and the automap anchor carving,
    /// so the two can never disagree.
    pub fn out_width(&self, inherited: u64) -> u64 {
        match self {
            LayerKind::Input { raw_bytes, .. } => *raw_bytes,
            LayerKind::Dense { cols, .. } => *cols,
            LayerKind::Conv2d { layer, .. } => {
                layer.pooled_hw() * layer.pooled_hw() * layer.out_ch / 4
            }
            LayerKind::LstmCell { n_h, .. } => *n_h,
            LayerKind::Attention { d_model, .. } => *d_model,
            LayerKind::AttnHead { d_head, .. } => *d_head,
            LayerKind::Pool { elems, .. } => elems / 4,
            LayerKind::Merge { elems, .. } => *elems,
            LayerKind::MoE { cols, .. } => *cols,
            _ => inherited,
        }
    }
}

/// A structural or shape defect of a [`LayerGraph`], reported by
/// [`LayerGraph::validate`] / [`GraphBuilder::finish`]. Converts into
/// `workload::WorkloadError::InvalidGraph` at the compile boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GraphError {
    /// The graph has no nodes.
    Empty,
    /// An edge references a node id past the node list.
    EdgeOutOfBounds { from: NodeId, to: NodeId },
    /// A node feeds itself.
    SelfLoop { node: NodeId },
    /// The same `(producer, consumer)` edge appears twice.
    DuplicateEdge { from: NodeId, to: NodeId },
    /// The graph is not acyclic; `node` is on a cycle.
    Cycle { node: NodeId },
    /// A non-`Input` node has no producers.
    Unreachable { node: NodeId },
    /// A fork branch never rejoins: a non-`Output` node has no
    /// consumers.
    DanglingFork { node: NodeId },
    /// An `Input` node has incoming edges.
    InputHasPreds { node: NodeId },
    /// An `Output` node has outgoing edges.
    OutputHasSuccs { node: NodeId },
    /// The graph must contain exactly one `Input` node.
    InputCount { found: usize },
    /// The graph must contain exactly one `Output` node.
    OutputCount { found: usize },
    /// Only `Merge` nodes may join multiple branches.
    MultiInput { node: NodeId, preds: usize },
    /// A `Merge` node needs at least two branches to join.
    JoinArity { node: NodeId, preds: usize },
    /// A branch flowing into a join has the wrong width.
    JoinShapeMismatch { node: NodeId, expected: u64, got: u64 },
    /// A `MoE` node's expert/top-k/shape parameters are inconsistent.
    BadMoE { node: NodeId, reason: &'static str },
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::Empty => write!(f, "graph has no nodes"),
            GraphError::EdgeOutOfBounds { from, to } => {
                write!(f, "edge ({from}, {to}) references a node past the node list")
            }
            GraphError::SelfLoop { node } => write!(f, "node {node} feeds itself"),
            GraphError::DuplicateEdge { from, to } => {
                write!(f, "duplicate edge ({from}, {to})")
            }
            GraphError::Cycle { node } => {
                write!(f, "graph contains a cycle through node {node}")
            }
            GraphError::Unreachable { node } => {
                write!(f, "node {node} has no producers and is not an Input")
            }
            GraphError::DanglingFork { node } => {
                write!(f, "dangling fork branch: node {node} has no consumers and is not an Output")
            }
            GraphError::InputHasPreds { node } => {
                write!(f, "Input node {node} has incoming edges")
            }
            GraphError::OutputHasSuccs { node } => {
                write!(f, "Output node {node} has outgoing edges")
            }
            GraphError::InputCount { found } => {
                write!(f, "graph needs exactly one Input node, found {found}")
            }
            GraphError::OutputCount { found } => {
                write!(f, "graph needs exactly one Output node, found {found}")
            }
            GraphError::MultiInput { node, preds } => {
                write!(f, "node {node} joins {preds} branches but only Merge nodes may join")
            }
            GraphError::JoinArity { node, preds } => {
                write!(f, "Merge node {node} joins {preds} branch(es), needs at least 2")
            }
            GraphError::JoinShapeMismatch { node, expected, got } => {
                write!(f, "join shape mismatch at node {node}: branch width {got} vs {expected}")
            }
            GraphError::BadMoE { node, reason } => {
                write!(f, "MoE node {node}: {reason}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// A node of the layer graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerNode {
    pub id: NodeId,
    pub kind: LayerKind,
}

/// The workload IR: typed layer nodes plus dataflow edges.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerGraph {
    pub name: String,
    pub nodes: Vec<LayerNode>,
    /// Dataflow edges `(producer, consumer)`.
    pub edges: Vec<(NodeId, NodeId)>,
}

impl LayerGraph {
    pub fn new(name: impl Into<String>) -> LayerGraph {
        LayerGraph { name: name.into(), nodes: Vec::new(), edges: Vec::new() }
    }

    /// Append a node, returning its id.
    pub fn add(&mut self, kind: LayerKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(LayerNode { id, kind });
        id
    }

    /// Append a node chained after `prev`.
    pub fn chain(&mut self, prev: NodeId, kind: LayerKind) -> NodeId {
        let id = self.add(kind);
        self.edges.push((prev, id));
        id
    }

    pub fn node(&self, id: NodeId) -> Option<&LayerNode> {
        self.nodes.get(id)
    }

    /// Producers of `id`, in edge-insertion order.
    pub fn preds(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|&&(_, b)| b == id).map(|&(a, _)| a).collect()
    }

    /// Consumers of `id`, in edge-insertion order.
    pub fn succs(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|&&(a, _)| a == id).map(|&(_, b)| b).collect()
    }

    /// Is this the classic linear chain (`edges[i] == (i, i + 1)`)? Such
    /// graphs take the exact pre-DAG compile and automap paths and stay
    /// bit-identical to them.
    pub fn is_chain(&self) -> bool {
        self.edges.len() + 1 == self.nodes.len()
            && self.edges.iter().enumerate().all(|(i, &(a, b))| a == i && b == i + 1)
    }

    /// Kahn topological order with a smallest-id-first tie-break, so
    /// branch nodes created consecutively stay consecutive in the
    /// linearization (and a chain graph linearizes to `0..n`).
    /// Deterministic; errors on cycles or out-of-range edges.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for &(a, b) in &self.edges {
            if a >= n || b >= n {
                return Err(GraphError::EdgeOutOfBounds { from: a, to: b });
            }
            if a == b {
                return Err(GraphError::SelfLoop { node: a });
            }
            indeg[b] += 1;
            succs[a].push(b);
        }
        let mut ready: BTreeSet<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order: Vec<NodeId> = Vec::with_capacity(n);
        while let Some(&id) = ready.iter().next() {
            ready.remove(&id);
            order.push(id);
            for &s in &succs[id] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.insert(s);
                }
            }
        }
        if order.len() != n {
            let node = (0..n).find(|&i| indeg[i] > 0).unwrap_or(0);
            return Err(GraphError::Cycle { node });
        }
        Ok(order)
    }

    /// Activation width (4-byte words) flowing out of every node,
    /// computed in topological order with [`LayerKind::out_width`]. A
    /// multi-pred node inherits from its first predecessor (only `Merge`
    /// nodes may have several, and they never inherit).
    pub fn node_widths(&self) -> Result<Vec<u64>, GraphError> {
        let order = self.topo_order()?;
        let mut widths = vec![0u64; self.nodes.len()];
        for id in order {
            let inherited = self.preds(id).first().map(|&p| widths[p]).unwrap_or(0);
            widths[id] = self.nodes[id].kind.out_width(inherited);
        }
        Ok(widths)
    }

    /// Full structural + shape validation: in-bounds deduplicated edges,
    /// acyclicity, exactly one `Input` and one `Output`, no dangling
    /// fork branches or unreachable nodes, joins only at `Merge` nodes,
    /// and width agreement at every join (`Add`: every branch equals
    /// `elems`; `Concat`: branch widths sum to `elems`).
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.nodes.is_empty() {
            return Err(GraphError::Empty);
        }
        let mut seen: BTreeSet<(NodeId, NodeId)> = BTreeSet::new();
        for &(a, b) in &self.edges {
            if a >= self.nodes.len() || b >= self.nodes.len() {
                return Err(GraphError::EdgeOutOfBounds { from: a, to: b });
            }
            if !seen.insert((a, b)) {
                return Err(GraphError::DuplicateEdge { from: a, to: b });
            }
        }
        let widths = self.node_widths()?; // checks self-loops + cycles
        let inputs = self
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Input { .. }))
            .count();
        if inputs != 1 {
            return Err(GraphError::InputCount { found: inputs });
        }
        let outputs = self
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Output { .. }))
            .count();
        if outputs != 1 {
            return Err(GraphError::OutputCount { found: outputs });
        }
        for node in &self.nodes {
            let preds = self.preds(node.id);
            let succs = self.succs(node.id);
            match node.kind {
                LayerKind::Input { .. } => {
                    if !preds.is_empty() {
                        return Err(GraphError::InputHasPreds { node: node.id });
                    }
                }
                _ if preds.is_empty() => {
                    return Err(GraphError::Unreachable { node: node.id });
                }
                _ => {}
            }
            match node.kind {
                LayerKind::Output { .. } => {
                    if !succs.is_empty() {
                        return Err(GraphError::OutputHasSuccs { node: node.id });
                    }
                }
                _ if succs.is_empty() => {
                    return Err(GraphError::DanglingFork { node: node.id });
                }
                _ => {}
            }
            match node.kind {
                LayerKind::Merge { op, elems } => {
                    if preds.len() < 2 {
                        return Err(GraphError::JoinArity { node: node.id, preds: preds.len() });
                    }
                    match op {
                        MergeOp::Add => {
                            for &p in &preds {
                                if widths[p] != elems {
                                    return Err(GraphError::JoinShapeMismatch {
                                        node: node.id,
                                        expected: elems,
                                        got: widths[p],
                                    });
                                }
                            }
                        }
                        MergeOp::Concat => {
                            let sum: u64 = preds.iter().map(|&p| widths[p]).sum();
                            if sum != elems {
                                return Err(GraphError::JoinShapeMismatch {
                                    node: node.id,
                                    expected: elems,
                                    got: sum,
                                });
                            }
                        }
                    }
                }
                LayerKind::MoE { rows, cols, experts, top_k, .. } => {
                    if experts == 0 {
                        return Err(GraphError::BadMoE { node: node.id, reason: "experts == 0" });
                    }
                    if top_k == 0 || top_k > experts {
                        return Err(GraphError::BadMoE {
                            node: node.id,
                            reason: "top_k must be in 1..=experts",
                        });
                    }
                    if rows == 0 || cols == 0 {
                        return Err(GraphError::BadMoE { node: node.id, reason: "empty expert matrix" });
                    }
                    if preds.len() != 1 {
                        return Err(GraphError::MultiInput { node: node.id, preds: preds.len() });
                    }
                }
                _ => {
                    if preds.len() > 1 {
                        return Err(GraphError::MultiInput { node: node.id, preds: preds.len() });
                    }
                }
            }
        }
        Ok(())
    }

    /// An MLP as a linear chain: `dims = [in, h1, .., out]` gives
    /// `dims.len() - 1` Dense+ReLU layers. `mlp(&[1024, 1024, 1024])` is
    /// the paper's Fig. 6(a) network.
    pub fn mlp(dims: &[u64]) -> LayerGraph {
        assert!(dims.len() >= 2, "an MLP needs at least [in, out] dims");
        let mut g = LayerGraph::new(format!("mlp[{}]", join_dims(dims)));
        let mut prev = g.add(LayerKind::Input {
            bytes: 4 * dims[0],
            marshal_insts: dims[0] / 4 + 40,
            raw_bytes: dims[0],
        });
        for l in 0..dims.len() - 1 {
            prev = g.chain(prev, LayerKind::Dense {
                rows: dims[l],
                cols: dims[l + 1],
                weight_slot: l,
            });
            prev = g.chain(prev, LayerKind::Activation {
                kind: ActKind::Relu,
                elems: dims[l + 1],
            });
        }
        g.chain(prev, LayerKind::Output { bytes: 4 * dims[dims.len() - 1] });
        g
    }

    /// The paper's MLP (§VII): two 1024x1024 Dense+ReLU layers.
    pub fn mlp_paper(m: &MlpModel) -> LayerGraph {
        let mut dims = vec![m.dim];
        dims.extend(std::iter::repeat(m.dim).take(m.layers as usize));
        LayerGraph::mlp(&dims)
    }

    /// The paper's LSTM (§VIII): cell layer + dense + softmax. Node ids:
    /// 0 input, 1 cell, 2 dense, 3 softmax, 4 output.
    pub fn lstm(m: &LstmModel) -> LayerGraph {
        let mut g = LayerGraph::new(format!("lstm{}", m.n_h));
        let input = g.add(LayerKind::Input {
            bytes: 4 * m.x,
            marshal_insts: (m.n_h + m.x) / 4 + 30,
            raw_bytes: m.x,
        });
        let cell = g.chain(input, LayerKind::LstmCell { x: m.x, n_h: m.n_h, weight_slot: 0 });
        let dense = g.chain(cell, LayerKind::Dense {
            rows: m.dense_rows(),
            cols: m.dense_cols(),
            weight_slot: 1,
        });
        let sm = g.chain(dense, LayerKind::Activation { kind: ActKind::Softmax, elems: m.y });
        g.chain(sm, LayerKind::Output { bytes: m.y });
        g
    }

    /// A pre-norm transformer encoder running one token step against a
    /// `seq`-deep KV cache — a workload class the paper never evaluated.
    /// Per encoder layer: LayerNorm -> Attention -> residual ->
    /// LayerNorm -> Dense(d_model x d_ff) + ReLU -> Dense(d_ff x
    /// d_model) -> residual; a final LayerNorm precedes the output.
    /// Weight slots: layer `l` uses `3l` (packed Wq|Wk|Wv|Wo), `3l + 1`
    /// (FFN up) and `3l + 2` (FFN down).
    ///
    /// The residuals here are *linear-chain* `Elementwise` stages (the
    /// skip connection is folded into the node's instruction budget), so
    /// the graph compiles through the exact pre-DAG path. For residuals
    /// as true fork/join branches — and per-head branch parallelism —
    /// see [`LayerGraph::transformer_parallel`].
    pub fn transformer(d_model: u64, heads: u64, seq: u64, layers: u64, d_ff: u64) -> LayerGraph {
        assert!(layers >= 1, "a transformer needs at least one encoder layer");
        assert!(heads >= 1 && d_model % heads == 0, "heads must divide d_model");
        let mut g = LayerGraph::new(format!(
            "transformer[d{d_model}h{heads}s{seq}l{layers}f{d_ff}]"
        ));
        let mut prev = g.add(LayerKind::Input {
            bytes: 4 * d_model,
            marshal_insts: d_model / 4 + 40,
            raw_bytes: d_model,
        });
        let residual = LayerKind::Elementwise { simd_insts: d_model / 4 + 4, fp_insts: 0 };
        for l in 0..layers as usize {
            prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
            prev = g.chain(prev, LayerKind::Attention { d_model, heads, seq, weight_slot: 3 * l });
            prev = g.chain(prev, residual);
            prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
            prev = g.chain(prev, LayerKind::Dense { rows: d_model, cols: d_ff, weight_slot: 3 * l + 1 });
            prev = g.chain(prev, LayerKind::Activation { kind: ActKind::Relu, elems: d_ff });
            prev = g.chain(prev, LayerKind::Dense { rows: d_ff, cols: d_model, weight_slot: 3 * l + 2 });
            prev = g.chain(prev, residual);
        }
        prev = g.chain(prev, LayerKind::LayerNorm { elems: d_model });
        g.chain(prev, LayerKind::Output { bytes: 4 * d_model });
        g
    }

    /// The paper's CNNs (§IX): 5 conv layers (fused post-ops) + 3 dense
    /// layers + softmax. Node ids: 0 input, 1..=5 convs, then
    /// (dense, act) pairs, last node output.
    pub fn cnn(m: &CnnModel) -> LayerGraph {
        let mut g = LayerGraph::new(format!("cnn-{}", m.variant.name()));
        let c0 = &m.convs[0];
        let image_bytes = c0.in_hw * c0.in_hw * c0.in_ch;
        let mut prev = g.add(LayerKind::Input {
            bytes: image_bytes,
            marshal_insts: 0,
            raw_bytes: image_bytes,
        });
        for (k, l) in m.convs.iter().enumerate() {
            prev = g.chain(prev, LayerKind::Conv2d { layer: *l, weight_slot: k });
        }
        let dims = [
            (m.dense_inputs(), m.dense[0]),
            (m.dense[0], m.dense[1]),
            (m.dense[1], m.dense[2]),
        ];
        for (d, (rows, cols)) in dims.into_iter().enumerate() {
            prev = g.chain(prev, LayerKind::Dense { rows, cols, weight_slot: 8 + d });
            let kind = if d == 2 { ActKind::Softmax } else { ActKind::Relu };
            prev = g.chain(prev, LayerKind::Activation { kind, elems: cols });
        }
        g.chain(prev, LayerKind::Output { bytes: m.dense[2] });
        g
    }

    /// A residual CNN basic block with a classifier head — the smallest
    /// true fork/join graph: a stem conv produces `x`, a two-conv branch
    /// computes `F(x)`, and a `Merge::Add` joins `F(x) + x` (the
    /// identity shortcut is a real second graph edge, not a folded
    /// instruction budget). All convs are 3x3 stride-1 pad-1 with `ch`
    /// channels over an `hw x hw` map, so both branches agree on the
    /// `hw * hw * ch / 4`-word join width. Weight slots: stem 0, branch
    /// 1 and 2, head dense 3.
    pub fn resnet_block(hw: u64, ch: u64, classes: u64) -> LayerGraph {
        assert!(hw >= 3 && ch >= 1 && classes >= 1, "resnet_block needs hw >= 3, ch, classes >= 1");
        assert_eq!((hw * hw * ch) % 4, 0, "hw * hw * ch must be a multiple of 4");
        let conv = |name: &'static str| CnnLayer {
            name,
            in_hw: hw,
            in_ch: ch,
            kernel: 3,
            out_ch: ch,
            stride: 1,
            pad: 1,
            pool: 1,
            pool_stride: 1,
            lrn: false,
        };
        let width = hw * hw * ch / 4;
        let image_bytes = hw * hw * ch;
        let mut b = GraphBuilder::new(format!("resnet[{hw}x{hw}x{ch}c{classes}]"));
        let input = b.input(image_bytes, 0, image_bytes);
        let stem = b
            .layer(LayerKind::Conv2d { layer: conv("rb_stem"), weight_slot: 0 })
            .after(&[input]);
        let f1 = b
            .layer(LayerKind::Conv2d { layer: conv("rb_conv_a"), weight_slot: 1 })
            .after(&[stem]);
        let f2 = b
            .layer(LayerKind::Conv2d { layer: conv("rb_conv_b"), weight_slot: 2 })
            .after(&[f1]);
        let add = b
            .layer(LayerKind::Merge { op: MergeOp::Add, elems: width })
            .after(&[f2, stem]);
        let relu = b
            .layer(LayerKind::Activation { kind: ActKind::Relu, elems: hw * hw * ch })
            .after(&[add]);
        let head = b
            .layer(LayerKind::Dense { rows: width, cols: classes, weight_slot: 3 })
            .after(&[relu]);
        let sm = b
            .layer(LayerKind::Activation { kind: ActKind::Softmax, elems: classes })
            .after(&[head]);
        b.layer(LayerKind::Output { bytes: 4 * classes }).after(&[sm]);
        b.finish().expect("resnet_block constructs a valid graph")
    }

    /// A pre-norm transformer encoder with **genuinely parallel
    /// attention-head branches**: each head is its own graph branch (a
    /// `d_model x 3*d_head` QKV `Dense` followed by an [`AttnHead`]
    /// score block), the heads join through a `Merge::Concat`, and both
    /// residuals are true fork/join `Merge::Add` joins — so automap can
    /// place heads on disjoint cores/tiles and pipeline them
    /// branch-parallel. Weight slots: layer `l` uses `l * (heads + 3) +
    /// h` for head `h`'s QKV, `.. + heads` for Wo, `.. + heads + 1` /
    /// `.. + heads + 2` for the FFN; head `h`'s KV cache lives at slot
    /// `l * heads + h`.
    ///
    /// [`AttnHead`]: LayerKind::AttnHead
    pub fn transformer_parallel(
        d_model: u64,
        heads: u64,
        seq: u64,
        layers: u64,
        d_ff: u64,
    ) -> LayerGraph {
        assert!(layers >= 1, "a transformer needs at least one encoder layer");
        assert!(heads >= 1 && d_model % heads == 0, "heads must divide d_model");
        let d_head = d_model / heads;
        let mut b = GraphBuilder::new(format!(
            "transformer-par[d{d_model}h{heads}s{seq}l{layers}f{d_ff}]"
        ));
        let mut x = b.input(4 * d_model, d_model / 4 + 40, d_model);
        for l in 0..layers as usize {
            let slot0 = l * (heads as usize + 3);
            let ln1 = b.layer(LayerKind::LayerNorm { elems: d_model }).after(&[x]);
            let head_outs: Vec<NodeId> = (0..heads as usize)
                .map(|h| {
                    let qkv = b
                        .layer(LayerKind::Dense {
                            rows: d_model,
                            cols: 3 * d_head,
                            weight_slot: slot0 + h,
                        })
                        .after(&[ln1]);
                    b.layer(LayerKind::AttnHead {
                        d_head,
                        seq,
                        kv_slot: l * heads as usize + h,
                    })
                    .after(&[qkv])
                })
                .collect();
            let cat = b
                .layer(LayerKind::Merge { op: MergeOp::Concat, elems: d_model })
                .after(&head_outs);
            let wo = b
                .layer(LayerKind::Dense {
                    rows: d_model,
                    cols: d_model,
                    weight_slot: slot0 + heads as usize,
                })
                .after(&[cat]);
            let add1 = b
                .layer(LayerKind::Merge { op: MergeOp::Add, elems: d_model })
                .after(&[wo, x]);
            let ln2 = b.layer(LayerKind::LayerNorm { elems: d_model }).after(&[add1]);
            let ff1 = b
                .layer(LayerKind::Dense {
                    rows: d_model,
                    cols: d_ff,
                    weight_slot: slot0 + heads as usize + 1,
                })
                .after(&[ln2]);
            let relu = b
                .layer(LayerKind::Activation { kind: ActKind::Relu, elems: d_ff })
                .after(&[ff1]);
            let ff2 = b
                .layer(LayerKind::Dense {
                    rows: d_ff,
                    cols: d_model,
                    weight_slot: slot0 + heads as usize + 2,
                })
                .after(&[relu]);
            x = b
                .layer(LayerKind::Merge { op: MergeOp::Add, elems: d_model })
                .after(&[ff2, add1]);
        }
        let ln = b.layer(LayerKind::LayerNorm { elems: d_model }).after(&[x]);
        b.layer(LayerKind::Output { bytes: 4 * d_model }).after(&[ln]);
        b.finish().expect("transformer_parallel constructs a valid graph")
    }

    /// A single mixture-of-experts classifier: router + `experts` expert
    /// matrices of `d_in x d_model` (top-`top_k` routed per inference),
    /// ReLU, and a dense head to `classes` outputs. A linear chain at
    /// the graph level — the expert parallelism lives inside the
    /// [`LayerKind::MoE`] node, where automap's column replication
    /// slices every expert across cores. Weight slots: expert bank 0,
    /// head dense 1.
    pub fn moe(d_in: u64, d_model: u64, experts: u64, top_k: u64, classes: u64) -> LayerGraph {
        assert!(experts >= 1 && top_k >= 1 && top_k <= experts, "top_k must be in 1..=experts");
        let mut b = GraphBuilder::new(format!("moe[{d_in}x{d_model}e{experts}k{top_k}c{classes}]"));
        let input = b.input(4 * d_in, d_in / 4 + 40, d_in);
        let moe = b
            .layer(LayerKind::MoE { rows: d_in, cols: d_model, experts, top_k, weight_slot: 0 })
            .after(&[input]);
        let relu = b
            .layer(LayerKind::Activation { kind: ActKind::Relu, elems: d_model })
            .after(&[moe]);
        let head = b
            .layer(LayerKind::Dense { rows: d_model, cols: classes, weight_slot: 1 })
            .after(&[relu]);
        let sm = b
            .layer(LayerKind::Activation { kind: ActKind::Softmax, elems: classes })
            .after(&[head]);
        b.layer(LayerKind::Output { bytes: 4 * classes }).after(&[sm]);
        b.finish().expect("moe constructs a valid graph")
    }
}

/// Fluent DAG constructor: `input(..)` once, `layer(kind).after(&[..])`
/// per node, `finish()` to validate and take the graph. Node ids are
/// assigned in call order, so builders produce the same ids as the
/// legacy `add`/`chain` helpers would.
pub struct GraphBuilder {
    graph: LayerGraph,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> GraphBuilder {
        GraphBuilder { graph: LayerGraph::new(name) }
    }

    /// Add the graph's `Input` node (fp32 `bytes`, `marshal_insts` of
    /// AIMClib marshalling, int8 `raw_bytes`).
    pub fn input(&mut self, bytes: u64, marshal_insts: u64, raw_bytes: u64) -> NodeId {
        self.graph.add(LayerKind::Input { bytes, marshal_insts, raw_bytes })
    }

    /// Add a layer node; wire its producers with
    /// [`PendingNode::after`].
    pub fn layer(&mut self, kind: LayerKind) -> PendingNode<'_> {
        let id = self.graph.add(kind);
        PendingNode { builder: self, id }
    }

    /// Validate and return the finished graph.
    pub fn finish(self) -> Result<LayerGraph, GraphError> {
        self.graph.validate()?;
        Ok(self.graph)
    }

    /// The graph built so far, without validation (tests of the
    /// validator itself use this to construct deliberately bad graphs).
    pub fn into_unvalidated(self) -> LayerGraph {
        self.graph
    }
}

/// A freshly added node awaiting its input edges.
pub struct PendingNode<'a> {
    builder: &'a mut GraphBuilder,
    id: NodeId,
}

impl PendingNode<'_> {
    /// Wire this node after the given producers (edge order is
    /// preserved — it is the branch order a `Merge::Concat` joins in)
    /// and return its id.
    pub fn after(self, preds: &[NodeId]) -> NodeId {
        for &p in preds {
            self.builder.graph.edges.push((p, self.id));
        }
        self.id
    }

    /// The node's id without wiring any inputs (only valid for nodes
    /// that legitimately have none).
    pub fn id(self) -> NodeId {
        self.id
    }
}

fn join_dims(dims: &[u64]) -> String {
    dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_graph_shape() {
        let g = LayerGraph::mlp(&[784, 512, 512, 10]);
        // input + 3x(dense, relu) + output
        assert_eq!(g.nodes.len(), 8);
        assert_eq!(g.edges.len(), 7);
        assert!(matches!(g.nodes[1].kind, LayerKind::Dense { rows: 784, cols: 512, weight_slot: 0 }));
        assert!(matches!(g.nodes[7].kind, LayerKind::Output { bytes: 40 }));
        assert_eq!(g.name, "mlp[784x512x512x10]");
    }

    #[test]
    fn paper_mlp_matches_model() {
        let g = LayerGraph::mlp_paper(&MlpModel::paper());
        assert_eq!(g.nodes.len(), 6);
        assert!(matches!(g.nodes[3].kind, LayerKind::Dense { rows: 1024, cols: 1024, weight_slot: 1 }));
    }

    #[test]
    fn lstm_graph_shape() {
        let m = LstmModel::paper(256);
        let g = LayerGraph::lstm(&m);
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.nodes[1].kind.mvm_rows(), Some(306));
        assert_eq!(g.nodes[1].kind.mvm_cols(), Some(1024));
        assert!(matches!(g.nodes[3].kind, LayerKind::Activation { kind: ActKind::Softmax, elems: 50 }));
    }

    #[test]
    fn cnn_graph_shape() {
        let m = CnnModel::paper(crate::nn::CnnVariant::Fast);
        let g = LayerGraph::cnn(&m);
        // input + 5 convs + 3x(dense, act) + output
        assert_eq!(g.nodes.len(), 13);
        assert!(matches!(g.nodes[0].kind, LayerKind::Input { bytes, .. } if bytes == 224 * 224 * 3));
        assert!(matches!(g.nodes[12].kind, LayerKind::Output { bytes: 1000 }));
    }

    #[test]
    fn transformer_graph_shape() {
        let g = LayerGraph::transformer(256, 4, 64, 2, 1024);
        // input + 2 x 8 encoder nodes + final LN + output
        assert_eq!(g.nodes.len(), 2 * 8 + 3);
        assert_eq!(g.edges.len(), g.nodes.len() - 1);
        assert!(matches!(
            g.nodes[2].kind,
            LayerKind::Attention { d_model: 256, heads: 4, seq: 64, weight_slot: 0 }
        ));
        assert!(matches!(g.nodes[5].kind, LayerKind::Dense { rows: 256, cols: 1024, weight_slot: 1 }));
        assert!(matches!(g.nodes[10].kind, LayerKind::Attention { weight_slot: 3, .. }));
        assert!(matches!(g.nodes[17].kind, LayerKind::LayerNorm { elems: 256 }));
        assert!(matches!(g.nodes[18].kind, LayerKind::Output { bytes: 1024 }));
        // Attention is not a single MVM: placed via AttentionTiles, not Tile.
        assert_eq!(g.nodes[2].kind.mvm_rows(), None);
    }

    #[test]
    #[should_panic(expected = "heads must divide d_model")]
    fn transformer_rejects_bad_heads() {
        let _ = LayerGraph::transformer(100, 3, 8, 1, 64);
    }

    #[test]
    fn chain_edges_connect() {
        let g = LayerGraph::mlp(&[8, 4]);
        assert!(g.is_chain());
        for (i, (a, b)) in g.edges.iter().enumerate() {
            assert_eq!(*a, i);
            assert_eq!(*b, i + 1);
        }
    }

    #[test]
    fn legacy_constructors_validate() {
        LayerGraph::mlp(&[64, 32, 16]).validate().unwrap();
        LayerGraph::lstm(&LstmModel::paper(256)).validate().unwrap();
        LayerGraph::transformer(64, 2, 16, 1, 128).validate().unwrap();
        LayerGraph::cnn(&CnnModel::paper(crate::nn::CnnVariant::Fast)).validate().unwrap();
    }

    #[test]
    fn builder_matches_chain_construction() {
        let legacy = LayerGraph::mlp(&[64, 32]);
        let mut b = GraphBuilder::new("mlp[64x32]");
        let i = b.input(256, 56, 64);
        let d = b.layer(LayerKind::Dense { rows: 64, cols: 32, weight_slot: 0 }).after(&[i]);
        let r = b.layer(LayerKind::Activation { kind: ActKind::Relu, elems: 32 }).after(&[d]);
        b.layer(LayerKind::Output { bytes: 128 }).after(&[r]);
        let g = b.finish().unwrap();
        assert_eq!(g, legacy);
    }

    #[test]
    fn topo_order_is_min_id_kahn() {
        let g = LayerGraph::resnet_block(8, 4, 10);
        let order = g.topo_order().unwrap();
        // Construction order is already topological here.
        assert_eq!(order, (0..g.nodes.len()).collect::<Vec<_>>());
        assert!(!g.is_chain());
    }

    #[test]
    fn node_widths_follow_branches() {
        let g = LayerGraph::transformer_parallel(64, 2, 16, 1, 128);
        g.validate().unwrap();
        let w = g.node_widths().unwrap();
        // Input and every residual join carry d_model words.
        assert_eq!(w[0], 64);
        for n in &g.nodes {
            match n.kind {
                LayerKind::AttnHead { .. } => assert_eq!(w[n.id], 32),
                LayerKind::Merge { .. } => assert_eq!(w[n.id], 64),
                _ => {}
            }
        }
    }

    #[test]
    fn validate_detects_cycles() {
        let mut g = LayerGraph::new("cyclic");
        let i = g.add(LayerKind::Input { bytes: 64, marshal_insts: 4, raw_bytes: 16 });
        let a = g.chain(i, LayerKind::Dense { rows: 16, cols: 16, weight_slot: 0 });
        let m = g.add(LayerKind::Merge { op: MergeOp::Add, elems: 16 });
        g.edges.push((a, m));
        g.edges.push((m, a)); // cycle a -> m -> a
        g.chain(m, LayerKind::Output { bytes: 64 });
        assert!(matches!(g.validate(), Err(GraphError::Cycle { .. })));
    }

    #[test]
    fn validate_detects_join_shape_mismatch() {
        let mut b = GraphBuilder::new("bad-join");
        let i = b.input(256, 56, 64);
        let a = b.layer(LayerKind::Dense { rows: 64, cols: 32, weight_slot: 0 }).after(&[i]);
        let c = b.layer(LayerKind::Dense { rows: 64, cols: 64, weight_slot: 1 }).after(&[i]);
        let m = b.layer(LayerKind::Merge { op: MergeOp::Add, elems: 64 }).after(&[a, c]);
        b.layer(LayerKind::Output { bytes: 256 }).after(&[m]);
        assert!(matches!(
            b.finish(),
            Err(GraphError::JoinShapeMismatch { expected: 64, got: 32, .. })
        ));
    }

    #[test]
    fn validate_detects_dangling_fork() {
        let mut b = GraphBuilder::new("dangling");
        let i = b.input(256, 56, 64);
        let a = b.layer(LayerKind::Dense { rows: 64, cols: 64, weight_slot: 0 }).after(&[i]);
        // Second branch forks off the input and never rejoins.
        let dead = b.layer(LayerKind::Dense { rows: 64, cols: 64, weight_slot: 1 }).after(&[i]);
        b.layer(LayerKind::Output { bytes: 256 }).after(&[a]);
        let err = b.finish().unwrap_err();
        assert_eq!(err, GraphError::DanglingFork { node: dead });
    }

    #[test]
    fn validate_rejects_non_merge_joins() {
        let mut b = GraphBuilder::new("bad-multi");
        let i = b.input(256, 56, 64);
        let a = b.layer(LayerKind::Dense { rows: 64, cols: 64, weight_slot: 0 }).after(&[i]);
        // LayerNorm cannot join two branches.
        let ln = b.layer(LayerKind::LayerNorm { elems: 64 }).after(&[a, i]);
        b.layer(LayerKind::Output { bytes: 256 }).after(&[ln]);
        assert!(matches!(b.finish(), Err(GraphError::MultiInput { preds: 2, .. })));
    }

    #[test]
    fn resnet_block_shape() {
        let g = LayerGraph::resnet_block(16, 8, 10);
        g.validate().unwrap();
        // input, stem, conv_a, conv_b, add, relu, dense, softmax, output
        assert_eq!(g.nodes.len(), 9);
        assert_eq!(g.edges.len(), 9); // chain edges + the skip edge
        assert_eq!(g.preds(4), vec![3, 1]); // add joins conv_b and the stem
        let w = g.node_widths().unwrap();
        assert_eq!(w[1], 16 * 16 * 8 / 4);
        assert_eq!(w[4], 16 * 16 * 8 / 4);
    }

    #[test]
    fn transformer_parallel_shape() {
        let g = LayerGraph::transformer_parallel(64, 2, 16, 2, 128);
        g.validate().unwrap();
        // Per layer: ln + 2*(qkv, head) + cat + wo + add + ln + ff1 +
        // relu + ff2 + add = 13 nodes; plus input, final ln, output.
        assert_eq!(g.nodes.len(), 2 * 13 + 3);
        let heads = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::AttnHead { d_head: 32, seq: 16, .. }))
            .count();
        assert_eq!(heads, 4);
        // The concat joins both heads of the layer.
        let cat = g
            .nodes
            .iter()
            .find(|n| matches!(n.kind, LayerKind::Merge { op: MergeOp::Concat, .. }))
            .unwrap();
        assert_eq!(g.preds(cat.id).len(), 2);
    }

    #[test]
    fn moe_graph_shape() {
        let g = LayerGraph::moe(128, 64, 4, 2, 10);
        g.validate().unwrap();
        assert!(g.is_chain());
        assert!(matches!(
            g.nodes[1].kind,
            LayerKind::MoE { rows: 128, cols: 64, experts: 4, top_k: 2, weight_slot: 0 }
        ));
        assert_eq!(g.node_widths().unwrap()[1], 64);
        // MoE is not a generic single-matrix MVM.
        assert_eq!(g.nodes[1].kind.mvm_rows(), None);
    }

    #[test]
    fn moe_validation_rejects_bad_top_k() {
        let mut b = GraphBuilder::new("bad-moe");
        let i = b.input(256, 56, 64);
        let m = b
            .layer(LayerKind::MoE { rows: 64, cols: 32, experts: 2, top_k: 3, weight_slot: 0 })
            .after(&[i]);
        b.layer(LayerKind::Output { bytes: 128 }).after(&[m]);
        assert!(matches!(b.finish(), Err(GraphError::BadMoE { .. })));
    }
}
