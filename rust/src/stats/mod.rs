//! gem5-style statistics collection.
//!
//! Every simulation run produces a `RunStats`: per-core cycle/instruction
//! counters, cache hit/miss counters per level, DRAM access counts, AIMC
//! tile counters, and the sub-ROI timing breakdown the paper uses in
//! Figs. 8 and 11. `RunStats` is the single input to the energy model.

pub(crate) mod roi;

pub use roi::{RoiKind, RoiTimes};

/// Per-core execution statistics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CoreStats {
    /// Committed (micro-)instructions.
    pub insts: u64,
    /// Cycles spent actively executing.
    pub active_cycles: u64,
    /// Cycles stalled waiting for memory (gem5-X "WFM").
    pub wfm_cycles: u64,
    /// Cycles idle (waiting on mutexes / channels / nothing scheduled).
    pub idle_cycles: u64,
}

impl CoreStats {
    pub fn total_cycles(&self) -> u64 {
        self.active_cycles + self.wfm_cycles + self.idle_cycles
    }

    pub fn ipc(&self) -> f64 {
        let t = self.total_cycles();
        if t == 0 {
            0.0
        } else {
            self.insts as f64 / t as f64
        }
    }

    pub fn idle_fraction(&self) -> f64 {
        let t = self.total_cycles();
        if t == 0 {
            0.0
        } else {
            self.idle_cycles as f64 / t as f64
        }
    }
}

/// Per-cache-level counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub read_hits: u64,
    pub read_misses: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.read_hits + self.read_misses + self.write_hits + self.write_misses
    }

    pub fn misses(&self) -> u64 {
        self.read_misses + self.write_misses
    }

    pub fn miss_rate(&self) -> f64 {
        let a = self.accesses();
        if a == 0 {
            0.0
        } else {
            self.misses() as f64 / a as f64
        }
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.read_hits += other.read_hits;
        self.read_misses += other.read_misses;
        self.write_hits += other.write_hits;
        self.write_misses += other.write_misses;
        self.writebacks += other.writebacks;
    }
}

/// Integer activity counters of one AIMC tile. Energy and weighted op
/// totals are *derived* from these at run aggregation
/// (`AimcTile::energy_j` / `process_ops_weighted`) rather than
/// accumulated per event, so the fast-forward engine's closed-form
/// counter extrapolation reproduces full replay bit for bit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TileActivity {
    /// CM_PROCESS invocations.
    pub processes: u64,
    /// Bytes moved CPU -> tile input memory (CM_QUEUE).
    pub queued_bytes: u64,
    /// Bytes moved tile output memory -> CPU (CM_DEQUEUE).
    pub dequeued_bytes: u64,
    /// Devices programmed by CM_INITIALIZE (one-time, outside ROI).
    pub programmed_weights: u64,
}

/// AIMC tile usage counters (per run, summed over tiles).
#[derive(Clone, Debug, Default)]
pub struct AimcStats {
    /// CM_PROCESS invocations.
    pub processes: u64,
    /// Bytes moved CPU -> tile input memory (CM_QUEUE).
    pub queued_bytes: u64,
    /// Bytes moved tile output memory -> CPU (CM_DEQUEUE).
    pub dequeued_bytes: u64,
    /// Devices programmed by CM_INITIALIZE (one-time, outside ROI).
    pub programmed_weights: u64,
    /// Sum over processes of (rows*cols) — for energy. Derived from the
    /// per-tile [`TileActivity`] counters at run aggregation
    /// (`AimcTile::process_ops_weighted`).
    pub process_ops_weighted: f64,
    /// Tile activity energy, joules. Derived at run aggregation
    /// (`AimcTile::energy_j`) from the per-tile [`TileActivity`].
    pub energy_j: f64,
}

/// Whole-run statistics.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Simulated wall-clock of the region of interest, picoseconds.
    pub roi_time_ps: u64,
    pub cores: Vec<CoreStats>,
    pub l1d: CacheStats,
    pub llc: CacheStats,
    pub dram_accesses: u64,
    pub llc_bytes_read: u64,
    pub llc_bytes_written: u64,
    pub aimc: AimcStats,
    pub roi: RoiTimes,
}

impl RunStats {
    pub fn new(num_cores: usize) -> RunStats {
        RunStats {
            cores: vec![CoreStats::default(); num_cores],
            ..Default::default()
        }
    }

    /// Panic unless `self` and `other` agree **bit for bit** (f64 fields
    /// compared by bit pattern). This is THE equivalence check behind
    /// the fast-forward / batched-stream / parallel-sweep guarantees —
    /// it destructures both structs completely, so adding a `RunStats`
    /// field without extending the comparison is a compile error.
    pub fn assert_bit_identical(&self, other: &RunStats, label: &str) {
        let RunStats {
            roi_time_ps,
            cores,
            l1d,
            llc,
            dram_accesses,
            llc_bytes_read,
            llc_bytes_written,
            aimc,
            roi,
        } = self;
        assert_eq!(*roi_time_ps, other.roi_time_ps, "{label}: roi_time_ps");
        assert_eq!(*cores, other.cores, "{label}: per-core stats");
        assert_eq!(*l1d, other.l1d, "{label}: L1D stats");
        assert_eq!(*llc, other.llc, "{label}: LLC stats");
        assert_eq!(*dram_accesses, other.dram_accesses, "{label}: dram accesses");
        assert_eq!(*llc_bytes_read, other.llc_bytes_read, "{label}: llc bytes read");
        assert_eq!(*llc_bytes_written, other.llc_bytes_written, "{label}: llc bytes written");
        let AimcStats {
            processes,
            queued_bytes,
            dequeued_bytes,
            programmed_weights,
            process_ops_weighted,
            energy_j,
        } = aimc;
        assert_eq!(*processes, other.aimc.processes, "{label}: aimc processes");
        assert_eq!(*queued_bytes, other.aimc.queued_bytes, "{label}: aimc queued bytes");
        assert_eq!(*dequeued_bytes, other.aimc.dequeued_bytes, "{label}: aimc dequeued bytes");
        assert_eq!(*programmed_weights, other.aimc.programmed_weights, "{label}: aimc programmed");
        assert_eq!(
            process_ops_weighted.to_bits(),
            other.aimc.process_ops_weighted.to_bits(),
            "{label}: aimc process_ops_weighted"
        );
        assert_eq!(energy_j.to_bits(), other.aimc.energy_j.to_bits(), "{label}: aimc energy");
        assert_eq!(*roi, other.roi, "{label}: roi times");
    }

    pub fn total_insts(&self) -> u64 {
        self.cores.iter().map(|c| c.insts).sum()
    }

    /// The paper's memory-intensity metric: LLC misses per (k)instruction.
    pub fn llc_mpki(&self) -> f64 {
        let insts = self.total_insts();
        if insts == 0 {
            0.0
        } else {
            self.llc.misses() as f64 / (insts as f64 / 1000.0)
        }
    }

    pub fn roi_time_s(&self) -> f64 {
        self.roi_time_ps as f64 * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipc_and_idle() {
        let c = CoreStats { insts: 800, active_cycles: 800, wfm_cycles: 100, idle_cycles: 100 };
        assert!((c.ipc() - 0.8).abs() < 1e-12);
        assert!((c.idle_fraction() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn zero_cycles_no_nan() {
        let c = CoreStats::default();
        assert_eq!(c.ipc(), 0.0);
        assert_eq!(c.idle_fraction(), 0.0);
    }

    #[test]
    fn cache_stats_merge_and_rates() {
        let mut a = CacheStats { read_hits: 90, read_misses: 10, ..Default::default() };
        let b = CacheStats { write_hits: 45, write_misses: 5, writebacks: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.accesses(), 150);
        assert_eq!(a.misses(), 15);
        assert!((a.miss_rate() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn mpki_definition() {
        let mut rs = RunStats::new(1);
        rs.cores[0].insts = 10_000;
        rs.llc.read_misses = 50;
        assert!((rs.llc_mpki() - 5.0).abs() < 1e-12);
    }
}
