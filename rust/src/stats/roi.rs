//! Sub-ROI (region of interest) timing attribution.
//!
//! The paper decomposes each inference into sub-ROIs — Fig. 8 (MLP):
//! input load, analog queue, analog process, analog dequeue, digital
//! activation, output writeback, digital MVM; Fig. 11 (LSTM) adds gate
//! combination and dense-layer phases. Workload traces bracket their ops
//! with `RoiBegin`/`RoiEnd` markers; the machine accumulates per-kind
//! wall-clock here.

/// Sub-ROI categories across all three explorations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RoiKind {
    /// Loading initial inputs from memory.
    InputLoad,
    /// Packing + CM_QUEUE into tile input memory.
    AnalogQueue,
    /// CM_PROCESS (tile MVM).
    AnalogProcess,
    /// CM_DEQUEUE from tile output memory.
    AnalogDequeue,
    /// The digital MVM of the reference implementation.
    DigitalMvm,
    /// Digital activation functions (ReLU / sigmoid / tanh / softmax).
    Activation,
    /// LSTM gate element-wise combination (c/h updates).
    GateCombine,
    /// Storing outputs back to memory.
    Writeback,
    /// Core-to-core communication (pipelining channels).
    Communication,
    /// Mutex/barrier synchronization.
    Sync,
    /// Everything else.
    Misc,
}

impl RoiKind {
    pub const ALL: [RoiKind; 11] = [
        RoiKind::InputLoad,
        RoiKind::AnalogQueue,
        RoiKind::AnalogProcess,
        RoiKind::AnalogDequeue,
        RoiKind::DigitalMvm,
        RoiKind::Activation,
        RoiKind::GateCombine,
        RoiKind::Writeback,
        RoiKind::Communication,
        RoiKind::Sync,
        RoiKind::Misc,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoiKind::InputLoad => "input_load",
            RoiKind::AnalogQueue => "analog_queue",
            RoiKind::AnalogProcess => "analog_process",
            RoiKind::AnalogDequeue => "analog_dequeue",
            RoiKind::DigitalMvm => "digital_mvm",
            RoiKind::Activation => "activation",
            RoiKind::GateCombine => "gate_combine",
            RoiKind::Writeback => "writeback",
            RoiKind::Communication => "communication",
            RoiKind::Sync => "sync",
            RoiKind::Misc => "misc",
        }
    }

    fn index(&self) -> usize {
        RoiKind::ALL.iter().position(|k| k == self).unwrap()
    }
}

/// Accumulated picoseconds per sub-ROI (summed across cores: the paper's
/// run-time-percentage figures normalize by the summed distribution).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoiTimes {
    ps: [u64; 11],
}

impl RoiTimes {
    pub fn add(&mut self, kind: RoiKind, ps: u64) {
        self.ps[kind.index()] += ps;
    }

    pub fn get(&self, kind: RoiKind) -> u64 {
        self.ps[kind.index()]
    }

    pub fn total(&self) -> u64 {
        self.ps.iter().sum()
    }

    /// Fraction of total attributed time spent in `kind` (0 if empty).
    pub fn fraction(&self, kind: RoiKind) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.get(kind) as f64 / t as f64
        }
    }

    /// Visit every per-kind accumulator in a fixed order (the trace
    /// machine's fast-forward engine snapshots and extrapolates them).
    pub fn for_each_counter(&mut self, f: &mut dyn FnMut(&mut u64)) {
        for v in &mut self.ps {
            f(v);
        }
    }

    pub fn merge(&mut self, other: &RoiTimes) {
        for (a, b) in self.ps.iter_mut().zip(other.ps.iter()) {
            *a += b;
        }
    }

    /// Non-zero entries as (kind, fraction), largest first.
    pub fn breakdown(&self) -> Vec<(RoiKind, f64)> {
        let mut v: Vec<(RoiKind, f64)> = RoiKind::ALL
            .iter()
            .filter(|k| self.get(**k) > 0)
            .map(|k| (*k, self.fraction(*k)))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_fraction() {
        let mut r = RoiTimes::default();
        r.add(RoiKind::InputLoad, 300);
        r.add(RoiKind::AnalogQueue, 700);
        assert_eq!(r.total(), 1000);
        assert!((r.fraction(RoiKind::AnalogQueue) - 0.7).abs() < 1e-12);
        assert_eq!(r.fraction(RoiKind::Misc), 0.0);
    }

    #[test]
    fn breakdown_sorted_desc() {
        let mut r = RoiTimes::default();
        r.add(RoiKind::Writeback, 10);
        r.add(RoiKind::DigitalMvm, 90);
        let b = r.breakdown();
        assert_eq!(b[0].0, RoiKind::DigitalMvm);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RoiTimes::default();
        a.add(RoiKind::Sync, 5);
        let mut b = RoiTimes::default();
        b.add(RoiKind::Sync, 7);
        a.merge(&b);
        assert_eq!(a.get(RoiKind::Sync), 12);
    }

    #[test]
    fn all_kinds_have_unique_names() {
        let names: std::collections::HashSet<_> =
            RoiKind::ALL.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), RoiKind::ALL.len());
    }
}
