//! Interconnect models: the coherent memory bus between L1s, the LLC and
//! the DRAM controller, and the peripheral I/O bus used by loosely-coupled
//! AIMC accelerators (§IV.A).
//!
//! The memory bus follows Table I-A: 16-byte width, 3-cycle frontend,
//! 4-cycle forward/response/snoop, clocked at the core frequency domain
//! (gem5-X RealView puts the XBar in the CPU clock domain).

#[derive(Clone, Debug)]
pub struct MemBus {
    /// Frontend + forward latency per transaction, picoseconds.
    request_ps: u64,
    /// Response path latency, picoseconds.
    response_ps: u64,
    /// Occupancy per 64B line (width-limited), picoseconds.
    transfer_ps: u64,
    busy_until_ps: u64,
    pub transactions: u64,
}

impl MemBus {
    pub fn new(
        cycle_ps: u64,
        frontend_cycles: u64,
        fwd_cycles: u64,
        width_bytes: u64,
        line_bytes: u64,
    ) -> MemBus {
        let beats = line_bytes.div_ceil(width_bytes);
        MemBus {
            request_ps: (frontend_cycles + fwd_cycles) * cycle_ps,
            response_ps: fwd_cycles * cycle_ps,
            transfer_ps: beats * cycle_ps,
            busy_until_ps: 0,
            transactions: 0,
        }
    }

    /// One line transaction crossing the bus at `now`; returns the time at
    /// which the request has reached the far side (response latency is
    /// added by `round_trip_extra`).
    pub fn request(&mut self, now_ps: u64) -> u64 {
        self.transactions += 1;
        let start = now_ps.max(self.busy_until_ps);
        self.busy_until_ps = start + self.transfer_ps;
        start + self.request_ps
    }

    /// Latency of the response leg, ps.
    pub fn response_ps(&self) -> u64 {
        self.response_ps
    }

    pub fn busy_until_ps(&self) -> u64 {
        self.busy_until_ps
    }

    /// Advance the occupancy reservation by `d` ps (fast-forward jumps
    /// shift every clock in the machine uniformly).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.busy_until_ps += d;
    }

    pub fn reset(&mut self) {
        self.busy_until_ps = 0;
        self.transactions = 0;
    }
}

/// Peripheral I/O bus for loosely-coupled accelerators: every beat is an
/// uncached device access with a fixed round-trip cost, pipelined at the
/// peripheral throughput.
#[derive(Clone, Debug)]
pub struct IoBus {
    /// Fixed per-transaction round trip, ps.
    transaction_ps: u64,
    /// Sustained throughput limit, bytes/ps (scaled).
    bytes_per_ps: f64,
    busy_until_ps: u64,
    pub transactions: u64,
}

impl IoBus {
    pub fn new(transaction_s: f64, throughput_bps: f64) -> IoBus {
        IoBus {
            transaction_ps: (transaction_s * 1e12).round() as u64,
            bytes_per_ps: throughput_bps / 1e12,
            busy_until_ps: 0,
            transactions: 0,
        }
    }

    /// Transfer `bytes` (in pipelined beats) starting at `now`; returns the
    /// completion time. The fixed transaction latency applies once per
    /// call (drivers batch beats), the throughput limit to the payload.
    pub fn transfer(&mut self, now_ps: u64, bytes: u64) -> u64 {
        self.transactions += 1;
        let start = now_ps.max(self.busy_until_ps);
        let payload_ps = (bytes as f64 / self.bytes_per_ps).round() as u64;
        let done = start + self.transaction_ps + payload_ps;
        self.busy_until_ps = done;
        done
    }

    pub fn busy_until_ps(&self) -> u64 {
        self.busy_until_ps
    }

    /// Advance the pipeline reservation by `d` ps (fast-forward jumps
    /// shift every clock in the machine uniformly).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.busy_until_ps += d;
    }

    pub fn reset(&mut self) {
        self.busy_until_ps = 0;
        self.transactions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membus_latency_math() {
        // 435ps cycle (2.3GHz), 3+4 cycles request, 16B width, 64B line.
        let mut b = MemBus::new(435, 3, 4, 16, 64);
        let t = b.request(0);
        assert_eq!(t, 7 * 435);
        assert_eq!(b.response_ps(), 4 * 435);
        assert_eq!(b.transactions, 1);
    }

    #[test]
    fn membus_occupancy_serializes() {
        let mut b = MemBus::new(1000, 3, 4, 16, 64);
        let t1 = b.request(0);
        let t2 = b.request(0);
        // second request waits 4 beats of occupancy.
        assert_eq!(t2 - t1, 4 * 1000);
    }

    #[test]
    fn iobus_fixed_plus_payload() {
        let mut io = IoBus::new(100e-9, 1e9); // 100ns + 1GB/s
        let t = io.transfer(0, 1000); // 1000B at 1B/ns = 1000ns
        assert_eq!(t, 100_000 + 1_000_000);
    }

    #[test]
    fn iobus_back_to_back_queues() {
        let mut io = IoBus::new(100e-9, 1e9);
        let t1 = io.transfer(0, 0);
        let t2 = io.transfer(0, 0);
        assert_eq!(t1, 100_000);
        assert_eq!(t2, 200_000);
    }
}
