//! DDR4 main-memory timing model.
//!
//! Single-channel DDR4-2400 (Table I-A): a fixed average access latency
//! (controller + CAS path) plus a bandwidth-limited data channel modeled
//! as a busy-until reservation. FCFS; accesses are 64-byte lines.

#[derive(Clone, Debug)]
pub struct Dram {
    /// Average access latency, picoseconds.
    latency_ps: u64,
    /// Channel occupancy per 64B access, picoseconds.
    transfer_ps: u64,
    /// Channel reserved until this time.
    busy_until_ps: u64,
    pub accesses: u64,
}

impl Dram {
    pub fn new(latency_s: f64, peak_bps: f64, line_bytes: u64) -> Dram {
        Dram {
            latency_ps: (latency_s * 1e12).round() as u64,
            transfer_ps: ((line_bytes as f64 / peak_bps) * 1e12).round() as u64,
            busy_until_ps: 0,
            accesses: 0,
        }
    }

    /// Issue one line access at `now`; returns the completion time (ps).
    pub fn access(&mut self, now_ps: u64) -> u64 {
        self.accesses += 1;
        let start = now_ps.max(self.busy_until_ps);
        self.busy_until_ps = start + self.transfer_ps;
        start + self.latency_ps
    }

    /// Completion time without contention (for tests/analysis).
    pub fn unloaded_latency_ps(&self) -> u64 {
        self.latency_ps
    }

    pub fn busy_until_ps(&self) -> u64 {
        self.busy_until_ps
    }

    /// Advance the channel reservation by `d` ps (fast-forward jumps
    /// shift every clock in the machine uniformly).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.busy_until_ps += d;
    }

    pub fn reset(&mut self) {
        self.busy_until_ps = 0;
        self.accesses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram() -> Dram {
        // 55ns latency, 19.2 GB/s, 64B lines -> transfer 3333ps.
        Dram::new(55e-9, 19.2e9, 64)
    }

    #[test]
    fn unloaded_access_sees_latency_only() {
        let mut d = dram();
        assert_eq!(d.access(0), 55_000);
        assert_eq!(d.accesses, 1);
    }

    #[test]
    fn back_to_back_accesses_queue_on_channel() {
        let mut d = dram();
        let t1 = d.access(0);
        let t2 = d.access(0); // same instant: must wait for the channel
        assert_eq!(t1, 55_000);
        assert_eq!(t2, 55_000 + 3_333);
    }

    #[test]
    fn spaced_accesses_do_not_queue() {
        let mut d = dram();
        let t1 = d.access(0);
        let t2 = d.access(100_000);
        assert_eq!(t1, 55_000);
        assert_eq!(t2, 155_000);
    }

    #[test]
    fn sustained_bandwidth_matches_peak() {
        let mut d = dram();
        let n = 10_000u64;
        let mut last = 0;
        for _ in 0..n {
            last = d.access(0);
        }
        // n accesses of 64B at 19.2 GB/s: ~ n * 3333 ps.
        let expect = n * 3_333;
        let got = last - 55_000;
        let rel = (got as f64 - expect as f64).abs() / expect as f64;
        assert!(rel < 0.01, "rel {rel}");
    }
}
