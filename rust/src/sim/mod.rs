//! The full-system timing simulator (the gem5-X substitute, DESIGN.md §2).
//!
//! Components mirror the paper's Table I platform: in-order cores
//! (implicitly modeled by the instruction-class costs executed by
//! `machine`), per-core L1 data caches, a shared LLC, the memory bus,
//! DDR4 DRAM, AIMC tiles (tight ISA coupling or loose PIO coupling), and
//! pthread-style synchronization. `machine::Machine` executes workload
//! traces against all of these and emits `stats::RunStats`.

pub mod aimc;
pub(crate) mod bus;
pub mod cache;
pub(crate) mod dram;
pub(crate) mod hierarchy;
pub mod machine;
pub(crate) mod sync;

pub use aimc::{AimcTile, Coupling, Placement, TileDriftSpec, TileFaultModel, TileHealth};
pub use machine::{ChannelSpec, Machine, MachineSpec, RunError, TileSpec};
