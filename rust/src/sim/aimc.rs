//! The AIMC tile device model (paper §III.B, §V.A, Table I-C).
//!
//! One tile = a PCM crossbar of `rows x cols` unit cells, per-word-line
//! DACs, per-bit-line ADCs, input/output SRAM memories and a local
//! controller. The timing contract:
//!
//!   CM_INITIALIZE — program weights (one-time, outside the ROI).
//!   CM_QUEUE      — move packed int8 inputs into the input memory at
//!                   the tile I/O throughput (4 GB/s tight-coupled).
//!   CM_PROCESS    — fire the MVM: constant 100 ns regardless of size.
//!   CM_DEQUEUE    — move int8 outputs out of the output memory.
//!
//! Tight coupling talks to the tile over a dedicated core-private port
//! (Fig. 2); loose coupling routes every transfer over the peripheral
//! I/O bus (`sim::bus::IoBus`) which the machine charges separately.

use crate::aimclib::faults::{drift_decay, DriftState};
use crate::config::AimcConfig;
use crate::stats::TileActivity;

/// How the tile is attached to the system (§IV.A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Coupling {
    /// Core-private tile behind the CM_* ISA extension (Fig. 2).
    Tight,
    /// Memory-mapped PIO device on the peripheral bus.
    Loose,
}

/// A rectangular region of the crossbar occupied by one logical matrix
/// (AIMClib `mapMatrix` tiles matrices at x/y offsets, §IV.C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub row0: u32,
    pub col0: u32,
    pub rows: u32,
    pub cols: u32,
}

impl Placement {
    pub fn overlaps(&self, other: &Placement) -> bool {
        self.row0 < other.row0 + other.rows
            && other.row0 < self.row0 + self.rows
            && self.col0 < other.col0 + other.cols
            && other.col0 < self.col0 + self.cols
    }
}

#[derive(Debug)]
pub enum AimcError {
    OutOfBounds(Placement, u32, u32),
    Overlap(Placement, Placement),
    InputOverflow(u64, u64),
    OutputOverflow(u64, u64),
    /// The tile's hard-failure time has passed; no further op completes.
    TileFailed { at_ps: u64 },
    /// The I/O port is inside a transient stall window; the op may be
    /// retried at `retry_at_ps` (the machine adds exponential backoff).
    TransientStall { retry_at_ps: u64 },
}

// Manual Display/Error impls: thiserror is not in the offline vendor set.
impl std::fmt::Display for AimcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AimcError::OutOfBounds(p, rows, cols) => {
                write!(f, "placement {p:?} exceeds crossbar {rows}x{cols}")
            }
            AimcError::Overlap(p, q) => {
                write!(f, "placement {p:?} overlaps existing matrix {q:?}")
            }
            AimcError::InputOverflow(bytes, cap) => {
                write!(f, "queue of {bytes} bytes exceeds input memory of {cap} bytes")
            }
            AimcError::OutputOverflow(bytes, cap) => {
                write!(f, "dequeue of {bytes} bytes exceeds output memory of {cap} bytes")
            }
            AimcError::TileFailed { at_ps } => {
                write!(f, "tile hard-failed at t={at_ps}ps")
            }
            AimcError::TransientStall { retry_at_ps } => {
                write!(f, "tile I/O port transiently stalled (retry at t={retry_at_ps}ps)")
            }
        }
    }
}

impl std::error::Error for AimcError {}

/// Deterministic transient/hard fault model of one tile. All faults are
/// parameterized by absolute simulated time — no randomness lives in
/// the device, so runs are reproducible at any `--jobs N` (seed-driven
/// randomness stays in the scenario layer, `coordinator::faults`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileFaultModel {
    /// Tile stops serving queue/dequeue at this time (hard failure).
    pub hard_fail_at_ps: Option<u64>,
    /// Transient stall window length at the start of every period
    /// (models periodic recalibration / refresh glitches of the analog
    /// periphery). `0` disables transient stalls.
    pub transient_stall_ps: u64,
    /// Period of the transient stall windows. `0` disables.
    pub transient_period_ps: u64,
}

impl TileFaultModel {
    /// The fault-free model (the default): every check short-circuits.
    pub fn none() -> TileFaultModel {
        TileFaultModel::default()
    }

    pub fn is_none(&self) -> bool {
        *self == TileFaultModel::default()
    }
}

/// Deterministic conductance-drift model of one tile, integer-encoded
/// (ppm) so the spec stays `Copy + Eq` like [`TileFaultModel`]. Drift
/// degrades *accuracy*, never timing: attaching a spec (active or not)
/// leaves `RunStats` bit-identical, and — unlike transient/hard faults
/// — it does not disable steady-state fast-forward, because the age it
/// is keyed on is the absolute virtual clock minus an absolute
/// programming timestamp, both of which closed-form jumps advance
/// consistently (the jump moves `now`; `programmed_at_ps` stays put).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileDriftSpec {
    /// Drift exponent nu in parts-per-million (50_000 = 0.05). 0
    /// disables drift.
    pub nu_ppm: u32,
    /// Per-device nu dispersion in ppm (see
    /// [`crate::aimclib::faults::DriftState::nu_sigma`]).
    pub nu_sigma_ppm: u32,
    /// Seed of the derived accuracy-proxy plan.
    pub seed: u64,
}

impl TileDriftSpec {
    /// The drift-free spec (the default).
    pub fn none() -> TileDriftSpec {
        TileDriftSpec::default()
    }

    pub fn is_none(&self) -> bool {
        self.nu_ppm == 0
    }

    pub fn nu(&self) -> f64 {
        self.nu_ppm as f64 * 1e-6
    }

    pub fn nu_sigma(&self) -> f64 {
        self.nu_sigma_ppm as f64 * 1e-6
    }
}

/// One reading of a tile's drift-health sensor (see
/// [`AimcTile::health`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileHealth {
    /// When the crossbar was last programmed (virtual ps).
    pub programmed_at_ps: u64,
    /// Time since programming at the probed instant (virtual ps).
    pub age_ps: u64,
    /// Mean conductance decay `(t/t0)^-nu` at the probed instant
    /// (1.0 = fresh or drift disabled).
    pub drift_factor: f64,
}

/// The device: geometry, placements, busy-until reservation, counters.
#[derive(Clone, Debug)]
pub struct AimcTile {
    pub rows: u32,
    pub cols: u32,
    pub coupling: Coupling,
    process_ps: u64,
    io_bytes_per_ps: f64,
    mvm_energy_j: f64,
    io_energy_j_per_byte: f64,
    placements: Vec<Placement>,
    /// The DAC/ADC register file port (queue/dequeue transfers). Double
    /// buffering lets transfers overlap the crossbar MVM (§III.B:
    /// "DACs and ADCs with dedicated registers").
    io_busy_until_ps: u64,
    /// The crossbar itself (CM_PROCESS occupancy).
    xbar_busy_until_ps: u64,
    /// Completion time of the most recent queue (process consumes it).
    last_queue_done_ps: u64,
    /// FIFO of un-dequeued MVM completion times: a dequeue retrieves the
    /// *oldest* pending result (software pipelining queues pixel p+1 and
    /// fires its MVM before draining pixel p's outputs).
    pending_results_ps: std::collections::VecDeque<u64>,
    /// Injected fault model (default: fault-free).
    fault: TileFaultModel,
    /// Injected drift model (default: drift-free). Accuracy-only.
    drift: TileDriftSpec,
    /// Absolute virtual-time programming timestamp t0 of the drift law.
    /// Deliberately NOT advanced by `shift_time` and NOT part of
    /// `ff_state`: fast-forward jumps move `now` past it so drift age
    /// keeps advancing exactly as in full replay.
    programmed_at_ps: u64,
    pub stats: TileActivity,
}

impl AimcTile {
    pub fn new(cfg: &AimcConfig, rows: u32, cols: u32, coupling: Coupling) -> AimcTile {
        AimcTile {
            rows,
            cols,
            coupling,
            process_ps: (cfg.process_latency_s * 1e12).round() as u64,
            io_bytes_per_ps: cfg.io_throughput_bps / 1e12,
            mvm_energy_j: cfg.mvm_energy_j(rows, cols),
            io_energy_j_per_byte: cfg.io_energy_j_per_byte(),
            placements: Vec::new(),
            io_busy_until_ps: 0,
            xbar_busy_until_ps: 0,
            last_queue_done_ps: 0,
            pending_results_ps: std::collections::VecDeque::new(),
            fault: TileFaultModel::none(),
            drift: TileDriftSpec::none(),
            programmed_at_ps: 0,
            stats: TileActivity::default(),
        }
    }

    pub fn set_fault_model(&mut self, fault: TileFaultModel) {
        self.fault = fault;
    }

    pub fn fault_model(&self) -> &TileFaultModel {
        &self.fault
    }

    pub fn set_drift_spec(&mut self, drift: TileDriftSpec) {
        self.drift = drift;
    }

    pub fn drift_spec(&self) -> &TileDriftSpec {
        &self.drift
    }

    /// When the crossbar was last programmed (virtual ps).
    pub fn programmed_at_ps(&self) -> u64 {
        self.programmed_at_ps
    }

    /// Reprogram the crossbar at virtual time `now_ps`, restarting the
    /// drift clock. The refresh downtime/energy is priced by
    /// [`crate::aimclib::faults::reprogram_cost`] at whatever layer
    /// schedules the refresh (the serving router books it as replica
    /// downtime); the device model only moves the timestamp.
    pub fn reprogram(&mut self, now_ps: u64) {
        self.programmed_at_ps = now_ps;
    }

    /// The drift-health sensor: age and conductance decay at `now_ps`.
    /// Pure read — probing never perturbs timing or counters.
    pub fn health(&self, now_ps: u64) -> TileHealth {
        let age_ps = now_ps.saturating_sub(self.programmed_at_ps);
        TileHealth {
            programmed_at_ps: self.programmed_at_ps,
            age_ps,
            drift_factor: drift_decay(age_ps as f64 * 1e-12, self.drift.nu()),
        }
    }

    /// The [`DriftState`] this tile's spec + timestamp imply, for
    /// accuracy-proxy probes through `aimclib::faults::assess_mvm`.
    pub fn drift_state(&self) -> DriftState {
        DriftState {
            programmed_at_ps: self.programmed_at_ps,
            nu: self.drift.nu(),
            nu_sigma: self.drift.nu_sigma(),
            seed: self.drift.seed,
        }
    }

    /// Gate an I/O op at `now_ps` against the injected fault model.
    #[inline]
    fn fault_check(&self, now_ps: u64) -> Result<(), AimcError> {
        if self.fault.is_none() {
            return Ok(());
        }
        if let Some(t) = self.fault.hard_fail_at_ps {
            if now_ps >= t {
                return Err(AimcError::TileFailed { at_ps: t });
            }
        }
        if self.fault.transient_period_ps > 0 && self.fault.transient_stall_ps > 0 {
            let phase = now_ps % self.fault.transient_period_ps;
            if phase < self.fault.transient_stall_ps {
                return Err(AimcError::TransientStall {
                    retry_at_ps: now_ps - phase + self.fault.transient_stall_ps,
                });
            }
        }
        Ok(())
    }

    /// Input memory capacity: one int8 per word line (Table I-C: "M B").
    pub fn input_mem_bytes(&self) -> u64 {
        self.rows as u64
    }

    /// Output memory capacity: one int8 per bit line.
    pub fn output_mem_bytes(&self) -> u64 {
        self.cols as u64
    }

    /// CM_INITIALIZE: claim a crossbar region for a matrix. Programming is
    /// a one-time cost outside the region of interest (§VII.E).
    pub fn map_matrix(&mut self, p: Placement) -> Result<(), AimcError> {
        if p.row0 + p.rows > self.rows || p.col0 + p.cols > self.cols {
            return Err(AimcError::OutOfBounds(p, self.rows, self.cols));
        }
        if let Some(other) = self.placements.iter().find(|q| q.overlaps(&p)) {
            return Err(AimcError::Overlap(p, *other));
        }
        self.placements.push(p);
        self.stats.programmed_weights += p.rows as u64 * p.cols as u64;
        Ok(())
    }

    pub fn placements(&self) -> &[Placement] {
        &self.placements
    }

    /// Transfer time of `bytes` over the *tight* tile port, ps.
    pub fn io_transfer_ps(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.io_bytes_per_ps).round() as u64
    }

    /// CM_QUEUE: `bytes` into input memory starting at `now`. Returns
    /// completion time at the device. Uses the I/O port only — a queue
    /// for the *next* MVM may overlap a running CM_PROCESS.
    pub fn queue(&mut self, now_ps: u64, bytes: u64) -> Result<u64, AimcError> {
        self.fault_check(now_ps)?;
        if bytes > self.input_mem_bytes() {
            return Err(AimcError::InputOverflow(bytes, self.input_mem_bytes()));
        }
        self.stats.queued_bytes += bytes;
        let start = now_ps.max(self.io_busy_until_ps);
        let done = start + self.io_transfer_ps(bytes);
        self.io_busy_until_ps = done;
        self.last_queue_done_ps = done;
        Ok(done)
    }

    /// CM_PROCESS: the analog MVM. Constant latency (Table I-C). Starts
    /// once the crossbar is free and its inputs have finished queueing.
    pub fn process(&mut self, now_ps: u64) -> u64 {
        self.stats.processes += 1;
        let start = now_ps.max(self.xbar_busy_until_ps).max(self.last_queue_done_ps);
        let done = start + self.process_ps;
        self.xbar_busy_until_ps = done;
        self.pending_results_ps.push_back(done);
        done
    }

    /// CM_DEQUEUE: `bytes` out of output memory. Waits for the pending
    /// MVM (ADC registers hold its result) and the I/O port.
    pub fn dequeue(&mut self, now_ps: u64, bytes: u64) -> Result<u64, AimcError> {
        self.fault_check(now_ps)?;
        if bytes > self.output_mem_bytes() {
            return Err(AimcError::OutputOverflow(bytes, self.output_mem_bytes()));
        }
        self.stats.dequeued_bytes += bytes;
        let result_ready = self.pending_results_ps.pop_front().unwrap_or(0);
        let start = now_ps.max(self.io_busy_until_ps).max(result_ready);
        let done = start + self.io_transfer_ps(bytes);
        self.io_busy_until_ps = done;
        Ok(done)
    }

    pub fn process_latency_ps(&self) -> u64 {
        self.process_ps
    }

    /// Tile energy, derived from the integer activity counters (rather
    /// than accumulated per event): `processes * E_mvm + io_bytes *
    /// E_io`. Deriving keeps a fast-forwarded run — which extrapolates
    /// the counters in closed form — bit-identical to full replay.
    pub fn energy_j(&self) -> f64 {
        self.stats.processes as f64 * self.mvm_energy_j
            + (self.stats.queued_bytes + self.stats.dequeued_bytes) as f64
                * self.io_energy_j_per_byte
    }

    /// Sum over processes of (rows * cols), derived from the process
    /// counter (every MVM on this tile has the same geometry).
    pub fn process_ops_weighted(&self) -> f64 {
        self.stats.processes as f64 * (self.rows as f64 * self.cols as f64)
    }

    /// Time-offset state for the periodicity digest: port/crossbar
    /// reservations and pending MVM completions relative to `t_ref`
    /// (stale values clamp — see `sim::machine`).
    pub(crate) fn ff_state(&self, t_ref: u64, out: &mut Vec<u64>) {
        out.push(self.io_busy_until_ps.saturating_sub(t_ref));
        out.push(self.xbar_busy_until_ps.saturating_sub(t_ref));
        out.push(self.last_queue_done_ps.saturating_sub(t_ref));
        out.push(self.pending_results_ps.len() as u64);
        out.extend(self.pending_results_ps.iter().map(|r| r.saturating_sub(t_ref)));
    }

    /// Advance every internal clock by `d` ps (fast-forward jump).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.io_busy_until_ps += d;
        self.xbar_busy_until_ps += d;
        self.last_queue_done_ps += d;
        for r in &mut self.pending_results_ps {
            *r += d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AimcConfig, SystemKind};

    fn tile() -> AimcTile {
        AimcTile::new(&AimcConfig::for_kind(SystemKind::HighPower), 1024, 1024, Coupling::Tight)
    }

    #[test]
    fn process_latency_is_100ns() {
        let mut t = tile();
        assert_eq!(t.process(0), 100_000);
    }

    #[test]
    fn queue_at_4gbps() {
        let mut t = tile();
        // 1024 bytes at 4 GB/s = 256 ns.
        assert_eq!(t.queue(0, 1024).unwrap(), 256_000);
    }

    #[test]
    fn device_serializes_operations() {
        let mut t = tile();
        let q = t.queue(0, 1024).unwrap();
        let p = t.process(0); // issued "early" but queued behind the queue op
        assert_eq!(p, q + 100_000);
    }

    #[test]
    fn overflow_checks() {
        let mut t = tile();
        assert!(t.queue(0, 1025).is_err());
        assert!(t.dequeue(0, 1025).is_err());
        assert!(t.queue(0, 1024).is_ok());
    }

    #[test]
    fn map_matrix_bounds_and_overlap() {
        let mut t = tile();
        let a = Placement { row0: 0, col0: 0, rows: 512, cols: 512 };
        let b = Placement { row0: 256, col0: 256, rows: 512, cols: 512 };
        let c = Placement { row0: 512, col0: 512, rows: 512, cols: 512 };
        let oob = Placement { row0: 600, col0: 0, rows: 512, cols: 16 };
        assert!(t.map_matrix(a).is_ok());
        assert!(matches!(t.map_matrix(b), Err(AimcError::Overlap(..))));
        assert!(t.map_matrix(c).is_ok());
        assert!(matches!(t.map_matrix(oob), Err(AimcError::OutOfBounds(..))));
    }

    #[test]
    fn energy_accumulates() {
        let mut t = tile();
        let e0 = t.energy_j();
        t.process(0);
        let e1 = t.energy_j();
        assert!(e1 > e0);
        t.queue(0, 512).unwrap();
        assert!(t.energy_j() > e1);
        assert!(t.process_ops_weighted() > 0.0);
    }

    #[test]
    fn counters_track_bytes() {
        let mut t = tile();
        t.queue(0, 100).unwrap();
        t.dequeue(0, 50).unwrap();
        assert_eq!(t.stats.queued_bytes, 100);
        assert_eq!(t.stats.dequeued_bytes, 50);
    }

    #[test]
    fn fault_model_gates_io_ops() {
        let mut t = tile();
        // Transient window: first 10 ns of every 100 ns.
        t.set_fault_model(TileFaultModel {
            transient_stall_ps: 10_000,
            transient_period_ps: 100_000,
            ..TileFaultModel::none()
        });
        assert!(matches!(
            t.queue(5_000, 64),
            Err(AimcError::TransientStall { retry_at_ps: 10_000 })
        ));
        // Outside the window the op proceeds and counts.
        assert!(t.queue(20_000, 64).is_ok());
        assert_eq!(t.stats.queued_bytes, 64);
        // Hard failure dominates from its onset time.
        t.set_fault_model(TileFaultModel {
            hard_fail_at_ps: Some(50_000),
            ..TileFaultModel::none()
        });
        assert!(t.dequeue(40_000, 64).is_ok());
        assert!(matches!(t.queue(60_000, 64), Err(AimcError::TileFailed { at_ps: 50_000 })));
        // Failed attempts must not perturb the activity counters.
        assert_eq!(t.stats.queued_bytes, 64);
        assert_eq!(t.stats.dequeued_bytes, 64);
    }

    #[test]
    fn none_fault_model_is_default_and_cheap() {
        let mut t = tile();
        assert!(t.fault_model().is_none());
        t.set_fault_model(TileFaultModel::none());
        assert!(t.queue(0, 64).is_ok());
    }

    #[test]
    fn health_sensor_ages_in_virtual_time_and_reprogram_resets() {
        const S: u64 = 1_000_000_000_000;
        let mut t = tile();
        assert!(t.drift_spec().is_none());
        t.set_drift_spec(TileDriftSpec { nu_ppm: 50_000, nu_sigma_ppm: 10_000, seed: 9 });
        assert_eq!(t.drift_spec().nu(), 0.05);
        // Fresh tile: factor 1.0 regardless of spec.
        assert_eq!(t.health(0).drift_factor, 1.0);
        // Aged tile: decay < 1, monotone in age.
        let h1 = t.health(1_000 * S);
        let h2 = t.health(1_000_000 * S);
        assert!(h1.drift_factor < 1.0);
        assert!(h2.drift_factor < h1.drift_factor);
        assert_eq!(h2.age_ps, 1_000_000 * S);
        // Reprogramming restarts the drift clock.
        t.reprogram(1_000_000 * S);
        let h3 = t.health(1_000_000 * S);
        assert_eq!(h3.age_ps, 0);
        assert_eq!(h3.drift_factor, 1.0);
        assert_eq!(t.programmed_at_ps(), 1_000_000 * S);
        let st = t.drift_state();
        assert_eq!(st.programmed_at_ps, 1_000_000 * S);
        assert_eq!(st.nu, 0.05);
    }

    #[test]
    fn shift_time_never_moves_the_programming_timestamp() {
        // Fast-forward jumps advance `now` and the tile's internal
        // reservation clocks, but the programming timestamp is an
        // absolute event in the past — shifting it would freeze drift
        // age across jumps and diverge from full replay.
        let mut t = tile();
        t.set_drift_spec(TileDriftSpec { nu_ppm: 50_000, nu_sigma_ppm: 0, seed: 1 });
        t.queue(0, 64).unwrap();
        let before = t.programmed_at_ps();
        let mut ff_before = Vec::new();
        t.ff_state(0, &mut ff_before);
        t.shift_time(5_000_000);
        assert_eq!(t.programmed_at_ps(), before);
        // The ff digest must not encode the timestamp either: two tiles
        // differing only in programmed_at_ps digest identically.
        let mut u = tile();
        u.set_drift_spec(TileDriftSpec { nu_ppm: 50_000, nu_sigma_ppm: 0, seed: 1 });
        u.queue(0, 64).unwrap();
        u.reprogram(0); // same timestamp value, but prove the digest ignores it
        let (mut da, mut db) = (Vec::new(), Vec::new());
        t.ff_state(5_000_000, &mut da);
        u.shift_time(5_000_000);
        u.ff_state(5_000_000, &mut db);
        assert_eq!(da, db);
    }
}
