//! The memory system: per-core L1D caches, a shared LLC, the memory bus
//! and DRAM, composed exactly as in the paper's Table I-A systems.
//!
//! All methods take and return picosecond timestamps; contention state
//! (bus/DRAM busy-until) lives inside, so callers must issue accesses in
//! non-decreasing time order (the trace machine guarantees this by always
//! stepping the earliest core).

use crate::config::SystemConfig;
use crate::sim::bus::MemBus;
use crate::sim::cache::{Access, Cache};
use crate::sim::dram::Dram;
use crate::stats::CacheStats;
use crate::workload::costs;

#[derive(Clone, Copy, Debug)]
pub struct AccessOutcome {
    /// Time at which the data is available to the core, ps.
    pub completion_ps: u64,
    pub l1_hit: bool,
    pub llc_hit: bool,
    pub dram_access: bool,
}

/// Aggregate outcome of one bulk sequential stream ([`MemorySystem::stream`]).
/// Per-level hit/miss counts live in the caches' own `stats`, as with
/// `access` — this carries only what the core model needs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamOutcome {
    /// Core-visible time after issuing every line and absorbing the
    /// effective (prefetch-overlapped) stalls, ps.
    pub end_ps: u64,
    /// Total effective stall time accumulated over the stream, ps.
    pub stall_ps: u64,
    /// Lines served from the core's L1.
    pub l1_hits: u64,
}

pub struct MemorySystem {
    l1d: Vec<Cache>,
    llc: Cache,
    bus: MemBus,
    dram: Dram,
    line_bytes: u64,
    l1_hit_ps: u64,
    llc_hit_ps: u64,
    snoop_ps: u64,
    pub llc_bytes_read: u64,
    pub llc_bytes_written: u64,
}

impl MemorySystem {
    pub fn new(cfg: &SystemConfig) -> MemorySystem {
        let cycle = cfg.cycle_ps();
        MemorySystem {
            l1d: (0..cfg.num_cores).map(|_| Cache::new(cfg.l1d)).collect(),
            llc: Cache::new(cfg.llc),
            bus: MemBus::new(
                cycle,
                cfg.membus_frontend_cycles,
                cfg.membus_fwd_cycles,
                cfg.membus_width_bytes,
                cfg.llc.line_bytes,
            ),
            dram: Dram::new(cfg.dram_latency_s, cfg.dram_peak_bps, cfg.llc.line_bytes),
            line_bytes: cfg.l1d.line_bytes,
            l1_hit_ps: cfg.l1d.hit_latency_cycles * cycle,
            llc_hit_ps: cfg.llc.hit_latency_cycles * cycle,
            snoop_ps: cfg.membus_fwd_cycles * cycle,
            llc_bytes_read: 0,
            llc_bytes_written: 0,
        }
    }

    pub fn line_bytes(&self) -> u64 {
        self.line_bytes
    }

    /// One line-granular access by `core` at time `now`.
    pub fn access(&mut self, core: usize, addr: u64, write: bool, now_ps: u64) -> AccessOutcome {
        let kind = if write { Access::Write } else { Access::Read };
        let r1 = self.l1d[core].access(addr, kind);
        if r1.hit {
            return AccessOutcome {
                completion_ps: now_ps + self.l1_hit_ps,
                l1_hit: true,
                llc_hit: false,
                dram_access: false,
            };
        }
        self.after_l1_miss(r1.writeback, addr, now_ps)
    }

    /// The below-L1 leg of a miss (shared by `access` and `stream`): the
    /// L1 has already allocated the line and reported whether it evicted
    /// a dirty victim.
    #[inline]
    fn after_l1_miss(&mut self, l1_victim_dirty: bool, addr: u64, now_ps: u64) -> AccessOutcome {
        // L1 victim writeback drains to the LLC via the write buffer; it
        // consumes LLC write bandwidth/energy but does not stall the core.
        if l1_victim_dirty {
            self.llc.access(addr ^ 0x8000_0000_0000, Access::Write); // victim line
            self.llc_bytes_written += self.line_bytes;
        }

        // Cross the bus to the LLC.
        let at_llc = self.bus.request(now_ps + self.l1_hit_ps);
        let r2 = self.llc.access(addr, Access::Read);
        self.llc_bytes_read += self.line_bytes;
        if r2.hit {
            let done = at_llc + self.llc_hit_ps + self.bus.response_ps();
            return AccessOutcome {
                completion_ps: done,
                l1_hit: false,
                llc_hit: true,
                dram_access: false,
            };
        }
        // LLC victim writeback to DRAM: consumes channel bandwidth only.
        if r2.writeback {
            self.dram.access(at_llc + self.llc_hit_ps);
        }
        let from_dram = self.dram.access(at_llc + self.llc_hit_ps);
        // Fill travels back through LLC and bus.
        self.llc_bytes_written += self.line_bytes;
        let done = from_dram + self.bus.response_ps();
        AccessOutcome {
            completion_ps: done,
            l1_hit: false,
            llc_hit: false,
            dram_access: true,
        }
    }

    /// Bulk sequential stream: `lines` consecutive lines from `base` by
    /// `core`, with the core-side issue/stall policy folded in so the
    /// whole walk runs as one tight loop. Semantics are line-for-line
    /// identical to the per-line `access` loop the trace machine used to
    /// run (the machine keeps that loop as a reference mode and tests
    /// assert bit-equality):
    ///
    /// * each line first charges `issue_ps_per_line` of core issue time;
    /// * an L1 hit stalls nothing;
    /// * a miss stalls for `completion - now`, divided by the stride
    ///   prefetcher depth for every miss past the first when
    ///   `prefetchable` (§VI.C) — the effective stall advances `now`.
    ///
    /// The fast path: L1-resident runs are swallowed by a single
    /// `Cache::stream_run` walk per miss-to-miss span (one set-index
    /// walk, amortized stats, no per-line outcome plumbing), and WFM
    /// cycle conversion is left to the caller as one aggregate
    /// `stall_ps` instead of a division per line.
    #[allow(clippy::too_many_arguments)]
    pub fn stream(
        &mut self,
        core: usize,
        base: u64,
        lines: u64,
        write: bool,
        now_ps: u64,
        issue_ps_per_line: u64,
        prefetchable: bool,
    ) -> StreamOutcome {
        let kind = if write { Access::Write } else { Access::Read };
        let line_bytes = self.line_bytes;
        let mut out = StreamOutcome { end_ps: now_ps, ..Default::default() };
        let mut now = now_ps;
        let mut k = 0u64;
        let mut first_miss = true;
        while k < lines {
            let run = self.l1d[core].stream_run(base + k * line_bytes, lines - k, kind);
            now += run.hits * issue_ps_per_line;
            out.l1_hits += run.hits;
            k += run.hits;
            let Some(l1_victim_dirty) = run.miss_writeback else {
                break; // every remaining line hit
            };
            // Line `k` missed (already allocated in L1 by the walk):
            // charge its issue slot, then walk the lower levels.
            now += issue_ps_per_line;
            let o = self.after_l1_miss(l1_victim_dirty, base + k * line_bytes, now);
            let stall = o.completion_ps.saturating_sub(now);
            // A stride prefetcher overlaps misses past the first in a
            // sequential stream; random access pays full latency.
            let eff = if prefetchable && !first_miss {
                stall / costs::PREFETCH_DEPTH
            } else {
                stall
            };
            first_miss = false;
            now += eff;
            out.stall_ps += eff;
            k += 1;
        }
        out.end_ps = now;
        out
    }

    /// Consumer `to` reads a line most recently written by producer `from`
    /// (pipeline channels, §VI.C ping-pong buffers). Models the coherent
    /// transfer: snoop the producer's L1, move the line to the consumer.
    pub fn shared_transfer(&mut self, from: usize, to: usize, addr: u64, now_ps: u64) -> AccessOutcome {
        // Invalidate at the producer (line migrates).
        let was_in_producer = self.l1d[from].invalidate(addr);
        // The consumer's access then misses L1 and is served either by the
        // producer's L1 (snoop hit) or by the LLC.
        let at_llc = self.bus.request(now_ps + self.l1_hit_ps);
        let snoop_extra = if was_in_producer { self.snoop_ps } else { 0 };
        let r2 = self.llc.access(addr, Access::Write); // line lands shared+dirty
        self.llc_bytes_written += self.line_bytes;
        let base = if r2.hit || was_in_producer {
            at_llc + self.llc_hit_ps + snoop_extra
        } else {
            if r2.writeback {
                self.dram.access(at_llc + self.llc_hit_ps);
            }
            self.dram.access(at_llc + self.llc_hit_ps)
        };
        // Install in the consumer's L1.
        self.l1d[to].access(addr, Access::Read);
        AccessOutcome {
            completion_ps: base + self.bus.response_ps(),
            l1_hit: false,
            llc_hit: r2.hit,
            dram_access: !(r2.hit || was_in_producer),
        }
    }

    /// Visit every monotonic counter in a fixed order (fast-forward
    /// snapshot/extrapolation — see `sim::machine`).
    pub(crate) fn for_each_counter(&mut self, f: &mut dyn FnMut(&mut u64)) {
        for c in &mut self.l1d {
            c.for_each_counter(f);
        }
        self.llc.for_each_counter(f);
        f(&mut self.llc_bytes_read);
        f(&mut self.llc_bytes_written);
        f(&mut self.dram.accesses);
        f(&mut self.bus.transactions);
    }

    /// Cheap time-offset state for the periodicity digest: DRAM-channel
    /// and memory-bus reservations relative to `t_ref` (values at or
    /// before `t_ref` are behaviorally stale — every future access
    /// happens at `t >= t_ref` — so they clamp to zero).
    pub(crate) fn ff_state(&self, t_ref: u64, out: &mut Vec<u64>) {
        out.push(self.dram.busy_until_ps().saturating_sub(t_ref));
        out.push(self.bus.busy_until_ps().saturating_sub(t_ref));
    }

    /// Per-cache occupancy fingerprints (the expensive O(lines) digest
    /// tier, computed only on candidate rounds).
    pub(crate) fn occupancy_vec(&self) -> Vec<u64> {
        let mut v = Vec::with_capacity(3 * (self.l1d.len() + 1));
        for c in &self.l1d {
            let (valid, dirty, hash) = c.occupancy_digest();
            v.extend([valid, dirty, hash]);
        }
        let (valid, dirty, hash) = self.llc.occupancy_digest();
        v.extend([valid, dirty, hash]);
        v
    }

    /// Advance every internal clock by `d` ps (fast-forward jump).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.dram.shift_time(d);
        self.bus.shift_time(d);
    }

    pub fn l1_stats(&self, core: usize) -> &CacheStats {
        &self.l1d[core].stats
    }

    pub fn l1_stats_merged(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for c in &self.l1d {
            s.merge(&c.stats);
        }
        s
    }

    pub fn llc_stats(&self) -> &CacheStats {
        &self.llc.stats
    }

    pub fn dram_accesses(&self) -> u64 {
        self.dram.accesses
    }

    pub fn l1_hit_ps(&self) -> u64 {
        self.l1_hit_ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn ms() -> MemorySystem {
        MemorySystem::new(&SystemConfig::high_power())
    }

    #[test]
    fn l1_hit_is_fast() {
        let mut m = ms();
        m.access(0, 0x1000, false, 0);
        let o = m.access(0, 0x1000, false, 1_000_000);
        assert!(o.l1_hit);
        assert_eq!(o.completion_ps - 1_000_000, 2 * 435);
    }

    #[test]
    fn cold_miss_goes_to_dram() {
        let mut m = ms();
        let o = m.access(0, 0x1000, false, 0);
        assert!(!o.l1_hit && !o.llc_hit && o.dram_access);
        // At least the DRAM latency.
        assert!(o.completion_ps > 55_000);
        assert_eq!(m.dram_accesses(), 1);
    }

    #[test]
    fn second_core_hits_llc() {
        let mut m = ms();
        m.access(0, 0x2000, false, 0);
        let o = m.access(1, 0x2000, false, 1_000_000);
        assert!(!o.l1_hit && o.llc_hit && !o.dram_access);
        assert!(o.completion_ps - 1_000_000 < 55_000);
    }

    #[test]
    fn streaming_2mb_thrashes_1mb_llc() {
        let mut m = ms();
        let mb = 1024 * 1024;
        // Two passes over 2 MiB: every access in the second pass still
        // misses the 1 MiB LLC (the paper's MLP working-set argument).
        let mut t = 0;
        for pass in 0..2 {
            let mut dram_hits = 0;
            for addr in (0..2 * mb).step_by(64) {
                let o = m.access(0, addr, false, t);
                t = o.completion_ps;
                if o.dram_access {
                    dram_hits += 1;
                }
            }
            assert!(
                dram_hits > 30_000,
                "pass {pass}: expected thrashing, got {dram_hits} DRAM accesses"
            );
        }
    }

    #[test]
    fn small_working_set_stays_in_l1() {
        let mut m = ms();
        let mut t = 0;
        for addr in (0..3 * 1024).step_by(64) {
            t = m.access(0, addr, false, t).completion_ps;
        }
        let before = m.dram_accesses();
        for addr in (0..3 * 1024).step_by(64) {
            let o = m.access(0, addr, false, t);
            t = o.completion_ps;
            assert!(o.l1_hit);
        }
        assert_eq!(m.dram_accesses(), before);
    }

    #[test]
    fn stream_equals_per_line_access_loop() {
        let mut bulk = ms();
        let mut per_line = ms();
        let issue = 2 * 435u64;
        // Pass 0: cold prefetchable stream; pass 1: all L1 hits.
        for _pass in 0..2 {
            let mut now = 1_000u64;
            let mut first_miss = true;
            let mut stall_total = 0u64;
            for k in 0..32u64 {
                now += issue;
                let o = per_line.access(0, 0x4000 + k * 64, false, now);
                if !o.l1_hit {
                    let stall = o.completion_ps.saturating_sub(now);
                    let eff = if !first_miss { stall / costs::PREFETCH_DEPTH } else { stall };
                    first_miss = false;
                    now += eff;
                    stall_total += eff;
                }
            }
            let out = bulk.stream(0, 0x4000, 32, false, 1_000, issue, true);
            assert_eq!(out.end_ps, now);
            assert_eq!(out.stall_ps, stall_total);
            assert_eq!(bulk.dram_accesses(), per_line.dram_accesses());
            assert_eq!(bulk.l1_stats(0), per_line.l1_stats(0));
        }
        // The second pass saw only hits.
        let out = bulk.stream(0, 0x4000, 32, false, 0, issue, true);
        assert_eq!(out.l1_hits, 32);
        assert_eq!(out.stall_ps, 0);
    }

    #[test]
    fn shared_transfer_moves_line() {
        let mut m = ms();
        m.access(0, 0x3000, true, 0); // producer writes
        let o = m.shared_transfer(0, 1, 0x3000, 1_000_000);
        assert!(!o.dram_access, "snoop-served, not DRAM");
        // Consumer now hits locally.
        let o2 = m.access(1, 0x3000, false, o.completion_ps);
        assert!(o2.l1_hit);
    }
}
