//! The trace machine: executes per-core `TraceOp` streams against the
//! timing models (cores, memory hierarchy, AIMC tiles, sync primitives)
//! and produces `RunStats`.
//!
//! Scheduling is conservative global-time ordering: the machine always
//! steps the earliest-time runnable core, so shared resources (bus, DRAM,
//! tiles, mutexes, channels) observe accesses in near-nondecreasing time
//! order. A core blocked on a channel or mutex is advanced to just after
//! the earliest other runnable core and retried — the standard
//! lockstep-free conservative scheme.

use crate::config::SystemConfig;

use crate::sim::aimc::{AimcTile, Coupling};
use crate::sim::bus::IoBus;
use crate::sim::hierarchy::MemorySystem;
use crate::sim::sync::{SimChannel, SimMutex};
use crate::stats::{CoreStats, RoiKind, RoiTimes, RunStats};
use crate::workload::costs;
use crate::workload::trace::TraceOp;

/// Static description of the simulated platform's accelerator + sync
/// fabric (which tile belongs to which core, channel topology).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MachineSpec {
    pub tiles: Vec<TileSpec>,
    pub mutexes: usize,
    pub channels: Vec<ChannelSpec>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSpec {
    pub rows: u32,
    pub cols: u32,
    pub coupling: Coupling,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChannelSpec {
    pub producer: usize,
    pub consumer: usize,
    pub capacity: usize,
}

struct CoreRun {
    now_ps: u64,
    pc: usize,
    roi_stack: Vec<RoiKind>,
    stats: CoreStats,
    /// This core was parked at the current pc (retry after a block): sync
    /// ops must not complete earlier than the event that unparked them.
    retrying: bool,
    /// Sub-cycle remainders so ps->cycle conversion conserves time.
    wfm_residual_ps: u64,
    idle_residual_ps: u64,
}

pub struct Machine {
    cfg: SystemConfig,
    mem: MemorySystem,
    tiles: Vec<AimcTile>,
    iobus: IoBus,
    mutexes: Vec<SimMutex>,
    channels: Vec<SimChannel>,
    channel_specs: Vec<ChannelSpec>,
    roi: RoiTimes,
    cycle_ps: u64,
    /// Route `MemStream` through the bulk `MemorySystem::stream` fast
    /// path (default). The per-line reference loop is kept for the
    /// equivalence tests and the `micro_sim` baseline bench.
    batched_streams: bool,
}

enum StepResult {
    Progressed,
    Blocked,
}

impl Machine {
    pub fn new(cfg: SystemConfig, spec: MachineSpec) -> Machine {
        let MachineSpec { tiles: tile_specs, mutexes, channels } = spec;
        let tiles = tile_specs
            .iter()
            .map(|t| AimcTile::new(&cfg.aimc, t.rows, t.cols, t.coupling))
            .collect();
        let iobus = IoBus::new(cfg.aimc.pio_transaction_s, cfg.aimc.pio_throughput_bps);
        Machine {
            mem: MemorySystem::new(&cfg),
            tiles,
            iobus,
            mutexes: (0..mutexes).map(|_| SimMutex::default()).collect(),
            channels: channels.iter().map(|c| SimChannel::new(c.capacity)).collect(),
            channel_specs: channels,
            roi: RoiTimes::default(),
            cycle_ps: cfg.cycle_ps(),
            batched_streams: true,
            cfg,
        }
    }

    pub fn tiles(&self) -> &[AimcTile] {
        &self.tiles
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Select between the bulk memory-stream fast path (default) and the
    /// per-line reference loop. Both produce bit-identical statistics;
    /// the knob exists for equivalence tests and perf baselines.
    pub fn set_batched_streams(&mut self, on: bool) {
        self.batched_streams = on;
    }

    /// Execute one trace per core (empty traces = unused cores). Returns
    /// the full run statistics.
    pub fn run(&mut self, traces: Vec<Vec<TraceOp>>) -> RunStats {
        assert!(traces.len() <= self.cfg.num_cores, "more traces than cores");
        let n = traces.len();
        let mut cores: Vec<CoreRun> = (0..n)
            .map(|_| CoreRun {
                now_ps: 0,
                pc: 0,
                roi_stack: Vec::new(),
                stats: CoreStats::default(),
                retrying: false,
                wfm_residual_ps: 0,
                idle_residual_ps: 0,
            })
            .collect();

        // Blocked-flag scheduling: a core that cannot make progress (full
        // channel, empty channel, held mutex) is parked until *any* other
        // core progresses; the grant/ready timestamps of the sync
        // primitives supply the correct wait times on retry.
        let mut blocked = vec![false; n];
        loop {
            let mut next: Option<usize> = None;
            for i in 0..n {
                if cores[i].pc < traces[i].len() && !blocked[i] {
                    match next {
                        Some(j) if cores[j].now_ps <= cores[i].now_ps => {}
                        _ => next = Some(i),
                    }
                }
            }
            let Some(i) = next else {
                // Report *every* blocked core with its pending op — a
                // multi-core deadlock is rarely diagnosable from the
                // first victim alone.
                let stuck: Vec<String> = (0..n)
                    .filter(|&j| cores[j].pc < traces[j].len())
                    .map(|j| {
                        format!(
                            "core {j} @ t={}ps op[{}/{}] {:?}",
                            cores[j].now_ps,
                            cores[j].pc,
                            traces[j].len(),
                            traces[j][cores[j].pc]
                        )
                    })
                    .collect();
                if !stuck.is_empty() {
                    panic!(
                        "deadlock: {} core(s) blocked with no runnable peers:\n  {}",
                        stuck.len(),
                        stuck.join("\n  ")
                    );
                }
                break;
            };

            match self.step(i, &mut cores, &traces) {
                StepResult::Progressed => {
                    blocked.iter_mut().for_each(|b| *b = false);
                    cores[i].retrying = false;
                }
                StepResult::Blocked => {
                    blocked[i] = true;
                    cores[i].retrying = true;
                }
            }
        }

        // Pad finished cores to the global end-of-ROI (idle).
        let end = cores.iter().map(|c| c.now_ps).max().unwrap_or(0);
        for c in &mut cores {
            c.stats.idle_cycles += (end - c.now_ps) / self.cycle_ps;
            c.now_ps = end;
        }

        let mut rs = RunStats::new(n);
        rs.roi_time_ps = end;
        for (i, c) in cores.into_iter().enumerate() {
            rs.cores[i] = c.stats;
        }
        rs.l1d = self.mem.l1_stats_merged();
        rs.llc = self.mem.llc_stats().clone();
        rs.dram_accesses = self.mem.dram_accesses();
        rs.llc_bytes_read = self.mem.llc_bytes_read;
        rs.llc_bytes_written = self.mem.llc_bytes_written;
        for t in &self.tiles {
            rs.aimc.processes += t.stats.processes;
            rs.aimc.queued_bytes += t.stats.queued_bytes;
            rs.aimc.dequeued_bytes += t.stats.dequeued_bytes;
            rs.aimc.programmed_weights += t.stats.programmed_weights;
            rs.aimc.process_ops_weighted += t.stats.process_ops_weighted;
            rs.aimc.energy_j += t.stats.energy_j;
        }
        rs.roi = self.roi.clone();
        rs
    }

    fn step(&mut self, i: usize, cores: &mut [CoreRun], traces: &[Vec<TraceOp>]) -> StepResult {
        let op = traces[i][cores[i].pc];
        let t0 = cores[i].now_ps;
        let result = self.exec(i, &mut cores[i], op);
        if matches!(result, StepResult::Progressed) {
            let kind = cores[i].roi_stack.last().copied().unwrap_or(RoiKind::Misc);
            self.roi.add(kind, cores[i].now_ps - t0);
            cores[i].pc += 1;
        }
        result
    }

    #[inline]
    fn active(&self, core: &mut CoreRun, cycles: u64, insts: u64) {
        core.stats.active_cycles += cycles;
        core.stats.insts += insts;
        core.now_ps += cycles * self.cycle_ps;
    }

    #[inline]
    fn wfm(&self, core: &mut CoreRun, ps: u64) {
        let total = ps + core.wfm_residual_ps;
        core.stats.wfm_cycles += total / self.cycle_ps;
        core.wfm_residual_ps = total % self.cycle_ps;
        core.now_ps += ps;
    }

    #[inline]
    fn idle(&self, core: &mut CoreRun, ps: u64) {
        let total = ps + core.idle_residual_ps;
        core.stats.idle_cycles += total / self.cycle_ps;
        core.idle_residual_ps = total % self.cycle_ps;
        core.now_ps += ps;
    }

    fn exec(&mut self, i: usize, core: &mut CoreRun, op: TraceOp) -> StepResult {
        match op {
            TraceOp::Compute { class, insts } => {
                self.active(core, insts * class.cycles(), insts);
            }

            TraceOp::MemStream { base, bytes, write, insts_per_line, prefetchable } => {
                let line = self.mem.line_bytes();
                let lines = bytes.div_ceil(line);
                if self.batched_streams {
                    // Bulk fast path: one hierarchy walk for the whole
                    // stream. Issue/stall interleaving happens inside
                    // `MemorySystem::stream`; one aggregate active() +
                    // wfm() call is exactly the residual-carry sum of the
                    // per-line calls (the reference loop in the `else`
                    // arm), so stats are bit-identical. Both helpers also
                    // advance now_ps, which the stream already accounted
                    // for — end_ps overwrites it below.
                    let issue_ps = insts_per_line * self.cycle_ps;
                    let out = self.mem.stream(
                        i,
                        base,
                        lines,
                        write,
                        core.now_ps,
                        issue_ps,
                        prefetchable,
                    );
                    self.active(core, lines * insts_per_line, lines * insts_per_line);
                    self.wfm(core, out.stall_ps);
                    core.now_ps = out.end_ps;
                } else {
                    // Per-line reference loop (the pre-batching semantics;
                    // kept for equivalence tests and perf baselines).
                    let mut first_miss = true;
                    for k in 0..lines {
                        self.active(core, insts_per_line, insts_per_line);
                        let o = self.mem.access(i, base + k * line, write, core.now_ps);
                        if !o.l1_hit {
                            let stall = o.completion_ps.saturating_sub(core.now_ps);
                            // A stride prefetcher overlaps misses past the first
                            // in a sequential stream; random access pays full.
                            let eff = if prefetchable && !first_miss {
                                stall / costs::PREFETCH_DEPTH
                            } else {
                                stall
                            };
                            first_miss = false;
                            self.wfm(core, eff);
                        }
                    }
                }
            }

            TraceOp::CmInit { tile, placement } => {
                self.tiles[tile]
                    .map_matrix(placement)
                    .expect("workload generator produced an invalid placement");
                self.active(core, 1, 1);
            }

            TraceOp::CmQueue { tile, bytes } => {
                // The device transfer streams concurrently with the CPU's
                // CM_QUEUE beat issue: the device is engaged from the
                // first beat, the CPU stalls only for the residual.
                let start = core.now_ps;
                let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST);
                let overhead = beats * costs::CM_IO_OVERHEAD_PER_INST_X1000 / 1000;
                let done = match self.tiles[tile].coupling {
                    Coupling::Tight => self.tiles[tile]
                        .queue(start, bytes)
                        .expect("queue exceeds tile input memory"),
                    Coupling::Loose => {
                        let bus_done = self.iobus.transfer(start, bytes);
                        self.tiles[tile]
                            .queue(bus_done, 0)
                            .expect("zero-byte device op cannot overflow");
                        bus_done
                    }
                };
                self.active(core, beats + overhead, beats + overhead);
                let stall = done.saturating_sub(core.now_ps);
                self.wfm(core, stall);
            }

            TraceOp::CmProcess { tile } => {
                // Tight coupling: CM_PROCESS fires the MVM and retires
                // (the result is awaited by the dependent CM_DEQUEUE, so
                // software can overlap the next queue with the MVM).
                // Loose coupling: the doorbell+poll round trip blocks.
                self.active(core, 1, 1);
                let done = self.tiles[tile].process(core.now_ps);
                if self.tiles[tile].coupling == Coupling::Loose {
                    self.wfm(core, done - core.now_ps);
                }
            }

            TraceOp::CmDequeue { tile, bytes } => {
                let start = core.now_ps;
                let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST);
                let overhead = beats * costs::CM_IO_OVERHEAD_PER_INST_X1000 / 1000;
                let done = match self.tiles[tile].coupling {
                    Coupling::Tight => self.tiles[tile]
                        .dequeue(start, bytes)
                        .expect("dequeue exceeds tile output memory"),
                    Coupling::Loose => {
                        let bus_done = self.iobus.transfer(start, bytes);
                        self.tiles[tile]
                            .dequeue(bus_done, 0)
                            .expect("zero-byte device op cannot overflow");
                        bus_done
                    }
                };
                self.active(core, beats + overhead, beats + overhead);
                let stall = done.saturating_sub(core.now_ps);
                self.wfm(core, stall);
            }

            TraceOp::MutexLock { id } => {
                let Some(granted) = self.mutexes[id].try_acquire(core.now_ps) else {
                    return StepResult::Blocked;
                };
                self.mutexes[id].lock();
                if granted > core.now_ps {
                    let wait = granted - core.now_ps;
                    self.idle(core, wait);
                }
                self.active(core, costs::MUTEX_INSTS, costs::MUTEX_INSTS);
            }

            TraceOp::MutexUnlock { id } => {
                self.active(core, costs::MUTEX_INSTS / 2, costs::MUTEX_INSTS / 2);
                self.mutexes[id].release(core.now_ps);
            }

            TraceOp::Send { ch, bytes, addr } => {
                if self.channels[ch].len() >= self.channels[ch].capacity {
                    return StepResult::Blocked;
                }
                // If this send was parked on a full buffer, it resumes no
                // earlier than the drain that freed the slot.
                if core.retrying && self.channels[ch].last_recv_ps > core.now_ps {
                    let wait = self.channels[ch].last_recv_ps - core.now_ps;
                    self.idle(core, wait);
                }
                self.active(core, costs::CHANNEL_INSTS, costs::CHANNEL_INSTS);
                // Producer writes the buffer through its cache.
                let line = self.mem.line_bytes();
                for k in 0..bytes.div_ceil(line) {
                    self.active(core, 1, 1);
                    let o = self.mem.access(i, addr + k * line, true, core.now_ps);
                    if !o.l1_hit {
                        self.wfm(core, (o.completion_ps - core.now_ps) / costs::PREFETCH_DEPTH);
                    }
                }
                let ok = self.channels[ch].try_send(core.now_ps, bytes, addr);
                debug_assert!(ok);
            }

            TraceOp::Recv { ch } => {
                let msg = match self.channels[ch].head_ready_ps() {
                    None => return StepResult::Blocked,
                    Some(ready) => {
                        // If the message is already there, the condvar
                        // fast-path applies (no sleep). If the consumer
                        // must wait, it sleeps on the futex and pays the
                        // kernel wake-up latency on resume.
                        if ready > core.now_ps {
                            let wake_ps = costs::CHANNEL_WAKE_CYCLES * self.cycle_ps;
                            let wait = ready + wake_ps - core.now_ps;
                            self.idle(core, wait);
                        }
                        self.channels[ch].try_recv(core.now_ps).unwrap()
                    }
                };
                self.active(core, costs::CHANNEL_INSTS, costs::CHANNEL_INSTS);
                let producer = self.channel_specs[ch].producer;
                let line = self.mem.line_bytes();
                for k in 0..msg.bytes.div_ceil(line) {
                    self.active(core, 1, 1);
                    let o = self.mem.shared_transfer(producer, i, msg.addr + k * line, core.now_ps);
                    self.wfm(core, (o.completion_ps - core.now_ps) / 2);
                }
            }

            TraceOp::RoiPush { kind } => {
                core.roi_stack.push(kind);
            }
            TraceOp::RoiPop => {
                core.roi_stack.pop();
            }
        }
        StepResult::Progressed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::InstClass;
    use crate::sim::aimc::Placement;
    use crate::workload::trace::TraceBuilder;

    fn hp_machine(spec: MachineSpec) -> Machine {
        Machine::new(SystemConfig::high_power(), spec)
    }

    #[test]
    fn pure_compute_ipc_near_one() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 100_000);
        let rs = m.run(vec![b.build()]);
        assert!((rs.cores[0].ipc() - 1.0).abs() < 0.01);
        assert_eq!(rs.total_insts(), 100_000);
    }

    #[test]
    fn mem_stream_generates_dram_traffic() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.stream_read(0x10_0000, 4 * 1024 * 1024, 4); // 4 MiB > 1 MiB LLC
        let rs = m.run(vec![b.build()]);
        assert!(rs.dram_accesses > 60_000, "{}", rs.dram_accesses);
        assert!(rs.cores[0].wfm_cycles > 0);
    }

    #[test]
    fn small_stream_second_pass_hits_l1() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.stream_read(0, 8 * 1024, 4);
        b.stream_read(0, 8 * 1024, 4);
        let rs = m.run(vec![b.build()]);
        // Second pass hits: misses only from first pass.
        assert_eq!(rs.l1d.read_misses, 8 * 1024 / 64);
    }

    #[test]
    fn cm_dequeue_waits_for_process_100ns() {
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 1024, cols: 1024, coupling: Coupling::Tight }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let ops = vec![
            TraceOp::CmInit {
                tile: 0,
                placement: Placement { row0: 0, col0: 0, rows: 1024, cols: 1024 },
            },
            TraceOp::CmProcess { tile: 0 },
            // The dependent dequeue observes the full 100 ns MVM latency
            // (CM_PROCESS itself retires immediately — double-buffered
            // DAC/ADC registers let software overlap the next queue).
            TraceOp::CmDequeue { tile: 0, bytes: 4 },
        ];
        let rs = m.run(vec![ops]);
        assert!(rs.roi_time_ps >= 100_000, "{}", rs.roi_time_ps);
        assert_eq!(rs.aimc.processes, 1);
    }

    #[test]
    fn queue_throughput_4gbps() {
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 4096, cols: 64, coupling: Coupling::Tight }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let ops = vec![TraceOp::CmQueue { tile: 0, bytes: 4096 }];
        let rs = m.run(vec![ops]);
        // 4096B at 4GB/s = 1024ns; issue of 1024+512 insts at 2.3GHz ~ 668ns,
        // so the transfer dominates and total ~ 1024ns.
        assert!(rs.roi_time_ps >= 1_024_000, "{}", rs.roi_time_ps);
        assert!(rs.roi_time_ps < 1_200_000, "{}", rs.roi_time_ps);
    }

    #[test]
    fn loose_coupling_slower_than_tight() {
        let mk = |coupling| MachineSpec {
            tiles: vec![TileSpec { rows: 1024, cols: 1024, coupling }],
            ..Default::default()
        };
        let run = |coupling| {
            let mut m = hp_machine(mk(coupling));
            let ops = vec![
                TraceOp::CmQueue { tile: 0, bytes: 1024 },
                TraceOp::CmProcess { tile: 0 },
                TraceOp::CmDequeue { tile: 0, bytes: 1024 },
            ];
            m.run(vec![ops]).roi_time_ps
        };
        let tight = run(Coupling::Tight);
        let loose = run(Coupling::Loose);
        assert!(loose > 2 * tight, "tight {tight} loose {loose}");
    }

    #[test]
    fn channel_pipeline_transfers_data() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let mut p = TraceBuilder::new();
        p.compute(InstClass::IntAlu, 1000);
        p.push(TraceOp::Send { ch: 0, bytes: 1024, addr: 0x5000 });
        let mut c = TraceBuilder::new();
        c.push(TraceOp::Recv { ch: 0 });
        c.compute(InstClass::IntAlu, 1000);
        let rs = m.run(vec![p.build(), c.build()]);
        // Consumer idled waiting for the producer.
        assert!(rs.cores[1].idle_cycles > 0);
        assert_eq!(rs.cores.len(), 2);
    }

    #[test]
    fn bounded_channel_blocks_producer() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 1 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let mut p = TraceBuilder::new();
        for k in 0..4 {
            p.push(TraceOp::Send { ch: 0, bytes: 64, addr: 0x5000 + k * 64 });
        }
        let mut c = TraceBuilder::new();
        c.compute(InstClass::IntAlu, 500_000); // slow consumer
        for _ in 0..4 {
            c.push(TraceOp::Recv { ch: 0 });
        }
        let rs = m.run(vec![p.build(), c.build()]);
        assert!(rs.cores[0].idle_cycles > 100_000, "{}", rs.cores[0].idle_cycles);
    }

    #[test]
    fn mutex_serializes_cores() {
        let spec = MachineSpec { mutexes: 1, ..Default::default() };
        let mut m = hp_machine(spec);
        let critical = |_: usize| {
            let mut b = TraceBuilder::new();
            b.push(TraceOp::MutexLock { id: 0 });
            b.compute(InstClass::IntAlu, 100_000);
            b.push(TraceOp::MutexUnlock { id: 0 });
            b.build()
        };
        let rs = m.run(vec![critical(0), critical(1)]);
        // Both critical sections serialized: ~200k cycles total.
        let total_cycles = rs.roi_time_ps / SystemConfig::high_power().cycle_ps();
        assert!(total_cycles > 195_000, "{total_cycles}");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn recv_without_sender_deadlocks() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 1 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let c = vec![TraceOp::Recv { ch: 0 }];
        m.run(vec![Vec::new(), c]);
    }

    #[test]
    fn batched_and_per_line_streams_agree() {
        // Mixed stream workload: cold DRAM-bound reads, L1-resident
        // re-reads, writes (dirty victims), and a non-prefetchable load.
        let trace = {
            let mut b = TraceBuilder::new();
            b.compute(InstClass::IntAlu, 1000);
            b.stream_read(0x10_0000, 256 * 1024, 2);
            b.stream_read(0x10_0000, 8 * 1024, 4); // second pass: L1 hits
            b.stream_write(0x80_0000, 64 * 1024, 2);
            b.push(TraceOp::MemStream {
                base: 0x90_0040, // deliberately line-offset base
                bytes: 24 * 64,
                write: false,
                insts_per_line: 3,
                prefetchable: false,
            });
            b.stream_write(0x80_0000, 4 * 1024, 1); // dirty re-hits
            b.build()
        };
        let run = |batched: bool| {
            let mut m = hp_machine(MachineSpec::default());
            m.set_batched_streams(batched);
            m.run(vec![trace.clone()])
        };
        let fast = run(true);
        let reference = run(false);
        assert_eq!(fast.roi_time_ps, reference.roi_time_ps);
        assert_eq!(fast.cores[0], reference.cores[0]);
        assert_eq!(fast.l1d, reference.l1d);
        assert_eq!(fast.llc, reference.llc);
        assert_eq!(fast.dram_accesses, reference.dram_accesses);
        assert_eq!(fast.llc_bytes_read, reference.llc_bytes_read);
        assert_eq!(fast.llc_bytes_written, reference.llc_bytes_written);
    }

    #[test]
    fn roi_attribution_covers_time() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.roi(RoiKind::DigitalMvm, |b| {
            b.compute(InstClass::SimdOp, 10_000);
        });
        b.roi(RoiKind::Activation, |b| {
            b.compute(InstClass::FpOp, 1_000);
        });
        let rs = m.run(vec![b.build()]);
        assert!(rs.roi.fraction(RoiKind::DigitalMvm) > 0.7);
        assert!(rs.roi.fraction(RoiKind::Activation) > 0.1);
        let sum = rs.roi.total();
        assert_eq!(sum, rs.roi_time_ps);
    }
}
