//! The trace machine: executes per-core [`Trace`] programs against the
//! timing models (cores, memory hierarchy, AIMC tiles, sync primitives)
//! and produces `RunStats`.
//!
//! Scheduling is conservative global-time ordering: the machine always
//! steps the earliest-time runnable core, so shared resources (bus, DRAM,
//! tiles, mutexes, channels) observe accesses in near-nondecreasing time
//! order. A core blocked on a channel or mutex is advanced to just after
//! the earliest other runnable core and retried — the standard
//! lockstep-free conservative scheme.
//!
//! ## Steady-state fast-forward
//!
//! Traces store their per-inference block inside a `Rep` loop (possibly
//! nested under `Loop` segments — a CNN row-loop inside the
//! per-inference loop), and after warm-up the machine's whole state
//! evolves periodically: every iteration adds the same stat deltas and
//! advances every clock by the same Δt. The machine detects this with a
//! cheap periodicity digest taken once per *round* (each time the
//! globally slowest core finishes another innermost-`Rep` iteration):
//! per-core cursor/stack/lead/time offsets and stat deltas, ROI deltas,
//! per-core cumulative stall/idle picoseconds, channel/mutex/tile/
//! DRAM/bus timing offsets relative to the round's reference time, plus
//! cache occupancy. Loop-level iteration counters live in a separate
//! per-round *progress* vector: the digest matches when the positional
//! state repeats and every counter's per-round delta repeats, which
//! gives each loop level of each core a constant per-round *velocity*
//! (0 for an outer loop that only wraps occasionally, 1 for the
//! innermost `Rep`, k for a core running k iterations per round). The
//! remaining periods are then applied in closed form — counters
//! extrapolate linearly, stall/idle cycles via their exact
//! cumulative-ps floor conversion, clocks shift by p·Δt, every loop
//! level advances by p·velocity — capped so each level keeps at least
//! one live iteration. An inner `Rep` therefore closed-form-jumps even
//! when the enclosing loop never reaches a whole-trace steady state;
//! the whole-trace digest of flat `Rep` programs is the degenerate
//! single-scope case. The result is bit-identical to full replay —
//! enforced by unit tests, the `machine-fastforward-equivalence`
//! proptest, the per-paper-case suite in `tests/fastforward.rs`, and
//! the CI determinism gate; `set_fast_forward(false)` keeps the full
//! replay path, and `set_nested_fast_forward(false)` restricts jumps to
//! top-level `Rep` segments (the pre-nesting behaviour), exactly like
//! `set_batched_streams`.
//!
//! The digest is a *detector*, not a proof: cache tag/LRU content is
//! checked only through stat deltas and the occupancy fingerprint
//! (deliberately rotation-invariant, because steady streams over fresh
//! per-inference addresses march their footprint through the sets). A
//! trace whose per-round stat deltas and occupancy repeat while some
//! set-positional cache interaction still evolves could in principle be
//! jumped unsoundly; no compiler-emitted workload has that shape (fresh
//! regions are never revisited, resident regions are set-stationary),
//! and the equivalence gates above are the contract that keeps it that
//! way.

use crate::config::SystemConfig;

use crate::sim::aimc::{AimcError, AimcTile, Coupling, TileFaultModel};
use crate::sim::bus::IoBus;
use crate::sim::hierarchy::MemorySystem;
use crate::sim::sync::{SimChannel, SimMutex};
use crate::stats::{CoreStats, RoiKind, RoiTimes, RunStats};
use crate::workload::costs;
use crate::workload::trace::{apply_stride, Segment, Trace, TraceOp};

/// Static description of the simulated platform's accelerator + sync
/// fabric (which tile belongs to which core, channel topology).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MachineSpec {
    pub tiles: Vec<TileSpec>,
    pub mutexes: usize,
    pub channels: Vec<ChannelSpec>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileSpec {
    pub rows: u32,
    pub cols: u32,
    pub coupling: Coupling,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChannelSpec {
    pub producer: usize,
    pub consumer: usize,
    pub capacity: usize,
}

/// Structured run failure. Replaces the machine's former `panic!`s so
/// callers (sweeps, the auto-mapper, the server, the CLI) can degrade —
/// remap around a failed tile, drop a case, report an error row —
/// instead of aborting the whole process.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RunError {
    /// No core can make progress. One diagnostic line per blocked core
    /// (`core j @ t=...ps depth d seg s/n op k iter i: <op>`).
    Deadlock { blocked_cores: Vec<String> },
    /// A tile's hard-failure time was reached; the op can never complete.
    TileFailed { tile: usize, at_ps: u64 },
    /// Retry-with-exponential-backoff exhausted its attempts against a
    /// tile that stayed transiently stalled.
    Timeout { core: usize, tile: usize, attempts: u32, at_ps: u64 },
    /// A device/sync op failed in a way the trace cannot recover from
    /// (placement out of bounds, queue overflow, poisoned channel).
    Device { core: usize, op: &'static str, reason: String },
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Deadlock { blocked_cores } => write!(
                f,
                "deadlock: {} core(s) blocked with no runnable peers:\n  {}",
                blocked_cores.len(),
                blocked_cores.join("\n  ")
            ),
            RunError::TileFailed { tile, at_ps } => {
                write!(f, "tile {tile} hard-failed at t={at_ps}ps")
            }
            RunError::Timeout { core, tile, attempts, at_ps } => write!(
                f,
                "core {core}: tile {tile} op timed out after {attempts} backoff retries (t={at_ps}ps)"
            ),
            RunError::Device { core, op, reason } => {
                write!(f, "core {core}: {op} failed: {reason}")
            }
        }
    }
}

impl std::error::Error for RunError {}

/// First backoff wait after a transient tile stall (doubles per retry).
pub const BACKOFF_BASE_PS: u64 = 1_000;
/// Give up (-> `RunError::Timeout`) after this many backoff retries.
pub const BACKOFF_MAX_RETRIES: u32 = 8;

/// One level of loop nesting: the cursor is inside the body of the
/// `Loop` at index `seg` of the enclosing segment list.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Frame {
    /// Index of the `Loop` segment in its enclosing segment list.
    seg: usize,
    /// Current iteration of that `Loop`.
    iter: u32,
    /// Stored-op offset of the current child segment within the `Loop`
    /// body (sum of `stored_ops` of the body segments before it), so
    /// per-op stride lookups stay O(depth) without rescanning the body.
    base: usize,
}

/// Execution position inside a [`Trace`] program: the enclosing `Loop`
/// frames (outermost first) plus the position inside the innermost
/// segment list.
#[derive(Clone, Debug, Default)]
struct Cursor {
    /// Enclosing `Loop` levels, outermost first (empty = top level).
    stack: Vec<Frame>,
    /// Index into the innermost segment list.
    seg: usize,
    /// Op index inside the current segment (`Ops` run or `Rep` body).
    op: usize,
    /// Current iteration of the current `Rep` segment.
    iter: u32,
}

/// The innermost segment list the cursor currently executes.
fn cur_segments<'t>(trace: &'t Trace, c: &Cursor) -> &'t [Segment] {
    let mut segs: &[Segment] = &trace.segments;
    for f in &c.stack {
        let Segment::Loop { body, .. } = &segs[f.seg] else {
            unreachable!("cursor frame does not sit on a Loop segment");
        };
        segs = body;
    }
    segs
}

/// The op the cursor points at (cursor must be normalized and not
/// done). Address shifts compose additively across loop levels: each
/// enclosing `Loop` contributes `strides[j] * iter` for the stored-op
/// index `j` of the op within that level's body (the suffix sum of the
/// frame bases below it plus the in-segment op index).
fn cur_op(trace: &Trace, c: &Cursor) -> TraceOp {
    let mut idx: usize = c.op + c.stack.iter().map(|f| f.base).sum::<usize>();
    let mut shift: i64 = 0;
    let mut segs: &[Segment] = &trace.segments;
    for f in &c.stack {
        let Segment::Loop { body, strides, .. } = &segs[f.seg] else {
            unreachable!("cursor frame does not sit on a Loop segment");
        };
        shift = shift
            .wrapping_add(strides.get(idx).copied().unwrap_or(0).wrapping_mul(i64::from(f.iter)));
        idx -= f.base;
        segs = body;
    }
    let op = match &segs[c.seg] {
        Segment::Ops(v) => v[c.op],
        Segment::Rep { body, strides, .. } => {
            apply_stride(body[c.op], strides.get(c.op).copied().unwrap_or(0), c.iter)
        }
        Segment::Loop { .. } => unreachable!("normalized cursor never rests on a Loop"),
    };
    apply_stride(op, shift, 1)
}

fn done(trace: &Trace, c: &Cursor) -> bool {
    c.stack.is_empty() && c.seg >= trace.segments.len()
}

/// Step the cursor past the current segment (holding `stored` stored
/// ops), crediting them to the enclosing frame's stride base.
fn advance_past(c: &mut Cursor, stored: usize) {
    if let Some(f) = c.stack.last_mut() {
        f.base += stored;
    }
    c.seg += 1;
    c.op = 0;
    c.iter = 0;
}

/// Advance the cursor past exhausted runs/iterations/loop levels until
/// it points at a concrete op (or the end). Returns how many innermost
/// `Rep` iterations were completed by this normalization (0 or 1 for
/// well-formed programs).
fn normalize(trace: &Trace, c: &mut Cursor) -> u32 {
    let mut completed = 0;
    loop {
        // Re-resolve the innermost list each step: the borrow is tied to
        // `trace` only, and nesting depth is tiny.
        let segs = cur_segments(trace, c);
        if c.seg >= segs.len() {
            let Some(mut f) = c.stack.pop() else {
                return completed; // end of the whole trace
            };
            let parent = cur_segments(trace, c);
            let Segment::Loop { count, .. } = &parent[f.seg] else {
                unreachable!("cursor frame does not sit on a Loop segment");
            };
            f.iter += 1;
            if f.iter < *count {
                f.base = 0;
                c.stack.push(f);
                c.seg = 0;
                c.op = 0;
                c.iter = 0;
            } else {
                c.seg = f.seg;
                let stored = parent[c.seg].stored_ops();
                advance_past(c, stored);
            }
            continue;
        }
        match &segs[c.seg] {
            Segment::Ops(v) => {
                if c.op < v.len() {
                    return completed;
                }
                advance_past(c, v.len());
            }
            Segment::Rep { body, count, .. } => {
                if body.is_empty() || c.iter >= *count {
                    advance_past(c, body.len());
                } else if c.op < body.len() {
                    return completed;
                } else {
                    completed += 1;
                    c.iter += 1;
                    c.op = 0;
                    if c.iter >= *count {
                        advance_past(c, body.len());
                    }
                }
            }
            seg @ Segment::Loop { body, count, .. } => {
                if *count == 0 || body.iter().all(|s| s.flat_len() == Some(0)) {
                    advance_past(c, seg.stored_ops());
                } else {
                    c.stack.push(Frame { seg: c.seg, iter: 0, base: 0 });
                    c.seg = 0;
                    c.op = 0;
                    c.iter = 0;
                }
            }
        }
    }
}

struct CoreRun {
    now_ps: u64,
    cursor: Cursor,
    roi_stack: Vec<RoiKind>,
    stats: CoreStats,
    /// This core was parked at the current op (retry after a block): sync
    /// ops must not complete earlier than the event that unparked them.
    retrying: bool,
    /// Sub-cycle remainders so ps->cycle conversion conserves time.
    wfm_residual_ps: u64,
    idle_residual_ps: u64,
    /// Cumulative `Rep` iterations completed (fast-forward rounds).
    completed_iters: u64,
}

/// Give up on fast-forward after this many rounds whose stat deltas
/// repeat but whose cache occupancy is still evolving (a large LLC
/// slowly filling with per-inference data can stay transient for the
/// whole run; scanning it every round would cost more than it saves).
const FF_MAX_OCCUPANCY_MISSES: u32 = 24;

/// Steady-state detection state for one `run` (see the module docs).
struct FfTracker {
    enabled: bool,
    /// Round index = min completed `Rep` iterations over running cores.
    last_round: u64,
    prev: Option<FfSnapshot>,
    prev_digest: Option<Vec<u64>>,
    prev_occupancy: Option<Vec<u64>>,
    occupancy_misses: u32,
}

impl FfTracker {
    fn new(enabled: bool) -> FfTracker {
        FfTracker {
            enabled,
            last_round: 0,
            prev: None,
            prev_digest: None,
            prev_occupancy: None,
            occupancy_misses: 0,
        }
    }
}

/// Machine state captured at one round boundary.
struct FfSnapshot {
    round: u64,
    t_ref: u64,
    /// Positional/offset state: must repeat exactly between rounds.
    state: Vec<u64>,
    /// Per-core loop-level iteration counters (`completed_iters`, each
    /// stack frame's iteration, the innermost `Rep` iteration). Their
    /// per-round deltas are the levels' *velocities*: they must repeat
    /// between rounds, and the closed-form jump advances each level by
    /// `p * velocity`.
    progress: Vec<u64>,
    /// Monotonic counters: their per-round deltas must repeat.
    counters: Vec<u64>,
    /// Per-core cumulative stall/idle picoseconds (`cycles * cycle_ps +
    /// residual`). Extrapolated in closed form so the floor-to-cycles
    /// conversion stays bit-exact across a jump even when the
    /// per-iteration stall is not a whole number of cycles.
    cum_wfm_ps: Vec<u64>,
    cum_idle_ps: Vec<u64>,
}

pub struct Machine {
    cfg: SystemConfig,
    mem: MemorySystem,
    tiles: Vec<AimcTile>,
    iobus: IoBus,
    mutexes: Vec<SimMutex>,
    channels: Vec<SimChannel>,
    channel_specs: Vec<ChannelSpec>,
    roi: RoiTimes,
    cycle_ps: u64,
    /// Route `MemStream` through the bulk `MemorySystem::stream` fast
    /// path (default). The per-line reference loop is kept for the
    /// equivalence tests and the `micro_sim` baseline bench.
    batched_streams: bool,
    /// Fast-forward `Rep` steady state in closed form (default). The
    /// full replay path is kept for the equivalence tests and the
    /// `micro_sim` baseline bench.
    fast_forward: bool,
    /// Allow closed-form jumps of `Rep` segments nested under `Loop`
    /// levels (default). Off restricts jumps to top-level `Rep`
    /// segments — the pre-nesting eligibility rule.
    nested_fast_forward: bool,
    ff_jumps: u32,
    ff_skipped_iters: u64,
}

/// Process-wide default for [`Machine::set_nested_fast_forward`], so
/// sweep drivers (`--no-nested-ff`) reach every internally-constructed
/// machine without threading a flag through each call site — the same
/// idiom as `util::parallel::set_jobs`.
static NESTED_FF_DEFAULT: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(true);

/// Set the process-wide default for nested fast-forward (read once per
/// `Machine::new`; per-machine `set_nested_fast_forward` overrides).
pub fn set_nested_fast_forward_default(on: bool) {
    NESTED_FF_DEFAULT.store(on, std::sync::atomic::Ordering::Relaxed);
}

enum StepResult {
    Progressed,
    Blocked,
}

impl Machine {
    pub fn new(cfg: SystemConfig, spec: MachineSpec) -> Machine {
        let MachineSpec { tiles: tile_specs, mutexes, channels } = spec;
        let tiles = tile_specs
            .iter()
            .map(|t| AimcTile::new(&cfg.aimc, t.rows, t.cols, t.coupling))
            .collect();
        let iobus = IoBus::new(cfg.aimc.pio_transaction_s, cfg.aimc.pio_throughput_bps);
        Machine {
            mem: MemorySystem::new(&cfg),
            tiles,
            iobus,
            mutexes: (0..mutexes).map(|_| SimMutex::default()).collect(),
            channels: channels.iter().map(|c| SimChannel::new(c.capacity)).collect(),
            channel_specs: channels,
            roi: RoiTimes::default(),
            cycle_ps: cfg.cycle_ps(),
            batched_streams: true,
            fast_forward: true,
            nested_fast_forward: NESTED_FF_DEFAULT.load(std::sync::atomic::Ordering::Relaxed),
            ff_jumps: 0,
            ff_skipped_iters: 0,
            cfg,
        }
    }

    pub fn tiles(&self) -> &[AimcTile] {
        &self.tiles
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Select between the bulk memory-stream fast path (default) and the
    /// per-line reference loop. Both produce bit-identical statistics;
    /// the knob exists for equivalence tests and perf baselines.
    pub fn set_batched_streams(&mut self, on: bool) {
        self.batched_streams = on;
    }

    /// Select between steady-state fast-forward of `Rep` loops (default)
    /// and full op-by-op replay. Both produce bit-identical statistics;
    /// the knob exists for equivalence tests and perf baselines.
    pub fn set_fast_forward(&mut self, on: bool) {
        self.fast_forward = on;
    }

    /// Select between segment-scoped steady-state detection that also
    /// jumps `Rep` segments nested under `Loop` levels (default) and
    /// the top-level-only eligibility rule. Both produce bit-identical
    /// statistics; the knob exists for equivalence tests and perf
    /// baselines (`--no-nested-ff`).
    pub fn set_nested_fast_forward(&mut self, on: bool) {
        self.nested_fast_forward = on;
    }

    /// Closed-form jumps taken by the fast-forward engine so far.
    pub fn fast_forward_jumps(&self) -> u32 {
        self.ff_jumps
    }

    /// Total `Rep` iterations skipped in closed form so far.
    pub fn fast_forward_skipped_iters(&self) -> u64 {
        self.ff_skipped_iters
    }

    /// Attach (or clear, with `TileFaultModel::none()`) a fault model to
    /// one tile. Any active fault model disables steady-state
    /// fast-forward for subsequent runs: transient stall windows are
    /// phased against absolute time, which a closed-form clock shift
    /// would silently re-phase. The fault-free default path is untouched.
    pub fn set_tile_fault(&mut self, tile: usize, model: TileFaultModel) {
        self.tiles[tile].set_fault_model(model);
    }

    /// True if any tile has an active fault model.
    pub fn has_tile_faults(&self) -> bool {
        self.tiles.iter().any(|t| !t.fault_model().is_none())
    }

    /// Attach (or clear, with `TileDriftSpec::none()`) a conductance
    /// drift model to one tile. Unlike `set_tile_fault` this does NOT
    /// disable fast-forward: drift degrades only the accuracy proxy,
    /// never timing, and its age is keyed on absolute timestamps that
    /// closed-form jumps advance consistently (the jump moves `now`;
    /// the programming timestamp stays put). `tests/fastforward.rs`
    /// pins ff-vs-replay bit-identity with an active spec attached.
    pub fn set_tile_drift(&mut self, tile: usize, drift: crate::sim::aimc::TileDriftSpec) {
        self.tiles[tile].set_drift_spec(drift);
    }

    /// True if any tile has an active drift model.
    pub fn has_tile_drift(&self) -> bool {
        self.tiles.iter().any(|t| !t.drift_spec().is_none())
    }

    /// Probe one tile's drift-health sensor at virtual time `now_ps`.
    /// Pure read; never perturbs timing, counters, or the ff digest.
    pub fn tile_health(&self, tile: usize, now_ps: u64) -> crate::sim::aimc::TileHealth {
        self.tiles[tile].health(now_ps)
    }

    /// Reprogram one tile's crossbar at virtual time `now_ps` (restarts
    /// its drift clock; see `AimcTile::reprogram` for the cost model).
    pub fn reprogram_tile(&mut self, tile: usize, now_ps: u64) {
        self.tiles[tile].reprogram(now_ps);
    }

    /// Execute one trace per core (empty traces = unused cores). Accepts
    /// looped [`Trace`] programs or flat `Vec<TraceOp>` streams. Returns
    /// the full run statistics, or a typed [`RunError`] (deadlock, tile
    /// failure, retry timeout) instead of panicking.
    pub fn run<T: Into<Trace>>(&mut self, traces: Vec<T>) -> Result<RunStats, RunError> {
        let traces: Vec<Trace> = traces.into_iter().map(Into::into).collect();
        self.run_traces(traces)
    }

    fn run_traces(&mut self, traces: Vec<Trace>) -> Result<RunStats, RunError> {
        assert!(traces.len() <= self.cfg.num_cores, "more traces than cores");
        let n = traces.len();
        let mut cores: Vec<CoreRun> = (0..n)
            .map(|i| {
                let mut cursor = Cursor::default();
                normalize(&traces[i], &mut cursor);
                CoreRun {
                    now_ps: 0,
                    cursor,
                    roi_stack: Vec::new(),
                    stats: CoreStats::default(),
                    retrying: false,
                    wfm_residual_ps: 0,
                    idle_residual_ps: 0,
                    completed_iters: 0,
                }
            })
            .collect();

        // Blocked-flag scheduling: a core that cannot make progress (full
        // channel, empty channel, held mutex) is parked until *any* other
        // core progresses; the grant/ready timestamps of the sync
        // primitives supply the correct wait times on retry.
        let mut blocked = vec![false; n];
        let mut ff = FfTracker::new(self.fast_forward && !self.has_tile_faults());
        loop {
            let mut next: Option<usize> = None;
            for i in 0..n {
                if !done(&traces[i], &cores[i].cursor) && !blocked[i] {
                    match next {
                        Some(j) if cores[j].now_ps <= cores[i].now_ps => {}
                        _ => next = Some(i),
                    }
                }
            }
            let Some(i) = next else {
                // Report *every* blocked core with its pending op — a
                // multi-core deadlock is rarely diagnosable from the
                // first victim alone.
                let stuck: Vec<String> = (0..n)
                    .filter(|&j| !done(&traces[j], &cores[j].cursor))
                    .map(|j| {
                        let c = &cores[j].cursor;
                        format!(
                            "core {j} @ t={}ps depth {} seg {}/{} op {} iter {}: {:?}",
                            cores[j].now_ps,
                            c.stack.len(),
                            c.seg,
                            cur_segments(&traces[j], c).len(),
                            c.op,
                            c.iter,
                            cur_op(&traces[j], c)
                        )
                    })
                    .collect();
                if !stuck.is_empty() {
                    return Err(RunError::Deadlock { blocked_cores: stuck });
                }
                break;
            };

            match self.step(i, &mut cores, &traces)? {
                Some(completed) => {
                    blocked.iter_mut().for_each(|b| *b = false);
                    cores[i].retrying = false;
                    if completed > 0 {
                        cores[i].completed_iters += completed as u64;
                        if ff.enabled {
                            self.maybe_fast_forward(&traces, &mut cores, &mut ff);
                        }
                    }
                }
                None => {
                    blocked[i] = true;
                    cores[i].retrying = true;
                }
            }
        }

        // Pad finished cores to the global end-of-ROI (idle).
        let end = cores.iter().map(|c| c.now_ps).max().unwrap_or(0);
        for c in &mut cores {
            c.stats.idle_cycles += (end - c.now_ps) / self.cycle_ps;
            c.now_ps = end;
        }

        let mut rs = RunStats::new(n);
        rs.roi_time_ps = end;
        for (i, c) in cores.into_iter().enumerate() {
            rs.cores[i] = c.stats;
        }
        rs.l1d = self.mem.l1_stats_merged();
        rs.llc = self.mem.llc_stats().clone();
        rs.dram_accesses = self.mem.dram_accesses();
        rs.llc_bytes_read = self.mem.llc_bytes_read;
        rs.llc_bytes_written = self.mem.llc_bytes_written;
        for t in &self.tiles {
            rs.aimc.processes += t.stats.processes;
            rs.aimc.queued_bytes += t.stats.queued_bytes;
            rs.aimc.dequeued_bytes += t.stats.dequeued_bytes;
            rs.aimc.programmed_weights += t.stats.programmed_weights;
            // Energy and weighted op counts are derived from the integer
            // activity counters so a fast-forwarded run reproduces full
            // replay bit for bit (per-event f64 accumulation would not
            // extrapolate exactly).
            rs.aimc.process_ops_weighted += t.process_ops_weighted();
            rs.aimc.energy_j += t.energy_j();
        }
        rs.roi = self.roi.clone();
        Ok(rs)
    }

    /// Execute one op on core `i`. `Some(k)` on progress (k = `Rep`
    /// iterations completed by the cursor advance), `None` when blocked.
    fn step(
        &mut self,
        i: usize,
        cores: &mut [CoreRun],
        traces: &[Trace],
    ) -> Result<Option<u32>, RunError> {
        let op = cur_op(&traces[i], &cores[i].cursor);
        let t0 = cores[i].now_ps;
        match self.exec(i, &mut cores[i], op)? {
            StepResult::Blocked => Ok(None),
            StepResult::Progressed => {
                let kind = cores[i].roi_stack.last().copied().unwrap_or(RoiKind::Misc);
                self.roi.add(kind, cores[i].now_ps - t0);
                cores[i].cursor.op += 1;
                Ok(Some(normalize(&traces[i], &mut cores[i].cursor)))
            }
        }
    }

    // -----------------------------------------------------------------
    // Steady-state fast-forward
    // -----------------------------------------------------------------

    /// Visit every monotonic machine counter in a fixed order (snapshot
    /// and extrapolation must agree). Stall/idle cycles are *not* here:
    /// their residual-carry floor conversion is extrapolated separately
    /// in closed form.
    fn for_each_counter(&mut self, cores: &mut [CoreRun], f: &mut dyn FnMut(&mut u64)) {
        for c in cores.iter_mut() {
            f(&mut c.stats.insts);
            f(&mut c.stats.active_cycles);
        }
        self.roi.for_each_counter(f);
        self.mem.for_each_counter(f);
        for t in &mut self.tiles {
            f(&mut t.stats.processes);
            f(&mut t.stats.queued_bytes);
            f(&mut t.stats.dequeued_bytes);
            f(&mut t.stats.programmed_weights);
        }
        for m in &mut self.mutexes {
            f(&mut m.acquisitions);
            f(&mut m.contended);
        }
        for ch in &mut self.channels {
            f(&mut ch.sends);
            f(&mut ch.recvs);
        }
        f(&mut self.iobus.transactions);
    }

    fn ff_snapshot(&mut self, traces: &[Trace], cores: &mut [CoreRun], t_ref: u64, round: u64) -> FfSnapshot {
        let cycle = self.cycle_ps;
        let mut state = Vec::with_capacity(16 * cores.len() + 32);
        let mut progress = Vec::with_capacity(3 * cores.len());
        for (i, c) in cores.iter().enumerate() {
            state.push(done(&traces[i], &c.cursor) as u64);
            state.push(c.cursor.stack.len() as u64);
            for f in &c.cursor.stack {
                state.push(f.seg as u64);
                state.push(f.base as u64);
            }
            state.push(c.cursor.seg as u64);
            state.push(c.cursor.op as u64);
            state.push(c.now_ps.saturating_sub(t_ref));
            state.push(c.retrying as u64);
            state.push(c.roi_stack.len() as u64);
            state.extend(c.roi_stack.iter().map(|k| *k as u64));
            // Loop-level iteration counters, outermost first. The state
            // above pins the stack *shape*, so matching rounds always
            // produce identically-shaped progress vectors.
            progress.push(c.completed_iters);
            progress.extend(c.cursor.stack.iter().map(|f| u64::from(f.iter)));
            progress.push(u64::from(c.cursor.iter));
        }
        self.mem.ff_state(t_ref, &mut state);
        for t in &self.tiles {
            t.ff_state(t_ref, &mut state);
        }
        for m in &self.mutexes {
            state.push(m.is_locked() as u64);
            state.push(m.last_release_ps().saturating_sub(t_ref));
        }
        for ch in &self.channels {
            state.push(ch.len() as u64);
            for msg in ch.msgs() {
                state.push(msg.ready_ps.saturating_sub(t_ref));
                state.push(msg.bytes);
                state.push(msg.addr);
            }
            state.push(ch.last_recv_ps.saturating_sub(t_ref));
        }
        state.push(self.iobus.busy_until_ps().saturating_sub(t_ref));

        let mut counters = Vec::with_capacity(64);
        self.for_each_counter(cores, &mut |c| counters.push(*c));

        let cum_wfm_ps = cores.iter().map(|c| c.stats.wfm_cycles * cycle + c.wfm_residual_ps).collect();
        let cum_idle_ps = cores.iter().map(|c| c.stats.idle_cycles * cycle + c.idle_residual_ps).collect();
        FfSnapshot { round, t_ref, state, progress, counters, cum_wfm_ps, cum_idle_ps }
    }

    /// Delta-form digest of one round: the positional state verbatim plus
    /// the per-round deltas of every counter and cumulative ps quantity.
    /// Progress deltas are wrapping: an iteration counter that *wrapped*
    /// (a whole inner `Rep` restarting each round) still digests to a
    /// stable value, and the jump-budget check separately rejects
    /// non-monotone levels before extrapolating.
    fn ff_digest(cur: &FfSnapshot, prev: &FfSnapshot) -> Vec<u64> {
        let mut d = cur.state.clone();
        debug_assert_eq!(cur.counters.len(), prev.counters.len());
        debug_assert_eq!(cur.progress.len(), prev.progress.len());
        d.extend(cur.progress.iter().zip(&prev.progress).map(|(a, b)| a.wrapping_sub(*b)));
        d.extend(cur.counters.iter().zip(&prev.counters).map(|(a, b)| a - b));
        d.extend(cur.cum_wfm_ps.iter().zip(&prev.cum_wfm_ps).map(|(a, b)| a - b));
        d.extend(cur.cum_idle_ps.iter().zip(&prev.cum_idle_ps).map(|(a, b)| a - b));
        d
    }

    /// Largest whole-period jump the current velocities allow: every
    /// loop level of every running core must keep at least one live
    /// iteration (`iter + p*v <= count - 1`), and every level must be
    /// non-decreasing over the last round (a wrapped level cannot be
    /// extrapolated). `None` if any level wrapped or nothing is capped.
    fn ff_jump_budget(
        traces: &[Trace],
        cores: &[CoreRun],
        snap: &FfSnapshot,
        prev: &FfSnapshot,
    ) -> Option<u64> {
        let mut p = u64::MAX;
        let mut pi = 0usize;
        for (i, c) in cores.iter().enumerate() {
            let entries = 2 + c.cursor.stack.len();
            if done(&traces[i], &c.cursor) {
                pi += entries;
                continue;
            }
            // completed_iters: monotonic by construction, never capped.
            pi += 1;
            let mut cap = |count: u32, iter: u32, pi: usize| -> Option<()> {
                let v = snap.progress[pi].checked_sub(prev.progress[pi])?;
                if v > 0 {
                    let rem = u64::from(count - 1).saturating_sub(u64::from(iter));
                    p = p.min(rem / v);
                }
                Some(())
            };
            let mut segs: &[Segment] = &traces[i].segments;
            for f in &c.cursor.stack {
                let Segment::Loop { body, count, .. } = &segs[f.seg] else {
                    unreachable!("cursor frame does not sit on a Loop segment");
                };
                cap(*count, f.iter, pi)?;
                pi += 1;
                segs = body;
            }
            match segs.get(c.cursor.seg) {
                Some(Segment::Rep { count, .. }) => cap(*count, c.cursor.iter, pi)?,
                // Inside a Loop but between inner Reps: the innermost
                // iteration counter is pinned at 0 by the matched state.
                _ => {
                    if snap.progress[pi] != prev.progress[pi] {
                        return None;
                    }
                }
            }
            pi += 1;
        }
        (p >= 1 && p != u64::MAX).then_some(p)
    }

    /// Round bookkeeping + periodicity detection; called whenever a core
    /// completes an innermost `Rep` iteration.
    fn maybe_fast_forward(&mut self, traces: &[Trace], cores: &mut [CoreRun], ff: &mut FfTracker) {
        let mut cur_min = u64::MAX;
        let mut t_ref = u64::MAX;
        let mut eligible = true;
        let mut running = 0usize;
        for (i, c) in cores.iter().enumerate() {
            if done(&traces[i], &c.cursor) {
                continue;
            }
            running += 1;
            cur_min = cur_min.min(c.completed_iters);
            t_ref = t_ref.min(c.now_ps);
            let in_rep = matches!(
                cur_segments(&traces[i], &c.cursor).get(c.cursor.seg),
                Some(Segment::Rep { .. })
            );
            // Nested mode: any periodic scope qualifies — an innermost
            // `Rep`, or any position inside an enclosing `Loop` (its
            // level velocity carries the jump). Top-level-only mode is
            // the pre-nesting rule: a `Rep` with no enclosing frames.
            eligible &= if self.nested_fast_forward {
                in_rep || !c.cursor.stack.is_empty()
            } else {
                in_rep && c.cursor.stack.is_empty()
            };
        }
        if running == 0 || cur_min <= ff.last_round {
            return;
        }
        ff.last_round = cur_min;
        if !eligible {
            ff.prev = None;
            ff.prev_digest = None;
            ff.prev_occupancy = None;
            return;
        }

        let snap = self.ff_snapshot(traces, cores, t_ref, cur_min);
        let digest = match &ff.prev {
            Some(p) if p.round + 1 == cur_min && p.progress.len() == snap.progress.len() => {
                Some(Self::ff_digest(&snap, p))
            }
            _ => None,
        };
        let cheap_match =
            matches!((&digest, &ff.prev_digest), (Some(d), Some(pd)) if d == pd);
        if cheap_match {
            // The cheap digest is a necessary condition; the cache
            // occupancy scan (O(lines)) runs only on candidate rounds.
            let occ = self.mem.occupancy_vec();
            if ff.prev_occupancy.as_ref() == Some(&occ) {
                // Skip every whole period the level velocities allow
                // while leaving each loop level at least one live
                // iteration to run into its wrap/epilogue.
                let budget = {
                    let prev = ff.prev.as_ref().expect("cheap_match implies a previous snapshot");
                    Self::ff_jump_budget(traces, cores, &snap, prev)
                };
                if let Some(p) = budget {
                    let prev = ff.prev.take().expect("cheap_match implies a previous snapshot");
                    let dt = snap.t_ref - prev.t_ref;
                    self.apply_fast_forward(traces, cores, &prev, p, dt);
                    ff.last_round = cur_min + p;
                    ff.prev_digest = None;
                    ff.prev_occupancy = None;
                    return;
                }
                ff.prev_occupancy = Some(occ);
            } else {
                if ff.prev_occupancy.is_some() {
                    ff.occupancy_misses += 1;
                    if ff.occupancy_misses > FF_MAX_OCCUPANCY_MISSES {
                        ff.enabled = false;
                        return;
                    }
                }
                ff.prev_occupancy = Some(occ);
            }
        } else {
            ff.prev_occupancy = None;
        }
        ff.prev_digest = digest;
        ff.prev = Some(snap);
    }

    /// Apply `p` whole periods in closed form: counters gain `p` more
    /// per-round deltas, every clock shifts by `p * dt`, and each running
    /// core's loop levels advance `p` velocities' worth of iterations.
    /// Cache/tile *content* is untouched: in steady state it is
    /// equivalent up to the renaming of per-inference addresses that are
    /// never revisited.
    fn apply_fast_forward(
        &mut self,
        traces: &[Trace],
        cores: &mut [CoreRun],
        prev: &FfSnapshot,
        p: u64,
        dt: u64,
    ) {
        let shift = p * dt;
        let cycle = self.cycle_ps;
        let mut idx = 0usize;
        self.for_each_counter(cores, &mut |c| {
            *c += p * (*c - prev.counters[idx]);
            idx += 1;
        });
        let mut pi = 0usize;
        for (i, c) in cores.iter_mut().enumerate() {
            let entries = 2 + c.cursor.stack.len();
            if done(&traces[i], &c.cursor) {
                pi += entries;
                continue;
            }
            c.now_ps += shift;
            // Advance every loop level by p * its per-round velocity
            // (the jump budget already verified monotonicity and caps).
            let v = c.completed_iters - prev.progress[pi];
            c.completed_iters += p * v;
            pi += 1;
            for f in &mut c.cursor.stack {
                let v = u64::from(f.iter) - prev.progress[pi];
                f.iter += (p * v) as u32;
                pi += 1;
            }
            let v = u64::from(c.cursor.iter) - prev.progress[pi];
            c.cursor.iter += (p * v) as u32;
            pi += 1;
            let cum_w = c.stats.wfm_cycles * cycle + c.wfm_residual_ps;
            let new_w = cum_w + p * (cum_w - prev.cum_wfm_ps[i]);
            c.stats.wfm_cycles = new_w / cycle;
            c.wfm_residual_ps = new_w % cycle;
            let cum_i = c.stats.idle_cycles * cycle + c.idle_residual_ps;
            let new_i = cum_i + p * (cum_i - prev.cum_idle_ps[i]);
            c.stats.idle_cycles = new_i / cycle;
            c.idle_residual_ps = new_i % cycle;
        }
        self.mem.shift_time(shift);
        for t in &mut self.tiles {
            t.shift_time(shift);
        }
        for m in &mut self.mutexes {
            m.shift_time(shift);
        }
        for ch in &mut self.channels {
            ch.shift_time(shift);
        }
        self.iobus.shift_time(shift);
        self.ff_jumps += 1;
        self.ff_skipped_iters += p;
    }

    // -----------------------------------------------------------------
    // Op execution
    // -----------------------------------------------------------------

    #[inline]
    fn active(&self, core: &mut CoreRun, cycles: u64, insts: u64) {
        core.stats.active_cycles += cycles;
        core.stats.insts += insts;
        core.now_ps += cycles * self.cycle_ps;
    }

    #[inline]
    fn wfm(&self, core: &mut CoreRun, ps: u64) {
        let total = ps + core.wfm_residual_ps;
        core.stats.wfm_cycles += total / self.cycle_ps;
        core.wfm_residual_ps = total % self.cycle_ps;
        core.now_ps += ps;
    }

    #[inline]
    fn idle(&self, core: &mut CoreRun, ps: u64) {
        let total = ps + core.idle_residual_ps;
        core.stats.idle_cycles += total / self.cycle_ps;
        core.idle_residual_ps = total % self.cycle_ps;
        core.now_ps += ps;
    }

    /// Issue a fallible tile I/O op with retry-with-exponential-backoff:
    /// a transiently-stalled tile is retried at `retry_at + base << k`
    /// (the wait lands in the caller's WFM stall via the returned
    /// completion time); a hard failure or exhausted retry budget
    /// surfaces as a typed error.
    fn tile_io_with_retry(
        &mut self,
        core_id: usize,
        tile: usize,
        mut start: u64,
        op: &'static str,
        f: impl Fn(&mut AimcTile, u64) -> Result<u64, AimcError>,
    ) -> Result<u64, RunError> {
        let mut attempt = 0u32;
        loop {
            match f(&mut self.tiles[tile], start) {
                Ok(done) => return Ok(done),
                Err(AimcError::TileFailed { at_ps }) => {
                    return Err(RunError::TileFailed { tile, at_ps })
                }
                Err(AimcError::TransientStall { retry_at_ps }) => {
                    if attempt >= BACKOFF_MAX_RETRIES {
                        return Err(RunError::Timeout {
                            core: core_id,
                            tile,
                            attempts: attempt,
                            at_ps: start,
                        });
                    }
                    start = retry_at_ps.max(start) + (BACKOFF_BASE_PS << attempt);
                    attempt += 1;
                }
                Err(e) => {
                    return Err(RunError::Device { core: core_id, op, reason: e.to_string() })
                }
            }
        }
    }

    fn exec(&mut self, i: usize, core: &mut CoreRun, op: TraceOp) -> Result<StepResult, RunError> {
        match op {
            TraceOp::Compute { class, insts } => {
                self.active(core, insts * class.cycles(), insts);
            }

            TraceOp::MemStream { base, bytes, write, insts_per_line, prefetchable } => {
                let line = self.mem.line_bytes();
                let lines = bytes.div_ceil(line);
                if self.batched_streams {
                    // Bulk fast path: one hierarchy walk for the whole
                    // stream. Issue/stall interleaving happens inside
                    // `MemorySystem::stream`; one aggregate active() +
                    // wfm() call is exactly the residual-carry sum of the
                    // per-line calls (the reference loop in the `else`
                    // arm), so stats are bit-identical. Both helpers also
                    // advance now_ps, which the stream already accounted
                    // for — end_ps overwrites it below.
                    let issue_ps = insts_per_line * self.cycle_ps;
                    let out = self.mem.stream(
                        i,
                        base,
                        lines,
                        write,
                        core.now_ps,
                        issue_ps,
                        prefetchable,
                    );
                    self.active(core, lines * insts_per_line, lines * insts_per_line);
                    self.wfm(core, out.stall_ps);
                    core.now_ps = out.end_ps;
                } else {
                    // Per-line reference loop (the pre-batching semantics;
                    // kept for equivalence tests and perf baselines).
                    let mut first_miss = true;
                    for k in 0..lines {
                        self.active(core, insts_per_line, insts_per_line);
                        let o = self.mem.access(i, base + k * line, write, core.now_ps);
                        if !o.l1_hit {
                            let stall = o.completion_ps.saturating_sub(core.now_ps);
                            // A stride prefetcher overlaps misses past the first
                            // in a sequential stream; random access pays full.
                            let eff = if prefetchable && !first_miss {
                                stall / costs::PREFETCH_DEPTH
                            } else {
                                stall
                            };
                            first_miss = false;
                            self.wfm(core, eff);
                        }
                    }
                }
            }

            TraceOp::CmInit { tile, placement } => {
                self.tiles[tile].map_matrix(placement).map_err(|e| RunError::Device {
                    core: i,
                    op: "CM_INITIALIZE",
                    reason: e.to_string(),
                })?;
                self.active(core, 1, 1);
            }

            TraceOp::CmQueue { tile, bytes } => {
                // The device transfer streams concurrently with the CPU's
                // CM_QUEUE beat issue: the device is engaged from the
                // first beat, the CPU stalls only for the residual.
                let start = core.now_ps;
                let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST);
                let overhead = beats * costs::CM_IO_OVERHEAD_PER_INST_X1000 / 1000;
                let done = match self.tiles[tile].coupling {
                    Coupling::Tight => self
                        .tile_io_with_retry(i, tile, start, "CM_QUEUE", |t, at| t.queue(at, bytes))?,
                    Coupling::Loose => {
                        let bus_done = self.iobus.transfer(start, bytes);
                        self.tile_io_with_retry(i, tile, bus_done, "CM_QUEUE", |t, at| {
                            t.queue(at, 0)
                        })?
                        .max(bus_done)
                    }
                };
                self.active(core, beats + overhead, beats + overhead);
                let stall = done.saturating_sub(core.now_ps);
                self.wfm(core, stall);
            }

            TraceOp::CmProcess { tile } => {
                // Tight coupling: CM_PROCESS fires the MVM and retires
                // (the result is awaited by the dependent CM_DEQUEUE, so
                // software can overlap the next queue with the MVM).
                // Loose coupling: the doorbell+poll round trip blocks.
                self.active(core, 1, 1);
                let done = self.tiles[tile].process(core.now_ps);
                if self.tiles[tile].coupling == Coupling::Loose {
                    self.wfm(core, done - core.now_ps);
                }
            }

            TraceOp::CmDequeue { tile, bytes } => {
                let start = core.now_ps;
                let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST);
                let overhead = beats * costs::CM_IO_OVERHEAD_PER_INST_X1000 / 1000;
                let done = match self.tiles[tile].coupling {
                    Coupling::Tight => self.tile_io_with_retry(i, tile, start, "CM_DEQUEUE", |t, at| {
                        t.dequeue(at, bytes)
                    })?,
                    Coupling::Loose => {
                        let bus_done = self.iobus.transfer(start, bytes);
                        self.tile_io_with_retry(i, tile, bus_done, "CM_DEQUEUE", |t, at| {
                            t.dequeue(at, 0)
                        })?
                        .max(bus_done)
                    }
                };
                self.active(core, beats + overhead, beats + overhead);
                let stall = done.saturating_sub(core.now_ps);
                self.wfm(core, stall);
            }

            TraceOp::MutexLock { id } => {
                let Some(granted) = self.mutexes[id].try_acquire(core.now_ps) else {
                    return Ok(StepResult::Blocked);
                };
                self.mutexes[id].lock();
                if granted > core.now_ps {
                    let wait = granted - core.now_ps;
                    self.idle(core, wait);
                }
                self.active(core, costs::MUTEX_INSTS, costs::MUTEX_INSTS);
            }

            TraceOp::MutexUnlock { id } => {
                self.active(core, costs::MUTEX_INSTS / 2, costs::MUTEX_INSTS / 2);
                self.mutexes[id].release(core.now_ps);
            }

            TraceOp::Send { ch, bytes, addr } => {
                if self.channels[ch].len() >= self.channels[ch].capacity {
                    return Ok(StepResult::Blocked);
                }
                // If this send was parked on a full buffer, it resumes no
                // earlier than the drain that freed the slot.
                if core.retrying && self.channels[ch].last_recv_ps > core.now_ps {
                    let wait = self.channels[ch].last_recv_ps - core.now_ps;
                    self.idle(core, wait);
                }
                self.active(core, costs::CHANNEL_INSTS, costs::CHANNEL_INSTS);
                // Producer writes the buffer through its cache.
                let line = self.mem.line_bytes();
                for k in 0..bytes.div_ceil(line) {
                    self.active(core, 1, 1);
                    let o = self.mem.access(i, addr + k * line, true, core.now_ps);
                    if !o.l1_hit {
                        let stall = o.completion_ps.saturating_sub(core.now_ps);
                        self.wfm(core, stall / costs::PREFETCH_DEPTH);
                    }
                }
                let ok = self.channels[ch].try_send(core.now_ps, bytes, addr);
                debug_assert!(ok);
            }

            TraceOp::Recv { ch } => {
                let msg = match self.channels[ch].head_ready_ps() {
                    None => return Ok(StepResult::Blocked),
                    Some(ready) => {
                        // If the message is already there, the condvar
                        // fast-path applies (no sleep). If the consumer
                        // must wait, it sleeps on the futex and pays the
                        // kernel wake-up latency on resume.
                        if ready > core.now_ps {
                            let wake_ps = costs::CHANNEL_WAKE_CYCLES * self.cycle_ps;
                            let wait = ready + wake_ps - core.now_ps;
                            self.idle(core, wait);
                        }
                        match self.channels[ch].try_recv(core.now_ps) {
                            Some(msg) => msg,
                            None => {
                                return Err(RunError::Device {
                                    core: i,
                                    op: "Recv",
                                    reason: format!(
                                        "channel {ch} advertised a ready message but delivered none"
                                    ),
                                })
                            }
                        }
                    }
                };
                self.active(core, costs::CHANNEL_INSTS, costs::CHANNEL_INSTS);
                let producer = self.channel_specs[ch].producer;
                let line = self.mem.line_bytes();
                for k in 0..msg.bytes.div_ceil(line) {
                    self.active(core, 1, 1);
                    let o = self.mem.shared_transfer(producer, i, msg.addr + k * line, core.now_ps);
                    let stall = o.completion_ps.saturating_sub(core.now_ps);
                    self.wfm(core, stall / 2);
                }
            }

            TraceOp::RoiPush { kind } => {
                core.roi_stack.push(kind);
            }
            TraceOp::RoiPop => {
                core.roi_stack.pop();
            }
        }
        Ok(StepResult::Progressed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::InstClass;
    use crate::sim::aimc::Placement;
    use crate::workload::trace::TraceBuilder;

    fn hp_machine(spec: MachineSpec) -> Machine {
        Machine::new(SystemConfig::high_power(), spec)
    }

    fn assert_stats_identical(a: &RunStats, b: &RunStats) {
        // Exhaustive destructuring comparison shared with the
        // integration gates (a new RunStats field cannot be silently
        // excluded).
        a.assert_bit_identical(b, "machine");
    }

    #[test]
    fn pure_compute_ipc_near_one() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 100_000);
        let rs = m.run(vec![b.build()]).unwrap();
        assert!((rs.cores[0].ipc() - 1.0).abs() < 0.01);
        assert_eq!(rs.total_insts(), 100_000);
    }

    #[test]
    fn mem_stream_generates_dram_traffic() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.stream_read(0x10_0000, 4 * 1024 * 1024, 4); // 4 MiB > 1 MiB LLC
        let rs = m.run(vec![b.build()]).unwrap();
        assert!(rs.dram_accesses > 60_000, "{}", rs.dram_accesses);
        assert!(rs.cores[0].wfm_cycles > 0);
    }

    #[test]
    fn small_stream_second_pass_hits_l1() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.stream_read(0, 8 * 1024, 4);
        b.stream_read(0, 8 * 1024, 4);
        let rs = m.run(vec![b.build()]).unwrap();
        // Second pass hits: misses only from first pass.
        assert_eq!(rs.l1d.read_misses, 8 * 1024 / 64);
    }

    #[test]
    fn cm_dequeue_waits_for_process_100ns() {
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 1024, cols: 1024, coupling: Coupling::Tight }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let ops = vec![
            TraceOp::CmInit {
                tile: 0,
                placement: Placement { row0: 0, col0: 0, rows: 1024, cols: 1024 },
            },
            TraceOp::CmProcess { tile: 0 },
            // The dependent dequeue observes the full 100 ns MVM latency
            // (CM_PROCESS itself retires immediately — double-buffered
            // DAC/ADC registers let software overlap the next queue).
            TraceOp::CmDequeue { tile: 0, bytes: 4 },
        ];
        let rs = m.run(vec![ops]).unwrap();
        assert!(rs.roi_time_ps >= 100_000, "{}", rs.roi_time_ps);
        assert_eq!(rs.aimc.processes, 1);
    }

    #[test]
    fn queue_throughput_4gbps() {
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 4096, cols: 64, coupling: Coupling::Tight }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let ops = vec![TraceOp::CmQueue { tile: 0, bytes: 4096 }];
        let rs = m.run(vec![ops]).unwrap();
        // 4096B at 4GB/s = 1024ns; issue of 1024+512 insts at 2.3GHz ~ 668ns,
        // so the transfer dominates and total ~ 1024ns.
        assert!(rs.roi_time_ps >= 1_024_000, "{}", rs.roi_time_ps);
        assert!(rs.roi_time_ps < 1_200_000, "{}", rs.roi_time_ps);
    }

    #[test]
    fn loose_coupling_slower_than_tight() {
        let mk = |coupling| MachineSpec {
            tiles: vec![TileSpec { rows: 1024, cols: 1024, coupling }],
            ..Default::default()
        };
        let run = |coupling| {
            let mut m = hp_machine(mk(coupling));
            let ops = vec![
                TraceOp::CmQueue { tile: 0, bytes: 1024 },
                TraceOp::CmProcess { tile: 0 },
                TraceOp::CmDequeue { tile: 0, bytes: 1024 },
            ];
            m.run(vec![ops]).unwrap().roi_time_ps
        };
        let tight = run(Coupling::Tight);
        let loose = run(Coupling::Loose);
        assert!(loose > 2 * tight, "tight {tight} loose {loose}");
    }

    #[test]
    fn channel_pipeline_transfers_data() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let mut p = TraceBuilder::new();
        p.compute(InstClass::IntAlu, 1000);
        p.push(TraceOp::Send { ch: 0, bytes: 1024, addr: 0x5000 });
        let mut c = TraceBuilder::new();
        c.push(TraceOp::Recv { ch: 0 });
        c.compute(InstClass::IntAlu, 1000);
        let rs = m.run(vec![p.build(), c.build()]).unwrap();
        // Consumer idled waiting for the producer.
        assert!(rs.cores[1].idle_cycles > 0);
        assert_eq!(rs.cores.len(), 2);
    }

    #[test]
    fn bounded_channel_blocks_producer() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 1 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let mut p = TraceBuilder::new();
        for k in 0..4 {
            p.push(TraceOp::Send { ch: 0, bytes: 64, addr: 0x5000 + k * 64 });
        }
        let mut c = TraceBuilder::new();
        c.compute(InstClass::IntAlu, 500_000); // slow consumer
        for _ in 0..4 {
            c.push(TraceOp::Recv { ch: 0 });
        }
        let rs = m.run(vec![p.build(), c.build()]).unwrap();
        assert!(rs.cores[0].idle_cycles > 100_000, "{}", rs.cores[0].idle_cycles);
    }

    #[test]
    fn mutex_serializes_cores() {
        let spec = MachineSpec { mutexes: 1, ..Default::default() };
        let mut m = hp_machine(spec);
        let critical = |_: usize| {
            let mut b = TraceBuilder::new();
            b.push(TraceOp::MutexLock { id: 0 });
            b.compute(InstClass::IntAlu, 100_000);
            b.push(TraceOp::MutexUnlock { id: 0 });
            b.build()
        };
        let rs = m.run(vec![critical(0), critical(1)]).unwrap();
        // Both critical sections serialized: ~200k cycles total.
        let total_cycles = rs.roi_time_ps / SystemConfig::high_power().cycle_ps();
        assert!(total_cycles > 195_000, "{total_cycles}");
    }

    #[test]
    fn recv_without_sender_deadlocks() {
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 1 }],
            ..Default::default()
        };
        let mut m = hp_machine(spec);
        let c = vec![TraceOp::Recv { ch: 0 }];
        let err = m.run(vec![Vec::new(), c]).unwrap_err();
        match err {
            RunError::Deadlock { blocked_cores } => {
                assert_eq!(blocked_cores.len(), 1, "{blocked_cores:?}");
                assert!(blocked_cores[0].starts_with("core 1 "), "{}", blocked_cores[0]);
                assert!(blocked_cores[0].contains("Recv"), "{}", blocked_cores[0]);
            }
            other => panic!("expected RunError::Deadlock, got {other:?}"),
        }
    }

    #[test]
    fn batched_and_per_line_streams_agree() {
        // Mixed stream workload: cold DRAM-bound reads, L1-resident
        // re-reads, writes (dirty victims), and a non-prefetchable load.
        let trace = {
            let mut b = TraceBuilder::new();
            b.compute(InstClass::IntAlu, 1000);
            b.stream_read(0x10_0000, 256 * 1024, 2);
            b.stream_read(0x10_0000, 8 * 1024, 4); // second pass: L1 hits
            b.stream_write(0x80_0000, 64 * 1024, 2);
            b.push(TraceOp::MemStream {
                base: 0x90_0040, // deliberately line-offset base
                bytes: 24 * 64,
                write: false,
                insts_per_line: 3,
                prefetchable: false,
            });
            b.stream_write(0x80_0000, 4 * 1024, 1); // dirty re-hits
            b.build()
        };
        let run = |batched: bool| {
            let mut m = hp_machine(MachineSpec::default());
            m.set_batched_streams(batched);
            m.run(vec![trace.clone()]).unwrap()
        };
        let fast = run(true);
        let reference = run(false);
        assert_eq!(fast.roi_time_ps, reference.roi_time_ps);
        assert_eq!(fast.cores[0], reference.cores[0]);
        assert_eq!(fast.l1d, reference.l1d);
        assert_eq!(fast.llc, reference.llc);
        assert_eq!(fast.dram_accesses, reference.dram_accesses);
        assert_eq!(fast.llc_bytes_read, reference.llc_bytes_read);
        assert_eq!(fast.llc_bytes_written, reference.llc_bytes_written);
    }

    #[test]
    fn roi_attribution_covers_time() {
        let mut m = hp_machine(MachineSpec::default());
        let mut b = TraceBuilder::new();
        b.roi(RoiKind::DigitalMvm, |b| {
            b.compute(InstClass::SimdOp, 10_000);
        });
        b.roi(RoiKind::Activation, |b| {
            b.compute(InstClass::FpOp, 1_000);
        });
        let rs = m.run(vec![b.build()]).unwrap();
        assert!(rs.roi.fraction(RoiKind::DigitalMvm) > 0.7);
        assert!(rs.roi.fraction(RoiKind::Activation) > 0.1);
        let sum = rs.roi.total();
        assert_eq!(sum, rs.roi_time_ps);
    }

    // -----------------------------------------------------------------
    // Looped-trace execution + steady-state fast-forward
    // -----------------------------------------------------------------

    /// One MLP-ish steady-state iteration: a big fixed-address weight
    /// stream (LLC-thrashing), a fresh per-iteration input stream, a
    /// fresh output write, and compute.
    fn steady_iteration(b: &mut TraceBuilder, k: u32) {
        b.roi(RoiKind::InputLoad, |b| {
            b.stream_read(0x8000_0000 + k as u64 * 0x1_0000, 48 * 1024, 2);
        });
        b.roi(RoiKind::DigitalMvm, |b| {
            b.stream_read(0x1000_0000, 2 * 1024 * 1024, 1);
            b.compute(InstClass::SimdOp, 40_000);
        });
        b.roi(RoiKind::Writeback, |b| {
            b.stream_write(0xA000_0000 + k as u64 * 0x1_0000, 4 * 1024, 2);
        });
    }

    #[test]
    fn looped_trace_executes_like_flat() {
        let mut lb = TraceBuilder::new();
        lb.compute(InstClass::IntAlu, 500);
        lb.repeat(12, steady_iteration);
        lb.compute(InstClass::FpOp, 100);
        let looped = lb.build_trace();

        let flat = looped.flatten();
        let mut m1 = hp_machine(MachineSpec::default());
        m1.set_fast_forward(false);
        let a = m1.run(vec![looped.clone()]).unwrap();
        let mut m2 = hp_machine(MachineSpec::default());
        m2.set_fast_forward(false);
        let b = m2.run(vec![flat]).unwrap();
        assert_stats_identical(&a, &b);
    }

    #[test]
    fn fast_forward_bit_identical_on_steady_loop() {
        let mut b = TraceBuilder::new();
        b.repeat(40, steady_iteration);
        let trace = b.build_trace();
        let run = |ff: bool| {
            let mut m = hp_machine(MachineSpec::default());
            m.set_fast_forward(ff);
            let rs = m.run(vec![trace.clone()]).unwrap();
            (rs, m.fast_forward_jumps(), m.fast_forward_skipped_iters())
        };
        let (fast, jumps, skipped) = run(true);
        let (reference, no_jumps, _) = run(false);
        assert_stats_identical(&fast, &reference);
        assert!(jumps >= 1, "fast-forward never engaged");
        assert!(skipped > 20, "skipped only {skipped} iterations");
        assert_eq!(no_jumps, 0, "knob off must fully replay");
    }

    #[test]
    fn fast_forward_bit_identical_with_channels_mutexes_tiles() {
        // A two-stage pipeline: core 0 queues/fires/drains a tile, takes
        // a mutex barrier and sends to core 1, which streams fresh
        // per-iteration data and receives. Exercises every interacting
        // machine resource under the digest.
        let spec = MachineSpec {
            tiles: vec![TileSpec { rows: 512, cols: 512, coupling: Coupling::Tight }],
            mutexes: 1,
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
        };
        let n = 30u32;
        let mut p = TraceBuilder::new();
        p.push(TraceOp::CmInit {
            tile: 0,
            placement: Placement { row0: 0, col0: 0, rows: 512, cols: 512 },
        });
        p.repeat(n, |b, k| {
            b.roi(RoiKind::InputLoad, |b| {
                b.stream_read(0x8000_0000 + k as u64 * 0x800, 2048, 2);
            });
            b.roi(RoiKind::DigitalMvm, |b| {
                // LLC-thrashing fixed weight stream: occupancy reaches
                // its steady state within the first couple of iterations,
                // so the fast-forward digest can lock on.
                b.stream_read(0x1000_0000, 2 * 1024 * 1024, 1);
            });
            b.push(TraceOp::CmQueue { tile: 0, bytes: 512 });
            b.push(TraceOp::CmProcess { tile: 0 });
            b.push(TraceOp::CmDequeue { tile: 0, bytes: 512 });
            b.push(TraceOp::MutexLock { id: 0 });
            b.push(TraceOp::MutexUnlock { id: 0 });
            // Fixed buffer address (iteration-invariant, so the emission
            // stays affine-encodable as a single Rep body).
            b.push(TraceOp::Send { ch: 0, bytes: 2048, addr: 0xB000_0000 });
        });
        let mut c = TraceBuilder::new();
        c.repeat(n, |b, k| {
            b.push(TraceOp::Recv { ch: 0 });
            b.push(TraceOp::MutexLock { id: 0 });
            b.compute(InstClass::SimdOp, 3000);
            b.push(TraceOp::MutexUnlock { id: 0 });
            // L1-thrashing fixed re-read so the consumer's cache
            // occupancy also stabilizes within a couple of iterations.
            b.stream_read(0x2000_0000, 64 * 1024, 1);
            b.roi(RoiKind::Writeback, |b| {
                b.stream_write(0xA000_0000 + k as u64 * 0x800, 1024, 2);
            });
        });
        let traces = vec![p.build_trace(), c.build_trace()];
        let run = |ff: bool| {
            let mut m = hp_machine(spec.clone());
            m.set_fast_forward(ff);
            let rs = m.run(traces.clone()).unwrap();
            (rs, m.fast_forward_jumps())
        };
        let (fast, jumps) = run(true);
        let (reference, _) = run(false);
        assert_stats_identical(&fast, &reference);
        assert!(jumps >= 1, "fast-forward never engaged on the pipeline");
    }

    #[test]
    fn fast_forward_handles_uneven_rep_counts() {
        // Producer loops 30 times, consumer receives 30 messages but in
        // a Rep of 15 double-iterations: leads and periods differ.
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        };
        let mut p = TraceBuilder::new();
        p.repeat(30, |b, k| {
            b.compute(InstClass::IntAlu, 2000);
            b.push(TraceOp::Send { ch: 0, bytes: 256, addr: 0xB000_0000 + k as u64 * 0x400 });
        });
        let mut c = TraceBuilder::new();
        c.repeat(15, |b, _| {
            b.push(TraceOp::Recv { ch: 0 });
            b.compute(InstClass::SimdOp, 1500);
            b.push(TraceOp::Recv { ch: 0 });
            b.compute(InstClass::SimdOp, 1500);
        });
        let traces = vec![p.build_trace(), c.build_trace()];
        let run = |ff: bool| {
            let mut m = hp_machine(spec.clone());
            m.set_fast_forward(ff);
            m.run(traces.clone()).unwrap()
        };
        assert_stats_identical(&run(true), &run(false));
    }

    /// A CNN-ish nested steady state: an outer per-inference `Loop`
    /// whose body is an inner row-group `Rep` (fresh input slice + an
    /// LLC-thrashing fixed weight stream + compute) plus a small
    /// per-inference epilogue — the outer loop never reaches a
    /// whole-trace steady state, only the inner `Rep` is periodic.
    fn nested_workload(outer: u32, rows: u32) -> Trace {
        let mut b = TraceBuilder::new();
        b.repeat_nested(outer, move |b, k| {
            b.repeat(rows, move |b, g| {
                b.roi(RoiKind::InputLoad, |b| {
                    b.stream_read(0x8000_0000 + k as u64 * 0x10_0000 + g as u64 * 0x800, 2048, 2);
                });
                b.roi(RoiKind::DigitalMvm, |b| {
                    b.stream_read(0x1000_0000, 2 * 1024 * 1024, 1);
                    b.compute(InstClass::SimdOp, 6_000);
                });
            });
            b.roi(RoiKind::Writeback, |b| {
                b.stream_write(0xA000_0000 + k as u64 * 0x1000, 1024, 2);
            });
        });
        b.build_trace()
    }

    #[test]
    fn nested_loop_trace_executes_like_flat() {
        let looped = nested_workload(6, 8);
        assert!(
            looped.segments.iter().any(|s| matches!(s, Segment::Loop { .. })),
            "workload should encode as a nested Loop"
        );
        let flat = looped.flatten();
        let mut m1 = hp_machine(MachineSpec::default());
        m1.set_fast_forward(false);
        let a = m1.run(vec![looped]).unwrap();
        let mut m2 = hp_machine(MachineSpec::default());
        m2.set_fast_forward(false);
        let b = m2.run(vec![flat]).unwrap();
        assert_stats_identical(&a, &b);
    }

    #[test]
    fn nested_fast_forward_jumps_inner_rep_and_stays_bit_identical() {
        let trace = nested_workload(8, 24);
        let run = |ff: bool, nested: bool| {
            let mut m = hp_machine(MachineSpec::default());
            m.set_fast_forward(ff);
            m.set_nested_fast_forward(nested);
            let rs = m.run(vec![trace.clone()]).unwrap();
            (rs, m.fast_forward_jumps(), m.fast_forward_skipped_iters())
        };
        let (fast, jumps, skipped) = run(true, true);
        let (reference, no_jumps, _) = run(false, true);
        assert_stats_identical(&fast, &reference);
        assert!(jumps >= 2, "inner Rep never fast-forwarded (jumps {jumps})");
        assert!(skipped > 8 * 24 / 2, "skipped only {skipped} of {} iterations", 8 * 24);
        assert_eq!(no_jumps, 0, "knob off must fully replay");
        // Top-level-only mode: the cursor is always inside the Loop, so
        // the pre-nesting eligibility rule never fires a jump — but the
        // stats stay bit-identical all the same.
        let (legacy, legacy_jumps, _) = run(true, false);
        assert_stats_identical(&legacy, &reference);
        assert_eq!(legacy_jumps, 0, "nested-ff off must not jump inside a Loop");
    }

    #[test]
    fn velocity_scheme_jumps_heterogeneous_periods() {
        // Producer runs 2 iterations per consumer iteration: the
        // per-round velocities are (2, 1), which the pre-velocity digest
        // (lead offsets in positional state) could never match.
        let spec = MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        };
        let mut p = TraceBuilder::new();
        p.repeat(60, |b, _| {
            b.compute(InstClass::IntAlu, 2000);
            b.push(TraceOp::Send { ch: 0, bytes: 256, addr: 0xB000_0000 });
        });
        let mut c = TraceBuilder::new();
        c.repeat(30, |b, _| {
            b.push(TraceOp::Recv { ch: 0 });
            b.compute(InstClass::SimdOp, 1500);
            b.push(TraceOp::Recv { ch: 0 });
            b.compute(InstClass::SimdOp, 1500);
        });
        let traces = vec![p.build_trace(), c.build_trace()];
        let run = |ff: bool| {
            let mut m = hp_machine(spec.clone());
            m.set_fast_forward(ff);
            let rs = m.run(traces.clone()).unwrap();
            (rs, m.fast_forward_jumps())
        };
        let (fast, jumps) = run(true);
        let (reference, _) = run(false);
        assert_stats_identical(&fast, &reference);
        assert!(jumps >= 1, "velocity-2 producer blocked the jump");
    }

    // -----------------------------------------------------------------
    // Tile fault injection
    // -----------------------------------------------------------------

    fn tile_pipeline_trace(iters: u32) -> Vec<TraceOp> {
        let mut ops = vec![TraceOp::CmInit {
            tile: 0,
            placement: Placement { row0: 0, col0: 0, rows: 512, cols: 512 },
        }];
        for _ in 0..iters {
            ops.push(TraceOp::CmQueue { tile: 0, bytes: 512 });
            ops.push(TraceOp::CmProcess { tile: 0 });
            ops.push(TraceOp::CmDequeue { tile: 0, bytes: 512 });
        }
        ops
    }

    fn tile_spec() -> MachineSpec {
        MachineSpec {
            tiles: vec![TileSpec { rows: 512, cols: 512, coupling: Coupling::Tight }],
            ..Default::default()
        }
    }

    #[test]
    fn explicit_none_fault_model_is_bit_identical() {
        let run = |set_none: bool| {
            let mut m = hp_machine(tile_spec());
            if set_none {
                m.set_tile_fault(0, TileFaultModel::none());
            }
            m.run(vec![tile_pipeline_trace(8)]).unwrap()
        };
        assert_stats_identical(&run(true), &run(false));
    }

    #[test]
    fn transient_stalls_slow_the_run_but_complete() {
        let run = |model: TileFaultModel| {
            let mut m = hp_machine(tile_spec());
            m.set_tile_fault(0, model);
            m.run(vec![tile_pipeline_trace(8)]).unwrap().roi_time_ps
        };
        let clean = run(TileFaultModel::none());
        let faulty = run(TileFaultModel {
            transient_period_ps: 400_000,
            transient_stall_ps: 60_000,
            ..TileFaultModel::none()
        });
        assert!(faulty > clean, "clean {clean} faulty {faulty}");
    }

    #[test]
    fn hard_tile_failure_is_a_typed_error() {
        let mut m = hp_machine(tile_spec());
        m.set_tile_fault(0, TileFaultModel { hard_fail_at_ps: Some(500_000), ..TileFaultModel::none() });
        let err = m.run(vec![tile_pipeline_trace(64)]).unwrap_err();
        assert!(
            matches!(err, RunError::TileFailed { tile: 0, at_ps: 500_000 }),
            "expected TileFailed, got {err:?}"
        );
    }

    #[test]
    fn permanent_transient_stall_times_out() {
        // Stall window covers the whole period: every backoff retry
        // lands back inside a stall, so the retry budget must exhaust
        // into a typed Timeout rather than spinning forever.
        let mut m = hp_machine(tile_spec());
        m.set_tile_fault(
            0,
            TileFaultModel {
                transient_period_ps: 100_000,
                transient_stall_ps: 100_000,
                ..TileFaultModel::none()
            },
        );
        let err = m.run(vec![tile_pipeline_trace(4)]).unwrap_err();
        assert!(
            matches!(err, RunError::Timeout { tile: 0, attempts: BACKOFF_MAX_RETRIES, .. }),
            "expected Timeout, got {err:?}"
        );
    }
}
