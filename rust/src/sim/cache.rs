//! Set-associative cache timing model (LRU, write-back, write-allocate).
//!
//! The model is line-granular and functional-less: it tracks tags only,
//! which is all the timing/energy model needs. Hit/miss behaviour under
//! streaming and thrashing working sets is what drives the paper's
//! results (§VII.E, §VIII.E), so the replacement state is exact, not
//! approximated.

use crate::config::CacheGeometry;
use crate::stats::CacheStats;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

#[derive(Clone, Copy, Debug)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp: larger == more recently used.
    lru: u64,
}

/// Result of one cache lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupResult {
    pub hit: bool,
    /// A dirty victim was evicted (must be written back downstream).
    pub writeback: bool,
}

pub struct Cache {
    geom: CacheGeometry,
    /// Flat line array, `assoc` consecutive entries per set (§Perf: the
    /// nested Vec<Vec<Line>> layout cost ~25% of the whole-stack
    /// simulation time in pointer chasing; see EXPERIMENTS.md).
    lines: Vec<Line>,
    set_mask: usize,
    assoc: usize,
    stamp: u64,
    pub stats: CacheStats,
}

impl Cache {
    pub fn new(geom: CacheGeometry) -> Cache {
        let n_sets = geom.sets() as usize;
        assert!(n_sets.is_power_of_two(), "sets must be a power of two");
        Cache {
            geom,
            lines: vec![
                Line { tag: 0, valid: false, dirty: false, lru: 0 };
                n_sets * geom.assoc as usize
            ],
            set_mask: n_sets - 1,
            assoc: geom.assoc as usize,
            stamp: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn geometry(&self) -> &CacheGeometry {
        &self.geom
    }

    #[inline]
    fn set_range_tag(&self, addr: u64) -> (usize, u64) {
        let line = addr / self.geom.line_bytes;
        let idx = (line as usize) & self.set_mask;
        (idx * self.assoc, line)
    }

    /// Access one line. On miss the line is allocated (write-allocate) and
    /// the LRU victim evicted; `writeback` reports whether the victim was
    /// dirty.
    pub fn access(&mut self, addr: u64, kind: Access) -> LookupResult {
        self.stamp += 1;
        let (base, tag) = self.set_range_tag(addr);
        let set = &mut self.lines[base..base + self.assoc];

        if let Some(line) = set.iter_mut().find(|l| l.valid && l.tag == tag) {
            line.lru = self.stamp;
            if kind == Access::Write {
                line.dirty = true;
                self.stats.write_hits += 1;
            } else {
                self.stats.read_hits += 1;
            }
            return LookupResult { hit: true, writeback: false };
        }

        // Miss: evict LRU victim, allocate.
        let victim = set
            .iter_mut()
            .min_by_key(|l| if l.valid { l.lru } else { 0 })
            .unwrap();
        let writeback = victim.valid && victim.dirty;
        if writeback {
            self.stats.writebacks += 1;
        }
        *victim = Line {
            tag,
            valid: true,
            dirty: kind == Access::Write,
            lru: self.stamp,
        };
        if kind == Access::Write {
            self.stats.write_misses += 1;
        } else {
            self.stats.read_misses += 1;
        }
        LookupResult { hit: false, writeback }
    }

    /// Invalidate a line if present (cross-core producer/consumer sharing:
    /// the consumer-side model invalidates the producer's L1 copy).
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let (base, tag) = self.set_range_tag(addr);
        for l in &mut self.lines[base..base + self.assoc] {
            if l.valid && l.tag == tag {
                l.valid = false;
                return true;
            }
        }
        false
    }

    /// Does the cache currently hold this address? (no LRU update)
    pub fn probe(&self, addr: u64) -> bool {
        let (base, tag) = self.set_range_tag(addr);
        self.lines[base..base + self.assoc]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    pub fn line_bytes(&self) -> u64 {
        self.geom.line_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 64B = 512B.
        Cache::new(CacheGeometry { size_bytes: 512, assoc: 2, line_bytes: 64, hit_latency_cycles: 2 })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = small();
        assert!(!c.access(0x1000, Access::Read).hit);
        assert!(c.access(0x1000, Access::Read).hit);
        assert!(c.access(0x1010, Access::Read).hit, "same line");
        assert_eq!(c.stats.read_misses, 1);
        assert_eq!(c.stats.read_hits, 2);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // Three lines in the same set (stride = sets * line = 256B).
        c.access(0x0, Access::Read);
        c.access(0x100, Access::Read);
        c.access(0x0, Access::Read); // touch: 0x0 is MRU
        c.access(0x200, Access::Read); // evicts 0x100
        assert!(c.probe(0x0));
        assert!(!c.probe(0x100));
        assert!(c.probe(0x200));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = small();
        c.access(0x0, Access::Write);
        c.access(0x100, Access::Read);
        let r = c.access(0x200, Access::Read); // evicts dirty 0x0
        assert!(r.writeback);
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn write_allocate() {
        let mut c = small();
        let r = c.access(0x40, Access::Write);
        assert!(!r.hit);
        assert!(c.probe(0x40));
        assert!(c.access(0x40, Access::Read).hit);
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = small();
        c.access(0x40, Access::Read);
        assert!(c.invalidate(0x40));
        assert!(!c.probe(0x40));
        assert!(!c.invalidate(0x40));
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = small(); // 512B
        // Stream 4 KiB twice: second pass must still miss everywhere.
        for pass in 0..2 {
            for addr in (0..4096).step_by(64) {
                let r = c.access(addr, Access::Read);
                assert!(!r.hit, "pass {pass} addr {addr}");
            }
        }
    }

    #[test]
    fn working_set_smaller_than_cache_hits_after_warmup() {
        let mut c = small();
        for addr in (0..256).step_by(64) {
            c.access(addr, Access::Read);
        }
        for addr in (0..256).step_by(64) {
            assert!(c.access(addr, Access::Read).hit);
        }
    }
}
