//! Set-associative cache timing model (LRU, write-back, write-allocate).
//!
//! The model is line-granular and functional-less: it tracks tags only,
//! which is all the timing/energy model needs. Hit/miss behaviour under
//! streaming and thrashing working sets is what drives the paper's
//! results (§VII.E, §VIII.E), so the replacement state is exact, not
//! approximated.

use crate::config::CacheGeometry;
use crate::stats::CacheStats;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

#[derive(Clone, Copy, Debug)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp: larger == more recently used.
    lru: u64,
}

/// Result of one cache lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupResult {
    pub hit: bool,
    /// A dirty victim was evicted (must be written back downstream).
    pub writeback: bool,
}

/// Result of one step of a bulk sequential walk ([`Cache::stream_run`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamRun {
    /// Consecutive leading lines that hit (LRU + stats already updated).
    pub hits: u64,
    /// `Some(dirty_victim_evicted)` if the walk stopped at a miss (the
    /// missing line is already allocated); `None` if every line hit.
    pub miss_writeback: Option<bool>,
}

pub struct Cache {
    geom: CacheGeometry,
    /// Flat line array, `assoc` consecutive entries per set (§Perf: the
    /// nested Vec<Vec<Line>> layout cost ~25% of the whole-stack
    /// simulation time in pointer chasing; see EXPERIMENTS.md).
    lines: Vec<Line>,
    set_mask: usize,
    assoc: usize,
    /// log2(line_bytes): addr-to-line is a shift, not a u64 division
    /// (§Perf: the division showed up on every access of every level).
    line_shift: u32,
    stamp: u64,
    pub stats: CacheStats,
}

impl Cache {
    pub fn new(geom: CacheGeometry) -> Cache {
        let n_sets = geom.sets() as usize;
        assert!(n_sets.is_power_of_two(), "sets must be a power of two");
        assert!(geom.line_bytes.is_power_of_two(), "line size must be a power of two");
        Cache {
            geom,
            lines: vec![
                Line { tag: 0, valid: false, dirty: false, lru: 0 };
                n_sets * geom.assoc as usize
            ],
            set_mask: n_sets - 1,
            assoc: geom.assoc as usize,
            line_shift: geom.line_bytes.trailing_zeros(),
            stamp: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn geometry(&self) -> &CacheGeometry {
        &self.geom
    }

    #[inline]
    fn set_range_tag(&self, addr: u64) -> (usize, u64) {
        let line = addr >> self.line_shift;
        let idx = (line as usize) & self.set_mask;
        (idx * self.assoc, line)
    }

    /// One pass over a set: the way holding `tag`, or the victim way
    /// (first invalid way, else least-recent `lru` — first-minimum on
    /// ties, exactly `min_by_key`'s tie break on an all-zero invalid key).
    #[inline]
    fn find_or_victim(set: &[Line], tag: u64) -> (Option<usize>, usize) {
        let mut victim_idx = 0usize;
        let mut victim_key = u64::MAX;
        for (w, l) in set.iter().enumerate() {
            if l.valid && l.tag == tag {
                return (Some(w), victim_idx);
            }
            let key = if l.valid { l.lru } else { 0 };
            if key < victim_key {
                victim_key = key;
                victim_idx = w;
            }
        }
        (None, victim_idx)
    }

    /// Refresh a hit way: LRU touch + write-allocate dirty bit.
    #[inline]
    fn touch_hit(line: &mut Line, stamp: u64, kind: Access) {
        line.lru = stamp;
        if kind == Access::Write {
            line.dirty = true;
        }
    }

    /// Evict `victim` and allocate `tag` into it (write-allocate).
    /// Returns whether the victim was dirty; `stats.writebacks` is
    /// bumped here, hit/miss counters stay with the caller (the bulk
    /// walk amortizes them).
    #[inline]
    fn allocate_into(victim: &mut Line, tag: u64, stamp: u64, kind: Access, stats: &mut CacheStats) -> bool {
        let writeback = victim.valid && victim.dirty;
        if writeback {
            stats.writebacks += 1;
        }
        *victim = Line {
            tag,
            valid: true,
            dirty: kind == Access::Write,
            lru: stamp,
        };
        writeback
    }

    /// Access one line. On miss the line is allocated (write-allocate) and
    /// the LRU victim evicted; `writeback` reports whether the victim was
    /// dirty.
    pub fn access(&mut self, addr: u64, kind: Access) -> LookupResult {
        self.stamp += 1;
        let (base, tag) = self.set_range_tag(addr);
        let set = &mut self.lines[base..base + self.assoc];

        let (hit_idx, victim_idx) = Self::find_or_victim(set, tag);
        if let Some(w) = hit_idx {
            Self::touch_hit(&mut set[w], self.stamp, kind);
            if kind == Access::Write {
                self.stats.write_hits += 1;
            } else {
                self.stats.read_hits += 1;
            }
            return LookupResult { hit: true, writeback: false };
        }

        // Miss: evict LRU victim, allocate.
        let writeback =
            Self::allocate_into(&mut set[victim_idx], tag, self.stamp, kind, &mut self.stats);
        if kind == Access::Write {
            self.stats.write_misses += 1;
        } else {
            self.stats.read_misses += 1;
        }
        LookupResult { hit: false, writeback }
    }

    /// Bulk sequential walk: equivalent to `access` on `max_lines`
    /// consecutive lines starting at `addr`, but with a single
    /// incrementing set-index walk, amortized stat updates, and an
    /// early-out at the first miss (which is allocated before returning,
    /// exactly like `access`, so the caller only has to model the levels
    /// below). State and statistics after a walk are bit-identical to
    /// the per-line loop — see the equivalence proptest.
    pub fn stream_run(&mut self, addr: u64, max_lines: u64, kind: Access) -> StreamRun {
        let mut line = addr >> self.line_shift;
        let mut hits = 0u64;
        while hits < max_lines {
            self.stamp += 1;
            let base = ((line as usize) & self.set_mask) * self.assoc;
            let set = &mut self.lines[base..base + self.assoc];
            let (hit_idx, victim_idx) = Self::find_or_victim(set, line);
            if let Some(w) = hit_idx {
                Self::touch_hit(&mut set[w], self.stamp, kind);
                hits += 1;
                line += 1;
                continue;
            }
            // First miss of the run: allocate it, flush the amortized hit
            // counters, and hand control back to the hierarchy walk.
            let writeback =
                Self::allocate_into(&mut set[victim_idx], line, self.stamp, kind, &mut self.stats);
            if kind == Access::Write {
                self.stats.write_hits += hits;
                self.stats.write_misses += 1;
            } else {
                self.stats.read_hits += hits;
                self.stats.read_misses += 1;
            }
            return StreamRun { hits, miss_writeback: Some(writeback) };
        }
        if kind == Access::Write {
            self.stats.write_hits += hits;
        } else {
            self.stats.read_hits += hits;
        }
        StreamRun { hits, miss_writeback: None }
    }

    /// Invalidate a line if present (cross-core producer/consumer sharing:
    /// the consumer-side model invalidates the producer's L1 copy).
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let (base, tag) = self.set_range_tag(addr);
        for l in &mut self.lines[base..base + self.assoc] {
            if l.valid && l.tag == tag {
                l.valid = false;
                return true;
            }
        }
        false
    }

    /// Visit the cache's monotonic counters in a fixed order (the trace
    /// machine's fast-forward engine snapshots and extrapolates them).
    pub(crate) fn for_each_counter(&mut self, f: &mut dyn FnMut(&mut u64)) {
        f(&mut self.stats.read_hits);
        f(&mut self.stats.read_misses);
        f(&mut self.stats.write_hits);
        f(&mut self.stats.write_misses);
        f(&mut self.stats.writebacks);
        f(&mut self.stamp);
    }

    /// Occupancy fingerprint for periodicity detection: total valid and
    /// dirty lines plus a commutative hash over the per-set
    /// (valid, dirty) counts. Commutativity matters: steady-state
    /// streams over fresh per-inference addresses rotate their footprint
    /// through the sets each iteration, which must not perturb the
    /// digest — while a cache still *filling* (growing counts) must.
    /// The trade-off: tags, LRU order and set *positions* are not
    /// fingerprinted, so this is a necessary-not-sufficient periodicity
    /// check (see the `sim::machine` module docs for why that is sound
    /// for compiler-emitted workloads and how the equivalence gates pin
    /// it).
    pub(crate) fn occupancy_digest(&self) -> (u64, u64, u64) {
        let mut valid = 0u64;
        let mut dirty = 0u64;
        let mut hash = 0u64;
        for set in self.lines.chunks(self.assoc) {
            let mut v = 0u64;
            let mut d = 0u64;
            for l in set {
                if l.valid {
                    v += 1;
                    if l.dirty {
                        d += 1;
                    }
                }
            }
            valid += v;
            dirty += d;
            let h = v.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ d.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
            hash = hash.wrapping_add(h.wrapping_mul(h | 1));
        }
        (valid, dirty, hash)
    }

    /// Does the cache currently hold this address? (no LRU update)
    pub fn probe(&self, addr: u64) -> bool {
        let (base, tag) = self.set_range_tag(addr);
        self.lines[base..base + self.assoc]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    pub fn line_bytes(&self) -> u64 {
        self.geom.line_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets x 2 ways x 64B = 512B.
        Cache::new(CacheGeometry { size_bytes: 512, assoc: 2, line_bytes: 64, hit_latency_cycles: 2 })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = small();
        assert!(!c.access(0x1000, Access::Read).hit);
        assert!(c.access(0x1000, Access::Read).hit);
        assert!(c.access(0x1010, Access::Read).hit, "same line");
        assert_eq!(c.stats.read_misses, 1);
        assert_eq!(c.stats.read_hits, 2);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // Three lines in the same set (stride = sets * line = 256B).
        c.access(0x0, Access::Read);
        c.access(0x100, Access::Read);
        c.access(0x0, Access::Read); // touch: 0x0 is MRU
        c.access(0x200, Access::Read); // evicts 0x100
        assert!(c.probe(0x0));
        assert!(!c.probe(0x100));
        assert!(c.probe(0x200));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = small();
        c.access(0x0, Access::Write);
        c.access(0x100, Access::Read);
        let r = c.access(0x200, Access::Read); // evicts dirty 0x0
        assert!(r.writeback);
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn write_allocate() {
        let mut c = small();
        let r = c.access(0x40, Access::Write);
        assert!(!r.hit);
        assert!(c.probe(0x40));
        assert!(c.access(0x40, Access::Read).hit);
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = small();
        c.access(0x40, Access::Read);
        assert!(c.invalidate(0x40));
        assert!(!c.probe(0x40));
        assert!(!c.invalidate(0x40));
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = small(); // 512B
        // Stream 4 KiB twice: second pass must still miss everywhere.
        for pass in 0..2 {
            for addr in (0..4096).step_by(64) {
                let r = c.access(addr, Access::Read);
                assert!(!r.hit, "pass {pass} addr {addr}");
            }
        }
    }

    #[test]
    fn stream_run_matches_per_line_access() {
        let mut per_line = small();
        let mut bulk = small();
        // Warm both with the same 4 lines.
        for addr in (0..256).step_by(64) {
            per_line.access(addr, Access::Read);
            bulk.access(addr, Access::Read);
        }
        // Walk 8 lines: 4 hits, then a miss that stops the run.
        let mut ref_hits = 0;
        let mut first_miss = None;
        for k in 0..8u64 {
            let r = per_line.access(k * 64, Access::Read);
            if r.hit {
                ref_hits += 1;
            } else {
                first_miss = Some(k);
                break;
            }
        }
        let run = bulk.stream_run(0, 8, Access::Read);
        assert_eq!(run.hits, ref_hits);
        assert_eq!(first_miss, Some(run.hits));
        assert!(run.miss_writeback.is_some());
        assert_eq!(per_line.stats.read_hits, bulk.stats.read_hits);
        assert_eq!(per_line.stats.read_misses, bulk.stats.read_misses);
        // The miss line was allocated by the walk, exactly like access().
        assert!(bulk.probe(run.hits * 64));
    }

    #[test]
    fn stream_run_all_hits_early_out() {
        let mut c = small();
        for addr in (0..256).step_by(64) {
            c.access(addr, Access::Read);
        }
        let run = c.stream_run(0, 4, Access::Read);
        assert_eq!(run.hits, 4);
        assert_eq!(run.miss_writeback, None);
        assert_eq!(c.stats.read_hits, 4);
    }

    #[test]
    fn working_set_smaller_than_cache_hits_after_warmup() {
        let mut c = small();
        for addr in (0..256).step_by(64) {
            c.access(addr, Access::Read);
        }
        for addr in (0..256).step_by(64) {
            assert!(c.access(addr, Access::Read).hit);
        }
    }
}
