//! Inter-core synchronization primitives for the timing model.
//!
//! The paper's multi-core mappings pipeline layers across cores with
//! libpthread mutexes and ping-pong buffers (§VI.C). The trace machine
//! executes cores in global-time order, so these primitives only need
//! "busy-until" semantics: a lock is an interval reservation, a channel a
//! queue of (ready-time, bytes) messages.

use std::collections::VecDeque;

/// A pthread-style mutex with real mutual exclusion: while locked, other
/// cores' acquisition attempts block (the trace machine retries them
/// after advancing time past the holder).
#[derive(Clone, Debug, Default)]
pub struct SimMutex {
    locked: bool,
    /// Time of the most recent release (ps).
    last_release_ps: u64,
    pub acquisitions: u64,
    pub contended: u64,
}

impl SimMutex {
    /// Try to acquire at `now`. Returns the grant time, or None if the
    /// lock is currently held (caller must retry later). No side effects
    /// on failure.
    pub fn try_acquire(&mut self, now_ps: u64) -> Option<u64> {
        if self.locked {
            self.contended += 1;
            return None;
        }
        self.acquisitions += 1;
        Some(now_ps.max(self.last_release_ps))
    }

    /// Commit the acquisition granted by `try_acquire`.
    pub fn lock(&mut self) {
        debug_assert!(!self.locked);
        self.locked = true;
    }

    /// Release at `now`.
    pub fn release(&mut self, now_ps: u64) {
        debug_assert!(self.locked, "release of unheld mutex");
        self.locked = false;
        self.last_release_ps = self.last_release_ps.max(now_ps);
    }

    pub fn is_locked(&self) -> bool {
        self.locked
    }

    pub fn last_release_ps(&self) -> u64 {
        self.last_release_ps
    }

    /// Advance the release timestamp by `d` ps (fast-forward jumps shift
    /// every clock in the machine uniformly).
    pub(crate) fn shift_time(&mut self, d: u64) {
        self.last_release_ps += d;
    }
}

/// A single-producer single-consumer message channel (ping-pong buffer).
/// Messages become visible to the consumer at their `ready_ps` time.
#[derive(Clone, Debug, Default)]
pub struct SimChannel {
    msgs: VecDeque<Msg>,
    /// Ping-pong depth: a bounded buffer of 2 entries (§VI.C). A producer
    /// sending when `capacity` messages are in flight blocks until the
    /// consumer drains one.
    pub capacity: usize,
    pub sends: u64,
    pub recvs: u64,
    /// Time of the most recent receive — a producer that was blocked on a
    /// full buffer cannot send earlier than the drain that freed its slot.
    pub last_recv_ps: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct Msg {
    pub ready_ps: u64,
    pub bytes: u64,
    /// Base address of the buffer (for cache modeling of the transfer).
    pub addr: u64,
}

impl SimChannel {
    pub fn new(capacity: usize) -> SimChannel {
        SimChannel { capacity, ..Default::default() }
    }

    /// Producer sends at `now`; Ok(()) if the buffer has room, otherwise
    /// Err(earliest-retry-time-hint) — but since the consumer's progress is
    /// unknown until it runs, the machine retries based on core ordering.
    pub fn try_send(&mut self, now_ps: u64, bytes: u64, addr: u64) -> bool {
        if self.msgs.len() >= self.capacity {
            return false;
        }
        self.sends += 1;
        self.msgs.push_back(Msg { ready_ps: now_ps, bytes, addr });
        true
    }

    /// Consumer receives at `now`: returns the message if one is ready
    /// (sent at or before a visibility horizon the machine enforces).
    pub fn try_recv(&mut self, now_ps: u64) -> Option<Msg> {
        match self.msgs.front() {
            Some(m) if m.ready_ps <= now_ps => {
                self.recvs += 1;
                self.last_recv_ps = self.last_recv_ps.max(now_ps);
                self.msgs.pop_front()
            }
            _ => None,
        }
    }

    /// Earliest ready time of the head message, if any.
    pub fn head_ready_ps(&self) -> Option<u64> {
        self.msgs.front().map(|m| m.ready_ps)
    }

    pub fn len(&self) -> usize {
        self.msgs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.msgs.is_empty()
    }

    /// In-flight messages, oldest first (fast-forward digest).
    pub fn msgs(&self) -> impl Iterator<Item = &Msg> {
        self.msgs.iter()
    }

    /// Advance every message timestamp by `d` ps (fast-forward jumps
    /// shift every clock in the machine uniformly).
    pub(crate) fn shift_time(&mut self, d: u64) {
        for m in &mut self.msgs {
            m.ready_ps += d;
        }
        self.last_recv_ps += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_uncontended() {
        let mut m = SimMutex::default();
        assert_eq!(m.try_acquire(100), Some(100));
        m.lock();
        m.release(200);
        assert_eq!(m.acquisitions, 1);
        assert_eq!(m.contended, 0);
    }

    #[test]
    fn mutex_blocks_while_held() {
        let mut m = SimMutex::default();
        assert_eq!(m.try_acquire(0), Some(0));
        m.lock();
        assert_eq!(m.try_acquire(100), None, "held: must block");
        m.release(500);
        // Retry after release: granted no earlier than the release time.
        assert_eq!(m.try_acquire(100), Some(500));
        assert_eq!(m.contended, 1);
    }

    #[test]
    fn mutex_grant_respects_arrival_time() {
        let mut m = SimMutex::default();
        m.try_acquire(0).unwrap();
        m.lock();
        m.release(500);
        assert_eq!(m.try_acquire(900), Some(900));
    }

    #[test]
    fn channel_fifo_and_readiness() {
        let mut ch = SimChannel::new(2);
        assert!(ch.try_send(1000, 64, 0x100));
        assert!(ch.try_send(2000, 64, 0x140));
        assert!(!ch.try_send(2500, 64, 0x180), "ping-pong capacity 2");
        assert!(ch.try_recv(500).is_none(), "not ready yet");
        let m = ch.try_recv(1500).unwrap();
        assert_eq!(m.ready_ps, 1000);
        assert!(ch.try_send(2600, 64, 0x180), "room after drain");
    }

    #[test]
    fn recv_on_empty_is_none() {
        let mut ch = SimChannel::new(2);
        assert!(ch.try_recv(u64::MAX).is_none());
    }
}
