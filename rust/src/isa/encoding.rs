//! Binary encodings of the CM_* instructions (Fig. 3b).
//!
//! Layout (32-bit word, custom-opcode space of AArch64):
//!
//!   [31:20] opcode   (0x108 queue/dequeue, 0x008 process, 0x208 init)
//!   [19]    r/w      (1 = queue/write direction, 0 = read/other)
//!   [18:14] Rm       source register (packed data)
//!   [13:10] Ra       auxiliary (count of valid packed bytes)
//!   [9:5]   Rn       index register (input/output memory offset)
//!   [4:0]   Rd       destination register

/// The four operations of the extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmOp {
    Queue,
    Dequeue,
    Process,
    Initialize,
}

impl CmOp {
    pub fn opcode(&self) -> u16 {
        match self {
            CmOp::Queue | CmOp::Dequeue => 0x108,
            CmOp::Process => 0x008,
            CmOp::Initialize => 0x208,
        }
    }

    pub fn rw_bit(&self) -> bool {
        matches!(self, CmOp::Queue)
    }

    pub fn name(&self) -> &'static str {
        match self {
            CmOp::Queue => "CM_QUEUE",
            CmOp::Dequeue => "CM_DEQUEUE",
            CmOp::Process => "CM_PROCESS",
            CmOp::Initialize => "CM_INITIALIZE",
        }
    }
}

/// A decoded CM instruction with its register fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CmInstruction {
    pub op: CmOp,
    pub rm: u8,
    pub ra: u8,
    pub rn: u8,
    pub rd: u8,
}

#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    UnknownOpcode(u16),
    BadRegister,
}

// Manual Display/Error impls: thiserror is not in the offline vendor set.
impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::UnknownOpcode(op) => write!(f, "unknown CM opcode {op:#05x}"),
            DecodeError::BadRegister => write!(f, "register field out of range"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Encode to the 32-bit instruction word.
pub fn encode(inst: &CmInstruction) -> u32 {
    assert!(inst.rm < 32 && inst.rn < 32 && inst.rd < 32 && inst.ra < 16);
    ((inst.op.opcode() as u32) << 20)
        | ((inst.op.rw_bit() as u32) << 19)
        | ((inst.rm as u32) << 14)
        | ((inst.ra as u32) << 10)
        | ((inst.rn as u32) << 5)
        | (inst.rd as u32)
}

/// Decode a 32-bit instruction word.
pub fn decode(word: u32) -> Result<CmInstruction, DecodeError> {
    let opcode = (word >> 20) as u16 & 0xFFF;
    let rw = (word >> 19) & 1 == 1;
    let op = match (opcode, rw) {
        (0x108, true) => CmOp::Queue,
        (0x108, false) => CmOp::Dequeue,
        (0x008, false) => CmOp::Process,
        (0x208, false) => CmOp::Initialize,
        _ => return Err(DecodeError::UnknownOpcode(opcode)),
    };
    Ok(CmInstruction {
        op,
        rm: ((word >> 14) & 0x1F) as u8,
        ra: ((word >> 10) & 0xF) as u8,
        rn: ((word >> 5) & 0x1F) as u8,
        rd: (word & 0x1F) as u8,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::miniprop;

    #[test]
    fn fig3b_opcodes() {
        assert_eq!(CmOp::Queue.opcode(), 0x108);
        assert_eq!(CmOp::Dequeue.opcode(), 0x108);
        assert_eq!(CmOp::Process.opcode(), 0x008);
        assert_eq!(CmOp::Initialize.opcode(), 0x208);
        assert!(CmOp::Queue.rw_bit());
        assert!(!CmOp::Dequeue.rw_bit());
    }

    #[test]
    fn roundtrip_all_ops() {
        for op in [CmOp::Queue, CmOp::Dequeue, CmOp::Process, CmOp::Initialize] {
            let inst = CmInstruction { op, rm: 3, ra: 7, rn: 12, rd: 29 };
            assert_eq!(decode(encode(&inst)).unwrap(), inst);
        }
    }

    #[test]
    fn queue_dequeue_distinguished_by_rw() {
        let q = CmInstruction { op: CmOp::Queue, rm: 1, ra: 2, rn: 3, rd: 4 };
        let d = CmInstruction { op: CmOp::Dequeue, ..q };
        assert_ne!(encode(&q), encode(&d));
        assert_eq!(decode(encode(&q)).unwrap().op, CmOp::Queue);
        assert_eq!(decode(encode(&d)).unwrap().op, CmOp::Dequeue);
    }

    #[test]
    fn unknown_opcode_rejected() {
        assert!(matches!(decode(0xFFF0_0000), Err(DecodeError::UnknownOpcode(_))));
    }

    #[test]
    fn roundtrip_property() {
        miniprop::check("cm-encode-roundtrip", 0xA1, |rng| {
            let op = match rng.below(4) {
                0 => CmOp::Queue,
                1 => CmOp::Dequeue,
                2 => CmOp::Process,
                _ => CmOp::Initialize,
            };
            let inst = CmInstruction {
                op,
                rm: rng.below(32) as u8,
                ra: rng.below(16) as u8,
                rn: rng.below(32) as u8,
                rd: rng.below(32) as u8,
            };
            assert_eq!(decode(encode(&inst)).unwrap(), inst);
        });
    }
}
