//! The ALPINE ISA extension (paper §IV.B, Fig. 3) and the micro-op cost
//! classes of the core timing model.
//!
//! The four CM_* instructions occupy previously-unused ARMv8 opcodes and
//! govern the core-private AIMC tile:
//!
//! | Op            | OpCode | Rm | R/W | Ra | Rn | Rd |
//! |---------------|--------|----|-----|----|----|----|
//! | CM_QUEUE      | 0x108  | Rm | 1   | Ra | Rn | Rd |
//! | CM_DEQUEUE    | 0x108  | Rm | 0   | X  | Rn | Rd |
//! | CM_PROCESS    | 0x008  | X  | 0   | X  | X  | Rd |
//! | CM_INITIALIZE | 0x208  | Rm | 0   | Ra | Rn | Rd |
//!
//! CM_QUEUE/CM_DEQUEUE move 4 packed int8 values per instruction through
//! a 32-bit argument register; Ra carries the count of valid packed
//! inputs, Rn the input/output-memory index, Rd the destination.

pub(crate) mod encoding;

pub use encoding::{decode, encode, CmInstruction, CmOp, DecodeError};

/// Micro-op classes of the in-order (MinorCPU-like) core model, with
/// their issue costs in cycles. These are the knobs the workload
/// generators use to express software cost (see workload::costs for the
/// per-primitive instruction-count models).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum InstClass {
    /// Integer ALU op (add/shift/compare/address math).
    IntAlu,
    /// Integer multiply.
    IntMul,
    /// Scalar FP op (the paper's sigmoid/tanh/softmax run in fp32).
    FpOp,
    /// 128-bit NEON op: int8 MAC (SDOT-style, 16 MACs/inst) or move.
    SimdOp,
    /// Load/store issue slot (cache timing handled separately).
    MemIssue,
    /// Branch (predicted; misprediction amortized into generator counts).
    Branch,
    /// CM_QUEUE / CM_DEQUEUE beat (4 bytes per instruction).
    CmIo,
    /// CM_PROCESS / CM_INITIALIZE issue.
    CmCtl,
}

impl InstClass {
    /// Issue cycles on the 4-stage in-order pipeline (dual-issue is not
    /// modeled; gem5-X Minor on A53-class cores sustains ~1 IPC on ALU
    /// streams, which this reproduces).
    pub fn cycles(&self) -> u64 {
        match self {
            InstClass::IntAlu => 1,
            InstClass::IntMul => 2,
            InstClass::FpOp => 3,
            InstClass::SimdOp => 1,
            InstClass::MemIssue => 1,
            InstClass::Branch => 1,
            InstClass::CmIo => 1,
            InstClass::CmCtl => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_costs_sane() {
        assert_eq!(InstClass::IntAlu.cycles(), 1);
        assert_eq!(InstClass::SimdOp.cycles(), 1);
        assert!(InstClass::FpOp.cycles() > InstClass::IntAlu.cycles());
    }
}
