//! Automatic mapping search over the `(LayerGraph, Mapping)` space.
//!
//! Given any linear-chain [`LayerGraph`] and a machine topology budget
//! (cores, tiles, tile dims, channels), the search enumerates candidate
//! [`Mapping`]s — digital vs. analog placement per layer, greedy
//! column-packing of MVM regions onto budget tiles, row-splitting of
//! tall matrices, column-replication across cores, 1..N-stage
//! pipelining, and ping-pong vs. shared-buffer hand-offs — prunes them
//! with the fast analytic cost model in [`cost`] (closed-form timing of
//! the real compiled traces), and returns the top candidates ranked by
//! estimated cycles (plus the most energy-efficient ones, so the
//! validated Pareto front sees both axes).
//!
//! Simulation of the surviving candidates lives in
//! `coordinator::automap`, which fans them out across the parallel
//! sweep engine and computes the Pareto front on *simulated*
//! (cycles, energy).
//!
//! Everything here is deterministic: enumeration order is fixed,
//! ranking breaks f64 ties on the candidate descriptor, and no
//! randomness is involved — so `--jobs N` cannot change the result.
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

pub mod cost;
mod enumerate;

pub use cost::{estimate, CostEstimate};

use crate::config::SystemConfig;
use crate::nn::LayerGraph;
use crate::workload::compile::mapping::{Handoff, Mapping};
use crate::workload::WorkloadError;
use enumerate::CandidateSpec;

/// The machine resources a mapping may claim.
#[derive(Clone, Copy, Debug)]
pub struct TopologyBudget {
    pub cores: usize,
    pub tiles: usize,
    pub tile_rows: u32,
    pub tile_cols: u32,
    /// Cap on compiled channel count (boundary fan-out x hand-off acks).
    pub channels: usize,
}

impl TopologyBudget {
    /// Budget matching a Table-I system: its cores and its physical
    /// crossbar dimensions, with generous tile/channel headroom.
    pub fn for_config(cfg: &SystemConfig) -> TopologyBudget {
        TopologyBudget {
            cores: cfg.num_cores,
            tiles: 16,
            tile_rows: cfg.aimc.tile_rows,
            tile_cols: cfg.aimc.tile_cols,
            channels: 64,
        }
    }
}

/// A surviving candidate: the concrete mapping plus its analytic cost.
pub struct Candidate {
    pub mapping: Mapping,
    /// Human-readable point in the search space, e.g. `"s2 r2 pp AD|DA"`.
    pub desc: String,
    pub est: CostEstimate,
}

/// Result of [`search`].
pub struct SearchOutcome {
    /// Specs enumerated (including budget-infeasible ones).
    pub enumerated: usize,
    /// Specs that produced a valid mapping under the budget.
    pub feasible: usize,
    /// The walk hit [`CANDIDATE_CAP`] (or the mask space was reduced).
    pub truncated: bool,
    /// Top candidates, sorted by estimated cycles (stable tie-break on
    /// the descriptor).
    pub ranked: Vec<Candidate>,
}

/// Hard cap on enumerated candidates — keeps degenerate budgets bounded.
pub const CANDIDATE_CAP: usize = 60_000;

/// Search the mapping space of `graph` under `budget`, returning the
/// `top_k` candidates by estimated cycles plus up to `top_k / 2`
/// energy-ranked extras (deduplicated).
pub fn search(
    graph: &LayerGraph,
    budget: &TopologyBudget,
    cfg: &SystemConfig,
    top_k: usize,
) -> Result<SearchOutcome, WorkloadError> {
    let (anchors, input, output) = enumerate::anchors(graph)?;
    let (specs, truncated) = enumerate::enumerate_specs(&anchors, budget, CANDIDATE_CAP);
    let enumerated = specs.len();

    struct Eval {
        spec_idx: usize,
        desc: String,
        est: CostEstimate,
    }
    let mut evals: Vec<Eval> = Vec::new();
    for (spec_idx, spec) in specs.iter().enumerate() {
        let Some((mapping, desc)) = enumerate::build_mapping(graph, &anchors, input, output, spec, budget)
        else {
            continue;
        };
        match cost::estimate(graph, &mapping, cfg) {
            Ok(est) => evals.push(Eval { spec_idx, desc, est }),
            Err(e) => {
                debug_assert!(false, "automap built an uncompilable mapping ({desc}): {e}");
            }
        }
    }
    let feasible = evals.len();

    let mut by_cycles: Vec<usize> = (0..evals.len()).collect();
    by_cycles.sort_by(|&a, &b| {
        evals[a]
            .est
            .cycles_per_inf
            .total_cmp(&evals[b].est.cycles_per_inf)
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });
    let mut selected: Vec<usize> = by_cycles.iter().copied().take(top_k).collect();
    let mut by_energy: Vec<usize> = (0..evals.len()).collect();
    by_energy.sort_by(|&a, &b| {
        evals[a]
            .est
            .energy_per_inf_j
            .total_cmp(&evals[b].est.energy_per_inf_j)
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });
    for &i in &by_energy {
        if selected.len() >= top_k + top_k.div_ceil(2) {
            break;
        }
        if !selected.contains(&i) {
            selected.push(i);
        }
    }

    // Rebuild only the winners' mappings; their estimates are reused.
    let mut ranked: Vec<Candidate> = Vec::with_capacity(selected.len());
    for &i in &selected {
        let spec = &specs[evals[i].spec_idx];
        let (mapping, desc) = enumerate::build_mapping(graph, &anchors, input, output, spec, budget)
            .expect("spec was feasible on the first build");
        ranked.push(Candidate { mapping, desc, est: evals[i].est.clone() });
    }
    ranked.sort_by(|a, b| {
        a.est
            .cycles_per_inf
            .total_cmp(&b.est.cycles_per_inf)
            .then_with(|| a.desc.cmp(&b.desc))
    });
    Ok(SearchOutcome { enumerated, feasible, truncated, ranked })
}

/// The naive all-digital single-core mapping — the acceptance baseline
/// every searched mapping is compared against.
pub fn digital_baseline(graph: &LayerGraph) -> Result<(Mapping, String), WorkloadError> {
    let (anchors, input, output) = enumerate::anchors(graph)?;
    let spec = CandidateSpec {
        starts: vec![0],
        analog_mask: 0,
        replicas: 1,
        handoff: Handoff::PingPong,
    };
    let budget = TopologyBudget { cores: 1, tiles: 0, tile_rows: 1, tile_cols: 1, channels: 0 };
    enumerate::build_mapping(graph, &anchors, input, output, &spec, &budget)
        .ok_or_else(|| WorkloadError::InvalidMapping("failed to build the all-digital baseline".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::compile;

    fn hp() -> SystemConfig {
        SystemConfig::high_power()
    }

    #[test]
    fn search_ranks_analog_first_on_a_small_mlp() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 6).unwrap();
        assert!(out.feasible > 8, "space too small: {}", out.feasible);
        assert!(!out.ranked.is_empty());
        // The fastest estimate puts every layer on AIMC.
        assert!(out.ranked[0].desc.contains('A'), "{}", out.ranked[0].desc);
        assert!(!out.truncated);
        // Every ranked candidate compiles.
        for c in &out.ranked {
            compile::compile(&g, &c.mapping, 1).unwrap();
        }
    }

    #[test]
    fn search_is_deterministic() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 128, tile_cols: 256, channels: 32 };
        let a = search(&g, &budget, &hp(), 5).unwrap();
        let b = search(&g, &budget, &hp(), 5).unwrap();
        assert_eq!(a.enumerated, b.enumerated);
        assert_eq!(a.feasible, b.feasible);
        let descs = |o: &SearchOutcome| o.ranked.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(descs(&a), descs(&b));
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.est.cycles_per_inf.to_bits(), y.est.cycles_per_inf.to_bits());
        }
    }

    #[test]
    fn tight_tile_budget_prunes_analog_candidates() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let roomy = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let cramped = TopologyBudget { cores: 4, tiles: 0, tile_rows: 256, tile_cols: 256, channels: 32 };
        let a = search(&g, &roomy, &hp(), 4).unwrap();
        let b = search(&g, &cramped, &hp(), 4).unwrap();
        assert!(b.feasible < a.feasible);
        // With zero tiles only all-digital mappings survive.
        assert!(b.ranked.iter().all(|c| !c.desc.contains('A')));
    }

    #[test]
    fn wide_layers_need_column_replication_for_analog() {
        // 128x512 dense: 512 output columns exceed a 256-wide tile, so
        // analog placement is only reachable through a 2-way column
        // split (256 per replica) — the search must find it.
        let g = LayerGraph::mlp(&[128, 512]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 8).unwrap();
        let analog: Vec<&Candidate> = out.ranked.iter().filter(|c| c.desc.contains('A')).collect();
        assert!(!analog.is_empty(), "no analog candidate found");
        assert!(analog.iter().all(|c| !c.desc.contains("r1")), "analog requires replication here");
    }

    #[test]
    fn baseline_is_single_core_all_digital() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let (m, desc) = digital_baseline(&g).unwrap();
        assert_eq!(m.stages.len(), 1);
        assert_eq!(m.stages[0].cores, vec![0]);
        assert!(m.tiles.is_empty());
        assert!(desc.starts_with("s1 r1 pp"));
        compile::compile(&g, &m, 2).unwrap();
    }

    #[test]
    fn rejects_conv_pipelines_cleanly() {
        let g = LayerGraph::cnn(&crate::nn::CnnModel::paper(crate::nn::CnnVariant::Fast));
        let budget = TopologyBudget::for_config(&hp());
        assert!(matches!(
            search(&g, &budget, &hp(), 4),
            Err(WorkloadError::InvalidGraph(_))
        ));
    }
}
