//! Automatic mapping search over the `(LayerGraph, Mapping)` space.
//!
//! Given any validated [`LayerGraph`] — linear chain or fork/join DAG
//! (residual blocks, parallel attention heads, MoE expert banks) — and
//! a machine topology budget (cores, tiles, tile dims, channels), the
//! search walks candidate mappings — digital vs. analog placement per
//! layer, greedy column-packing of MVM regions onto budget tiles,
//! row-splitting of tall matrices, column-replication across cores
//! (1/2/4/8, chain dataflow only; on an MoE chain the replica axis
//! doubles as expert parallelism), 1..8-stage pipelining over the
//! topologically linearized anchor list (branches cut into different
//! stages run concurrently on their own cores), and ping-pong vs.
//! shared-buffer hand-offs — scores them
//! with the **compositional cost engine** in [`cost`] (per-anchor stage
//! profiles compiled once per search, composed per candidate; the
//! full-compile estimator survives behind [`CostModel::Compiled`] as
//! the oracle), and returns the top candidates ranked by estimated
//! cycles plus the estimated-(cycles, energy) Pareto front.
//!
//! Enumeration is **lazy branch-and-bound**: partition subtrees carry
//! admissible per-partition and per-engine-mask cycle lower bounds, and
//! a subtree is skipped once it provably cannot reach the top-k (by
//! cycles or energy) nor the incrementally maintained Pareto front —
//! so the space needs no hard candidate cap (the old 60k
//! `CANDIDATE_CAP` is gone; `SearchOptions::cap` restores the legacy
//! collect-then-cap walk for bounded exploration and as the exhaustive
//! reference in tests). The one residual bound is combinatorial: past
//! `MAX_PARTITIONS` pipeline partitions (chains of ~30+ anchors at
//! depth 8) the partition axis keeps its canonical prefix and the
//! outcome reports `truncated`. Subtrees fan out across the same worker pool as
//! the sweep engine (`util::parallel`); each chunk of consecutive
//! partitions prunes against its own deterministic local state, so the
//! merged result is bit-identical to the serial walk at any `--jobs N`.
//!
//! Pruning is *exact*, not heuristic: a candidate is only skipped when
//! an admissible lower bound proves it cannot enter the result, so the
//! pruned search returns exactly the same ranked list and Pareto front
//! as exhaustive scoring (gated by `tests/automap.rs`).
//!
//! Simulation of the surviving candidates lives in
//! `coordinator::automap`, which fans them out across the parallel
//! sweep engine and computes the Pareto front on *simulated*
//! (cycles, energy).
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

pub mod cost;
mod enumerate;

pub use cost::{estimate, CostEstimate};

use crate::config::SystemConfig;
use crate::nn::LayerGraph;
use crate::util::parallel;
use crate::workload::compile::cache::{CompileCache, CompileCacheStats};
use crate::workload::compile::mapping::{Handoff, Mapping, Place};
use crate::workload::WorkloadError;
use enumerate::{Anchor, CandidateSpec};
use std::sync::Mutex;

/// The machine resources a mapping may claim.
#[derive(Clone, Copy, Debug)]
pub struct TopologyBudget {
    pub cores: usize,
    pub tiles: usize,
    pub tile_rows: u32,
    pub tile_cols: u32,
    /// Cap on compiled channel count (boundary fan-out x hand-off acks).
    pub channels: usize,
}

impl TopologyBudget {
    /// Budget matching a Table-I system: its cores and its physical
    /// crossbar dimensions, with generous tile/channel headroom.
    pub fn for_config(cfg: &SystemConfig) -> TopologyBudget {
        TopologyBudget {
            cores: cfg.num_cores,
            tiles: 16,
            tile_rows: cfg.aimc.tile_rows,
            tile_cols: cfg.aimc.tile_cols,
            channels: 64,
        }
    }
}

/// Which cost engine scores candidates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostModel {
    /// Compose cached per-anchor profiles — O(1) compiles per
    /// candidate; the default.
    Compositional,
    /// Compile every candidate's full trace and walk it — the oracle
    /// the compositional engine is gated against.
    Compiled,
}

/// Search knobs. `Default` gives the full production search:
/// compositional scoring, branch-and-bound (no cap), pipeline depth up
/// to 8, replication up to 8, serial walk.
#[derive(Clone, Debug)]
pub struct SearchOptions {
    /// Candidates returned by estimated cycles (plus up to `top_k / 2`
    /// energy-ranked extras).
    pub top_k: usize,
    pub model: CostModel,
    /// `Some(n)`: legacy collect-then-cap walk — enumerate at most `n`
    /// candidates in canonical order, score all of them, no pruning
    /// (this is also the exhaustive reference the pruned walk is gated
    /// against). `None`: lazy branch-and-bound over the whole space.
    pub cap: Option<usize>,
    /// Deepest pipeline partition to try (clamped to cores and anchors).
    pub max_depth: usize,
    /// Largest column-replication factor to try (of {1, 2, 4, 8}).
    pub max_replica: usize,
    /// Worker threads for the partition-subtree fan-out.
    pub jobs: usize,
    /// Share lowered step fragments across `Compiled`-oracle candidate
    /// compiles (keyed by anchor/engine/replication/alias shape). Scores
    /// are bit-identical either way; off only costs time. Ignored under
    /// `Compositional` scoring.
    pub compile_cache: bool,
}

impl Default for SearchOptions {
    fn default() -> SearchOptions {
        SearchOptions {
            top_k: 8,
            model: CostModel::Compositional,
            cap: None,
            max_depth: 8,
            max_replica: 8,
            jobs: 1,
            compile_cache: true,
        }
    }
}

/// A surviving candidate: the concrete mapping plus its analytic cost.
pub struct Candidate {
    pub mapping: Mapping,
    /// Human-readable point in the search space, e.g. `"s2 r2 pp AD|DA"`.
    pub desc: String,
    pub est: CostEstimate,
}

/// One point of the estimated Pareto front. Deliberately mapping-free:
/// front members outside the ranked list are reported, not simulated,
/// so rebuilding their full `Mapping`s would be discarded work.
pub struct FrontPoint {
    pub desc: String,
    pub est: CostEstimate,
}

/// Result of [`search`].
pub struct SearchOutcome {
    /// Candidate points visited, including pruned subtrees (the full
    /// space size when uncapped).
    pub enumerated: usize,
    /// Candidates skipped by branch-and-bound lower bounds.
    pub pruned: usize,
    /// Scored candidates that produced a valid mapping under the budget.
    pub feasible: usize,
    /// The space was not fully covered: the walk hit
    /// `SearchOptions::cap`, the engine-mask axis was reduced to its
    /// extremes (> 12 MVM anchors), or the partition axis hit the
    /// `MAX_PARTITIONS` materialization bound (very deep chains).
    pub truncated: bool,
    /// Top candidates, sorted by estimated cycles (stable tie-break on
    /// the descriptor).
    pub ranked: Vec<Candidate>,
    /// The Pareto front on estimated (cycles, energy) over the whole
    /// feasible space, sorted by cycles.
    pub front: Vec<FrontPoint>,
    /// Compile-cache counters of the `Compiled`-oracle walk (`None`
    /// under compositional scoring or with the cache disabled).
    /// Excluded from outcome-identity comparisons: hit/miss split
    /// depends on thread interleaving even though scores do not.
    pub cache: Option<CompileCacheStats>,
}

/// Search with the default options (compositional branch-and-bound over
/// the full space) at the given `top_k`.
pub fn search(
    graph: &LayerGraph,
    budget: &TopologyBudget,
    cfg: &SystemConfig,
    top_k: usize,
) -> Result<SearchOutcome, WorkloadError> {
    search_opts(graph, budget, cfg, &SearchOptions { top_k, ..SearchOptions::default() })
}

/// One scored point of the space, light enough to keep in the pruning
/// state (the full `Mapping` is rebuilt for winners only).
struct Scored {
    spec: CandidateSpec,
    desc: String,
    est: CostEstimate,
}

impl Scored {
    fn cycles(&self) -> f64 {
        self.est.cycles_per_inf
    }

    fn energy(&self) -> f64 {
        self.est.energy_per_inf_j
    }
}

fn strictly_dominates(ac: f64, ae: f64, bc: f64, be: f64) -> bool {
    ac <= bc && ae <= be && (ac < bc || ae < be)
}

/// The incrementally maintained result state of one walk: best `top_k`
/// by cycles, best `top_k + ceil(top_k/2)` by energy (the most the
/// final selection can ever consume), and the (cycles, energy) Pareto
/// front. Everything outside these sets provably cannot appear in the
/// search outcome, which is what makes bound pruning exact.
struct Keeper {
    top_k: usize,
    n_en: usize,
    items: Vec<Scored>,
    by_cyc: Vec<usize>,
    by_en: Vec<usize>,
    front: Vec<usize>,
}

impl Keeper {
    fn new(top_k: usize) -> Keeper {
        Keeper {
            top_k,
            n_en: top_k + top_k.div_ceil(2),
            items: Vec::new(),
            by_cyc: Vec::new(),
            by_en: Vec::new(),
            front: Vec::new(),
        }
    }

    /// Worst kept cycles, once the cycles list is full (`None` before).
    fn cyc_bound(&self) -> Option<f64> {
        if self.top_k == 0 {
            return Some(f64::NEG_INFINITY);
        }
        (self.by_cyc.len() >= self.top_k).then(|| self.items[self.by_cyc[self.top_k - 1]].cycles())
    }

    fn en_bound(&self) -> Option<f64> {
        if self.n_en == 0 {
            return Some(f64::NEG_INFINITY);
        }
        (self.by_en.len() >= self.n_en).then(|| self.items[self.by_en[self.n_en - 1]].energy())
    }

    /// May every candidate with cycles >= `clb` and energy >= `elb` be
    /// skipped? True only when the bound proves it cannot enter the
    /// cycles top-k (strictly worse than the kth — ties may still win
    /// on the descriptor tie-break), cannot enter the energy keep, and
    /// is strictly dominated on the front corner by a kept or seed
    /// point (strictness makes exact front ties survive).
    fn can_prune(&self, seeds: &[(f64, f64)], clb: f64, elb: f64) -> bool {
        let Some(cb) = self.cyc_bound() else { return false };
        if clb <= cb {
            return false;
        }
        let Some(eb) = self.en_bound() else { return false };
        if elb <= eb {
            return false;
        }
        self.front
            .iter()
            .map(|&i| (self.items[i].cycles(), self.items[i].energy()))
            .chain(seeds.iter().copied())
            .any(|(c, e)| strictly_dominates(c, e, clb, elb))
    }

    fn offer(&mut self, s: Scored) {
        let cyc_less = |a: &Scored, b: &Scored| {
            a.cycles().total_cmp(&b.cycles()).then_with(|| a.desc.cmp(&b.desc)) == std::cmp::Ordering::Less
        };
        let en_less = |a: &Scored, b: &Scored| {
            a.energy().total_cmp(&b.energy()).then_with(|| a.desc.cmp(&b.desc)) == std::cmp::Ordering::Less
        };
        let want_cyc = self.top_k > 0
            && (self.by_cyc.len() < self.top_k
                || cyc_less(&s, &self.items[*self.by_cyc.last().expect("non-empty")]));
        let want_en = self.n_en > 0
            && (self.by_en.len() < self.n_en
                || en_less(&s, &self.items[*self.by_en.last().expect("non-empty")]));
        let want_front = !self
            .front
            .iter()
            .any(|&i| strictly_dominates(self.items[i].cycles(), self.items[i].energy(), s.cycles(), s.energy()));
        if !(want_cyc || want_en || want_front) {
            return;
        }
        self.items.push(s);
        let idx = self.items.len() - 1;
        if want_cyc {
            let pos = self.by_cyc.partition_point(|&i| cyc_less(&self.items[i], &self.items[idx]));
            self.by_cyc.insert(pos, idx);
            self.by_cyc.truncate(self.top_k);
        }
        if want_en {
            let pos = self.by_en.partition_point(|&i| en_less(&self.items[i], &self.items[idx]));
            self.by_en.insert(pos, idx);
            self.by_en.truncate(self.n_en);
        }
        if want_front {
            let (c, e) = (self.items[idx].cycles(), self.items[idx].energy());
            self.front.retain(|&i| {
                !strictly_dominates(c, e, self.items[i].cycles(), self.items[i].energy())
            });
            self.front.push(idx);
        }
        self.maybe_compact();
    }

    /// Drop items evicted from every list so memory stays proportional
    /// to the live result state, not to the number of improving offers.
    fn maybe_compact(&mut self) {
        let live = self.by_cyc.len() + self.by_en.len() + self.front.len();
        if self.items.len() < 256 || self.items.len() < 3 * live {
            return;
        }
        let mut alive = vec![false; self.items.len()];
        for &i in self.by_cyc.iter().chain(&self.by_en).chain(&self.front) {
            alive[i] = true;
        }
        let mut remap = vec![usize::MAX; self.items.len()];
        let mut items = Vec::with_capacity(live);
        for (old, s) in std::mem::take(&mut self.items).into_iter().enumerate() {
            if alive[old] {
                remap[old] = items.len();
                items.push(s);
            }
        }
        self.items = items;
        for list in [&mut self.by_cyc, &mut self.by_en, &mut self.front] {
            for i in list.iter_mut() {
                *i = remap[*i];
            }
        }
    }

    /// All live kept candidates (union of the three lists), deduplicated,
    /// in item-insertion order.
    fn into_kept(self) -> Vec<Scored> {
        let mut keep: Vec<usize> = self
            .by_cyc
            .iter()
            .chain(&self.by_en)
            .chain(&self.front)
            .copied()
            .collect();
        keep.sort_unstable();
        keep.dedup();
        let mut slots: Vec<Option<Scored>> = self.items.into_iter().map(Some).collect();
        keep.into_iter()
            .map(|i| slots[i].take().expect("kept index is live"))
            .collect()
    }
}

/// Result of one walked chunk of partition subtrees.
struct SubResult {
    kept: Vec<Scored>,
    enumerated: usize,
    pruned: usize,
    feasible: usize,
    truncated: bool,
}

/// Walk a chunk of consecutive partitions in canonical order. With
/// `bounds`, subtrees and engine-mask groups are pruned against the
/// chunk-local keeper + the global seed points (deterministic: the
/// chunk's decisions depend only on its own inputs). With `cap`, the
/// walk is the legacy exhaustive one and stops after `cap` candidates.
#[allow(clippy::too_many_arguments)]
fn walk_chunk<F>(
    chunk: &[Vec<usize>],
    masks: &[u64],
    replica_opts: &[usize],
    top_k: usize,
    seeds: &[(f64, f64)],
    bounds: Option<(&cost::CostEngine, &[Anchor], &[Option<usize>])>,
    score: &F,
    cap: Option<usize>,
) -> SubResult
where
    F: Fn(&CandidateSpec) -> Option<(String, CostEstimate)>,
{
    let mut keeper = Keeper::new(top_k);
    let (mut enumerated, mut pruned, mut feasible) = (0usize, 0usize, 0usize);
    let mut truncated = false;
    'outer: for starts in chunk {
        let s = starts.len();
        let handoffs: &[Handoff] =
            if s == 1 { &[Handoff::PingPong] } else { &[Handoff::PingPong, Handoff::SharedBuffer] };
        let per_mask = replica_opts.len() * handoffs.len();
        if cap.is_none() {
            if let Some((eng, anchors, _)) = bounds {
                let plb = eng.partition_lower_bound(anchors, starts);
                if keeper.can_prune(seeds, plb, eng.energy_floor(plb)) {
                    enumerated += masks.len() * per_mask;
                    pruned += masks.len() * per_mask;
                    continue;
                }
            }
        }
        // One reusable spec per partition: the inner loops only flip its
        // scalar axes, and an owned copy is made just for the (rare)
        // candidates the keeper actually retains.
        let mut spec = CandidateSpec {
            starts: starts.clone(),
            analog_mask: 0,
            replicas: 1,
            handoff: Handoff::PingPong,
        };
        for &mask in masks {
            if cap.is_none() {
                if let Some((eng, anchors, mvm_index)) = bounds {
                    let mlb = eng.mask_lower_bound(anchors, mvm_index, starts, mask);
                    if keeper.can_prune(seeds, mlb, eng.energy_floor(mlb)) {
                        enumerated += per_mask;
                        pruned += per_mask;
                        continue;
                    }
                }
            }
            for &r in replica_opts {
                for &h in handoffs {
                    if let Some(c) = cap {
                        if enumerated >= c {
                            truncated = true;
                            break 'outer;
                        }
                    }
                    enumerated += 1;
                    spec.analog_mask = mask;
                    spec.replicas = r;
                    spec.handoff = h;
                    if let Some((desc, est)) = score(&spec) {
                        feasible += 1;
                        keeper.offer(Scored { spec: spec.clone(), desc, est });
                    }
                }
            }
        }
    }
    SubResult { kept: keeper.into_kept(), enumerated, pruned, feasible, truncated }
}

/// Search the mapping space of `graph` under `budget` with explicit
/// [`SearchOptions`].
pub fn search_opts(
    graph: &LayerGraph,
    budget: &TopologyBudget,
    cfg: &SystemConfig,
    opts: &SearchOptions,
) -> Result<SearchOutcome, WorkloadError> {
    let (anchors, input, output) = enumerate::anchors(graph)?;
    let n = anchors.len();
    let m = anchors.iter().filter(|a| a.mvm.is_some()).count();
    let (masks, reduced_masks) = enumerate::engine_masks(m);
    let replica_opts: Vec<usize> = [1usize, 2, 4, 8]
        .iter()
        .copied()
        .filter(|&r| r <= budget.cores && r <= opts.max_replica.max(1))
        .collect();
    // Column replication is defined on chain anchor dataflow only
    // (`stage_layout` rejects every r > 1 point otherwise), so skip
    // enumerating — and profiling — the axis for fork/join graphs.
    let replica_opts = if enumerate::anchor_dag(graph, &anchors, input).chain {
        replica_opts
    } else {
        vec![1]
    };
    let max_stages = opts.max_depth.max(1).min(budget.cores).min(n.max(1));
    // A capped walk touches at most `cap` partitions (each yields >= 1
    // candidate), so don't materialize cut lists past the cap.
    let (parts_list, parts_truncated) =
        enumerate::partitions(n, max_stages, opts.cap.unwrap_or(usize::MAX));
    let mvm_index: Vec<Option<usize>> = {
        let mut k = 0usize;
        anchors
            .iter()
            .map(|a| {
                a.mvm.as_ref().map(|_| {
                    let i = k;
                    k += 1;
                    i
                })
            })
            .collect()
    };

    let engine = match opts.model {
        CostModel::Compositional => Some(cost::CostEngine::new(
            graph,
            &anchors,
            input,
            output,
            budget,
            cfg,
            &replica_opts,
        )),
        CostModel::Compiled => None,
    };
    // One fragment cache shared by every `Compiled`-oracle candidate
    // compile in this search (fragments are keyed candidate-
    // independently, so the cache is safe — and hot — across the whole
    // space and across worker threads). When disabled, each `estimate`
    // call uses its own throwaway cache internally: same walk, no
    // sharing, so the arena cannot grow with the space.
    let cache = (opts.model == CostModel::Compiled && opts.compile_cache)
        .then(|| Mutex::new(CompileCache::new(true)));
    let score = |spec: &CandidateSpec| -> Option<(String, CostEstimate)> {
        match &engine {
            Some(eng) => {
                let est = eng.score(&anchors, spec)?;
                Some((enumerate::spec_desc(&anchors, spec), est))
            }
            None => {
                let (mapping, desc) = enumerate::build_mapping(graph, &anchors, input, output, spec, budget)?;
                let est = match &cache {
                    Some(c) => cost::estimate_with(graph, &mapping, cfg, c),
                    None => cost::estimate(graph, &mapping, cfg),
                };
                match est {
                    Ok(est) => Some((desc, est)),
                    Err(e) => {
                        debug_assert!(false, "automap built an uncompilable mapping ({desc}): {e}");
                        None
                    }
                }
            }
        }
    };

    #[derive(Default)]
    struct Merged {
        enumerated: usize,
        pruned: usize,
        feasible: usize,
        truncated: bool,
        evals: Vec<Scored>,
    }
    let fold = |mut acc: Merged, r: SubResult| -> Merged {
        acc.enumerated += r.enumerated;
        acc.pruned += r.pruned;
        acc.feasible += r.feasible;
        acc.truncated |= r.truncated;
        acc.evals.extend(r.kept);
        acc
    };

    let merged: Merged = if let Some(cap) = opts.cap {
        // Legacy exhaustive-capped walk: serial, unpruned, canonical
        // order — the reference the branch-and-bound walk is gated
        // against.
        fold(
            Merged::default(),
            walk_chunk(&parts_list, &masks, &replica_opts, opts.top_k, &[], None, &score, Some(cap)),
        )
    } else {
        // Seed the chunk-local pruners with the single-stage extremes so
        // even the first subtrees can discard dominated regions.
        let seeds: Vec<(f64, f64)> = match &engine {
            Some(eng) => {
                let mut seed_specs = vec![CandidateSpec {
                    starts: vec![0],
                    analog_mask: 0,
                    replicas: 1,
                    handoff: Handoff::PingPong,
                }];
                if let Some(&all) = masks.last() {
                    if all != 0 {
                        seed_specs.push(CandidateSpec {
                            starts: vec![0],
                            analog_mask: all,
                            replicas: 1,
                            handoff: Handoff::PingPong,
                        });
                    }
                }
                seed_specs
                    .iter()
                    .filter_map(|s| eng.score(&anchors, s))
                    .map(|e| (e.cycles_per_inf, e.energy_per_inf_j))
                    .collect()
            }
            None => Vec::new(),
        };
        let bounds = engine
            .as_ref()
            .map(|e| (e, anchors.as_slice(), mvm_index.as_slice()));
        // Fixed-size chunking (independent of the worker count) keeps
        // the per-chunk pruning decisions — and therefore every counter
        // — bit-identical at any `--jobs N`.
        let chunk = parts_list.len().div_ceil(64).max(1);
        let tasks: Vec<&[Vec<usize>]> = parts_list.chunks(chunk).collect();
        parallel::parallel_reduce(
            tasks,
            opts.jobs,
            Merged::default(),
            |task| walk_chunk(task, &masks, &replica_opts, opts.top_k, &seeds, bounds, &score, None),
            fold,
        )
    };
    let Merged { enumerated, pruned, feasible, truncated, evals } = merged;
    let truncated = truncated || reduced_masks || parts_truncated;

    // Exact final selection over the union of kept candidates — the
    // same rule the collect-everything walk used, so pruning is
    // outcome-invisible: top_k by cycles, then energy-ranked extras.
    let mut by_cycles: Vec<usize> = (0..evals.len()).collect();
    by_cycles.sort_by(|&a, &b| {
        evals[a]
            .cycles()
            .total_cmp(&evals[b].cycles())
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });
    let mut selected: Vec<usize> = by_cycles.iter().copied().take(opts.top_k).collect();
    let mut by_energy: Vec<usize> = (0..evals.len()).collect();
    by_energy.sort_by(|&a, &b| {
        evals[a]
            .energy()
            .total_cmp(&evals[b].energy())
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });
    for &i in &by_energy {
        if selected.len() >= opts.top_k + opts.top_k.div_ceil(2) {
            break;
        }
        if !selected.contains(&i) {
            selected.push(i);
        }
    }
    // Pareto front by sorted sweep (O(n log n), not pairwise O(n^2)):
    // walk cycles-ascending groups of equal cycles; a group's min-energy
    // points survive iff they beat the best energy of every strictly
    // faster candidate (ties on both axes are non-dominated and all
    // kept — the same strict-dominance rule the simulated front uses).
    let mut order: Vec<usize> = (0..evals.len()).collect();
    order.sort_by(|&a, &b| {
        evals[a]
            .cycles()
            .total_cmp(&evals[b].cycles())
            .then_with(|| evals[a].energy().total_cmp(&evals[b].energy()))
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });
    let mut front_idx: Vec<usize> = Vec::new();
    let mut best_energy = f64::INFINITY;
    let mut i = 0usize;
    while i < order.len() {
        let mut j = i;
        while j < order.len()
            && evals[order[j]].cycles().total_cmp(&evals[order[i]].cycles()).is_eq()
        {
            j += 1;
        }
        let group_min = evals[order[i]].energy();
        if group_min < best_energy {
            for &idx in &order[i..j] {
                if evals[idx].energy().total_cmp(&group_min).is_eq() {
                    front_idx.push(idx);
                } else {
                    break;
                }
            }
            best_energy = group_min;
        }
        i = j;
    }
    front_idx.sort_by(|&a, &b| {
        evals[a]
            .cycles()
            .total_cmp(&evals[b].cycles())
            .then_with(|| evals[a].desc.cmp(&evals[b].desc))
    });

    // Rebuild only the winners' mappings; their estimates are reused.
    let build = |i: usize| -> Candidate {
        let (mapping, desc) = enumerate::build_mapping(graph, &anchors, input, output, &evals[i].spec, budget)
            .expect("spec was feasible when scored");
        debug_assert_eq!(desc, evals[i].desc);
        Candidate { mapping, desc, est: evals[i].est.clone() }
    };
    let mut ranked: Vec<Candidate> = selected.iter().map(|&i| build(i)).collect();
    ranked.sort_by(|a, b| {
        a.est
            .cycles_per_inf
            .total_cmp(&b.est.cycles_per_inf)
            .then_with(|| a.desc.cmp(&b.desc))
    });
    let front: Vec<FrontPoint> = front_idx
        .iter()
        .map(|&i| FrontPoint { desc: evals[i].desc.clone(), est: evals[i].est.clone() })
        .collect();

    let cache_stats =
        cache.map(|c| c.into_inner().expect("compile cache poisoned").stats());
    Ok(SearchOutcome { enumerated, pruned, feasible, truncated, ranked, front, cache: cache_stats })
}

/// The naive all-digital single-core mapping — the acceptance baseline
/// every searched mapping is compared against.
pub fn digital_baseline(graph: &LayerGraph) -> Result<(Mapping, String), WorkloadError> {
    let (anchors, input, output) = enumerate::anchors(graph)?;
    let spec = CandidateSpec {
        starts: vec![0],
        analog_mask: 0,
        replicas: 1,
        handoff: Handoff::PingPong,
    };
    let budget = TopologyBudget { cores: 1, tiles: 0, tile_rows: 1, tile_cols: 1, channels: 0 };
    enumerate::build_mapping(graph, &anchors, input, output, &spec, &budget)
        .ok_or_else(|| WorkloadError::InvalidMapping("failed to build the all-digital baseline".into()))
}

/// Result of the graceful-degradation pass: the rebuilt mapping after a
/// hard tile failure, with every MVM anchor that had a region on the
/// failed tile moved to digital CPU fallback.
pub struct Degraded {
    pub mapping: Mapping,
    /// Descriptor of the degraded point of the space (same format as
    /// [`Candidate::desc`]).
    pub desc: String,
    /// Chain-order indices of the MVM anchors remapped off the tile.
    pub remapped_anchors: Vec<usize>,
}

/// All tile indices a step's placement touches (empty for digital).
fn place_tiles(place: &Place) -> Vec<usize> {
    match place {
        Place::Cpu | Place::Fused => Vec::new(),
        Place::Tile { per_replica } => per_replica.iter().map(|t| t.tile).collect(),
        Place::TileRowSplit { tiles } | Place::TileChain { tiles } => {
            tiles.iter().map(|t| t.tile).collect()
        }
        Place::AttentionTiles { q, k, v, o } => vec![q.tile, k.tile, v.tile, o.tile],
    }
}

/// Graceful degradation after a hard tile failure: reconstruct the
/// search-space point of `mapping` (stage cuts, engine mask,
/// replication, hand-off), clear the engine bit of every MVM anchor
/// with a region on `failed_tile`, and rebuild the mapping through the
/// same constructor the search uses — so the surviving analog anchors
/// are repacked onto the remaining (logical) tiles and the failed
/// anchors lower to the digital CPU path. Deterministic; errors cleanly
/// when `mapping` is not an automap-style chain mapping or the tile
/// hosts no analog region.
///
/// `budget` must be the topology budget the mapping was built under
/// (its tile geometry governs how the survivors repack).
pub fn degrade_mapping(
    graph: &LayerGraph,
    mapping: &Mapping,
    failed_tile: usize,
    budget: &TopologyBudget,
) -> Result<Degraded, WorkloadError> {
    degrade_mapping_multi(graph, mapping, &[failed_tile], budget)
}

/// [`degrade_mapping`] generalized to **multiple / cascading** tile
/// failures: the rebuild iterates over every failed tile, accumulating
/// the union of MVM anchors that had a region on *any* of them, then
/// rebuilds once with the whole union lowered to the digital CPU path.
/// Tile indices refer to the original `mapping`'s tile numbering (a
/// cascade observed against an already-degraded mapping is expressed by
/// listing all tiles failed so far). A tile that hosts nothing is fine
/// as long as the union is non-empty — under cascading failures the
/// later casualties may hit tiles the first rebuild already vacated.
pub fn degrade_mapping_multi(
    graph: &LayerGraph,
    mapping: &Mapping,
    failed_tiles: &[usize],
    budget: &TopologyBudget,
) -> Result<Degraded, WorkloadError> {
    let bad = |msg: String| WorkloadError::InvalidMapping(msg);
    if failed_tiles.is_empty() {
        return Err(bad(format!("no failed tiles given for mapping {}", mapping.label)));
    }
    let (anchors, input, output) = enumerate::anchors(graph)?;

    // Where did the original mapping put every node?
    let mut node_stage: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    let mut node_place: Vec<Option<&Place>> = vec![None; graph.nodes.len()];
    for (si, st) in mapping.stages.iter().enumerate() {
        for step in &st.steps {
            if step.node >= node_stage.len() {
                return Err(bad(format!("mapping {} places unknown node {}", mapping.label, step.node)));
            }
            node_stage[step.node] = Some(si);
            node_place[step.node] = Some(&step.place);
        }
    }

    // Stage cuts: anchors must cover the stages contiguously in order.
    let mut starts: Vec<usize> = Vec::new();
    let mut prev_stage: Option<usize> = None;
    for (ai, a) in anchors.iter().enumerate() {
        let first = a.nodes[0];
        let si = node_stage[first]
            .ok_or_else(|| bad(format!("mapping {} does not place node {first}", mapping.label)))?;
        match prev_stage {
            None if si == 0 => starts.push(ai),
            Some(p) if si == p => {}
            Some(p) if si == p + 1 => starts.push(ai),
            _ => {
                return Err(bad(format!(
                    "mapping {} is not a contiguous automap pipeline (anchor {ai} lands on stage {si})",
                    mapping.label
                )));
            }
        }
        prev_stage = Some(si);
    }
    if starts.len() != mapping.stages.len() {
        return Err(bad(format!(
            "mapping {} has {} stages but its anchors span {}",
            mapping.label,
            mapping.stages.len(),
            starts.len()
        )));
    }

    // Engine mask, minus everything that lived on the failed tile.
    let mut analog_mask = 0u64;
    let mut remapped_anchors: Vec<usize> = Vec::new();
    let mut mvm_idx = 0usize;
    for a in &anchors {
        let Some(m) = a.mvm else { continue };
        let place = node_place[m.node()]
            .ok_or_else(|| bad(format!("mapping {} does not place MVM node {}", mapping.label, m.node())))?;
        let tiles = place_tiles(place);
        if !tiles.is_empty() {
            if tiles.iter().any(|t| failed_tiles.contains(t)) {
                remapped_anchors.push(mvm_idx);
            } else if mvm_idx < 64 {
                analog_mask |= 1 << mvm_idx;
            }
        }
        mvm_idx += 1;
    }
    if remapped_anchors.is_empty() {
        return Err(match failed_tiles {
            [t] => bad(format!("tile {t} hosts no analog region of mapping {}", mapping.label)),
            ts => bad(format!(
                "tiles {ts:?} host no analog region of mapping {}",
                mapping.label
            )),
        });
    }

    let spec = CandidateSpec {
        starts,
        analog_mask,
        replicas: mapping.stages.iter().map(|s| s.cores.len()).max().unwrap_or(1),
        handoff: mapping.stages.first().map(|s| s.handoff).unwrap_or(Handoff::PingPong),
    };
    let (mapping, desc) = enumerate::build_mapping(graph, &anchors, input, output, &spec, budget)
        .ok_or_else(|| bad(format!("degraded spec {spec:?} is infeasible under the budget")))?;
    Ok(Degraded { mapping, desc, remapped_anchors })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::compile;

    fn hp() -> SystemConfig {
        SystemConfig::high_power()
    }

    #[test]
    fn search_ranks_analog_first_on_a_small_mlp() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 6).unwrap();
        assert!(out.feasible > 8, "space too small: {}", out.feasible);
        assert!(!out.ranked.is_empty());
        // The fastest estimate puts every layer on AIMC.
        assert!(out.ranked[0].desc.contains('A'), "{}", out.ranked[0].desc);
        assert!(!out.truncated);
        assert!(!out.front.is_empty());
        // Every ranked candidate compiles.
        for c in &out.ranked {
            compile::compile(&g, &c.mapping, 1).unwrap();
        }
    }

    #[test]
    fn search_is_deterministic() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 128, tile_cols: 256, channels: 32 };
        let a = search(&g, &budget, &hp(), 5).unwrap();
        let b = search(&g, &budget, &hp(), 5).unwrap();
        assert_eq!(a.enumerated, b.enumerated);
        assert_eq!(a.pruned, b.pruned);
        assert_eq!(a.feasible, b.feasible);
        let descs = |o: &SearchOutcome| o.ranked.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(descs(&a), descs(&b));
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.est.cycles_per_inf.to_bits(), y.est.cycles_per_inf.to_bits());
        }
    }

    #[test]
    fn parallel_walk_is_bit_identical_to_serial() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 128, tile_cols: 256, channels: 32 };
        let serial = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 5, jobs: 1, ..Default::default() }).unwrap();
        let parallel = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 5, jobs: 4, ..Default::default() }).unwrap();
        assert_eq!(serial.enumerated, parallel.enumerated);
        assert_eq!(serial.pruned, parallel.pruned);
        assert_eq!(serial.feasible, parallel.feasible);
        assert_eq!(serial.ranked.len(), parallel.ranked.len());
        for (a, b) in serial.ranked.iter().zip(&parallel.ranked) {
            assert_eq!(a.desc, b.desc);
            assert_eq!(a.est.cycles_per_inf.to_bits(), b.est.cycles_per_inf.to_bits());
            assert_eq!(a.est.energy_per_inf_j.to_bits(), b.est.energy_per_inf_j.to_bits());
        }
        let fd = |o: &SearchOutcome| o.front.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(fd(&serial), fd(&parallel));
    }

    #[test]
    fn compiled_oracle_cache_is_score_invisible() {
        // Cache on vs. off under the full-compile oracle: every semantic
        // outcome field must match bit for bit (only the `cache` stats
        // field may differ — that is the whole point of the knob).
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let run = |cc: bool| {
            search_opts(
                &g,
                &budget,
                &hp(),
                &SearchOptions {
                    top_k: 5,
                    model: CostModel::Compiled,
                    cap: Some(400),
                    compile_cache: cc,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.enumerated, off.enumerated);
        assert_eq!(on.pruned, off.pruned);
        assert_eq!(on.feasible, off.feasible);
        assert_eq!(on.truncated, off.truncated);
        assert_eq!(on.ranked.len(), off.ranked.len());
        for (a, b) in on.ranked.iter().zip(&off.ranked) {
            assert_eq!(a.desc, b.desc);
            assert_eq!(a.est.cycles_per_inf.to_bits(), b.est.cycles_per_inf.to_bits());
            assert_eq!(a.est.energy_per_inf_j.to_bits(), b.est.energy_per_inf_j.to_bits());
        }
        let fd = |o: &SearchOutcome| o.front.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(fd(&on), fd(&off));
        // The shared cache actually worked: hits dominate once the
        // space revisits anchor/engine/replication combinations.
        let stats = on.cache.expect("cache stats reported when enabled");
        assert!(stats.hits > stats.misses, "cache never warmed: {stats:?}");
        assert!(off.cache.is_none());
    }

    #[test]
    fn capped_walk_truncates_and_reports_it() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 4, cap: Some(10), ..Default::default() })
            .unwrap();
        assert!(out.truncated);
        assert_eq!(out.enumerated, 10);
        assert_eq!(out.pruned, 0);
        // An ample cap behaves like the exhaustive walk.
        let full = search_opts(
            &g,
            &budget,
            &hp(),
            &SearchOptions { top_k: 4, cap: Some(usize::MAX), ..Default::default() },
        )
        .unwrap();
        assert!(!full.truncated);
        let pruned = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 4, ..Default::default() }).unwrap();
        assert_eq!(full.enumerated, pruned.enumerated);
        assert!(pruned.feasible <= full.feasible);
        let descs = |o: &SearchOutcome| o.ranked.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(descs(&full), descs(&pruned));
        let fronts = |o: &SearchOutcome| o.front.iter().map(|c| c.desc.clone()).collect::<Vec<_>>();
        assert_eq!(fronts(&full), fronts(&pruned));
    }

    #[test]
    fn tight_tile_budget_prunes_analog_candidates() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let roomy = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let cramped = TopologyBudget { cores: 4, tiles: 0, tile_rows: 256, tile_cols: 256, channels: 32 };
        let a = search(&g, &roomy, &hp(), 4).unwrap();
        let b = search(&g, &cramped, &hp(), 4).unwrap();
        assert!(b.feasible < a.feasible);
        // With zero tiles only all-digital mappings survive.
        assert!(b.ranked.iter().all(|c| !c.desc.contains('A')));
    }

    #[test]
    fn wide_layers_need_column_replication_for_analog() {
        // 128x512 dense: 512 output columns exceed a 256-wide tile, so
        // analog placement is only reachable through a 2-way column
        // split (256 per replica) — the search must find it.
        let g = LayerGraph::mlp(&[128, 512]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 8).unwrap();
        let analog: Vec<&Candidate> = out.ranked.iter().filter(|c| c.desc.contains('A')).collect();
        assert!(!analog.is_empty(), "no analog candidate found");
        assert!(analog.iter().all(|c| !c.desc.contains("r1")), "analog requires replication here");
    }

    #[test]
    fn deeper_pipelines_and_octal_replication_are_searched() {
        // 7 dense anchors on an 8-core budget: the enlarged space
        // (depth 1..8, replication {1,2,4,8}) must exceed the removed
        // 60k cap, and narrowing either axis must shrink it.
        let dims: Vec<u64> = vec![512; 8];
        let g = LayerGraph::mlp(&dims);
        let budget = TopologyBudget { cores: 8, tiles: 16, tile_rows: 512, tile_cols: 512, channels: 64 };
        let out = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 8, ..Default::default() }).unwrap();
        assert!(out.enumerated > 60_000, "enlarged space should exceed the old cap: {}", out.enumerated);
        assert!(!out.truncated);
        let narrow_r = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 8, max_replica: 4, ..Default::default() })
            .unwrap();
        let shallow = search_opts(&g, &budget, &hp(), &SearchOptions { top_k: 8, max_depth: 6, ..Default::default() })
            .unwrap();
        assert!(narrow_r.enumerated < out.enumerated, "r8 axis missing");
        assert!(shallow.enumerated < out.enumerated, "depth 7..8 axis missing");
        // The best deep-space mapping still compiles.
        compile::compile(&g, &out.ranked[0].mapping, 1).unwrap();
    }

    #[test]
    fn baseline_is_single_core_all_digital() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let (m, desc) = digital_baseline(&g).unwrap();
        assert_eq!(m.stages.len(), 1);
        assert_eq!(m.stages[0].cores, vec![0]);
        assert!(m.tiles.is_empty());
        assert!(desc.starts_with("s1 r1 pp"));
        compile::compile(&g, &m, 2).unwrap();
    }

    #[test]
    fn degrade_moves_failed_tile_anchors_to_cpu() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 4).unwrap();
        let best = &out.ranked[0];
        let analog_steps = |m: &Mapping| {
            m.stages
                .iter()
                .flat_map(|s| &s.steps)
                .filter(|st| !matches!(st.place, Place::Cpu))
                .count()
        };
        let before = analog_steps(&best.mapping);
        assert!(before > 0, "best MLP candidate should be analog: {}", best.desc);

        let d = degrade_mapping(&g, &best.mapping, 0, &budget).unwrap();
        assert!(!d.remapped_anchors.is_empty());
        assert_eq!(analog_steps(&d.mapping), before - d.remapped_anchors.len());
        // The degraded mapping still compiles and costs at least as much
        // as the (rank-0) original point of the same space.
        compile::compile(&g, &d.mapping, 1).unwrap();
        let est = estimate(&g, &d.mapping, &hp()).unwrap();
        assert!(est.cycles_per_inf >= best.est.cycles_per_inf);
        // Deterministic.
        let d2 = degrade_mapping(&g, &best.mapping, 0, &budget).unwrap();
        assert_eq!(d.desc, d2.desc);
        assert_eq!(d.remapped_anchors, d2.remapped_anchors);
    }

    #[test]
    fn degrade_rejects_tiles_hosting_nothing() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 4).unwrap();
        assert!(matches!(
            degrade_mapping(&g, &out.ranked[0].mapping, 99, &budget),
            Err(WorkloadError::InvalidMapping(_))
        ));
        // An all-digital mapping has nothing to degrade either.
        let (m, _) = digital_baseline(&g).unwrap();
        assert!(degrade_mapping(&g, &m, 0, &budget).is_err());
    }

    #[test]
    fn degrade_handles_multiple_and_cascading_failed_tiles() {
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let out = search(&g, &budget, &hp(), 4).unwrap();
        let best = &out.ranked[0];

        // Which tiles does the best mapping actually use?
        let used: Vec<usize> = {
            let mut ts: Vec<usize> = best
                .mapping
                .stages
                .iter()
                .flat_map(|s| &s.steps)
                .flat_map(|st| place_tiles(&st.place))
                .collect();
            ts.sort_unstable();
            ts.dedup();
            ts
        };
        assert!(used.len() >= 2, "need >= 2 used tiles, got {used:?}");

        // The union semantics: failing both tiles remaps at least the
        // union of what failing each alone remaps.
        let a = degrade_mapping(&g, &best.mapping, used[0], &budget).unwrap();
        let b = degrade_mapping(&g, &best.mapping, used[1], &budget).unwrap();
        let both =
            degrade_mapping_multi(&g, &best.mapping, &[used[0], used[1]], &budget).unwrap();
        let mut union: Vec<usize> =
            a.remapped_anchors.iter().chain(&b.remapped_anchors).copied().collect();
        union.sort_unstable();
        union.dedup();
        assert_eq!(both.remapped_anchors, union, "multi-degrade is not the union");
        // Single-tile calls are the one-element special case.
        let single = degrade_mapping_multi(&g, &best.mapping, &[used[0]], &budget).unwrap();
        assert_eq!(single.remapped_anchors, a.remapped_anchors);
        assert_eq!(single.desc, a.desc);
        // A cascade may include tiles hosting nothing — the union
        // carries it — but an all-miss set errors cleanly, as does an
        // empty set.
        let with_miss =
            degrade_mapping_multi(&g, &best.mapping, &[used[0], 99], &budget).unwrap();
        assert_eq!(with_miss.remapped_anchors, a.remapped_anchors);
        assert!(degrade_mapping_multi(&g, &best.mapping, &[98, 99], &budget).is_err());
        assert!(degrade_mapping_multi(&g, &best.mapping, &[], &budget).is_err());
        // The degraded mapping still compiles.
        compile::compile(&g, &both.mapping, 1).unwrap();
    }

    #[test]
    fn conv_chains_are_searchable() {
        // Conv layers carve into per-inference im2col MVM anchors, so
        // the CNN chain — once rejected outright — now searches like any
        // other graph (the hand-built row-streamed pipeline remains a
        // separate, unsearched mapping style).
        let g = LayerGraph::cnn(&crate::nn::CnnModel::paper(crate::nn::CnnVariant::Fast));
        let budget = TopologyBudget::for_config(&hp());
        let out = search(&g, &budget, &hp(), 4).unwrap();
        assert!(out.feasible > 0, "no feasible conv mapping");
        assert!(!out.ranked.is_empty());
        compile::compile(&g, &out.ranked[0].mapping, 1).unwrap();
    }

    #[test]
    fn searches_fork_join_graphs() {
        let g = LayerGraph::resnet_block(8, 4, 10);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 64 };
        let out = search(&g, &budget, &hp(), 4).unwrap();
        assert!(out.feasible > 0, "no feasible DAG mapping");
        // Replication is chain-only: every DAG candidate runs r = 1.
        assert!(out.ranked.iter().all(|c| c.desc.contains("r1")), "DAG candidate replicated");
        // Winners compile and include a pipelined (multi-stage) point.
        for c in &out.ranked {
            compile::compile(&g, &c.mapping, 2).unwrap();
        }
        assert!(out.ranked.iter().any(|c| c.mapping.stages.len() > 1));
    }
}
