//! Candidate enumeration: carve a chain [`LayerGraph`] into anchors,
//! walk the (pipeline depth x partition x per-layer engine x replication
//! x hand-off) space, and construct a concrete [`Mapping`] for each
//! feasible point — packing analog MVM regions onto budget tiles
//! greedily, column-major, opening a new tile when the current one runs
//! out of columns.
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

use crate::nn::{LayerGraph, LayerKind, NodeId};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile::mapping::{
    Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::WorkloadError;

use super::TopologyBudget;

/// One mappable unit of a chain graph: at most one MVM-bearing layer
/// plus its elementwise companions, in dataflow order.
pub(crate) struct Anchor {
    pub nodes: Vec<NodeId>,
    pub mvm: Option<MvmInfo>,
    /// Activation width (elements) flowing out of this anchor.
    pub out_width: u64,
}

#[derive(Clone, Copy)]
pub(crate) enum MvmInfo {
    Dense { node: NodeId, rows: u64, cols: u64 },
    Lstm { node: NodeId, rows: u64, cols: u64 },
    Attention { node: NodeId, d_model: u64 },
}

impl MvmInfo {
    fn node(&self) -> NodeId {
        match self {
            MvmInfo::Dense { node, .. } | MvmInfo::Lstm { node, .. } | MvmInfo::Attention { node, .. } => *node,
        }
    }
}

fn err(msg: String) -> WorkloadError {
    WorkloadError::InvalidGraph(msg)
}

/// Split a linear chain graph into anchors. Returns the anchors plus the
/// graph's input and output node ids.
pub(crate) fn anchors(graph: &LayerGraph) -> Result<(Vec<Anchor>, NodeId, NodeId), WorkloadError> {
    let n = graph.nodes.len();
    if n < 3 {
        return Err(err("automap needs at least input -> layer -> output".into()));
    }
    if graph.edges.len() != n - 1 || graph.edges.iter().enumerate().any(|(i, &(a, b))| a != i || b != i + 1)
    {
        return Err(err("automap searches linear chain graphs only".into()));
    }
    let LayerKind::Input { raw_bytes, .. } = graph.nodes[0].kind else {
        return Err(err("automap chains must start at an Input node".into()));
    };
    if !matches!(graph.nodes[n - 1].kind, LayerKind::Output { .. }) {
        return Err(err("automap chains must end at an Output node".into()));
    }

    let mut out: Vec<Anchor> = Vec::new();
    let mut pending: Vec<NodeId> = Vec::new();
    let mut width = raw_bytes;
    for node in &graph.nodes[1..n - 1] {
        let mvm = match node.kind {
            LayerKind::Conv2d { .. } => {
                return Err(err("automap does not search row-streamed conv pipelines".into()));
            }
            LayerKind::Input { .. } | LayerKind::Output { .. } => {
                return Err(err(format!("interior input/output node {}", node.id)));
            }
            LayerKind::Dense { rows, cols, .. } => Some(MvmInfo::Dense { node: node.id, rows, cols }),
            LayerKind::LstmCell { x, n_h, .. } => {
                Some(MvmInfo::Lstm { node: node.id, rows: n_h + x, cols: 4 * n_h })
            }
            LayerKind::Attention { d_model, .. } => Some(MvmInfo::Attention { node: node.id, d_model }),
            _ => None,
        };
        width = match node.kind {
            LayerKind::Dense { cols, .. } => cols,
            LayerKind::LstmCell { n_h, .. } => n_h,
            LayerKind::Attention { d_model, .. } => d_model,
            LayerKind::Pool { elems, .. } => elems / 4,
            _ => width,
        };
        if let Some(m) = mvm {
            let mut nodes = std::mem::take(&mut pending);
            nodes.push(node.id);
            out.push(Anchor { nodes, mvm: Some(m), out_width: width });
        } else if let Some(last) = out.last_mut() {
            last.nodes.push(node.id);
            last.out_width = width;
        } else {
            pending.push(node.id);
        }
    }
    if !pending.is_empty() {
        out.push(Anchor { nodes: pending, mvm: None, out_width: width });
    }
    Ok((out, 0, n - 1))
}

/// One point of the search space, small enough to hold for every
/// enumerated candidate (the full `Mapping` is rebuilt on demand).
#[derive(Clone, Debug)]
pub(crate) struct CandidateSpec {
    /// Stage start indices into the anchor list (`starts[0] == 0`).
    pub starts: Vec<usize>,
    /// Bit `i`: the `i`-th MVM anchor (in chain order) goes on AIMC.
    pub analog_mask: u64,
    /// Replication factor applied to every column-replicable stage.
    pub replicas: usize,
    pub handoff: Handoff,
}

/// Deepest pipeline the enumerator will try.
const MAX_STAGES: usize = 6;
/// Above this many MVM anchors, only the all-digital and all-analog
/// engine assignments are enumerated (the full 2^m mask space explodes).
const FULL_MASK_ANCHORS: usize = 12;

/// Enumerate candidate specs in a fixed deterministic order (stage count
/// ascending, cut positions lexicographic, engine mask ascending,
/// replication ascending, ping-pong before shared-buffer). Returns the
/// specs and whether the walk hit `cap` (truncated).
pub(crate) fn enumerate_specs(
    anchors: &[Anchor],
    budget: &TopologyBudget,
    cap: usize,
) -> (Vec<CandidateSpec>, bool) {
    let n = anchors.len();
    let m = anchors.iter().filter(|a| a.mvm.is_some()).count();
    let masks: Vec<u64> = if m <= FULL_MASK_ANCHORS {
        (0..(1u64 << m)).collect()
    } else {
        // Mask space too large: keep the all-digital and all-analog ends.
        vec![0, (1u64 << m.min(63)) - 1]
    };
    let reduced_masks = m > FULL_MASK_ANCHORS;
    let replica_opts: Vec<usize> = [1usize, 2, 4].iter().copied().filter(|&r| r <= budget.cores).collect();
    let max_stages = MAX_STAGES.min(budget.cores).min(n.max(1));

    let mut specs = Vec::new();
    let mut truncated = reduced_masks;
    'outer: for s in 1..=max_stages {
        let handoffs: &[Handoff] = if s == 1 {
            &[Handoff::PingPong]
        } else {
            &[Handoff::PingPong, Handoff::SharedBuffer]
        };
        let mut done = false;
        for_each_starts(n, s, &mut |starts| {
            for &mask in &masks {
                for &r in &replica_opts {
                    for &h in handoffs {
                        if specs.len() >= cap {
                            done = true;
                            return false;
                        }
                        specs.push(CandidateSpec {
                            starts: starts.to_vec(),
                            analog_mask: mask,
                            replicas: r,
                            handoff: h,
                        });
                    }
                }
            }
            true
        });
        if done {
            truncated = true;
            break 'outer;
        }
    }
    (specs, truncated)
}

/// Visit every way of cutting `n` anchors into `s` contiguous stages,
/// passing the stage start indices. The visitor returns `false` to stop.
fn for_each_starts(n: usize, s: usize, f: &mut impl FnMut(&[usize]) -> bool) {
    let k = s - 1;
    if k == 0 {
        f(&[0]);
        return;
    }
    if k >= n {
        return;
    }
    // Combinations of k cut positions from 1..n, lexicographic.
    let mut c: Vec<usize> = (1..=k).collect();
    let mut starts = vec![0usize; s];
    loop {
        starts[1..].copy_from_slice(&c);
        if !f(&starts) {
            return;
        }
        let mut i = k;
        loop {
            if i == 0 {
                return;
            }
            i -= 1;
            if c[i] < n - k + i {
                c[i] += 1;
                for j in i + 1..k {
                    c[j] = c[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// Greedy column-packing of one `rows x cols` region onto the budget
/// tiles: reuse the last open tile when the region fits next to what is
/// already there, otherwise open a new tile. `floor` is the first tile
/// the current core may reuse — tiles are core-private (tight coupling,
/// Fig. 2), so callers pass the tile count at their stage boundary and
/// regions never share a tile across cores.
fn pack(
    budget: &TopologyBudget,
    tiles: &mut Vec<TileSpec>,
    used_cols: &mut Vec<u32>,
    floor: usize,
    rows: u64,
    cols: u64,
) -> Option<TilePlacement> {
    if rows == 0 || cols == 0 || rows > budget.tile_rows as u64 || cols > budget.tile_cols as u64 {
        return None;
    }
    let (r, c) = (rows as u32, cols as u32);
    if let Some(last) = tiles.len().checked_sub(1) {
        if last >= floor && used_cols[last] + c <= budget.tile_cols {
            let tp = TilePlacement {
                tile: last,
                placement: Placement { row0: 0, col0: used_cols[last], rows: r, cols: c },
            };
            used_cols[last] += c;
            return Some(tp);
        }
    }
    if tiles.len() >= budget.tiles {
        return None;
    }
    tiles.push(TileSpec { rows: budget.tile_rows, cols: budget.tile_cols, coupling: Coupling::Tight });
    used_cols.push(c);
    Some(TilePlacement { tile: tiles.len() - 1, placement: Placement { row0: 0, col0: 0, rows: r, cols: c } })
}

/// Construct the `Mapping` of one spec, or `None` when the spec is
/// infeasible under the budget (tile geometry, tile count, core count,
/// channel count) or degenerate (replication requested but no stage
/// eligible). Also returns the human-readable descriptor, e.g.
/// `"s2 r2 pp AD|DA"` (stages, replicas, hand-off, engine per anchor
/// with `.` for MVM-less anchors and `|` at stage cuts).
pub(crate) fn build_mapping(
    graph: &LayerGraph,
    anchors: &[Anchor],
    input_node: NodeId,
    output_node: NodeId,
    spec: &CandidateSpec,
    budget: &TopologyBudget,
) -> Option<(Mapping, String)> {
    let s_count = spec.starts.len();
    let mut stages: Vec<Stage> = Vec::with_capacity(s_count);
    let mut tiles: Vec<TileSpec> = Vec::new();
    let mut used_cols: Vec<u32> = Vec::new();
    let mut next_core = 0usize;
    let mut any_replicated = false;
    let mut mvm_idx = 0usize;
    let mut pat = String::new();

    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        let range = &anchors[lo..hi];
        // A stage replicates only when every slice is exact: truncated
        // `cols / parts` slices would compile a smaller network than the
        // r = 1 candidates and bias the search toward replication.
        let r = spec.replicas as u64;
        let replicable = r > 1
            && range.iter().all(|a| match a.mvm {
                None => true,
                Some(MvmInfo::Dense { cols, .. }) => cols % r == 0,
                Some(_) => false,
            })
            && range.last().expect("stages are non-empty").out_width % r == 0;
        let parts = if replicable { spec.replicas } else { 1 };
        any_replicated |= parts > 1;

        let mut st = Stage::on_core(next_core);
        if parts > 1 {
            st.cores = (next_core..next_core + parts).collect();
            st.split = SplitKind::Columns;
            st.barrier = true;
        }
        next_core += parts;
        if next_core > budget.cores {
            return None;
        }
        // Tiles are core-private (tight coupling): this stage's single
        // core may pack onto tiles opened from here on, never onto a
        // previous stage's.
        let stage_floor = tiles.len();

        for a in range {
            let analog = match a.mvm {
                Some(_) => {
                    let bit = (spec.analog_mask >> mvm_idx) & 1 == 1;
                    mvm_idx += 1;
                    bit
                }
                None => false,
            };
            pat.push(match (a.mvm.is_some(), analog) {
                (false, _) => '.',
                (true, false) => 'D',
                (true, true) => 'A',
            });
            for &nid in &a.nodes {
                let is_mvm = a.mvm.is_some_and(|mvm| mvm.node() == nid);
                if !is_mvm || !analog {
                    st.steps.push(Step::cpu(nid));
                    continue;
                }
                match a.mvm.expect("is_mvm checked") {
                    MvmInfo::Dense { node, rows, cols } => {
                        let slice = cols / parts as u64;
                        if rows <= budget.tile_rows as u64 && slice <= budget.tile_cols as u64 {
                            let mut per_replica = Vec::with_capacity(parts);
                            for _ in 0..parts {
                                // Replicas run on distinct cores, so each
                                // slice gets a fresh tile when replicated.
                                let floor = if parts > 1 { tiles.len() } else { stage_floor };
                                per_replica.push(pack(budget, &mut tiles, &mut used_cols, floor, rows, slice)?);
                            }
                            st.steps.push(Step { node, place: Place::Tile { per_replica } });
                        } else if parts == 1
                            && rows > budget.tile_rows as u64
                            && cols <= budget.tile_cols as u64
                            && rows % rows.div_ceil(budget.tile_rows as u64) == 0
                        {
                            // Tall matrix: row-split over k tiles with
                            // digital partial accumulation (Fig. 6b case 2).
                            // Non-divisible splits are rejected: the
                            // `rows / k` lowering would silently drop the
                            // remainder rows and bias the analog-vs-digital
                            // comparison in the search. Each sub-region
                            // gets its own tile — parallel crossbars are
                            // the point of the split.
                            let k = rows.div_ceil(budget.tile_rows as u64);
                            let sub = rows / k;
                            let mut split = Vec::with_capacity(k as usize);
                            for _ in 0..k {
                                let floor = tiles.len();
                                split.push(pack(budget, &mut tiles, &mut used_cols, floor, sub, cols)?);
                            }
                            st.steps.push(Step { node, place: Place::TileRowSplit { tiles: split } });
                        } else {
                            return None;
                        }
                    }
                    MvmInfo::Lstm { node, rows, cols } => {
                        let tp = pack(budget, &mut tiles, &mut used_cols, stage_floor, rows, cols)?;
                        st.steps.push(Step {
                            node,
                            place: Place::Tile { per_replica: vec![tp] },
                        });
                    }
                    MvmInfo::Attention { node, d_model } => {
                        let q = pack(budget, &mut tiles, &mut used_cols, stage_floor, d_model, d_model)?;
                        let k = pack(budget, &mut tiles, &mut used_cols, stage_floor, d_model, d_model)?;
                        let v = pack(budget, &mut tiles, &mut used_cols, stage_floor, d_model, d_model)?;
                        let o = pack(budget, &mut tiles, &mut used_cols, stage_floor, d_model, d_model)?;
                        st.steps.push(Step { node, place: Place::AttentionTiles { q, k, v, o } });
                    }
                }
            }
        }

        st.input = if si == 0 { StageInput::Memory { node: input_node } } else { StageInput::Channel };
        st.output = if si + 1 == s_count {
            StageOutput::Memory { node: output_node }
        } else {
            let width = range.last().expect("stages are non-empty").out_width;
            StageOutput::Channel { bytes: 4 * width / parts as u64 }
        };
        st.handoff = spec.handoff;
        stages.push(st);
        if si + 1 < s_count {
            pat.push('|');
        }
    }

    if spec.replicas > 1 && !any_replicated {
        return None; // identical to the r = 1 spec
    }
    let mut channels = 0usize;
    for i in 0..stages.len().saturating_sub(1) {
        let fan = stages[i].cores.len() * stages[i + 1].cores.len();
        channels += fan * if spec.handoff == Handoff::SharedBuffer { 2 } else { 1 };
    }
    if channels > budget.channels {
        return None;
    }

    let desc = format!(
        "s{s_count} r{} {} {pat}",
        spec.replicas,
        match spec.handoff {
            Handoff::PingPong => "pp",
            Handoff::SharedBuffer => "sb",
        }
    );
    let label = format!("automap/{desc}");
    Some((Mapping { label, tiles, min_mutexes: 0, stages }, desc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_chain_splits_into_dense_anchors() {
        let g = LayerGraph::mlp(&[64, 32, 16]);
        let (a, input, output) = anchors(&g).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!((input, output), (0, 5));
        assert!(matches!(a[0].mvm, Some(MvmInfo::Dense { rows: 64, cols: 32, .. })));
        assert_eq!(a[0].out_width, 32);
        assert_eq!(a[1].out_width, 16);
        // Each anchor holds its dense + relu.
        assert_eq!(a[0].nodes, vec![1, 2]);
    }

    #[test]
    fn transformer_chain_attaches_leading_norms() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let (a, _, _) = anchors(&g).unwrap();
        // attention anchor, FFN-up anchor, FFN-down anchor
        assert_eq!(a.len(), 3);
        assert!(matches!(a[0].mvm, Some(MvmInfo::Attention { d_model: 64, .. })));
        // The pre-attention LayerNorm rides in the attention anchor.
        assert_eq!(a[0].nodes[0], 1);
        assert_eq!(a[2].out_width, 64);
    }

    #[test]
    fn non_chain_graphs_are_rejected() {
        let mut g = LayerGraph::new("dag");
        let i = g.add(LayerKind::Input { bytes: 64, marshal_insts: 4, raw_bytes: 16 });
        let d = g.chain(i, LayerKind::Dense { rows: 16, cols: 16, weight_slot: 0 });
        let o = g.chain(d, LayerKind::Output { bytes: 64 });
        g.edges.push((i, o)); // skip edge -> not a chain
        assert!(anchors(&g).is_err());
    }

    #[test]
    fn starts_enumeration_counts_compositions() {
        // 4 anchors into 2 stages: C(3,1) = 3 compositions.
        let mut seen = Vec::new();
        for_each_starts(4, 2, &mut |s| {
            seen.push(s.to_vec());
            true
        });
        assert_eq!(seen, vec![vec![0, 1], vec![0, 2], vec![0, 3]]);
    }

    #[test]
    fn packer_opens_new_tile_when_columns_run_out() {
        let budget = TopologyBudget { cores: 4, tiles: 3, tile_rows: 64, tile_cols: 100, channels: 8 };
        let mut tiles = Vec::new();
        let mut used = Vec::new();
        let a = pack(&budget, &mut tiles, &mut used, 0, 64, 60).unwrap();
        let b = pack(&budget, &mut tiles, &mut used, 0, 32, 30).unwrap();
        let c = pack(&budget, &mut tiles, &mut used, 0, 64, 60).unwrap();
        assert_eq!((a.tile, b.tile, c.tile), (0, 0, 1));
        assert_eq!(b.placement.col0, 60);
        // A raised floor (next pipeline stage / replica) never reuses an
        // earlier core's open tile even though columns remain.
        let d = pack(&budget, &mut tiles, &mut used, 2, 16, 10).unwrap();
        assert_eq!(d.tile, 2);
        assert_eq!(d.placement.col0, 0);
        // Budget of 3 tiles exhausted.
        assert!(pack(&budget, &mut tiles, &mut used, 3, 64, 90).is_none());
        // Oversized regions never fit.
        assert!(pack(&budget, &mut tiles, &mut used, 0, 65, 10).is_none());
    }
}
