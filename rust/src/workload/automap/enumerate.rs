//! Candidate enumeration: carve a chain [`LayerGraph`] into anchors and
//! construct a concrete [`Mapping`] for any point of the (pipeline depth
//! x partition x per-layer engine x replication x hand-off) space —
//! packing analog MVM regions onto budget tiles greedily, column-major,
//! opening a new tile when the current one runs out of columns.
//!
//! The *walk* over the space lives in the parent module's
//! branch-and-bound search; this module owns the shared pieces both the
//! mapping constructor and the compositional cost engine must agree on
//! byte-for-byte: per-stage replication ([`stage_parts`]), analog
//! placement geometry ([`analog_shape`]), the greedy tile packer
//! ([`Packer`]), and the candidate descriptor ([`spec_desc`]).
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

use crate::nn::{LayerGraph, LayerKind, NodeId};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile::mapping::{
    Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::WorkloadError;

use super::TopologyBudget;

/// One mappable unit of a chain graph: at most one MVM-bearing layer
/// plus its elementwise companions, in dataflow order.
pub(crate) struct Anchor {
    pub nodes: Vec<NodeId>,
    pub mvm: Option<MvmInfo>,
    /// Activation width (elements) flowing out of this anchor.
    pub out_width: u64,
}

#[derive(Clone, Copy)]
pub(crate) enum MvmInfo {
    Dense { node: NodeId, rows: u64, cols: u64 },
    Lstm { node: NodeId, rows: u64, cols: u64 },
    Attention { node: NodeId, d_model: u64 },
}

impl MvmInfo {
    pub(crate) fn node(&self) -> NodeId {
        match self {
            MvmInfo::Dense { node, .. } | MvmInfo::Lstm { node, .. } | MvmInfo::Attention { node, .. } => *node,
        }
    }
}

fn err(msg: String) -> WorkloadError {
    WorkloadError::InvalidGraph(msg)
}

/// Split a linear chain graph into anchors. Returns the anchors plus the
/// graph's input and output node ids.
pub(crate) fn anchors(graph: &LayerGraph) -> Result<(Vec<Anchor>, NodeId, NodeId), WorkloadError> {
    let n = graph.nodes.len();
    if n < 3 {
        return Err(err("automap needs at least input -> layer -> output".into()));
    }
    if graph.edges.len() != n - 1 || graph.edges.iter().enumerate().any(|(i, &(a, b))| a != i || b != i + 1)
    {
        return Err(err("automap searches linear chain graphs only".into()));
    }
    let LayerKind::Input { raw_bytes, .. } = graph.nodes[0].kind else {
        return Err(err("automap chains must start at an Input node".into()));
    };
    if !matches!(graph.nodes[n - 1].kind, LayerKind::Output { .. }) {
        return Err(err("automap chains must end at an Output node".into()));
    }

    let mut out: Vec<Anchor> = Vec::new();
    let mut pending: Vec<NodeId> = Vec::new();
    let mut width = raw_bytes;
    for node in &graph.nodes[1..n - 1] {
        let mvm = match node.kind {
            LayerKind::Conv2d { .. } => {
                return Err(err("automap does not search row-streamed conv pipelines".into()));
            }
            LayerKind::Input { .. } | LayerKind::Output { .. } => {
                return Err(err(format!("interior input/output node {}", node.id)));
            }
            LayerKind::Dense { rows, cols, .. } => Some(MvmInfo::Dense { node: node.id, rows, cols }),
            LayerKind::LstmCell { x, n_h, .. } => {
                Some(MvmInfo::Lstm { node: node.id, rows: n_h + x, cols: 4 * n_h })
            }
            LayerKind::Attention { d_model, .. } => Some(MvmInfo::Attention { node: node.id, d_model }),
            _ => None,
        };
        width = match node.kind {
            LayerKind::Dense { cols, .. } => cols,
            LayerKind::LstmCell { n_h, .. } => n_h,
            LayerKind::Attention { d_model, .. } => d_model,
            LayerKind::Pool { elems, .. } => elems / 4,
            _ => width,
        };
        if let Some(m) = mvm {
            let mut nodes = std::mem::take(&mut pending);
            nodes.push(node.id);
            out.push(Anchor { nodes, mvm: Some(m), out_width: width });
        } else if let Some(last) = out.last_mut() {
            last.nodes.push(node.id);
            last.out_width = width;
        } else {
            pending.push(node.id);
        }
    }
    if !pending.is_empty() {
        out.push(Anchor { nodes: pending, mvm: None, out_width: width });
    }
    Ok((out, 0, n - 1))
}

/// One point of the search space, small enough to hold for every
/// enumerated candidate (the full `Mapping` is rebuilt on demand).
#[derive(Clone, Debug)]
pub(crate) struct CandidateSpec {
    /// Stage start indices into the anchor list (`starts[0] == 0`).
    pub starts: Vec<usize>,
    /// Bit `i`: the `i`-th MVM anchor (in chain order) goes on AIMC.
    pub analog_mask: u64,
    /// Replication factor applied to every column-replicable stage.
    pub replicas: usize,
    pub handoff: Handoff,
}

/// Above this many MVM anchors, only the all-digital and all-analog
/// engine assignments are enumerated (the full 2^m mask space explodes).
pub(crate) const FULL_MASK_ANCHORS: usize = 12;

/// The engine-mask axis of the space for `m` MVM anchors, plus whether
/// it was reduced to the all-digital/all-analog extremes.
pub(crate) fn engine_masks(m: usize) -> (Vec<u64>, bool) {
    if m <= FULL_MASK_ANCHORS {
        ((0..(1u64 << m)).collect(), false)
    } else {
        (vec![0, (1u64 << m.min(63)) - 1], true)
    }
}

/// Engine bit of MVM anchor `idx` — the one mask reader every consumer
/// (descriptor, mapping constructor, cost engine, lower bounds) goes
/// through. Anchors past the u64 mask width read as digital instead of
/// shifting out of range (only reachable through the reduced-mask
/// extremes of 64+-MVM chains, where the "all-analog" seed is then
/// analog on the first 63 anchors — consistently so across every
/// reader).
pub(crate) fn mask_bit(mask: u64, idx: usize) -> bool {
    idx < 64 && (mask >> idx) & 1 == 1
}

/// Hard bound on materialized pipeline partitions (~tens of MB of cut
/// lists). `sum_{s<=8} C(n-1, s-1)` explodes combinatorially for deep
/// chains; past this bound the walk keeps the canonical prefix and
/// reports the space as truncated rather than exhausting memory.
pub(crate) const MAX_PARTITIONS: usize = 250_000;

/// Every way of cutting `n` anchors into 1..=`max_stages` contiguous
/// stages, as stage-start index lists — the subtree roots of the
/// branch-and-bound walk, in the canonical enumeration order (stage
/// count ascending, cut positions lexicographic). At most
/// `limit.min(MAX_PARTITIONS)` lists are materialized (a capped walk
/// can never consume more partitions than candidates, so callers pass
/// the candidate cap); the second return is true when the bound cut
/// the list short.
pub(crate) fn partitions(n: usize, max_stages: usize, limit: usize) -> (Vec<Vec<usize>>, bool) {
    let limit = limit.min(MAX_PARTITIONS);
    let mut out = Vec::new();
    let mut truncated = false;
    'all: for s in 1..=max_stages.min(n.max(1)).max(1) {
        let mut full = true;
        for_each_starts(n, s, &mut |starts| {
            if out.len() >= limit {
                full = false;
                return false;
            }
            out.push(starts.to_vec());
            true
        });
        if !full {
            truncated = true;
            break 'all;
        }
    }
    (out, truncated)
}

/// Visit every way of cutting `n` anchors into `s` contiguous stages,
/// passing the stage start indices. The visitor returns `false` to stop.
fn for_each_starts(n: usize, s: usize, f: &mut impl FnMut(&[usize]) -> bool) {
    let k = s - 1;
    if k == 0 {
        f(&[0]);
        return;
    }
    if k >= n {
        return;
    }
    // Combinations of k cut positions from 1..n, lexicographic.
    let mut c: Vec<usize> = (1..=k).collect();
    let mut starts = vec![0usize; s];
    loop {
        starts[1..].copy_from_slice(&c);
        if !f(&starts) {
            return;
        }
        let mut i = k;
        loop {
            if i == 0 {
                return;
            }
            i -= 1;
            if c[i] < n - k + i {
                c[i] += 1;
                for j in i + 1..k {
                    c[j] = c[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// Per-anchor half of the replication rule: can this anchor run inside
/// an `r`-way column-replicated stage? (Dense MVMs need exact column
/// slices; non-Dense MVMs pin their stage to a single replica.)
pub(crate) fn anchor_replicable(a: &Anchor, r: u64) -> bool {
    match a.mvm {
        None => true,
        Some(MvmInfo::Dense { cols, .. }) => cols % r == 0,
        Some(_) => false,
    }
}

/// Replica count a stage actually runs with: `replicas` when every
/// anchor is replicable *and* the stage's output width slices exactly
/// (truncated slices would compile a smaller network than the r = 1
/// candidates and bias the search toward replication), else 1.
pub(crate) fn stage_parts(range: &[Anchor], replicas: usize) -> u64 {
    let r = replicas as u64;
    let replicable = r > 1
        && range.iter().all(|a| anchor_replicable(a, r))
        && range.last().expect("stages are non-empty").out_width % r == 0;
    if replicable {
        r
    } else {
        1
    }
}

/// Analog placement geometry of one MVM under a replication factor —
/// the single source of truth shared by the mapping constructor, the
/// tile-packing feasibility walk, and the profile emitter.
#[derive(Clone, Copy, Debug)]
pub(crate) enum AnalogShape {
    /// One `rows x slice` region per replica.
    Direct { rows: u64, slice: u64 },
    /// Tall matrix row-split over `k` stacked `sub x cols` regions with
    /// digital partial accumulation (Fig. 6b case 2). Non-divisible
    /// splits are rejected: the `rows / k` lowering would silently drop
    /// the remainder rows and bias the analog-vs-digital comparison.
    RowSplit { k: u64, sub: u64, cols: u64 },
    /// A single `rows x cols` region (LSTM gate block).
    One { rows: u64, cols: u64 },
    /// Four `d x d` projection regions (attention Wq|Wk|Wv|Wo).
    Quad { d: u64 },
}

pub(crate) fn analog_shape(mvm: &MvmInfo, parts: u64, tile_rows: u32, tile_cols: u32) -> Option<AnalogShape> {
    match *mvm {
        MvmInfo::Dense { rows, cols, .. } => {
            let slice = cols / parts;
            if rows <= tile_rows as u64 && slice <= tile_cols as u64 {
                Some(AnalogShape::Direct { rows, slice })
            } else if parts == 1
                && rows > tile_rows as u64
                && cols <= tile_cols as u64
                && rows % rows.div_ceil(tile_rows as u64) == 0
            {
                let k = rows.div_ceil(tile_rows as u64);
                Some(AnalogShape::RowSplit { k, sub: rows / k, cols })
            } else {
                None
            }
        }
        MvmInfo::Lstm { rows, cols, .. } => Some(AnalogShape::One { rows, cols }),
        MvmInfo::Attention { d_model, .. } => Some(AnalogShape::Quad { d: d_model }),
    }
}

/// Per-stage replica counts of a spec, with the core-budget,
/// channel-budget, and degenerate-replication checks applied — `None`
/// exactly when the spec is infeasible on those axes. The single
/// source of truth shared by `build_mapping` and the compositional
/// cost engine's `score`, so the two cannot drift.
pub(crate) fn stage_layout(
    anchors: &[Anchor],
    spec: &CandidateSpec,
    budget: &TopologyBudget,
) -> Option<Vec<u64>> {
    let s_count = spec.starts.len();
    let mut parts: Vec<u64> = Vec::with_capacity(s_count);
    let mut next_core = 0usize;
    let mut any_replicated = false;
    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        let p = stage_parts(&anchors[lo..hi], spec.replicas);
        any_replicated |= p > 1;
        next_core += p as usize;
        if next_core > budget.cores {
            return None;
        }
        parts.push(p);
    }
    if spec.replicas > 1 && !any_replicated {
        return None; // identical to the r = 1 spec
    }
    let mut channels = 0usize;
    for i in 0..s_count.saturating_sub(1) {
        let fan = (parts[i] * parts[i + 1]) as usize;
        channels += fan * if spec.handoff == Handoff::SharedBuffer { 2 } else { 1 };
    }
    if channels > budget.channels {
        return None;
    }
    Some(parts)
}

/// Claim every tile region of one analog MVM shape through the packer,
/// in packing order with the shape's floor rules (fresh tile per
/// replica when replicated, per-sub-region floors for row splits,
/// the stage floor otherwise), feeding each claim to `sink` as
/// `(tile, col0, rows, cols)`. `None` when any region fails geometry
/// or the tile budget. The single packing walk shared by
/// `build_mapping` (which materializes placements) and the cost
/// engine's `score` (which only counts).
pub(crate) fn place_shape(
    packer: &mut Packer,
    budget: &TopologyBudget,
    stage_floor: usize,
    shape: &AnalogShape,
    parts: u64,
    mut sink: impl FnMut(usize, u32, u64, u64),
) -> Option<()> {
    match *shape {
        AnalogShape::Direct { rows, slice } => {
            for _ in 0..parts {
                // Replicas run on distinct cores, so each slice gets a
                // fresh tile when replicated.
                let floor = if parts > 1 { packer.count() } else { stage_floor };
                let (t, c0) = packer.place(budget, floor, rows, slice)?;
                sink(t, c0, rows, slice);
            }
        }
        AnalogShape::RowSplit { k, sub, cols } => {
            // Each sub-region gets its own tile — parallel crossbars
            // are the point of the split.
            for _ in 0..k {
                let floor = packer.count();
                let (t, c0) = packer.place(budget, floor, sub, cols)?;
                sink(t, c0, sub, cols);
            }
        }
        AnalogShape::One { rows, cols } => {
            let (t, c0) = packer.place(budget, stage_floor, rows, cols)?;
            sink(t, c0, rows, cols);
        }
        AnalogShape::Quad { d } => {
            for _ in 0..4 {
                let (t, c0) = packer.place(budget, stage_floor, d, d)?;
                sink(t, c0, d, d);
            }
        }
    }
    Some(())
}

/// Greedy column-major tile packer. Only the most recently opened tile
/// is ever reusable, so the full state is a tile count plus the open
/// tile's used columns — cheap enough to run per scored candidate.
/// `floor` is the first tile the current region may reuse: tiles are
/// core-private (tight coupling, Fig. 2), so callers pass the tile
/// count at their stage/replica boundary and regions never share a
/// tile across cores.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Packer {
    count: usize,
    open_cols: u32,
}

impl Packer {
    pub(crate) fn new() -> Packer {
        Packer::default()
    }

    pub(crate) fn count(&self) -> usize {
        self.count
    }

    /// Claim a `rows x cols` region: reuse the open tile when the region
    /// fits next to what is already there (and the tile is at or above
    /// `floor`), otherwise open a new tile. Returns the `(tile, col0)`
    /// of the claim, or `None` when the region is geometrically
    /// oversized or the tile budget is exhausted.
    pub(crate) fn place(
        &mut self,
        budget: &TopologyBudget,
        floor: usize,
        rows: u64,
        cols: u64,
    ) -> Option<(usize, u32)> {
        if rows == 0 || cols == 0 || rows > budget.tile_rows as u64 || cols > budget.tile_cols as u64 {
            return None;
        }
        let c = cols as u32;
        if let Some(last) = self.count.checked_sub(1) {
            if last >= floor && self.open_cols as u64 + c as u64 <= budget.tile_cols as u64 {
                let col0 = self.open_cols;
                self.open_cols += c;
                return Some((last, col0));
            }
        }
        if self.count >= budget.tiles {
            return None;
        }
        self.count += 1;
        self.open_cols = c;
        Some((self.count - 1, 0))
    }
}

/// Human-readable point in the search space, e.g. `"s2 r2 pp AD|DA"`
/// (stages, replicas, hand-off, engine per anchor with `.` for MVM-less
/// anchors and `|` at stage cuts). Unique per spec, so it doubles as the
/// deterministic ranking tie-break.
pub(crate) fn spec_desc(anchors: &[Anchor], spec: &CandidateSpec) -> String {
    let s_count = spec.starts.len();
    let mut pat = String::new();
    let mut mvm_idx = 0usize;
    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        for a in &anchors[lo..hi] {
            pat.push(match a.mvm {
                None => '.',
                Some(_) => {
                    let bit = mask_bit(spec.analog_mask, mvm_idx);
                    mvm_idx += 1;
                    if bit {
                        'A'
                    } else {
                        'D'
                    }
                }
            });
        }
        if si + 1 < s_count {
            pat.push('|');
        }
    }
    format!(
        "s{s_count} r{} {} {pat}",
        spec.replicas,
        match spec.handoff {
            Handoff::PingPong => "pp",
            Handoff::SharedBuffer => "sb",
        }
    )
}

/// Construct the `Mapping` of one spec, or `None` when the spec is
/// infeasible under the budget (tile geometry, tile count, core count,
/// channel count) or degenerate (replication requested but no stage
/// eligible). Also returns the descriptor from [`spec_desc`].
pub(crate) fn build_mapping(
    graph: &LayerGraph,
    anchors: &[Anchor],
    input_node: NodeId,
    output_node: NodeId,
    spec: &CandidateSpec,
    budget: &TopologyBudget,
) -> Option<(Mapping, String)> {
    let s_count = spec.starts.len();
    let parts_per_stage = stage_layout(anchors, spec, budget)?;
    let mut stages: Vec<Stage> = Vec::with_capacity(s_count);
    let mut tiles: Vec<TileSpec> = Vec::new();
    let mut packer = Packer::new();
    let mut next_core = 0usize;
    let mut mvm_idx = 0usize;

    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        let range = &anchors[lo..hi];
        let parts_n = parts_per_stage[si];
        let parts = parts_n as usize;

        let mut st = Stage::on_core(next_core);
        if parts > 1 {
            st.cores = (next_core..next_core + parts).collect();
            st.split = SplitKind::Columns;
            st.barrier = true;
        }
        next_core += parts;
        // Tiles are core-private (tight coupling): this stage's single
        // core may pack onto tiles opened from here on, never onto a
        // previous stage's.
        let stage_floor = packer.count();

        for a in range {
            let analog = match a.mvm {
                Some(_) => {
                    let bit = mask_bit(spec.analog_mask, mvm_idx);
                    mvm_idx += 1;
                    bit
                }
                None => false,
            };
            for &nid in &a.nodes {
                let is_mvm = a.mvm.is_some_and(|mvm| mvm.node() == nid);
                if !is_mvm || !analog {
                    st.steps.push(Step::cpu(nid));
                    continue;
                }
                let mvm = a.mvm.expect("is_mvm checked");
                let node = mvm.node();
                let shape = analog_shape(&mvm, parts_n, budget.tile_rows, budget.tile_cols)?;
                let mut claims: Vec<TilePlacement> = Vec::new();
                place_shape(&mut packer, budget, stage_floor, &shape, parts_n, |tile, col0, rows, cols| {
                    while tiles.len() <= tile {
                        tiles.push(TileSpec {
                            rows: budget.tile_rows,
                            cols: budget.tile_cols,
                            coupling: Coupling::Tight,
                        });
                    }
                    claims.push(TilePlacement {
                        tile,
                        placement: Placement { row0: 0, col0, rows: rows as u32, cols: cols as u32 },
                    });
                })?;
                let place = match shape {
                    AnalogShape::Direct { .. } | AnalogShape::One { .. } => {
                        Place::Tile { per_replica: claims }
                    }
                    AnalogShape::RowSplit { .. } => Place::TileRowSplit { tiles: claims },
                    AnalogShape::Quad { .. } => {
                        let [q, k, v, o] = <[TilePlacement; 4]>::try_from(claims)
                            .expect("Quad shapes claim exactly four regions");
                        Place::AttentionTiles { q, k, v, o }
                    }
                };
                st.steps.push(Step { node, place });
            }
        }

        st.input = if si == 0 { StageInput::Memory { node: input_node } } else { StageInput::Channel };
        st.output = if si + 1 == s_count {
            StageOutput::Memory { node: output_node }
        } else {
            let width = range.last().expect("stages are non-empty").out_width;
            StageOutput::Channel { bytes: 4 * width / parts as u64 }
        };
        st.handoff = spec.handoff;
        stages.push(st);
    }

    let desc = spec_desc(anchors, spec);
    let label = format!("automap/{desc}");
    Some((Mapping { label, tiles, min_mutexes: 0, stages }, desc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_chain_splits_into_dense_anchors() {
        let g = LayerGraph::mlp(&[64, 32, 16]);
        let (a, input, output) = anchors(&g).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!((input, output), (0, 5));
        assert!(matches!(a[0].mvm, Some(MvmInfo::Dense { rows: 64, cols: 32, .. })));
        assert_eq!(a[0].out_width, 32);
        assert_eq!(a[1].out_width, 16);
        // Each anchor holds its dense + relu.
        assert_eq!(a[0].nodes, vec![1, 2]);
    }

    #[test]
    fn transformer_chain_attaches_leading_norms() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let (a, _, _) = anchors(&g).unwrap();
        // attention anchor, FFN-up anchor, FFN-down anchor
        assert_eq!(a.len(), 3);
        assert!(matches!(a[0].mvm, Some(MvmInfo::Attention { d_model: 64, .. })));
        // The pre-attention LayerNorm rides in the attention anchor.
        assert_eq!(a[0].nodes[0], 1);
        assert_eq!(a[2].out_width, 64);
    }

    #[test]
    fn non_chain_graphs_are_rejected() {
        let mut g = LayerGraph::new("dag");
        let i = g.add(LayerKind::Input { bytes: 64, marshal_insts: 4, raw_bytes: 16 });
        let d = g.chain(i, LayerKind::Dense { rows: 16, cols: 16, weight_slot: 0 });
        let o = g.chain(d, LayerKind::Output { bytes: 64 });
        g.edges.push((i, o)); // skip edge -> not a chain
        assert!(anchors(&g).is_err());
    }

    #[test]
    fn starts_enumeration_counts_compositions() {
        // 4 anchors into 2 stages: C(3,1) = 3 compositions.
        let mut seen = Vec::new();
        for_each_starts(4, 2, &mut |s| {
            seen.push(s.to_vec());
            true
        });
        assert_eq!(seen, vec![vec![0, 1], vec![0, 2], vec![0, 3]]);
    }

    #[test]
    fn partitions_cover_all_depths_in_order() {
        let (p, truncated) = partitions(4, 3, usize::MAX);
        // s=1: 1; s=2: C(3,1)=3; s=3: C(3,2)=3.
        assert!(!truncated);
        assert_eq!(p.len(), 7);
        assert_eq!(p[0], vec![0]);
        assert_eq!(p[1], vec![0, 1]);
        assert_eq!(p[6], vec![0, 2, 3]);
        // Depth never exceeds the anchor count.
        assert_eq!(partitions(2, 8, usize::MAX).0.len(), 2);
        // Combinatorial blow-ups are bounded, kept to the canonical
        // prefix, and reported as truncated instead of exhausting memory.
        let (big, big_truncated) = partitions(60, 8, usize::MAX);
        assert!(big_truncated);
        assert_eq!(big.len(), MAX_PARTITIONS);
        assert_eq!(big[0], vec![0]);
        // A candidate cap bounds the materialization too.
        let (capped, capped_truncated) = partitions(60, 8, 10);
        assert!(capped_truncated);
        assert_eq!(capped.len(), 10);
    }

    #[test]
    fn packer_opens_new_tile_when_columns_run_out() {
        let budget = TopologyBudget { cores: 4, tiles: 3, tile_rows: 64, tile_cols: 100, channels: 8 };
        let mut p = Packer::new();
        let a = p.place(&budget, 0, 64, 60).unwrap();
        let b = p.place(&budget, 0, 32, 30).unwrap();
        let c = p.place(&budget, 0, 64, 60).unwrap();
        assert_eq!((a.0, b.0, c.0), (0, 0, 1));
        assert_eq!(b.1, 60);
        // A raised floor (next pipeline stage / replica) never reuses an
        // earlier core's open tile even though columns remain.
        let d = p.place(&budget, 2, 16, 10).unwrap();
        assert_eq!(d, (2, 0));
        // Budget of 3 tiles exhausted.
        assert!(p.place(&budget, 3, 64, 90).is_none());
        // Oversized regions never fit.
        assert!(p.place(&budget, 0, 65, 10).is_none());
    }

    #[test]
    fn spec_desc_matches_build_mapping() {
        let g = LayerGraph::mlp(&[64, 32, 16]);
        let (a, input, output) = anchors(&g).unwrap();
        let budget = TopologyBudget { cores: 4, tiles: 4, tile_rows: 64, tile_cols: 64, channels: 8 };
        let spec = CandidateSpec {
            starts: vec![0, 1],
            analog_mask: 0b10,
            replicas: 1,
            handoff: Handoff::SharedBuffer,
        };
        let (_, desc) = build_mapping(&g, &a, input, output, &spec, &budget).unwrap();
        assert_eq!(desc, spec_desc(&a, &spec));
        assert_eq!(desc, "s2 r1 sb D|A");
    }

    #[test]
    fn stage_parts_requires_exact_slices() {
        let g = LayerGraph::mlp(&[64, 48, 16]);
        let (a, _, _) = anchors(&g).unwrap();
        // 48 % 4 == 0 and out widths divide: both anchors replicate at 2.
        assert_eq!(stage_parts(&a[0..1], 2), 2);
        // 48 % 32 != 0: not replicable at 32.
        assert_eq!(stage_parts(&a[0..1], 32), 1);
        // A non-Dense MVM pins the stage to one replica.
        let lg = LayerGraph::lstm(&crate::nn::LstmModel::paper(750));
        let (la, _, _) = anchors(&lg).unwrap();
        assert_eq!(stage_parts(&la[0..1], 2), 1);
    }
}
