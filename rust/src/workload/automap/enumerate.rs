//! Candidate enumeration: carve a [`LayerGraph`] — linear chain or true
//! fork/join DAG — into anchors along its topological order and
//! construct a concrete [`Mapping`] for any point of the (pipeline depth
//! x partition x per-layer engine x replication x hand-off) space —
//! packing analog MVM regions onto budget tiles greedily, column-major,
//! opening a new tile when the current one runs out of columns.
//!
//! Stages are contiguous *intervals over the topologically linearized
//! anchor list* — the exact partition axis the chain search always
//! used — and only the stage boundaries generalize: [`stage_edges`]
//! derives the stage-level dataflow from the anchor DAG, so two
//! branches cut into adjacent stages (with no edge between them) run
//! concurrently on their own cores without any new search dimension.
//!
//! The *walk* over the space lives in the parent module's
//! branch-and-bound search; this module owns the shared pieces both the
//! mapping constructor and the compositional cost engine must agree on
//! byte-for-byte: per-stage replication ([`stage_parts`]), analog
//! placement geometry ([`analog_shape`]), the stage dataflow
//! ([`AnchorDag`] / [`stage_edges`]), the greedy tile packer
//! ([`Packer`]), and the candidate descriptor ([`spec_desc`]).
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

use crate::nn::{LayerGraph, LayerKind, NodeId};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile::mapping::{
    Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::WorkloadError;

use super::TopologyBudget;

/// One mappable unit of a graph: at most one MVM-bearing layer plus its
/// elementwise companions, in dataflow order. Anchors are indexed in the
/// graph's topological order; every edge between anchors leaves from its
/// source anchor's *last* node (runs only fork at their endpoints), so
/// `out_width` is also the payload width of every outgoing anchor edge.
pub(crate) struct Anchor {
    pub nodes: Vec<NodeId>,
    pub mvm: Option<MvmInfo>,
    /// Activation width (elements) flowing out of this anchor.
    pub out_width: u64,
}

#[derive(Clone, Copy)]
pub(crate) enum MvmInfo {
    Dense { node: NodeId, rows: u64, cols: u64 },
    Lstm { node: NodeId, rows: u64, cols: u64 },
    Attention { node: NodeId, d_model: u64 },
    /// Per-inference conv as an im2col MVM (`im2col_rows x out_ch`) —
    /// DAG branches and conv chains, where the row-streamed pipeline's
    /// single-chain hand-off does not apply.
    Conv { node: NodeId, rows: u64, cols: u64 },
    /// MoE expert bank: all `experts` column slices side by side on one
    /// region; replication column-slices *every* expert, so automap's
    /// replica axis doubles as expert parallelism.
    Moe { node: NodeId, rows: u64, cols: u64, experts: u64, top_k: u64 },
}

impl MvmInfo {
    pub(crate) fn node(&self) -> NodeId {
        match self {
            MvmInfo::Dense { node, .. }
            | MvmInfo::Lstm { node, .. }
            | MvmInfo::Attention { node, .. }
            | MvmInfo::Conv { node, .. }
            | MvmInfo::Moe { node, .. } => *node,
        }
    }
}

fn err(msg: String) -> WorkloadError {
    WorkloadError::InvalidGraph(msg)
}

/// Split a validated graph — chain or DAG — into anchors. Returns the
/// anchors (in topological order) plus the graph's input and output
/// node ids.
///
/// The interior nodes are segmented into maximal *runs*: consecutive
/// topological positions stay in one run iff they are joined by a plain
/// chain edge (out-degree 1 into in-degree 1). All of a run's external
/// edges attach at its endpoints, so each run carves into anchors
/// exactly like the legacy linear chain — which is itself the
/// single-run case, carved bit-identically.
pub(crate) fn anchors(graph: &LayerGraph) -> Result<(Vec<Anchor>, NodeId, NodeId), WorkloadError> {
    if graph.nodes.len() < 3 {
        return Err(err("automap needs at least input -> layer -> output".into()));
    }
    graph.validate().map_err(|e| err(format!("automap rejects the graph: {e}")))?;
    let order = graph.topo_order().expect("validated graphs are acyclic");
    let widths = graph.node_widths().expect("validated graphs have widths");
    // validate() guarantees exactly one Input and one Output node.
    let find = |pick: fn(&LayerKind) -> bool| {
        graph.nodes.iter().find(|n| pick(&n.kind)).expect("validated").id
    };
    let input = find(|k| matches!(k, LayerKind::Input { .. }));
    let output = find(|k| matches!(k, LayerKind::Output { .. }));

    let mut out: Vec<Anchor> = Vec::new();
    let mut pending: Vec<NodeId> = Vec::new();
    let mut run_first_anchor = 0usize;
    let mut prev: Option<NodeId> = None;
    let mut flush_pending = |pending: &mut Vec<NodeId>, out: &mut Vec<Anchor>| {
        if !pending.is_empty() {
            let w = widths[*pending.last().expect("non-empty")];
            out.push(Anchor { nodes: std::mem::take(pending), mvm: None, out_width: w });
        }
    };
    for &id in order
        .iter()
        .filter(|&&id| !matches!(graph.nodes[id].kind, LayerKind::Input { .. } | LayerKind::Output { .. }))
    {
        let new_run = match prev {
            None => true,
            Some(p) => {
                !(graph.edges.contains(&(p, id))
                    && graph.succs(p).len() == 1
                    && graph.preds(id).len() == 1)
            }
        };
        if new_run {
            // The previous run's trailing elementwise tail becomes its
            // own MVM-less anchor; appending across runs would move
            // nodes onto another branch's stage.
            flush_pending(&mut pending, &mut out);
            run_first_anchor = out.len();
        }
        let node = &graph.nodes[id];
        let mvm = match node.kind {
            LayerKind::Dense { rows, cols, .. } => Some(MvmInfo::Dense { node: id, rows, cols }),
            LayerKind::LstmCell { x, n_h, .. } => {
                Some(MvmInfo::Lstm { node: id, rows: n_h + x, cols: 4 * n_h })
            }
            LayerKind::Attention { d_model, .. } => Some(MvmInfo::Attention { node: id, d_model }),
            LayerKind::Conv2d { ref layer, .. } => {
                Some(MvmInfo::Conv { node: id, rows: layer.im2col_rows(), cols: layer.out_ch })
            }
            LayerKind::MoE { rows, cols, experts, top_k, .. } => {
                Some(MvmInfo::Moe { node: id, rows, cols, experts, top_k })
            }
            LayerKind::Input { .. } | LayerKind::Output { .. } => {
                unreachable!("interior nodes only")
            }
            _ => None,
        };
        if let Some(m) = mvm {
            let mut nodes = std::mem::take(&mut pending);
            nodes.push(id);
            out.push(Anchor { nodes, mvm: Some(m), out_width: widths[id] });
        } else if out.len() > run_first_anchor {
            let last = out.last_mut().expect("run has an anchor");
            last.nodes.push(id);
            last.out_width = widths[id];
        } else {
            pending.push(id);
        }
        prev = Some(id);
    }
    flush_pending(&mut pending, &mut out);
    Ok((out, input, output))
}

/// Anchor-level dataflow of a graph: which anchors feed which (deduped,
/// ascending — anchors are topologically ordered, so every edge points
/// forward), and which anchors read the graph `Input` node directly.
/// Shared by `build_mapping` and the compositional cost engine so the
/// stage boundaries they derive cannot drift.
pub(crate) struct AnchorDag {
    pub succs: Vec<Vec<usize>>,
    pub preds: Vec<Vec<usize>>,
    /// Anchors with a direct edge from the graph `Input` node.
    pub reads_input: Vec<bool>,
    /// True when the anchor dataflow is the linear chain `0 -> 1 -> ..`
    /// with only anchor 0 reading the input — the legacy search space,
    /// and the only shape column replication is defined on.
    pub chain: bool,
}

pub(crate) fn anchor_dag(graph: &LayerGraph, anchors: &[Anchor], input: NodeId) -> AnchorDag {
    let mut anchor_of: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    for (ai, a) in anchors.iter().enumerate() {
        for &nid in &a.nodes {
            anchor_of[nid] = Some(ai);
        }
    }
    let n = anchors.len();
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut reads_input = vec![false; n];
    for &(u, v) in &graph.edges {
        if u == input {
            if let Some(&Some(av)) = anchor_of.get(v) {
                reads_input[av] = true;
            }
            continue;
        }
        if let (Some(&Some(au)), Some(&Some(av))) = (anchor_of.get(u), anchor_of.get(v)) {
            if au != av && !succs[au].contains(&av) {
                succs[au].push(av);
                preds[av].push(au);
            }
        }
    }
    for s in &mut succs {
        s.sort_unstable();
    }
    for p in &mut preds {
        p.sort_unstable();
    }
    let chain = (0..n).all(|i| {
        let s_ok = if i + 1 < n { succs[i] == [i + 1] } else { succs[i].is_empty() };
        s_ok && reads_input[i] == (i == 0)
    });
    AnchorDag { succs, preds, reads_input, chain }
}

/// Stage-boundary dataflow of one partition: ascending `(producer
/// stage, consumer stage, payload bytes)` edges, where the payload sums
/// `4 * out_width` over the distinct producer anchors feeding that
/// consumer stage (fp32 activations; a producer anchor feeding two
/// anchors of one consumer stage is sent once). For a chain partition
/// this is exactly the legacy consecutive-stage boundary list.
pub(crate) fn stage_edges(
    dag: &AnchorDag,
    anchors: &[Anchor],
    starts: &[usize],
) -> Vec<(usize, usize, u64)> {
    let stage_of = stage_of_anchors(starts, anchors.len());
    let mut edges: std::collections::BTreeMap<(usize, usize), u64> = std::collections::BTreeMap::new();
    for (ai, succ) in dag.succs.iter().enumerate() {
        let si = stage_of[ai];
        let mut seen: Vec<usize> = Vec::new();
        for &aj in succ {
            let sj = stage_of[aj];
            if sj != si && !seen.contains(&sj) {
                seen.push(sj);
                *edges.entry((si, sj)).or_insert(0) += 4 * anchors[ai].out_width;
            }
        }
    }
    edges.into_iter().map(|((a, b), w)| (a, b, w)).collect()
}

/// Stage index of every anchor under a starts-partition.
pub(crate) fn stage_of_anchors(starts: &[usize], n_anchors: usize) -> Vec<usize> {
    let mut stage_of = vec![0usize; n_anchors];
    for (si, &lo) in starts.iter().enumerate() {
        let hi = if si + 1 < starts.len() { starts[si + 1] } else { n_anchors };
        for a in stage_of.iter_mut().take(hi).skip(lo) {
            *a = si;
        }
    }
    stage_of
}

/// One point of the search space, small enough to hold for every
/// enumerated candidate (the full `Mapping` is rebuilt on demand).
#[derive(Clone, Debug)]
pub(crate) struct CandidateSpec {
    /// Stage start indices into the anchor list (`starts[0] == 0`).
    pub starts: Vec<usize>,
    /// Bit `i`: the `i`-th MVM anchor (in chain order) goes on AIMC.
    pub analog_mask: u64,
    /// Replication factor applied to every column-replicable stage.
    pub replicas: usize,
    pub handoff: Handoff,
}

/// Above this many MVM anchors, only the all-digital and all-analog
/// engine assignments are enumerated (the full 2^m mask space explodes).
pub(crate) const FULL_MASK_ANCHORS: usize = 12;

/// The engine-mask axis of the space for `m` MVM anchors, plus whether
/// it was reduced to the all-digital/all-analog extremes.
pub(crate) fn engine_masks(m: usize) -> (Vec<u64>, bool) {
    if m <= FULL_MASK_ANCHORS {
        ((0..(1u64 << m)).collect(), false)
    } else {
        (vec![0, (1u64 << m.min(63)) - 1], true)
    }
}

/// Engine bit of MVM anchor `idx` — the one mask reader every consumer
/// (descriptor, mapping constructor, cost engine, lower bounds) goes
/// through. Anchors past the u64 mask width read as digital instead of
/// shifting out of range (only reachable through the reduced-mask
/// extremes of 64+-MVM chains, where the "all-analog" seed is then
/// analog on the first 63 anchors — consistently so across every
/// reader).
pub(crate) fn mask_bit(mask: u64, idx: usize) -> bool {
    idx < 64 && (mask >> idx) & 1 == 1
}

/// Hard bound on materialized pipeline partitions (~tens of MB of cut
/// lists). `sum_{s<=8} C(n-1, s-1)` explodes combinatorially for deep
/// chains; past this bound the walk keeps the canonical prefix and
/// reports the space as truncated rather than exhausting memory.
pub(crate) const MAX_PARTITIONS: usize = 250_000;

/// Every way of cutting `n` anchors into 1..=`max_stages` contiguous
/// stages, as stage-start index lists — the subtree roots of the
/// branch-and-bound walk, in the canonical enumeration order (stage
/// count ascending, cut positions lexicographic). At most
/// `limit.min(MAX_PARTITIONS)` lists are materialized (a capped walk
/// can never consume more partitions than candidates, so callers pass
/// the candidate cap); the second return is true when the bound cut
/// the list short.
pub(crate) fn partitions(n: usize, max_stages: usize, limit: usize) -> (Vec<Vec<usize>>, bool) {
    let limit = limit.min(MAX_PARTITIONS);
    let mut out = Vec::new();
    let mut truncated = false;
    'all: for s in 1..=max_stages.min(n.max(1)).max(1) {
        let mut full = true;
        for_each_starts(n, s, &mut |starts| {
            if out.len() >= limit {
                full = false;
                return false;
            }
            out.push(starts.to_vec());
            true
        });
        if !full {
            truncated = true;
            break 'all;
        }
    }
    (out, truncated)
}

/// Visit every way of cutting `n` anchors into `s` contiguous stages,
/// passing the stage start indices. The visitor returns `false` to stop.
fn for_each_starts(n: usize, s: usize, f: &mut impl FnMut(&[usize]) -> bool) {
    let k = s - 1;
    if k == 0 {
        f(&[0]);
        return;
    }
    if k >= n {
        return;
    }
    // Combinations of k cut positions from 1..n, lexicographic.
    let mut c: Vec<usize> = (1..=k).collect();
    let mut starts = vec![0usize; s];
    loop {
        starts[1..].copy_from_slice(&c);
        if !f(&starts) {
            return;
        }
        let mut i = k;
        loop {
            if i == 0 {
                return;
            }
            i -= 1;
            if c[i] < n - k + i {
                c[i] += 1;
                for j in i + 1..k {
                    c[j] = c[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// Per-anchor half of the replication rule: can this anchor run inside
/// an `r`-way column-replicated stage? (Dense MVMs need exact column
/// slices; MoE banks slice every expert's columns, so replication acts
/// as expert parallelism; other MVMs pin their stage to one replica.)
pub(crate) fn anchor_replicable(a: &Anchor, r: u64) -> bool {
    match a.mvm {
        None => true,
        Some(MvmInfo::Dense { cols, .. }) | Some(MvmInfo::Moe { cols, .. }) => cols % r == 0,
        Some(_) => false,
    }
}

/// Replica count a stage actually runs with: `replicas` when every
/// anchor is replicable *and* the stage's output width slices exactly
/// (truncated slices would compile a smaller network than the r = 1
/// candidates and bias the search toward replication), else 1.
pub(crate) fn stage_parts(range: &[Anchor], replicas: usize) -> u64 {
    let r = replicas as u64;
    let replicable = r > 1
        && range.iter().all(|a| anchor_replicable(a, r))
        && range.last().expect("stages are non-empty").out_width % r == 0;
    if replicable {
        r
    } else {
        1
    }
}

/// Analog placement geometry of one MVM under a replication factor —
/// the single source of truth shared by the mapping constructor, the
/// tile-packing feasibility walk, and the profile emitter.
#[derive(Clone, Copy, Debug)]
pub(crate) enum AnalogShape {
    /// One `rows x slice` region per replica.
    Direct { rows: u64, slice: u64 },
    /// Tall matrix row-split over `k` stacked `sub x cols` regions with
    /// digital partial accumulation (Fig. 6b case 2). Non-divisible
    /// splits are rejected: the `rows / k` lowering would silently drop
    /// the remainder rows and bias the analog-vs-digital comparison.
    RowSplit { k: u64, sub: u64, cols: u64 },
    /// A single `rows x cols` region (LSTM gate block).
    One { rows: u64, cols: u64 },
    /// Four `d x d` projection regions (attention Wq|Wk|Wv|Wo).
    Quad { d: u64 },
}

pub(crate) fn analog_shape(mvm: &MvmInfo, parts: u64, tile_rows: u32, tile_cols: u32) -> Option<AnalogShape> {
    match *mvm {
        MvmInfo::Dense { rows, cols, .. } => {
            let slice = cols / parts;
            if rows <= tile_rows as u64 && slice <= tile_cols as u64 {
                Some(AnalogShape::Direct { rows, slice })
            } else if parts == 1
                && rows > tile_rows as u64
                && cols <= tile_cols as u64
                && rows % rows.div_ceil(tile_rows as u64) == 0
            {
                let k = rows.div_ceil(tile_rows as u64);
                Some(AnalogShape::RowSplit { k, sub: rows / k, cols })
            } else {
                None
            }
        }
        MvmInfo::Lstm { rows, cols, .. } => Some(AnalogShape::One { rows, cols }),
        MvmInfo::Attention { d_model, .. } => Some(AnalogShape::Quad { d: d_model }),
        MvmInfo::Conv { rows, cols, .. } => {
            // The im2col matrix must fit one region whole: the per-pixel
            // CM-op block queues all `rows` taps into a single tile.
            if rows <= tile_rows as u64 && cols <= tile_cols as u64 {
                Some(AnalogShape::Direct { rows, slice: cols })
            } else {
                None
            }
        }
        MvmInfo::Moe { rows, cols, experts, .. } => {
            // One region per replica holding every expert's column slice
            // side by side; only the routed top-k slices are dequeued.
            let slice = experts * (cols / parts);
            if rows <= tile_rows as u64 && slice <= tile_cols as u64 {
                Some(AnalogShape::Direct { rows, slice })
            } else {
                None
            }
        }
    }
}

/// Per-stage replica counts of a spec, with the core-budget,
/// channel-budget, and degenerate-replication checks applied — `None`
/// exactly when the spec is infeasible on those axes. The single
/// source of truth shared by `build_mapping` and the compositional
/// cost engine's `score`, so the two cannot drift.
pub(crate) fn stage_layout(
    anchors: &[Anchor],
    dag: &AnchorDag,
    spec: &CandidateSpec,
    budget: &TopologyBudget,
) -> Option<Vec<u64>> {
    // Column replication is defined on chain dataflow only: replicated
    // fork/join boundaries would need all-to-all slice exchanges the
    // stage hand-off does not model. Non-chain graphs search r = 1.
    if spec.replicas > 1 && !dag.chain {
        return None;
    }
    let s_count = spec.starts.len();
    let mut parts: Vec<u64> = Vec::with_capacity(s_count);
    let mut next_core = 0usize;
    let mut any_replicated = false;
    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        let p = stage_parts(&anchors[lo..hi], spec.replicas);
        any_replicated |= p > 1;
        next_core += p as usize;
        if next_core > budget.cores {
            return None;
        }
        parts.push(p);
    }
    if spec.replicas > 1 && !any_replicated {
        return None; // identical to the r = 1 spec
    }
    let mut channels = 0usize;
    for &(si, sj, _) in &stage_edges(dag, anchors, &spec.starts) {
        let fan = (parts[si] * parts[sj]) as usize;
        channels += fan * if spec.handoff == Handoff::SharedBuffer { 2 } else { 1 };
    }
    if channels > budget.channels {
        return None;
    }
    Some(parts)
}

/// Claim every tile region of one analog MVM shape through the packer,
/// in packing order with the shape's floor rules (fresh tile per
/// replica when replicated, per-sub-region floors for row splits,
/// the stage floor otherwise), feeding each claim to `sink` as
/// `(tile, col0, rows, cols)`. `None` when any region fails geometry
/// or the tile budget. The single packing walk shared by
/// `build_mapping` (which materializes placements) and the cost
/// engine's `score` (which only counts).
pub(crate) fn place_shape(
    packer: &mut Packer,
    budget: &TopologyBudget,
    stage_floor: usize,
    shape: &AnalogShape,
    parts: u64,
    mut sink: impl FnMut(usize, u32, u64, u64),
) -> Option<()> {
    match *shape {
        AnalogShape::Direct { rows, slice } => {
            for _ in 0..parts {
                // Replicas run on distinct cores, so each slice gets a
                // fresh tile when replicated.
                let floor = if parts > 1 { packer.count() } else { stage_floor };
                let (t, c0) = packer.place(budget, floor, rows, slice)?;
                sink(t, c0, rows, slice);
            }
        }
        AnalogShape::RowSplit { k, sub, cols } => {
            // Each sub-region gets its own tile — parallel crossbars
            // are the point of the split.
            for _ in 0..k {
                let floor = packer.count();
                let (t, c0) = packer.place(budget, floor, sub, cols)?;
                sink(t, c0, sub, cols);
            }
        }
        AnalogShape::One { rows, cols } => {
            let (t, c0) = packer.place(budget, stage_floor, rows, cols)?;
            sink(t, c0, rows, cols);
        }
        AnalogShape::Quad { d } => {
            for _ in 0..4 {
                let (t, c0) = packer.place(budget, stage_floor, d, d)?;
                sink(t, c0, d, d);
            }
        }
    }
    Some(())
}

/// Greedy column-major tile packer. Only the most recently opened tile
/// is ever reusable, so the full state is a tile count plus the open
/// tile's used columns — cheap enough to run per scored candidate.
/// `floor` is the first tile the current region may reuse: tiles are
/// core-private (tight coupling, Fig. 2), so callers pass the tile
/// count at their stage/replica boundary and regions never share a
/// tile across cores.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Packer {
    count: usize,
    open_cols: u32,
}

impl Packer {
    pub(crate) fn new() -> Packer {
        Packer::default()
    }

    pub(crate) fn count(&self) -> usize {
        self.count
    }

    /// Claim a `rows x cols` region: reuse the open tile when the region
    /// fits next to what is already there (and the tile is at or above
    /// `floor`), otherwise open a new tile. Returns the `(tile, col0)`
    /// of the claim, or `None` when the region is geometrically
    /// oversized or the tile budget is exhausted.
    pub(crate) fn place(
        &mut self,
        budget: &TopologyBudget,
        floor: usize,
        rows: u64,
        cols: u64,
    ) -> Option<(usize, u32)> {
        if rows == 0 || cols == 0 || rows > budget.tile_rows as u64 || cols > budget.tile_cols as u64 {
            return None;
        }
        let c = cols as u32;
        if let Some(last) = self.count.checked_sub(1) {
            if last >= floor && self.open_cols as u64 + c as u64 <= budget.tile_cols as u64 {
                let col0 = self.open_cols;
                self.open_cols += c;
                return Some((last, col0));
            }
        }
        if self.count >= budget.tiles {
            return None;
        }
        self.count += 1;
        self.open_cols = c;
        Some((self.count - 1, 0))
    }
}

/// Human-readable point in the search space, e.g. `"s2 r2 pp AD|DA"`
/// (stages, replicas, hand-off, engine per anchor with `.` for MVM-less
/// anchors and `|` at stage cuts). Unique per spec, so it doubles as the
/// deterministic ranking tie-break.
pub(crate) fn spec_desc(anchors: &[Anchor], spec: &CandidateSpec) -> String {
    let s_count = spec.starts.len();
    let mut pat = String::new();
    let mut mvm_idx = 0usize;
    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        for a in &anchors[lo..hi] {
            pat.push(match a.mvm {
                None => '.',
                Some(_) => {
                    let bit = mask_bit(spec.analog_mask, mvm_idx);
                    mvm_idx += 1;
                    if bit {
                        'A'
                    } else {
                        'D'
                    }
                }
            });
        }
        if si + 1 < s_count {
            pat.push('|');
        }
    }
    format!(
        "s{s_count} r{} {} {pat}",
        spec.replicas,
        match spec.handoff {
            Handoff::PingPong => "pp",
            Handoff::SharedBuffer => "sb",
        }
    )
}

/// Construct the `Mapping` of one spec, or `None` when the spec is
/// infeasible under the budget (tile geometry, tile count, core count,
/// channel count) or degenerate (replication requested but no stage
/// eligible). Also returns the descriptor from [`spec_desc`].
pub(crate) fn build_mapping(
    graph: &LayerGraph,
    anchors: &[Anchor],
    input_node: NodeId,
    output_node: NodeId,
    spec: &CandidateSpec,
    budget: &TopologyBudget,
) -> Option<(Mapping, String)> {
    let s_count = spec.starts.len();
    let dag = anchor_dag(graph, anchors, input_node);
    let parts_per_stage = stage_layout(anchors, &dag, spec, budget)?;
    let edges = stage_edges(&dag, anchors, &spec.starts);
    let mut stages: Vec<Stage> = Vec::with_capacity(s_count);
    let mut tiles: Vec<TileSpec> = Vec::new();
    let mut packer = Packer::new();
    let mut next_core = 0usize;
    let mut mvm_idx = 0usize;

    for si in 0..s_count {
        let lo = spec.starts[si];
        let hi = if si + 1 < s_count { spec.starts[si + 1] } else { anchors.len() };
        let range = &anchors[lo..hi];
        let parts_n = parts_per_stage[si];
        let parts = parts_n as usize;

        let mut st = Stage::on_core(next_core);
        if parts > 1 {
            st.cores = (next_core..next_core + parts).collect();
            st.split = SplitKind::Columns;
            st.barrier = true;
        }
        next_core += parts;
        // Tiles are core-private (tight coupling): this stage's single
        // core may pack onto tiles opened from here on, never onto a
        // previous stage's.
        let stage_floor = packer.count();

        for a in range {
            let analog = match a.mvm {
                Some(_) => {
                    let bit = mask_bit(spec.analog_mask, mvm_idx);
                    mvm_idx += 1;
                    bit
                }
                None => false,
            };
            for &nid in &a.nodes {
                let is_mvm = a.mvm.is_some_and(|mvm| mvm.node() == nid);
                if !is_mvm || !analog {
                    st.steps.push(Step::cpu(nid));
                    continue;
                }
                let mvm = a.mvm.expect("is_mvm checked");
                let node = mvm.node();
                let shape = analog_shape(&mvm, parts_n, budget.tile_rows, budget.tile_cols)?;
                let mut claims: Vec<TilePlacement> = Vec::new();
                place_shape(&mut packer, budget, stage_floor, &shape, parts_n, |tile, col0, rows, cols| {
                    while tiles.len() <= tile {
                        tiles.push(TileSpec {
                            rows: budget.tile_rows,
                            cols: budget.tile_cols,
                            coupling: Coupling::Tight,
                        });
                    }
                    claims.push(TilePlacement {
                        tile,
                        placement: Placement { row0: 0, col0, rows: rows as u32, cols: cols as u32 },
                    });
                })?;
                let place = match shape {
                    AnalogShape::Direct { .. } | AnalogShape::One { .. } => {
                        Place::Tile { per_replica: claims }
                    }
                    AnalogShape::RowSplit { .. } => Place::TileRowSplit { tiles: claims },
                    AnalogShape::Quad { .. } => {
                        let [q, k, v, o] = <[TilePlacement; 4]>::try_from(claims)
                            .expect("Quad shapes claim exactly four regions");
                        Place::AttentionTiles { q, k, v, o }
                    }
                };
                st.steps.push(Step { node, place });
            }
        }

        // Stage boundaries from the anchor dataflow. Chains reduce to
        // the legacy Memory -> Channel -> .. -> Memory shape exactly;
        // DAG partitions get Join inputs (with an optional direct tap
        // of the graph input) and Fanout outputs.
        let from: Vec<usize> = edges.iter().filter(|&&(_, t, _)| t == si).map(|&(p, _, _)| p).collect();
        let to: Vec<(usize, u64)> =
            edges.iter().filter(|&&(p, _, _)| p == si).map(|&(_, t, b)| (t, b)).collect();
        let taps_input = (lo..hi).any(|a| dag.reads_input[a]);
        st.input = if from.is_empty() {
            // Stage 0, or a branch fed straight from the graph input.
            StageInput::Memory { node: input_node }
        } else if from == [si - 1] && !taps_input {
            StageInput::Channel
        } else {
            let mem = if taps_input { Some(input_node) } else { None };
            StageInput::Join { mem, from }
        };
        st.output = if to.is_empty() {
            StageOutput::Memory { node: output_node }
        } else if to.len() == 1 && to[0].0 == si + 1 {
            StageOutput::Channel { bytes: to[0].1 / parts as u64 }
        } else {
            StageOutput::Fanout { to }
        };
        st.handoff = spec.handoff;
        stages.push(st);
    }

    let desc = spec_desc(anchors, spec);
    let label = format!("automap/{desc}");
    Some((Mapping { label, tiles, min_mutexes: 0, stages }, desc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_chain_splits_into_dense_anchors() {
        let g = LayerGraph::mlp(&[64, 32, 16]);
        let (a, input, output) = anchors(&g).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!((input, output), (0, 5));
        assert!(matches!(a[0].mvm, Some(MvmInfo::Dense { rows: 64, cols: 32, .. })));
        assert_eq!(a[0].out_width, 32);
        assert_eq!(a[1].out_width, 16);
        // Each anchor holds its dense + relu.
        assert_eq!(a[0].nodes, vec![1, 2]);
    }

    #[test]
    fn transformer_chain_attaches_leading_norms() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let (a, _, _) = anchors(&g).unwrap();
        // attention anchor, FFN-up anchor, FFN-down anchor
        assert_eq!(a.len(), 3);
        assert!(matches!(a[0].mvm, Some(MvmInfo::Attention { d_model: 64, .. })));
        // The pre-attention LayerNorm rides in the attention anchor.
        assert_eq!(a[0].nodes[0], 1);
        assert_eq!(a[2].out_width, 64);
    }

    #[test]
    fn non_chain_graphs_are_rejected() {
        let mut g = LayerGraph::new("dag");
        let i = g.add(LayerKind::Input { bytes: 64, marshal_insts: 4, raw_bytes: 16 });
        let d = g.chain(i, LayerKind::Dense { rows: 16, cols: 16, weight_slot: 0 });
        let o = g.chain(d, LayerKind::Output { bytes: 64 });
        g.edges.push((i, o)); // skip edge -> not a chain
        assert!(anchors(&g).is_err());
    }

    #[test]
    fn starts_enumeration_counts_compositions() {
        // 4 anchors into 2 stages: C(3,1) = 3 compositions.
        let mut seen = Vec::new();
        for_each_starts(4, 2, &mut |s| {
            seen.push(s.to_vec());
            true
        });
        assert_eq!(seen, vec![vec![0, 1], vec![0, 2], vec![0, 3]]);
    }

    #[test]
    fn partitions_cover_all_depths_in_order() {
        let (p, truncated) = partitions(4, 3, usize::MAX);
        // s=1: 1; s=2: C(3,1)=3; s=3: C(3,2)=3.
        assert!(!truncated);
        assert_eq!(p.len(), 7);
        assert_eq!(p[0], vec![0]);
        assert_eq!(p[1], vec![0, 1]);
        assert_eq!(p[6], vec![0, 2, 3]);
        // Depth never exceeds the anchor count.
        assert_eq!(partitions(2, 8, usize::MAX).0.len(), 2);
        // Combinatorial blow-ups are bounded, kept to the canonical
        // prefix, and reported as truncated instead of exhausting memory.
        let (big, big_truncated) = partitions(60, 8, usize::MAX);
        assert!(big_truncated);
        assert_eq!(big.len(), MAX_PARTITIONS);
        assert_eq!(big[0], vec![0]);
        // A candidate cap bounds the materialization too.
        let (capped, capped_truncated) = partitions(60, 8, 10);
        assert!(capped_truncated);
        assert_eq!(capped.len(), 10);
    }

    #[test]
    fn packer_opens_new_tile_when_columns_run_out() {
        let budget = TopologyBudget { cores: 4, tiles: 3, tile_rows: 64, tile_cols: 100, channels: 8 };
        let mut p = Packer::new();
        let a = p.place(&budget, 0, 64, 60).unwrap();
        let b = p.place(&budget, 0, 32, 30).unwrap();
        let c = p.place(&budget, 0, 64, 60).unwrap();
        assert_eq!((a.0, b.0, c.0), (0, 0, 1));
        assert_eq!(b.1, 60);
        // A raised floor (next pipeline stage / replica) never reuses an
        // earlier core's open tile even though columns remain.
        let d = p.place(&budget, 2, 16, 10).unwrap();
        assert_eq!(d, (2, 0));
        // Budget of 3 tiles exhausted.
        assert!(p.place(&budget, 3, 64, 90).is_none());
        // Oversized regions never fit.
        assert!(p.place(&budget, 0, 65, 10).is_none());
    }

    #[test]
    fn spec_desc_matches_build_mapping() {
        let g = LayerGraph::mlp(&[64, 32, 16]);
        let (a, input, output) = anchors(&g).unwrap();
        let budget = TopologyBudget { cores: 4, tiles: 4, tile_rows: 64, tile_cols: 64, channels: 8 };
        let spec = CandidateSpec {
            starts: vec![0, 1],
            analog_mask: 0b10,
            replicas: 1,
            handoff: Handoff::SharedBuffer,
        };
        let (_, desc) = build_mapping(&g, &a, input, output, &spec, &budget).unwrap();
        assert_eq!(desc, spec_desc(&a, &spec));
        assert_eq!(desc, "s2 r1 sb D|A");
    }

    #[test]
    fn stage_parts_requires_exact_slices() {
        let g = LayerGraph::mlp(&[64, 48, 16]);
        let (a, _, _) = anchors(&g).unwrap();
        // 48 % 4 == 0 and out widths divide: both anchors replicate at 2.
        assert_eq!(stage_parts(&a[0..1], 2), 2);
        // 48 % 32 != 0: not replicable at 32.
        assert_eq!(stage_parts(&a[0..1], 32), 1);
        // A non-Dense MVM pins the stage to one replica.
        let lg = LayerGraph::lstm(&crate::nn::LstmModel::paper(750));
        let (la, _, _) = anchors(&lg).unwrap();
        assert_eq!(stage_parts(&la[0..1], 2), 1);
    }
}
