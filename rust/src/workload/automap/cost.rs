//! The fast analytic cost model that prunes the mapping space.
//!
//! Rather than duplicating per-layer formulas (which would drift from
//! the compiler), the model compiles the candidate to its real trace
//! (two inferences) and walks the ops with closed-form timing: issue
//! cycles per instruction class, stream stalls classified by working-set
//! residency, AIMC I/O at the port throughput, the 100 ns MVM latency on
//! the dependent dequeue, and the calibrated channel/mutex constants.
//! No cache state, no event scheduling — O(ops), microseconds per
//! candidate — while staying within a small factor of the simulator
//! (pinned by `tests/automap.rs::cost_model_tracks_simulated_cycles`).
//!
//! Pipeline steady-state throughput is the slowest core, so the
//! per-inference estimate is the max over per-core estimates.

use crate::config::SystemConfig;
use crate::nn::LayerGraph;
use crate::sim::aimc::Coupling;
use crate::workload::compile::{self, mapping::Mapping};
use crate::workload::trace::TraceOp;
use crate::workload::{addr, costs, WorkloadError};

/// Analytic per-inference estimate of one mapped workload.
#[derive(Clone, Debug)]
pub struct CostEstimate {
    /// Steady-state cycles per inference (max over cores).
    pub cycles_per_inf: f64,
    /// Per-core cycles per inference, trace order.
    pub per_core_cycles: Vec<f64>,
    /// Coarse energy per inference (core active/idle + static + DRAM +
    /// AIMC), joules.
    pub energy_per_inf_j: f64,
}

/// Fraction of the LLC a streamed working set may occupy and still be
/// classified as cache-resident.
const LLC_RESIDENT_FRACTION: f64 = 0.7;
/// Miss-path overhead beyond the raw DRAM latency (bus frontend/forward
/// hops), cycles.
const MISS_OVERHEAD_CYCLES: f64 = 10.0;

/// Estimate one candidate. Compiles the mapping (two inferences, so
/// steady-state effects like shared-buffer acks are represented) and
/// walks the traces.
pub fn estimate(graph: &LayerGraph, mapping: &Mapping, cfg: &SystemConfig) -> Result<CostEstimate, WorkloadError> {
    const N_INF: f64 = 2.0;
    let w = compile::compile(graph, mapping, N_INF as u32)?;

    // Channel payloads (a Recv op does not carry the message size).
    // Walks visit each stored op once with its `Rep` multiplicity, so
    // looped traces cost one period regardless of the inference count;
    // strided ops report iteration-0 addresses, which is region-exact
    // (the synthetic address regions are stride-closed).
    let mut ch_bytes = vec![0u64; w.spec.channels.len()];
    for trace in &w.traces {
        trace.for_each_weighted(&mut |op, _| {
            if let TraceOp::Send { ch, bytes, .. } = op {
                if ch_bytes[ch] == 0 {
                    ch_bytes[ch] = bytes;
                }
            }
        });
    }

    // Residency classification: per-inference streamed working sets.
    let (mut weight_bytes, mut kv_bytes) = (0u64, 0u64);
    for trace in &w.traces {
        trace.for_each_weighted(&mut |op, mult| {
            if let TraceOp::MemStream { base, bytes, .. } = op {
                if (addr::WEIGHTS..addr::INPUTS).contains(&base) {
                    weight_bytes += mult * bytes;
                } else if base >= addr::KV {
                    kv_bytes += mult * bytes;
                }
            }
        });
    }
    weight_bytes = (weight_bytes as f64 / N_INF) as u64;
    kv_bytes = (kv_bytes as f64 / N_INF) as u64;
    let llc_budget = (cfg.llc.size_bytes as f64 * LLC_RESIDENT_FRACTION) as u64;
    let weights_resident = weight_bytes <= llc_budget;
    let kv_resident =
        kv_bytes <= llc_budget.saturating_sub(if weights_resident { weight_bytes } else { 0 });

    let freq = cfg.freq_hz;
    let line = 64f64;
    let hit_stall = cfg.llc.hit_latency_cycles as f64;
    let miss_stall = cfg.dram_latency_s * freq + hit_stall + MISS_OVERHEAD_CYCLES;
    let proc_cycles = cfg.aimc.process_latency_s * freq;
    let tight_cyc_per_byte = freq / cfg.aimc.io_throughput_bps;

    let mut per_core: Vec<f64> = Vec::with_capacity(w.traces.len());
    let mut dram_lines = 0f64;
    let mut aimc_j = 0f64;
    for trace in &w.traces {
        let mut cyc = 0f64;
        // Per-op costs are position-independent, so walking one `Rep`
        // period and multiplying by its count is exactly the flattened
        // walk — O(stored ops), not O(executed ops).
        trace.for_each_weighted(&mut |op, mult| {
            let mult = mult as f64;
            match op {
                TraceOp::Compute { class, insts } => cyc += mult * (insts * class.cycles()) as f64,
                TraceOp::MemStream { base, bytes, insts_per_line, prefetchable, .. } => {
                    let lines = (bytes as f64 / line).ceil().max(1.0);
                    let stall = if (addr::WEIGHTS..addr::INPUTS).contains(&base) {
                        if weights_resident {
                            hit_stall
                        } else {
                            dram_lines += mult * lines;
                            miss_stall
                        }
                    } else if base >= addr::KV {
                        if kv_resident {
                            hit_stall
                        } else {
                            dram_lines += mult * lines;
                            miss_stall
                        }
                    } else if (addr::INPUTS..addr::ACTIVATIONS).contains(&base) {
                        // Fresh per-inference data is always cold.
                        dram_lines += mult * lines;
                        miss_stall
                    } else {
                        hit_stall
                    };
                    let stall_total = if prefetchable {
                        stall + (lines - 1.0) * stall / costs::PREFETCH_DEPTH as f64
                    } else {
                        lines * stall
                    };
                    cyc += mult * (lines * insts_per_line as f64 + stall_total);
                }
                TraceOp::CmQueue { tile, bytes } => {
                    cyc += mult
                        * cm_io_cycles(&w.spec.tiles[tile].coupling, bytes, cfg, tight_cyc_per_byte, 0.0);
                    aimc_j += mult * bytes as f64 * cfg.aimc.io_energy_j_per_byte();
                }
                TraceOp::CmProcess { tile } => {
                    cyc += mult;
                    let t = &w.spec.tiles[tile];
                    aimc_j += mult * cfg.aimc.mvm_energy_j(t.rows, t.cols);
                    if t.coupling == Coupling::Loose {
                        cyc += mult * proc_cycles;
                    }
                }
                TraceOp::CmDequeue { tile, bytes } => {
                    // The dependent dequeue observes the 100 ns MVM.
                    let wait = if w.spec.tiles[tile].coupling == Coupling::Tight { proc_cycles } else { 0.0 };
                    cyc += mult
                        * cm_io_cycles(&w.spec.tiles[tile].coupling, bytes, cfg, tight_cyc_per_byte, wait);
                    aimc_j += mult * bytes as f64 * cfg.aimc.io_energy_j_per_byte();
                }
                TraceOp::Send { bytes, .. } => {
                    cyc += mult * (costs::CHANNEL_INSTS as f64 + (bytes as f64 / line).ceil() * 2.0);
                }
                TraceOp::Recv { ch } => {
                    let lines = (ch_bytes[ch] as f64 / line).ceil();
                    cyc += mult * (costs::CHANNEL_INSTS as f64 + lines * (1.0 + hit_stall / 2.0));
                }
                TraceOp::MutexLock { .. } => cyc += mult * costs::MUTEX_INSTS as f64,
                TraceOp::MutexUnlock { .. } => cyc += mult * costs::MUTEX_INSTS as f64 / 2.0,
                TraceOp::CmInit { .. } => cyc += mult,
                TraceOp::RoiPush { .. } | TraceOp::RoiPop => {}
            }
        });
        per_core.push(cyc / N_INF);
    }
    dram_lines /= N_INF;
    aimc_j /= N_INF;

    let cycles_per_inf = per_core.iter().copied().fold(1.0, f64::max);
    let p = &cfg.power;
    let active_j: f64 = per_core.iter().map(|c| c * p.active_core_j_per_cycle).sum();
    let idle_j: f64 = per_core
        .iter()
        .map(|c| (cycles_per_inf - c) * p.idle_core_j_per_cycle)
        .sum::<f64>()
        + cfg.num_cores.saturating_sub(per_core.len()) as f64
            * cycles_per_inf
            * p.idle_core_j_per_cycle;
    let t_inf_s = cycles_per_inf / freq;
    let static_j = (p.mem_ctrl_io_w + p.llc_leakage_w(cfg.llc.size_bytes)) * t_inf_s;
    let energy_per_inf_j = active_j + idle_j + static_j + dram_lines * p.dram_j_per_access + aimc_j;

    Ok(CostEstimate { cycles_per_inf, per_core_cycles: per_core, energy_per_inf_j })
}

/// Cycles of one CM_QUEUE/CM_DEQUEUE: the beat issue overlaps the device
/// transfer, so the op costs whichever is longer — plus `extra_wait`
/// device cycles the transfer cannot start before (the pending MVM).
fn cm_io_cycles(
    coupling: &Coupling,
    bytes: u64,
    cfg: &SystemConfig,
    tight_cyc_per_byte: f64,
    extra_wait: f64,
) -> f64 {
    let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST) as f64;
    let active = beats * (1.0 + costs::CM_IO_OVERHEAD_PER_INST_X1000 as f64 / 1000.0);
    let transfer = match coupling {
        Coupling::Tight => bytes as f64 * tight_cyc_per_byte,
        Coupling::Loose => {
            (cfg.aimc.pio_transaction_s + bytes as f64 / cfg.aimc.pio_throughput_bps) * cfg.freq_hz
        }
    };
    active.max(extra_wait + transfer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mlp::{self, MlpCase};

    fn est(case: MlpCase) -> CostEstimate {
        let (g, m) = mlp::case_table(case).unwrap();
        estimate(&g, &m, &SystemConfig::high_power()).unwrap()
    }

    #[test]
    fn analog_estimated_faster_than_digital() {
        let dig = est(MlpCase::Digital { cores: 1 });
        let ana = est(MlpCase::Analog { case: 1 });
        assert!(
            ana.cycles_per_inf * 4.0 < dig.cycles_per_inf,
            "analog {} vs digital {}",
            ana.cycles_per_inf,
            dig.cycles_per_inf
        );
        assert!(ana.energy_per_inf_j < dig.energy_per_inf_j);
    }

    #[test]
    fn pipeline_estimate_takes_the_max_stage() {
        let two = est(MlpCase::Digital { cores: 2 });
        assert_eq!(two.per_core_cycles.len(), 2);
        let max = two.per_core_cycles.iter().copied().fold(0.0, f64::max);
        assert_eq!(two.cycles_per_inf, max);
        // Splitting the two layers roughly halves the per-inference bound.
        let one = est(MlpCase::Digital { cores: 1 });
        assert!(two.cycles_per_inf < 0.8 * one.cycles_per_inf);
    }

    #[test]
    fn estimates_are_deterministic() {
        let a = est(MlpCase::Analog { case: 3 });
        let b = est(MlpCase::Analog { case: 3 });
        assert_eq!(a.cycles_per_inf.to_bits(), b.cycles_per_inf.to_bits());
        assert_eq!(a.energy_per_inf_j.to_bits(), b.energy_per_inf_j.to_bits());
    }
}
