//! The fast analytic cost models that prune the mapping space.
//!
//! Two engines share one set of per-op timing formulas:
//!
//! * [`estimate`] — the **oracle**: compiles the candidate to its real
//!   trace (two inferences) and walks the ops with closed-form timing.
//!   O(ops) per candidate, exact by construction, but the compile
//!   dominates large searches.
//! * [`CostEngine`] — the **compositional** engine: compiles each anchor
//!   region *in isolation* once per `(anchor, engine, replication)`
//!   combination (O(anchors x engines x shapes) compiles per search),
//!   then scores any candidate by composing the cached profiles across
//!   its pipeline partition, replication factor, and hand-off kind plus
//!   closed-form boundary terms (channel sends/receives, barrier
//!   mutexes, shared-buffer acks, CM_INITIALIZE preambles). Because the
//!   profiles are emitted by the *same* lowering rules the compiler
//!   uses ([`compile::emit_step`]) and walked by the *same* per-op
//!   formulas, a composed score covers exactly the op multiset of the
//!   compiled trace — it differs from the oracle only in f64 summation
//!   order (sub-ulp), so candidate ranking and the Pareto front agree
//!   up to exact-tie round-off (gated by `tests/automap.rs`).
//!
//! Per-op timing: issue cycles per instruction class, stream stalls
//! classified by working-set residency, AIMC I/O at the port
//! throughput, the 100 ns MVM latency on the dependent dequeue, and the
//! calibrated channel/mutex constants. No cache state, no event
//! scheduling — microseconds per compiled walk, sub-microsecond per
//! composed score.
//!
//! Pipeline steady-state throughput is the slowest core, so the
//! per-inference estimate is the max over per-core estimates.

use crate::config::SystemConfig;
use crate::nn::{LayerGraph, LayerKind};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile::cache::CompileCache;
use crate::workload::compile::mapping::{Handoff, Mapping, Place, Step, TilePlacement};
use crate::workload::compile::{self, CacheCtx, FragSpan, ACK_BYTES};
use crate::workload::trace::{Segment, TraceBuilder, TraceOp};
use crate::workload::{addr, costs, WorkloadError};
use std::sync::Mutex;

use super::enumerate::{
    analog_shape, anchor_dag, anchor_replicable, mask_bit, place_shape, stage_edges, stage_layout,
    AnalogShape, Anchor, AnchorDag, CandidateSpec, MvmInfo, Packer,
};
use super::TopologyBudget;

/// Analytic per-inference estimate of one mapped workload.
#[derive(Clone, Debug)]
pub struct CostEstimate {
    /// Steady-state cycles per inference (max over cores).
    pub cycles_per_inf: f64,
    /// Per-core cycles per inference, trace order.
    pub per_core_cycles: Vec<f64>,
    /// Coarse energy per inference (core active/idle + static + DRAM +
    /// AIMC), joules.
    pub energy_per_inf_j: f64,
}

/// Fraction of the LLC a streamed working set may occupy and still be
/// classified as cache-resident.
const LLC_RESIDENT_FRACTION: f64 = 0.7;
/// Miss-path overhead beyond the raw DRAM latency (bus frontend/forward
/// hops), cycles.
const MISS_OVERHEAD_CYCLES: f64 = 10.0;
/// Inferences the oracle compiles per candidate (steady-state effects
/// like shared-buffer acks appear from inference 1 on).
const N_INF: f64 = 2.0;

/// Per-config timing constants shared by both engines.
#[derive(Clone, Debug)]
pub(crate) struct Consts {
    hit_stall: f64,
    miss_stall: f64,
    proc_cycles: f64,
    tight_cyc_per_byte: f64,
    llc_budget: u64,
}

impl Consts {
    pub(crate) fn new(cfg: &SystemConfig) -> Consts {
        let freq = cfg.freq_hz;
        let hit_stall = cfg.llc.hit_latency_cycles as f64;
        Consts {
            hit_stall,
            miss_stall: cfg.dram_latency_s * freq + hit_stall + MISS_OVERHEAD_CYCLES,
            proc_cycles: cfg.aimc.process_latency_s * freq,
            tight_cyc_per_byte: freq / cfg.aimc.io_throughput_bps,
            llc_budget: (cfg.llc.size_bytes as f64 * LLC_RESIDENT_FRACTION) as u64,
        }
    }
}

/// A residency-parametric cost accumulator: every op's cycles either
/// land in `fixed` or in a per-region stall coefficient, so the same
/// walked profile can be priced under any (weights, kv) residency
/// outcome. Byte totals stay integral so the residency *classification*
/// is bit-identical between the oracle and the compositional engine.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Profile {
    fixed: f64,
    w_stall: f64,
    w_lines: f64,
    kv_stall: f64,
    kv_lines: f64,
    dram_lines: f64,
    aimc_j: f64,
    w_bytes: u64,
    kv_bytes: u64,
}

impl Profile {
    /// Fold one trace op (with its `Rep` multiplicity) into the profile.
    /// `ch_bytes` resolves Recv payloads (a Recv op does not carry the
    /// message size); profiles emitted from isolated anchor regions
    /// contain no channel ops and may pass an empty slice.
    pub(crate) fn absorb(
        &mut self,
        op: TraceOp,
        mult: u64,
        tiles: &[TileSpec],
        ch_bytes: &[u64],
        cfg: &SystemConfig,
        k: &Consts,
    ) {
        let line = 64f64;
        let multi = mult;
        let mult = mult as f64;
        match op {
            TraceOp::Compute { class, insts } => self.fixed += mult * (insts * class.cycles()) as f64,
            TraceOp::MemStream { base, bytes, insts_per_line, prefetchable, .. } => {
                let lines = (bytes as f64 / line).ceil().max(1.0);
                // Prefetchable streams overlap misses beyond the first.
                let stall_mult = if prefetchable {
                    1.0 + (lines - 1.0) / costs::PREFETCH_DEPTH as f64
                } else {
                    lines
                };
                self.fixed += mult * lines * insts_per_line as f64;
                if (addr::WEIGHTS..addr::INPUTS).contains(&base) {
                    self.w_stall += mult * stall_mult;
                    self.w_lines += mult * lines;
                    self.w_bytes += multi * bytes;
                } else if base >= addr::KV {
                    self.kv_stall += mult * stall_mult;
                    self.kv_lines += mult * lines;
                    self.kv_bytes += multi * bytes;
                } else if (addr::INPUTS..addr::ACTIVATIONS).contains(&base) {
                    // Fresh per-inference data is always cold.
                    self.fixed += mult * stall_mult * k.miss_stall;
                    self.dram_lines += mult * lines;
                } else {
                    self.fixed += mult * stall_mult * k.hit_stall;
                }
            }
            TraceOp::CmQueue { tile, bytes } => {
                self.fixed +=
                    mult * cm_io_cycles(&tiles[tile].coupling, bytes, cfg, k.tight_cyc_per_byte, 0.0);
                self.aimc_j += mult * bytes as f64 * cfg.aimc.io_energy_j_per_byte();
            }
            TraceOp::CmProcess { tile } => {
                self.fixed += mult;
                let t = &tiles[tile];
                self.aimc_j += mult * cfg.aimc.mvm_energy_j(t.rows, t.cols);
                if t.coupling == Coupling::Loose {
                    self.fixed += mult * k.proc_cycles;
                }
            }
            TraceOp::CmDequeue { tile, bytes } => {
                // The dependent dequeue observes the 100 ns MVM.
                let wait = if tiles[tile].coupling == Coupling::Tight { k.proc_cycles } else { 0.0 };
                self.fixed +=
                    mult * cm_io_cycles(&tiles[tile].coupling, bytes, cfg, k.tight_cyc_per_byte, wait);
                self.aimc_j += mult * bytes as f64 * cfg.aimc.io_energy_j_per_byte();
            }
            TraceOp::Send { bytes, .. } => self.fixed += mult * send_cycles(bytes),
            TraceOp::Recv { ch } => self.fixed += mult * recv_cycles(ch_bytes[ch], k),
            TraceOp::MutexLock { .. } => self.fixed += mult * costs::MUTEX_INSTS as f64,
            TraceOp::MutexUnlock { .. } => self.fixed += mult * costs::MUTEX_INSTS as f64 / 2.0,
            TraceOp::CmInit { .. } => self.fixed += mult,
            TraceOp::RoiPush { .. } | TraceOp::RoiPop => {}
        }
    }

    pub(crate) fn add(&mut self, o: &Profile) {
        self.fixed += o.fixed;
        self.w_stall += o.w_stall;
        self.w_lines += o.w_lines;
        self.kv_stall += o.kv_stall;
        self.kv_lines += o.kv_lines;
        self.dram_lines += o.dram_lines;
        self.aimc_j += o.aimc_j;
        self.w_bytes += o.w_bytes;
        self.kv_bytes += o.kv_bytes;
    }

    /// Price the profile under a residency outcome.
    pub(crate) fn cycles(&self, w_resident: bool, kv_resident: bool, k: &Consts) -> f64 {
        let w = if w_resident { k.hit_stall } else { k.miss_stall };
        let kv = if kv_resident { k.hit_stall } else { k.miss_stall };
        self.fixed + self.w_stall * w + self.kv_stall * kv
    }

    fn dram(&self, w_resident: bool, kv_resident: bool) -> f64 {
        let mut d = self.dram_lines;
        if !w_resident {
            d += self.w_lines;
        }
        if !kv_resident {
            d += self.kv_lines;
        }
        d
    }
}

/// One ping-pong channel send of `bytes`.
fn send_cycles(bytes: u64) -> f64 {
    costs::CHANNEL_INSTS as f64 + (bytes as f64 / 64.0).ceil() * 2.0
}

/// One channel receive of a `bytes`-sized message (drained line by line
/// out of the LLC-resident channel buffer).
fn recv_cycles(bytes: u64, k: &Consts) -> f64 {
    costs::CHANNEL_INSTS as f64 + (bytes as f64 / 64.0).ceil() * (1.0 + k.hit_stall / 2.0)
}

/// Residency classification from per-inference streamed working sets.
fn residency(weight_bytes: u64, kv_bytes: u64, k: &Consts) -> (bool, bool) {
    let weights_resident = weight_bytes <= k.llc_budget;
    let kv_resident =
        kv_bytes <= k.llc_budget.saturating_sub(if weights_resident { weight_bytes } else { 0 });
    (weights_resident, kv_resident)
}

/// Assemble the estimate from per-inference per-core cycles + DRAM/AIMC
/// totals — the shared back end of both engines.
fn finish(per_core: Vec<f64>, dram_lines: f64, aimc_j: f64, cfg: &SystemConfig) -> CostEstimate {
    let cycles_per_inf = per_core.iter().copied().fold(1.0, f64::max);
    let p = &cfg.power;
    let active_j: f64 = per_core.iter().map(|c| c * p.active_core_j_per_cycle).sum();
    let idle_j: f64 = per_core
        .iter()
        .map(|c| (cycles_per_inf - c) * p.idle_core_j_per_cycle)
        .sum::<f64>()
        + cfg.num_cores.saturating_sub(per_core.len()) as f64
            * cycles_per_inf
            * p.idle_core_j_per_cycle;
    let t_inf_s = cycles_per_inf / cfg.freq_hz;
    let static_j = (p.mem_ctrl_io_w + p.llc_leakage_w(cfg.llc.size_bytes)) * t_inf_s;
    let energy_per_inf_j = active_j + idle_j + static_j + dram_lines * p.dram_j_per_access + aimc_j;
    CostEstimate { cycles_per_inf, per_core_cycles: per_core, energy_per_inf_j }
}

/// Estimate one candidate through the **oracle** path: compile the
/// mapping (two inferences) and walk the real traces.
///
/// Runs [`estimate_with`] over a private disabled compile cache, so the
/// walk takes the exact fragment-grouped code path a cache-backed
/// search uses — cached and uncached scores are bit-identical by
/// construction, not by numerical luck.
pub fn estimate(graph: &LayerGraph, mapping: &Mapping, cfg: &SystemConfig) -> Result<CostEstimate, WorkloadError> {
    estimate_with(graph, mapping, cfg, &Mutex::new(CompileCache::new(false)))
}

/// The oracle against a shared compile cache: the candidate compiles in
/// *scoring mode* — cached step fragments are recorded as spans, never
/// materialized — and the walk absorbs the glue ops individually while
/// adding one memoized [`Profile`] per fragment. A cache hit therefore
/// skips both the step's lowering and its per-op walk; only the
/// candidate-specific glue (wiring, boundary phases, preambles) is
/// re-priced.
pub(crate) fn estimate_with(
    graph: &LayerGraph,
    mapping: &Mapping,
    cfg: &SystemConfig,
    cache: &Mutex<CompileCache>,
) -> Result<CostEstimate, WorkloadError> {
    let mut spans: Vec<Vec<FragSpan>> = Vec::new();
    let w = {
        let mut ctx = CacheCtx::scoring(cache, &mut spans);
        compile::compile_with(graph, mapping, N_INF as u32, Some(&mut ctx))?
    };
    let k = Consts::new(cfg);

    // Channel payloads (a Recv op does not carry the message size).
    // Walks visit each stored op once with its `Rep` multiplicity, so
    // looped traces cost one period regardless of the inference count;
    // strided ops report iteration-0 addresses, which is region-exact
    // (the synthetic address regions are stride-closed). Fragments are
    // channel-free by construction, so the thinned traces carry every
    // Send.
    let mut ch_bytes = vec![0u64; w.spec.channels.len()];
    for trace in &w.traces {
        trace.for_each_weighted(&mut |op, _| {
            if let TraceOp::Send { ch, bytes, .. } = op {
                if ch_bytes[ch] == 0 {
                    ch_bytes[ch] = bytes;
                }
            }
        });
    }

    // Per-op costs are position-independent, so walking one `Rep`
    // period and multiplying by its count is exactly the flattened
    // walk — O(stored ops), not O(executed ops). Cores with recorded
    // fragment spans walk glue ops + memoized fragment profiles
    // instead; cores without (row-streamed stages, whose loops the
    // cache bypasses) keep the weighted walk.
    let profiles: Vec<Profile> = w
        .traces
        .iter()
        .enumerate()
        .map(|(core, trace)| {
            let mut p = Profile::default();
            let core_spans = spans.get(core).map_or(&[][..], Vec::as_slice);
            if core_spans.is_empty() {
                trace.for_each_weighted(&mut |op, mult| {
                    p.absorb(op, mult, &w.spec.tiles, &ch_bytes, cfg, &k);
                });
                return p;
            }
            // Span positions index the flat op stream; per-inference
            // stage cores never emit loop segments at N_INF = 2.
            let ops: &[TraceOp] = match trace.segments.as_slice() {
                [Segment::Ops(v)] => v,
                _ => unreachable!("span-recorded traces are flat"),
            };
            let mut pos = 0usize;
            let mut c = cache.lock().expect("compile cache poisoned");
            for sp in core_spans {
                for &op in &ops[pos..sp.pos] {
                    p.absorb(op, 1, &w.spec.tiles, &ch_bytes, cfg, &k);
                }
                pos = sp.pos;
                let fp = c.profile_for(sp.frag, &sp.specs, |frag_ops, specs| {
                    let mut q = Profile::default();
                    for &op in frag_ops {
                        q.absorb(op, 1, specs, &[], cfg, &k);
                    }
                    q
                });
                p.add(&fp);
            }
            for &op in &ops[pos..] {
                p.absorb(op, 1, &w.spec.tiles, &ch_bytes, cfg, &k);
            }
            p
        })
        .collect();

    let weight_bytes = (profiles.iter().map(|p| p.w_bytes).sum::<u64>() as f64 / N_INF) as u64;
    let kv_bytes = (profiles.iter().map(|p| p.kv_bytes).sum::<u64>() as f64 / N_INF) as u64;
    let (w_res, kv_res) = residency(weight_bytes, kv_bytes, &k);

    let per_core: Vec<f64> = profiles.iter().map(|p| p.cycles(w_res, kv_res, &k) / N_INF).collect();
    let dram_lines = profiles.iter().map(|p| p.dram(w_res, kv_res)).sum::<f64>() / N_INF;
    let aimc_j = profiles.iter().map(|p| p.aimc_j).sum::<f64>() / N_INF;
    Ok(finish(per_core, dram_lines, aimc_j, cfg))
}

/// Cycles of one CM_QUEUE/CM_DEQUEUE: the beat issue overlaps the device
/// transfer, so the op costs whichever is longer — plus `extra_wait`
/// device cycles the transfer cannot start before (the pending MVM).
fn cm_io_cycles(
    coupling: &Coupling,
    bytes: u64,
    cfg: &SystemConfig,
    tight_cyc_per_byte: f64,
    extra_wait: f64,
) -> f64 {
    let beats = bytes.div_ceil(costs::CM_IO_BYTES_PER_INST) as f64;
    let active = beats * (1.0 + costs::CM_IO_OVERHEAD_PER_INST_X1000 as f64 / 1000.0);
    let transfer = match coupling {
        Coupling::Tight => bytes as f64 * tight_cyc_per_byte,
        Coupling::Loose => {
            (cfg.aimc.pio_transaction_s + bytes as f64 / cfg.aimc.pio_throughput_bps) * cfg.freq_hz
        }
    };
    active.max(extra_wait + transfer)
}

// ---------------------------------------------------------------------------
// Compositional engine
// ---------------------------------------------------------------------------

/// Cached profile of one `(anchor, engine, replication)` combination:
/// the walked cost of the anchor's steps emitted in isolation, plus the
/// CM_INITIALIZE preamble ops the compiler would add for its tiles.
#[derive(Clone, Copy, Debug)]
struct AnchorProfile {
    prof: Profile,
    cminit: f64,
}

struct AnchorCosts {
    dig: Vec<Option<AnchorProfile>>,
    ana: Vec<Option<AnchorProfile>>,
    /// Admissible per-anchor cycle floors (best-case residency, best
    /// engine/replication) for branch-and-bound lower bounds.
    min_any: f64,
    min_dig: f64,
    min_ana: f64,
}

/// The compositional cost engine of one `(graph, budget, config)`
/// search: all per-anchor profiles, the boundary-phase profiles, and the
/// admissible lower-bound tables.
pub(crate) struct CostEngine {
    cfg: SystemConfig,
    k: Consts,
    budget: TopologyBudget,
    replica_opts: Vec<usize>,
    /// Anchor-level dataflow — the same derivation `build_mapping` runs,
    /// so stage boundaries (and their boundary terms) cannot drift.
    dag: AnchorDag,
    anchors_cost: Vec<AnchorCosts>,
    input_prof: Profile,
    /// Writeback profile per replica-option index (last stage only).
    wb_prof: Vec<Profile>,
    /// Admissible energy floor per estimated cycle (idle fleet + static).
    floor_rate: f64,
}

impl CostEngine {
    /// Build the engine: one isolated-region compile + walk per
    /// `(anchor, engine, replication)` combination — O(anchors x
    /// engines x shapes), independent of how many candidates are
    /// scored.
    pub(crate) fn new(
        graph: &LayerGraph,
        anchors: &[Anchor],
        input_node: usize,
        output_node: usize,
        budget: &TopologyBudget,
        cfg: &SystemConfig,
        replica_opts: &[usize],
    ) -> CostEngine {
        let k = Consts::new(cfg);
        // All automap tiles are budget-dimension, tightly coupled; the
        // profile walker only reads coupling + full-tile dims, so one
        // dummy tile stands in for any packing outcome.
        let dummy_tiles =
            vec![TileSpec { rows: budget.tile_rows, cols: budget.tile_cols, coupling: Coupling::Tight }];
        let walk = |ops: Vec<TraceOp>| -> Profile {
            let mut p = Profile::default();
            for op in ops {
                p.absorb(op, 1, &dummy_tiles, &[], cfg, &k);
            }
            p
        };

        let anchors_cost: Vec<AnchorCosts> = anchors
            .iter()
            .map(|a| {
                let mut dig: Vec<Option<AnchorProfile>> = Vec::with_capacity(replica_opts.len());
                let mut ana: Vec<Option<AnchorProfile>> = Vec::with_capacity(replica_opts.len());
                for &r in replica_opts {
                    // A profile exists for every replication the anchor
                    // could run under inside SOME stage. This is the
                    // per-anchor half of `stage_parts` only — the
                    // stage-level out-width condition applies to a
                    // stage's *last* anchor, which need not be this one.
                    let usable = r == 1 || anchor_replicable(a, r as u64);
                    dig.push(if usable {
                        Some(AnchorProfile {
                            prof: walk(emit_anchor(graph, a, false, r as u64, budget)
                                .expect("digital lowering is always expressible")),
                            cminit: 0.0,
                        })
                    } else {
                        None
                    });
                    ana.push(if usable && a.mvm.is_some() {
                        emit_anchor(graph, a, true, r as u64, budget).map(|ops| AnchorProfile {
                            prof: walk(ops),
                            cminit: cminit_count(a.mvm.as_ref().expect("checked"), r as u64, budget),
                        })
                    } else {
                        None
                    });
                }
                let best = |side: &[Option<AnchorProfile>]| {
                    side.iter()
                        .flatten()
                        .map(|p| p.prof.cycles(true, true, &k))
                        .fold(f64::INFINITY, f64::min)
                };
                let (min_dig, min_ana) = (best(&dig), best(&ana));
                AnchorCosts { dig, ana, min_any: min_dig.min(min_ana), min_dig, min_ana }
            })
            .collect();

        let input_prof = match graph.nodes[input_node].kind {
            LayerKind::Input { bytes, marshal_insts, .. } => {
                let mut b = TraceBuilder::new();
                compile::lower::input_load(&mut b, 0, bytes, marshal_insts);
                walk(b.build())
            }
            _ => Profile::default(),
        };
        let out_bytes = match graph.nodes[output_node].kind {
            LayerKind::Output { bytes } => bytes,
            _ => 0,
        };
        let wb_prof: Vec<Profile> = replica_opts
            .iter()
            .map(|&r| {
                let mut b = TraceBuilder::new();
                compile::lower::writeback(&mut b, 0, out_bytes / r as u64);
                walk(b.build())
            })
            .collect();

        // Admissible energy floor per estimated cycle: the bottleneck
        // core is active for every cycle, every other core at least
        // idles, and the uncore static power burns for the whole
        // inference; DRAM and AIMC energy are >= 0.
        let p = &cfg.power;
        let (act, idle) = (p.active_core_j_per_cycle, p.idle_core_j_per_cycle);
        let core_floor = if act >= idle {
            act + (cfg.num_cores as f64 - 1.0) * idle
        } else {
            cfg.num_cores as f64 * act
        };
        let floor_rate =
            core_floor + (p.mem_ctrl_io_w + p.llc_leakage_w(cfg.llc.size_bytes)) / cfg.freq_hz;

        CostEngine {
            cfg: cfg.clone(),
            k,
            budget: *budget,
            replica_opts: replica_opts.to_vec(),
            dag: anchor_dag(graph, anchors, input_node),
            anchors_cost,
            input_prof,
            wb_prof,
            floor_rate,
        }
    }

    fn opt_idx(&self, parts: u64) -> usize {
        self.replica_opts
            .iter()
            .position(|&r| r as u64 == parts)
            .expect("stage parts is always one of the replica options")
    }

    /// Admissible cycle lower bound of any candidate on this partition
    /// (max over stages of the sum of per-anchor best-case floors;
    /// boundary phases and CM preambles are >= 0).
    pub(crate) fn partition_lower_bound(&self, anchors: &[Anchor], starts: &[usize]) -> f64 {
        self.stage_max(starts, anchors.len(), |ai| self.anchors_cost[ai].min_any)
    }

    /// Admissible cycle lower bound once the engine assignment (analog
    /// mask over MVM anchors) is fixed.
    pub(crate) fn mask_lower_bound(
        &self,
        anchors: &[Anchor],
        mvm_index: &[Option<usize>],
        starts: &[usize],
        mask: u64,
    ) -> f64 {
        self.stage_max(starts, anchors.len(), |ai| match mvm_index[ai] {
            Some(mi) if mask_bit(mask, mi) => self.anchors_cost[ai].min_ana,
            Some(_) | None => self.anchors_cost[ai].min_dig,
        })
    }

    /// Admissible energy floor for a candidate whose cycles are at least
    /// `cycles_lb` (an idle fleet plus static power for that long).
    pub(crate) fn energy_floor(&self, cycles_lb: f64) -> f64 {
        cycles_lb * self.floor_rate
    }

    fn stage_max(&self, starts: &[usize], n: usize, f: impl Fn(usize) -> f64) -> f64 {
        let mut lb = 0f64;
        for (si, &lo) in starts.iter().enumerate() {
            let hi = if si + 1 < starts.len() { starts[si + 1] } else { n };
            let stage: f64 = (lo..hi).map(&f).sum();
            lb = lb.max(stage);
        }
        lb
    }

    /// Score one candidate spec by composing cached profiles — no trace
    /// compilation. Returns `None` exactly when `build_mapping` would
    /// (budget infeasibility or a degenerate replication request); on
    /// `Some`, the estimate covers the same op multiset as the oracle's
    /// compiled walk.
    pub(crate) fn score(&self, anchors: &[Anchor], spec: &CandidateSpec) -> Option<CostEstimate> {
        let s_count = spec.starts.len();
        let n = anchors.len();
        let range = |si: usize| {
            let lo = spec.starts[si];
            let hi = if si + 1 < s_count { spec.starts[si + 1] } else { n };
            (lo, hi)
        };

        // Pass A: per-stage replication under the core/channel budgets —
        // the exact helper `build_mapping` uses, so feasibility cannot
        // drift between the two walks — plus the stage-boundary dataflow
        // the candidate's partition induces on the anchor DAG.
        let parts = stage_layout(anchors, &self.dag, spec, &self.budget)?;
        let edges = stage_edges(&self.dag, anchors, &spec.starts);
        let next_core: usize = parts.iter().map(|&p| p as usize).sum();

        // Pass B: compose stage profiles + greedy tile packing.
        let mut packer = Packer::new();
        let mut mvm_idx = 0usize;
        // (per-core per-inference profile, once-only cycles, preamble cycles)
        let mut stage_costs: Vec<(Profile, f64, f64)> = Vec::with_capacity(s_count);
        for si in 0..s_count {
            let (lo, hi) = range(si);
            let p = parts[si];
            let pi = self.opt_idx(p);
            let stage_floor = packer.count();
            let mut prof = Profile::default();
            let mut cminit = 0.0;
            for (ai, a) in anchors.iter().enumerate().take(hi).skip(lo) {
                let analog = match a.mvm {
                    Some(_) => {
                        let bit = mask_bit(spec.analog_mask, mvm_idx);
                        mvm_idx += 1;
                        bit
                    }
                    None => false,
                };
                let side = if analog { &self.anchors_cost[ai].ana } else { &self.anchors_cost[ai].dig };
                let ap = side[pi].as_ref()?;
                if analog {
                    // The exact greedy column-packing walk `build_mapping`
                    // runs (shared helper), counting tiles only.
                    let mvm = a.mvm.as_ref().expect("analog anchors have an MVM");
                    let shape = analog_shape(mvm, p, self.budget.tile_rows, self.budget.tile_cols)?;
                    place_shape(&mut packer, &self.budget, stage_floor, &shape, p, |_, _, _, _| {})?;
                }
                prof.add(&ap.prof);
                cminit += ap.cminit;
            }
            // Boundary phases (closed-form twins of the compiler's
            // input/join/barrier/fanout/ack emission). Per stage edge
            // `src -> si` the consumer receives one slice message from
            // each of the producer's `parts[src]` replicas; per edge
            // `si -> tgt` each replica sends `parts[tgt]` slice messages.
            // The legacy chain terms are exactly the single-in-edge /
            // single-out-edge case of these sums.
            let mut once = 0.0;
            if (lo..hi).any(|ai| self.dag.reads_input[ai]) {
                // `StageInput::Memory` on stage 0 or an input-fed branch,
                // or the `mem` tap of a residual `StageInput::Join`.
                prof.add(&self.input_prof);
            }
            for &(src, tgt, bytes) in &edges {
                if tgt != si {
                    continue;
                }
                let np = parts[src];
                prof.fixed += np as f64 * recv_cycles(bytes / np, &self.k);
                if spec.handoff == Handoff::SharedBuffer {
                    // Ack the incoming shared buffer, every inference.
                    prof.fixed += np as f64 * send_cycles(ACK_BYTES);
                }
            }
            if p > 1 {
                prof.fixed += costs::MUTEX_INSTS as f64 * 1.5; // barrier lock+unlock
            }
            let mut sinks = false;
            for &(src, tgt, bytes) in &edges {
                if src != si {
                    continue;
                }
                sinks = true;
                let nc = parts[tgt] as f64;
                prof.fixed += nc * send_cycles(bytes / p);
                if spec.handoff == Handoff::SharedBuffer {
                    // The consumer's ack is awaited from inference 1 on:
                    // once across the oracle's two compiled inferences.
                    once += nc * recv_cycles(ACK_BYTES, &self.k);
                }
            }
            if !sinks {
                // No consumer stage: the graph output writes back here.
                prof.add(&self.wb_prof[pi]);
            }
            stage_costs.push((prof, once, cminit));
        }

        // Residency classification over the whole candidate (all cores).
        let weight_bytes: u64 = stage_costs.iter().zip(&parts).map(|((pr, _, _), &p)| p * pr.w_bytes).sum();
        let kv_bytes: u64 = stage_costs.iter().zip(&parts).map(|((pr, _, _), &p)| p * pr.kv_bytes).sum();
        let (w_res, kv_res) = residency(weight_bytes, kv_bytes, &self.k);

        let mut per_core: Vec<f64> = Vec::with_capacity(next_core);
        let mut dram_lines = 0f64;
        let mut aimc_j = 0f64;
        for ((prof, once, cminit), &p) in stage_costs.iter().zip(&parts) {
            // Amortize exactly like the oracle: one preamble + one ack
            // wait across N_INF compiled inferences.
            let c = (cminit + once + N_INF * prof.cycles(w_res, kv_res, &self.k)) / N_INF;
            for _ in 0..p {
                per_core.push(c);
            }
            dram_lines += p as f64 * prof.dram(w_res, kv_res);
            aimc_j += p as f64 * prof.aimc_j;
        }
        Some(finish(per_core, dram_lines, aimc_j, &self.cfg))
    }
}

/// CM_INITIALIZE ops one replica's preamble emits for an analog MVM.
fn cminit_count(mvm: &MvmInfo, parts: u64, budget: &TopologyBudget) -> f64 {
    match analog_shape(mvm, parts, budget.tile_rows, budget.tile_cols) {
        Some(AnalogShape::Direct { .. }) => 1.0,
        Some(AnalogShape::RowSplit { k, .. }) => k as f64,
        Some(AnalogShape::One { .. }) => 1.0,
        Some(AnalogShape::Quad { .. }) => 4.0,
        None => 0.0,
    }
}

/// Emit one anchor's steps in isolation through the compiler's own
/// lowering rules (`compile::emit_step`), with dummy tile indices — the
/// walker only reads coupling and full-tile dimensions, which are
/// uniform across automap tiles. Returns `None` when the analog shape
/// is geometrically infeasible under the budget.
fn emit_anchor(
    graph: &LayerGraph,
    a: &Anchor,
    analog: bool,
    parts: u64,
    budget: &TopologyBudget,
) -> Option<Vec<TraceOp>> {
    let dummy = |rows: u64, cols: u64| TilePlacement {
        tile: 0,
        placement: Placement { row0: 0, col0: 0, rows: rows as u32, cols: cols as u32 },
    };
    let mut b = TraceBuilder::new();
    for &nid in &a.nodes {
        let is_mvm = a.mvm.as_ref().is_some_and(|m| m.node() == nid);
        let place = if is_mvm && analog {
            let mvm = a.mvm.as_ref().expect("is_mvm checked");
            match analog_shape(mvm, parts, budget.tile_rows, budget.tile_cols)? {
                AnalogShape::Direct { rows, slice } => {
                    if !fits(rows, slice, budget) {
                        return None;
                    }
                    Place::Tile { per_replica: vec![dummy(rows, slice); parts as usize] }
                }
                AnalogShape::RowSplit { k, sub, cols } => {
                    if !fits(sub, cols, budget) {
                        return None;
                    }
                    Place::TileRowSplit { tiles: vec![dummy(sub, cols); k as usize] }
                }
                AnalogShape::One { rows, cols } => {
                    if !fits(rows, cols, budget) {
                        return None;
                    }
                    Place::Tile { per_replica: vec![dummy(rows, cols)] }
                }
                AnalogShape::Quad { d } => {
                    if !fits(d, d, budget) {
                        return None;
                    }
                    Place::AttentionTiles { q: dummy(d, d), k: dummy(d, d), v: dummy(d, d), o: dummy(d, d) }
                }
            }
        } else {
            Place::Cpu
        };
        let step = Step { node: nid, place };
        compile::emit_step(&mut b, graph, &step, 0, parts);
    }
    Some(b.build())
}

/// The geometry half of `Packer::place`: a region fits a budget tile.
fn fits(rows: u64, cols: u64, budget: &TopologyBudget) -> bool {
    rows > 0 && cols > 0 && rows <= budget.tile_rows as u64 && cols <= budget.tile_cols as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::mlp::{self, MlpCase};

    fn est(case: MlpCase) -> CostEstimate {
        let (g, m) = mlp::case_table(case).unwrap();
        estimate(&g, &m, &SystemConfig::high_power()).unwrap()
    }

    #[test]
    fn analog_estimated_faster_than_digital() {
        let dig = est(MlpCase::Digital { cores: 1 });
        let ana = est(MlpCase::Analog { case: 1 });
        assert!(
            ana.cycles_per_inf * 4.0 < dig.cycles_per_inf,
            "analog {} vs digital {}",
            ana.cycles_per_inf,
            dig.cycles_per_inf
        );
        assert!(ana.energy_per_inf_j < dig.energy_per_inf_j);
    }

    #[test]
    fn pipeline_estimate_takes_the_max_stage() {
        let two = est(MlpCase::Digital { cores: 2 });
        assert_eq!(two.per_core_cycles.len(), 2);
        let max = two.per_core_cycles.iter().copied().fold(0.0, f64::max);
        assert_eq!(two.cycles_per_inf, max);
        // Splitting the two layers roughly halves the per-inference bound.
        let one = est(MlpCase::Digital { cores: 1 });
        assert!(two.cycles_per_inf < 0.8 * one.cycles_per_inf);
    }

    #[test]
    fn estimates_are_deterministic() {
        let a = est(MlpCase::Analog { case: 3 });
        let b = est(MlpCase::Analog { case: 3 });
        assert_eq!(a.cycles_per_inf.to_bits(), b.cycles_per_inf.to_bits());
        assert_eq!(a.energy_per_inf_j.to_bits(), b.energy_per_inf_j.to_bits());
    }

    #[test]
    fn composed_score_matches_oracle_on_every_feasible_spec() {
        use crate::nn::LayerGraph;
        // Exhaustively cross-check the compositional engine against the
        // compiled oracle over a small space that exercises replication,
        // row-splitting, pipelining, and both hand-offs.
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 128, tile_cols: 256, channels: 32 };
        let cfg = SystemConfig::high_power();
        let (anchors, input, output) = super::super::enumerate::anchors(&g).unwrap();
        let opts = [1usize, 2, 4];
        let engine = CostEngine::new(&g, &anchors, input, output, &budget, &cfg, &opts);

        let mut checked = 0;
        for starts in super::super::enumerate::partitions(anchors.len(), 4, usize::MAX).0 {
            for mask in 0u64..4 {
                for &r in &opts {
                    for h in [Handoff::PingPong, Handoff::SharedBuffer] {
                        let spec = CandidateSpec {
                            starts: starts.clone(),
                            analog_mask: mask,
                            replicas: r,
                            handoff: h,
                        };
                        let built = super::super::enumerate::build_mapping(
                            &g, &anchors, input, output, &spec, &budget,
                        );
                        let composed = engine.score(&anchors, &spec);
                        assert_eq!(built.is_some(), composed.is_some(), "feasibility drift on {spec:?}");
                        let (Some((mapping, desc)), Some(c)) = (built, composed) else { continue };
                        let o = estimate(&g, &mapping, &cfg).unwrap();
                        let rel = (c.cycles_per_inf - o.cycles_per_inf).abs() / o.cycles_per_inf;
                        assert!(rel < 1e-9, "{desc}: composed {} vs oracle {}", c.cycles_per_inf, o.cycles_per_inf);
                        let rel_e = (c.energy_per_inf_j - o.energy_per_inf_j).abs() / o.energy_per_inf_j;
                        assert!(rel_e < 1e-9, "{desc}: composed energy {} vs oracle {}", c.energy_per_inf_j, o.energy_per_inf_j);
                        assert_eq!(c.per_core_cycles.len(), o.per_core_cycles.len(), "{desc}");
                        checked += 1;
                    }
                }
            }
        }
        assert!(checked > 20, "cross-check space collapsed: {checked}");
    }

    #[test]
    fn compositional_matches_compiled_oracle_on_pinned_dag_cases() {
        use crate::nn::LayerGraph;
        // Pinned DAG cases: a residual fork/join block, an MoE expert
        // bank (a chain, so it also cross-checks expert replication at
        // r = 2), and a two-head parallel-attention encoder. Every
        // feasible (partition, mask, replicas, hand-off) point must
        // score identically to the compiled oracle, and feasibility
        // itself must agree between `score` and `build_mapping`.
        let cases = [
            LayerGraph::resnet_block(8, 4, 10),
            LayerGraph::moe(64, 32, 4, 2, 10),
            LayerGraph::transformer_parallel(16, 2, 8, 1, 32),
        ];
        let budget =
            TopologyBudget { cores: 4, tiles: 12, tile_rows: 256, tile_cols: 256, channels: 64 };
        let cfg = SystemConfig::high_power();
        let opts = [1usize, 2];
        for g in &cases {
            let (anchors, input, output) = super::super::enumerate::anchors(g).unwrap();
            let engine = CostEngine::new(g, &anchors, input, output, &budget, &cfg, &opts);
            let n_mvm = anchors.iter().filter(|a| a.mvm.is_some()).count();
            let masks: Vec<u64> = if n_mvm <= 4 {
                (0..(1u64 << n_mvm)).collect()
            } else {
                vec![0, (1u64 << n_mvm) - 1]
            };
            let mut checked = 0;
            for starts in super::super::enumerate::partitions(anchors.len(), 3, usize::MAX).0 {
                for &mask in &masks {
                    for &r in &opts {
                        for h in [Handoff::PingPong, Handoff::SharedBuffer] {
                            let spec = CandidateSpec {
                                starts: starts.clone(),
                                analog_mask: mask,
                                replicas: r,
                                handoff: h,
                            };
                            let built = super::super::enumerate::build_mapping(
                                g, &anchors, input, output, &spec, &budget,
                            );
                            let composed = engine.score(&anchors, &spec);
                            assert_eq!(
                                built.is_some(),
                                composed.is_some(),
                                "{}: feasibility drift on {spec:?}",
                                g.name
                            );
                            let (Some((mapping, desc)), Some(c)) = (built, composed) else { continue };
                            let o = estimate(g, &mapping, &cfg).unwrap();
                            let rel = (c.cycles_per_inf - o.cycles_per_inf).abs() / o.cycles_per_inf;
                            assert!(
                                rel < 1e-9,
                                "{}/{desc}: composed {} vs oracle {}",
                                g.name,
                                c.cycles_per_inf,
                                o.cycles_per_inf
                            );
                            let rel_e =
                                (c.energy_per_inf_j - o.energy_per_inf_j).abs() / o.energy_per_inf_j;
                            assert!(rel_e < 1e-9, "{}/{desc}: composed energy drift", g.name);
                            assert_eq!(c.per_core_cycles.len(), o.per_core_cycles.len(), "{desc}");
                            checked += 1;
                        }
                    }
                }
            }
            assert!(checked > 5, "{}: cross-check space collapsed: {checked}", g.name);
        }
    }

    #[test]
    fn lower_bounds_are_admissible() {
        use crate::nn::LayerGraph;
        let g = LayerGraph::mlp(&[256, 128, 64]);
        let budget = TopologyBudget { cores: 4, tiles: 8, tile_rows: 256, tile_cols: 256, channels: 32 };
        let cfg = SystemConfig::high_power();
        let (anchors, input, output) = super::super::enumerate::anchors(&g).unwrap();
        let opts = [1usize, 2, 4];
        let engine = CostEngine::new(&g, &anchors, input, output, &budget, &cfg, &opts);
        let mvm_index: Vec<Option<usize>> = {
            let mut k = 0;
            anchors.iter().map(|a| a.mvm.as_ref().map(|_| { let i = k; k += 1; i })).collect()
        };
        for starts in super::super::enumerate::partitions(anchors.len(), 4, usize::MAX).0 {
            let plb = engine.partition_lower_bound(&anchors, &starts);
            for mask in 0u64..4 {
                let mlb = engine.mask_lower_bound(&anchors, &mvm_index, &starts, mask);
                assert!(mlb + 1e-9 >= plb, "mask bound below partition bound");
                for &r in &opts {
                    for h in [Handoff::PingPong, Handoff::SharedBuffer] {
                        let spec = CandidateSpec { starts: starts.clone(), analog_mask: mask, replicas: r, handoff: h };
                        if let Some(est) = engine.score(&anchors, &spec) {
                            assert!(est.cycles_per_inf >= mlb - 1e-9, "score below mask bound");
                            assert!(est.cycles_per_inf >= plb - 1e-9, "score below partition bound");
                            assert!(est.energy_per_inf_j * (1.0 + 1e-9) >= engine.energy_floor(plb));
                        }
                    }
                }
            }
        }
    }
}
