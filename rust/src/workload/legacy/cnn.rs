//! Legacy hand-written CNN generator — bit-equivalence oracle for the
//! mapping compiler (see `workload::legacy`).

use crate::config::SystemConfig;
use crate::isa::InstClass;
use crate::nn::cnn::{CnnLayer, CnnModel, CnnVariant};
use crate::workload::cnn::CnnCase;
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::{ChannelSpec, MachineSpec, TileSpec};
use crate::stats::RoiKind;
use crate::workload::trace::{TraceBuilder, TraceOp};
use crate::workload::{addr, costs, Workload};

/// Row-chunk granularity of the inter-stage pipeline: sending every
/// feature-map row individually would explode the trace; the paper's
/// fine-grained pipelining is preserved at the level of `ROW_GROUP`
/// output rows per transfer.
const ROW_GROUP: u64 = 4;

pub fn generate(case: CnnCase, variant: CnnVariant, _cfg: &SystemConfig, n_inf: u32) -> Workload {
    let model = CnnModel::paper(variant);
    let analog = case == CnnCase::Analog;

    // Tiles: one per conv layer (analog only), sized for the flattened
    // kernels (§V.B: component dimensions are parameterizable).
    let tiles: Vec<TileSpec> = if analog {
        model
            .convs
            .iter()
            .map(|l| TileSpec {
                rows: l.im2col_rows() as u32,
                cols: l.out_ch as u32,
                coupling: Coupling::Tight,
            })
            .collect()
    } else {
        Vec::new()
    };

    // Channels: conv_k -> conv_{k+1} (0..3), conv5 -> dense1 (4),
    // dense1 -> dense2 (5), dense2 -> dense3 (6).
    let channels: Vec<ChannelSpec> = (0..7)
        .map(|k| ChannelSpec { producer: k, consumer: k + 1, capacity: 2 })
        .collect();

    let mut cores: Vec<TraceBuilder> = (0..8).map(|_| TraceBuilder::new()).collect();

    if analog {
        for (k, l) in model.convs.iter().enumerate() {
            cores[k].push(TraceOp::CmInit {
                tile: k,
                placement: Placement {
                    row0: 0,
                    col0: 0,
                    rows: l.im2col_rows() as u32,
                    cols: l.out_ch as u32,
                },
            });
        }
    }

    // Per-layer, per-row CM-op block (analog): the queue/process/dequeue
    // sequence is identical for every output row of a layer — it carries
    // no addresses — so it is built once here and memcpy-appended per
    // row (and per inference) instead of being re-emitted op by op.
    let row_blocks: Vec<Vec<TraceOp>> = if analog {
        model
            .convs
            .iter()
            .enumerate()
            .map(|(k, l)| analog_row_block(k, l))
            .collect()
    } else {
        Vec::new()
    };

    let marks: Vec<usize> = cores.iter().map(TraceBuilder::mark).collect();
    for i in 0..n_inf {
        if i == 1 {
            // Inference 0 sized one block per core; reserve the rest.
            for (b, mk) in cores.iter_mut().zip(&marks) {
                b.reserve_repeats(*mk, n_inf - 1);
            }
        }
        let mut prev_msgs: Option<u64> = None; // conv1 reads from memory
        for (k, layer) in model.convs.iter().enumerate() {
            let groups = layer.out_hw().div_ceil(ROW_GROUP);
            let row_block = if analog { Some(row_blocks[k].as_slice()) } else { None };
            emit_conv_stage(&mut cores[k], k, layer, i, row_block, prev_msgs);
            prev_msgs = Some(groups);
        }
        emit_dense_stages(&mut cores, &model, i, prev_msgs.unwrap());
    }

    Workload {
        label: format!("cnn-{}/{}", variant.name(), case.label()),
        traces: cores.into_iter().map(|b| b.build().into()).collect(),
        spec: MachineSpec { tiles, channels, mutexes: 0 },
        inferences: n_inf,
    }
}

/// The per-output-row op sequence of one analog conv layer: im2col
/// gather, then per output pixel a software-pipelined queue/process
/// (+dequeue of the previous pixel), and the final drain. Identical for
/// every row of the layer, so callers append it as a block.
fn analog_row_block(k: usize, l: &CnnLayer) -> Vec<TraceOp> {
    let out_hw = l.out_hw();
    let kk = l.im2col_rows();
    let mut b = TraceBuilder::with_capacity(6 + 9 * out_hw as usize);
    // im2col gather of the patch happens on the CPU (the paper flags
    // tile-local SRAM reuse as future work, §IX.B); the feature maps are
    // already int8, so no per-patch cast. The loop is software-
    // pipelined: queue+fire pixel p, then retrieve pixel p-1 — the
    // double-buffered DAC/ADC registers overlap the transfer of one
    // pixel with the MVM of another.
    b.roi(RoiKind::AnalogQueue, |b| {
        b.compute(InstClass::IntAlu, out_hw * (kk / 4 + 12)); // gather
    });
    for px in 0..out_hw {
        b.push(TraceOp::RoiPush { kind: RoiKind::AnalogQueue });
        b.push(TraceOp::CmQueue { tile: k, bytes: kk });
        b.push(TraceOp::RoiPop);
        b.push(TraceOp::RoiPush { kind: RoiKind::AnalogProcess });
        b.push(TraceOp::CmProcess { tile: k });
        b.push(TraceOp::RoiPop);
        if px > 0 {
            b.push(TraceOp::RoiPush { kind: RoiKind::AnalogDequeue });
            b.push(TraceOp::CmDequeue { tile: k, bytes: l.out_ch });
            b.push(TraceOp::RoiPop);
        }
    }
    // Drain the last pixel of the row.
    b.push(TraceOp::RoiPush { kind: RoiKind::AnalogDequeue });
    b.push(TraceOp::CmDequeue { tile: k, bytes: l.out_ch });
    b.push(TraceOp::RoiPop);
    b.build()
}

/// One conv pipeline stage for one inference. `in_msgs` is the number of
/// messages the previous stage emits this inference (None: conv1 reads
/// the image from memory); the recvs are spread across this stage's own
/// row groups so producer and consumer counts always match.
/// `row_block` is the pre-built analog per-row CM block (None: digital).
fn emit_conv_stage(
    b: &mut TraceBuilder,
    k: usize,
    l: &CnnLayer,
    inf: u32,
    row_block: Option<&[TraceOp]>,
    in_msgs: Option<u64>,
) {
    let out_hw = l.out_hw();
    let row_groups = out_hw.div_ceil(ROW_GROUP);
    let out_row_bytes = l.pooled_hw() * l.out_ch;

    for g in 0..row_groups {
        // ---- receive input rows (conv1 loads from memory instead) ----
        if let Some(in_msgs) = in_msgs {
            // Distribute `in_msgs` recvs over `row_groups` groups.
            let start = g * in_msgs / row_groups;
            let end = (g + 1) * in_msgs / row_groups;
            b.roi(RoiKind::Communication, |b| {
                for _ in start..end {
                    b.push(TraceOp::Recv { ch: k - 1 });
                }
            });
        } else {
            b.roi(RoiKind::InputLoad, |b| {
                // The corresponding slice of the 224x224x3 input image.
                let bytes = ROW_GROUP * l.stride * 224 * 3;
                b.push(TraceOp::MemStream {
                    base: addr::input(inf, 224 * 224 * 3) + g * bytes,
                    bytes,
                    write: false,
                    insts_per_line: 1,
                    prefetchable: true,
                });
            });
        }

        let this_rows = ROW_GROUP.min(out_hw - g * ROW_GROUP);
        let px = this_rows * out_hw;
        let kk = l.im2col_rows();

        if let Some(block) = row_block {
            // ---- analog: per output pixel queue/process/dequeue -------
            // (pre-built per-row block; see `analog_row_block`).
            b.reserve(block.len() * this_rows as usize);
            for _row in 0..this_rows {
                b.extend_from_slice(block);
            }
        } else {
            // ---- digital: blocked int8 GEMM over this row group -------
            b.roi(RoiKind::DigitalMvm, |b| {
                // im2col materialization (gather).
                b.compute(InstClass::IntAlu, px * (kk / 4 + 12));
                // Weight panel streamed once per GEMM_ROW_BLOCK of pixels
                // (this is the §IX "multiple passes on weights"; whether
                // the passes hit LLC or DRAM is decided by the cache sim).
                let passes = px.div_ceil(costs::GEMM_ROW_BLOCK);
                for _ in 0..passes {
                    b.stream_read(addr::weights(k), kk * l.out_ch, 1);
                }
                // out_ch dots of length kk per output pixel (blocked
                // im2col GEMM efficiency, see costs::CONV_MACS_PER_INST).
                b.compute(
                    InstClass::SimdOp,
                    px * l.out_ch * (kk / costs::CONV_MACS_PER_INST + 1),
                );
                b.compute(InstClass::IntAlu, px * l.out_ch / 8);
            });
        }

        // ---- post-ops: ReLU (+LRN) (+pool), identical in both variants --
        let elems = px * l.out_ch;
        b.roi(RoiKind::Activation, |b| {
            b.compute(InstClass::SimdOp, elems / 8 + 4); // ReLU
            if l.lrn {
                b.compute(InstClass::SimdOp, elems * costs::LRN_SIMD_PER_ELEM);
            }
            if l.pool > 1 {
                // window^2 comparisons per pooled element, stride 2.
                let pooled = elems / 4;
                b.compute(InstClass::SimdOp, pooled * l.pool * l.pool / 4 + 4);
            }
        });

        // ---- forward pooled rows to the next stage --------------------
        b.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Send {
                ch: k,
                bytes: (this_rows.div_ceil(l.pool.max(1)) * out_row_bytes / ROW_GROUP.max(1)).max(64),
                addr: addr::channel(k, inf.wrapping_add(g as u32)),
            });
        });
    }
}

/// Dense1-3 on cores 5-7 (digital in both variants, §IX.A).
fn emit_dense_stages(cores: &mut [TraceBuilder], model: &CnnModel, inf: u32, conv_groups: u64) {
    let dims = [
        (model.dense_inputs(), model.dense[0]),
        (model.dense[0], model.dense[1]),
        (model.dense[1], model.dense[2]),
    ];
    for (d, (rows, cols)) in dims.iter().enumerate() {
        let core = 5 + d;
        let b = &mut cores[core];
        b.roi(RoiKind::Communication, |b| {
            if d == 0 {
                // Drain all row-group messages from conv5.
                for _ in 0..conv_groups {
                    b.push(TraceOp::Recv { ch: 4 });
                }
            } else {
                b.push(TraceOp::Recv { ch: 4 + d });
            }
        });
        b.roi(RoiKind::DigitalMvm, |b| {
            b.stream_read(addr::weights(8 + d), rows * cols, 1);
            let c = costs::gemv_row_insts(*rows);
            b.compute(InstClass::SimdOp, cols * c.simd_insts);
            b.compute(InstClass::IntAlu, cols * c.alu_insts);
        });
        b.roi(RoiKind::Activation, |b| {
            if d == 2 {
                b.compute(
                    InstClass::FpOp,
                    cols * costs::activation_insts_per_elem(costs::Activation::SoftmaxPerElem),
                );
            } else {
                b.compute(InstClass::SimdOp, cols / 8 + 4);
            }
        });
        if d < 2 {
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send {
                    ch: 5 + d,
                    bytes: *cols,
                    addr: addr::channel(5 + d, inf),
                });
            });
        } else {
            b.roi(RoiKind::Writeback, |b| {
                b.stream_write(addr::output(inf, *cols), *cols, 2);
            });
        }
    }
}

