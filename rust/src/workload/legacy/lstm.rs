//! Legacy hand-written LSTM generator — bit-equivalence oracle for the
//! mapping compiler (see `workload::legacy`).

use crate::config::SystemConfig;
use crate::isa::InstClass;
use crate::nn::LstmModel;
use crate::workload::lstm::LstmCase;
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::{ChannelSpec, MachineSpec, TileSpec};
use crate::stats::RoiKind;
use crate::workload::legacy::mlp::{emit_dequeue, emit_process, emit_queue};
use crate::workload::trace::{TraceBuilder, TraceOp};
use crate::workload::{addr, costs, Workload};

pub fn generate(case: LstmCase, n_h: u64, _cfg: &SystemConfig, n_inf: u32) -> Workload {
    let m = LstmModel::paper(n_h);
    match case {
        LstmCase::Digital { cores: 1 } => digital_1core(m, n_inf),
        LstmCase::Digital { cores: 2 } => digital_2core(m, n_inf),
        LstmCase::Digital { cores: 5 } => digital_5core(m, n_inf),
        LstmCase::Digital { cores } => panic!("unsupported digital core count {cores}"),
        LstmCase::Analog { case: c @ (1 | 2) } => analog_single(m, n_inf, c),
        LstmCase::Analog { case: 3 } => analog_case3(m, n_inf),
        LstmCase::Analog { case: 4 } => analog_case4(m, n_inf),
        LstmCase::Analog { case } => panic!("unsupported analog case {case}"),
    }
}

// ---------------------------------------------------------------------------
// Digital building blocks
// ---------------------------------------------------------------------------

fn emit_input_load(b: &mut TraceBuilder, i: u32, m: &LstmModel) {
    b.roi(RoiKind::InputLoad, |b| {
        // fp32 character embedding, cold per step.
        b.push(TraceOp::MemStream {
            base: addr::input(i, 4 * m.x),
            bytes: 4 * m.x,
            write: false,
            insts_per_line: 2,
            prefetchable: false,
        });
        // Concatenate [h, x] into the staging buffer.
        b.compute(InstClass::IntAlu, (m.n_h + m.x) / 4 + 30);
    });
}

/// Cell-gate activations: 3x sigmoid + 1x tanh over n_h-vectors each.
fn emit_gate_activations(b: &mut TraceBuilder, n_h: u64, fraction: u64) {
    let n = n_h / fraction;
    b.roi(RoiKind::Activation, |b| {
        let fp = 3 * n * costs::activation_insts_per_elem(costs::Activation::Sigmoid)
            + n * costs::activation_insts_per_elem(costs::Activation::Tanh);
        b.compute(InstClass::FpOp, fp);
    });
}

/// c/h update: elementwise mults/adds + tanh(c_new).
fn emit_gate_combine(b: &mut TraceBuilder, n_h: u64, fraction: u64) {
    let n = n_h / fraction;
    b.roi(RoiKind::GateCombine, |b| {
        b.compute(InstClass::SimdOp, n); // f*c + i*g etc., 4-wide fp32
        b.compute(
            InstClass::FpOp,
            n * costs::activation_insts_per_elem(costs::Activation::Tanh),
        );
    });
}

fn emit_softmax(b: &mut TraceBuilder, y: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(
            InstClass::FpOp,
            y * costs::activation_insts_per_elem(costs::Activation::SoftmaxPerElem),
        );
    });
}

fn emit_writeback(b: &mut TraceBuilder, i: u32, y: u64) {
    b.roi(RoiKind::Writeback, |b| {
        b.stream_write(addr::output(i, y), y, 2);
    });
}

/// Digital cell MVM: stream the 4-gate weight matrix, SDOT GEMV.
fn emit_digital_cell(b: &mut TraceBuilder, m: &LstmModel, col_fraction: u64) {
    let rows = m.cell_rows();
    let cols = m.cell_cols() / col_fraction;
    b.roi(RoiKind::DigitalMvm, |b| {
        b.stream_read(addr::weights(0), rows * cols, 1);
        let c = costs::gemv_row_insts(rows);
        b.compute(InstClass::SimdOp, cols * c.simd_insts);
        b.compute(InstClass::IntAlu, cols * c.alu_insts);
    });
}

fn emit_digital_dense(b: &mut TraceBuilder, m: &LstmModel) {
    b.roi(RoiKind::DigitalMvm, |b| {
        b.stream_read(addr::weights(1), m.dense_rows() * m.dense_cols(), 1);
        let c = costs::gemv_row_insts(m.dense_rows());
        b.compute(InstClass::SimdOp, m.dense_cols() * c.simd_insts);
        b.compute(InstClass::IntAlu, m.dense_cols() * c.alu_insts);
    });
}

// ---------------------------------------------------------------------------
// Digital cases
// ---------------------------------------------------------------------------

fn digital_1core(m: LstmModel, n_inf: u32) -> Workload {
    let mut b = TraceBuilder::new();
    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            // Inference 0 sized one block; reserve the rest up front.
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, &m);
        emit_digital_cell(&mut b, &m, 1);
        emit_gate_activations(&mut b, m.n_h, 1);
        emit_gate_combine(&mut b, m.n_h, 1);
        emit_digital_dense(&mut b, &m);
        emit_softmax(&mut b, m.y);
        emit_writeback(&mut b, i, m.y);
    }
    Workload {
        label: format!("lstm{}/DIG-1core", m.n_h),
        traces: vec![b.build().into()],
        spec: MachineSpec::default(),
        inferences: n_inf,
    }
}

fn digital_2core(m: LstmModel, n_inf: u32) -> Workload {
    let mut c0 = TraceBuilder::new();
    let mut c1 = TraceBuilder::new();
    let (s0, s1) = (c0.mark(), c1.mark());
    for i in 0..n_inf {
        if i == 1 {
            c0.reserve_repeats(s0, n_inf - 1);
            c1.reserve_repeats(s1, n_inf - 1);
        }
        emit_input_load(&mut c0, i, &m);
        emit_digital_cell(&mut c0, &m, 1);
        emit_gate_activations(&mut c0, m.n_h, 1);
        emit_gate_combine(&mut c0, m.n_h, 1);
        c0.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Send { ch: 0, bytes: 4 * m.n_h, addr: addr::channel(0, i) });
        });
        c1.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Recv { ch: 0 });
        });
        emit_digital_dense(&mut c1, &m);
        emit_softmax(&mut c1, m.y);
        emit_writeback(&mut c1, i, m.y);
    }
    Workload {
        label: format!("lstm{}/DIG-2core", m.n_h),
        traces: vec![c0.build().into(), c1.build().into()],
        spec: MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

fn digital_5core(m: LstmModel, n_inf: u32) -> Workload {
    // Cores 0-3: cell column slices; core 0 additionally assembles h and
    // broadcasts it (for the recurrence) and feeds core 4 (dense).
    let mut cores: Vec<TraceBuilder> = (0..5).map(|_| TraceBuilder::new()).collect();
    let spec = quin_core_spec(&[], m.n_h);
    let marks: Vec<usize> = cores.iter().map(TraceBuilder::mark).collect();
    for i in 0..n_inf {
        if i == 1 {
            for (b, mk) in cores.iter_mut().zip(&marks) {
                b.reserve_repeats(*mk, n_inf - 1);
            }
        }
        quin_core_step(
            &mut cores,
            &m,
            i,
            |b, core, m| {
                // Each cell core streams its quarter of the weight columns.
                let rows = m.cell_rows();
                let cols = m.cell_cols() / 4;
                b.roi(RoiKind::DigitalMvm, |b| {
                    b.stream_read(addr::weights(0) + core as u64 * rows * cols, rows * cols, 1);
                    let c = costs::gemv_row_insts(rows);
                    b.compute(InstClass::SimdOp, cols * c.simd_insts);
                    b.compute(InstClass::IntAlu, cols * c.alu_insts);
                });
            },
            |b, m, i| {
                emit_digital_dense(b, m);
                emit_softmax(b, m.y);
                emit_writeback(b, i, m.y);
            },
        );
    }
    Workload {
        label: format!("lstm{}/DIG-5core", m.n_h),
        traces: cores.into_iter().map(|b| b.build().into()).collect(),
        spec,
        inferences: n_inf,
    }
}

// ---------------------------------------------------------------------------
// Analog cases
// ---------------------------------------------------------------------------

/// Cases 1 and 2 (single core). Case 1 tiles cell + dense in one large
/// crossbar (Table II-B case-1 dims); case 2 uses one tile per layer.
fn analog_single(m: LstmModel, n_inf: u32, case: u8) -> Workload {
    let mut b = TraceBuilder::new();
    let (tiles, cell_tile, dense_tile): (Vec<TileSpec>, usize, usize) = if case == 1 {
        let (r, c) = LstmModel::paper_tile_dims(m.n_h, 1)
            .unwrap_or((m.cell_rows() + m.dense_rows(), m.cell_cols() + m.y));
        (
            vec![TileSpec { rows: r as u32, cols: c as u32, coupling: Coupling::Tight }],
            0,
            0,
        )
    } else {
        (
            vec![
                TileSpec {
                    rows: m.cell_rows() as u32,
                    cols: m.cell_cols() as u32,
                    coupling: Coupling::Tight,
                },
                TileSpec {
                    rows: m.dense_rows() as u32,
                    cols: m.dense_cols() as u32,
                    coupling: Coupling::Tight,
                },
            ],
            0,
            1,
        )
    };
    // Program: cell at (0,0); dense diagonally below-right in case 1.
    b.push(TraceOp::CmInit {
        tile: cell_tile,
        placement: Placement {
            row0: 0,
            col0: 0,
            rows: m.cell_rows() as u32,
            cols: m.cell_cols() as u32,
        },
    });
    let dense_placement = if case == 1 {
        Placement {
            row0: m.cell_rows() as u32,
            col0: m.cell_cols() as u32,
            rows: m.dense_rows() as u32,
            cols: m.dense_cols() as u32,
        }
    } else {
        Placement { row0: 0, col0: 0, rows: m.dense_rows() as u32, cols: m.dense_cols() as u32 }
    };
    b.push(TraceOp::CmInit { tile: dense_tile, placement: dense_placement });

    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, &m);
        // Queue [h, x]; one CM_PROCESS yields all four gates (§VIII.D).
        emit_queue(&mut b, cell_tile, m.cell_rows());
        emit_process(&mut b, cell_tile);
        emit_dequeue(&mut b, cell_tile, m.cell_cols());
        emit_gate_activations(&mut b, m.n_h, 1);
        emit_gate_combine(&mut b, m.n_h, 1);
        emit_queue(&mut b, dense_tile, m.dense_rows());
        emit_process(&mut b, dense_tile);
        emit_dequeue(&mut b, dense_tile, m.dense_cols());
        emit_softmax(&mut b, m.y);
        emit_writeback(&mut b, i, m.y);
    }
    Workload {
        label: format!("lstm{}/ANA-case{case}", m.n_h),
        traces: vec![b.build().into()],
        spec: MachineSpec { tiles, ..Default::default() },
        inferences: n_inf,
    }
}

/// Case 3: dual core — cell layer on core 0, dense on core 1.
fn analog_case3(m: LstmModel, n_inf: u32) -> Workload {
    let mut c0 = TraceBuilder::new();
    let mut c1 = TraceBuilder::new();
    c0.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement {
            row0: 0,
            col0: 0,
            rows: m.cell_rows() as u32,
            cols: m.cell_cols() as u32,
        },
    });
    c1.push(TraceOp::CmInit {
        tile: 1,
        placement: Placement { row0: 0, col0: 0, rows: m.dense_rows() as u32, cols: m.dense_cols() as u32 },
    });
    let (s0, s1) = (c0.mark(), c1.mark());
    for i in 0..n_inf {
        if i == 1 {
            c0.reserve_repeats(s0, n_inf - 1);
            c1.reserve_repeats(s1, n_inf - 1);
        }
        emit_input_load(&mut c0, i, &m);
        emit_queue(&mut c0, 0, m.cell_rows());
        emit_process(&mut c0, 0);
        emit_dequeue(&mut c0, 0, m.cell_cols());
        emit_gate_activations(&mut c0, m.n_h, 1);
        emit_gate_combine(&mut c0, m.n_h, 1);
        c0.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Send { ch: 0, bytes: 4 * m.n_h, addr: addr::channel(0, i) });
        });

        c1.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Recv { ch: 0 });
        });
        emit_queue(&mut c1, 1, m.dense_rows());
        emit_process(&mut c1, 1);
        emit_dequeue(&mut c1, 1, m.dense_cols());
        emit_softmax(&mut c1, m.y);
        emit_writeback(&mut c1, i, m.y);
    }
    let (r3, c3) = LstmModel::paper_tile_dims(m.n_h, 3)
        .unwrap_or((m.cell_rows(), m.cell_cols()));
    Workload {
        label: format!("lstm{}/ANA-case3", m.n_h),
        traces: vec![c0.build().into(), c1.build().into()],
        spec: MachineSpec {
            tiles: vec![
                TileSpec { rows: r3 as u32, cols: c3 as u32, coupling: Coupling::Tight },
                TileSpec {
                    rows: m.dense_rows() as u32,
                    cols: m.dense_cols() as u32,
                    coupling: Coupling::Tight,
                },
            ],
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

/// Shared quin-core step structure (used by ANA case 4 and DIG 5-core):
/// cores 0-3 produce their quarter of the cell output (`cell_mvm` emits
/// the per-core MVM work), sync through core 0, which broadcasts h for
/// the recurrence and feeds the dense core 4.
fn quin_core_step(
    cores: &mut [TraceBuilder],
    m: &LstmModel,
    i: u32,
    cell_mvm: impl Fn(&mut TraceBuilder, usize, &LstmModel),
    dense_body: impl Fn(&mut TraceBuilder, &LstmModel, u32),
) {
    let quarter = m.n_h / 4;
    // Channels: 1->0 (ch0), 2->0 (ch1), 3->0 (ch2);
    // 0->1 (ch3), 0->2 (ch4), 0->3 (ch5); 0->4 (ch6).
    for core in 0..4usize {
        // Split borrow: we need one builder at a time.
        let b = &mut cores[core];
        if core == 0 {
            emit_input_load(b, i, m);
        } else {
            // Non-leader cores read the same input (hits LLC after core 0).
            b.roi(RoiKind::InputLoad, |b| {
                b.push(TraceOp::MemStream {
                    base: addr::input(i, m.x),
                    bytes: m.x,
                    write: false,
                    insts_per_line: 2,
                    prefetchable: false,
                });
                b.compute(InstClass::IntAlu, (m.n_h + m.x) / 4 + 30);
            });
        }
        cell_mvm(b, core, m);
        emit_gate_activations(b, m.n_h, 4);
        emit_gate_combine(b, m.n_h, 4);
        if core == 0 {
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Recv { ch: 0 });
                b.push(TraceOp::Recv { ch: 1 });
                b.push(TraceOp::Recv { ch: 2 });
                // Broadcast assembled h for the recurrence + dense layer.
                for (k, ch) in [3usize, 4, 5, 6].iter().enumerate() {
                    b.push(TraceOp::Send {
                        ch: *ch,
                        bytes: 4 * m.n_h,
                        addr: addr::channel(*ch, i) + k as u64,
                    });
                }
            });
        } else {
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send {
                    ch: core - 1,
                    bytes: 4 * quarter,
                    addr: addr::channel(core - 1, i),
                });
                b.push(TraceOp::Recv { ch: core + 2 }); // h broadcast
            });
        }
    }
    // Core 4: dense layer (body supplied by the variant).
    let b = &mut cores[4];
    b.roi(RoiKind::Communication, |b| {
        b.push(TraceOp::Recv { ch: 6 });
    });
    dense_body(b, m, i);
}

fn quin_core_spec(tiles: &[TileSpec], _n_h: u64) -> MachineSpec {
    MachineSpec {
        tiles: tiles.to_vec(),
        mutexes: 1,
        channels: vec![
            ChannelSpec { producer: 1, consumer: 0, capacity: 2 },
            ChannelSpec { producer: 2, consumer: 0, capacity: 2 },
            ChannelSpec { producer: 3, consumer: 0, capacity: 2 },
            ChannelSpec { producer: 0, consumer: 1, capacity: 2 },
            ChannelSpec { producer: 0, consumer: 2, capacity: 2 },
            ChannelSpec { producer: 0, consumer: 3, capacity: 2 },
            ChannelSpec { producer: 0, consumer: 4, capacity: 2 },
        ],
        ..Default::default()
    }
}

/// Case 4: quin core — cell column-sliced over 4 tiles/cores (the
/// four-consecutive-columns gate slicing of [37]), dense on core 4.
fn analog_case4(m: LstmModel, n_inf: u32) -> Workload {
    let quarter_cols = (m.cell_cols() / 4) as u32;
    let (r4, c4) = LstmModel::paper_tile_dims(m.n_h, 4)
        .unwrap_or((m.cell_rows(), m.cell_cols() / 4));
    let mut tiles: Vec<TileSpec> = (0..4)
        .map(|_| TileSpec { rows: r4 as u32, cols: c4 as u32, coupling: Coupling::Tight })
        .collect();
    tiles.push(TileSpec {
        rows: m.dense_rows() as u32,
        cols: m.dense_cols() as u32,
        coupling: Coupling::Tight,
    });

    let mut cores: Vec<TraceBuilder> = (0..5).map(|_| TraceBuilder::new()).collect();
    for core in 0..4usize {
        cores[core].push(TraceOp::CmInit {
            tile: core,
            placement: Placement {
                row0: 0,
                col0: 0,
                rows: m.cell_rows() as u32,
                cols: quarter_cols.min(c4 as u32),
            },
        });
    }
    cores[4].push(TraceOp::CmInit {
        tile: 4,
        placement: Placement { row0: 0, col0: 0, rows: m.dense_rows() as u32, cols: m.dense_cols() as u32 },
    });

    let marks: Vec<usize> = cores.iter().map(TraceBuilder::mark).collect();
    for i in 0..n_inf {
        if i == 1 {
            for (b, mk) in cores.iter_mut().zip(&marks) {
                b.reserve_repeats(*mk, n_inf - 1);
            }
        }
        quin_core_step(
            &mut cores,
            &m,
            i,
            |b, core, m| {
                emit_queue(b, core, m.cell_rows());
                emit_process(b, core);
                emit_dequeue(b, core, m.n_h); // this core's quarter of all 4 gates
            },
            |b, m, i| {
                emit_queue(b, 4, m.dense_rows());
                emit_process(b, 4);
                emit_dequeue(b, 4, m.dense_cols());
                emit_softmax(b, m.y);
                emit_writeback(b, i, m.y);
            },
        );
    }
    Workload {
        label: format!("lstm{}/ANA-case4", m.n_h),
        traces: cores.into_iter().map(|b| b.build().into()).collect(),
        spec: quin_core_spec(&tiles, m.n_h),
        inferences: n_inf,
    }
}

