//! Legacy hand-written MLP generator — kept verbatim as the bit-equivalence
//! oracle for the mapping compiler (`workload::compile`); every `MlpCase`
//! compiled from its `(LayerGraph, Mapping)` table must reproduce these
//! traces exactly (see `tests/ir_equivalence.rs`). Deletable once the
//! compiler path has soaked.

use crate::config::SystemConfig;
use crate::isa::InstClass;
use crate::nn::MlpModel;
use crate::workload::mlp::MlpCase;
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::{ChannelSpec, MachineSpec, TileSpec};
use crate::stats::RoiKind;
use crate::workload::trace::{TraceBuilder, TraceOp};
use crate::workload::{addr, costs, Workload};

pub fn generate(case: MlpCase, _cfg: &SystemConfig, n_inf: u32) -> Workload {
    let model = MlpModel::paper();
    match case {
        MlpCase::Digital { cores: 1 } => digital_1core(model, n_inf),
        MlpCase::Digital { cores: 2 } => digital_2core(model, n_inf),
        MlpCase::Digital { cores: 4 } => digital_4core(model, n_inf),
        MlpCase::Digital { cores } => panic!("unsupported digital core count {cores}"),
        MlpCase::Analog { case: 1 } => analog_case1(model, n_inf),
        MlpCase::Analog { case: 2 } => analog_case2(model, n_inf),
        MlpCase::Analog { case: 3 } => analog_case3(model, n_inf),
        MlpCase::Analog { case: 4 } => analog_case4(model, n_inf),
        MlpCase::Analog { case } => panic!("unsupported analog case {case}"),
        MlpCase::AnalogLoose => analog_loose(model, n_inf),
    }
}

// ---------------------------------------------------------------------------
// Shared emission helpers
// ---------------------------------------------------------------------------

/// Digital GEMV over `rows x cols` int8 weights: weight stream + SIMD MACs.
fn emit_digital_gemv(b: &mut TraceBuilder, w_base: u64, rows: u64, cols: u64) {
    b.roi(RoiKind::DigitalMvm, |b| {
        // The weight matrix streams through the cache hierarchy once per
        // inference (this is the §VII.E thrashing working set).
        b.stream_read(w_base, rows * cols, 1);
        let c = costs::gemv_row_insts(rows); // dot over `rows` per output
        b.compute(InstClass::SimdOp, cols * c.simd_insts);
        b.compute(InstClass::IntAlu, cols * c.alu_insts);
    });
}

/// AIMClib queueVector: f32 -> int8 cast + pack + CM_QUEUE beats.
pub(crate) fn emit_queue(b: &mut TraceBuilder, tile: usize, elems: u64) {
    b.roi(RoiKind::AnalogQueue, |b| {
        b.compute(InstClass::SimdOp, costs::cast_insts(elems));
        b.push(TraceOp::CmQueue { tile, bytes: elems });
    });
}

pub(crate) fn emit_process(b: &mut TraceBuilder, tile: usize) {
    b.roi(RoiKind::AnalogProcess, |b| {
        b.push(TraceOp::CmProcess { tile });
    });
}

pub(crate) fn emit_dequeue(b: &mut TraceBuilder, tile: usize, elems: u64) {
    b.roi(RoiKind::AnalogDequeue, |b| {
        b.push(TraceOp::CmDequeue { tile, bytes: elems });
        b.compute(InstClass::SimdOp, costs::cast_insts(elems));
    });
}

fn emit_relu(b: &mut TraceBuilder, elems: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(InstClass::SimdOp, elems / 8 + 4);
    });
}

fn emit_input_load(b: &mut TraceBuilder, i: u32, elems: u64) {
    b.roi(RoiKind::InputLoad, |b| {
        // Fresh fp32 input per inference (casting to int8 is AIMClib's
        // job, §IV.C): cold lines, and the short read doesn't ramp the
        // stride prefetcher.
        let bytes = 4 * elems;
        b.push(TraceOp::MemStream {
            base: addr::input(i, bytes),
            bytes,
            write: false,
            insts_per_line: 2,
            prefetchable: false,
        });
        // AIMClib input marshalling (bounds checks, pointer setup).
        b.compute(InstClass::IntAlu, elems / 4 + 40);
    });
}

fn emit_writeback(b: &mut TraceBuilder, i: u32, elems: u64) {
    b.roi(RoiKind::Writeback, |b| {
        b.stream_write(addr::output(i, 4 * elems), 4 * elems, 2);
    });
}

// ---------------------------------------------------------------------------
// Digital references
// ---------------------------------------------------------------------------

fn digital_1core(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let mut b = TraceBuilder::new();
    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            // Inference 0 sized one block; reserve the rest up front.
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, n);
        for l in 0..m.layers as usize {
            emit_digital_gemv(&mut b, addr::weights(l), n, n);
            emit_relu(&mut b, n);
        }
        emit_writeback(&mut b, i, n);
    }
    Workload {
        label: "mlp/DIG-1core".into(),
        traces: vec![b.build().into()],
        spec: MachineSpec::default(),
        inferences: n_inf,
    }
}

fn digital_2core(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    // Core 0: input + layer 1; core 1: layer 2 + writeback.
    let mut c0 = TraceBuilder::new();
    let mut c1 = TraceBuilder::new();
    let (s0, s1) = (c0.mark(), c1.mark());
    for i in 0..n_inf {
        if i == 1 {
            c0.reserve_repeats(s0, n_inf - 1);
            c1.reserve_repeats(s1, n_inf - 1);
        }
        emit_input_load(&mut c0, i, n);
        emit_digital_gemv(&mut c0, addr::weights(0), n, n);
        emit_relu(&mut c0, n);
        c0.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Send { ch: 0, bytes: 4 * n, addr: addr::channel(0, i) });
        });

        c1.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Recv { ch: 0 });
        });
        emit_digital_gemv(&mut c1, addr::weights(1), n, n);
        emit_relu(&mut c1, n);
        emit_writeback(&mut c1, i, n);
    }
    Workload {
        label: "mlp/DIG-2core".into(),
        traces: vec![c0.build().into(), c1.build().into()],
        spec: MachineSpec {
            channels: vec![ChannelSpec { producer: 0, consumer: 1, capacity: 2 }],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

fn digital_4core(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let half = n / 2;
    // Cores 0,1: column halves of layer 1; cores 2,3: halves of layer 2.
    // Layer-1 halves are synced via a mutex before layer 2 proceeds.
    let mut cores: Vec<TraceBuilder> = (0..4).map(|_| TraceBuilder::new()).collect();
    // channels: 0->2, 0->3, 1->2, 1->3 (each layer-2 core needs both halves)
    let ch = |p: usize, c: usize| -> usize {
        match (p, c) {
            (0, 2) => 0,
            (0, 3) => 1,
            (1, 2) => 2,
            (1, 3) => 3,
            _ => unreachable!(),
        }
    };
    let marks: Vec<usize> = cores.iter().map(TraceBuilder::mark).collect();
    for i in 0..n_inf {
        if i == 1 {
            for (b, m) in cores.iter_mut().zip(&marks) {
                b.reserve_repeats(*m, n_inf - 1);
            }
        }
        for p in 0..2usize {
            let b = &mut cores[p];
            emit_input_load(b, i, n);
            // Half the columns: weight stream is half the matrix.
            b.roi(RoiKind::DigitalMvm, |b| {
                b.stream_read(addr::weights(0) + p as u64 * (n * half), n * half, 1);
                let c = costs::gemv_row_insts(n);
                b.compute(InstClass::SimdOp, half * c.simd_insts);
                b.compute(InstClass::IntAlu, half * c.alu_insts);
            });
            emit_relu(b, half);
            b.roi(RoiKind::Sync, |b| {
                b.push(TraceOp::MutexLock { id: 0 });
                b.push(TraceOp::MutexUnlock { id: 0 });
            });
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send { ch: ch(p, 2), bytes: 4 * half, addr: addr::channel(ch(p, 2), i) });
                b.push(TraceOp::Send { ch: ch(p, 3), bytes: 4 * half, addr: addr::channel(ch(p, 3), i) });
            });
        }
        for (idx, c) in [2usize, 3].iter().enumerate() {
            let b = &mut cores[*c];
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Recv { ch: ch(0, *c) });
                b.push(TraceOp::Recv { ch: ch(1, *c) });
            });
            b.roi(RoiKind::DigitalMvm, |b| {
                b.stream_read(addr::weights(1) + idx as u64 * (n * half), n * half, 1);
                let cst = costs::gemv_row_insts(n);
                b.compute(InstClass::SimdOp, half * cst.simd_insts);
                b.compute(InstClass::IntAlu, half * cst.alu_insts);
            });
            emit_relu(b, half);
            b.roi(RoiKind::Sync, |b| {
                b.push(TraceOp::MutexLock { id: 1 });
                b.push(TraceOp::MutexUnlock { id: 1 });
            });
            emit_writeback(b, i, half);
        }
    }
    Workload {
        label: "mlp/DIG-4core".into(),
        traces: cores.into_iter().map(|b| b.build().into()).collect(),
        spec: MachineSpec {
            mutexes: 2,
            channels: vec![
                ChannelSpec { producer: 0, consumer: 2, capacity: 2 },
                ChannelSpec { producer: 0, consumer: 3, capacity: 2 },
                ChannelSpec { producer: 1, consumer: 2, capacity: 2 },
                ChannelSpec { producer: 1, consumer: 3, capacity: 2 },
            ],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

// ---------------------------------------------------------------------------
// Analog cases (Fig. 6b)
// ---------------------------------------------------------------------------

/// Case 1: single core, one large 1024x2048 tile holding both layers
/// side by side; one CM_PROCESS per layer.
fn analog_case1(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let mut b = TraceBuilder::new();
    b.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 },
    });
    b.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement { row0: 0, col0: n as u32, rows: n as u32, cols: n as u32 },
    });
    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, n);
        for _l in 0..m.layers {
            emit_queue(&mut b, 0, n);
            emit_process(&mut b, 0);
            emit_dequeue(&mut b, 0, n);
            emit_relu(&mut b, n);
        }
        emit_writeback(&mut b, i, n);
    }
    Workload {
        label: "mlp/ANA-case1".into(),
        traces: vec![b.build().into()],
        spec: MachineSpec {
            tiles: vec![TileSpec { rows: n as u32, cols: 2 * n as u32, coupling: Coupling::Tight }],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

/// Case 2: single core, half-height tiles — each layer is split into two
/// 512-row blocks (2 x CM_PROCESS per layer, partials accumulated by the
/// tile-local digital logic), so CM_PROCESS fires twice as often (§VII.B).
fn analog_case2(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let half = (n / 2) as u32;
    let mut b = TraceBuilder::new();
    for t in 0..4usize {
        b.push(TraceOp::CmInit {
            tile: t,
            placement: Placement { row0: 0, col0: 0, rows: half, cols: n as u32 },
        });
    }
    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, n);
        for l in 0..m.layers as usize {
            let (ta, tb) = (2 * l, 2 * l + 1);
            // Split the input vector across the two row-block tiles.
            emit_queue(&mut b, ta, n / 2);
            emit_queue(&mut b, tb, n / 2);
            emit_process(&mut b, ta);
            emit_process(&mut b, tb);
            // Partial outputs accumulate digitally; one dequeue of the sum
            // plus the extra adds.
            emit_dequeue(&mut b, tb, n);
            b.roi(RoiKind::AnalogDequeue, |b| {
                b.compute(InstClass::SimdOp, n / 8);
            });
            emit_relu(&mut b, n);
        }
        emit_writeback(&mut b, i, n);
    }
    let tiles = (0..4)
        .map(|_| TileSpec { rows: half, cols: n as u32, coupling: Coupling::Tight })
        .collect();
    Workload {
        label: "mlp/ANA-case2".into(),
        traces: vec![b.build().into()],
        spec: MachineSpec { tiles, ..Default::default() },
        inferences: n_inf,
    }
}

/// Case 3: dual core, one layer per core. The hand-off buffer is the
/// paper's mutex-synchronized shared activation array: the producer may
/// not overwrite it until the consumer has finished the previous
/// inference (§VII.C attributes the multi-core slowdown to exactly this
/// inter-layer communication/synchronization).
fn analog_case3(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let mut c0 = TraceBuilder::new();
    let mut c1 = TraceBuilder::new();
    c0.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 },
    });
    c1.push(TraceOp::CmInit {
        tile: 1,
        placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 },
    });
    let (s0, s1) = (c0.mark(), c1.mark());
    for i in 0..n_inf {
        if i == 1 {
            c0.reserve_repeats(s0, n_inf - 1);
            c1.reserve_repeats(s1, n_inf - 1);
        }
        emit_input_load(&mut c0, i, n);
        emit_queue(&mut c0, 0, n);
        emit_process(&mut c0, 0);
        emit_dequeue(&mut c0, 0, n);
        emit_relu(&mut c0, n);
        c0.roi(RoiKind::Communication, |b| {
            if i > 0 {
                b.push(TraceOp::Recv { ch: 1 }); // buffer-free ack
            }
            b.push(TraceOp::Send { ch: 0, bytes: 4 * n, addr: addr::channel(0, i) });
        });

        c1.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Recv { ch: 0 });
        });
        emit_queue(&mut c1, 1, n);
        emit_process(&mut c1, 1);
        emit_dequeue(&mut c1, 1, n);
        emit_relu(&mut c1, n);
        emit_writeback(&mut c1, i, n);
        c1.roi(RoiKind::Communication, |b| {
            b.push(TraceOp::Send { ch: 1, bytes: 64, addr: addr::channel(1, i) });
        });
    }
    Workload {
        label: "mlp/ANA-case3".into(),
        traces: vec![c0.build().into(), c1.build().into()],
        spec: MachineSpec {
            tiles: vec![
                TileSpec { rows: n as u32, cols: n as u32, coupling: Coupling::Tight },
                TileSpec { rows: n as u32, cols: n as u32, coupling: Coupling::Tight },
            ],
            channels: vec![
                ChannelSpec { producer: 0, consumer: 1, capacity: 2 },
                ChannelSpec { producer: 1, consumer: 0, capacity: 2 },
            ],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

/// Case 4: quad core, each layer's columns split across two cores; the
/// layer-1 pair sync via a mutex, then both halves go to both layer-2
/// cores (Fig. 6b case 4).
fn analog_case4(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let half = n / 2;
    let mut cores: Vec<TraceBuilder> = (0..4).map(|_| TraceBuilder::new()).collect();
    for (core, tile) in (0..4usize).zip(0..4usize) {
        cores[core].push(TraceOp::CmInit {
            tile,
            placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: half as u32 },
        });
    }
    let ch = |p: usize, c: usize| -> usize {
        match (p, c) {
            (0, 2) => 0,
            (0, 3) => 1,
            (1, 2) => 2,
            (1, 3) => 3,
            _ => unreachable!(),
        }
    };
    // Ack channels (shared-buffer synchronization, as in case 3):
    // 2->0 (4), 2->1 (5), 3->0 (6), 3->1 (7).
    let ack = |c: usize, p: usize| -> usize { 4 + (c - 2) * 2 + p };
    let marks: Vec<usize> = cores.iter().map(TraceBuilder::mark).collect();
    for i in 0..n_inf {
        if i == 1 {
            for (b, m) in cores.iter_mut().zip(&marks) {
                b.reserve_repeats(*m, n_inf - 1);
            }
        }
        for p in 0..2usize {
            let b = &mut cores[p];
            emit_input_load(b, i, n);
            emit_queue(b, p, n); // full input rows, half the columns
            emit_process(b, p);
            emit_dequeue(b, p, half);
            emit_relu(b, half);
            b.roi(RoiKind::Sync, |b| {
                b.push(TraceOp::MutexLock { id: 0 });
                b.push(TraceOp::MutexUnlock { id: 0 });
            });
            b.roi(RoiKind::Communication, |b| {
                if i > 0 {
                    b.push(TraceOp::Recv { ch: ack(2, p) });
                    b.push(TraceOp::Recv { ch: ack(3, p) });
                }
                b.push(TraceOp::Send { ch: ch(p, 2), bytes: 4 * half, addr: addr::channel(ch(p, 2), i) });
                b.push(TraceOp::Send { ch: ch(p, 3), bytes: 4 * half, addr: addr::channel(ch(p, 3), i) });
            });
        }
        for c in [2usize, 3] {
            let b = &mut cores[c];
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Recv { ch: ch(0, c) });
                b.push(TraceOp::Recv { ch: ch(1, c) });
            });
            emit_queue(b, c, n);
            emit_process(b, c);
            emit_dequeue(b, c, half);
            emit_relu(b, half);
            b.roi(RoiKind::Sync, |b| {
                b.push(TraceOp::MutexLock { id: 1 });
                b.push(TraceOp::MutexUnlock { id: 1 });
            });
            emit_writeback(b, i, half);
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send { ch: ack(c, 0), bytes: 64, addr: addr::channel(ack(c, 0), i) });
                b.push(TraceOp::Send { ch: ack(c, 1), bytes: 64, addr: addr::channel(ack(c, 1), i) });
            });
        }
    }
    let tiles = (0..4)
        .map(|_| TileSpec { rows: n as u32, cols: half as u32, coupling: Coupling::Tight })
        .collect();
    Workload {
        label: "mlp/ANA-case4".into(),
        traces: cores.into_iter().map(|b| b.build().into()).collect(),
        spec: MachineSpec {
            tiles,
            mutexes: 2,
            channels: vec![
                ChannelSpec { producer: 0, consumer: 2, capacity: 2 },
                ChannelSpec { producer: 0, consumer: 3, capacity: 2 },
                ChannelSpec { producer: 1, consumer: 2, capacity: 2 },
                ChannelSpec { producer: 1, consumer: 3, capacity: 2 },
                ChannelSpec { producer: 2, consumer: 0, capacity: 2 },
                ChannelSpec { producer: 2, consumer: 1, capacity: 2 },
                ChannelSpec { producer: 3, consumer: 0, capacity: 2 },
                ChannelSpec { producer: 3, consumer: 1, capacity: 2 },
            ],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

/// §VII.B loosely-coupled: two pipelined tiles with dedicated ReLU units
/// in an off-chip accelerator; a single CPU core feeds inputs and
/// collects outputs over the peripheral I/O bus.
fn analog_loose(m: MlpModel, n_inf: u32) -> Workload {
    let n = m.dim;
    let mut b = TraceBuilder::new();
    b.push(TraceOp::CmInit {
        tile: 0,
        placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 },
    });
    b.push(TraceOp::CmInit {
        tile: 1,
        placement: Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 },
    });
    let start = b.mark();
    for i in 0..n_inf {
        if i == 1 {
            b.reserve_repeats(start, n_inf - 1);
        }
        emit_input_load(&mut b, i, n);
        emit_queue(&mut b, 0, n);
        // Both layers execute inside the accelerator (tile-to-tile
        // forwarding through the dedicated ReLU units); the CPU only
        // waits for the two processes.
        emit_process(&mut b, 0);
        emit_process(&mut b, 1);
        emit_dequeue(&mut b, 1, n);
        emit_relu(&mut b, n);
        emit_writeback(&mut b, i, n);
    }
    Workload {
        label: "mlp/ANA-loose".into(),
        traces: vec![b.build().into()],
        spec: MachineSpec {
            tiles: vec![
                TileSpec { rows: n as u32, cols: n as u32, coupling: Coupling::Loose },
                TileSpec { rows: n as u32, cols: n as u32, coupling: Coupling::Loose },
            ],
            ..Default::default()
        },
        inferences: n_inf,
    }
}

