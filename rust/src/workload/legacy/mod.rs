//! The retired hand-written workload generators, kept **verbatim** as
//! the test oracle for the mapping compiler.
//!
//! `workload::{mlp,lstm,cnn}::generate` now lower every case through
//! `(LayerGraph, Mapping)` + `workload::compile::compile`; the
//! `ir_equivalence` integration tests (and the CI `ir-equivalence` gate)
//! assert the compiled traces, machine specs and resulting `RunStats`
//! are bit-identical to these generators for every paper case. Once the
//! compiler path has soaked for a release, this module can be deleted
//! along with those tests.

pub mod cnn;
pub mod lstm;
pub mod mlp;
