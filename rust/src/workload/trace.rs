//! The trace IR: the interface between workload generators and the
//! trace machine.
//!
//! A workload is one [`Trace`] per core: a program of [`Segment`]s that
//! is straight-line ops, an explicit `Rep { body, count }` loop of a
//! flat body, or a nested `Loop { body, count }` whose body is itself a
//! segment program (a CNN row-loop inside the per-inference loop,
//! per-request bodies in batched traces). Steady-state workloads
//! (N inferences of the same network) store the per-inference block
//! *once* inside a `Rep`/`Loop` instead of cloning it N times, so trace
//! memory and compile time are O(block), not O(N*block); nested loops
//! compose address strides additively across levels, and
//! [`Trace::flatten`] recovers the exact flat stream for oracle
//! comparisons. Ops are either *local* (compute bursts, memory streams)
//! or *interacting* (AIMC tile ops, mutexes, channels). Memory is
//! line-granular: `MemStream` walks cache lines through the full
//! hierarchy, so cache behaviour (and therefore LLCMPI and DRAM energy)
//! emerges from the actual access pattern rather than analytic formulas.

use crate::isa::InstClass;
use crate::sim::aimc::Placement;
use crate::stats::RoiKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// Execute `insts` instructions of `class` back to back.
    Compute { class: InstClass, insts: u64 },

    /// Stream `bytes` from `base`, touching every cache line once.
    /// `insts_per_line` models the loads/stores issued per line (e.g. 4
    /// NEON 16-byte loads). `prefetchable` streams hide miss latency up
    /// to the stride prefetcher's depth; random/pointer-chasing accesses
    /// do not.
    MemStream {
        base: u64,
        bytes: u64,
        write: bool,
        insts_per_line: u64,
        prefetchable: bool,
    },

    /// CM_INITIALIZE: program a matrix region onto a tile (one-time).
    CmInit { tile: usize, placement: Placement },

    /// CM_QUEUE `bytes` into the tile's input memory (4 B / instruction).
    CmQueue { tile: usize, bytes: u64 },

    /// CM_PROCESS: fire the MVM; the core blocks until the tile is done.
    CmProcess { tile: usize },

    /// CM_DEQUEUE `bytes` from the tile's output memory.
    CmDequeue { tile: usize, bytes: u64 },

    /// pthread mutex lock/unlock.
    MutexLock { id: usize },
    MutexUnlock { id: usize },

    /// Ping-pong channel send: publish `bytes` at `addr` to the consumer.
    /// Blocks while the bounded buffer is full.
    Send { ch: usize, bytes: u64, addr: u64 },

    /// Ping-pong channel receive: blocks until a message is ready, then
    /// pulls its lines through the coherent-transfer path.
    Recv { ch: usize },

    /// Sub-ROI attribution markers (nestable).
    RoiPush { kind: RoiKind },
    RoiPop,
}

/// Shift the iteration-affine address of `op` by `iter * stride`.
/// Only `MemStream` bases and `Send` buffer addresses evolve across
/// `Rep` iterations (fresh per-inference input/output regions); every
/// other field is iteration-invariant by construction.
#[inline]
pub fn apply_stride(op: TraceOp, stride: i64, iter: u32) -> TraceOp {
    if stride == 0 || iter == 0 {
        return op;
    }
    let delta = stride.wrapping_mul(iter as i64);
    match op {
        TraceOp::MemStream { base, bytes, write, insts_per_line, prefetchable } => {
            TraceOp::MemStream {
                base: base.wrapping_add_signed(delta),
                bytes,
                write,
                insts_per_line,
                prefetchable,
            }
        }
        TraceOp::Send { ch, bytes, addr } => {
            TraceOp::Send { ch, bytes, addr: addr.wrapping_add_signed(delta) }
        }
        other => other,
    }
}

/// Per-op address delta between two sample iterations, if the two ops
/// are the same op modulo an affine address shift.
fn stride_between(a: TraceOp, b: TraceOp) -> Option<i64> {
    if a == b {
        return Some(0);
    }
    match (a, b) {
        (
            TraceOp::MemStream { base: ba, bytes, write, insts_per_line, prefetchable },
            TraceOp::MemStream { base: bb, bytes: b2, write: w2, insts_per_line: i2, prefetchable: p2 },
        ) if bytes == b2 && write == w2 && insts_per_line == i2 && prefetchable == p2 => {
            Some(bb.wrapping_sub(ba) as i64)
        }
        (TraceOp::Send { ch, bytes, addr: aa }, TraceOp::Send { ch: c2, bytes: b2, addr: ab })
            if ch == c2 && bytes == b2 =>
        {
            Some(ab.wrapping_sub(aa) as i64)
        }
        _ => None,
    }
}

/// One segment of a [`Trace`] program. Segments nest: a `Loop` body is
/// itself a segment program, so a trace can hold e.g. a row-group `Rep`
/// inside a per-inference `Loop` without unrolling either level.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Segment {
    /// A straight-line run of ops, executed once.
    Ops(Vec<TraceOp>),
    /// `count` iterations of a flat `body`. `strides` (empty = all
    /// zero) holds one per-iteration address delta per body op: in
    /// iteration `k`, op `j` runs as `apply_stride(body[j], strides[j], k)`.
    Rep {
        body: Vec<TraceOp>,
        count: u32,
        strides: Vec<i64>,
    },
    /// `count` iterations of a nested segment program. `strides` (empty
    /// = all zero) holds one per-iteration address delta per *stored*
    /// op of `body` in recursive stored order: in outer iteration `k`,
    /// stored op `j` shifts by `strides[j] * k` on top of whatever
    /// shifts inner `Rep`/`Loop` levels apply — addresses are affine in
    /// every enclosing loop index, composing by wrapping addition.
    Loop {
        body: Vec<Segment>,
        count: u32,
        strides: Vec<i64>,
    },
}

impl Segment {
    /// Flattened op count of this segment. Panics if the (checked)
    /// [`Segment::flat_len`] overflows `usize`; size-validate untrusted
    /// nested traces with `flat_len` first.
    pub fn op_count(&self) -> usize {
        self.flat_len()
            .and_then(|n| usize::try_from(n).ok())
            .expect("segment flat length overflows usize — validate with flat_len()")
    }

    /// Checked flattened op count. Nested loop counts multiply, so the
    /// math is full checked `u64`: `None` means the product overflows
    /// (a trace that could never be simulated or unrolled anyway).
    pub fn flat_len(&self) -> Option<u64> {
        match self {
            Segment::Ops(v) => Some(v.len() as u64),
            Segment::Rep { body, count, .. } => {
                (body.len() as u64).checked_mul(u64::from(*count))
            }
            Segment::Loop { body, count, .. } => body
                .iter()
                .try_fold(0u64, |acc, s| acc.checked_add(s.flat_len()?))?
                .checked_mul(u64::from(*count)),
        }
    }

    /// Physically stored op count (a `Rep`/`Loop` body counts once;
    /// `Loop` bodies count recursively).
    pub fn stored_ops(&self) -> usize {
        match self {
            Segment::Ops(v) => v.len(),
            Segment::Rep { body, .. } => body.len(),
            Segment::Loop { body, .. } => body.iter().map(Segment::stored_ops).sum(),
        }
    }

    /// Visit the flattened ops of this segment with `shifts[j]` (one
    /// absolute address delta per stored op, missing = 0) already
    /// accumulated from enclosing loop levels.
    fn visit_shifted(&self, shifts: &[i64], f: &mut dyn FnMut(TraceOp)) {
        let shift_at = |j: usize| shifts.get(j).copied().unwrap_or(0);
        match self {
            Segment::Ops(v) => {
                for (j, &op) in v.iter().enumerate() {
                    f(apply_stride(op, shift_at(j), 1));
                }
            }
            Segment::Rep { body, count, strides } => {
                for k in 0..*count {
                    for (j, &op) in body.iter().enumerate() {
                        let op = apply_stride(op, strides.get(j).copied().unwrap_or(0), k);
                        f(apply_stride(op, shift_at(j), 1));
                    }
                }
            }
            Segment::Loop { body, count, strides } => {
                for k in 0..*count {
                    let mut base = 0usize;
                    for child in body {
                        let n = child.stored_ops();
                        if shifts.is_empty() && (strides.is_empty() || k == 0) {
                            child.visit_shifted(&[], f);
                        } else {
                            let child_shifts: Vec<i64> = (0..n)
                                .map(|j| {
                                    let s = strides.get(base + j).copied().unwrap_or(0);
                                    shift_at(base + j)
                                        .wrapping_add(s.wrapping_mul(i64::from(k)))
                                })
                                .collect();
                            child.visit_shifted(&child_shifts, f);
                        }
                        base += n;
                    }
                }
            }
        }
    }

    /// Visit every flattened op of this segment in order.
    pub fn visit_flat(&self, f: &mut dyn FnMut(TraceOp)) {
        self.visit_shifted(&[], f);
    }

    /// Visit each *stored* op once with its execution multiplicity
    /// scaled by `mult` (saturating — use [`Segment::flat_len`] to
    /// reject pathological count products up front).
    fn for_each_weighted(&self, mult: u64, f: &mut dyn FnMut(TraceOp, u64)) {
        match self {
            Segment::Ops(v) => {
                for &op in v {
                    f(op, mult);
                }
            }
            Segment::Rep { body, count, .. } => {
                let m = mult.saturating_mul(u64::from(*count));
                for &op in body {
                    f(op, m);
                }
            }
            Segment::Loop { body, count, .. } => {
                let m = mult.saturating_mul(u64::from(*count));
                for child in body {
                    child.for_each_weighted(m, f);
                }
            }
        }
    }

    /// Build a `Rep` from sampled iterations when the emission is
    /// iteration-affine: every `(sample, k)` in `checks` must equal
    /// `first` (= iteration 0) op for op with its addresses advanced by
    /// `k` per-op strides (derived from the first check). Callers sample
    /// iterations 1, 2 AND `count - 1` — collinearity at 0..2 plus the
    /// far endpoint rejects any periodic or piecewise pattern that
    /// merely starts out straight — and fall back to flat unrolling on
    /// `None`, so the encoding is always bit-exact.
    pub fn rep_from_samples(
        first: &[TraceOp],
        checks: &[(&[TraceOp], u32)],
        count: u32,
    ) -> Option<Segment> {
        let (second, k1) = *checks.first()?;
        if first.len() != second.len() || k1 != 1 {
            return None;
        }
        let mut strides = vec![0i64; first.len()];
        let mut any = false;
        for (j, (&a, &b)) in first.iter().zip(second).enumerate() {
            let s = stride_between(a, b)?;
            strides[j] = s;
            any |= s != 0;
        }
        for &(sample, k) in &checks[1..] {
            if sample.len() != first.len() {
                return None;
            }
            for (j, (&a, &c)) in first.iter().zip(sample).enumerate() {
                if apply_stride(a, strides[j], k) != c {
                    return None;
                }
            }
        }
        Some(Segment::Rep {
            body: first.to_vec(),
            count,
            strides: if any { strides } else { Vec::new() },
        })
    }

    /// Nested analogue of [`Segment::rep_from_samples`]: build a `Loop`
    /// from whole sampled iteration *programs* (each a segment list,
    /// possibly containing inner `Rep`/`Loop` segments). Samples must be
    /// structurally identical — same segment kinds, body lengths, inner
    /// counts and inner strides — with stored-op addresses affine in the
    /// outer iteration index. `checks` follows the same protocol
    /// (iteration 1 first, then 2 and `count - 1` as far-endpoint
    /// guards); callers fall back to splicing the samples flat on
    /// `None`, so the encoding is always bit-exact.
    ///
    /// A single flat `Ops` sample degrades to a plain `Rep`, so nested
    /// emission never pessimizes traces the flat encoder handles.
    pub fn loop_from_samples(
        first: &[Segment],
        checks: &[(&[Segment], u32)],
        count: u32,
    ) -> Option<Segment> {
        let (second, k1) = *checks.first()?;
        if k1 != 1 {
            return None;
        }
        let mut strides = Vec::new();
        let any = derive_loop_strides(first, second, &mut strides)?;
        for &(sample, k) in &checks[1..] {
            let mut idx = 0usize;
            if !check_loop_sample(first, sample, &strides, k, &mut idx) {
                return None;
            }
        }
        let strides = if any { strides } else { Vec::new() };
        if let [Segment::Ops(body)] = first {
            return Some(Segment::Rep { body: body.clone(), count, strides });
        }
        Some(Segment::Loop { body: first.to_vec(), count, strides })
    }
}

/// Walk two structurally-identical segment programs in recursive
/// stored-op order, appending the per-outer-iteration stride of every
/// stored op to `out`. Returns `Some(any_nonzero)` on success, `None`
/// on any structural mismatch or non-affine op pair.
fn derive_loop_strides(a: &[Segment], b: &[Segment], out: &mut Vec<i64>) -> Option<bool> {
    if a.len() != b.len() {
        return None;
    }
    let mut any = false;
    for (sa, sb) in a.iter().zip(b) {
        match (sa, sb) {
            (Segment::Ops(x), Segment::Ops(y)) => {
                if x.len() != y.len() {
                    return None;
                }
                for (&oa, &ob) in x.iter().zip(y) {
                    let s = stride_between(oa, ob)?;
                    any |= s != 0;
                    out.push(s);
                }
            }
            (
                Segment::Rep { body: x, count: cx, strides: sx },
                Segment::Rep { body: y, count: cy, strides: sy },
            ) => {
                // Inner strides must be outer-invariant: only the body's
                // base addresses may advance with the outer index.
                if cx != cy || sx != sy || x.len() != y.len() {
                    return None;
                }
                for (&oa, &ob) in x.iter().zip(y) {
                    let s = stride_between(oa, ob)?;
                    any |= s != 0;
                    out.push(s);
                }
            }
            (
                Segment::Loop { body: x, count: cx, strides: sx },
                Segment::Loop { body: y, count: cy, strides: sy },
            ) => {
                if cx != cy || sx != sy {
                    return None;
                }
                any |= derive_loop_strides(x, y, out)?;
            }
            _ => return None,
        }
    }
    Some(any)
}

/// Verify that `sample` equals `first` with every stored op shifted by
/// `strides[j] * k` (`j` advancing through `idx` in recursive stored
/// order), with identical structure at every level.
fn check_loop_sample(
    first: &[Segment],
    sample: &[Segment],
    strides: &[i64],
    k: u32,
    idx: &mut usize,
) -> bool {
    if first.len() != sample.len() {
        return false;
    }
    let check_ops = |x: &[TraceOp], y: &[TraceOp], idx: &mut usize| {
        if x.len() != y.len() {
            return false;
        }
        for (&oa, &ob) in x.iter().zip(y) {
            let s = strides.get(*idx).copied().unwrap_or(0);
            *idx += 1;
            if apply_stride(oa, s, k) != ob {
                return false;
            }
        }
        true
    };
    for (sa, sb) in first.iter().zip(sample) {
        let ok = match (sa, sb) {
            (Segment::Ops(x), Segment::Ops(y)) => check_ops(x, y, idx),
            (
                Segment::Rep { body: x, count: cx, strides: sx },
                Segment::Rep { body: y, count: cy, strides: sy },
            ) => cx == cy && sx == sy && check_ops(x, y, idx),
            (
                Segment::Loop { body: x, count: cx, strides: sx },
                Segment::Loop { body: y, count: cy, strides: sy },
            ) => cx == cy && sx == sy && check_loop_sample(x, y, strides, k, idx),
            _ => false,
        };
        if !ok {
            return false;
        }
    }
    true
}

/// A per-core trace program: segments executed in order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    pub segments: Vec<Segment>,
}

impl Trace {
    /// True if the flattened program has no ops.
    pub fn is_empty(&self) -> bool {
        self.segments.iter().all(|s| s.flat_len() == Some(0))
    }

    /// Flattened op count (what a fully unrolled trace would hold).
    /// Panics on `usize` overflow; size-validate untrusted nested
    /// traces with [`Trace::flat_len`] first.
    pub fn op_count(&self) -> usize {
        self.flat_len()
            .and_then(|n| usize::try_from(n).ok())
            .expect("trace flat length overflows usize — validate with flat_len()")
    }

    /// Checked flattened op count: `None` if nested loop counts multiply
    /// past `u64` (see [`Segment::flat_len`]).
    pub fn flat_len(&self) -> Option<u64> {
        self.segments.iter().try_fold(0u64, |acc, s| acc.checked_add(s.flat_len()?))
    }

    /// Physically stored op count (`Rep`/`Loop` bodies count once).
    pub fn stored_ops(&self) -> usize {
        self.segments.iter().map(Segment::stored_ops).sum()
    }

    /// Iterate the flattened op stream (repeating `Rep`/`Loop` bodies
    /// `count` times with their address strides applied). Yields ops by
    /// value — strided ops are materialized per iteration; nested
    /// `Loop` segments materialize their flattened body up front.
    pub fn iter_ops(&self) -> impl Iterator<Item = TraceOp> + '_ {
        fn segment_ops(seg: &Segment) -> Box<dyn Iterator<Item = TraceOp> + '_> {
            match seg {
                Segment::Ops(v) => Box::new(v.iter().copied()),
                Segment::Rep { body, count, strides } => {
                    Box::new((0..*count).flat_map(move |k| {
                        body.iter().enumerate().map(move |(j, &op)| {
                            apply_stride(op, strides.get(j).copied().unwrap_or(0), k)
                        })
                    }))
                }
                Segment::Loop { .. } => {
                    let mut v = Vec::with_capacity(seg.op_count());
                    seg.visit_flat(&mut |op| v.push(op));
                    Box::new(v.into_iter())
                }
            }
        }
        self.segments.iter().flat_map(segment_ops)
    }

    /// Visit each *stored* op once with its total execution multiplicity
    /// (loop body ops carry the product of their enclosing counts,
    /// saturating). Strided ops are reported with their iteration-0
    /// address — the synthetic address regions are stride-closed, so
    /// region classification is exact for every iteration.
    pub fn for_each_weighted(&self, f: &mut impl FnMut(TraceOp, u64)) {
        for seg in &self.segments {
            seg.for_each_weighted(1, &mut *f);
        }
    }

    /// Fully unroll into a flat op vector (the legacy representation; the
    /// `legacy/` oracle tests compare against this form).
    pub fn flatten(&self) -> Vec<TraceOp> {
        let mut out = Vec::with_capacity(self.op_count());
        out.extend(self.iter_ops());
        out
    }
}

impl From<Vec<TraceOp>> for Trace {
    fn from(ops: Vec<TraceOp>) -> Trace {
        if ops.is_empty() {
            Trace::default()
        } else {
            Trace { segments: vec![Segment::Ops(ops)] }
        }
    }
}

/// Builder helper so generators read naturally. Plain pushes accumulate
/// into an open straight-line run (`ops`); [`TraceBuilder::repeat`] and
/// [`TraceBuilder::push_segment`] close it and append looped segments.
#[derive(Clone, Debug, Default)]
pub struct TraceBuilder {
    /// The open straight-line tail (kept public: generators inspect and
    /// manipulate it directly).
    pub ops: Vec<TraceOp>,
    segments: Vec<Segment>,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// Builder with pre-reserved op capacity (generators that know their
    /// trace size up front avoid the re-allocation churn of multi-megaop
    /// CNN traces).
    pub fn with_capacity(cap: usize) -> TraceBuilder {
        TraceBuilder { ops: Vec::with_capacity(cap), segments: Vec::new() }
    }

    /// Reserve room for at least `additional` more ops.
    pub fn reserve(&mut self, additional: usize) -> &mut Self {
        self.ops.reserve(additional);
        self
    }

    /// Current op count of the open run — pair with
    /// [`TraceBuilder::reserve_repeats`].
    pub fn mark(&self) -> usize {
        self.ops.len()
    }

    /// After emitting one repeating block (e.g. the first inference)
    /// that started at `mark`, reserve capacity for `remaining` more
    /// blocks of the same size in one shot.
    pub fn reserve_repeats(&mut self, mark: usize, remaining: u32) -> &mut Self {
        let per_block = self.ops.len().saturating_sub(mark);
        self.ops.reserve(per_block.saturating_mul(remaining as usize));
        self
    }

    /// Append a pre-built op block (`TraceOp` is `Copy`, so this is a
    /// flat memcpy — the workload generators reuse per-inference /
    /// per-row blocks instead of re-emitting them op by op).
    pub fn extend_from_slice(&mut self, block: &[TraceOp]) -> &mut Self {
        self.ops.extend_from_slice(block);
        self
    }

    pub fn push(&mut self, op: TraceOp) -> &mut Self {
        self.ops.push(op);
        self
    }

    pub fn compute(&mut self, class: InstClass, insts: u64) -> &mut Self {
        if insts > 0 {
            self.push(TraceOp::Compute { class, insts });
        }
        self
    }

    pub fn stream_read(&mut self, base: u64, bytes: u64, insts_per_line: u64) -> &mut Self {
        self.push(TraceOp::MemStream { base, bytes, write: false, insts_per_line, prefetchable: true })
    }

    pub fn stream_write(&mut self, base: u64, bytes: u64, insts_per_line: u64) -> &mut Self {
        self.push(TraceOp::MemStream { base, bytes, write: true, insts_per_line, prefetchable: true })
    }

    pub fn roi(&mut self, kind: RoiKind, f: impl FnOnce(&mut TraceBuilder)) -> &mut Self {
        self.push(TraceOp::RoiPush { kind });
        f(self);
        self.push(TraceOp::RoiPop);
        self
    }

    /// Close the open straight-line run into its own segment.
    fn flush(&mut self) {
        if !self.ops.is_empty() {
            self.segments.push(Segment::Ops(std::mem::take(&mut self.ops)));
        }
    }

    /// Append a pre-built segment (closing the open run first).
    pub fn push_segment(&mut self, seg: Segment) -> &mut Self {
        self.flush();
        self.segments.push(seg);
        self
    }

    /// Emit `count` iterations of `f(builder, k)`. When the emission is
    /// iteration-affine (identical ops modulo linearly-advancing
    /// `MemStream`/`Send` addresses — verified against sampled
    /// iterations 1, 2 and `count - 1`) the result is a single looped
    /// `Rep` segment of one body; otherwise every iteration is unrolled
    /// flat. Either way the flattened trace is bit-identical to calling
    /// `f` for k in 0..count, so `f` must depend only on `k` (not on
    /// call order).
    pub fn repeat(&mut self, count: u32, mut f: impl FnMut(&mut TraceBuilder, u32)) -> &mut Self {
        fn sample(f: &mut dyn FnMut(&mut TraceBuilder, u32), k: u32) -> Vec<TraceOp> {
            let mut sb = TraceBuilder::new();
            f(&mut sb, k);
            sb.build()
        }
        // Below 5 iterations the 4 affinity samples cost as much as the
        // loop; just unroll.
        if count < 5 {
            for k in 0..count {
                let ops = sample(&mut f, k);
                self.ops.extend_from_slice(&ops);
            }
            return self;
        }
        let s0 = sample(&mut f, 0);
        let s1 = sample(&mut f, 1);
        let s2 = sample(&mut f, 2);
        let s_last = sample(&mut f, count - 1);
        let checks = [(s1.as_slice(), 1u32), (s2.as_slice(), 2), (s_last.as_slice(), count - 1)];
        match Segment::rep_from_samples(&s0, &checks, count) {
            Some(seg) => {
                self.push_segment(seg);
            }
            None => {
                self.ops.extend_from_slice(&s0);
                self.ops.extend_from_slice(&s1);
                self.ops.extend_from_slice(&s2);
                for k in 3..count - 1 {
                    let ops = sample(&mut f, k);
                    self.ops.extend_from_slice(&ops);
                }
                self.ops.extend_from_slice(&s_last);
            }
        }
        self
    }

    /// Nested-loop analogue of [`TraceBuilder::repeat`]: `f` emits a
    /// whole segment *program* per iteration (it may itself call
    /// `repeat`/`push_segment`), and iteration-affine emissions collapse
    /// into a single [`Segment::Loop`] — verified against sampled
    /// iterations 1, 2 and `count - 1`, exactly like the flat encoder.
    /// Non-affine emissions splice every sampled iteration's segments
    /// back in order, so the flattened trace is always bit-identical to
    /// calling `f` for k in 0..count (`f` must depend only on `k`).
    pub fn repeat_nested(
        &mut self,
        count: u32,
        mut f: impl FnMut(&mut TraceBuilder, u32),
    ) -> &mut Self {
        fn sample(f: &mut dyn FnMut(&mut TraceBuilder, u32), k: u32) -> Trace {
            let mut sb = TraceBuilder::new();
            f(&mut sb, k);
            sb.build_trace()
        }
        // Below 5 iterations the 4 affinity samples cost as much as the
        // loop; just splice.
        if count < 5 {
            for k in 0..count {
                self.splice(sample(&mut f, k));
            }
            return self;
        }
        let s0 = sample(&mut f, 0);
        let s1 = sample(&mut f, 1);
        let s2 = sample(&mut f, 2);
        let s_last = sample(&mut f, count - 1);
        let checks = [
            (s1.segments.as_slice(), 1u32),
            (s2.segments.as_slice(), 2),
            (s_last.segments.as_slice(), count - 1),
        ];
        match Segment::loop_from_samples(&s0.segments, &checks, count) {
            Some(seg) => {
                self.push_segment(seg);
            }
            None => {
                self.splice(s0);
                self.splice(s1);
                self.splice(s2);
                for k in 3..count - 1 {
                    let s = sample(&mut f, k);
                    self.splice(s);
                }
                self.splice(s_last);
            }
        }
        self
    }

    /// Append another trace's segments in emission order (straight-line
    /// runs merge into the open run; looped segments pass through).
    fn splice(&mut self, t: Trace) {
        for seg in t.segments {
            match seg {
                Segment::Ops(v) => {
                    self.ops.extend_from_slice(&v);
                }
                other => {
                    self.push_segment(other);
                }
            }
        }
    }

    /// Finish as a flat op vector (any looped segments are unrolled).
    pub fn build(self) -> Vec<TraceOp> {
        if self.segments.is_empty() {
            return self.ops;
        }
        let mut t = Trace { segments: self.segments };
        if !self.ops.is_empty() {
            t.segments.push(Segment::Ops(self.ops));
        }
        t.flatten()
    }

    /// Finish as a looped [`Trace`] program.
    pub fn build_trace(mut self) -> Trace {
        self.flush();
        Trace { segments: self.segments }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::addr;

    #[test]
    fn builder_skips_zero_compute() {
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 0);
        b.compute(InstClass::IntAlu, 5);
        assert_eq!(b.ops.len(), 1);
    }

    #[test]
    fn reserve_repeats_sizes_capacity() {
        let mut b = TraceBuilder::new();
        let start = b.mark();
        b.compute(InstClass::IntAlu, 5);
        b.stream_read(0, 64, 1);
        b.reserve_repeats(start, 9);
        // 2 ops emitted + room for 9 more blocks of 2.
        assert!(b.ops.capacity() >= 20);
        assert_eq!(b.ops.len(), 2);
    }

    #[test]
    fn extend_from_slice_appends_block() {
        let mut b = TraceBuilder::new();
        let block = vec![
            TraceOp::Compute { class: InstClass::SimdOp, insts: 4 },
            TraceOp::RoiPop,
        ];
        b.extend_from_slice(&block);
        b.extend_from_slice(&block);
        assert_eq!(b.ops.len(), 4);
        assert!(matches!(b.ops[2], TraceOp::Compute { insts: 4, .. }));
    }

    #[test]
    fn roi_brackets() {
        let mut b = TraceBuilder::new();
        b.roi(RoiKind::InputLoad, |b| {
            b.stream_read(0, 64, 4);
        });
        assert!(matches!(b.ops[0], TraceOp::RoiPush { kind: RoiKind::InputLoad }));
        assert!(matches!(b.ops[2], TraceOp::RoiPop));
        assert_eq!(b.ops.len(), 3);
    }

    /// One iteration of a representative affine block: a fixed-address
    /// weight stream, a fresh (iteration-advancing) input stream, and a
    /// compute burst.
    fn affine_block(b: &mut TraceBuilder, k: u32) {
        b.stream_read(addr::weights(0), 4096, 1);
        b.stream_read(addr::input(k, 256), 256, 2);
        b.compute(InstClass::SimdOp, 100);
    }

    #[test]
    fn repeat_affine_emits_single_rep() {
        let mut b = TraceBuilder::new();
        b.repeat(50, affine_block);
        let t = b.build_trace();
        assert_eq!(t.segments.len(), 1);
        let Segment::Rep { body, count, strides } = &t.segments[0] else {
            panic!("expected a Rep, got {:?}", t.segments[0]);
        };
        assert_eq!(*count, 50);
        assert_eq!(body.len(), 3);
        assert_eq!(strides[0], 0, "weight stream is iteration-invariant");
        assert_eq!(strides[1], addr::input(1, 256) as i64 - addr::input(0, 256) as i64);
        assert_eq!(t.stored_ops(), 3);
        assert_eq!(t.op_count(), 150);
    }

    #[test]
    fn repeat_flatten_matches_unrolled_emission() {
        let mut looped = TraceBuilder::new();
        looped.repeat(23, affine_block);
        let mut flat = TraceBuilder::new();
        for k in 0..23 {
            affine_block(&mut flat, k);
        }
        assert_eq!(looped.build_trace().flatten(), flat.build());
    }

    #[test]
    fn repeat_non_affine_falls_back_to_unroll() {
        // Iteration-dependent instruction counts are not affine-encodable.
        let f = |b: &mut TraceBuilder, k: u32| {
            b.compute(InstClass::IntAlu, 10 + k as u64);
        };
        let mut looped = TraceBuilder::new();
        looped.repeat(9, f);
        let t = looped.build_trace();
        assert!(t.segments.iter().all(|s| matches!(s, Segment::Ops(_))));
        let mut flat = TraceBuilder::new();
        for k in 0..9 {
            f(&mut flat, k);
        }
        assert_eq!(t.flatten(), flat.build());
    }

    #[test]
    fn repeat_small_counts_unroll() {
        let mut b = TraceBuilder::new();
        b.repeat(3, affine_block);
        let t = b.build_trace();
        assert!(t.segments.iter().all(|s| matches!(s, Segment::Ops(_))));
        assert_eq!(t.op_count(), 9);
    }

    #[test]
    fn period_three_collinear_prefix_is_rejected() {
        // k % 3 addresses are collinear over samples 0..2; only the
        // far-endpoint (count - 1) check exposes them.
        let f = |b: &mut TraceBuilder, k: u32| {
            b.stream_read(0x1000 + (k as u64 % 3) * 0x1000, 64, 1);
        };
        let mut looped = TraceBuilder::new();
        looped.repeat(9, f);
        let t = looped.build_trace();
        assert!(t.segments.iter().all(|s| matches!(s, Segment::Ops(_))));
        let mut flat = TraceBuilder::new();
        for k in 0..9 {
            f(&mut flat, k);
        }
        assert_eq!(t.flatten(), flat.build());
    }

    #[test]
    fn period_two_masquerading_as_affine_is_rejected() {
        // Alternating addresses diff "cleanly" between samples 0 and 1
        // but fail the third-sample affinity check.
        let f = |b: &mut TraceBuilder, k: u32| {
            b.stream_read(0x1000 + (k as u64 % 2) * 0x8000, 64, 1);
        };
        let mut looped = TraceBuilder::new();
        looped.repeat(8, f);
        let t = looped.build_trace();
        assert!(t.segments.iter().all(|s| matches!(s, Segment::Ops(_))));
        let mut flat = TraceBuilder::new();
        for k in 0..8 {
            f(&mut flat, k);
        }
        assert_eq!(t.flatten(), flat.build());
    }

    #[test]
    fn iter_ops_and_weighted_agree_with_flatten() {
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 7);
        b.repeat(12, affine_block);
        b.compute(InstClass::FpOp, 3);
        let t = b.build_trace();
        let flat = t.flatten();
        assert_eq!(flat.len(), t.op_count());
        assert_eq!(t.iter_ops().count(), flat.len());
        assert!(t.iter_ops().zip(&flat).all(|(a, &b)| a == b));
        // Weighted walk covers the same multiset of op executions.
        let mut weighted = 0u64;
        t.for_each_weighted(&mut |_, w| weighted += w);
        assert_eq!(weighted as usize, flat.len());
    }

    #[test]
    fn trace_from_flat_vec_roundtrips() {
        let ops = vec![
            TraceOp::Compute { class: InstClass::IntAlu, insts: 4 },
            TraceOp::RoiPush { kind: RoiKind::Misc },
            TraceOp::RoiPop,
        ];
        let t = Trace::from(ops.clone());
        assert_eq!(t.flatten(), ops);
        assert!(!t.is_empty());
        assert!(Trace::from(Vec::new()).is_empty());
    }

    /// One iteration of a nested block: an outer-advancing input
    /// stream, an inner affine row loop (base advancing with the outer
    /// index, stride advancing with the inner index), and a tail burst.
    fn nested_block(b: &mut TraceBuilder, k: u32) {
        b.stream_read(addr::input(k, 256), 256, 2);
        b.repeat(8, move |b, g| {
            b.stream_read(addr::ACTIVATIONS + k as u64 * 0x1000 + g as u64 * 0x100, 64, 1);
            b.compute(InstClass::SimdOp, 50);
        });
        b.compute(InstClass::FpOp, 10);
    }

    #[test]
    fn repeat_nested_affine_emits_single_loop() {
        let mut b = TraceBuilder::new();
        b.repeat_nested(12, nested_block);
        let t = b.build_trace();
        assert_eq!(t.segments.len(), 1);
        let Segment::Loop { body, count, strides } = &t.segments[0] else {
            panic!("expected a Loop, got {:?}", t.segments[0]);
        };
        assert_eq!(*count, 12);
        assert_eq!(body.len(), 3, "Ops / inner Rep / Ops");
        assert!(matches!(body[1], Segment::Rep { count: 8, .. }));
        // Stored order: input stream, inner body (stream, compute), tail.
        assert_eq!(strides.as_slice(), &[256, 0x1000, 0, 0]);
        assert_eq!(t.stored_ops(), 4);
        assert_eq!(t.op_count(), 12 * (1 + 8 * 2 + 1));
    }

    #[test]
    fn repeat_nested_flatten_matches_unrolled_emission() {
        let mut looped = TraceBuilder::new();
        looped.repeat_nested(11, nested_block);
        let mut flat = TraceBuilder::new();
        for k in 0..11 {
            nested_block(&mut flat, k);
        }
        assert_eq!(looped.build_trace().flatten(), flat.build());
    }

    #[test]
    fn repeat_nested_flat_body_degrades_to_rep() {
        let mut nested = TraceBuilder::new();
        nested.repeat_nested(50, affine_block);
        let mut plain = TraceBuilder::new();
        plain.repeat(50, affine_block);
        assert_eq!(nested.build_trace(), plain.build_trace());
    }

    #[test]
    fn repeat_nested_non_affine_falls_back_to_splice() {
        // Outer-dependent inner trip counts are structurally non-affine.
        let f = |b: &mut TraceBuilder, k: u32| {
            b.repeat(6 + k, |b, g| {
                b.stream_read(0x1000 + g as u64 * 64, 64, 1);
            });
        };
        let mut looped = TraceBuilder::new();
        looped.repeat_nested(7, f);
        let t = looped.build_trace();
        assert!(t.segments.iter().all(|s| !matches!(s, Segment::Loop { .. })));
        let mut flat = TraceBuilder::new();
        for k in 0..7 {
            f(&mut flat, k);
        }
        assert_eq!(t.flatten(), flat.build());
    }

    #[test]
    fn repeat_nested_far_endpoint_rejects_periodic_outer() {
        // Inner bases periodic in the outer index mod 3: collinear over
        // outer samples 0..2, exposed only by the count-1 endpoint.
        let f = |b: &mut TraceBuilder, k: u32| {
            b.repeat(6, move |b, g| {
                b.stream_read(0x1000 + (k as u64 % 3) * 0x10000 + g as u64 * 64, 64, 1);
            });
        };
        let mut looped = TraceBuilder::new();
        looped.repeat_nested(9, f);
        let t = looped.build_trace();
        assert!(t.segments.iter().all(|s| !matches!(s, Segment::Loop { .. })));
        let mut flat = TraceBuilder::new();
        for k in 0..9 {
            f(&mut flat, k);
        }
        assert_eq!(t.flatten(), flat.build());
    }

    #[test]
    fn nested_iter_and_weighted_agree_with_flatten() {
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 7);
        b.repeat_nested(12, nested_block);
        b.compute(InstClass::FpOp, 3);
        let t = b.build_trace();
        let flat = t.flatten();
        assert_eq!(flat.len(), t.op_count());
        assert_eq!(t.flat_len(), Some(flat.len() as u64));
        assert_eq!(t.iter_ops().count(), flat.len());
        assert!(t.iter_ops().zip(&flat).all(|(a, &b)| a == b));
        let mut weighted = 0u64;
        t.for_each_weighted(&mut |_, w| weighted += w);
        assert_eq!(weighted as usize, flat.len());
    }

    #[test]
    fn nested_flat_len_is_checked_not_wrapped() {
        let op = TraceOp::Compute { class: InstClass::IntAlu, insts: 1 };
        let inner = Segment::Rep { body: vec![op, op], count: u32::MAX, strides: Vec::new() };
        assert_eq!(inner.flat_len(), Some(2 * (u32::MAX as u64)));
        let outer = Segment::Loop { body: vec![inner], count: u32::MAX, strides: Vec::new() };
        // 2 * (2^32-1)^2 > 2^64: the checked math reports the overflow
        // instead of silently wrapping like the old usize multiply.
        assert_eq!(outer.flat_len(), None);
        let t = Trace { segments: vec![outer] };
        assert_eq!(t.flat_len(), None);
        assert!(!t.is_empty());
    }
}
