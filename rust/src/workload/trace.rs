//! The trace IR: the interface between workload generators and the
//! trace machine.
//!
//! A workload is one `Vec<TraceOp>` per core. Ops are either *local*
//! (compute bursts, memory streams) or *interacting* (AIMC tile ops,
//! mutexes, channels). Memory is line-granular: `MemStream` walks cache
//! lines through the full hierarchy, so cache behaviour (and therefore
//! LLCMPI and DRAM energy) emerges from the actual access pattern rather
//! than analytic formulas.

use crate::isa::InstClass;
use crate::sim::aimc::Placement;
use crate::stats::RoiKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// Execute `insts` instructions of `class` back to back.
    Compute { class: InstClass, insts: u64 },

    /// Stream `bytes` from `base`, touching every cache line once.
    /// `insts_per_line` models the loads/stores issued per line (e.g. 4
    /// NEON 16-byte loads). `prefetchable` streams hide miss latency up
    /// to the stride prefetcher's depth; random/pointer-chasing accesses
    /// do not.
    MemStream {
        base: u64,
        bytes: u64,
        write: bool,
        insts_per_line: u64,
        prefetchable: bool,
    },

    /// CM_INITIALIZE: program a matrix region onto a tile (one-time).
    CmInit { tile: usize, placement: Placement },

    /// CM_QUEUE `bytes` into the tile's input memory (4 B / instruction).
    CmQueue { tile: usize, bytes: u64 },

    /// CM_PROCESS: fire the MVM; the core blocks until the tile is done.
    CmProcess { tile: usize },

    /// CM_DEQUEUE `bytes` from the tile's output memory.
    CmDequeue { tile: usize, bytes: u64 },

    /// pthread mutex lock/unlock.
    MutexLock { id: usize },
    MutexUnlock { id: usize },

    /// Ping-pong channel send: publish `bytes` at `addr` to the consumer.
    /// Blocks while the bounded buffer is full.
    Send { ch: usize, bytes: u64, addr: u64 },

    /// Ping-pong channel receive: blocks until a message is ready, then
    /// pulls its lines through the coherent-transfer path.
    Recv { ch: usize },

    /// Sub-ROI attribution markers (nestable).
    RoiPush { kind: RoiKind },
    RoiPop,
}

/// Builder helper so generators read naturally.
#[derive(Clone, Debug, Default)]
pub struct TraceBuilder {
    pub ops: Vec<TraceOp>,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// Builder with pre-reserved op capacity (generators that know their
    /// trace size up front avoid the re-allocation churn of multi-megaop
    /// CNN traces).
    pub fn with_capacity(cap: usize) -> TraceBuilder {
        TraceBuilder { ops: Vec::with_capacity(cap) }
    }

    /// Reserve room for at least `additional` more ops.
    pub fn reserve(&mut self, additional: usize) -> &mut Self {
        self.ops.reserve(additional);
        self
    }

    /// Current op count — pair with [`TraceBuilder::reserve_repeats`].
    pub fn mark(&self) -> usize {
        self.ops.len()
    }

    /// After emitting one repeating block (e.g. the first inference)
    /// that started at `mark`, reserve capacity for `remaining` more
    /// blocks of the same size in one shot.
    pub fn reserve_repeats(&mut self, mark: usize, remaining: u32) -> &mut Self {
        let per_block = self.ops.len().saturating_sub(mark);
        self.ops.reserve(per_block.saturating_mul(remaining as usize));
        self
    }

    /// Append a pre-built op block (`TraceOp` is `Copy`, so this is a
    /// flat memcpy — the workload generators reuse per-inference /
    /// per-row blocks instead of re-emitting them op by op).
    pub fn extend_from_slice(&mut self, block: &[TraceOp]) -> &mut Self {
        self.ops.extend_from_slice(block);
        self
    }

    pub fn push(&mut self, op: TraceOp) -> &mut Self {
        self.ops.push(op);
        self
    }

    pub fn compute(&mut self, class: InstClass, insts: u64) -> &mut Self {
        if insts > 0 {
            self.push(TraceOp::Compute { class, insts });
        }
        self
    }

    pub fn stream_read(&mut self, base: u64, bytes: u64, insts_per_line: u64) -> &mut Self {
        self.push(TraceOp::MemStream { base, bytes, write: false, insts_per_line, prefetchable: true })
    }

    pub fn stream_write(&mut self, base: u64, bytes: u64, insts_per_line: u64) -> &mut Self {
        self.push(TraceOp::MemStream { base, bytes, write: true, insts_per_line, prefetchable: true })
    }

    pub fn roi(&mut self, kind: RoiKind, f: impl FnOnce(&mut TraceBuilder)) -> &mut Self {
        self.push(TraceOp::RoiPush { kind });
        f(self);
        self.push(TraceOp::RoiPop);
        self
    }

    pub fn build(self) -> Vec<TraceOp> {
        self.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_skips_zero_compute() {
        let mut b = TraceBuilder::new();
        b.compute(InstClass::IntAlu, 0);
        b.compute(InstClass::IntAlu, 5);
        assert_eq!(b.ops.len(), 1);
    }

    #[test]
    fn reserve_repeats_sizes_capacity() {
        let mut b = TraceBuilder::new();
        let start = b.mark();
        b.compute(InstClass::IntAlu, 5);
        b.stream_read(0, 64, 1);
        b.reserve_repeats(start, 9);
        // 2 ops emitted + room for 9 more blocks of 2.
        assert!(b.ops.capacity() >= 20);
        assert_eq!(b.ops.len(), 2);
    }

    #[test]
    fn extend_from_slice_appends_block() {
        let mut b = TraceBuilder::new();
        let block = vec![
            TraceOp::Compute { class: InstClass::SimdOp, insts: 4 },
            TraceOp::RoiPop,
        ];
        b.extend_from_slice(&block);
        b.extend_from_slice(&block);
        assert_eq!(b.ops.len(), 4);
        assert!(matches!(b.ops[2], TraceOp::Compute { insts: 4, .. }));
    }

    #[test]
    fn roi_brackets() {
        let mut b = TraceBuilder::new();
        b.roi(RoiKind::InputLoad, |b| {
            b.stream_read(0, 64, 4);
        });
        assert!(matches!(b.ops[0], TraceOp::RoiPush { kind: RoiKind::InputLoad }));
        assert!(matches!(b.ops[2], TraceOp::RoiPop));
        assert_eq!(b.ops.len(), 3);
    }
}
