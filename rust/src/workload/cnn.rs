//! CNN workloads — Exploration Three (§IX, Fig. 12) as a case table.
//!
//! 8-core MPSoC pipeline: conv1-5 on cores 0-4 (AIMC-mapped in the
//! analog variant), dense1-3 on cores 5-7 (always CPU-side, §IX.A),
//! expressed as five row-streamed stages + three per-inference stages
//! over the mapping compiler. Fine-grained pipelining is preserved at
//! [`ROW_GROUP`]-output-row granularity, as before.

use crate::config::SystemConfig;
use crate::nn::cnn::{CnnModel, CnnVariant};
use crate::nn::LayerGraph;
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile;
use crate::workload::compile::mapping::{Mapping, Stage, StageInput, StageOutput, Step};
use crate::workload::{Workload, WorkloadError};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CnnCase {
    Digital,
    Analog,
}

impl CnnCase {
    pub fn label(&self) -> &'static str {
        match self {
            CnnCase::Digital => "DIG",
            CnnCase::Analog => "ANA",
        }
    }
}

/// Row-chunk granularity of the inter-stage pipeline: sending every
/// feature-map row individually would explode the trace; the paper's
/// fine-grained pipelining is preserved at the level of `ROW_GROUP`
/// output rows per transfer.
pub const ROW_GROUP: u64 = 4;

/// Node ids of `LayerGraph::cnn`: 0 input, 1..=5 convs, then
/// (dense, activation) pairs, last node output.
const INPUT_NODE: usize = 0;
fn conv_node(k: usize) -> usize {
    1 + k
}
fn dense_node(d: usize) -> usize {
    6 + 2 * d
}
fn act_node(d: usize) -> usize {
    7 + 2 * d
}
const OUTPUT_NODE: usize = 12;

pub fn generate(
    case: CnnCase,
    variant: CnnVariant,
    _cfg: &SystemConfig,
    n_inf: u32,
) -> Result<Workload, WorkloadError> {
    let (graph, mapping) = case_table(case, variant);
    compile::compile(&graph, &mapping, n_inf)
}

/// The paper-case table: `(CnnCase, CnnVariant) -> (LayerGraph, Mapping)`.
pub fn case_table(case: CnnCase, variant: CnnVariant) -> (LayerGraph, Mapping) {
    let model = CnnModel::paper(variant);
    let analog = case == CnnCase::Analog;
    let graph = LayerGraph::cnn(&model);

    // Tiles: one per conv layer (analog only), sized for the flattened
    // kernels (§V.B: component dimensions are parameterizable).
    let tiles: Vec<TileSpec> = if analog {
        model
            .convs
            .iter()
            .map(|l| TileSpec {
                rows: l.im2col_rows() as u32,
                cols: l.out_ch as u32,
                coupling: Coupling::Tight,
            })
            .collect()
    } else {
        Vec::new()
    };

    let mut stages = Vec::new();
    for (k, l) in model.convs.iter().enumerate() {
        let mut s = Stage::on_core(k);
        s.row_group = Some(ROW_GROUP);
        s.input = if k == 0 { StageInput::Memory { node: INPUT_NODE } } else { StageInput::Channel };
        // Conv forward payloads are derived from the layer geometry.
        s.output = StageOutput::Channel { bytes: 0 };
        s.steps = vec![if analog {
            Step::tile(
                conv_node(k),
                k,
                Placement { row0: 0, col0: 0, rows: l.im2col_rows() as u32, cols: l.out_ch as u32 },
            )
        } else {
            Step::cpu(conv_node(k))
        }];
        stages.push(s);
    }
    for d in 0..3 {
        let mut s = Stage::on_core(5 + d);
        s.input = StageInput::Channel;
        s.output = if d < 2 {
            StageOutput::Channel { bytes: model.dense[d] }
        } else {
            StageOutput::Memory { node: OUTPUT_NODE }
        };
        s.steps = vec![Step::cpu(dense_node(d)), Step::cpu(act_node(d))];
        stages.push(s);
    }

    let mapping = Mapping {
        label: format!("cnn-{}/{}", variant.name(), case.label()),
        tiles,
        min_mutexes: 0,
        stages,
    };
    (graph, mapping)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceOp;

    fn cfg() -> SystemConfig {
        SystemConfig::high_power()
    }

    #[test]
    fn both_cases_generate_for_all_variants() {
        for v in CnnVariant::ALL {
            for case in [CnnCase::Digital, CnnCase::Analog] {
                let w = generate(case, v, &cfg(), 1).unwrap();
                assert_eq!(w.traces.len(), 8, "{}", w.label);
                assert!(w.total_ops() > 100);
            }
        }
    }

    #[test]
    fn analog_processes_once_per_output_pixel() {
        let w = generate(CnnCase::Analog, CnnVariant::Fast, &cfg(), 1).unwrap();
        let model = CnnModel::paper(CnnVariant::Fast);
        for (k, l) in model.convs.iter().enumerate() {
            let procs = w.traces[k]
                .iter_ops()
                .filter(|op| matches!(op, TraceOp::CmProcess { tile } if *tile == k))
                .count() as u64;
            assert_eq!(procs, l.output_pixels(), "layer {k}");
        }
    }

    #[test]
    fn digital_has_no_tiles() {
        let w = generate(CnnCase::Digital, CnnVariant::Slow, &cfg(), 1).unwrap();
        assert!(w.spec.tiles.is_empty());
    }

    #[test]
    fn analog_tile_dims_match_im2col() {
        let w = generate(CnnCase::Analog, CnnVariant::Medium, &cfg(), 1).unwrap();
        let model = CnnModel::paper(CnnVariant::Medium);
        assert_eq!(w.spec.tiles.len(), 5);
        assert_eq!(w.spec.tiles[1].rows as u64, model.convs[1].im2col_rows());
        assert_eq!(w.spec.tiles[1].cols as u64, model.convs[1].out_ch);
    }

    #[test]
    fn pipeline_channel_topology() {
        let w = generate(CnnCase::Analog, CnnVariant::Fast, &cfg(), 1).unwrap();
        assert_eq!(w.spec.channels.len(), 7);
        for (k, ch) in w.spec.channels.iter().enumerate() {
            assert_eq!(ch.producer, k);
            assert_eq!(ch.consumer, k + 1);
        }
    }
}
