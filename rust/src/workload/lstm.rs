//! LSTM workloads — Exploration Two (§VIII, Fig. 9, Table II) as a case
//! table over the mapping compiler.
//!
//! One inference step = one character: cell-layer MVM (all four gates in
//! a single CM_PROCESS, §VIII.D) + digital gate math, dense layer,
//! softmax. Cases: single-core with one large tile (1) or per-layer
//! tiles (2), dual-core pipelined (3), quin-core with the cell
//! column-sliced across four cores via a leader-gather split (4), and
//! the digital references on 1/2/5 cores.

use crate::config::SystemConfig;
use crate::nn::{LayerGraph, LstmModel};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile;
use crate::workload::compile::mapping::{
    Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::{Workload, WorkloadError};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LstmCase {
    /// SIMD CPU reference on 1/2/5 cores.
    Digital { cores: usize },
    /// Fig. 9(b) analog cases 1-4.
    Analog { case: u8 },
}

impl LstmCase {
    pub fn label(&self) -> String {
        match self {
            LstmCase::Digital { cores } => format!("DIG-{cores}core"),
            LstmCase::Analog { case } => format!("ANA-case{case}"),
        }
    }
}

/// Node ids of `LayerGraph::lstm`.
const INPUT_NODE: usize = 0;
const CELL_NODE: usize = 1;
const DENSE_NODE: usize = 2;
const SOFTMAX_NODE: usize = 3;
const OUTPUT_NODE: usize = 4;

pub fn generate(
    case: LstmCase,
    n_h: u64,
    _cfg: &SystemConfig,
    n_inf: u32,
) -> Result<Workload, WorkloadError> {
    let (graph, mapping) = case_table(case, n_h)?;
    compile::compile(&graph, &mapping, n_inf)
}

/// The paper-case table: `LstmCase -> (LayerGraph, Mapping)`.
pub fn case_table(case: LstmCase, n_h: u64) -> Result<(LayerGraph, Mapping), WorkloadError> {
    let m = LstmModel::paper(n_h);
    let graph = LayerGraph::lstm(&m);
    let tight = |rows: u64, cols: u64| TileSpec {
        rows: rows as u32,
        cols: cols as u32,
        coupling: Coupling::Tight,
    };
    let cell_pl = Placement {
        row0: 0,
        col0: 0,
        rows: m.cell_rows() as u32,
        cols: m.cell_cols() as u32,
    };
    let dense_pl = Placement {
        row0: 0,
        col0: 0,
        rows: m.dense_rows() as u32,
        cols: m.dense_cols() as u32,
    };
    let label = |case: &LstmCase| format!("lstm{}/{}", n_h, case.label());

    let mapping = match case {
        LstmCase::Digital { cores: 1 } => {
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: OUTPUT_NODE };
            s.steps = vec![Step::cpu(CELL_NODE), Step::cpu(DENSE_NODE), Step::cpu(SOFTMAX_NODE)];
            Mapping { label: label(&case), tiles: vec![], min_mutexes: 0, stages: vec![s] }
        }
        LstmCase::Digital { cores: 2 } => {
            let mut s0 = Stage::on_core(0);
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * m.n_h };
            s0.steps = vec![Step::cpu(CELL_NODE)];
            let mut s1 = Stage::on_core(1);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: OUTPUT_NODE };
            s1.steps = vec![Step::cpu(DENSE_NODE), Step::cpu(SOFTMAX_NODE)];
            Mapping { label: label(&case), tiles: vec![], min_mutexes: 0, stages: vec![s0, s1] }
        }
        LstmCase::Digital { cores: 5 } => {
            // Cores 0-3: cell column slices, core 0 gathers/broadcasts h;
            // core 4: dense. (The platform declares one unused mutex.)
            let mut s0 = Stage::on_core(0);
            s0.cores = vec![0, 1, 2, 3];
            s0.split = SplitKind::LeaderGather;
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * m.n_h };
            s0.steps = vec![Step::cpu(CELL_NODE)];
            let mut s1 = Stage::on_core(4);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: OUTPUT_NODE };
            s1.steps = vec![Step::cpu(DENSE_NODE), Step::cpu(SOFTMAX_NODE)];
            Mapping { label: label(&case), tiles: vec![], min_mutexes: 1, stages: vec![s0, s1] }
        }
        LstmCase::Analog { case: c @ (1 | 2) } => {
            // Case 1: cell + dense tiled diagonally in one large crossbar
            // (Table II-B dims); case 2: one tile per layer.
            let (tiles, cell_tile, dense_tile, dense_placement) = if c == 1 {
                let (r, cc) = LstmModel::paper_tile_dims(m.n_h, 1)
                    .unwrap_or((m.cell_rows() + m.dense_rows(), m.cell_cols() + m.y));
                let diag = Placement {
                    row0: m.cell_rows() as u32,
                    col0: m.cell_cols() as u32,
                    rows: m.dense_rows() as u32,
                    cols: m.dense_cols() as u32,
                };
                (vec![tight(r, cc)], 0usize, 0usize, diag)
            } else {
                (
                    vec![
                        tight(m.cell_rows(), m.cell_cols()),
                        tight(m.dense_rows(), m.dense_cols()),
                    ],
                    0usize,
                    1usize,
                    dense_pl,
                )
            };
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: OUTPUT_NODE };
            s.steps = vec![
                Step::tile(CELL_NODE, cell_tile, cell_pl),
                Step::tile(DENSE_NODE, dense_tile, dense_placement),
                Step::cpu(SOFTMAX_NODE),
            ];
            Mapping { label: label(&case), tiles, min_mutexes: 0, stages: vec![s] }
        }
        LstmCase::Analog { case: 3 } => {
            // Cell on core 0/tile 0, dense on core 1/tile 1, pipelined.
            let (r3, c3) =
                LstmModel::paper_tile_dims(m.n_h, 3).unwrap_or((m.cell_rows(), m.cell_cols()));
            let mut s0 = Stage::on_core(0);
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * m.n_h };
            s0.steps = vec![Step::tile(CELL_NODE, 0, cell_pl)];
            let mut s1 = Stage::on_core(1);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: OUTPUT_NODE };
            s1.steps = vec![Step::tile(DENSE_NODE, 1, dense_pl), Step::cpu(SOFTMAX_NODE)];
            Mapping {
                label: label(&case),
                tiles: vec![tight(r3, c3), tight(m.dense_rows(), m.dense_cols())],
                min_mutexes: 0,
                stages: vec![s0, s1],
            }
        }
        LstmCase::Analog { case: 4 } => {
            // Quin core: the cell column-sliced over 4 tiles/cores (the
            // four-consecutive-columns gate slicing of [37]), dense on
            // core 4. Leader-gather split; one declared (unused) mutex.
            let quarter_cols = (m.cell_cols() / 4) as u32;
            let (r4, c4) = LstmModel::paper_tile_dims(m.n_h, 4)
                .unwrap_or((m.cell_rows(), m.cell_cols() / 4));
            let slice_pl = Placement {
                row0: 0,
                col0: 0,
                rows: m.cell_rows() as u32,
                cols: quarter_cols.min(c4 as u32),
            };
            let mut tiles: Vec<TileSpec> = (0..4).map(|_| tight(r4, c4)).collect();
            tiles.push(tight(m.dense_rows(), m.dense_cols()));
            let mut s0 = Stage::on_core(0);
            s0.cores = vec![0, 1, 2, 3];
            s0.split = SplitKind::LeaderGather;
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * m.n_h };
            s0.steps = vec![Step {
                node: CELL_NODE,
                place: Place::Tile {
                    per_replica: (0..4)
                        .map(|t| TilePlacement { tile: t, placement: slice_pl })
                        .collect(),
                },
            }];
            let mut s1 = Stage::on_core(4);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: OUTPUT_NODE };
            s1.steps = vec![Step::tile(DENSE_NODE, 4, dense_pl), Step::cpu(SOFTMAX_NODE)];
            Mapping { label: label(&case), tiles, min_mutexes: 1, stages: vec![s0, s1] }
        }
        LstmCase::Digital { cores } => {
            return Err(WorkloadError::UnsupportedCase {
                workload: "lstm",
                case: format!("dig{cores}"),
                supported: "dig1 dig2 dig5 ana1 ana2 ana3 ana4",
            });
        }
        LstmCase::Analog { case } => {
            return Err(WorkloadError::UnsupportedCase {
                workload: "lstm",
                case: format!("ana{case}"),
                supported: "dig1 dig2 dig5 ana1 ana2 ana3 ana4",
            });
        }
    };
    Ok((graph, mapping))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceOp;
    use crate::workload::addr;

    fn cfg() -> SystemConfig {
        SystemConfig::high_power()
    }

    #[test]
    fn all_cases_generate_for_all_sizes() {
        for n_h in [256u64, 512, 750] {
            for case in [
                LstmCase::Digital { cores: 1 },
                LstmCase::Digital { cores: 2 },
                LstmCase::Digital { cores: 5 },
                LstmCase::Analog { case: 1 },
                LstmCase::Analog { case: 2 },
                LstmCase::Analog { case: 3 },
                LstmCase::Analog { case: 4 },
            ] {
                let w = generate(case, n_h, &cfg(), 2).unwrap();
                assert!(w.total_ops() > 0, "{}", w.label);
            }
        }
    }

    #[test]
    fn unsupported_cases_error_cleanly() {
        assert!(generate(LstmCase::Digital { cores: 3 }, 256, &cfg(), 1).is_err());
        assert!(generate(LstmCase::Analog { case: 7 }, 256, &cfg(), 1).is_err());
    }

    #[test]
    fn analog_case1_two_processes_per_step() {
        // One for the cell (all four gates at once, §VIII.D), one dense.
        let w = generate(LstmCase::Analog { case: 1 }, 256, &cfg(), 4).unwrap();
        let procs = w.traces[0]
            .iter_ops()
            .filter(|op| matches!(op, TraceOp::CmProcess { .. }))
            .count();
        assert_eq!(procs, 2 * 4);
    }

    #[test]
    fn case4_uses_five_cores_and_tiles() {
        let w = generate(LstmCase::Analog { case: 4 }, 512, &cfg(), 1).unwrap();
        assert_eq!(w.cores_used(), 5);
        assert_eq!(w.spec.tiles.len(), 5);
    }

    #[test]
    fn digital_cell_streams_gate_matrix() {
        let w = generate(LstmCase::Digital { cores: 1 }, 256, &cfg(), 1).unwrap();
        let m = LstmModel::paper(256);
        let bytes: u64 = w.traces[0]
            .iter_ops()
            .filter_map(|op| match op {
                TraceOp::MemStream { base, bytes, .. }
                    if base >= addr::WEIGHTS && base < addr::INPUTS =>
                {
                    Some(bytes)
                }
                _ => None,
            })
            .sum();
        assert_eq!(bytes, m.cell_rows() * m.cell_cols() + m.dense_rows() * m.dense_cols());
    }

    #[test]
    fn case1_tile_uses_paper_dims() {
        let w = generate(LstmCase::Analog { case: 1 }, 750, &cfg(), 1).unwrap();
        assert_eq!(w.spec.tiles[0].rows, 1600);
        assert_eq!(w.spec.tiles[0].cols, 3050);
    }
}
