//! MLP workloads — Exploration One (§VII, Fig. 6) as a case table.
//!
//! Every case is a `(LayerGraph, Mapping)` pair lowered by the mapping
//! compiler: digital references on 1/2/4 cores, the four analog tile
//! configurations of Fig. 6(b), the loosely-coupled accelerator of
//! §VII.B — plus *custom* MLPs of arbitrary shape ([`MlpShape`]) under
//! digital or analog pipelined mappings not expressible before
//! ([`CustomMlpMapping`]).

use crate::config::SystemConfig;
use crate::nn::{LayerGraph, MlpModel};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile;
use crate::workload::compile::mapping::{
    Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::{addr, Workload, WorkloadError};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpCase {
    /// SIMD CPU reference on 1/2/4 cores.
    Digital { cores: usize },
    /// Fig. 6(b) analog cases 1-4.
    Analog { case: u8 },
    /// §VII.B loosely-coupled two-tile accelerator.
    AnalogLoose,
}

impl MlpCase {
    pub fn label(&self) -> String {
        match self {
            MlpCase::Digital { cores } => format!("DIG-{cores}core"),
            MlpCase::Analog { case } => format!("ANA-case{case}"),
            MlpCase::AnalogLoose => "ANA-loose".to_string(),
        }
    }
}

/// Node ids of `LayerGraph::mlp` chains (input, L x (dense, relu), output).
fn dense_node(l: usize) -> usize {
    1 + 2 * l
}
fn relu_node(l: usize) -> usize {
    2 + 2 * l
}
fn output_node(layers: usize) -> usize {
    1 + 2 * layers
}
const INPUT_NODE: usize = 0;

pub fn generate(case: MlpCase, _cfg: &SystemConfig, n_inf: u32) -> Result<Workload, WorkloadError> {
    let (graph, mapping) = case_table(case)?;
    compile::compile(&graph, &mapping, n_inf)
}

/// The paper-case table: `MlpCase -> (LayerGraph, Mapping)`.
pub fn case_table(case: MlpCase) -> Result<(LayerGraph, Mapping), WorkloadError> {
    let m = MlpModel::paper();
    let n = m.dim;
    let half = n / 2;
    let graph = LayerGraph::mlp_paper(&m);
    let tight = |rows: u64, cols: u64| TileSpec {
        rows: rows as u32,
        cols: cols as u32,
        coupling: Coupling::Tight,
    };
    let square = Placement { row0: 0, col0: 0, rows: n as u32, cols: n as u32 };

    let mapping = match case {
        MlpCase::Digital { cores: 1 } => {
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: output_node(2) };
            s.steps = vec![
                Step::cpu(dense_node(0)),
                Step::cpu(relu_node(0)),
                Step::cpu(dense_node(1)),
                Step::cpu(relu_node(1)),
            ];
            Mapping { label: "mlp/DIG-1core".into(), tiles: vec![], min_mutexes: 0, stages: vec![s] }
        }
        MlpCase::Digital { cores: 2 } => {
            // Core 0: input + layer 1; core 1: layer 2 + writeback.
            let mut s0 = Stage::on_core(0);
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * n };
            s0.steps = vec![Step::cpu(dense_node(0)), Step::cpu(relu_node(0))];
            let mut s1 = Stage::on_core(1);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: output_node(2) };
            s1.steps = vec![Step::cpu(dense_node(1)), Step::cpu(relu_node(1))];
            Mapping { label: "mlp/DIG-2core".into(), tiles: vec![], min_mutexes: 0, stages: vec![s0, s1] }
        }
        MlpCase::Digital { cores: 4 } => {
            // Column halves of each layer on a core pair, mutex-synced.
            let mut s0 = Stage::on_core(0);
            s0.cores = vec![0, 1];
            s0.split = SplitKind::Columns;
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * half };
            s0.barrier = true;
            s0.steps = vec![Step::cpu(dense_node(0)), Step::cpu(relu_node(0))];
            let mut s1 = Stage::on_core(2);
            s1.cores = vec![2, 3];
            s1.split = SplitKind::Columns;
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: output_node(2) };
            s1.barrier = true;
            s1.steps = vec![Step::cpu(dense_node(1)), Step::cpu(relu_node(1))];
            Mapping { label: "mlp/DIG-4core".into(), tiles: vec![], min_mutexes: 0, stages: vec![s0, s1] }
        }
        MlpCase::Analog { case: 1 } => {
            // One large tile holding both layers side by side.
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: output_node(2) };
            s.steps = vec![
                Step::tile(dense_node(0), 0, square),
                Step::cpu(relu_node(0)),
                Step::tile(dense_node(1), 0, Placement { row0: 0, col0: n as u32, rows: n as u32, cols: n as u32 }),
                Step::cpu(relu_node(1)),
            ];
            Mapping {
                label: "mlp/ANA-case1".into(),
                tiles: vec![tight(n, 2 * n)],
                min_mutexes: 0,
                stages: vec![s],
            }
        }
        MlpCase::Analog { case: 2 } => {
            // Half-height tiles: each layer row-split over two tiles with
            // digital partial accumulation (2x CM_PROCESS rate, §VII.B).
            let half_pl = Placement { row0: 0, col0: 0, rows: half as u32, cols: n as u32 };
            let row_split = |ta: usize, tb: usize| Place::TileRowSplit {
                tiles: vec![
                    TilePlacement { tile: ta, placement: half_pl },
                    TilePlacement { tile: tb, placement: half_pl },
                ],
            };
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: output_node(2) };
            s.steps = vec![
                Step { node: dense_node(0), place: row_split(0, 1) },
                Step::cpu(relu_node(0)),
                Step { node: dense_node(1), place: row_split(2, 3) },
                Step::cpu(relu_node(1)),
            ];
            Mapping {
                label: "mlp/ANA-case2".into(),
                tiles: (0..4).map(|_| tight(half, n)).collect(),
                min_mutexes: 0,
                stages: vec![s],
            }
        }
        MlpCase::Analog { case: 3 } => {
            // One layer per core; the hand-off is the paper's mutex-style
            // shared activation buffer (§VII.C) -> SharedBuffer hand-off.
            let mut s0 = Stage::on_core(0);
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * n };
            s0.handoff = Handoff::SharedBuffer;
            s0.steps = vec![Step::tile(dense_node(0), 0, square), Step::cpu(relu_node(0))];
            let mut s1 = Stage::on_core(1);
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: output_node(2) };
            s1.steps = vec![Step::tile(dense_node(1), 1, square), Step::cpu(relu_node(1))];
            Mapping {
                label: "mlp/ANA-case3".into(),
                tiles: vec![tight(n, n), tight(n, n)],
                min_mutexes: 0,
                stages: vec![s0, s1],
            }
        }
        MlpCase::Analog { case: 4 } => {
            // Each layer's columns split across two cores/tiles; pairs
            // sync via mutexes, hand-offs are shared buffers (Fig. 6b).
            let col_pl = Placement { row0: 0, col0: 0, rows: n as u32, cols: half as u32 };
            let pair = |ta: usize, tb: usize| Place::Tile {
                per_replica: vec![
                    TilePlacement { tile: ta, placement: col_pl },
                    TilePlacement { tile: tb, placement: col_pl },
                ],
            };
            let mut s0 = Stage::on_core(0);
            s0.cores = vec![0, 1];
            s0.split = SplitKind::Columns;
            s0.input = StageInput::Memory { node: INPUT_NODE };
            s0.output = StageOutput::Channel { bytes: 4 * half };
            s0.handoff = Handoff::SharedBuffer;
            s0.barrier = true;
            s0.steps = vec![Step { node: dense_node(0), place: pair(0, 1) }, Step::cpu(relu_node(0))];
            let mut s1 = Stage::on_core(2);
            s1.cores = vec![2, 3];
            s1.split = SplitKind::Columns;
            s1.input = StageInput::Channel;
            s1.output = StageOutput::Memory { node: output_node(2) };
            s1.barrier = true;
            s1.steps = vec![Step { node: dense_node(1), place: pair(2, 3) }, Step::cpu(relu_node(1))];
            Mapping {
                label: "mlp/ANA-case4".into(),
                tiles: (0..4).map(|_| tight(n, half)).collect(),
                min_mutexes: 0,
                stages: vec![s0, s1],
            }
        }
        MlpCase::AnalogLoose => {
            // Two pipelined tiles behind the peripheral I/O bus; layer-1
            // ReLU and the tile-to-tile forward happen in-accelerator.
            let loose = TileSpec { rows: n as u32, cols: n as u32, coupling: Coupling::Loose };
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: output_node(2) };
            s.steps = vec![
                Step {
                    node: dense_node(0),
                    place: Place::TileChain {
                        tiles: vec![
                            TilePlacement { tile: 0, placement: square },
                            TilePlacement { tile: 1, placement: square },
                        ],
                    },
                },
                Step { node: relu_node(0), place: Place::Fused },
                Step { node: dense_node(1), place: Place::Fused },
                Step::cpu(relu_node(1)),
            ];
            Mapping {
                label: "mlp/ANA-loose".into(),
                tiles: vec![loose, loose],
                min_mutexes: 0,
                stages: vec![s],
            }
        }
        MlpCase::Digital { cores } => {
            return Err(WorkloadError::UnsupportedCase {
                workload: "mlp",
                case: format!("dig{cores}"),
                supported: "dig1 dig2 dig4 ana1 ana2 ana3 ana4 loose",
            });
        }
        MlpCase::Analog { case } => {
            return Err(WorkloadError::UnsupportedCase {
                workload: "mlp",
                case: format!("ana{case}"),
                supported: "dig1 dig2 dig4 ana1 ana2 ana3 ana4 loose",
            });
        }
    };
    Ok((graph, mapping))
}

// ---------------------------------------------------------------------------
// Custom-shape MLPs (not expressible before the mapping compiler)
// ---------------------------------------------------------------------------

/// Maximum `in x h1 x .. x out` dims of a custom shape (8 layers).
pub const MAX_SHAPE_DIMS: usize = 9;

/// A fixed-capacity MLP shape, `Copy` so sweep cases stay plain data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlpShape {
    dims: [u64; MAX_SHAPE_DIMS],
    len: usize,
}

impl MlpShape {
    pub fn new(dims: &[u64]) -> Result<MlpShape, WorkloadError> {
        if dims.len() < 2 || dims.len() > MAX_SHAPE_DIMS {
            return Err(WorkloadError::InvalidGraph(format!(
                "shape needs 2..={MAX_SHAPE_DIMS} dims, got {}",
                dims.len()
            )));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(WorkloadError::InvalidGraph("shape dims must be > 0".into()));
        }
        // Tile/placement geometry is u32; reject dims that would wrap.
        if dims.iter().any(|&d| d > u32::MAX as u64) {
            return Err(WorkloadError::InvalidGraph(format!(
                "shape dims must fit a {}-column crossbar axis (u32)",
                u32::MAX
            )));
        }
        // The synthetic address map spaces weight slots WEIGHTS_STRIDE
        // apart and gives each I/O vector a bounded slice of its region;
        // larger shapes would alias regions and corrupt cache statistics.
        if dims.windows(2).any(|w| w[0].saturating_mul(w[1]) > addr::WEIGHTS_STRIDE) {
            return Err(WorkloadError::InvalidGraph(format!(
                "a layer's weight matrix exceeds the {} B weight-slot stride of the synthetic address map",
                addr::WEIGHTS_STRIDE
            )));
        }
        const MAX_VECTOR_BYTES: u64 = 0x0100_0000; // 16 MiB per fp32 vector
        if dims.iter().any(|&d| 4 * d > MAX_VECTOR_BYTES) {
            return Err(WorkloadError::InvalidGraph(format!(
                "a {MAX_VECTOR_BYTES} B cap per fp32 activation vector keeps the input/output regions alias-free"
            )));
        }
        let mut buf = [0u64; MAX_SHAPE_DIMS];
        buf[..dims.len()].copy_from_slice(dims);
        Ok(MlpShape { dims: buf, len: dims.len() })
    }

    /// Parse `"784x512x512x10"`.
    pub fn parse(s: &str) -> Result<MlpShape, WorkloadError> {
        let dims: Result<Vec<u64>, _> = s.split('x').map(|p| p.trim().parse::<u64>()).collect();
        match dims {
            Ok(d) => MlpShape::new(&d),
            Err(_) => Err(WorkloadError::InvalidGraph(format!(
                "bad shape {s:?} (expected e.g. 784x512x512x10)"
            ))),
        }
    }

    pub fn dims(&self) -> &[u64] {
        &self.dims[..self.len]
    }

    pub fn layers(&self) -> usize {
        self.len - 1
    }
}

impl std::fmt::Display for MlpShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self.dims().iter().map(|d| d.to_string()).collect();
        write!(f, "{}", parts.join("x"))
    }
}

/// Mappings for custom-shape MLPs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CustomMlpMapping {
    /// SIMD reference: 1 core, or one pipeline stage per layer
    /// (`cores == layers`).
    Digital { cores: usize },
    /// AIMC: `pipeline == false` packs all layers onto one core
    /// (`tiles` = 1 shared crossbar, or one tile per layer);
    /// `pipeline == true` splits the layers into `tiles` channel-
    /// connected stages, one core + one tile each.
    Analog { tiles: usize, pipeline: bool },
}

impl CustomMlpMapping {
    pub fn label(&self) -> String {
        match self {
            CustomMlpMapping::Digital { cores: 1 } => "DIG-1core".into(),
            CustomMlpMapping::Digital { cores } => format!("DIG-pipe{cores}"),
            CustomMlpMapping::Analog { tiles, pipeline: false } => format!("ANA-{tiles}tile"),
            CustomMlpMapping::Analog { tiles, pipeline: true } => format!("ANA-pipe{tiles}"),
        }
    }
}

/// Generate a custom-shape MLP workload under the given mapping.
pub fn generate_custom(
    shape: MlpShape,
    mapping: CustomMlpMapping,
    n_inf: u32,
) -> Result<Workload, WorkloadError> {
    let (graph, m) = custom_table(shape, mapping)?;
    compile::compile(&graph, &m, n_inf)
}

/// Build the `(LayerGraph, Mapping)` of a custom case.
pub fn custom_table(
    shape: MlpShape,
    mapping: CustomMlpMapping,
) -> Result<(LayerGraph, Mapping), WorkloadError> {
    let dims = shape.dims();
    let layers = shape.layers();
    let graph = LayerGraph::mlp(dims);
    let label = format!("mlp-custom[{shape}]/{}", mapping.label());
    let out_node = output_node(layers);
    let unsupported = |case: String| WorkloadError::UnsupportedCase {
        workload: "mlp-custom",
        case,
        supported: "dig1, dig-pipe (cores == layers), ana packed (tiles = 1 or layers), ana-pipe (1..=layers stages)",
    };

    let m = match mapping {
        CustomMlpMapping::Digital { cores: 1 } => {
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: out_node };
            for l in 0..layers {
                s.steps.push(Step::cpu(dense_node(l)));
                s.steps.push(Step::cpu(relu_node(l)));
            }
            Mapping { label, tiles: vec![], min_mutexes: 0, stages: vec![s] }
        }
        CustomMlpMapping::Digital { cores } if cores == layers => {
            let mut stages = Vec::new();
            for l in 0..layers {
                let mut s = Stage::on_core(l);
                s.input = if l == 0 { StageInput::Memory { node: INPUT_NODE } } else { StageInput::Channel };
                s.output = if l == layers - 1 {
                    StageOutput::Memory { node: out_node }
                } else {
                    StageOutput::Channel { bytes: 4 * dims[l + 1] }
                };
                s.steps = vec![Step::cpu(dense_node(l)), Step::cpu(relu_node(l))];
                stages.push(s);
            }
            Mapping { label, tiles: vec![], min_mutexes: 0, stages }
        }
        CustomMlpMapping::Digital { cores } => {
            return Err(unsupported(format!("dig{cores} for {layers} layers")));
        }
        CustomMlpMapping::Analog { tiles: 1, pipeline: false } => {
            // All layers side by side on one shared crossbar.
            let rows = *dims[..layers].iter().max().expect("layers >= 1");
            let cols: u64 = dims[1..].iter().sum();
            if cols > u32::MAX as u64 {
                return Err(WorkloadError::InvalidMapping(format!(
                    "packed crossbar needs {cols} columns, exceeding the u32 tile axis"
                )));
            }
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: out_node };
            let mut col0 = 0u64;
            for l in 0..layers {
                let pl = Placement {
                    row0: 0,
                    col0: col0 as u32,
                    rows: dims[l] as u32,
                    cols: dims[l + 1] as u32,
                };
                col0 += dims[l + 1];
                s.steps.push(Step::tile(dense_node(l), 0, pl));
                s.steps.push(Step::cpu(relu_node(l)));
            }
            Mapping {
                label,
                tiles: vec![TileSpec { rows: rows as u32, cols: cols as u32, coupling: Coupling::Tight }],
                min_mutexes: 0,
                stages: vec![s],
            }
        }
        CustomMlpMapping::Analog { tiles, pipeline: false } if tiles == layers => {
            // One tile per layer, all driven by a single core.
            let mut s = Stage::on_core(0);
            s.input = StageInput::Memory { node: INPUT_NODE };
            s.output = StageOutput::Memory { node: out_node };
            let mut tile_specs = Vec::new();
            for l in 0..layers {
                tile_specs.push(TileSpec {
                    rows: dims[l] as u32,
                    cols: dims[l + 1] as u32,
                    coupling: Coupling::Tight,
                });
                let pl = Placement { row0: 0, col0: 0, rows: dims[l] as u32, cols: dims[l + 1] as u32 };
                s.steps.push(Step::tile(dense_node(l), l, pl));
                s.steps.push(Step::cpu(relu_node(l)));
            }
            Mapping { label, tiles: tile_specs, min_mutexes: 0, stages: vec![s] }
        }
        CustomMlpMapping::Analog { tiles, pipeline: true } if tiles >= 1 && tiles <= layers => {
            // `tiles` channel-connected stages, each owning one core and
            // one crossbar holding its contiguous block of layers.
            let mut stages = Vec::new();
            let mut tile_specs = Vec::new();
            for t in 0..tiles {
                let lo = t * layers / tiles;
                let hi = (t + 1) * layers / tiles;
                let rows = *dims[lo..hi].iter().max().expect("non-empty block");
                let cols: u64 = dims[lo + 1..=hi].iter().sum();
                if cols > u32::MAX as u64 {
                    return Err(WorkloadError::InvalidMapping(format!(
                        "pipeline stage {t} packs {cols} columns, exceeding the u32 tile axis"
                    )));
                }
                tile_specs.push(TileSpec { rows: rows as u32, cols: cols as u32, coupling: Coupling::Tight });
                let mut s = Stage::on_core(t);
                s.input = if t == 0 { StageInput::Memory { node: INPUT_NODE } } else { StageInput::Channel };
                s.output = if t == tiles - 1 {
                    StageOutput::Memory { node: out_node }
                } else {
                    StageOutput::Channel { bytes: 4 * dims[hi] }
                };
                let mut col0 = 0u64;
                for l in lo..hi {
                    let pl = Placement { row0: 0, col0: col0 as u32, rows: dims[l] as u32, cols: dims[l + 1] as u32 };
                    col0 += dims[l + 1];
                    s.steps.push(Step::tile(dense_node(l), t, pl));
                    s.steps.push(Step::cpu(relu_node(l)));
                }
                stages.push(s);
            }
            Mapping { label, tiles: tile_specs, min_mutexes: 0, stages }
        }
        CustomMlpMapping::Analog { tiles, pipeline } => {
            return Err(unsupported(format!(
                "ana tiles={tiles} pipeline={pipeline} for {layers} layers"
            )));
        }
    };
    Ok((graph, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceOp;
    use crate::workload::{addr, Workload};

    fn cfg() -> SystemConfig {
        SystemConfig::high_power()
    }

    #[test]
    fn all_cases_generate() {
        for case in [
            MlpCase::Digital { cores: 1 },
            MlpCase::Digital { cores: 2 },
            MlpCase::Digital { cores: 4 },
            MlpCase::Analog { case: 1 },
            MlpCase::Analog { case: 2 },
            MlpCase::Analog { case: 3 },
            MlpCase::Analog { case: 4 },
            MlpCase::AnalogLoose,
        ] {
            let w = generate(case, &cfg(), 2).unwrap();
            assert!(w.total_ops() > 0, "{}", w.label);
            assert!(w.cores_used() >= 1);
        }
    }

    #[test]
    fn unsupported_cases_error_cleanly() {
        let e = generate(MlpCase::Digital { cores: 3 }, &cfg(), 1).unwrap_err();
        assert!(matches!(e, WorkloadError::UnsupportedCase { workload: "mlp", .. }), "{e}");
        assert!(generate(MlpCase::Analog { case: 9 }, &cfg(), 1).is_err());
    }

    #[test]
    fn analog_case1_has_two_processes_per_inference() {
        let w = generate(MlpCase::Analog { case: 1 }, &cfg(), 3).unwrap();
        let procs = w.traces[0]
            .iter_ops()
            .filter(|op| matches!(op, TraceOp::CmProcess { .. }))
            .count();
        assert_eq!(procs, 2 * 3);
    }

    #[test]
    fn analog_case2_has_double_the_processes() {
        // §VII.B: "the CM_PROCESS instruction needs to be called twice as
        // much ... in Case 2".
        let c1 = generate(MlpCase::Analog { case: 1 }, &cfg(), 5).unwrap();
        let c2 = generate(MlpCase::Analog { case: 2 }, &cfg(), 5).unwrap();
        let count = |w: &Workload| {
            w.traces
                .iter()
                .flat_map(crate::workload::trace::Trace::iter_ops)
                .filter(|op| matches!(op, TraceOp::CmProcess { .. }))
                .count()
        };
        assert_eq!(count(&c2), 2 * count(&c1));
    }

    #[test]
    fn case_core_counts_match_fig6() {
        assert_eq!(generate(MlpCase::Analog { case: 1 }, &cfg(), 1).unwrap().cores_used(), 1);
        assert_eq!(generate(MlpCase::Analog { case: 3 }, &cfg(), 1).unwrap().cores_used(), 2);
        assert_eq!(generate(MlpCase::Analog { case: 4 }, &cfg(), 1).unwrap().cores_used(), 4);
    }

    #[test]
    fn digital_streams_full_weight_matrix() {
        let w = generate(MlpCase::Digital { cores: 1 }, &cfg(), 1).unwrap();
        let weight_bytes: u64 = w.traces[0]
            .iter_ops()
            .filter_map(|op| match op {
                TraceOp::MemStream { base, bytes, .. } if base >= addr::WEIGHTS && base < addr::INPUTS => Some(bytes),
                _ => None,
            })
            .sum();
        assert_eq!(weight_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn loose_case_uses_loose_tiles() {
        let w = generate(MlpCase::AnalogLoose, &cfg(), 1).unwrap();
        assert!(w.spec.tiles.iter().all(|t| t.coupling == Coupling::Loose));
    }

    #[test]
    fn shape_parsing() {
        let s = MlpShape::parse("784x512x512x10").unwrap();
        assert_eq!(s.dims(), &[784, 512, 512, 10]);
        assert_eq!(s.layers(), 3);
        assert_eq!(s.to_string(), "784x512x512x10");
        assert!(MlpShape::parse("784").is_err());
        assert!(MlpShape::parse("784x0x10").is_err());
        assert!(MlpShape::parse("12ax3").is_err());
    }

    #[test]
    fn custom_shape_digital_compiles() {
        let shape = MlpShape::parse("784x512x512x10").unwrap();
        let w = generate_custom(shape, CustomMlpMapping::Digital { cores: 1 }, 2).unwrap();
        assert_eq!(w.traces.len(), 1);
        assert!(w.label.contains("784x512x512x10"));
        // Layer weight streams: 784*512 + 512*512 + 512*10 per inference.
        let per_inf: u64 = 784 * 512 + 512 * 512 + 512 * 10;
        let weight_bytes: u64 = w.traces[0]
            .iter_ops()
            .filter_map(|op| match op {
                TraceOp::MemStream { base, bytes, .. } if base >= addr::WEIGHTS && base < addr::INPUTS => Some(bytes),
                _ => None,
            })
            .sum();
        assert_eq!(weight_bytes, 2 * per_inf);
    }

    #[test]
    fn custom_three_stage_analog_pipeline() {
        let shape = MlpShape::parse("784x512x512x10").unwrap();
        let w = generate_custom(shape, CustomMlpMapping::Analog { tiles: 3, pipeline: true }, 2).unwrap();
        assert_eq!(w.cores_used(), 3, "one core per pipeline stage");
        assert_eq!(w.spec.tiles.len(), 3);
        assert_eq!(w.spec.channels.len(), 2, "3-stage pipeline has 2 boundaries");
        assert!(w.label.contains("ANA-pipe3"));
        // One CM_PROCESS per layer per inference.
        let procs: usize = w
            .traces
            .iter()
            .flat_map(crate::workload::trace::Trace::iter_ops)
            .filter(|op| matches!(op, TraceOp::CmProcess { .. }))
            .count();
        assert_eq!(procs, 3 * 2);
    }

    #[test]
    fn custom_packed_single_tile() {
        let shape = MlpShape::parse("256x128x64").unwrap();
        let w = generate_custom(shape, CustomMlpMapping::Analog { tiles: 1, pipeline: false }, 1).unwrap();
        assert_eq!(w.spec.tiles.len(), 1);
        assert_eq!(w.spec.tiles[0].rows, 256);
        assert_eq!(w.spec.tiles[0].cols, 128 + 64);
    }

    #[test]
    fn custom_invalid_mappings_error() {
        let shape = MlpShape::parse("784x512x10").unwrap();
        assert!(generate_custom(shape, CustomMlpMapping::Digital { cores: 5 }, 1).is_err());
        assert!(generate_custom(shape, CustomMlpMapping::Analog { tiles: 7, pipeline: true }, 1).is_err());
    }
}
