//! Workloads: compile neural-network mappings into per-core `TraceOp`
//! streams plus the machine specification (tiles, mutexes, channels)
//! they require.
//!
//! Every workload is described as a [`crate::nn::LayerGraph`] plus a
//! [`compile::mapping::Mapping`] and lowered by [`compile::compile`];
//! the paper's cases (Fig. 6 MLP, Fig. 9 LSTM, Fig. 12 CNN pipeline)
//! are thin case tables in [`mlp`], [`lstm`] and [`cnn`]. The retired
//! hand-written generators live under [`legacy`] as the bit-equivalence
//! oracle.
//!
//! Address-space layout is synthetic but consistent: weights, inputs,
//! activations, outputs and channel buffers live in disjoint regions so
//! cache behaviour (thrashing vs. residency) emerges exactly as the
//! paper's working-set analysis predicts.

pub mod automap;
pub mod cnn;
pub mod compile;
pub(crate) mod costs;
pub mod legacy;
pub mod lstm;
pub mod mlp;
pub mod trace;
pub mod transformer;

use crate::sim::machine::{MachineSpec, RunError};
use std::fmt;
use trace::Trace;

/// Errors from workload construction: an unsupported case selection, or
/// a layer graph / mapping pair the compiler rejects. Surfaced as clean
/// CLI errors by `main.rs` (the legacy generators panicked instead).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkloadError {
    /// A case table was asked for a configuration it does not define.
    UnsupportedCase {
        workload: &'static str,
        case: String,
        supported: &'static str,
    },
    /// The layer graph itself is malformed.
    InvalidGraph(String),
    /// The mapping does not fit the graph/platform (bad core/tile/channel
    /// topology, placement out of bounds, ...).
    InvalidMapping(String),
    /// A machine-level failure while simulating the workload (deadlock,
    /// injected tile fault) — carried so mixed compile/run pipelines such
    /// as the automap validator report one error type.
    Run(RunError),
    /// A core's trace would flatten to more than `u64::MAX` ops (nested
    /// loop counts multiply): it could never be simulated or unrolled,
    /// so the compiler rejects it instead of silently wrapping lengths.
    TraceTooLarge { core: usize },
}

impl fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WorkloadError::UnsupportedCase { workload, case, supported } => {
                write!(f, "unsupported {workload} case {case:?} (supported: {supported})")
            }
            WorkloadError::InvalidGraph(msg) => write!(f, "invalid layer graph: {msg}"),
            WorkloadError::InvalidMapping(msg) => write!(f, "invalid mapping: {msg}"),
            WorkloadError::Run(e) => write!(f, "simulation failed: {e}"),
            WorkloadError::TraceTooLarge { core } => {
                write!(f, "core {core}: flattened trace length overflows u64 (nested loop counts multiply)")
            }
        }
    }
}

impl std::error::Error for WorkloadError {}

impl From<RunError> for WorkloadError {
    fn from(e: RunError) -> WorkloadError {
        WorkloadError::Run(e)
    }
}

/// A fully-generated workload, ready for `sim::Machine::run`. Traces are
/// looped [`Trace`] programs: steady-state workloads hold their
/// per-inference block once inside a `Rep` segment, so workload memory
/// stays O(block) regardless of the inference count.
pub struct Workload {
    pub label: String,
    pub traces: Vec<Trace>,
    pub spec: MachineSpec,
    /// Number of inferences in the region of interest.
    pub inferences: u32,
}

impl Workload {
    pub fn cores_used(&self) -> usize {
        self.traces.iter().filter(|t| !t.is_empty()).count()
    }

    /// Flattened op count (what a fully unrolled trace would execute).
    /// Panics on `usize` overflow; compiled workloads are pre-validated
    /// (`compile` rejects overlong traces with
    /// [`WorkloadError::TraceTooLarge`]), so guard hand-built nested
    /// traces with [`Workload::flat_len`] first.
    pub fn total_ops(&self) -> usize {
        self.traces.iter().map(Trace::op_count).sum()
    }

    /// Checked flattened op count across every core: `None` if nested
    /// loop counts multiply past `u64`.
    pub fn flat_len(&self) -> Option<u64> {
        self.traces.iter().try_fold(0u64, |acc, t| acc.checked_add(t.flat_len()?))
    }

    /// Physically stored op count (`Rep` bodies count once).
    pub fn stored_ops(&self) -> usize {
        self.traces.iter().map(Trace::stored_ops).sum()
    }
}

/// Synthetic address map (bases chosen to never alias within a run).
pub mod addr {
    pub const WEIGHTS: u64 = 0x1000_0000;
    pub const WEIGHTS_STRIDE: u64 = 0x0400_0000; // per layer
    pub const INPUTS: u64 = 0x8000_0000;
    pub const ACTIVATIONS: u64 = 0x9000_0000;
    pub const OUTPUTS: u64 = 0xA000_0000;
    pub const CHANNELS: u64 = 0xB000_0000;
    pub const CHANNEL_STRIDE: u64 = 0x0010_0000;
    /// Per-token K/V caches of attention layers (re-read every token,
    /// so they live in their own region away from the weight streams).
    pub const KV: u64 = 0xD000_0000;
    pub const KV_STRIDE: u64 = 0x0100_0000;

    pub fn weights(layer: usize) -> u64 {
        WEIGHTS + layer as u64 * WEIGHTS_STRIDE
    }

    pub fn kv(slot: usize) -> u64 {
        KV + slot as u64 * KV_STRIDE
    }

    pub fn input(inference: u32, bytes_per: u64) -> u64 {
        INPUTS + inference as u64 * bytes_per.next_multiple_of(64)
    }

    pub fn output(inference: u32, bytes_per: u64) -> u64 {
        OUTPUTS + inference as u64 * bytes_per.next_multiple_of(64)
    }

    pub fn channel(ch: usize, slot: u32) -> u64 {
        CHANNELS + ch as u64 * CHANNEL_STRIDE + (slot % 2) as u64 * 0x8000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_regions_disjoint() {
        assert!(addr::weights(3) < addr::INPUTS);
        assert!(addr::input(1000, 1024) < addr::ACTIVATIONS);
        assert!(addr::output(1000, 1024) < addr::CHANNELS);
        // 64 channels (the automap budget cap) stay clear of the KV region.
        assert!(addr::channel(64, 1) < addr::KV);
        assert!(addr::kv(0) >= addr::KV);
        assert_eq!(addr::kv(2) - addr::kv(1), addr::KV_STRIDE);
    }

    #[test]
    fn channel_slots_pingpong() {
        let a = addr::channel(0, 0);
        let b = addr::channel(0, 1);
        let c = addr::channel(0, 2);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }
}
