//! Software cost models: instruction counts for the primitives the
//! workload generators emit. This is the single calibration point of the
//! digital baseline (Eigen + NEON, §VI.C) and the AIMClib software path.
//!
//! The counts are first-principles estimates of the inner loops Eigen and
//! AIMClib generate on an in-order ARMv8 core, cross-checked against the
//! paper's observed *ratios* (Fig. 7/10/13 speedups, Fig. 8/11 sub-ROI
//! distributions). Anything tuned during calibration is marked CALIBRATED
//! with its rationale. See EXPERIMENTS.md for paper-vs-measured.

/// int8 MACs performed by one NEON SDOT-style instruction.
pub const SIMD_MACS_PER_INST: u64 = 16;

/// Bytes loaded per NEON load instruction.
pub const SIMD_LOAD_BYTES: u64 = 16;

/// Loop overhead (index update + compare + branch) amortized per
/// iteration of a well-unrolled inner loop (Eigen unrolls by 4-8).
pub const LOOP_OVERHEAD_PER_ITER_X1000: u64 = 750; // 0.75 inst/iter

/// Instructions per element for fp32<->int8 convert+pack (AIMClib
/// type-casting templates, §IV.C). On an in-order A53-class core the
/// convert loop is only partially vectorizable (fcvtzs + saturating
/// narrow + byte packing + bounds handling): ~5 insts/element.
/// CALIBRATED against Fig. 8 and the Fig. 7 12.8x headline: keeps analog
/// queue+dequeue at ~40-55% of the analog MLP ROI.
pub const CAST_INSTS_PER_ELEM_X1000: u64 = 5000;

/// Casting cost for `elems` elements.
pub fn cast_insts(elems: u64) -> u64 {
    elems * CAST_INSTS_PER_ELEM_X1000 / 1000 + 16
}

/// Instruction cost of one output element of the NEON int8 GEMV inner
/// loop (dot product over `rows` inputs): per 16 weights one SDOT-class
/// MAC with the paired load dual-issued, plus reduction/loop overhead.
pub fn gemv_row_insts(rows: u64) -> GemvCost {
    GemvCost {
        simd_insts: rows / SIMD_MACS_PER_INST + 2,
        alu_insts: rows / 64 + 2,
    }
}

#[derive(Clone, Copy, Debug)]
pub struct GemvCost {
    pub simd_insts: u64,
    pub alu_insts: u64,
}

/// Per-element instruction counts for the digital activation functions.
/// Eigen vectorizes exp/tanh with NEON polynomial kernels (4-wide fp32:
/// ~20 insts per 4 elements), so the effective per-element cost is a few
/// instructions, not a scalar libm call. CALIBRATED jointly with the
/// Fig. 11 shape (activations ~70% of the analog LSTM's dequeue+
/// activation share).
pub fn activation_insts_per_elem(kind: Activation) -> u64 {
    match kind {
        Activation::Relu => 1, // vectorized max
        Activation::Sigmoid => 5,
        Activation::Tanh => 6,
        Activation::SoftmaxPerElem => 8, // exp + running sum + final div
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Sigmoid,
    Tanh,
    SoftmaxPerElem,
}

/// pthread mutex lock/unlock instruction cost (uncontended fast path:
/// ldaxr/stlxr pair + barriers; glibc ~40-80 insts round trip).
pub const MUTEX_INSTS: u64 = 60;

/// Ping-pong buffer send/recv bookkeeping (pointer swap, condvar
/// signal + glibc bookkeeping — §VI.C). CALIBRATED together with
/// CHANNEL_WAKE_PS: the pair reproduces the paper's multi-core MLP
/// finding that Case 1 beats Cases 3/4 by ~20-30% (core-to-core
/// communication becomes the bottleneck, §VII.C).
pub const CHANNEL_INSTS: u64 = 2000;

/// Consumer-side wake-up latency of a pthread condvar/futex hand-off
/// (signal -> kernel -> scheduler -> resume), in core cycles — the
/// syscall/scheduler path is instruction-bound, so it scales with the
/// core clock (~4 us at 2.3 GHz, ~11 us at 0.8 GHz). CALIBRATED (see
/// CHANNEL_INSTS).
pub const CHANNEL_WAKE_CYCLES: u64 = 9_000;

/// Per-CM_QUEUE/DEQUEUE beat: 4 int8 payload bytes per instruction
/// (§IV.B: "packs 8-bit inputs into a 32-bit argument register").
pub const CM_IO_BYTES_PER_INST: u64 = 4;

/// Extra integer instructions around each CM_QUEUE beat (address/index
/// update inside AIMClib's queueVector loop).
pub const CM_IO_OVERHEAD_PER_INST_X1000: u64 = 500; // 0.5 inst/beat

/// Stride-prefetcher depth: sequential streams overlap up to this many
/// outstanding line fills (L2 prefetcher on gem5-X ARM configs). Misses
/// beyond the first in a stream cost latency/PREFETCH_DEPTH.
/// CALIBRATED: 20 puts the digital MLP's DRAM-bound phase near peak
/// DDR4 bandwidth, matching the memory-bound behaviour gem5 reports for
/// Eigen GEMV weight streams.
pub const PREFETCH_DEPTH: u64 = 20;

/// Number of rows processed per im2col row-block in the blocked GEMM of
/// the digital CNN (Eigen's default mc panel for int8 on these caches).
pub const GEMM_ROW_BLOCK: u64 = 64;

/// Vectorized local-response-normalization cost per element (squares,
/// 5-wide cross-map window running sum, rsqrt-based power approximation;
/// NEON 4-wide fp32).
pub const LRN_SIMD_PER_ELEM: u64 = 2;

/// int8 MACs per instruction achieved by the *blocked im2col GEMM* of
/// the digital convolutions. Lower than the GEMV path: patch rows are
/// unaligned, the panel pack adds instructions, and the int8->int16
/// widening MAC chain (SMLAL) sustains fewer MACs/cycle than a clean
/// SDOT stream (Eigen further lacks a native int8 GEMM: the conv path
/// computes in fp32 after widening, ~4 MACs/inst NEON minus pack
/// overhead). CALIBRATED against the Fig. 13 CNN-S ~20x headline.
pub const CONV_MACS_PER_INST: u64 = 3;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemv_cost_scales_with_rows() {
        let small = gemv_row_insts(256);
        let big = gemv_row_insts(1024);
        assert!(big.simd_insts > 3 * small.simd_insts);
        assert_eq!(small.simd_insts, 256 / 16 + 2);
    }

    #[test]
    fn cast_cost_linear() {
        assert!(cast_insts(1024) > 2 * cast_insts(500));
        assert_eq!(cast_insts(1000), 5000 + 16);
    }

    #[test]
    fn activations_ordered_by_complexity() {
        use Activation::*;
        assert!(activation_insts_per_elem(Relu) < activation_insts_per_elem(Sigmoid));
        assert!(activation_insts_per_elem(Sigmoid) <= activation_insts_per_elem(Tanh));
    }

    #[test]
    fn cm_io_packing_density() {
        // Fig. 3: one 32-bit register carries 4 int8 inputs.
        assert_eq!(CM_IO_BYTES_PER_INST, 4);
    }
}
