//! Transformer-encoder workloads — a network class the paper never ran,
//! expressed as `(LayerGraph, Mapping)` pairs like every other workload.
//!
//! [`TransformerShape`] describes a pre-norm encoder running one token
//! step against a `seq`-deep KV cache (see [`LayerGraph::transformer`]).
//! The case table maps it two hand-written ways — the all-digital
//! single-core reference and an idealized analog packing with one
//! exactly-sized crossbar region per projection/FFN matrix — while
//! `workload::automap` searches the constrained-budget mapping space
//! automatically.

use crate::nn::{LayerGraph, LayerKind};
use crate::sim::aimc::{Coupling, Placement};
use crate::sim::machine::TileSpec;
use crate::workload::compile;
use crate::workload::compile::mapping::{
    Mapping, Place, Stage, StageInput, StageOutput, Step, TilePlacement,
};
use crate::workload::{addr, Workload, WorkloadError};

/// A transformer-encoder shape, `Copy` so sweep cases stay plain data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerShape {
    pub d_model: u64,
    pub heads: u64,
    pub seq: u64,
    pub layers: u64,
    pub d_ff: u64,
}

impl TransformerShape {
    pub fn new(d_model: u64, heads: u64, seq: u64, layers: u64, d_ff: u64) -> Result<TransformerShape, WorkloadError> {
        let bad = |msg: String| Err(WorkloadError::InvalidGraph(msg));
        if d_model == 0 || heads == 0 || seq == 0 || layers == 0 || d_ff == 0 {
            return bad("transformer dims must be > 0".into());
        }
        if d_model % heads != 0 {
            return bad(format!("heads ({heads}) must divide d_model ({d_model})"));
        }
        if d_model > 2048 || d_ff > 8192 || seq > 4096 || layers > 8 || heads > 16 {
            return bad(format!(
                "shape d{d_model}h{heads}s{seq}l{layers}f{d_ff} exceeds the supported caps \
                 (d_model<=2048, d_ff<=8192, seq<=4096, layers<=8, heads<=16)"
            ));
        }
        // Alias guards for the synthetic address map (cf. MlpShape).
        if 4 * d_model * d_model > addr::WEIGHTS_STRIDE || d_model * d_ff > addr::WEIGHTS_STRIDE {
            return bad("a weight block exceeds the weight-slot stride of the address map".into());
        }
        if 2 * seq * d_model > addr::KV_STRIDE {
            return bad("the K/V cache exceeds its per-layer region of the address map".into());
        }
        Ok(TransformerShape { d_model, heads, seq, layers, d_ff })
    }

    pub fn graph(&self) -> LayerGraph {
        LayerGraph::transformer(self.d_model, self.heads, self.seq, self.layers, self.d_ff)
    }
}

impl std::fmt::Display for TransformerShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "d{}h{}s{}l{}f{}",
            self.d_model, self.heads, self.seq, self.layers, self.d_ff
        )
    }
}

/// Hand-written transformer mappings (the automap search goes beyond
/// these; they anchor the sweeps and the acceptance baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformerCase {
    /// All layers digital on one core — the naive reference mapping.
    Digital,
    /// One core driving exactly-sized crossbars: a `d x 4d` tile per
    /// attention block (four projection regions side by side) and one
    /// tile per FFN matrix.
    Analog,
}

impl TransformerCase {
    pub fn label(&self) -> &'static str {
        match self {
            TransformerCase::Digital => "DIG-1core",
            TransformerCase::Analog => "ANA-packed",
        }
    }
}

/// Generate a transformer workload under the given case.
pub fn generate(shape: TransformerShape, case: TransformerCase, n_inf: u32) -> Result<Workload, WorkloadError> {
    let (graph, mapping) = case_table(shape, case)?;
    compile::compile(&graph, &mapping, n_inf)
}

/// Build the `(LayerGraph, Mapping)` of a transformer case.
pub fn case_table(shape: TransformerShape, case: TransformerCase) -> Result<(LayerGraph, Mapping), WorkloadError> {
    let graph = shape.graph();
    let out_node = graph.nodes.len() - 1;
    let label = format!("{}/{}", graph.name, case.label());
    let mut s = Stage::on_core(0);
    s.input = StageInput::Memory { node: 0 };
    s.output = StageOutput::Memory { node: out_node };

    let mut tiles: Vec<TileSpec> = Vec::new();
    for node in &graph.nodes {
        match node.kind {
            LayerKind::Input { .. } | LayerKind::Output { .. } => {}
            LayerKind::Attention { d_model, .. } if case == TransformerCase::Analog => {
                let d = d_model as u32;
                let tile = tiles.len();
                tiles.push(TileSpec { rows: d, cols: 4 * d, coupling: Coupling::Tight });
                let pl = |col0: u32| Placement { row0: 0, col0, rows: d, cols: d };
                s.steps.push(Step {
                    node: node.id,
                    place: Place::AttentionTiles {
                        q: TilePlacement { tile, placement: pl(0) },
                        k: TilePlacement { tile, placement: pl(d) },
                        v: TilePlacement { tile, placement: pl(2 * d) },
                        o: TilePlacement { tile, placement: pl(3 * d) },
                    },
                });
            }
            LayerKind::Dense { rows, cols, .. } if case == TransformerCase::Analog => {
                let tile = tiles.len();
                tiles.push(TileSpec { rows: rows as u32, cols: cols as u32, coupling: Coupling::Tight });
                s.steps.push(Step::tile(
                    node.id,
                    tile,
                    Placement { row0: 0, col0: 0, rows: rows as u32, cols: cols as u32 },
                ));
            }
            _ => s.steps.push(Step::cpu(node.id)),
        }
    }
    Ok((graph, Mapping { label, tiles, min_mutexes: 0, stages: vec![s] }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{Trace, TraceOp};

    #[test]
    fn shape_validation() {
        assert!(TransformerShape::new(256, 4, 64, 2, 1024).is_ok());
        assert!(TransformerShape::new(100, 3, 64, 2, 1024).is_err(), "heads must divide");
        assert!(TransformerShape::new(0, 1, 1, 1, 1).is_err());
        assert!(TransformerShape::new(4096, 4, 64, 2, 1024).is_err(), "over cap");
        let s = TransformerShape::new(256, 4, 64, 2, 1024).unwrap();
        assert_eq!(s.to_string(), "d256h4s64l2f1024");
    }

    #[test]
    fn digital_case_compiles_single_core() {
        let shape = TransformerShape::new(64, 2, 16, 1, 128).unwrap();
        let w = generate(shape, TransformerCase::Digital, 2).unwrap();
        assert_eq!(w.cores_used(), 1);
        assert!(w.spec.tiles.is_empty());
        assert!(w.label.ends_with("DIG-1core"));
    }

    #[test]
    fn analog_case_fires_projections_and_ffns() {
        let shape = TransformerShape::new(64, 2, 16, 2, 128).unwrap();
        let w = generate(shape, TransformerCase::Analog, 3).unwrap();
        // Per layer per inference: 4 projection MVMs + 2 FFN MVMs.
        let procs = w
            .traces
            .iter()
            .flat_map(Trace::iter_ops)
            .filter(|op| matches!(op, TraceOp::CmProcess { .. }))
            .count();
        assert_eq!(procs, 2 * 6 * 3);
        // One d x 4d attention tile + two FFN tiles per layer.
        assert_eq!(w.spec.tiles.len(), 2 * 3);
        assert_eq!(w.spec.tiles[0].cols, 4 * 64);
    }

    #[test]
    fn kv_cache_streamed_even_when_analog() {
        let shape = TransformerShape::new(64, 2, 16, 1, 128).unwrap();
        let w = generate(shape, TransformerCase::Analog, 1).unwrap();
        let kv: u64 = w
            .traces
            .iter()
            .flat_map(Trace::iter_ops)
            .filter_map(|op| match op {
                TraceOp::MemStream { base, bytes, .. } if base >= addr::KV => Some(bytes),
                _ => None,
            })
            .sum();
        assert_eq!(kv, 2 * 16 * 64);
    }
}
