//! Shared lowering rules: the per-primitive trace emission the mapping
//! compiler composes. These are the cost models the hand-written
//! generators used (digital GEMV, AIMClib queue/process/dequeue with
//! casts, activations, streaming input/writeback, the blocked conv GEMM
//! and the software-pipelined per-pixel analog conv loop), factored out
//! so every mapping lowers through one set of rules.

use crate::isa::InstClass;
use crate::nn::cnn::CnnLayer;
use crate::stats::RoiKind;
use crate::workload::trace::{TraceBuilder, TraceOp};
use crate::workload::{addr, costs};

/// Digital GEMV over `rows x cols` int8 weights starting at `w_base`:
/// one weight stream through the hierarchy + SDOT-style MACs.
pub(crate) fn digital_gemv(b: &mut TraceBuilder, w_base: u64, rows: u64, cols: u64) {
    b.roi(RoiKind::DigitalMvm, |b| {
        b.stream_read(w_base, rows * cols, 1);
        let c = costs::gemv_row_insts(rows);
        b.compute(InstClass::SimdOp, cols * c.simd_insts);
        b.compute(InstClass::IntAlu, cols * c.alu_insts);
    });
}

/// AIMClib queueVector: f32 -> int8 cast + pack + CM_QUEUE beats.
pub(crate) fn queue(b: &mut TraceBuilder, tile: usize, elems: u64) {
    b.roi(RoiKind::AnalogQueue, |b| {
        b.compute(InstClass::SimdOp, costs::cast_insts(elems));
        b.push(TraceOp::CmQueue { tile, bytes: elems });
    });
}

pub(crate) fn process(b: &mut TraceBuilder, tile: usize) {
    b.roi(RoiKind::AnalogProcess, |b| {
        b.push(TraceOp::CmProcess { tile });
    });
}

pub(crate) fn dequeue(b: &mut TraceBuilder, tile: usize, elems: u64) {
    b.roi(RoiKind::AnalogDequeue, |b| {
        b.push(TraceOp::CmDequeue { tile, bytes: elems });
        b.compute(InstClass::SimdOp, costs::cast_insts(elems));
    });
}

/// Vectorized ReLU over `elems` values.
pub(crate) fn relu(b: &mut TraceBuilder, elems: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(InstClass::SimdOp, elems / 8 + 4);
    });
}

/// Scalar-FP softmax over `elems` values.
pub(crate) fn softmax(b: &mut TraceBuilder, elems: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(
            InstClass::FpOp,
            elems * costs::activation_insts_per_elem(costs::Activation::SoftmaxPerElem),
        );
    });
}

/// LSTM cell-gate activations over an `n`-slice: 3x sigmoid + 1x tanh.
pub(crate) fn gate_activations(b: &mut TraceBuilder, n: u64) {
    b.roi(RoiKind::Activation, |b| {
        let fp = 3 * n * costs::activation_insts_per_elem(costs::Activation::Sigmoid)
            + n * costs::activation_insts_per_elem(costs::Activation::Tanh);
        b.compute(InstClass::FpOp, fp);
    });
}

/// LSTM c/h update over an `n`-slice: elementwise mults/adds + tanh.
pub(crate) fn gate_combine(b: &mut TraceBuilder, n: u64) {
    b.roi(RoiKind::GateCombine, |b| {
        b.compute(InstClass::SimdOp, n);
        b.compute(
            InstClass::FpOp,
            n * costs::activation_insts_per_elem(costs::Activation::Tanh),
        );
    });
}

/// Standalone max-pool over `elems` values (window^2 comparisons per
/// pooled element, stride-2 pooling).
pub(crate) fn pool(b: &mut TraceBuilder, elems: u64, window: u64) {
    b.roi(RoiKind::Activation, |b| {
        let pooled = elems / 4;
        b.compute(InstClass::SimdOp, pooled * window * window / 4 + 4);
    });
}

/// Generic elementwise stage with explicit instruction budgets.
pub(crate) fn elementwise(b: &mut TraceBuilder, simd_insts: u64, fp_insts: u64) {
    b.roi(RoiKind::GateCombine, |b| {
        b.compute(InstClass::SimdOp, simd_insts);
        b.compute(InstClass::FpOp, fp_insts);
    });
}

/// Layer normalization over `elems` values: vectorized mean/variance
/// reductions plus a scalar-FP rsqrt and per-element normalize + affine
/// (NEON handles the sums 4-wide; the normalize runs as fp32 pairs).
pub(crate) fn layer_norm(b: &mut TraceBuilder, elems: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(InstClass::SimdOp, elems / 4 + 8);
        b.compute(InstClass::FpOp, elems / 2 + 8);
    });
}

/// The digital middle of a multi-head attention step: stream the int8
/// K/V caches (`2 * seq * d_model` bytes, re-read every token), compute
/// the `heads x seq` attention scores (q.K^T) and the context
/// accumulation (A.V) as SDOT GEMVs, softmax the score rows. Always
/// digital — the caches change per token, so they cannot be
/// weight-stationary on a crossbar.
pub(crate) fn attention_context(b: &mut TraceBuilder, d_model: u64, heads: u64, seq: u64, slot: usize) {
    b.roi(RoiKind::DigitalMvm, |b| {
        b.stream_read(addr::kv(slot), 2 * seq * d_model, 1);
        // Scores + context are 2 * seq * d_model MACs total, plus the
        // per-score reduction tails.
        let macs = 2 * seq * d_model;
        b.compute(InstClass::SimdOp, macs / costs::SIMD_MACS_PER_INST + heads * seq / 4 + 8);
        b.compute(InstClass::IntAlu, macs / 64 + 8);
    });
    b.roi(RoiKind::Activation, |b| {
        b.compute(
            InstClass::FpOp,
            heads * seq * costs::activation_insts_per_elem(costs::Activation::SoftmaxPerElem),
        );
    });
}

/// Fresh per-inference input: a cold, non-prefetchable stream of `bytes`
/// plus AIMClib input marshalling.
pub(crate) fn input_load(b: &mut TraceBuilder, inference: u32, bytes: u64, marshal_insts: u64) {
    b.roi(RoiKind::InputLoad, |b| {
        b.push(TraceOp::MemStream {
            base: addr::input(inference, bytes),
            bytes,
            write: false,
            insts_per_line: 2,
            prefetchable: false,
        });
        b.compute(InstClass::IntAlu, marshal_insts);
    });
}

/// Result writeback: `bytes` streamed to the output region.
pub(crate) fn writeback(b: &mut TraceBuilder, inference: u32, bytes: u64) {
    b.roi(RoiKind::Writeback, |b| {
        b.stream_write(addr::output(inference, bytes), bytes, 2);
    });
}

/// Digital conv over `px` output pixels of one row group: im2col gather,
/// blocked int8 GEMM with weight-panel re-streaming, accumulation.
pub(crate) fn conv_digital_group(b: &mut TraceBuilder, l: &CnnLayer, weight_slot: usize, px: u64) {
    let kk = l.im2col_rows();
    b.roi(RoiKind::DigitalMvm, |b| {
        b.compute(InstClass::IntAlu, px * (kk / 4 + 12));
        let passes = px.div_ceil(costs::GEMM_ROW_BLOCK);
        for _ in 0..passes {
            b.stream_read(addr::weights(weight_slot), kk * l.out_ch, 1);
        }
        b.compute(
            InstClass::SimdOp,
            px * l.out_ch * (kk / costs::CONV_MACS_PER_INST + 1),
        );
        b.compute(InstClass::IntAlu, px * l.out_ch / 8);
    });
}

/// Fused conv post-ops over `elems` values: ReLU (+LRN) (+max-pool).
pub(crate) fn conv_post_ops(b: &mut TraceBuilder, l: &CnnLayer, elems: u64) {
    b.roi(RoiKind::Activation, |b| {
        b.compute(InstClass::SimdOp, elems / 8 + 4);
        if l.lrn {
            b.compute(InstClass::SimdOp, elems * costs::LRN_SIMD_PER_ELEM);
        }
        if l.pool > 1 {
            let pooled = elems / 4;
            b.compute(InstClass::SimdOp, pooled * l.pool * l.pool / 4 + 4);
        }
    });
}

/// The per-output-row op block of one analog conv layer: im2col gather,
/// then per output pixel a software-pipelined queue/process (+dequeue of
/// the previous pixel), and the final drain. Identical for every row of
/// the layer, so callers memcpy-append it per row.
pub(crate) fn analog_conv_row_block(tile: usize, l: &CnnLayer) -> Vec<TraceOp> {
    let out_hw = l.out_hw();
    let kk = l.im2col_rows();
    let mut b = TraceBuilder::with_capacity(6 + 9 * out_hw as usize);
    b.roi(RoiKind::AnalogQueue, |b| {
        b.compute(InstClass::IntAlu, out_hw * (kk / 4 + 12));
    });
    for px in 0..out_hw {
        b.push(TraceOp::RoiPush { kind: RoiKind::AnalogQueue });
        b.push(TraceOp::CmQueue { tile, bytes: kk });
        b.push(TraceOp::RoiPop);
        b.push(TraceOp::RoiPush { kind: RoiKind::AnalogProcess });
        b.push(TraceOp::CmProcess { tile });
        b.push(TraceOp::RoiPop);
        if px > 0 {
            b.push(TraceOp::RoiPush { kind: RoiKind::AnalogDequeue });
            b.push(TraceOp::CmDequeue { tile, bytes: l.out_ch });
            b.push(TraceOp::RoiPop);
        }
    }
    b.push(TraceOp::RoiPush { kind: RoiKind::AnalogDequeue });
    b.push(TraceOp::CmDequeue { tile, bytes: l.out_ch });
    b.push(TraceOp::RoiPop);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemv_rule_streams_whole_matrix() {
        let mut b = TraceBuilder::new();
        digital_gemv(&mut b, addr::weights(0), 1024, 1024);
        let bytes: u64 = b
            .ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::MemStream { bytes, .. } => Some(*bytes),
                _ => None,
            })
            .sum();
        assert_eq!(bytes, 1024 * 1024);
    }

    #[test]
    fn analog_row_block_one_process_per_pixel() {
        let l = crate::nn::CnnModel::paper(crate::nn::CnnVariant::Fast).convs[2];
        let block = analog_conv_row_block(2, &l);
        let procs = block.iter().filter(|op| matches!(op, TraceOp::CmProcess { .. })).count() as u64;
        let deqs = block.iter().filter(|op| matches!(op, TraceOp::CmDequeue { .. })).count() as u64;
        assert_eq!(procs, l.out_hw());
        assert_eq!(deqs, l.out_hw());
    }

    #[test]
    fn attention_context_streams_kv_cache() {
        let mut b = TraceBuilder::new();
        attention_context(&mut b, 128, 4, 32, 0);
        let kv_bytes: u64 = b
            .ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::MemStream { base, bytes, .. } if *base >= addr::KV => Some(*bytes),
                _ => None,
            })
            .sum();
        assert_eq!(kv_bytes, 2 * 32 * 128);
    }

    #[test]
    fn layer_norm_emits_balanced_roi() {
        let mut b = TraceBuilder::new();
        layer_norm(&mut b, 256);
        assert!(matches!(b.ops[0], TraceOp::RoiPush { kind: RoiKind::Activation }));
        assert!(matches!(b.ops.last(), Some(TraceOp::RoiPop)));
    }

    #[test]
    fn queue_dequeue_bracket_with_casts() {
        let mut b = TraceBuilder::new();
        queue(&mut b, 0, 256);
        dequeue(&mut b, 0, 256);
        assert!(matches!(b.ops[0], TraceOp::RoiPush { kind: RoiKind::AnalogQueue }));
        assert!(b.ops.iter().any(|op| matches!(op, TraceOp::CmQueue { tile: 0, bytes: 256 })));
        assert!(b.ops.iter().any(|op| matches!(op, TraceOp::CmDequeue { tile: 0, bytes: 256 })));
    }
}
