//! The mapping compiler: `(LayerGraph, Mapping) -> Workload` in one pass.
//!
//! The compiler validates the pair, derives the machine specification
//! (channel topology + numbering, barrier mutexes, tile list), emits the
//! CM_INITIALIZE preamble, and then lowers every stage's layer steps
//! through the shared rules in [`lower`] — once per inference for
//! per-inference stages, once per output-row group for row-streamed
//! (CNN-style) stages. The three paper workloads and any custom graph
//! compile through this same path; the retired hand-written generators
//! survive under `workload::legacy` purely as the bit-equivalence
//! oracle (see `tests/ir_equivalence.rs`).

pub mod cache;
pub(crate) mod lower;
pub mod mapping;

use crate::isa::InstClass;
use crate::nn::{LayerGraph, LayerKind, NodeId};
use crate::sim::machine::{ChannelSpec, MachineSpec, TileSpec};
use crate::stats::RoiKind;
use crate::workload::trace::{Segment, Trace, TraceBuilder, TraceOp};
use crate::workload::{addr, Workload, WorkloadError};
use cache::{tile_slots, CompileCache, FragKey};
use mapping::{Handoff, Mapping, Place, SplitKind, Stage, StageInput, StageOutput, Step};
use std::sync::Mutex;

/// Bounded ping-pong depth of every compiled channel.
pub const CHANNEL_CAPACITY: usize = 2;
/// Ack message payload of shared-buffer hand-offs (§VII.C).
pub const ACK_BYTES: u64 = 64;

/// Per-stage channel/mutex assignment derived by the compiler.
struct Wiring {
    /// LeaderGather intra-stage channels: replica r -> leader (index r-1).
    gather: Vec<usize>,
    /// LeaderGather intra-stage channels: leader -> replica r (index r-1).
    broadcast: Vec<usize>,
    /// Outgoing boundary forward channels, one list per out-edge (in
    /// `out_edges` order), each producer-major (`fwd[e][p * nc + c]`;
    /// LeaderGather producers: leader only, `fwd[e][c]`).
    fwd: Vec<Vec<usize>>,
    /// Outgoing boundary ack channels (SharedBuffer), one list per
    /// out-edge, each consumer-major (`ack[e][c * np + p]`; empty for
    /// PingPong hand-offs).
    ack: Vec<Vec<usize>>,
    /// Barrier mutex id, if the stage declares one.
    mutex: Option<usize>,
}

/// A stage's outgoing boundary edges as `(consumer stage, payload
/// bytes)` pairs. The legacy `Channel` variant is the single-edge case
/// targeting `idx + 1`; `Fanout` names its consumers explicitly.
fn out_edges(output: &StageOutput, idx: usize) -> Vec<(usize, u64)> {
    match output {
        StageOutput::Channel { bytes } => vec![(idx + 1, *bytes)],
        StageOutput::Fanout { to } => to.clone(),
        StageOutput::Memory { .. } | StageOutput::None => Vec::new(),
    }
}

/// A stage's producer stage indices, ascending. The legacy `Channel`
/// input is the single-producer case `idx - 1`.
fn in_stages(input: &StageInput, idx: usize) -> Vec<usize> {
    match input {
        StageInput::Channel => vec![idx - 1],
        StageInput::Join { from, .. } => from.clone(),
        StageInput::Memory { .. } | StageInput::None => Vec::new(),
    }
}

/// Position of the edge `p -> t` inside producer `p`'s out-edge list
/// (the first index of its `Wiring::fwd` / `Wiring::ack`).
fn edge_pos(output: &StageOutput, p: usize, t: usize) -> usize {
    out_edges(output, p)
        .iter()
        .position(|&(c, _)| c == t)
        .expect("validated: consumer listed in producer's out-edges")
}

/// One cached step occurrence inside a scoring-mode trace: the lowered
/// fragment was *not* materialized into the builder; instead its id and
/// position among the surrounding glue ops are recorded so the cost
/// walk can absorb the glue individually and add the fragment's
/// memoized profile (`automap::cost::estimate_with`).
pub(crate) struct FragSpan {
    /// Index into the core's flat op stream where the fragment would sit.
    pub(crate) pos: usize,
    /// Fragment id inside the shared [`CompileCache`].
    pub(crate) frag: usize,
    /// The step's slot table resolved to tile specs (the fragment's
    /// per-slot cost context).
    pub(crate) specs: Vec<TileSpec>,
}

/// Compile-cache session state threaded through one `compile_with` run.
///
/// Two modes share the same fragment arena:
/// - **scoring** (`spans: Some`): per-candidate oracle compiles. Cached
///   steps are never materialized — only a [`FragSpan`] is recorded —
///   so a hit skips the lowering *and* the per-op cost walk. Only valid
///   on the flat emission path (`n_inf` small enough to skip loop
///   encoding), where builder positions survive into the final trace.
/// - **materialize** (`spans: None`): real workload compiles (the
///   coordinator's top-K). Cached steps splice their arena ops into the
///   builder, relocated to the step's tiles; output is bit-identical to
///   an uncached compile (debug builds re-emit every hit and assert it).
pub(crate) struct CacheCtx<'a> {
    cache: &'a Mutex<CompileCache>,
    spans: Option<&'a mut Vec<Vec<FragSpan>>>,
    /// Off-trace emission buffer for scoring-mode misses (and the
    /// debug-build hit verifier).
    scratch: TraceBuilder,
}

impl<'a> CacheCtx<'a> {
    /// Scoring mode: record fragment spans per core instead of
    /// materializing cached steps.
    pub(crate) fn scoring(
        cache: &'a Mutex<CompileCache>,
        spans: &'a mut Vec<Vec<FragSpan>>,
    ) -> CacheCtx<'a> {
        CacheCtx { cache, spans: Some(spans), scratch: TraceBuilder::new() }
    }

    /// Materialize mode: splice cached fragments into the trace.
    pub(crate) fn materialize(cache: &'a Mutex<CompileCache>) -> CacheCtx<'a> {
        CacheCtx { cache, spans: None, scratch: TraceBuilder::new() }
    }

    /// Lower one step through the cache (uncacheable shapes fall back to
    /// a direct `emit_step`).
    fn step(
        &mut self,
        b: &mut TraceBuilder,
        graph: &LayerGraph,
        step: &Step,
        r: usize,
        parts: u64,
        core: usize,
        tiles: &[TileSpec],
    ) {
        let Some(key) = FragKey::for_step(step, r, parts) else {
            emit_step(b, graph, step, r, parts);
            return;
        };
        let slots = tile_slots(&step.place, r);
        let hit = self.cache.lock().expect("compile cache poisoned").lookup(key);
        if let Some(fid) = hit {
            match &mut self.spans {
                Some(spans) => {
                    let specs = slots.iter().map(|&t| tiles[t]).collect();
                    spans[core].push(FragSpan { pos: b.ops.len(), frag: fid, specs });
                }
                None => {
                    #[cfg(debug_assertions)]
                    {
                        self.scratch.ops.clear();
                        emit_step(&mut self.scratch, graph, step, r, parts);
                        debug_assert!(
                            self.cache
                                .lock()
                                .expect("compile cache poisoned")
                                .matches(fid, &self.scratch.ops, &slots),
                            "cached fragment diverges from fresh emission for {key:?}"
                        );
                    }
                    self.cache.lock().expect("compile cache poisoned").splice(fid, &slots, b);
                }
            }
            return;
        }
        match &mut self.spans {
            Some(spans) => {
                self.scratch.ops.clear();
                emit_step(&mut self.scratch, graph, step, r, parts);
                let fid = self
                    .cache
                    .lock()
                    .expect("compile cache poisoned")
                    .insert(key, &self.scratch.ops, &slots);
                let specs = slots.iter().map(|&t| tiles[t]).collect();
                spans[core].push(FragSpan { pos: b.ops.len(), frag: fid, specs });
            }
            None => {
                let start = b.ops.len();
                emit_step(b, graph, step, r, parts);
                self.cache
                    .lock()
                    .expect("compile cache poisoned")
                    .insert(key, &b.ops[start..], &slots);
            }
        }
    }
}

/// Compile a mapped layer graph into per-core traces + machine spec.
pub fn compile(graph: &LayerGraph, mapping: &Mapping, n_inf: u32) -> Result<Workload, WorkloadError> {
    compile_with(graph, mapping, n_inf, None)
}

/// [`compile`] with an optional compile-cache context (see [`CacheCtx`]).
pub(crate) fn compile_with(
    graph: &LayerGraph,
    mapping: &Mapping,
    n_inf: u32,
    mut ctx: Option<&mut CacheCtx>,
) -> Result<Workload, WorkloadError> {
    validate(graph, mapping)?;
    let (wirings, channels, mutexes) = wire(mapping);

    let n_cores = mapping
        .stages
        .iter()
        .flat_map(|s| s.cores.iter().copied())
        .max()
        .unwrap_or(0)
        + 1;
    let mut builders: Vec<TraceBuilder> = (0..n_cores).map(|_| TraceBuilder::new()).collect();
    if let Some(c) = ctx.as_deref_mut() {
        if let Some(spans) = &mut c.spans {
            spans.clear();
            spans.resize_with(n_cores, Vec::new);
        }
    }

    // CM_INITIALIZE preamble: program every claimed tile region, in
    // stage / replica / step order (one-time cost, outside the ROI loop).
    for s in &mapping.stages {
        for (r, &core) in s.cores.iter().enumerate() {
            for step in &s.steps {
                match &step.place {
                    Place::Tile { per_replica } => {
                        let tp = per_replica[r];
                        builders[core].push(TraceOp::CmInit { tile: tp.tile, placement: tp.placement });
                    }
                    Place::TileRowSplit { tiles } | Place::TileChain { tiles } => {
                        for tp in tiles {
                            builders[core].push(TraceOp::CmInit { tile: tp.tile, placement: tp.placement });
                        }
                    }
                    Place::AttentionTiles { q, k, v, o } => {
                        for tp in [q, k, v, o] {
                            builders[core].push(TraceOp::CmInit { tile: tp.tile, placement: tp.placement });
                        }
                    }
                    Place::Cpu | Place::Fused => {}
                }
            }
        }
    }

    // Pre-build the per-row CM-op block of each analog row-streamed
    // (conv) stage once; it is memcpy-appended per output row.
    let row_blocks: Vec<Option<Vec<TraceOp>>> = mapping
        .stages
        .iter()
        .map(|s| {
            if s.row_group.is_none() {
                return None;
            }
            let step = &s.steps[0];
            if let (Place::Tile { per_replica }, LayerKind::Conv2d { layer, .. }) =
                (&step.place, &graph.nodes[step.node].kind)
            {
                Some(lower::analog_conv_row_block(per_replica[0].tile, layer))
            } else {
                None
            }
        })
        .collect();

    // Emit one whole inference `i`, stage by stage, into the per-core
    // builders. Row-streamed stages bypass the compile cache (their row
    // loop is already compacted by the pre-built block + `Rep` pairs).
    let emit_inference =
        |builders: &mut [TraceBuilder], i: u32, mut ctx: Option<&mut CacheCtx>| {
            for (idx, s) in mapping.stages.iter().enumerate() {
                if let Some(rg) = s.row_group {
                    emit_row_streamed(
                        &mut builders[s.cores[0]],
                        graph,
                        mapping,
                        &wirings,
                        idx,
                        rg,
                        i,
                        row_blocks[idx].as_deref(),
                    );
                } else {
                    for r in 0..s.cores.len() {
                        emit_replica(
                            &mut builders[s.cores[r]],
                            graph,
                            mapping,
                            &wirings,
                            idx,
                            r,
                            i,
                            ctx.as_deref_mut(),
                        );
                    }
                }
            }
        };

    // Steady-state loop encoding: inference emission is periodic once
    // the shared-buffer ack gating (`i > 0`) is past, with period 2
    // (ping-pong channel slots key on `i % 2`) and per-inference
    // input/output addresses advancing linearly. Peel the warm-up
    // inferences flat, then store ONE period-2 pair per core — a `Rep`
    // segment when the pair lowers to straight-line ops, a nested
    // `Loop` when it carries inner loops (the row-group `Rep` of a
    // row-streamed stage) — verified against three sampled pairs, with
    // a flat unroll as the bit-exact fallback — so compile time and
    // trace memory are O(block), not O(N * block).
    const REP_WARMUP: u32 = 2;
    const REP_PERIOD: u32 = 2;
    let pairs = n_inf.saturating_sub(REP_WARMUP) / REP_PERIOD;
    // Below 4 pairs the three affinity samples cost as much as unrolling.
    if pairs >= 4 {
        // Span positions index a flat op stream; the loop-encoding path
        // rearranges ops across sample builders, so scoring mode (which
        // only compiles tiny n_inf) must never reach it.
        debug_assert!(
            ctx.as_deref_mut().map_or(true, |c| c.spans.is_none()),
            "span recording requires the flat emission path"
        );
        for i in 0..REP_WARMUP {
            emit_inference(&mut builders, i, ctx.as_deref_mut());
        }
        let sample_pair = |k: u32, mut ctx: Option<&mut CacheCtx>| -> Vec<Trace> {
            let mut sb: Vec<TraceBuilder> = (0..n_cores).map(|_| TraceBuilder::new()).collect();
            for j in 0..REP_PERIOD {
                emit_inference(&mut sb, REP_WARMUP + REP_PERIOD * k + j, ctx.as_deref_mut());
            }
            sb.into_iter().map(TraceBuilder::build_trace).collect()
        };
        // A sample that is one straight-line run (or empty — an idle
        // core) takes the flat `Rep` path, byte-for-byte the pre-nesting
        // encoding; anything else goes through `loop_from_samples`.
        fn flat_ops(t: &Trace) -> Option<&[TraceOp]> {
            match t.segments.as_slice() {
                [] => Some(&[]),
                [Segment::Ops(v)] => Some(v.as_slice()),
                _ => None,
            }
        }
        let s0 = sample_pair(0, ctx.as_deref_mut());
        let s1 = sample_pair(1, ctx.as_deref_mut());
        let s2 = sample_pair(2, ctx.as_deref_mut());
        let s_last = sample_pair(pairs - 1, ctx.as_deref_mut()); // far endpoint: rejects piecewise patterns
        let reps: Vec<Option<Segment>> = (0..n_cores)
            .map(|c| {
                match (flat_ops(&s0[c]), flat_ops(&s1[c]), flat_ops(&s2[c]), flat_ops(&s_last[c])) {
                    (Some(f0), Some(f1), Some(f2), Some(fl)) => {
                        let checks = [(f1, 1u32), (f2, 2), (fl, pairs - 1)];
                        Segment::rep_from_samples(f0, &checks, pairs)
                    }
                    _ => {
                        let checks = [
                            (s1[c].segments.as_slice(), 1u32),
                            (s2[c].segments.as_slice(), 2),
                            (s_last[c].segments.as_slice(), pairs - 1),
                        ];
                        Segment::loop_from_samples(&s0[c].segments, &checks, pairs)
                    }
                }
            })
            .collect();
        if reps.iter().all(Option::is_some) {
            for (b, seg) in builders.iter_mut().zip(reps) {
                b.push_segment(seg.expect("all segments verified affine"));
            }
            for i in (REP_WARMUP + REP_PERIOD * pairs)..n_inf {
                emit_inference(&mut builders, i, ctx.as_deref_mut()); // odd tail inference
            }
        } else {
            // Non-affine emission (not produced by any current lowering
            // rule): fall back to unrolling the rest flat.
            for i in REP_WARMUP..n_inf {
                emit_inference(&mut builders, i, ctx.as_deref_mut());
            }
        }
    } else {
        let marks: Vec<usize> = builders.iter().map(TraceBuilder::mark).collect();
        for i in 0..n_inf {
            if i == 1 {
                // Inference 0 sized one block per core; reserve the rest.
                for (b, m) in builders.iter_mut().zip(&marks) {
                    b.reserve_repeats(*m, n_inf - 1);
                }
            }
            emit_inference(&mut builders, i, ctx.as_deref_mut());
        }
    }

    // Nested loop counts multiply: reject any trace whose flattened
    // length overflows u64 with a typed error instead of letting the
    // wrap surface as a bogus op count downstream.
    let traces: Vec<Trace> = builders.into_iter().map(TraceBuilder::build_trace).collect();
    for (core, t) in traces.iter().enumerate() {
        if t.flat_len().is_none() {
            return Err(WorkloadError::TraceTooLarge { core });
        }
    }

    Ok(Workload {
        label: mapping.label.clone(),
        traces,
        spec: MachineSpec { tiles: mapping.tiles.clone(), mutexes, channels },
        inferences: n_inf,
    })
}

// ---------------------------------------------------------------------------
// Channel / mutex assignment
// ---------------------------------------------------------------------------

fn wire(mapping: &Mapping) -> (Vec<Wiring>, Vec<ChannelSpec>, usize) {
    let mut channels: Vec<ChannelSpec> = Vec::new();
    let mut wirings: Vec<Wiring> = Vec::with_capacity(mapping.stages.len());
    let mut mutex_count = 0usize;
    for (idx, s) in mapping.stages.iter().enumerate() {
        let mut w = Wiring {
            gather: Vec::new(),
            broadcast: Vec::new(),
            fwd: Vec::new(),
            ack: Vec::new(),
            mutex: None,
        };
        if s.barrier {
            w.mutex = Some(mutex_count);
            mutex_count += 1;
        }
        if s.split == SplitKind::LeaderGather {
            let leader = s.cores[0];
            for &r in &s.cores[1..] {
                w.gather.push(channels.len());
                channels.push(ChannelSpec { producer: r, consumer: leader, capacity: CHANNEL_CAPACITY });
            }
            for &r in &s.cores[1..] {
                w.broadcast.push(channels.len());
                channels.push(ChannelSpec { producer: leader, consumer: r, capacity: CHANNEL_CAPACITY });
            }
        }
        // Per out-edge, in edge order: all forward channels (producer-
        // major), then all ack channels (consumer-major, SharedBuffer
        // only). For a single `Channel` edge this is byte-for-byte the
        // legacy numbering.
        let edges = out_edges(&s.output, idx);
        let producers: Vec<usize> = if s.split == SplitKind::LeaderGather {
            vec![s.cores[0]]
        } else {
            s.cores.clone()
        };
        for &(t, _) in &edges {
            let mut fwd = Vec::new();
            for &p in &producers {
                for &c in &mapping.stages[t].cores {
                    fwd.push(channels.len());
                    channels.push(ChannelSpec { producer: p, consumer: c, capacity: CHANNEL_CAPACITY });
                }
            }
            w.fwd.push(fwd);
        }
        for &(t, _) in &edges {
            let mut ack = Vec::new();
            if s.handoff == Handoff::SharedBuffer {
                for &c in &mapping.stages[t].cores {
                    for &p in &producers {
                        ack.push(channels.len());
                        channels.push(ChannelSpec { producer: c, consumer: p, capacity: CHANNEL_CAPACITY });
                    }
                }
            }
            w.ack.push(ack);
        }
        wirings.push(w);
    }
    (wirings, channels, mutex_count.max(mapping.min_mutexes))
}

/// Forward channels a consumer replica receives on over out-edge `e`
/// of the producer stage, in producer order.
fn fwd_for_consumer(prev: &Stage, prev_w: &Wiring, e: usize, c_idx: usize, nc: usize) -> Vec<usize> {
    if prev.split == SplitKind::LeaderGather {
        vec![prev_w.fwd[e][c_idx]]
    } else {
        (0..prev.cores.len()).map(|p| prev_w.fwd[e][p * nc + c_idx]).collect()
    }
}

/// Messages per inference on each incoming channel: row-streamed
/// producers emit one message per output-row group.
fn messages_per_inference(prev: &Stage, graph: &LayerGraph) -> u64 {
    match prev.row_group {
        Some(rg) => {
            if let LayerKind::Conv2d { layer, .. } = &graph.nodes[prev.steps[0].node].kind {
                layer.out_hw().div_ceil(rg)
            } else {
                1
            }
        }
        None => 1,
    }
}

// ---------------------------------------------------------------------------
// Per-inference stage emission
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn emit_replica(
    b: &mut TraceBuilder,
    graph: &LayerGraph,
    mapping: &Mapping,
    wirings: &[Wiring],
    idx: usize,
    r: usize,
    i: u32,
    mut ctx: Option<&mut CacheCtx>,
) {
    let s = &mapping.stages[idx];
    let parts = s.parts();

    // ---- input phase ------------------------------------------------------
    match &s.input {
        StageInput::Memory { node } => {
            if let LayerKind::Input { bytes, marshal_insts, raw_bytes } = graph.nodes[*node].kind {
                if s.split == SplitKind::LeaderGather && r > 0 {
                    // Followers re-read the int8 copy of the same input
                    // (it hits the LLC after the leader's cold load).
                    b.roi(RoiKind::InputLoad, |b| {
                        b.push(TraceOp::MemStream {
                            base: addr::input(i, raw_bytes),
                            bytes: raw_bytes,
                            write: false,
                            insts_per_line: 2,
                            prefetchable: false,
                        });
                        b.compute(InstClass::IntAlu, marshal_insts);
                    });
                } else {
                    lower::input_load(b, i, bytes, marshal_insts);
                }
            }
        }
        StageInput::Channel | StageInput::Join { .. } => {
            // DAG joins may additionally tap the graph input directly
            // (a residual branch starting at the Input node).
            if let StageInput::Join { mem: Some(node), .. } = &s.input {
                if let LayerKind::Input { bytes, marshal_insts, .. } = graph.nodes[*node].kind {
                    lower::input_load(b, i, bytes, marshal_insts);
                }
            }
            // Receive from every producer stage, ascending, each
            // producer's replicas in producer-major order. The legacy
            // `Channel` input is the single-producer case.
            let producers = in_stages(&s.input, idx);
            b.roi(RoiKind::Communication, |b| {
                for &p in &producers {
                    let prev = &mapping.stages[p];
                    let e = edge_pos(&prev.output, p, idx);
                    let chs = fwd_for_consumer(prev, &wirings[p], e, r, s.cores.len());
                    let per_ch = messages_per_inference(prev, graph);
                    for &ch in &chs {
                        for _ in 0..per_ch {
                            b.push(TraceOp::Recv { ch });
                        }
                    }
                }
            });
        }
        StageInput::None => {}
    }

    // ---- layer steps ------------------------------------------------------
    let mut si = 0;
    while si < s.steps.len() {
        let step = &s.steps[si];
        if let Place::TileChain { tiles } = &step.place {
            // Collect the fused run this chain executes in-accelerator.
            let mut group: Vec<NodeId> = vec![step.node];
            let mut j = si + 1;
            while j < s.steps.len() && matches!(s.steps[j].place, Place::Fused) {
                group.push(s.steps[j].node);
                j += 1;
            }
            let rows = graph.nodes[group[0]].kind.mvm_rows().unwrap_or(0);
            let cols = group
                .iter()
                .rev()
                .find_map(|&n| graph.nodes[n].kind.mvm_cols())
                .unwrap_or(0);
            lower::queue(b, tiles[0].tile, rows);
            for tp in tiles {
                lower::process(b, tp.tile);
            }
            lower::dequeue(b, tiles.last().expect("validated non-empty chain").tile, cols);
            si = j;
        } else {
            match ctx.as_deref_mut() {
                Some(c) => c.step(b, graph, step, r, parts, s.cores[r], &mapping.tiles),
                None => emit_step(b, graph, step, r, parts),
            }
            si += 1;
        }
    }

    // ---- barrier ----------------------------------------------------------
    if let Some(m) = wirings[idx].mutex {
        b.roi(RoiKind::Sync, |b| {
            b.push(TraceOp::MutexLock { id: m });
            b.push(TraceOp::MutexUnlock { id: m });
        });
    }

    // ---- communication / output ------------------------------------------
    if s.split == SplitKind::LeaderGather {
        let &StageOutput::Channel { bytes } = &s.output else {
            unreachable!("validated: LeaderGather stages end in a channel")
        };
        let w = &wirings[idx];
        if r == 0 {
            b.roi(RoiKind::Communication, |b| {
                for &ch in &w.gather {
                    b.push(TraceOp::Recv { ch });
                }
                // Broadcast the assembled vector to every follower (the
                // recurrence) and feed the next stage; the +k address
                // nudge keeps the per-destination buffers distinct.
                for (k, &ch) in w.broadcast.iter().chain(w.fwd[0].iter()).enumerate() {
                    b.push(TraceOp::Send { ch, bytes, addr: addr::channel(ch, i) + k as u64 });
                }
            });
        } else {
            let gather_ch = w.gather[r - 1];
            let bcast_ch = w.broadcast[r - 1];
            // The gather message is the replica's fp32 output slice:
            // 4 * (width/parts) bytes, where width = bytes/4. (Not
            // bytes/parts — for widths not divisible by the replica
            // count, e.g. n_h = 750 over 4 cores, the slice rounds
            // down per element, not per byte.)
            let slice_bytes = 4 * (bytes / 4 / parts);
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send {
                    ch: gather_ch,
                    bytes: slice_bytes,
                    addr: addr::channel(gather_ch, i),
                });
                b.push(TraceOp::Recv { ch: bcast_ch });
            });
        }
    } else {
        match &s.output {
            StageOutput::Channel { .. } | StageOutput::Fanout { .. } => {
                let edges = out_edges(&s.output, idx);
                let w = &wirings[idx];
                let np = s.cores.len();
                b.roi(RoiKind::Communication, |b| {
                    if i > 0 {
                        // Shared-buffer hand-off: wait for each consumer's
                        // ack of the previous inference before reusing it.
                        for e in 0..edges.len() {
                            let acks = &w.ack[e];
                            if acks.is_empty() {
                                continue;
                            }
                            let nc = w.fwd[e].len() / np;
                            for c in 0..nc {
                                b.push(TraceOp::Recv { ch: acks[c * np + r] });
                            }
                        }
                    }
                    for (e, &(_, bytes)) in edges.iter().enumerate() {
                        let nc = w.fwd[e].len() / np;
                        for c in 0..nc {
                            let ch = w.fwd[e][r * nc + c];
                            b.push(TraceOp::Send { ch, bytes, addr: addr::channel(ch, i) });
                        }
                    }
                });
            }
            StageOutput::Memory { node } => {
                if let LayerKind::Output { bytes } = graph.nodes[*node].kind {
                    lower::writeback(b, i, bytes / parts);
                }
            }
            StageOutput::None => {}
        }
    }

    // ---- acknowledge incoming shared-buffer hand-offs ---------------------
    let producers = in_stages(&s.input, idx);
    if producers.iter().any(|&p| mapping.stages[p].handoff == Handoff::SharedBuffer) {
        b.roi(RoiKind::Communication, |b| {
            for &p in &producers {
                let prev = &mapping.stages[p];
                if prev.handoff != Handoff::SharedBuffer {
                    continue;
                }
                let pw = &wirings[p];
                let e = edge_pos(&prev.output, p, idx);
                let np = if prev.split == SplitKind::LeaderGather { 1 } else { prev.cores.len() };
                for pr in 0..np {
                    let ch = pw.ack[e][r * np + pr];
                    b.push(TraceOp::Send { ch, bytes: ACK_BYTES, addr: addr::channel(ch, i) });
                }
            }
        });
    }
}

/// Lower one non-chain layer step for replica `r` of a stage split
/// `parts` ways. Exposed crate-wide so the automap compositional cost
/// engine can emit anchor regions in isolation through the exact same
/// lowering rules the full compile uses (profiles cannot drift).
pub(crate) fn emit_step(b: &mut TraceBuilder, graph: &LayerGraph, step: &Step, r: usize, parts: u64) {
    let node = &graph.nodes[step.node];
    match &node.kind {
        LayerKind::Dense { rows, cols, weight_slot } => {
            emit_mvm(b, &step.place, *rows, *cols, *weight_slot, r, parts);
        }
        LayerKind::LstmCell { x, n_h, weight_slot } => {
            emit_mvm(b, &step.place, n_h + x, 4 * n_h, *weight_slot, r, parts);
            lower::gate_activations(b, n_h / parts);
            lower::gate_combine(b, n_h / parts);
        }
        LayerKind::Activation { kind, elems } => match kind {
            crate::nn::ActKind::Relu => lower::relu(b, elems / parts),
            crate::nn::ActKind::Softmax => lower::softmax(b, elems / parts),
        },
        LayerKind::Pool { elems, window } => lower::pool(b, elems / parts, *window),
        LayerKind::Elementwise { simd_insts, fp_insts } => {
            lower::elementwise(b, simd_insts / parts, fp_insts / parts)
        }
        LayerKind::LayerNorm { elems } => lower::layer_norm(b, elems / parts),
        LayerKind::Attention { d_model, heads, seq, weight_slot } => {
            let d = *d_model;
            match &step.place {
                Place::Cpu => {
                    // Q|K|V projections share the input vector: one
                    // digital GEMV over the packed d x 3d weight block.
                    lower::digital_gemv(b, addr::weights(*weight_slot), d, 3 * d);
                    lower::attention_context(b, d, *heads, *seq, *weight_slot);
                    lower::digital_gemv(b, addr::weights(*weight_slot) + 3 * d * d, d, d);
                }
                Place::AttentionTiles { q, k, v, o } => {
                    for tp in [q, k, v] {
                        lower::queue(b, tp.tile, d);
                        lower::process(b, tp.tile);
                        lower::dequeue(b, tp.tile, d);
                    }
                    lower::attention_context(b, d, *heads, *seq, *weight_slot);
                    lower::queue(b, o.tile, d);
                    lower::process(b, o.tile);
                    lower::dequeue(b, o.tile, d);
                }
                _ => unreachable!("validated: attention runs on Cpu or AttentionTiles"),
            }
        }
        LayerKind::Merge { op: _, elems } => {
            // Both merge flavors lower to one vector pass over the joined
            // activations (add: SIMD adds; concat: SIMD copies into the
            // packed layout) — the same budget as the legacy linear-chain
            // residual `Elementwise` node.
            lower::elementwise(b, (elems / 4 + 4) / parts, 0);
        }
        LayerKind::AttnHead { d_head, seq, kv_slot } => {
            // One head's score/softmax/context block over its private
            // K/V cache (the QKV projection is a separate Dense node).
            lower::attention_context(b, *d_head, 1, *seq, *kv_slot);
        }
        LayerKind::MoE { rows, cols, experts, top_k, weight_slot } => {
            let slice = cols / parts;
            // Router: a tiny dense gate over the expert logits plus the
            // top-k probability normalization — always digital (the gate
            // is far too small to earn a crossbar region).
            lower::digital_gemv(b, addr::weights(*weight_slot), *rows, *experts);
            lower::softmax(b, *experts);
            match &step.place {
                Place::Cpu => {
                    // Top-k expert FFNs, each a rows x slice digital GEMV
                    // over this replica's column slice of the expert.
                    for e in 0..*top_k {
                        let base = addr::weights(*weight_slot)
                            + rows * experts
                            + e * rows * cols
                            + r as u64 * rows * slice;
                        lower::digital_gemv(b, base, *rows, slice);
                    }
                }
                Place::Tile { per_replica } => {
                    // The replica's tile region holds ALL experts' column
                    // slices side by side (rows x experts*slice): queue
                    // the shared input once, fire the whole bank, dequeue
                    // only the top-k selected slices.
                    let tp = per_replica[r];
                    lower::queue(b, tp.tile, *rows);
                    lower::process(b, tp.tile);
                    lower::dequeue(b, tp.tile, top_k * slice);
                }
                _ => unreachable!("validated: MoE runs on Cpu or Tile"),
            }
            // Gate-weighted combine of the top-k expert outputs.
            lower::elementwise(b, top_k * slice / 8 + 4, *top_k);
        }
        LayerKind::Conv2d { layer, weight_slot } => {
            // Per-inference conv lowering (DAG branches, where the
            // row-streamed pipeline's single-chain hand-off does not
            // apply): the whole output map in one step.
            let px = layer.out_hw() * layer.out_hw();
            match &step.place {
                Place::Cpu => lower::conv_digital_group(b, layer, *weight_slot, px),
                Place::Tile { per_replica } => {
                    let block = lower::analog_conv_row_block(per_replica[r].tile, layer);
                    b.reserve(block.len() * layer.out_hw() as usize);
                    for _ in 0..layer.out_hw() {
                        b.extend_from_slice(&block);
                    }
                }
                _ => unreachable!("validated: Conv2d runs on Cpu or Tile"),
            }
            lower::conv_post_ops(b, layer, px * layer.out_ch);
        }
        LayerKind::Input { .. } | LayerKind::Output { .. } => {
            unreachable!("validated: not a per-inference step kind")
        }
    }
}

/// Lower one MVM (`rows x cols`, column-sliced `parts` ways) through the
/// step's engine.
fn emit_mvm(
    b: &mut TraceBuilder,
    place: &Place,
    rows: u64,
    cols: u64,
    weight_slot: usize,
    r: usize,
    parts: u64,
) {
    let slice = cols / parts;
    match place {
        Place::Cpu => {
            lower::digital_gemv(b, addr::weights(weight_slot) + r as u64 * (rows * slice), rows, slice);
        }
        Place::Tile { per_replica } => {
            let tp = per_replica[r];
            lower::queue(b, tp.tile, rows);
            lower::process(b, tp.tile);
            lower::dequeue(b, tp.tile, slice);
        }
        Place::TileRowSplit { tiles } => {
            let k = tiles.len() as u64;
            for tp in tiles {
                lower::queue(b, tp.tile, rows / k);
            }
            for tp in tiles {
                lower::process(b, tp.tile);
            }
            lower::dequeue(b, tiles.last().expect("validated non-empty split").tile, cols);
            // The k partial outputs accumulate digitally after the drain.
            b.roi(RoiKind::AnalogDequeue, |b| {
                b.compute(InstClass::SimdOp, (k - 1) * cols / 8);
            });
        }
        Place::Fused => {}
        Place::TileChain { .. } => unreachable!("chains are lowered by the caller"),
        Place::AttentionTiles { .. } => unreachable!("validated: attention lowers via emit_step"),
    }
}

// ---------------------------------------------------------------------------
// Row-streamed (CNN pipeline) stage emission
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn emit_row_streamed(
    b: &mut TraceBuilder,
    graph: &LayerGraph,
    mapping: &Mapping,
    wirings: &[Wiring],
    idx: usize,
    rg: u64,
    i: u32,
    row_block: Option<&[TraceOp]>,
) {
    let s = &mapping.stages[idx];
    let step = &s.steps[0];
    let LayerKind::Conv2d { layer: l, weight_slot } = &graph.nodes[step.node].kind else {
        unreachable!("validated: row-streamed stages run one Conv2d")
    };
    let out_hw = l.out_hw();
    let row_groups = out_hw.div_ceil(rg);
    let out_row_bytes = l.pooled_hw() * l.out_ch;

    // Per-group receive counts. With at least one producer message per
    // group this is the legacy span formula (kept verbatim for bit-
    // equivalence with the oracle, including its non-uniform remainder
    // distribution). With *fewer* messages than groups — a configuration
    // the legacy CNN could never produce — each message lands at the
    // FIRST group of its span, so no group computes on input that has
    // not arrived yet.
    let in_info: Option<(usize, Vec<u64>)> = if s.input == StageInput::Channel {
        let prev = &mapping.stages[idx - 1];
        let e = edge_pos(&prev.output, idx - 1, idx);
        let ch = fwd_for_consumer(prev, &wirings[idx - 1], e, 0, 1)[0];
        let in_msgs = messages_per_inference(prev, graph);
        let counts: Vec<u64> = if in_msgs >= row_groups {
            (0..row_groups)
                .map(|g| (g + 1) * in_msgs / row_groups - g * in_msgs / row_groups)
                .collect()
        } else {
            let mut c = vec![0u64; row_groups as usize];
            for m in 0..in_msgs {
                c[(m * row_groups / in_msgs) as usize] += 1;
            }
            c
        };
        Some((ch, counts))
    } else {
        None
    };
    let out_ch_id: Option<usize> = if matches!(s.output, StageOutput::Channel { .. }) {
        Some(wirings[idx].fwd[0][0])
    } else {
        None
    };

    // One output-row group; factored out so the group-pair loop below
    // can re-emit it per sampled iteration.
    let emit_group = |b: &mut TraceBuilder, g: u64| {
        // ---- receive input rows (or load the image slice) -----------------
        if let Some((ch, counts)) = &in_info {
            let ch = *ch;
            let n = counts[g as usize];
            b.roi(RoiKind::Communication, |b| {
                for _ in 0..n {
                    b.push(TraceOp::Recv { ch });
                }
            });
        } else if matches!(s.input, StageInput::Memory { .. }) {
            let image_bytes = l.in_hw * l.in_hw * l.in_ch;
            let bytes = rg * l.stride * l.in_hw * l.in_ch;
            b.roi(RoiKind::InputLoad, |b| {
                b.push(TraceOp::MemStream {
                    base: addr::input(i, image_bytes) + g * bytes,
                    bytes,
                    write: false,
                    insts_per_line: 1,
                    prefetchable: true,
                });
            });
        }

        let this_rows = rg.min(out_hw - g * rg);
        let px = this_rows * out_hw;

        if let Some(block) = row_block {
            // Analog: software-pipelined per-pixel CM ops, one pre-built
            // block per output row.
            b.reserve(block.len() * this_rows as usize);
            for _ in 0..this_rows {
                b.extend_from_slice(block);
            }
        } else {
            lower::conv_digital_group(b, l, *weight_slot, px);
        }

        lower::conv_post_ops(b, l, px * l.out_ch);

        // ---- forward pooled rows to the next stage ------------------------
        if let Some(ch) = out_ch_id {
            let bytes = (this_rows.div_ceil(l.pool.max(1)) * out_row_bytes / rg.max(1)).max(64);
            b.roi(RoiKind::Communication, |b| {
                b.push(TraceOp::Send { ch, bytes, addr: addr::channel(ch, i.wrapping_add(g as u32)) });
            });
        }
    };

    // Encode the row loop as a `Rep` over *pairs* of groups: the
    // forward Send's ping-pong slot keys on `(i + g) % 2`, so single
    // groups are not iteration-affine but group pairs are. The ragged
    // tail — the odd group, plus the short last group when `rg` does
    // not divide `out_hw` — unrolls flat after the loop. Non-affine
    // shapes (e.g. non-uniform per-group receive counts) fall back to
    // a flat unroll inside `repeat`, bit-identical either way.
    let full = if out_hw % rg == 0 { row_groups } else { row_groups.saturating_sub(1) };
    match u32::try_from(full / 2) {
        Ok(rep_pairs) => {
            b.repeat(rep_pairs, |b, k| {
                emit_group(b, 2 * u64::from(k));
                emit_group(b, 2 * u64::from(k) + 1);
            });
            for g in u64::from(rep_pairs) * 2..row_groups {
                emit_group(b, g);
            }
        }
        // A pair count past u32 (no realizable conv shape gets close)
        // cannot ride a `Rep`; emit every group flat.
        Err(_) => {
            for g in 0..row_groups {
                emit_group(b, g);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Validation
// ---------------------------------------------------------------------------

fn err(msg: String) -> WorkloadError {
    WorkloadError::InvalidMapping(msg)
}

/// Validate a `(LayerGraph, Mapping)` pair without emitting traces —
/// the same checks `compile` runs first (topology, placement bounds and
/// overlap, tile I/O capacity, layer coverage, dataflow order).
pub fn validate(graph: &LayerGraph, mapping: &Mapping) -> Result<(), WorkloadError> {
    if mapping.stages.is_empty() {
        return Err(err("mapping has no stages".into()));
    }
    let mut seen_cores = std::collections::HashSet::new();
    // Per-tile claimed regions, for bounds + overlap checking, plus the
    // single core allowed to drive each tile (tiles are core-private:
    // the device serializes its I/O port and pairs CM_PROCESS results
    // with CM_DEQUEUEs in FIFO order, so two cores interleaving on one
    // tile would cross-match results).
    let mut claims: Vec<Vec<crate::sim::aimc::Placement>> = vec![Vec::new(); mapping.tiles.len()];
    let mut owners: Vec<Option<usize>> = vec![None; mapping.tiles.len()];

    for (idx, s) in mapping.stages.iter().enumerate() {
        let last = idx + 1 == mapping.stages.len();
        if s.cores.is_empty() {
            return Err(err(format!("stage {idx} has no cores")));
        }
        for &c in &s.cores {
            if !seen_cores.insert(c) {
                return Err(err(format!("core {c} assigned to more than one stage")));
            }
        }
        match s.split {
            SplitKind::Single if s.cores.len() != 1 => {
                return Err(err(format!("stage {idx}: Single split with {} cores", s.cores.len())));
            }
            SplitKind::Columns | SplitKind::LeaderGather if s.cores.len() < 2 => {
                return Err(err(format!("stage {idx}: split stages need >= 2 cores")));
            }
            _ => {}
        }
        if s.split == SplitKind::LeaderGather {
            if !matches!(s.output, StageOutput::Channel { .. }) {
                return Err(err(format!("stage {idx}: LeaderGather must feed a channel")));
            }
            if s.handoff != Handoff::PingPong {
                return Err(err(format!("stage {idx}: LeaderGather supports PingPong hand-off only")));
            }
        }

        // Boundary structure (per-stage shape; the producer/consumer
        // cross-wiring is checked globally after this loop).
        match &s.input {
            StageInput::Channel => {
                if idx == 0 {
                    return Err(err("stage 0 cannot receive from a channel".into()));
                }
            }
            StageInput::Join { mem, from } => {
                if from.is_empty() {
                    return Err(err(format!("stage {idx}: join with no producer stages")));
                }
                if !from.windows(2).all(|w| w[0] < w[1]) {
                    return Err(err(format!("stage {idx}: join producers must be strictly ascending")));
                }
                if *from.last().expect("non-empty") >= idx {
                    return Err(err(format!("stage {idx}: join producers must precede the stage")));
                }
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: join stages are single-replica")));
                }
                if let Some(node) = mem {
                    let Some(n) = graph.node(*node) else {
                        return Err(err(format!("stage {idx}: join input node {node} not in graph")));
                    };
                    if !matches!(n.kind, LayerKind::Input { .. }) {
                        return Err(err(format!("stage {idx}: join input node {node} is not an Input layer")));
                    }
                }
            }
            StageInput::Memory { node } => {
                let Some(n) = graph.node(*node) else {
                    return Err(err(format!("stage {idx}: input node {node} not in graph")));
                };
                if !matches!(n.kind, LayerKind::Input { .. }) {
                    return Err(err(format!("stage {idx}: input node {node} is not an Input layer")));
                }
            }
            StageInput::None => {}
        }
        match &s.output {
            StageOutput::Channel { .. } => {
                if last {
                    return Err(err("the last stage cannot send to a channel".into()));
                }
            }
            StageOutput::Fanout { to } => {
                if to.is_empty() {
                    return Err(err(format!("stage {idx}: fan-out with no consumer stages")));
                }
                if !to.windows(2).all(|w| w[0].0 < w[1].0) {
                    return Err(err(format!("stage {idx}: fan-out consumers must be strictly ascending")));
                }
                if to[0].0 <= idx {
                    return Err(err(format!("stage {idx}: fan-out consumers must follow the stage")));
                }
                if to.last().expect("non-empty").0 >= mapping.stages.len() {
                    return Err(err(format!("stage {idx}: fan-out names a missing stage")));
                }
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: fan-out stages are single-replica")));
                }
            }
            StageOutput::Memory { node } => {
                let Some(n) = graph.node(*node) else {
                    return Err(err(format!("stage {idx}: output node {node} not in graph")));
                };
                if !matches!(n.kind, LayerKind::Output { .. }) {
                    return Err(err(format!("stage {idx}: output node {node} is not an Output layer")));
                }
            }
            StageOutput::None => {}
        }

        // Row-streamed stage shape.
        if let Some(rg) = s.row_group {
            if rg == 0 {
                return Err(err(format!("stage {idx}: row group must be >= 1")));
            }
            if s.cores.len() != 1 {
                return Err(err(format!("stage {idx}: row-streamed stages are single-core")));
            }
            if s.steps.len() != 1 {
                return Err(err(format!("stage {idx}: row-streamed stages run exactly one Conv2d step")));
            }
            match graph.node(s.steps[0].node) {
                Some(n) if matches!(n.kind, LayerKind::Conv2d { .. }) => {}
                _ => {
                    return Err(err(format!(
                        "stage {idx}: row-streamed stages run a Conv2d step (node {})",
                        s.steps[0].node
                    )));
                }
            }
            if s.barrier {
                return Err(err(format!("stage {idx}: barriers on row-streamed stages are unsupported")));
            }
            if matches!(s.output, StageOutput::Memory { .. }) {
                return Err(err(format!(
                    "stage {idx}: row-streamed stages cannot write back to memory (feed a per-inference consumer stage instead)"
                )));
            }
            if s.handoff != Handoff::PingPong {
                return Err(err(format!("stage {idx}: row-streamed stages support PingPong only")));
            }
            // The row loop is a single-chain hand-off: DAG joins and
            // fan-outs compile through per-inference conv stages instead.
            if matches!(s.input, StageInput::Join { .. }) {
                return Err(err(format!("stage {idx}: row-streamed stages take a chain input, not a join")));
            }
            if matches!(s.output, StageOutput::Fanout { .. }) {
                return Err(err(format!("stage {idx}: row-streamed stages feed one chain consumer, not a fan-out")));
            }
            if s.input == StageInput::Channel {
                let prev = &mapping.stages[idx - 1];
                if prev.handoff != Handoff::PingPong {
                    return Err(err(format!("stage {idx}: row-streamed consumers need a PingPong producer")));
                }
                if !matches!(prev.output, StageOutput::Channel { .. }) {
                    return Err(err(format!("stage {idx}: row-streamed consumers need a single chain producer")));
                }
                // The row loop receives on exactly one channel.
                if prev.cores.len() != 1 && prev.split != SplitKind::LeaderGather {
                    return Err(err(format!("stage {idx}: row-streamed consumers need a single producer endpoint")));
                }
            }
            // The row loop sends on exactly one channel.
            if matches!(s.output, StageOutput::Channel { .. })
                && mapping.stages[idx + 1].cores.len() != 1
            {
                return Err(err(format!("stage {idx}: row-streamed producers need a single consumer core")));
            }
        }
        validate_steps(graph, mapping, idx, s, &mut claims, &mut owners)?;
    }
    // Boundary cross-check: every declared edge must be mirrored on both
    // endpoints — producers name consumers (out-edges) and consumers
    // name producers (in-edges), whichever I/O variant declares it.
    for (idx, s) in mapping.stages.iter().enumerate() {
        for (t, _) in out_edges(&s.output, idx) {
            if !in_stages(&mapping.stages[t].input, t).contains(&idx) {
                return Err(err(format!(
                    "stage {idx} sends to stage {t} but stage {t} does not receive from it"
                )));
            }
        }
        for p in in_stages(&s.input, idx) {
            if out_edges(&mapping.stages[p].output, p).iter().all(|&(t, _)| t != idx) {
                return Err(err(format!(
                    "stage {idx} expects input from stage {p} but stage {p} does not send to it"
                )));
            }
        }
    }
    validate_coverage(graph, mapping)?;
    Ok(())
}

/// Every compute layer must be mapped by exactly one step, and the
/// mapping's global (stage-major) step order must respect the graph's
/// dataflow edges.
fn validate_coverage(graph: &LayerGraph, mapping: &Mapping) -> Result<(), WorkloadError> {
    let mut pos: Vec<Option<(usize, usize)>> = vec![None; graph.nodes.len()];
    for (sidx, s) in mapping.stages.iter().enumerate() {
        for (stepi, step) in s.steps.iter().enumerate() {
            // Out-of-range ids were already rejected by validate_steps.
            if pos[step.node].is_some() {
                return Err(err(format!("node {} is mapped by more than one step", step.node)));
            }
            pos[step.node] = Some((sidx, stepi));
        }
    }
    for node in &graph.nodes {
        let compute = !matches!(node.kind, LayerKind::Input { .. } | LayerKind::Output { .. });
        if compute && pos[node.id].is_none() {
            return Err(err(format!("compute node {} is not mapped by any stage", node.id)));
        }
    }
    for &(a, b) in &graph.edges {
        if let (Some(&Some(pa)), Some(&Some(pb))) = (pos.get(a), pos.get(b)) {
            if pa >= pb {
                return Err(err(format!(
                    "mapping violates dataflow: node {a} must execute before node {b}"
                )));
            }
        }
    }
    Ok(())
}

fn validate_steps(
    graph: &LayerGraph,
    mapping: &Mapping,
    idx: usize,
    s: &Stage,
    claims: &mut [Vec<crate::sim::aimc::Placement>],
    owners: &mut [Option<usize>],
) -> Result<(), WorkloadError> {
    let mut after_chain = false;
    for (si, step) in s.steps.iter().enumerate() {
        let Some(node) = graph.node(step.node) else {
            return Err(err(format!("stage {idx}: step node {} not in graph", step.node)));
        };
        // Node kind / stage kind compatibility.
        match &node.kind {
            LayerKind::Input { .. } | LayerKind::Output { .. } => {
                return Err(err(format!("stage {idx}: node {} (input/output) cannot be a step", step.node)));
            }
            LayerKind::Conv2d { .. } => {
                if !matches!(step.place, Place::Cpu | Place::Tile { .. }) {
                    return Err(err(format!("stage {idx}: Conv2d supports Cpu or Tile placement")));
                }
                // Outside a row-streamed stage the conv lowers whole-map
                // per inference (DAG branches) on a single replica.
                if s.row_group.is_none() && s.cores.len() != 1 {
                    return Err(err(format!(
                        "stage {idx}: per-inference Conv2d stages are single-core (node {})",
                        step.node
                    )));
                }
            }
            LayerKind::LstmCell { .. } => {
                if !matches!(step.place, Place::Cpu | Place::Tile { .. }) {
                    return Err(err(format!("stage {idx}: LstmCell supports Cpu or Tile placement")));
                }
            }
            LayerKind::Activation { .. }
            | LayerKind::Pool { .. }
            | LayerKind::Elementwise { .. }
            | LayerKind::LayerNorm { .. } => {
                if !matches!(step.place, Place::Cpu | Place::Fused) {
                    return Err(err(format!("stage {idx}: elementwise layers run on Cpu (or Fused)")));
                }
            }
            LayerKind::Merge { .. } => {
                if !matches!(step.place, Place::Cpu) {
                    return Err(err(format!("stage {idx}: Merge nodes run on Cpu")));
                }
            }
            LayerKind::AttnHead { .. } => {
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: attention-head steps need a single-replica stage")));
                }
                if !matches!(step.place, Place::Cpu) {
                    return Err(err(format!("stage {idx}: AttnHead runs on Cpu")));
                }
            }
            LayerKind::MoE { cols, .. } => {
                if !matches!(step.place, Place::Cpu | Place::Tile { .. }) {
                    return Err(err(format!("stage {idx}: MoE supports Cpu or Tile placement")));
                }
                if cols % s.parts() != 0 {
                    return Err(err(format!(
                        "stage {idx}: MoE expert width {cols} not divisible by {} replicas",
                        s.cores.len()
                    )));
                }
            }
            LayerKind::Attention { d_model, heads, .. } => {
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: attention steps need a single-replica stage")));
                }
                if *heads == 0 || d_model % heads != 0 {
                    return Err(err(format!("stage {idx}: attention heads must divide d_model")));
                }
                if !matches!(step.place, Place::Cpu | Place::AttentionTiles { .. }) {
                    return Err(err(format!("stage {idx}: attention supports Cpu or AttentionTiles placement")));
                }
            }
            LayerKind::Dense { .. } => {}
        }
        // Fused steps must ride a preceding chain.
        match &step.place {
            Place::TileChain { .. } => after_chain = true,
            Place::Fused => {
                if !after_chain {
                    return Err(err(format!("stage {idx}: Fused step {} has no preceding TileChain", step.node)));
                }
            }
            _ => after_chain = false,
        }
        // Engine shape checks + tile bookkeeping. A MoE tile region
        // holds every expert's column slice side by side, so its
        // effective MVM width is `experts * cols`.
        let parts = s.parts();
        let (rows, cols) = match &node.kind {
            LayerKind::MoE { rows, cols, experts, .. } => (Some(*rows), Some(experts * cols)),
            _ => (node.kind.mvm_rows(), node.kind.mvm_cols()),
        };
        match &step.place {
            Place::Cpu | Place::Fused => {}
            Place::Tile { per_replica } => {
                if per_replica.len() != s.cores.len() {
                    return Err(err(format!(
                        "stage {idx}: Tile placement count {} != replica count {}",
                        per_replica.len(),
                        s.cores.len()
                    )));
                }
                let (Some(rows), Some(cols)) = (rows, cols) else {
                    return Err(err(format!("stage {idx}: node {} has no MVM to place on a tile", step.node)));
                };
                for (ri, tp) in per_replica.iter().enumerate() {
                    claim_tile(mapping, claims, owners, s.cores[ri], idx, tp, rows, cols / parts)?;
                }
            }
            Place::TileRowSplit { tiles } => {
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: TileRowSplit requires a single-core stage")));
                }
                if tiles.is_empty() {
                    return Err(err(format!("stage {idx}: TileRowSplit needs >= 1 tile")));
                }
                if !matches!(node.kind, LayerKind::Dense { .. }) {
                    return Err(err(format!("stage {idx}: TileRowSplit supports Dense layers")));
                }
                let (rows, cols) = (rows.unwrap_or(0), cols.unwrap_or(0));
                let k = tiles.len() as u64;
                for tp in tiles {
                    claim_tile(mapping, claims, owners, s.cores[0], idx, tp, rows / k, cols)?;
                }
            }
            Place::AttentionTiles { q, k, v, o } => {
                let LayerKind::Attention { d_model, .. } = node.kind else {
                    return Err(err(format!(
                        "stage {idx}: AttentionTiles placement on non-attention node {}",
                        step.node
                    )));
                };
                if d_model > u32::MAX as u64 {
                    return Err(err(format!("stage {idx}: d_model exceeds the u32 tile axis")));
                }
                for tp in [q, k, v, o] {
                    let p = tp.placement;
                    if u64::from(p.rows) != d_model || u64::from(p.cols) != d_model {
                        return Err(err(format!(
                            "stage {idx}: attention projection region {p:?} is not {d_model}x{d_model}"
                        )));
                    }
                    claim_tile(mapping, claims, owners, s.cores[0], idx, tp, d_model, d_model)?;
                }
            }
            Place::TileChain { tiles } => {
                if s.cores.len() != 1 {
                    return Err(err(format!("stage {idx}: TileChain requires a single-core stage")));
                }
                if tiles.is_empty() {
                    return Err(err(format!("stage {idx}: TileChain needs >= 1 tile")));
                }
                if !matches!(node.kind, LayerKind::Dense { .. }) {
                    return Err(err(format!("stage {idx}: TileChain starts at a Dense layer")));
                }
                // Mirror the emission: the chain queues the head layer's
                // rows into the first tile and dequeues the fused run's
                // final MVM width from the last tile.
                let mut chain_cols = cols;
                for follow in &s.steps[si + 1..] {
                    if !matches!(follow.place, Place::Fused) {
                        break;
                    }
                    if let Some(c) = graph.node(follow.node).and_then(|n| n.kind.mvm_cols()) {
                        chain_cols = Some(c);
                    }
                }
                let rows = rows.unwrap_or(0);
                let chain_cols = chain_cols.unwrap_or(0);
                let last = tiles.len() - 1;
                for (ti, tp) in tiles.iter().enumerate() {
                    let q = if ti == 0 { rows } else { 0 };
                    let d = if ti == last { chain_cols } else { 0 };
                    claim_tile(mapping, claims, owners, s.cores[0], idx, tp, q, d)?;
                }
            }
        }
    }
    Ok(())
}

/// Record a tile claim and check bounds: placement inside the tile,
/// no overlap with earlier claims, queue/dequeue within I/O memory,
/// and single-core ownership (tiles are core-private).
#[allow(clippy::too_many_arguments)]
fn claim_tile(
    mapping: &Mapping,
    claims: &mut [Vec<crate::sim::aimc::Placement>],
    owners: &mut [Option<usize>],
    core: usize,
    idx: usize,
    tp: &mapping::TilePlacement,
    queue_elems: u64,
    dequeue_elems: u64,
) -> Result<(), WorkloadError> {
    let Some(tile) = mapping.tiles.get(tp.tile) else {
        return Err(err(format!("stage {idx}: tile {} not declared", tp.tile)));
    };
    match owners[tp.tile] {
        Some(owner) if owner != core => {
            return Err(err(format!(
                "stage {idx}: tile {} is driven by core {owner} and core {core} (tiles are core-private)",
                tp.tile
            )));
        }
        _ => owners[tp.tile] = Some(core),
    }
    let p = tp.placement;
    if u64::from(p.row0) + u64::from(p.rows) > u64::from(tile.rows)
        || u64::from(p.col0) + u64::from(p.cols) > u64::from(tile.cols)
    {
        return Err(err(format!(
            "stage {idx}: placement {p:?} exceeds tile {} ({}x{})",
            tp.tile, tile.rows, tile.cols
        )));
    }
    if queue_elems > u64::from(tile.rows) {
        return Err(err(format!(
            "stage {idx}: queue of {queue_elems} B exceeds tile {} input memory ({} B)",
            tp.tile, tile.rows
        )));
    }
    if dequeue_elems > u64::from(tile.cols) {
        return Err(err(format!(
            "stage {idx}: dequeue of {dequeue_elems} B exceeds tile {} output memory ({} B)",
            tp.tile, tile.cols
        )));
    }
    for prior in &claims[tp.tile] {
        if prior.overlaps(&p) {
            return Err(err(format!(
                "stage {idx}: placement {p:?} overlaps an earlier region on tile {}",
                tp.tile
            )));
        }
    }
    claims[tp.tile].push(p);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::mapping::*;
    use super::*;
    use crate::nn::LayerGraph;
    use crate::sim::aimc::{Coupling, Placement};
    use crate::sim::machine::TileSpec;

    fn two_stage_digital() -> (LayerGraph, Mapping) {
        let g = LayerGraph::mlp(&[64, 64, 64]);
        // nodes: 0 in, 1 dense0, 2 relu0, 3 dense1, 4 relu1, 5 out
        let mut s0 = Stage::on_core(0);
        s0.input = StageInput::Memory { node: 0 };
        s0.output = StageOutput::Channel { bytes: 4 * 64 };
        s0.steps = vec![Step::cpu(1), Step::cpu(2)];
        let mut s1 = Stage::on_core(1);
        s1.input = StageInput::Channel;
        s1.output = StageOutput::Memory { node: 5 };
        s1.steps = vec![Step::cpu(3), Step::cpu(4)];
        let m = Mapping {
            label: "test/dig2".into(),
            tiles: Vec::new(),
            min_mutexes: 0,
            stages: vec![s0, s1],
        };
        (g, m)
    }

    #[test]
    fn compiles_two_stage_pipeline() {
        let (g, m) = two_stage_digital();
        let w = compile(&g, &m, 3).unwrap();
        assert_eq!(w.traces.len(), 2);
        assert_eq!(w.spec.channels.len(), 1);
        assert_eq!(w.spec.channels[0].producer, 0);
        assert_eq!(w.spec.channels[0].consumer, 1);
        let sends = w.traces[0].iter_ops().filter(|op| matches!(op, TraceOp::Send { .. })).count();
        let recvs = w.traces[1].iter_ops().filter(|op| matches!(op, TraceOp::Recv { .. })).count();
        assert_eq!(sends, 3);
        assert_eq!(recvs, 3);
    }

    #[test]
    fn rejects_dangling_channel() {
        let (g, mut m) = two_stage_digital();
        m.stages[1].input = StageInput::None;
        assert!(compile(&g, &m, 1).is_err());
    }

    #[test]
    fn rejects_core_reuse() {
        let (g, mut m) = two_stage_digital();
        m.stages[1].cores = vec![0];
        assert!(compile(&g, &m, 1).is_err());
    }

    #[test]
    fn rejects_undeclared_tile() {
        let (g, mut m) = two_stage_digital();
        m.stages[0].steps[0] = Step::tile(1, 0, Placement { row0: 0, col0: 0, rows: 64, cols: 64 });
        assert!(compile(&g, &m, 1).is_err(), "no tiles declared");
        m.tiles = vec![TileSpec { rows: 64, cols: 64, coupling: Coupling::Tight }];
        assert!(compile(&g, &m, 1).is_ok());
    }

    #[test]
    fn rejects_overlapping_placements() {
        // Both dense layers packed on core 0's tile 0 (stage 1 keeps the
        // trailing relu so the pipeline shape stays intact).
        let (g, mut m) = two_stage_digital();
        m.tiles = vec![TileSpec { rows: 64, cols: 128, coupling: Coupling::Tight }];
        m.stages[0].steps = vec![
            Step::tile(1, 0, Placement { row0: 0, col0: 0, rows: 64, cols: 64 }),
            Step::cpu(2),
            Step::tile(3, 0, Placement { row0: 0, col0: 32, rows: 64, cols: 64 }),
        ];
        m.stages[1].steps = vec![Step::cpu(4)];
        assert!(compile(&g, &m, 1).is_err());
        m.stages[0].steps[2] = Step::tile(3, 0, Placement { row0: 0, col0: 64, rows: 64, cols: 64 });
        assert!(compile(&g, &m, 1).is_ok());
    }

    #[test]
    fn rejects_cross_core_tile_sharing() {
        // Disjoint regions, but stage 0 (core 0) and stage 1 (core 1)
        // would interleave on one device: tiles are core-private.
        let (g, mut m) = two_stage_digital();
        m.tiles = vec![TileSpec { rows: 64, cols: 128, coupling: Coupling::Tight }];
        m.stages[0].steps[0] = Step::tile(1, 0, Placement { row0: 0, col0: 0, rows: 64, cols: 64 });
        m.stages[1].steps[0] = Step::tile(3, 0, Placement { row0: 0, col0: 64, rows: 64, cols: 64 });
        assert!(compile(&g, &m, 1).is_err());
        // On its own tile the second stage is fine.
        m.tiles.push(TileSpec { rows: 64, cols: 64, coupling: Coupling::Tight });
        m.stages[1].steps[0] = Step::tile(3, 1, Placement { row0: 0, col0: 0, rows: 64, cols: 64 });
        assert!(compile(&g, &m, 1).is_ok());
    }

    #[test]
    fn barrier_mutexes_autonumber() {
        let (g, mut m) = two_stage_digital();
        m.stages[0].barrier = true;
        m.stages[1].barrier = true;
        let w = compile(&g, &m, 1).unwrap();
        assert_eq!(w.spec.mutexes, 2);
        assert!(w.traces[0].iter_ops().any(|op| matches!(op, TraceOp::MutexLock { id: 0 })));
        assert!(w.traces[1].iter_ops().any(|op| matches!(op, TraceOp::MutexLock { id: 1 })));
    }

    #[test]
    fn min_mutexes_respected() {
        let (g, mut m) = two_stage_digital();
        m.min_mutexes = 3;
        let w = compile(&g, &m, 1).unwrap();
        assert_eq!(w.spec.mutexes, 3);
    }

    #[test]
    fn rejects_unmapped_and_reordered_layers() {
        let (g, mut m) = two_stage_digital();
        m.stages[1].steps = vec![Step::cpu(4)]; // dense1 never mapped
        assert!(compile(&g, &m, 1).is_err());
        let (g, mut m) = two_stage_digital();
        m.stages[0].steps = vec![Step::cpu(2), Step::cpu(1)]; // relu before its dense
        assert!(compile(&g, &m, 1).is_err());
        let (g, mut m) = two_stage_digital();
        m.stages[1].steps = vec![Step::cpu(3), Step::cpu(4), Step::cpu(3)]; // double-mapped
        assert!(compile(&g, &m, 1).is_err());
    }

    #[test]
    fn compiles_attention_on_tiles_and_rejects_bad_regions() {
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        // nodes: 0 in, 1 ln, 2 attn, 3 res, 4 ln, 5 ff1, 6 relu, 7 ff2,
        // 8 res, 9 ln, 10 out
        let pl = |col0: u32| Placement { row0: 0, col0, rows: 64, cols: 64 };
        let att = Place::AttentionTiles {
            q: TilePlacement { tile: 0, placement: pl(0) },
            k: TilePlacement { tile: 0, placement: pl(64) },
            v: TilePlacement { tile: 0, placement: pl(128) },
            o: TilePlacement { tile: 0, placement: pl(192) },
        };
        let mut s = Stage::on_core(0);
        s.input = StageInput::Memory { node: 0 };
        s.output = StageOutput::Memory { node: 10 };
        s.steps = vec![Step::cpu(1), Step { node: 2, place: att }];
        s.steps.extend((3..=9).map(Step::cpu));
        let m = Mapping {
            label: "test/attn".into(),
            tiles: vec![TileSpec { rows: 64, cols: 256, coupling: Coupling::Tight }],
            min_mutexes: 0,
            stages: vec![s],
        };
        let w = compile(&g, &m, 2).unwrap();
        // Four projection MVMs fire per attention step per inference.
        let procs = w.traces[0].iter_ops().filter(|op| matches!(op, TraceOp::CmProcess { .. })).count();
        assert_eq!(procs, 4 * 2);

        // A projection region that is not d_model x d_model is rejected.
        let mut bad = m.clone();
        let Place::AttentionTiles { o, .. } = &mut bad.stages[0].steps[1].place else {
            unreachable!()
        };
        o.placement.cols = 32;
        assert!(compile(&g, &bad, 1).is_err());

        // Attention on a replicated stage is rejected.
        let mut split = m.clone();
        split.stages[0].cores = vec![0, 1];
        split.stages[0].split = SplitKind::Columns;
        assert!(compile(&g, &split, 1).is_err());
    }

    #[test]
    fn cached_materialize_compile_is_bit_identical() {
        // The attention mapping below aliases all four projection slots
        // on one tile — the hardest relocation case for the fragment
        // cache — and n_inf = 16 exercises the loop-encoding path with
        // cache hits across warm-up and sample pairs.
        let g = LayerGraph::transformer(64, 2, 16, 1, 128);
        let pl = |col0: u32| Placement { row0: 0, col0, rows: 64, cols: 64 };
        let att = Place::AttentionTiles {
            q: TilePlacement { tile: 0, placement: pl(0) },
            k: TilePlacement { tile: 0, placement: pl(64) },
            v: TilePlacement { tile: 0, placement: pl(128) },
            o: TilePlacement { tile: 0, placement: pl(192) },
        };
        let mut s = Stage::on_core(0);
        s.input = StageInput::Memory { node: 0 };
        s.output = StageOutput::Memory { node: 10 };
        s.steps = vec![Step::cpu(1), Step { node: 2, place: att }];
        s.steps.extend((3..=9).map(Step::cpu));
        let m = Mapping {
            label: "test/attn-cache".into(),
            tiles: vec![TileSpec { rows: 64, cols: 256, coupling: Coupling::Tight }],
            min_mutexes: 0,
            stages: vec![s],
        };
        for n_inf in [3, 16] {
            let cache = Mutex::new(CompileCache::new(true));
            let mut ctx = CacheCtx::materialize(&cache);
            let cached = compile_with(&g, &m, n_inf, Some(&mut ctx)).unwrap();
            let plain = compile(&g, &m, n_inf).unwrap();
            assert_eq!(cached.traces, plain.traces, "n_inf={n_inf}");
            let stats = cache.lock().unwrap().stats();
            assert!(stats.hits > 0, "repeat inferences must hit: {stats:?}");
        }
    }

    #[test]
    fn cached_compile_relocates_across_replicas() {
        // Two column-split replicas on distinct tiles: replica 1's MVM
        // must splice with its own tile id, not replica 0's.
        let g = LayerGraph::mlp(&[64, 64, 64]);
        let mut s0 = Stage::on_core(0);
        s0.cores = vec![0, 1];
        s0.split = SplitKind::Columns;
        s0.input = StageInput::Memory { node: 0 };
        s0.output = StageOutput::Channel { bytes: 4 * 64 };
        s0.steps = vec![
            Step {
                node: 1,
                place: Place::Tile {
                    per_replica: vec![
                        TilePlacement {
                            tile: 0,
                            placement: Placement { row0: 0, col0: 0, rows: 64, cols: 32 },
                        },
                        TilePlacement {
                            tile: 1,
                            placement: Placement { row0: 0, col0: 0, rows: 64, cols: 32 },
                        },
                    ],
                },
            },
            Step::cpu(2),
        ];
        let mut s1 = Stage::on_core(2);
        s1.input = StageInput::Channel;
        s1.output = StageOutput::Memory { node: 5 };
        s1.steps = vec![Step::cpu(3), Step::cpu(4)];
        let m = Mapping {
            label: "test/replica-cache".into(),
            tiles: vec![
                TileSpec { rows: 64, cols: 32, coupling: Coupling::Tight },
                TileSpec { rows: 64, cols: 32, coupling: Coupling::Tight },
            ],
            min_mutexes: 0,
            stages: vec![s0, s1],
        };
        let cache = Mutex::new(CompileCache::new(true));
        let mut ctx = CacheCtx::materialize(&cache);
        let cached = compile_with(&g, &m, 4, Some(&mut ctx)).unwrap();
        let plain = compile(&g, &m, 4).unwrap();
        assert_eq!(cached.traces, plain.traces);
        assert!(cached.traces[1].iter_ops().any(|op| matches!(op, TraceOp::CmProcess { tile: 1 })));
    }

    #[test]
    fn shared_buffer_adds_ack_channels() {
        let (g, mut m) = two_stage_digital();
        m.stages[0].handoff = Handoff::SharedBuffer;
        let w = compile(&g, &m, 2).unwrap();
        assert_eq!(w.spec.channels.len(), 2);
        assert_eq!(w.spec.channels[1].producer, 1);
        assert_eq!(w.spec.channels[1].consumer, 0);
        // Producer acks only from inference 1 on; consumer acks every one.
        let prod_recvs = w.traces[0].iter_ops().filter(|op| matches!(op, TraceOp::Recv { ch: 1 })).count();
        let cons_sends = w.traces[1].iter_ops().filter(|op| matches!(op, TraceOp::Send { ch: 1, .. })).count();
        assert_eq!(prod_recvs, 1);
        assert_eq!(cons_sends, 2);
    }
}
