//! The mapping schema: *where and how* each layer of a [`LayerGraph`]
//! executes.
//!
//! A [`Mapping`] is a DAG of pipeline [`Stage`]s, declared as a list in
//! dataflow (topological) order. Each stage owns one or more cores
//! (replicas), executes an ordered list of layer [`Step`]s, and connects
//! to its producers/consumers through channel boundaries — the classic
//! linear chain (stage `i` feeds `i + 1` via `StageInput::Channel` /
//! `StageOutput::Channel`) plus true fork/join dataflow via
//! [`StageOutput::Fanout`] and [`StageInput::Join`].
//! The compiler (`workload::compile::compile`) derives everything else —
//! channel topology and numbering, mutex ids, CM_INITIALIZE preambles,
//! per-core trace emission — from this declaration.
//!
//! [`LayerGraph`]: crate::nn::LayerGraph

use crate::nn::NodeId;
use crate::sim::aimc::Placement;
use crate::sim::machine::TileSpec;

/// Full placement declaration for one workload.
#[derive(Clone, Debug)]
pub struct Mapping {
    /// Workload label carried into the generated `Workload`.
    pub label: String,
    /// The AIMC tiles of the platform (indexed by `TilePlacement::tile`).
    pub tiles: Vec<TileSpec>,
    /// Lower bound on the declared mutex count. Barrier mutexes are
    /// auto-numbered on top; this exists because the paper's quin-core
    /// LSTM platform declares one (unused) mutex in its `MachineSpec`.
    pub min_mutexes: usize,
    /// Pipeline stages in dataflow (topological) order. With the legacy
    /// `Channel` I/O variants stage `i` feeds stage `i + 1`; `Fanout` /
    /// `Join` stages name their consumers/producers explicitly.
    pub stages: Vec<Stage>,
}

/// How a replicated stage divides its work (ignored for 1 replica).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitKind {
    /// Single core, no replication.
    Single,
    /// Column-parallel: each replica computes `1/parts` of every MVM's
    /// output columns (weight slice per replica) and communicates its
    /// slice to every consumer replica (Fig. 6b cases: DIG-4core, ANA-4).
    Columns,
    /// Column-parallel with a leader: replica 0 additionally gathers the
    /// partial outputs, re-broadcasts the assembled vector to the other
    /// replicas (recurrence) and alone feeds the next stage (the paper's
    /// quin-core LSTM, §VIII).
    LeaderGather,
}

/// Hand-off policy of the boundary *after* a stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Handoff {
    /// Plain bounded ping-pong channel(s).
    PingPong,
    /// Mutex-style shared activation buffer: the producer must not
    /// overwrite until the consumer acknowledges the previous inference
    /// (§VII.C); compiled as forward channels plus reverse ack channels.
    SharedBuffer,
}

/// Where a stage's per-inference input comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StageInput {
    /// No explicit input phase.
    None,
    /// Load the graph's `Input` node from memory.
    Memory { node: NodeId },
    /// Receive from the previous stage's boundary channels.
    Channel,
    /// DAG join: receive from every producer stage in `from` (ascending
    /// stage indices; each producer's replicas are received p-major),
    /// optionally preceded by a memory load of the graph's `Input` node
    /// (`mem`) when a residual branch taps the input directly.
    Join { mem: Option<NodeId>, from: Vec<usize> },
}

/// Where a stage's per-inference result goes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StageOutput {
    /// No explicit output phase.
    None,
    /// Write the graph's `Output` node back to memory.
    Memory { node: NodeId },
    /// Send to the next stage. `bytes` is the payload per forward
    /// message (a replica's slice under `Columns`; the assembled vector
    /// under `LeaderGather`, whose gather messages carry `bytes/parts`).
    /// Ignored (derived from the conv geometry) for row-streamed stages.
    Channel { bytes: u64 },
    /// DAG fan-out: send to every consumer stage in `to` (ascending
    /// stage indices) with the given payload bytes per forward message.
    /// `Channel { bytes }` is exactly `Fanout { to: vec![(idx + 1,
    /// bytes)] }`; the distinct variant keeps legacy chain mappings
    /// byte-stable.
    Fanout { to: Vec<(usize, u64)> },
}

/// One pipeline stage.
#[derive(Clone, Debug)]
pub struct Stage {
    /// Core id per replica (length 1 = no replication).
    pub cores: Vec<usize>,
    pub split: SplitKind,
    pub input: StageInput,
    pub output: StageOutput,
    /// Policy of this stage's *outgoing* boundary.
    pub handoff: Handoff,
    /// Bracket the stage with a mutex lock/unlock (auto-numbered).
    pub barrier: bool,
    /// `Some(rows)`: row-streamed execution (the CNN pipeline, §IX) —
    /// the stage's single Conv2d step runs `rows` output rows at a time,
    /// receiving/forwarding per row group instead of per inference.
    pub row_group: Option<u64>,
    /// Layer steps in execution order.
    pub steps: Vec<Step>,
}

impl Stage {
    /// A single-core per-inference stage with defaults.
    pub fn on_core(core: usize) -> Stage {
        Stage {
            cores: vec![core],
            split: SplitKind::Single,
            input: StageInput::None,
            output: StageOutput::None,
            handoff: Handoff::PingPong,
            barrier: false,
            row_group: None,
            steps: Vec::new(),
        }
    }

    pub fn parts(&self) -> u64 {
        self.cores.len() as u64
    }
}

/// One layer executed by a stage.
#[derive(Clone, Debug)]
pub struct Step {
    pub node: NodeId,
    pub place: Place,
}

impl Step {
    pub fn cpu(node: NodeId) -> Step {
        Step { node, place: Place::Cpu }
    }

    pub fn tile(node: NodeId, tile: usize, placement: Placement) -> Step {
        Step { node, place: Place::Tile { per_replica: vec![TilePlacement { tile, placement }] } }
    }
}

/// A tile region claimed by one layer (replica).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilePlacement {
    pub tile: usize,
    pub placement: Placement,
}

/// Execution engine of one step.
#[derive(Clone, Debug)]
pub enum Place {
    /// Digital lowering on the stage's core(s) (SIMD GEMV / blocked GEMM
    /// / vectorized elementwise).
    Cpu,
    /// AIMC MVM, one tile region per replica (`per_replica.len()` must
    /// equal the stage's replica count).
    Tile { per_replica: Vec<TilePlacement> },
    /// AIMC MVM row-split across tiles on one core, partial outputs
    /// accumulated digitally after dequeuing the last tile (Fig. 6b
    /// case 2).
    TileRowSplit { tiles: Vec<TilePlacement> },
    /// Loosely-coupled fused accelerator chain: queue into the first
    /// tile, fire every tile, dequeue from the last; the layers between
    /// (marked [`Place::Fused`]) execute inside the accelerator (§VII.B).
    TileChain { tiles: Vec<TilePlacement> },
    /// Multi-head attention on AIMC: the four `d_model x d_model`
    /// projection regions (Wq, Wk, Wv, Wo) each get their own
    /// queue/process/dequeue; the score/softmax/context block between
    /// the V and O projections always lowers digitally (the K/V caches
    /// change every token and cannot live on a PCM crossbar).
    /// Single-replica stages only.
    AttentionTiles { q: TilePlacement, k: TilePlacement, v: TilePlacement, o: TilePlacement },
    /// Executed by the preceding `TileChain` (dedicated in-accelerator
    /// units); emits no ops.
    Fused,
}
