//! Cross-candidate compile cache: keyed `emit_step` fragments in a
//! slab arena.
//!
//! One `CostModel::Compiled` search (and the top-K compile pass after
//! it) lowers the *same* anchor steps thousands of times — candidates
//! differ in pipeline cuts, replication, and hand-off, but a step's
//! emitted ops depend only on its graph node, its engine shape, the
//! replica index, and the stage replication. This module keys exactly
//! that tuple ([`FragKey`]) and stores each fragment once in an
//! arena-allocated `Vec<TraceOp>` slab with range handles, so repeat
//! lowerings splice a stored fragment (a memcpy plus tile-id
//! relocation) instead of re-running the lowering rules — the
//! compositional engine's per-anchor-profile trick generalized to the
//! simulator path.
//!
//! **Tile-id relocation.** Fragment ops are stored with tile fields
//! *abstracted to slot indices* — the position of the tile in the
//! placement's first-use order ([`tile_slots`]). A splice substitutes
//! the target placement's slot table. Placements that alias one tile
//! across slots carry their alias pattern in the key, so a stored
//! fragment is only reused for placements that alias identically
//! (which makes first-match slot abstraction exact).
//!
//! **Equivalence.** A cached splice is bit-identical to a fresh
//! `emit_step` by construction (the key covers every input the
//! lowering reads); debug builds re-emit every hit and assert it.
//! The fragment-grouped cost walk in `automap::cost` additionally
//! memoizes one [`Profile`] per fragment, so cache-on and cache-off
//! oracle scores group their f64 sums identically and match bit for
//! bit (gated by `tests/automap.rs`).

use std::collections::HashMap;

use crate::sim::machine::TileSpec;
use crate::workload::automap::cost::Profile;
use crate::workload::compile::mapping::{Place, Step};
use crate::workload::trace::{TraceBuilder, TraceOp};

/// Engine fingerprint of a step placement: everything `emit_step`'s
/// output depends on *except* concrete tile ids (those are relocated on
/// splice via the slot table). Placement coordinates are irrelevant —
/// the lowering reads shapes from the graph node, not the region.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) enum PlaceFp {
    Cpu,
    Tile,
    RowSplit(usize),
    Attention,
}

/// Cache key of one lowered step: (anchor node, engine fingerprint,
/// replica index, stage replication, tile alias pattern). The graph is
/// fixed per cache, so the node id pins rows/cols/weight-slot; `r`
/// covers the replica-dependent CPU weight addressing; `parts` the
/// column-slice denominator; `alias` the slot-aliasing shape (see
/// module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct FragKey {
    node: usize,
    place: PlaceFp,
    r: usize,
    parts: u64,
    alias: u64,
}

impl FragKey {
    /// The key of a step lowering, or `None` when the step does not go
    /// through `emit_step` (chain heads and fused riders lower inline
    /// in `emit_replica`) or its slot table is too wide to encode.
    pub(crate) fn for_step(step: &Step, r: usize, parts: u64) -> Option<FragKey> {
        let place = match &step.place {
            Place::Cpu => PlaceFp::Cpu,
            Place::Tile { .. } => PlaceFp::Tile,
            Place::TileRowSplit { tiles } => PlaceFp::RowSplit(tiles.len()),
            Place::AttentionTiles { .. } => PlaceFp::Attention,
            Place::TileChain { .. } | Place::Fused => return None,
        };
        let alias = alias_pattern(&tile_slots(&step.place, r))?;
        Some(FragKey { node: step.node, place, r, parts, alias })
    }
}

/// The tiles a placement drives, in slot order — the relocation table
/// for spliced fragments.
pub(crate) fn tile_slots(place: &Place, r: usize) -> Vec<usize> {
    match place {
        Place::Cpu | Place::Fused => Vec::new(),
        Place::Tile { per_replica } => vec![per_replica[r].tile],
        Place::TileRowSplit { tiles } | Place::TileChain { tiles } => {
            tiles.iter().map(|tp| tp.tile).collect()
        }
        Place::AttentionTiles { q, k, v, o } => vec![q.tile, k.tile, v.tile, o.tile],
    }
}

/// Canonical alias pattern of a slot table, nibble-encoded: slot `i`
/// maps to the first slot holding the same tile id. Tables past 16
/// slots don't fit the encoding and are not cached (`None`).
fn alias_pattern(slots: &[usize]) -> Option<u64> {
    if slots.len() > 16 {
        return None;
    }
    let mut pat = 0u64;
    for (i, &t) in slots.iter().enumerate() {
        let first = slots.iter().position(|&u| u == t).expect("t is in slots") as u64;
        pat |= first << (4 * i);
    }
    Some(pat)
}

/// Running hit/miss/footprint counters, surfaced through
/// `SearchOutcome` and the `alpine automap` progress line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompileCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Bytes held by the fragment op slab.
    pub arena_bytes: u64,
}

/// One stored fragment: a slab range (ops with slot-abstracted tile
/// fields) plus the lazily memoized cost profile of those ops under a
/// concrete slot -> `TileSpec` resolution.
struct Fragment {
    ops: std::ops::Range<u32>,
    slots: u32,
    profile: Option<(Vec<TileSpec>, Profile)>,
}

/// The arena-backed fragment cache. Callers wrap it in a `Mutex` to
/// share across search worker threads; all methods take `&mut self`.
///
/// A *disabled* cache (`CompileCache::new(false)`) never registers or
/// serves keys, but still arenas every fragment so the fragment-grouped
/// cost walk runs the exact same code path — that is what makes
/// cache-on vs. cache-off scores bit-identical.
pub struct CompileCache {
    enabled: bool,
    slab: Vec<TraceOp>,
    frags: Vec<Fragment>,
    map: HashMap<FragKey, usize>,
    hits: u64,
    misses: u64,
}

impl CompileCache {
    pub fn new(enabled: bool) -> CompileCache {
        CompileCache {
            enabled,
            slab: Vec::new(),
            frags: Vec::new(),
            map: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn stats(&self) -> CompileCacheStats {
        CompileCacheStats {
            hits: self.hits,
            misses: self.misses,
            arena_bytes: (self.slab.len() * std::mem::size_of::<TraceOp>()) as u64,
        }
    }

    /// Serve a fragment id for `key`, counting a hit. Always misses on
    /// a disabled cache.
    pub(crate) fn lookup(&mut self, key: FragKey) -> Option<usize> {
        if !self.enabled {
            return None;
        }
        let fid = self.map.get(&key).copied();
        if fid.is_some() {
            self.hits += 1;
        }
        fid
    }

    /// Store a freshly emitted fragment (ops of one `emit_step` run
    /// whose placement resolved to `slots`), counting a miss. Returns
    /// the fragment id; under a lookup/insert race the earlier
    /// registration wins and its id is returned.
    pub(crate) fn insert(&mut self, key: FragKey, ops: &[TraceOp], slots: &[usize]) -> usize {
        self.misses += 1;
        if self.enabled {
            if let Some(&fid) = self.map.get(&key) {
                // Another worker registered the key between our lookup
                // and this insert; the stored ops are identical because
                // the key covers every lowering input.
                debug_assert!(self.matches(fid, ops, slots), "compile cache key collision on {key:?}");
                return fid;
            }
        }
        let start = u32::try_from(self.slab.len()).expect("fragment arena exceeds u32 ops");
        for &op in ops {
            debug_assert!(
                !matches!(
                    op,
                    TraceOp::Send { .. }
                        | TraceOp::Recv { .. }
                        | TraceOp::MutexLock { .. }
                        | TraceOp::MutexUnlock { .. }
                        | TraceOp::CmInit { .. }
                ),
                "step fragments are channel/mutex/preamble-free: {op:?}"
            );
            self.slab.push(abstract_op(op, slots));
        }
        let end = u32::try_from(self.slab.len()).expect("fragment arena exceeds u32 ops");
        let fid = self.frags.len();
        self.frags.push(Fragment { ops: start..end, slots: slots.len() as u32, profile: None });
        if self.enabled {
            self.map.insert(key, fid);
        }
        fid
    }

    /// Splice fragment `fid` into `b`, relocating slot indices through
    /// the target placement's `slots` table.
    pub(crate) fn splice(&self, fid: usize, slots: &[usize], b: &mut TraceBuilder) {
        let f = &self.frags[fid];
        debug_assert_eq!(f.slots as usize, slots.len(), "slot table shape drift");
        b.reserve(f.ops.len());
        for &op in &self.slab[f.ops.start as usize..f.ops.end as usize] {
            b.push(concrete_op(op, slots));
        }
    }

    /// The memoized cost profile of fragment `fid` under the given
    /// slot -> spec resolution, computing (and storing) it on first use.
    /// `walk` folds slot-abstracted ops with a spec table indexed by
    /// slot — identical math whether the profile is fresh or reused.
    pub(crate) fn profile_for(
        &mut self,
        fid: usize,
        specs: &[TileSpec],
        walk: impl FnOnce(&[TraceOp], &[TileSpec]) -> Profile,
    ) -> Profile {
        let range = self.frags[fid].ops.start as usize..self.frags[fid].ops.end as usize;
        if let Some((memo_specs, p)) = &self.frags[fid].profile {
            if memo_specs == specs {
                return *p;
            }
            // Same fragment under differently-shaped tiles (not produced
            // by automap searches, where every tile is budget-dim): walk
            // fresh without disturbing the memo.
            return walk(&self.slab[range], specs);
        }
        let p = walk(&self.slab[self.frags[fid].ops.start as usize..self.frags[fid].ops.end as usize], specs);
        self.frags[fid].profile = Some((specs.to_vec(), p));
        p
    }

    /// Debug oracle: does the stored fragment match `ops` under `slots`?
    /// (Referenced from `debug_assert!` conditions, which type-check in
    /// release builds too, so this stays unconditionally compiled.)
    pub(crate) fn matches(&self, fid: usize, ops: &[TraceOp], slots: &[usize]) -> bool {
        let f = &self.frags[fid];
        let stored = &self.slab[f.ops.start as usize..f.ops.end as usize];
        stored.len() == ops.len()
            && stored.iter().zip(ops).all(|(&s, &o)| concrete_op(s, slots) == o)
    }
}

/// Replace concrete tile ids with their slot index (first match — exact
/// because aliasing placements carry their pattern in the key).
fn abstract_op(op: TraceOp, slots: &[usize]) -> TraceOp {
    map_tile(op, |tile| {
        slots.iter().position(|&t| t == tile).expect("fragment op drives an unplaced tile")
    })
}

/// Resolve slot indices back to the target placement's tile ids.
fn concrete_op(op: TraceOp, slots: &[usize]) -> TraceOp {
    map_tile(op, |slot| slots[slot])
}

fn map_tile(op: TraceOp, f: impl Fn(usize) -> usize) -> TraceOp {
    match op {
        TraceOp::CmQueue { tile, bytes } => TraceOp::CmQueue { tile: f(tile), bytes },
        TraceOp::CmProcess { tile } => TraceOp::CmProcess { tile: f(tile) },
        TraceOp::CmDequeue { tile, bytes } => TraceOp::CmDequeue { tile: f(tile), bytes },
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::InstClass;

    #[test]
    fn alias_pattern_distinguishes_sharing_shapes() {
        assert_eq!(alias_pattern(&[]), Some(0));
        assert_eq!(alias_pattern(&[7]), Some(0));
        // Distinct tiles: identity pattern.
        assert_eq!(alias_pattern(&[3, 5, 9]), Some(0x210));
        // All four slots on one tile vs. two pairs.
        assert_eq!(alias_pattern(&[2, 2, 2, 2]), Some(0));
        assert_eq!(alias_pattern(&[2, 2, 4, 4]), Some(0x2200));
        // Same pattern for different concrete ids.
        assert_eq!(alias_pattern(&[8, 8, 1, 1]), alias_pattern(&[2, 2, 4, 4]));
        assert!(alias_pattern(&vec![0usize; 17]).is_none());
    }

    #[test]
    fn splice_relocates_tiles_and_preserves_everything_else() {
        let mut c = CompileCache::new(true);
        let ops = [
            TraceOp::CmQueue { tile: 6, bytes: 128 },
            TraceOp::Compute { class: InstClass::SimdOp, insts: 40 },
            TraceOp::CmProcess { tile: 6 },
            TraceOp::CmDequeue { tile: 9, bytes: 64 },
        ];
        let key = FragKey { node: 1, place: PlaceFp::RowSplit(2), r: 0, parts: 1, alias: 0x10 };
        let fid = c.insert(key, &ops, &[6, 9]);
        let mut b = TraceBuilder::new();
        c.splice(fid, &[3, 0], &mut b);
        assert_eq!(
            b.ops,
            vec![
                TraceOp::CmQueue { tile: 3, bytes: 128 },
                TraceOp::Compute { class: InstClass::SimdOp, insts: 40 },
                TraceOp::CmProcess { tile: 3 },
                TraceOp::CmDequeue { tile: 0, bytes: 64 },
            ]
        );
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.lookup(key), Some(fid));
        assert_eq!(c.stats().hits, 1);
        assert!(c.stats().arena_bytes > 0);
    }

    #[test]
    fn disabled_cache_arenas_but_never_serves() {
        let mut c = CompileCache::new(false);
        let ops = [TraceOp::Compute { class: InstClass::IntAlu, insts: 8 }];
        let key = FragKey { node: 0, place: PlaceFp::Cpu, r: 0, parts: 1, alias: 0 };
        let a = c.insert(key, &ops, &[]);
        assert_eq!(c.lookup(key), None);
        let b = c.insert(key, &ops, &[]);
        assert_ne!(a, b, "disabled caches store per occurrence");
        assert_eq!(c.stats(), CompileCacheStats { hits: 0, misses: 2, arena_bytes: c.stats().arena_bytes });
    }
}
