//! A minimal batched inference server over the PJRT runtime — the
//! wall-clock Layer-3 request path of the e2e example. Requests are
//! collected into batches (up to the model's batch dimension) by a
//! dispatcher thread and executed on the AOT-compiled model;
//! per-request latency and aggregate throughput are reported.
//!
//! The virtual-time serving simulator (replicas, SLO-aware batching,
//! admission control, failover) lives in [`super::serving`]; this
//! module is the thin real-runtime counterpart that shares its arrival
//! processes and [`ServerStats`].
//!
//! tokio is unavailable in the offline vendor set (DESIGN.md §2), so the
//! event loop is std::thread + channels — the request path still never
//! touches Python.
//!
//! Shutdown contract: the dispatcher loop ends only when the feeder has
//! dropped its sender *and* the channel is drained. A feeder stall —
//! however long — just blocks `recv`; it can never silently drop queued
//! requests (the old 200 ms `recv_timeout` break did exactly that).
//! Conservation (responses == offered requests) is asserted in tests.

use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::runtime::LoadedModel;

use super::serving::arrival::ArrivalProcess;
pub use super::serving::stats::ServerStats;

pub struct Request {
    pub input: Vec<f32>,
    pub submitted: Instant,
}

pub struct Response {
    pub output: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// How requests trickle into the server: a seeded arrival process
/// (replacing the old hard-coded 50 us sleep), so e2e server runs are
/// reproducible schedules rather than wall-clock accidents.
#[derive(Clone, Debug)]
pub struct ArrivalSpec {
    pub process: ArrivalProcess,
    pub seed: u64,
}

impl ArrivalSpec {
    /// Evenly spaced arrivals at `rate_rps` (20 kHz == the legacy 50 us
    /// jitter).
    pub fn uniform(rate_rps: f64, seed: u64) -> ArrivalSpec {
        ArrivalSpec { process: ArrivalProcess::Uniform { rate_rps }, seed }
    }
}

/// Drive `requests` through the model with dynamic batching: the
/// dispatcher drains whatever is queued (up to `max_batch`) per step —
/// the same continuous-batching discipline the serving router uses.
pub fn serve_batched(
    model: &LoadedModel,
    requests: Vec<Vec<f32>>,
    max_batch: usize,
    per_request_elems: usize,
    arrival: &ArrivalSpec,
) -> Result<(Vec<Response>, ServerStats)> {
    serve_batched_with(
        |packed| {
            let outputs = model.run(&[packed.to_vec()])?;
            Ok(outputs.into_iter().next().unwrap_or_default())
        },
        requests,
        max_batch,
        per_request_elems,
        arrival,
    )
}

/// The batching loop over an arbitrary batch runner. `run_batch` gets
/// the packed `max_batch * per_request_elems` input and returns the flat
/// batch output. Separated from [`serve_batched`] so the
/// shutdown/conservation contract is testable without PJRT artifacts.
pub fn serve_batched_with<F>(
    mut run_batch: F,
    requests: Vec<Vec<f32>>,
    max_batch: usize,
    per_request_elems: usize,
    arrival: &ArrivalSpec,
) -> Result<(Vec<Response>, ServerStats)>
where
    F: FnMut(&[f32]) -> Result<Vec<f32>>,
{
    let max_batch = max_batch.max(1);
    let gaps = arrival.process.gaps(arrival.seed, requests.len());
    let (tx, rx) = mpsc::channel::<Request>();
    let feeder = {
        let inputs = requests;
        std::thread::spawn(move || {
            for (input, gap) in inputs.into_iter().zip(gaps) {
                if !gap.is_zero() {
                    std::thread::sleep(gap);
                }
                if tx.send(Request { input, submitted: Instant::now() }).is_err() {
                    break;
                }
            }
            // tx drops here: the explicit close signal the dispatcher
            // waits for.
        })
    };

    let mut responses = Vec::new();
    let mut stats = ServerStats::default();
    let t0 = Instant::now();

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Block for the first item; only a disconnected (dropped) sender
        // ends the loop.
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvError) => break,
            }
        }
        // Opportunistically drain whatever else has arrived.
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        let batch: Vec<Request> = pending.drain(..pending.len().min(max_batch)).collect();
        let bsz = batch.len();

        // Pack the batch into the model's fixed batch dimension, padding
        // with repeats of the last request.
        let mut packed = Vec::with_capacity(max_batch * per_request_elems);
        for r in &batch {
            packed.extend_from_slice(&r.input);
        }
        while packed.len() < max_batch * per_request_elems {
            let start = packed.len() - per_request_elems;
            let tail: Vec<f32> = packed[start..].to_vec();
            packed.extend_from_slice(&tail);
        }

        let out = run_batch(&packed)?;
        let per_out = out.len() / max_batch;
        let done = Instant::now();
        for (k, r) in batch.into_iter().enumerate() {
            let latency = done - r.submitted;
            stats.requests += 1;
            stats.total_latency += latency;
            stats.max_latency = stats.max_latency.max(latency);
            stats.latencies.push(latency);
            responses.push(Response {
                output: out[k * per_out..(k + 1) * per_out].to_vec(),
                latency,
                batch_size: bsz,
            });
        }
        stats.batches += 1;
    }
    feeder.join().ok();
    stats.wall = t0.elapsed();
    Ok((responses, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A mock batch runner: identity on the packed input, counting
    /// invocations.
    fn id_runner(calls: &mut u64) -> impl FnMut(&[f32]) -> Result<Vec<f32>> + '_ {
        move |packed| {
            *calls += 1;
            Ok(packed.to_vec())
        }
    }

    #[test]
    fn conservation_served_equals_offered() {
        let n: usize = 12;
        let dim = 3;
        let requests: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; dim]).collect();
        let spec = ArrivalSpec::uniform(1e9, 0); // 1 ns gaps: a flood
        let mut calls = 0;
        let (responses, stats) =
            serve_batched_with(id_runner(&mut calls), requests, 4, dim, &spec).unwrap();
        assert_eq!(responses.len(), n, "served + shed + timed-out == offered (no shed paths here)");
        assert_eq!(stats.requests as usize, n);
        assert!(stats.batches >= (n / 4) as u64);
        assert!(calls >= 1);
        // Outputs survive the round-trip in order.
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.output.len(), dim);
            assert_eq!(r.output[0], i as f32);
        }
    }

    #[test]
    fn feeder_stall_does_not_drop_requests() {
        // A 250 ms stall mid-trace: the old recv_timeout(200 ms) loop
        // broke out and silently dropped everything after the gap. The
        // close-signal loop must serve all of them.
        let dim = 2;
        let n = 6;
        let stall_ps = 250_000_000_000u64; // 250 ms in ps
        let times_ps: Vec<u64> =
            (0..n as u64).map(|i| i * 1_000 + if i >= 3 { stall_ps } else { 0 }).collect();
        let spec = ArrivalSpec { process: ArrivalProcess::Trace { times_ps }, seed: 0 };
        let requests: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; dim]).collect();
        let mut calls = 0;
        let (responses, stats) =
            serve_batched_with(id_runner(&mut calls), requests, 8, dim, &spec).unwrap();
        assert_eq!(responses.len(), n, "requests after a feeder stall must not be dropped");
        assert_eq!(stats.requests as usize, n);
        assert!(stats.batches >= 2, "the stall splits the trace into >= 2 batches");
    }

    #[test]
    fn empty_request_set_serves_nothing_cleanly() {
        let spec = ArrivalSpec::uniform(1e6, 0);
        let mut calls = 0;
        let (responses, stats) =
            serve_batched_with(id_runner(&mut calls), Vec::new(), 4, 2, &spec).unwrap();
        assert!(responses.is_empty());
        assert_eq!(stats.requests, 0);
        assert_eq!(calls, 0, "no batch may run for zero requests");
    }
}
