//! A minimal batched inference server over the PJRT runtime — the
//! Layer-3 request path of the e2e example. Requests are collected into
//! batches (up to the model's batch dimension) by a dispatcher thread and
//! executed on the AOT-compiled model; per-request latency and aggregate
//! throughput are reported.
//!
//! tokio is unavailable in the offline vendor set (DESIGN.md §2), so the
//! event loop is std::thread + channels — the request path still never
//! touches Python.

use anyhow::Result;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::LoadedModel;

pub struct Request {
    pub input: Vec<f32>,
    pub submitted: Instant,
}

pub struct Response {
    pub output: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    pub wall: Duration,
    /// Per-request latency samples, completion order (sorted on demand
    /// by [`ServerStats::percentile`] — a mean/max pair hides tail
    /// behaviour, and serving SLOs are stated in percentiles).
    pub latencies: Vec<Duration>,
}

impl ServerStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    /// Nearest-rank latency percentiles (each `p` in 0..=100) over the
    /// recorded samples — one sort serves every requested rank;
    /// `Duration::ZERO` entries when nothing was served.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        if self.latencies.is_empty() {
            return vec![Duration::ZERO; ps.len()];
        }
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        ps.iter()
            .map(|&p| {
                let rank = ((p.clamp(0.0, 100.0) / 100.0) * sorted.len() as f64).ceil() as usize;
                sorted[rank.clamp(1, sorted.len()) - 1]
            })
            .collect()
    }

    /// Nearest-rank latency percentile (`p` in 0..=100).
    pub fn percentile(&self, p: f64) -> Duration {
        self.percentiles(&[p])[0]
    }

    pub fn p50(&self) -> Duration {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(99.0)
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.requests as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Drive `requests` through the model with dynamic batching: the
/// dispatcher drains whatever is queued (up to `max_batch`) per step —
/// the same continuous-batching discipline a serving router uses.
pub fn serve_batched(
    model: &LoadedModel,
    requests: Vec<Vec<f32>>,
    max_batch: usize,
    per_request_elems: usize,
) -> Result<(Vec<Response>, ServerStats)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let feeder = {
        let inputs = requests;
        std::thread::spawn(move || {
            for input in inputs {
                // Arrival jitter: requests trickle in.
                std::thread::sleep(Duration::from_micros(50));
                if tx.send(Request { input, submitted: Instant::now() }).is_err() {
                    break;
                }
            }
        })
    };

    let mut responses = Vec::new();
    let mut stats = ServerStats::default();
    let t0 = Instant::now();
    let stats_lock = Arc::new(Mutex::new(()));
    let _guard = stats_lock.lock().unwrap();

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Drain what's available; block for the first item.
        if pending.is_empty() {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        let batch: Vec<Request> = pending.drain(..pending.len().min(max_batch)).collect();
        let bsz = batch.len();

        // Pack the batch into the model's fixed batch dimension, padding
        // with repeats of the last request.
        let mut packed = Vec::with_capacity(max_batch * per_request_elems);
        for r in &batch {
            packed.extend_from_slice(&r.input);
        }
        while packed.len() < max_batch * per_request_elems {
            let start = packed.len() - per_request_elems;
            let tail: Vec<f32> = packed[start..].to_vec();
            packed.extend_from_slice(&tail);
        }

        let outputs = model.run(&[packed])?;
        let out = &outputs[0];
        let per_out = out.len() / max_batch;
        let done = Instant::now();
        for (k, r) in batch.into_iter().enumerate() {
            let latency = done - r.submitted;
            stats.requests += 1;
            stats.total_latency += latency;
            stats.max_latency = stats.max_latency.max(latency);
            stats.latencies.push(latency);
            responses.push(Response {
                output: out[k * per_out..(k + 1) * per_out].to_vec(),
                latency,
                batch_size: bsz,
            });
        }
        stats.batches += 1;
    }
    feeder.join().ok();
    stats.wall = t0.elapsed();
    Ok((responses, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats {
            requests: 10,
            batches: 4,
            total_latency: Duration::from_millis(100),
            max_latency: Duration::from_millis(30),
            wall: Duration::from_millis(500),
            latencies: Vec::new(),
        };
        assert_eq!(s.mean_latency(), Duration::from_millis(10));
        assert!((s.throughput_rps() - 20.0).abs() < 1e-9);
        assert!((s.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_no_div_by_zero() {
        let s = ServerStats::default();
        assert_eq!(s.mean_latency(), Duration::ZERO);
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
        assert_eq!(s.p50(), Duration::ZERO);
        assert_eq!(s.p99(), Duration::ZERO);
    }

    #[test]
    fn percentiles_are_nearest_rank_over_unsorted_samples() {
        // 1..=100 ms, shuffled-ish insertion order: p50 = 50 ms,
        // p95 = 95 ms, p99 = 99 ms, p100 = max.
        let mut s = ServerStats::default();
        for ms in (1..=100u64).rev() {
            s.latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(s.p50(), Duration::from_millis(50));
        assert_eq!(s.p95(), Duration::from_millis(95));
        assert_eq!(s.p99(), Duration::from_millis(99));
        assert_eq!(s.percentile(100.0), Duration::from_millis(100));
        // Tiny sample sets stay in range.
        let mut t = ServerStats::default();
        t.latencies.push(Duration::from_millis(7));
        assert_eq!(t.p50(), Duration::from_millis(7));
        assert_eq!(t.p99(), Duration::from_millis(7));
        // Degenerate percentile arguments clamp instead of panicking.
        assert_eq!(t.percentile(0.0), Duration::from_millis(7));
        assert_eq!(t.percentile(250.0), Duration::from_millis(7));
    }
}
