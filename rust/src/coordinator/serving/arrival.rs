//! Open-loop arrival processes — seeded, deterministic, wall-clock-free.
//!
//! Every process generates absolute arrival timestamps in virtual
//! picoseconds from a `util::rng::Rng` seed, so a trace is replayable
//! byte-for-byte: the same (process, seed, n) triple yields the same
//! timestamps on any machine at any `--jobs N`. The non-homogeneous
//! shapes (bursty, diurnal) are thinning-free — each gap is an
//! exponential sample at the instantaneous rate, which keeps generation
//! O(n) and single-pass.

use crate::util::rng::Rng;
use std::time::Duration;

/// Default burst multiplier of [`ArrivalProcess::Bursty`].
pub const DEFAULT_BURST_X: f64 = 4.0;
/// Default burst/diurnal period: 100 us of virtual time (serving runs
/// span microseconds to milliseconds, so several cycles fit a run).
pub const DEFAULT_PERIOD_S: f64 = 100e-6;
/// Default in-burst fraction of the period.
pub const DEFAULT_DUTY: f64 = 0.25;
/// Default diurnal modulation amplitude.
pub const DEFAULT_AMPLITUDE: f64 = 0.8;

/// An open-loop request arrival process.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Evenly spaced arrivals at exactly `rate_rps` (the deterministic
    /// replacement of the old hard-coded 50 us jitter: 20 kHz uniform).
    Uniform { rate_rps: f64 },
    /// Memoryless Poisson arrivals at mean `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Poisson with a square-wave rate: `rate_rps * burst_x` during the
    /// first `duty` fraction of every `period_s`, `rate_rps` otherwise.
    Bursty { rate_rps: f64, burst_x: f64, period_s: f64, duty: f64 },
    /// Poisson with a sinusoidal rate:
    /// `rate_rps * (1 + amplitude * sin(2*pi*t/period_s))`, floored at
    /// 5% of the base rate.
    Diurnal { rate_rps: f64, amplitude: f64, period_s: f64 },
    /// A fixed timestamp trace (absolute picoseconds, non-decreasing) —
    /// replay of a recorded or hand-built schedule.
    Trace { times_ps: Vec<u64> },
}

impl ArrivalProcess {
    /// Parse a process *shape* from its CLI name; rates start at 0 and
    /// are filled in per load point via [`ArrivalProcess::with_rate`].
    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        match s {
            "uniform" => Some(ArrivalProcess::Uniform { rate_rps: 0.0 }),
            "poisson" => Some(ArrivalProcess::Poisson { rate_rps: 0.0 }),
            "bursty" => Some(ArrivalProcess::Bursty {
                rate_rps: 0.0,
                burst_x: DEFAULT_BURST_X,
                period_s: DEFAULT_PERIOD_S,
                duty: DEFAULT_DUTY,
            }),
            "diurnal" => Some(ArrivalProcess::Diurnal {
                rate_rps: 0.0,
                amplitude: DEFAULT_AMPLITUDE,
                period_s: DEFAULT_PERIOD_S * 10.0,
            }),
            _ => None,
        }
    }

    /// The same shape at a different base rate (`Trace` is returned
    /// unchanged — its schedule is absolute).
    pub fn with_rate(&self, rate: f64) -> ArrivalProcess {
        match self {
            ArrivalProcess::Uniform { .. } => ArrivalProcess::Uniform { rate_rps: rate },
            ArrivalProcess::Poisson { .. } => ArrivalProcess::Poisson { rate_rps: rate },
            ArrivalProcess::Bursty { burst_x, period_s, duty, .. } => ArrivalProcess::Bursty {
                rate_rps: rate,
                burst_x: *burst_x,
                period_s: *period_s,
                duty: *duty,
            },
            ArrivalProcess::Diurnal { amplitude, period_s, .. } => ArrivalProcess::Diurnal {
                rate_rps: rate,
                amplitude: *amplitude,
                period_s: *period_s,
            },
            ArrivalProcess::Trace { times_ps } => {
                ArrivalProcess::Trace { times_ps: times_ps.clone() }
            }
        }
    }

    /// Human-readable descriptor for reports.
    pub fn desc(&self) -> String {
        match self {
            ArrivalProcess::Uniform { .. } => "uniform".to_string(),
            ArrivalProcess::Poisson { .. } => "poisson".to_string(),
            ArrivalProcess::Bursty { burst_x, period_s, duty, .. } => {
                format!("bursty(x{burst_x:.1} duty {duty:.2} period {:.0}us)", period_s * 1e6)
            }
            ArrivalProcess::Diurnal { amplitude, period_s, .. } => {
                format!("diurnal(amp {amplitude:.2} period {:.0}us)", period_s * 1e6)
            }
            ArrivalProcess::Trace { times_ps } => format!("trace({} stamps)", times_ps.len()),
        }
    }

    /// Instantaneous rate at virtual time `t_s` (seconds).
    fn rate_at(&self, t_s: f64) -> f64 {
        match self {
            ArrivalProcess::Uniform { rate_rps } | ArrivalProcess::Poisson { rate_rps } => {
                *rate_rps
            }
            ArrivalProcess::Bursty { rate_rps, burst_x, period_s, duty } => {
                let phase = (t_s / period_s.max(1e-12)).fract();
                if phase < duty.clamp(0.0, 1.0) {
                    rate_rps * burst_x.max(1.0)
                } else {
                    *rate_rps
                }
            }
            ArrivalProcess::Diurnal { rate_rps, amplitude, period_s } => {
                let w = 2.0 * std::f64::consts::PI * t_s / period_s.max(1e-12);
                (rate_rps * (1.0 + amplitude * w.sin())).max(rate_rps * 0.05)
            }
            ArrivalProcess::Trace { .. } => 0.0,
        }
    }

    /// Generate `n` absolute arrival timestamps (picoseconds,
    /// non-decreasing). Deterministic in (self, seed, n).
    ///
    /// A `Trace` shorter than `n` is extended past its end by repeating
    /// its final gap (or 1 ps), so `n` requests are always offered.
    pub fn times_ps(&self, seed: u64, n: usize) -> Vec<u64> {
        if let ArrivalProcess::Trace { times_ps } = self {
            let mut out: Vec<u64> = times_ps.iter().copied().take(n).collect();
            let last_gap = match times_ps.len() {
                0 | 1 => 1,
                len => (times_ps[len - 1] - times_ps[len - 2]).max(1),
            };
            while out.len() < n {
                let last = out.last().copied().unwrap_or(0);
                out.push(last.saturating_add(last_gap));
            }
            return out;
        }
        let mut rng = Rng::new(seed);
        let mut t_ps = 0u64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let rate = self.rate_at(t_ps as f64 * 1e-12);
            assert!(rate > 0.0, "arrival process needs a positive rate (got {rate})");
            let gap_s = match self {
                ArrivalProcess::Uniform { .. } => 1.0 / rate,
                _ => exp_sample(&mut rng) / rate,
            };
            t_ps = t_ps.saturating_add(((gap_s * 1e12).round() as u64).max(1));
            out.push(t_ps);
        }
        out
    }

    /// Inter-arrival gaps as wall-clock `Duration`s (rounded up to whole
    /// nanoseconds) — the feed schedule of the PJRT serving path.
    pub fn gaps(&self, seed: u64, n: usize) -> Vec<Duration> {
        let times = self.times_ps(seed, n);
        let mut prev = 0u64;
        times
            .into_iter()
            .map(|t| {
                let gap_ps = t.saturating_sub(prev);
                prev = t;
                Duration::from_nanos(gap_ps.div_ceil(1000))
            })
            .collect()
    }
}

/// Standard exponential sample (mean 1). `next_f64` is in [0, 1), so
/// `1 - u` is in (0, 1] and the log is finite.
fn exp_sample(rng: &mut Rng) -> f64 {
    -(1.0 - rng.next_f64()).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_trace_any_shape() {
        for shape in ["uniform", "poisson", "bursty", "diurnal"] {
            let p = ArrivalProcess::parse(shape).unwrap().with_rate(1e6);
            assert_eq!(p.times_ps(42, 200), p.times_ps(42, 200), "{shape}");
        }
    }

    #[test]
    fn different_seeds_differ_for_random_shapes() {
        let p = ArrivalProcess::Poisson { rate_rps: 1e6 };
        assert_ne!(p.times_ps(1, 64), p.times_ps(2, 64));
        // Uniform ignores the seed by construction.
        let u = ArrivalProcess::Uniform { rate_rps: 1e6 };
        assert_eq!(u.times_ps(1, 64), u.times_ps(2, 64));
    }

    #[test]
    fn timestamps_are_strictly_increasing() {
        for shape in ["uniform", "poisson", "bursty", "diurnal"] {
            let p = ArrivalProcess::parse(shape).unwrap().with_rate(2e6);
            let ts = p.times_ps(7, 500);
            for w in ts.windows(2) {
                assert!(w[0] < w[1], "{shape}: {} !< {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let rate = 1e6;
        let n = 20_000;
        let ts = ArrivalProcess::Poisson { rate_rps: rate }.times_ps(9, n);
        let span_s = *ts.last().unwrap() as f64 * 1e-12;
        let achieved = n as f64 / span_s;
        assert!(
            (achieved / rate - 1.0).abs() < 0.05,
            "achieved {achieved:.0} rps vs {rate:.0}"
        );
    }

    #[test]
    fn uniform_matches_exact_spacing() {
        // 20 kHz == the old 50 us jitter.
        let ts = ArrivalProcess::Uniform { rate_rps: 20_000.0 }.times_ps(0, 4);
        assert_eq!(ts, vec![50_000_000, 100_000_000, 150_000_000, 200_000_000]);
    }

    #[test]
    fn bursty_is_denser_in_burst_window() {
        let p = ArrivalProcess::Bursty {
            rate_rps: 1e6,
            burst_x: 8.0,
            period_s: 100e-6,
            duty: 0.25,
        };
        let ts = p.times_ps(3, 5_000);
        let period_ps = 100_000_000u64;
        let duty_ps = period_ps / 4;
        let in_burst = ts.iter().filter(|&&t| t % period_ps < duty_ps).count();
        // 25% of the time at 8x rate should hold well over half the mass.
        assert!(
            in_burst * 2 > ts.len(),
            "only {in_burst}/{} arrivals in burst windows",
            ts.len()
        );
    }

    #[test]
    fn trace_extends_past_its_end_by_last_gap() {
        let p = ArrivalProcess::Trace { times_ps: vec![10, 30] };
        assert_eq!(p.times_ps(0, 4), vec![10, 30, 50, 70]);
        assert_eq!(p.times_ps(0, 1), vec![10]);
    }

    #[test]
    fn gaps_round_up_to_nanoseconds() {
        let p = ArrivalProcess::Trace { times_ps: vec![500, 1_500, 1_501] };
        let gaps = p.gaps(0, 3);
        assert_eq!(gaps[0], Duration::from_nanos(1)); // 500 ps -> 1 ns
        assert_eq!(gaps[1], Duration::from_nanos(1)); // 1000 ps
        assert_eq!(gaps[2], Duration::from_nanos(1)); // 1 ps -> 1 ns
    }
}
