//! One model replica: a sharded ALPINE chip's queue, health, and
//! in-flight batch state inside the serving simulation.

use std::collections::VecDeque;

/// Replica health, the router's health-check state machine:
/// `Healthy -> Failed` on a hard tile failure, `Failed -> Degraded`
/// when the replica rejoins after `degrade_mapping` re-simulation.
/// A `Degraded` replica serves at the backend's degraded batch cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Failed,
    Degraded,
}

impl Health {
    pub fn name(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Failed => "failed",
            Health::Degraded => "degraded",
        }
    }
}

/// The *accuracy* dimension of replica health, orthogonal to the
/// hard-failure dimension above: a replica can be structurally healthy
/// yet serving increasingly wrong answers as its analog conductances
/// drift. `Fresh -> DriftDegraded` when the accuracy proxy falls below
/// the degrade threshold; `-> Recalibrating` while a scheduled
/// reprogramming window drains and refreshes it; `-> Fresh` on rejoin.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccuracyHealth {
    /// Proxy at or above the degrade threshold.
    Fresh,
    /// Proxy below threshold: still serves, but the router prefers
    /// fresher replicas for accuracy-sensitive requests.
    DriftDegraded,
    /// Inside a recalibration window: drained, admits nothing, and
    /// never receives dispatches until the reprogram completes.
    Recalibrating,
}

impl AccuracyHealth {
    pub fn name(&self) -> &'static str {
        match self {
            AccuracyHealth::Fresh => "fresh",
            AccuracyHealth::DriftDegraded => "drift_degraded",
            AccuracyHealth::Recalibrating => "recalibrating",
        }
    }
}

/// One request inside the simulation. Latency and deadline are anchored
/// to the *original* arrival time — a retried request does not get a
/// fresh SLO budget.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub arrival_ps: u64,
    pub deadline_ps: u64,
    /// Retry attempts consumed (0 = first try).
    pub attempts: u32,
    /// Times this request was re-routed off a failed replica.
    pub failovers: u32,
}

/// One replica's simulation state.
#[derive(Debug)]
pub struct Replica {
    pub queue: VecDeque<Request>,
    pub in_flight: Vec<Request>,
    pub busy: bool,
    pub health: Health,
    /// Generation counter: bumped on every batch launch and on failure,
    /// so stale `BatchDone` / `BatchTimer` events are recognised and
    /// dropped instead of completing a batch the failure already ate.
    pub gen: u64,
    /// Pending batch timer (fire time, generation), if any — dedupes
    /// timer events so a burst of arrivals schedules one wakeup.
    pub timer: Option<(u64, u64)>,
    pub served: u64,
    /// Accuracy-dimension health (drift monitoring / recalibration).
    pub acc: AccuracyHealth,
    /// Virtual-time programming timestamp of this replica's analog
    /// tiles; the accuracy proxy is a function of `now - programmed_at`.
    pub programmed_at_ps: u64,
    /// Completed recalibration windows.
    pub recals: u64,
    /// Set while a recalibration waits for the in-flight batch to drain
    /// before the reprogram downtime starts.
    pub draining: bool,
}

impl Replica {
    pub fn new() -> Replica {
        Replica {
            queue: VecDeque::new(),
            in_flight: Vec::new(),
            busy: false,
            health: Health::Healthy,
            gen: 0,
            timer: None,
            served: 0,
            acc: AccuracyHealth::Fresh,
            programmed_at_ps: 0,
            recals: 0,
            draining: false,
        }
    }

    /// Queued + executing requests — the least-loaded routing metric.
    pub fn load(&self) -> usize {
        self.queue.len() + self.in_flight.len()
    }

    /// Can this replica admit one more request under `queue_cap`?
    /// Recalibrating replicas are drained and never admit — the other
    /// half of the "never receives dispatches" invariant enforced at
    /// batch launch.
    pub fn admits(&self, queue_cap: usize) -> bool {
        self.health != Health::Failed
            && self.acc != AccuracyHealth::Recalibrating
            && self.queue.len() < queue_cap
    }
}

impl Default for Replica {
    fn default() -> Replica {
        Replica::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_respects_health_and_capacity() {
        let mut r = Replica::new();
        assert!(r.admits(1));
        r.queue.push_back(Request {
            id: 0,
            arrival_ps: 0,
            deadline_ps: 100,
            attempts: 0,
            failovers: 0,
        });
        assert!(!r.admits(1), "queue at capacity");
        assert!(r.admits(2));
        r.health = Health::Failed;
        assert!(!r.admits(2), "failed replicas never admit");
        r.health = Health::Degraded;
        assert!(r.admits(2), "degraded replicas serve (at degraded cost)");
        assert_eq!(r.load(), 1);
        r.acc = AccuracyHealth::Recalibrating;
        assert!(!r.admits(2), "recalibrating replicas never admit");
        r.acc = AccuracyHealth::DriftDegraded;
        assert!(r.admits(2), "drift-degraded replicas still serve");
    }
}
