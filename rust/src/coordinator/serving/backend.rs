//! Per-replica execution backends of the serving simulator.
//!
//! A [`Backend`] answers one question: how long does a batch of `b`
//! requests take on one replica's chip? Three implementations:
//!
//! * [`TraceMachineBackend`] — the honest one. Automap-searches the
//!   model, compiles the best mapping at every batch size 1..=max, and
//!   runs the full trace machine (nested fast-forward intact) to fill a
//!   service-time table; the degraded table re-simulates the
//!   `degrade_mapping` remap of the first degradable tile, so a rejoined
//!   replica pays the measured digital-fallback cost, not a guess.
//! * [`InstantMockBackend`] — closed-form affine cost for unit tests and
//!   property tests: no simulation, microsecond-scale virtual times.
//! * [`PjrtBackend`] — calibrates the table from wall-clock runs of an
//!   AOT-compiled [`LoadedModel`]; lets the same router/SLO pipeline be
//!   driven by real runtime numbers when PJRT artifacts are available.
//!
//! Tables are in virtual picoseconds. All backends are `Sync` so load
//! points can fan out over `util::parallel` sharing one backend.

use std::time::Instant;

use crate::config::{SystemConfig, SystemKind};
use crate::coordinator::{run_workload, RunOptions};
use crate::nn::LayerGraph;
use crate::runtime::LoadedModel;
use crate::util::parallel;
use crate::workload::automap::{self, SearchOptions, TopologyBudget};
use crate::workload::compile::mapping::Mapping;
use crate::workload::{compile, WorkloadError};

/// Batch service-time source of one replica. `batch_ps(b)` must be
/// defined for `1 <= b <= max_batch()` and should be monotone in `b`.
pub trait Backend: Sync {
    /// Human-readable descriptor for reports.
    fn label(&self) -> String;
    /// Largest batch one replica executes at once.
    fn max_batch(&self) -> usize;
    /// Service time of a healthy replica executing a batch of `b`.
    fn batch_ps(&self, b: usize) -> u64;
    /// Service time after a tile failure + `degrade_mapping` rejoin.
    /// Defaults to the healthy cost (a backend with nothing to degrade).
    fn degraded_batch_ps(&self, b: usize) -> u64 {
        self.batch_ps(b)
    }
    /// Descriptor of the degraded mapping, when one exists.
    fn degraded_label(&self) -> Option<String> {
        None
    }
}

/// Affine-cost mock: `batch_ps(b) = base_ps + per_request_ps * b`,
/// degraded costs scaled by `degraded_x`. Instant to construct — the
/// unit/property-test backend.
#[derive(Clone, Debug)]
pub struct InstantMockBackend {
    pub base_ps: u64,
    pub per_request_ps: u64,
    pub degraded_x: u64,
    pub max_batch: usize,
}

impl Default for InstantMockBackend {
    fn default() -> InstantMockBackend {
        InstantMockBackend { base_ps: 10_000, per_request_ps: 1_000, degraded_x: 3, max_batch: 8 }
    }
}

impl Backend for InstantMockBackend {
    fn label(&self) -> String {
        format!(
            "instant-mock[{}+{}*b ps, degraded x{}]",
            self.base_ps, self.per_request_ps, self.degraded_x
        )
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn batch_ps(&self, b: usize) -> u64 {
        let b = b.clamp(1, self.max_batch) as u64;
        self.base_ps + self.per_request_ps * b
    }

    fn degraded_batch_ps(&self, b: usize) -> u64 {
        self.batch_ps(b) * self.degraded_x.max(1)
    }

    fn degraded_label(&self) -> Option<String> {
        Some(format!("mock degraded (x{})", self.degraded_x.max(1)))
    }
}

/// The trace-machine backend: serving numbers inherit the simulator's
/// fidelity because every table entry *is* a full-system simulation.
pub struct TraceMachineBackend {
    desc: String,
    degraded_desc: Option<String>,
    max_batch: usize,
    /// `healthy_ps[b - 1]` = simulated time of a `b`-inference trace.
    healthy_ps: Vec<u64>,
    degraded_ps: Vec<u64>,
}

impl TraceMachineBackend {
    /// Search + simulate an MLP of the given layer shape.
    pub fn build(
        shape: &[u64],
        system: SystemKind,
        max_batch: usize,
        jobs: usize,
    ) -> Result<TraceMachineBackend, WorkloadError> {
        let graph = LayerGraph::mlp(shape);
        TraceMachineBackend::build_graph(&graph, system, max_batch, jobs)
    }

    /// Search the graph under the system's topology budget, then fill
    /// the healthy and degraded service-time tables by simulation.
    pub fn build_graph(
        graph: &LayerGraph,
        system: SystemKind,
        max_batch: usize,
        jobs: usize,
    ) -> Result<TraceMachineBackend, WorkloadError> {
        TraceMachineBackend::build_graph_degraded(graph, system, max_batch, jobs, 1)
    }

    /// Like [`build_graph`](TraceMachineBackend::build_graph), but the
    /// degraded table models `degrade_tiles` *cascading* tile failures:
    /// the first `degrade_tiles` analog-hosting tiles fail together and
    /// the union remap (`degrade_mapping_multi`) is re-simulated.
    /// `degrade_tiles = 1` is the classic single-failure table.
    pub fn build_graph_degraded(
        graph: &LayerGraph,
        system: SystemKind,
        max_batch: usize,
        jobs: usize,
        degrade_tiles: usize,
    ) -> Result<TraceMachineBackend, WorkloadError> {
        let max_batch = max_batch.max(1);
        let cfg = SystemConfig::for_kind(system);
        let budget = TopologyBudget::for_config(&cfg);
        let out = automap::search_opts(
            graph,
            &budget,
            &cfg,
            &SearchOptions { top_k: 2, jobs, ..SearchOptions::default() },
        )?;
        let best = out.ranked.first().ok_or_else(|| {
            WorkloadError::InvalidMapping("automap found no feasible candidate".into())
        })?;

        let table = |mapping: &Mapping| -> Result<Vec<u64>, WorkloadError> {
            let sizes: Vec<u32> = (1..=max_batch as u32).collect();
            parallel::parallel_map(sizes, jobs, |b| {
                let w = compile::compile(graph, mapping, b)?;
                let r = run_workload(system, w, &RunOptions::default())?;
                Ok(SystemConfig::s_to_ps(r.time_s).max(1))
            })
            .into_iter()
            .collect()
        };
        let healthy_ps = table(&best.mapping)?;

        // Degraded table: fail the first `degrade_tiles` analog-hosting
        // tiles together and re-simulate the union remap. An all-digital
        // winner has nothing to degrade — the rejoined replica then
        // serves at healthy cost.
        let mut degraded_desc = None;
        let mut degraded_ps = healthy_ps.clone();
        let mut failed: Vec<usize> = Vec::new();
        for tile in 0..best.mapping.tiles.len() {
            if failed.len() >= degrade_tiles.max(1) {
                break;
            }
            if automap::degrade_mapping(graph, &best.mapping, tile, &budget).is_ok() {
                failed.push(tile);
            }
        }
        if !failed.is_empty() {
            let d = automap::degrade_mapping_multi(graph, &best.mapping, &failed, &budget)?;
            degraded_ps = table(&d.mapping)?;
            degraded_desc = Some(d.desc);
        }

        Ok(TraceMachineBackend {
            desc: best.desc.clone(),
            degraded_desc,
            max_batch,
            healthy_ps,
            degraded_ps,
        })
    }

    /// The searched mapping's descriptor (e.g. `"s2 r2 pp AD|DA"`).
    pub fn mapping_desc(&self) -> &str {
        &self.desc
    }
}

impl Backend for TraceMachineBackend {
    fn label(&self) -> String {
        format!("trace-machine[{}]", self.desc)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn batch_ps(&self, b: usize) -> u64 {
        self.healthy_ps[b.clamp(1, self.max_batch) - 1]
    }

    fn degraded_batch_ps(&self, b: usize) -> u64 {
        self.degraded_ps[b.clamp(1, self.max_batch) - 1]
    }

    fn degraded_label(&self) -> Option<String> {
        self.degraded_desc.clone()
    }
}

/// Wall-clock-calibrated backend over the PJRT runtime. The AOT model
/// has a fixed batch dimension, so one measured executable time covers
/// every `b` (smaller batches are padded to the full dimension — the
/// same packing `server::serve_batched` does).
pub struct PjrtBackend {
    label: String,
    max_batch: usize,
    batch_ps: u64,
}

impl PjrtBackend {
    /// Time `iters` runs of the loaded model and keep the fastest
    /// (minimum wall time is the standard noise-resistant calibration).
    pub fn calibrate(
        model: &LoadedModel,
        per_request_elems: usize,
        max_batch: usize,
        iters: u32,
    ) -> anyhow::Result<PjrtBackend> {
        let max_batch = max_batch.max(1);
        let packed = vec![0.1f32; max_batch * per_request_elems.max(1)];
        let mut best_ns = u64::MAX;
        for _ in 0..iters.max(1) {
            let t0 = Instant::now();
            model.run(&[packed.clone()])?;
            best_ns = best_ns.min(t0.elapsed().as_nanos() as u64);
        }
        Ok(PjrtBackend {
            label: format!("pjrt[batch {max_batch}, {best_ns} ns/batch]"),
            max_batch,
            batch_ps: best_ns.saturating_mul(1000).max(1),
        })
    }
}

impl Backend for PjrtBackend {
    fn label(&self) -> String {
        self.label.clone()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn batch_ps(&self, _b: usize) -> u64 {
        self.batch_ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_costs_are_affine_and_degraded_scales() {
        let m = InstantMockBackend::default();
        assert_eq!(m.batch_ps(1), 11_000);
        assert_eq!(m.batch_ps(8), 18_000);
        // Out-of-range batch sizes clamp instead of panicking.
        assert_eq!(m.batch_ps(0), m.batch_ps(1));
        assert_eq!(m.batch_ps(99), m.batch_ps(8));
        assert_eq!(m.degraded_batch_ps(4), 3 * m.batch_ps(4));
    }

    #[test]
    fn trace_backend_tables_are_monotone_and_degraded_is_slower() {
        let b = TraceMachineBackend::build(&[256, 128, 64], SystemKind::HighPower, 4, 1).unwrap();
        assert_eq!(b.max_batch(), 4);
        for k in 1..4 {
            assert!(
                b.batch_ps(k) < b.batch_ps(k + 1),
                "batch {k}: {} !< {}",
                b.batch_ps(k),
                b.batch_ps(k + 1)
            );
        }
        // The best MLP mapping is analog, so a degradable tile exists
        // and the digital-fallback table must not be faster.
        assert!(b.degraded_label().is_some(), "expected a degradable analog mapping");
        for k in 1..=4 {
            assert!(b.degraded_batch_ps(k) >= b.batch_ps(k));
        }
    }

    #[test]
    fn trace_backend_cascading_degrade_builds_a_valid_union_table() {
        let b = TraceMachineBackend::build_graph_degraded(
            &LayerGraph::mlp(&[128, 64]),
            SystemKind::HighPower,
            2,
            1,
            2,
        )
        .unwrap();
        // The union remap (up to two failed tiles) must still produce a
        // coherent table: no faster than healthy at any batch size.
        assert!(b.degraded_label().is_some(), "expected a degradable analog mapping");
        for k in 1..=2 {
            assert!(b.degraded_batch_ps(k) >= b.batch_ps(k));
        }
    }
}
