//! SLO-aware serving under overload and failure — the `alpine
//! serve-bench` subsystem (ISSUE 9, ROADMAP item 1).
//!
//! `coordinator/server.rs` (the wall-clock PJRT batcher) grew into this
//! package: a deterministic virtual-time load-testing harness that
//! sweeps offered load against a cluster of model replicas sharded
//! across simulated ALPINE chips.
//!
//! * [`backend`] — where a batch's service time comes from: the trace
//!   machine (full-system simulation, nested fast-forward intact), a
//!   calibrated PJRT runtime, or an instant mock for tests.
//! * [`arrival`] — seeded open-loop arrival processes (uniform /
//!   Poisson / bursty / diurnal / replayed trace).
//! * [`replica`] / [`router`] — the discrete-event request path:
//!   SLO-aware dynamic batching, admission control with queue-depth
//!   backpressure, typed load-shedding, per-request deadlines with
//!   timeout-drop, bounded retry with exponential backoff, and replica
//!   failover with degraded-cost rejoin.
//! * [`stats`] — typed resolution counters + latency percentiles (and
//!   the wall-clock [`stats::ServerStats`] the PJRT path reports).
//!
//! Determinism: the event loop is single-threaded per load point and
//! wall-clock-free; `--jobs` only fans independent load points out over
//! `util::parallel` with per-point seeds derived from the base seed.
//! Same seed => byte-identical `BENCH_serving.json` at any `--jobs N`.

pub mod accuracy;
pub mod arrival;
pub mod backend;
pub mod replica;
pub mod router;
pub mod stats;

pub use accuracy::{AccuracyModel, RecalConfig, RecalPolicy};
pub use arrival::ArrivalProcess;
pub use backend::{Backend, InstantMockBackend, PjrtBackend, TraceMachineBackend};
pub use replica::{AccuracyHealth, Health};
pub use router::{RecalWindow, RouterPolicy, SimConfig, SimResult};
pub use stats::{Counters, LatencyStats, RejectReason, ServerStats};

use crate::config::SystemKind;
use crate::util::parallel;
use crate::workload::WorkloadError;

/// Knobs of one `alpine serve-bench` invocation. The `Option` time
/// knobs default to multiples of the backend's full-batch service time
/// so one set of defaults is sane for microsecond-scale trace backends
/// and millisecond-scale PJRT backends alike.
#[derive(Clone, Debug)]
pub struct ServeBenchOptions {
    pub system: SystemKind,
    pub seed: u64,
    /// Requests offered per load point.
    pub requests: u64,
    pub replicas: usize,
    /// Batch capacity per replica (also the trace backend's table size).
    pub max_batch: usize,
    /// Per-replica queue bound (admission control).
    pub queue_cap: usize,
    /// Per-request SLO; `None` = 10x the full-batch service time.
    pub deadline_ps: Option<u64>,
    /// Partial-batch wait; `None` = 1x the full-batch service time.
    pub batch_wait_ps: Option<u64>,
    pub max_retries: u32,
    /// First-retry backoff; `None` = half the single-request service.
    pub backoff_base_ps: Option<u64>,
    /// Failure-to-rejoin repair time; `None` = 10x the full-batch
    /// service time.
    pub repair_ps: Option<u64>,
    pub policy: RouterPolicy,
    /// Arrival shape; its rate is overridden per load point.
    pub arrival: ArrivalProcess,
    /// Offered load per point, as fractions of the estimated saturation
    /// throughput (`replicas * max_batch / batch_ps(max_batch)`).
    pub load_fracs: Vec<f64>,
    /// Hard-fail replica `r` at `frac` of each point's arrival span.
    pub fail_replica: Option<(usize, f64)>,
    /// Drift-aware serving: accuracy model, accuracy SLO, and
    /// recalibration schedule. `None` keeps the drift-free router
    /// bit-identical to the pre-drift behaviour.
    pub recal: Option<RecalConfig>,
    /// MLP layer shape the trace backend searches and simulates.
    pub shape: Vec<u64>,
    pub jobs: usize,
}

impl Default for ServeBenchOptions {
    fn default() -> ServeBenchOptions {
        ServeBenchOptions {
            system: SystemKind::HighPower,
            seed: 0x5E21,
            requests: 256,
            replicas: 2,
            max_batch: 8,
            queue_cap: 32,
            deadline_ps: None,
            batch_wait_ps: None,
            max_retries: 3,
            backoff_base_ps: None,
            repair_ps: None,
            policy: RouterPolicy::LeastLoaded,
            arrival: ArrivalProcess::Poisson { rate_rps: 0.0 },
            load_fracs: vec![0.2, 0.4, 0.6, 0.8, 0.95, 1.1],
            fail_replica: None,
            recal: None,
            shape: vec![256, 128, 64],
            jobs: 1,
        }
    }
}

/// One point of the latency-vs-offered-load curve.
#[derive(Clone, Debug)]
pub struct LoadPoint {
    /// Offered load as a fraction of estimated saturation.
    pub load_frac: f64,
    pub offered_rps: f64,
    /// Served / makespan.
    pub achieved_rps: f64,
    pub counters: Counters,
    pub mean_batch: f64,
    pub p50_ps: u64,
    pub p95_ps: u64,
    pub p99_ps: u64,
    pub mean_ps: u64,
    pub max_ps: u64,
    pub makespan_ps: u64,
    pub per_replica_served: Vec<u64>,
    /// When the failed replica was hard-failed / rejoined (if a fault
    /// plan was active and the horizon reached the rejoin).
    pub fail_at_ps: Option<u64>,
    pub rejoin_at_ps: Option<u64>,
}

/// Full report of one `alpine serve-bench` invocation.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub system: SystemKind,
    pub backend_desc: String,
    pub degraded_desc: Option<String>,
    pub replicas: usize,
    pub max_batch: usize,
    pub queue_cap: usize,
    pub policy: RouterPolicy,
    pub arrival_desc: String,
    pub seed: u64,
    pub requests_per_point: u64,
    pub deadline_ps: u64,
    pub batch_wait_ps: u64,
    pub backoff_base_ps: u64,
    pub repair_ps: u64,
    pub max_retries: u32,
    /// `replicas * max_batch / batch_ps(max_batch)`.
    pub saturation_rps_est: f64,
    /// Highest achieved throughput over the curve.
    pub saturation_rps_measured: f64,
    /// First load fraction (past the first point) whose p99 is >= 3x
    /// the lowest point's p99 — the knee of the curve.
    pub knee_frac: Option<f64>,
    pub fail_replica: Option<(usize, f64)>,
    /// Healthy batch service-time table, `[batch_ps(1), ..]`.
    pub service_ps: Vec<u64>,
    pub degraded_service_ps: Vec<u64>,
    pub points: Vec<LoadPoint>,
}

/// Per-point seed: splitmix-style derivation so points are independent
/// streams of the base seed regardless of evaluation order.
fn point_seed(seed: u64, index: usize) -> u64 {
    let mut z = seed ^ (index as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Sweep the load curve on an explicit backend (tests inject the
/// instant mock here; `run_serve_bench` builds the trace backend).
pub fn run_serve_bench_on(
    opts: &ServeBenchOptions,
    backend: &dyn Backend,
) -> Result<ServeBenchReport, WorkloadError> {
    let bad = |m: String| WorkloadError::InvalidMapping(m);
    if opts.replicas == 0 {
        return Err(bad("serve-bench needs at least one replica".into()));
    }
    if opts.requests == 0 {
        return Err(bad("serve-bench needs at least one request per point".into()));
    }
    if opts.load_fracs.is_empty() || opts.load_fracs.iter().any(|&f| f <= 0.0) {
        return Err(bad("load points must be positive fractions of saturation".into()));
    }
    if let Some((r, frac)) = opts.fail_replica {
        if r >= opts.replicas {
            return Err(bad(format!(
                "--fail-replica {r}: only {} replica(s) configured",
                opts.replicas
            )));
        }
        if !(0.0..=1.0).contains(&frac) {
            return Err(bad(format!("--fail-replica fraction {frac} outside [0, 1]")));
        }
    }

    let bmax = backend.max_batch().max(1);
    let full_batch_ps = backend.batch_ps(bmax).max(1);
    let deadline_ps = opts.deadline_ps.unwrap_or(10 * full_batch_ps).max(1);
    let batch_wait_ps = opts.batch_wait_ps.unwrap_or(full_batch_ps);
    let backoff_base_ps = opts.backoff_base_ps.unwrap_or((backend.batch_ps(1) / 2).max(1));
    let repair_ps = opts.repair_ps.unwrap_or(10 * full_batch_ps).max(1);
    let saturation_rps_est =
        opts.replicas as f64 * bmax as f64 / (full_batch_ps as f64 * 1e-12);

    let items: Vec<(usize, f64)> = opts.load_fracs.iter().copied().enumerate().collect();
    let points: Vec<LoadPoint> = parallel::parallel_map(items, opts.jobs, |(i, frac)| {
        let offered_rps = saturation_rps_est * frac;
        let arrivals = opts
            .arrival
            .with_rate(offered_rps)
            .times_ps(point_seed(opts.seed, i), opts.requests as usize);
        let fail = opts.fail_replica.map(|(r, f)| {
            let a0 = arrivals[0];
            let a1 = *arrivals.last().expect("non-empty arrivals");
            (r, a0 + (((a1 - a0) as f64) * f).round() as u64)
        });
        let cfg = SimConfig {
            backend,
            replicas: opts.replicas,
            queue_cap: opts.queue_cap.max(1),
            deadline_ps,
            batch_wait_ps,
            max_retries: opts.max_retries,
            backoff_base_ps,
            repair_ps,
            policy: opts.policy,
            fail,
            recal: opts.recal.clone(),
        };
        let sim = router::simulate(&cfg, &arrivals);
        let makespan_s = sim.makespan_ps.max(1) as f64 * 1e-12;
        LoadPoint {
            load_frac: frac,
            offered_rps,
            achieved_rps: sim.counters.served as f64 / makespan_s,
            mean_batch: sim.counters.mean_batch(),
            p50_ps: sim.latencies.p50_ps(),
            p95_ps: sim.latencies.p95_ps(),
            p99_ps: sim.latencies.p99_ps(),
            mean_ps: sim.latencies.mean_ps(),
            max_ps: sim.latencies.max_ps(),
            counters: sim.counters,
            makespan_ps: sim.makespan_ps,
            per_replica_served: sim.per_replica_served,
            fail_at_ps: fail.map(|(_, t)| t),
            rejoin_at_ps: sim.rejoin_at_ps,
        }
    });

    let base_p99 = points.first().map(|p| p.p99_ps).unwrap_or(0);
    let knee_frac = if base_p99 == 0 {
        None
    } else {
        points.iter().skip(1).find(|p| p.p99_ps >= 3 * base_p99).map(|p| p.load_frac)
    };
    let saturation_rps_measured = points.iter().map(|p| p.achieved_rps).fold(0.0, f64::max);

    Ok(ServeBenchReport {
        system: opts.system,
        backend_desc: backend.label(),
        degraded_desc: backend.degraded_label(),
        replicas: opts.replicas,
        max_batch: bmax,
        queue_cap: opts.queue_cap.max(1),
        policy: opts.policy,
        arrival_desc: opts.arrival.desc(),
        seed: opts.seed,
        requests_per_point: opts.requests,
        deadline_ps,
        batch_wait_ps,
        backoff_base_ps,
        repair_ps,
        max_retries: opts.max_retries,
        saturation_rps_est,
        saturation_rps_measured,
        knee_frac,
        fail_replica: opts.fail_replica,
        service_ps: (1..=bmax).map(|b| backend.batch_ps(b)).collect(),
        degraded_service_ps: (1..=bmax).map(|b| backend.degraded_batch_ps(b)).collect(),
        points,
    })
}

/// Build the trace-machine backend for `opts.shape` and sweep the curve
/// — the `alpine serve-bench` entry point.
pub fn run_serve_bench(opts: &ServeBenchOptions) -> Result<ServeBenchReport, WorkloadError> {
    let backend =
        TraceMachineBackend::build(&opts.shape, opts.system, opts.max_batch, opts.jobs)?;
    run_serve_bench_on(opts, &backend)
}

/// Minimal JSON string escaping (mapping descriptors may quote ids).
fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn json_u64_list(vs: &[u64]) -> String {
    vs.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
}

impl ServeBenchReport {
    /// Hand-rolled JSON (serde is not in the offline vendor set).
    /// Byte-identical for identical reports — the determinism tests
    /// compare this string across `--jobs` values.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"system\": \"{}\",\n", self.system.name()));
        s.push_str(&format!("  \"backend\": \"{}\",\n", esc(&self.backend_desc)));
        s.push_str(&format!(
            "  \"degraded_backend\": {},\n",
            match &self.degraded_desc {
                Some(d) => format!("\"{}\"", esc(d)),
                None => "null".to_string(),
            }
        ));
        s.push_str(&format!("  \"replicas\": {},\n", self.replicas));
        s.push_str(&format!("  \"max_batch\": {},\n", self.max_batch));
        s.push_str(&format!("  \"queue_cap\": {},\n", self.queue_cap));
        s.push_str(&format!("  \"policy\": \"{}\",\n", self.policy.name()));
        s.push_str(&format!("  \"arrival\": \"{}\",\n", esc(&self.arrival_desc)));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"requests_per_point\": {},\n", self.requests_per_point));
        s.push_str(&format!("  \"deadline_ps\": {},\n", self.deadline_ps));
        s.push_str(&format!("  \"batch_wait_ps\": {},\n", self.batch_wait_ps));
        s.push_str(&format!("  \"backoff_base_ps\": {},\n", self.backoff_base_ps));
        s.push_str(&format!("  \"repair_ps\": {},\n", self.repair_ps));
        s.push_str(&format!("  \"max_retries\": {},\n", self.max_retries));
        s.push_str(&format!("  \"saturation_rps_est\": {:.3},\n", self.saturation_rps_est));
        s.push_str(&format!(
            "  \"saturation_rps_measured\": {:.3},\n",
            self.saturation_rps_measured
        ));
        s.push_str(&format!(
            "  \"knee_load_frac\": {},\n",
            match self.knee_frac {
                Some(f) => format!("{f:.4}"),
                None => "null".to_string(),
            }
        ));
        s.push_str(&format!(
            "  \"fail_replica\": {},\n",
            match self.fail_replica {
                Some((r, f)) => format!("{{\"replica\": {r}, \"at_frac\": {f:.4}}}"),
                None => "null".to_string(),
            }
        ));
        s.push_str(&format!("  \"service_ps\": [{}],\n", json_u64_list(&self.service_ps)));
        s.push_str(&format!(
            "  \"degraded_service_ps\": [{}],\n",
            json_u64_list(&self.degraded_service_ps)
        ));
        s.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            let c = &p.counters;
            s.push_str(&format!(
                "    {{\"load_frac\": {:.4}, \"offered_rps\": {:.3}, \
                 \"achieved_rps\": {:.3}, \"offered\": {}, \"served\": {}, \
                 \"shed_queue_full\": {}, \"shed_no_replica\": {}, \
                 \"shed_retries\": {}, \"shed_total\": {}, \"timed_out\": {}, \
                 \"slo_violations\": {}, \"retries\": {}, \"failovers\": {}, \
                 \"failover_served\": {}, \"failover_slo_ok\": {}, \
                 \"shed_accuracy_slo\": {}, \"recals\": {}, \"recal_drained\": {}, \
                 \"recal_downtime_ps\": {}, \"served_below_slo\": {}, \
                 \"batches\": {}, \"failed_batches\": {}, \"mean_batch\": {:.4}, \
                 \"p50_ps\": {}, \"p95_ps\": {}, \"p99_ps\": {}, \"mean_ps\": {}, \
                 \"max_ps\": {}, \"makespan_ps\": {}, \"per_replica_served\": [{}], \
                 \"fail_at_ps\": {}, \"rejoin_at_ps\": {}}}{}\n",
                p.load_frac,
                p.offered_rps,
                p.achieved_rps,
                c.offered,
                c.served,
                c.shed_queue_full,
                c.shed_no_replica,
                c.shed_retries,
                c.shed(),
                c.timed_out,
                c.slo_violations,
                c.retries,
                c.failovers,
                c.failover_served,
                c.failover_slo_ok,
                c.shed_accuracy_slo,
                c.recals,
                c.recal_drained,
                c.recal_downtime_ps,
                c.served_below_slo,
                c.batches,
                c.failed_batches,
                p.mean_batch,
                p.p50_ps,
                p.p95_ps,
                p.p99_ps,
                p.mean_ps,
                p.max_ps,
                p.makespan_ps,
                json_u64_list(&p.per_replica_served),
                match p.fail_at_ps {
                    Some(t) => t.to_string(),
                    None => "null".to_string(),
                },
                match p.rejoin_at_ps {
                    Some(t) => t.to_string(),
                    None => "null".to_string(),
                },
                if i + 1 < self.points.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Persist the curve as `BENCH_serving.json` (or wherever `path` says).
pub fn write_report(report: &ServeBenchReport, path: &str) -> std::io::Result<()> {
    std::fs::write(path, report.to_json())?;
    println!(
        "serve-bench: wrote {} load point(s){} to {path}",
        report.points.len(),
        if report.fail_replica.is_some() { " + failure plan" } else { "" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_opts() -> (ServeBenchOptions, InstantMockBackend) {
        let opts = ServeBenchOptions {
            requests: 128,
            queue_cap: 16,
            load_fracs: vec![0.2, 0.6, 0.95, 2.0],
            ..ServeBenchOptions::default()
        };
        (opts, InstantMockBackend::default())
    }

    #[test]
    fn curve_has_knee_shape_and_conserves_everywhere() {
        let (opts, backend) = mock_opts();
        let report = run_serve_bench_on(&opts, &backend).unwrap();
        assert_eq!(report.points.len(), 4);
        for p in &report.points {
            assert!(p.counters.conserved(), "{:?}", p.counters);
            assert!(p.counters.served > 0, "every point should serve something");
        }
        let first = &report.points[0];
        let last = &report.points[report.points.len() - 1];
        assert!(
            last.p99_ps > first.p99_ps,
            "p99 must grow toward saturation: {} !> {}",
            last.p99_ps,
            first.p99_ps
        );
        // Past saturation the system sheds or violates SLOs.
        assert!(
            last.counters.shed() + last.counters.timed_out + last.counters.slo_violations > 0,
            "overload point shows no distress: {:?}",
            last.counters
        );
        assert!(report.saturation_rps_measured > 0.0);
        assert!(report.saturation_rps_est > 0.0);
    }

    #[test]
    fn same_seed_is_byte_identical_at_any_jobs() {
        let (opts, backend) = mock_opts();
        let a = run_serve_bench_on(&ServeBenchOptions { jobs: 1, ..opts.clone() }, &backend)
            .unwrap()
            .to_json();
        let b = run_serve_bench_on(&ServeBenchOptions { jobs: 4, ..opts.clone() }, &backend)
            .unwrap()
            .to_json();
        assert_eq!(a, b, "serve-bench must be byte-identical across --jobs");
        // And a different seed must actually change the report.
        let c = run_serve_bench_on(
            &ServeBenchOptions { seed: opts.seed + 1, ..opts },
            &backend,
        )
        .unwrap()
        .to_json();
        assert_ne!(a, c, "the seed must matter");
    }

    #[test]
    fn mid_run_failure_fails_over_and_rejoins() {
        let (mut opts, backend) = mock_opts();
        opts.fail_replica = Some((1, 0.5));
        opts.load_fracs = vec![0.8];
        let report = run_serve_bench_on(&opts, &backend).unwrap();
        let p = &report.points[0];
        assert!(p.counters.conserved());
        assert!(p.fail_at_ps.is_some());
        assert!(
            p.counters.failovers > 0 || p.counters.shed() > 0,
            "a mid-run failure must be visible: {:?}",
            p.counters
        );
        // The degraded service table is the mock's 3x scaling.
        assert_eq!(report.degraded_service_ps[0], 3 * report.service_ps[0]);
    }

    #[test]
    fn report_json_is_parseable_shape() {
        let (opts, backend) = mock_opts();
        let report = run_serve_bench_on(&opts, &backend).unwrap();
        let text = report.to_json();
        assert!(text.trim_start().starts_with('{'));
        assert!(text.trim_end().ends_with('}'));
        assert!(text.contains("\"points\": ["));
        assert!(text.contains("\"p99_ps\""));
        assert!(text.contains("\"saturation_rps_est\""));
        assert!(text.contains("\"shed_queue_full\""));
    }

    #[test]
    fn bad_options_are_clean_errors() {
        let (opts, backend) = mock_opts();
        let oob = ServeBenchOptions { fail_replica: Some((9, 0.5)), ..opts.clone() };
        assert!(matches!(
            run_serve_bench_on(&oob, &backend),
            Err(WorkloadError::InvalidMapping(_))
        ));
        let empty = ServeBenchOptions { load_fracs: Vec::new(), ..opts.clone() };
        assert!(run_serve_bench_on(&empty, &backend).is_err());
        let zero = ServeBenchOptions { replicas: 0, ..opts };
        assert!(run_serve_bench_on(&zero, &backend).is_err());
    }

    #[test]
    fn point_seeds_are_distinct_streams() {
        let s: Vec<u64> = (0..8).map(|i| point_seed(7, i)).collect();
        for i in 0..s.len() {
            for j in (i + 1)..s.len() {
                assert_ne!(s[i], s[j]);
            }
        }
    }
}
