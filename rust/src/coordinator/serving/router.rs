//! The serving router: a deterministic discrete-event simulation of the
//! request path over sharded replicas, in virtual picoseconds.
//!
//! One event loop owns everything — arrivals, SLO-aware batch launches,
//! completions, hard replica failures, degraded rejoins. There is no
//! wall clock and no thread interleaving anywhere on the simulated
//! path, so a (config, arrival-trace) pair replays bit-identically on
//! any machine; `--jobs` parallelism lives one level up, across
//! independent load points.
//!
//! Robustness semantics (the ISSUE-9 pipeline):
//!
//! * **SLO-aware dynamic batching** — a replica launches either when
//!   its queue reaches `max_batch`, or at
//!   `min(oldest.deadline - service(b), oldest.arrival + batch_wait)`:
//!   it waits for more requests only while waiting cannot blow the
//!   oldest request's deadline (batch-deadline tradeoff, not
//!   fill-to-capacity).
//! * **Admission control** — per-replica bounded queues; when every
//!   live replica is full the request is shed as a typed
//!   `Rejected{queue_full}`; with no live replica at all,
//!   `Rejected{no_healthy_replica}`.
//! * **Timeout-drop** — queued requests whose deadline expires are
//!   dropped (typed) before every launch; a retry arriving past its
//!   deadline is dropped at routing.
//! * **Retry + failover** — a hard replica failure kills the in-flight
//!   batch; each victim retries with exponential backoff
//!   (`backoff * 2^(attempts-1)`) up to `max_retries`, then is shed as
//!   `Rejected{retries_exhausted}`. Queued requests on the failed
//!   replica fail over to survivors immediately. The replica rejoins
//!   `repair_ps` later in `Degraded` health, serving at the backend's
//!   degraded (re-simulated `degrade_mapping`) cost.
//!
//! The loop asserts conservation before returning: every offered
//! request resolves to exactly one of served / typed-shed /
//! typed-timeout.

//! The accuracy dimension (the ISSUE-10 pipeline):
//!
//! * **Drift monitoring** — with a [`RecalConfig`] attached, periodic
//!   health checks evaluate every replica's accuracy proxy (a function
//!   of `now - programmed_at`) and mark it `Fresh` / `DriftDegraded`.
//! * **Staggered recalibration** — `fixed`/`threshold` policies queue
//!   due replicas; at most one recalibrates at a time and never while
//!   another replica is hard-failed, so availability stays >= N-1.
//!   A window is planned drain (stop admitting, re-route the queue,
//!   let the in-flight batch finish) -> reprogram downtime -> rejoin
//!   fresh (`programmed_at = now`).
//! * **Accuracy-SLO routing** — accuracy-sensitive requests
//!   (`id % 1000 < sensitive_permille`) only go to replicas whose
//!   proxy meets the SLO, freshest first; with no compliant replica
//!   they shed typed (`Rejected{accuracy_slo}`). Non-sensitive
//!   requests served below the SLO are counted (`served_below_slo`),
//!   never silent.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::accuracy::{RecalConfig, RecalPolicy};
use super::backend::Backend;
use super::replica::{AccuracyHealth, Health, Replica, Request};
use super::stats::{Counters, LatencyStats};

/// How the router picks a replica for an admitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Rotate over replicas, skipping failed/full ones.
    RoundRobin,
    /// Fewest queued + in-flight requests (lowest index breaks ties).
    LeastLoaded,
    /// `id % replicas` is the preferred shard (weights stay hot in its
    /// AIMC tiles); fall forward to the next live replica when the
    /// preferred one is failed or full.
    CacheAffinity,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "rr" | "round-robin" => Some(RouterPolicy::RoundRobin),
            "least" | "least-loaded" => Some(RouterPolicy::LeastLoaded),
            "affinity" | "cache-affinity" => Some(RouterPolicy::CacheAffinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::CacheAffinity => "cache-affinity",
        }
    }
}

/// One load point's simulation knobs (all times in virtual ps).
pub struct SimConfig<'a> {
    pub backend: &'a dyn Backend,
    pub replicas: usize,
    /// Per-replica queue bound (admission control).
    pub queue_cap: usize,
    /// Per-request latency SLO, measured from arrival.
    pub deadline_ps: u64,
    /// Longest a partial batch waits for company.
    pub batch_wait_ps: u64,
    /// Retry budget after replica failures.
    pub max_retries: u32,
    /// First-retry backoff; doubles per attempt.
    pub backoff_base_ps: u64,
    /// Failure-to-rejoin repair time (models `degrade_mapping`
    /// re-simulation + tile reprogramming).
    pub repair_ps: u64,
    pub policy: RouterPolicy,
    /// Hard-fail replica `r` at absolute time `at_ps`.
    pub fail: Option<(usize, u64)>,
    /// Drift-aware accuracy monitoring + recalibration. `None` keeps
    /// the pre-drift router bit-identical.
    pub recal: Option<RecalConfig>,
}

/// One completed recalibration window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecalWindow {
    pub replica: usize,
    /// When the drain began (admission stopped).
    pub start_ps: u64,
    /// When the reprogram finished and the replica rejoined fresh.
    pub done_ps: u64,
}

/// Outcome of one simulated load point.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub counters: Counters,
    pub latencies: LatencyStats,
    /// Time of the last event processed (run horizon).
    pub makespan_ps: u64,
    pub per_replica_served: Vec<u64>,
    /// When the failed replica rejoined in `Degraded` health, if it did
    /// within the horizon.
    pub rejoin_at_ps: Option<u64>,
    /// Completed recalibration windows, in completion order — the
    /// accuracy-proxy timeline of the fleet is reconstructible from
    /// these plus the model.
    pub recal_windows: Vec<RecalWindow>,
    /// Fewest simultaneously dispatchable replicas (not failed, not
    /// recalibrating) observed at any event. Staggering keeps this at
    /// N-1 or better when no hard failure overlaps.
    pub min_available_replicas: usize,
}

enum EvKind {
    Arrive(Request),
    BatchTimer { r: usize, gen: u64 },
    BatchDone { r: usize, gen: u64 },
    Fail { r: usize },
    Rejoin { r: usize },
    /// Periodic fleet accuracy health check.
    RecalCheck,
    /// Reprogram downtime of replica `r` finished.
    RecalDone { r: usize },
}

/// Event queue: a min-heap of (time, seq). `seq` is the push order, so
/// simultaneous events pop in a deterministic total order and payloads
/// live in a slab indexed by seq.
struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64)>>,
    slab: Vec<Option<EvKind>>,
}

impl EventQueue {
    fn new(capacity: usize) -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(capacity), slab: Vec::with_capacity(capacity) }
    }

    fn push(&mut self, t: u64, kind: EvKind) {
        let seq = self.slab.len() as u64;
        self.slab.push(Some(kind));
        self.heap.push(Reverse((t, seq)));
    }

    fn pop(&mut self) -> Option<(u64, EvKind)> {
        let Reverse((t, seq)) = self.heap.pop()?;
        let kind = self.slab[seq as usize].take().expect("event popped twice");
        Some((t, kind))
    }
}

/// Service time of a batch of `b` on replica `r` given its health.
fn service_ps(cfg: &SimConfig, health: Health, b: usize) -> u64 {
    match health {
        Health::Degraded => cfg.backend.degraded_batch_ps(b).max(1),
        _ => cfg.backend.batch_ps(b).max(1),
    }
}

/// Launch a batch on replica `i` if its SLO-aware condition is met, or
/// (re)schedule the batch timer. Idempotent — safe to call after every
/// event that could change the replica's queue or health.
fn maybe_launch(
    i: usize,
    now: u64,
    cfg: &SimConfig,
    reps: &mut [Replica],
    counters: &mut Counters,
    events: &mut EventQueue,
) {
    let max_batch = cfg.backend.max_batch().max(1);
    let r = &mut reps[i];
    // A recalibrating replica never receives dispatches: its queue was
    // drained at window start and `admits` refuses new work, so this
    // guard is the launch-side half of the invariant (the BatchDone
    // handler asserts the completion-side half).
    if r.busy || r.health == Health::Failed || r.acc == AccuracyHealth::Recalibrating {
        return;
    }
    // Timeout-drop: expired requests can never be served in time.
    let mut dropped = 0u64;
    r.queue.retain(|q| {
        if q.deadline_ps <= now {
            dropped += 1;
            false
        } else {
            true
        }
    });
    counters.timed_out += dropped;
    if r.queue.is_empty() {
        return;
    }
    let b = r.queue.len().min(max_batch);
    let service = service_ps(cfg, r.health, b);
    let oldest = r.queue.front().expect("non-empty queue");
    // Latest launch that still meets the oldest request's deadline,
    // capped by the batching window from its arrival.
    let fire_deadline = oldest.deadline_ps.saturating_sub(service);
    let window = oldest.arrival_ps.saturating_add(cfg.batch_wait_ps);
    let fire_at = fire_deadline.min(window);
    if r.queue.len() >= max_batch || now >= fire_at {
        let batch: Vec<Request> = r.queue.drain(..b).collect();
        r.gen += 1;
        r.busy = true;
        r.timer = None;
        r.in_flight = batch;
        counters.batches += 1;
        counters.batched_requests += b as u64;
        events.push(now + service, EvKind::BatchDone { r: i, gen: r.gen });
    } else {
        // One pending wakeup is enough unless an earlier one is needed.
        match r.timer {
            Some((t, g)) if g == r.gen && t <= fire_at => {}
            _ => {
                r.timer = Some((fire_at, r.gen));
                events.push(fire_at, EvKind::BatchTimer { r: i, gen: r.gen });
            }
        }
    }
}

/// Run the discrete-event loop over the arrival trace. Panics if the
/// conservation invariant breaks — that is a router bug, not a load
/// condition.
pub fn simulate(cfg: &SimConfig, arrivals_ps: &[u64]) -> SimResult {
    assert!(cfg.replicas >= 1, "serving needs at least one replica");
    let n = cfg.replicas;
    let mut reps: Vec<Replica> = (0..n).map(|_| Replica::new()).collect();
    let mut counters = Counters { offered: arrivals_ps.len() as u64, ..Counters::default() };
    let mut latencies = LatencyStats::default();
    let mut events = EventQueue::new(arrivals_ps.len() * 2 + 8);
    let mut rr_cursor = 0usize;
    let mut rejoin_at_ps = None;
    let mut makespan_ps = 0u64;
    let mut recal_windows: Vec<RecalWindow> = Vec::new();
    // Recalibration bookkeeping: at most one window at a time.
    let mut recal_active: Option<usize> = None;
    let mut recal_pending = vec![false; n];
    let mut recal_started_at = vec![0u64; n];
    let mut min_available_replicas = n;

    for (id, &t) in arrivals_ps.iter().enumerate() {
        events.push(
            t,
            EvKind::Arrive(Request {
                id: id as u64,
                arrival_ps: t,
                deadline_ps: t.saturating_add(cfg.deadline_ps),
                attempts: 0,
                failovers: 0,
            }),
        );
    }
    if let Some((r, at_ps)) = cfg.fail {
        assert!(r < n, "--fail-replica {r}: only {n} replica(s)");
        events.push(at_ps, EvKind::Fail { r });
    }
    if let Some(rc) = &cfg.recal {
        // Health checks over the whole horizon, scheduled up front so
        // the event count is fixed by the config, not the load.
        assert!(rc.check_period_ps > 0, "recal check period must be positive");
        let horizon = arrivals_ps
            .last()
            .copied()
            .unwrap_or(0)
            .saturating_add(cfg.deadline_ps);
        let mut t = rc.check_period_ps;
        while t <= horizon {
            events.push(t, EvKind::RecalCheck);
            t = t.saturating_add(rc.check_period_ps);
        }
    }

    // Replicas that can take a dispatch right now.
    let available =
        |reps: &[Replica]| {
            reps.iter()
                .filter(|r| {
                    r.health != Health::Failed && r.acc != AccuracyHealth::Recalibrating
                })
                .count()
        };
    // Begin replica `ri`'s window: planned drain (stop admitting,
    // re-route the queue, let any in-flight batch finish), then the
    // reprogram downtime, scheduled here or at the drain's BatchDone.
    #[allow(clippy::too_many_arguments)]
    fn start_recal(
        ri: usize,
        now: u64,
        rc: &RecalConfig,
        reps: &mut [Replica],
        counters: &mut Counters,
        events: &mut EventQueue,
        recal_active: &mut Option<usize>,
        recal_pending: &mut [bool],
        recal_started_at: &mut [u64],
    ) {
        *recal_active = Some(ri);
        recal_pending[ri] = false;
        recal_started_at[ri] = now;
        reps[ri].acc = AccuracyHealth::Recalibrating;
        reps[ri].timer = None;
        let drained: Vec<Request> = reps[ri].queue.drain(..).collect();
        for q in drained {
            // Planned re-route: no retry budget consumed, not a failover.
            counters.recal_drained += 1;
            events.push(now, EvKind::Arrive(q));
        }
        if reps[ri].busy {
            reps[ri].draining = true; // BatchDone starts the downtime
        } else {
            counters.recal_downtime_ps += rc.reprogram_ps;
            events.push(now + rc.reprogram_ps.max(1), EvKind::RecalDone { r: ri });
        }
    }
    // Start the stalest pending window if none is active and no hard
    // failure already has the fleet below N-1 (single-replica fleets
    // have an N-1 floor of zero, so they may recal).
    #[allow(clippy::too_many_arguments)]
    fn try_start_recal(
        now: u64,
        rc: &RecalConfig,
        reps: &mut [Replica],
        counters: &mut Counters,
        events: &mut EventQueue,
        recal_active: &mut Option<usize>,
        recal_pending: &mut [bool],
        recal_started_at: &mut [u64],
    ) {
        if recal_active.is_some() {
            return;
        }
        let n = reps.len();
        if n > 1 && reps.iter().any(|r| r.health == Health::Failed) {
            return;
        }
        let due = (0..n)
            .filter(|&i| recal_pending[i] && reps[i].health != Health::Failed)
            .min_by_key(|&i| (reps[i].programmed_at_ps, i));
        if let Some(ri) = due {
            start_recal(
                ri,
                now,
                rc,
                reps,
                counters,
                events,
                recal_active,
                recal_pending,
                recal_started_at,
            );
        }
    }

    while let Some((now, kind)) = events.pop() {
        makespan_ps = makespan_ps.max(now);
        // Availability floor, sampled between events (every transition
        // that lowers it schedules a follow-up event, so the lowered
        // state is always observed here).
        min_available_replicas = min_available_replicas.min(available(&reps));
        match kind {
            EvKind::Arrive(req) => {
                // A retried request may already be past its deadline.
                if req.deadline_ps <= now {
                    counters.timed_out += 1;
                    continue;
                }
                if available(&reps) == 0 {
                    counters.shed_no_replica += 1;
                    continue;
                }
                // Accuracy-sensitive requests only go to replicas whose
                // proxy meets the accuracy SLO, freshest first; if no
                // compliant replica exists they shed typed — never a
                // silent wrong answer.
                if let Some(rc) = &cfg.recal {
                    if rc.sensitive(req.id) {
                        let compliant = |i: usize| {
                            reps[i].health != Health::Failed
                                && reps[i].acc != AccuracyHealth::Recalibrating
                                && rc.model
                                    .proxy_at(now.saturating_sub(reps[i].programmed_at_ps))
                                    >= rc.slo
                        };
                        if !(0..n).any(|i| compliant(i)) {
                            counters.shed_accuracy_slo += 1;
                            continue;
                        }
                        let pick = (0..n)
                            .filter(|&i| compliant(i) && reps[i].admits(cfg.queue_cap))
                            .max_by_key(|&i| (reps[i].programmed_at_ps, Reverse(i)));
                        match pick {
                            None => counters.shed_queue_full += 1,
                            Some(i) => {
                                reps[i].queue.push_back(req);
                                maybe_launch(i, now, cfg, &mut reps, &mut counters, &mut events);
                            }
                        }
                        continue;
                    }
                }
                let pick = match cfg.policy {
                    RouterPolicy::RoundRobin => {
                        let found = (0..n)
                            .map(|k| (rr_cursor + k) % n)
                            .find(|&i| reps[i].admits(cfg.queue_cap));
                        if let Some(i) = found {
                            rr_cursor = (i + 1) % n;
                        }
                        found
                    }
                    RouterPolicy::LeastLoaded => (0..n)
                        .filter(|&i| reps[i].admits(cfg.queue_cap))
                        .min_by_key(|&i| (reps[i].load(), i)),
                    RouterPolicy::CacheAffinity => {
                        let pref = (req.id % n as u64) as usize;
                        (0..n)
                            .map(|k| (pref + k) % n)
                            .find(|&i| reps[i].admits(cfg.queue_cap))
                    }
                };
                match pick {
                    None => counters.shed_queue_full += 1,
                    Some(i) => {
                        reps[i].queue.push_back(req);
                        maybe_launch(i, now, cfg, &mut reps, &mut counters, &mut events);
                    }
                }
            }
            EvKind::BatchTimer { r: ri, gen } => {
                if reps[ri].gen != gen {
                    continue; // a launch or failure superseded this wakeup
                }
                reps[ri].timer = None;
                maybe_launch(ri, now, cfg, &mut reps, &mut counters, &mut events);
            }
            EvKind::BatchDone { r: ri, gen } => {
                if reps[ri].gen != gen || !reps[ri].busy {
                    continue; // the failure event already ate this batch
                }
                // A completion on a recalibrating replica is legal only
                // for the batch the planned drain let finish; anything
                // else means a dispatch slipped into the window.
                if reps[ri].acc == AccuracyHealth::Recalibrating {
                    assert!(
                        reps[ri].draining,
                        "batch completed on recalibrating replica {ri} outside its drain"
                    );
                }
                reps[ri].busy = false;
                let b = reps[ri].in_flight.len() as u64;
                let batch = std::mem::take(&mut reps[ri].in_flight);
                for q in batch {
                    counters.served += 1;
                    reps[ri].served += 1;
                    latencies.record(now - q.arrival_ps);
                    if now > q.deadline_ps {
                        counters.slo_violations += 1;
                    }
                    if q.failovers > 0 {
                        counters.failover_served += 1;
                        if now <= q.deadline_ps {
                            counters.failover_slo_ok += 1;
                        }
                    }
                }
                if let Some(rc) = &cfg.recal {
                    // Known-stale ledger: answers served below the
                    // accuracy SLO are counted, never silent.
                    let proxy =
                        rc.model.proxy_at(now.saturating_sub(reps[ri].programmed_at_ps));
                    if proxy < rc.slo {
                        counters.served_below_slo += b;
                    }
                    if reps[ri].draining {
                        // Drain complete: the reprogram downtime starts.
                        reps[ri].draining = false;
                        counters.recal_downtime_ps += rc.reprogram_ps;
                        events.push(now + rc.reprogram_ps.max(1), EvKind::RecalDone { r: ri });
                        continue;
                    }
                }
                maybe_launch(ri, now, cfg, &mut reps, &mut counters, &mut events);
            }
            EvKind::Fail { r: ri } => {
                if reps[ri].health == Health::Failed {
                    continue;
                }
                reps[ri].health = Health::Failed;
                reps[ri].gen += 1;
                reps[ri].timer = None;
                if reps[ri].busy {
                    counters.failed_batches += 1;
                }
                reps[ri].busy = false;
                // In-flight victims: bounded retry with exponential
                // backoff (they consumed a service attempt).
                let orphans = std::mem::take(&mut reps[ri].in_flight);
                for mut q in orphans {
                    q.attempts += 1;
                    q.failovers += 1;
                    if q.attempts > cfg.max_retries {
                        counters.shed_retries += 1;
                    } else {
                        counters.retries += 1;
                        counters.failovers += 1;
                        let backoff = cfg
                            .backoff_base_ps
                            .max(1)
                            .saturating_mul(1u64 << (q.attempts - 1).min(16));
                        events.push(now + backoff, EvKind::Arrive(q));
                    }
                }
                // Queued requests were never attempted: fail over to the
                // survivors immediately, no retry budget consumed.
                let queued: Vec<Request> = reps[ri].queue.drain(..).collect();
                for mut q in queued {
                    q.failovers += 1;
                    counters.failovers += 1;
                    events.push(now, EvKind::Arrive(q));
                }
                events.push(now + cfg.repair_ps.max(1), EvKind::Rejoin { r: ri });
                // A failure mid-drain kills the batch the drain was
                // waiting on; start the reprogram downtime now so the
                // window (and `recal_active`) cannot leak.
                if reps[ri].acc == AccuracyHealth::Recalibrating && reps[ri].draining {
                    if let Some(rc) = &cfg.recal {
                        reps[ri].draining = false;
                        counters.recal_downtime_ps += rc.reprogram_ps;
                        events.push(now + rc.reprogram_ps.max(1), EvKind::RecalDone { r: ri });
                    }
                }
            }
            EvKind::Rejoin { r: ri } => {
                reps[ri].health = Health::Degraded;
                rejoin_at_ps = Some(now);
                maybe_launch(ri, now, cfg, &mut reps, &mut counters, &mut events);
                if let Some(rc) = &cfg.recal {
                    try_start_recal(
                        now,
                        rc,
                        &mut reps,
                        &mut counters,
                        &mut events,
                        &mut recal_active,
                        &mut recal_pending,
                        &mut recal_started_at,
                    );
                }
            }
            EvKind::RecalCheck => {
                let Some(rc) = &cfg.recal else { continue };
                for i in 0..n {
                    if reps[i].health == Health::Failed
                        || reps[i].acc == AccuracyHealth::Recalibrating
                    {
                        continue;
                    }
                    let age = now.saturating_sub(reps[i].programmed_at_ps);
                    let proxy = rc.model.proxy_at(age);
                    reps[i].acc = if proxy < rc.degrade_at {
                        AccuracyHealth::DriftDegraded
                    } else {
                        AccuracyHealth::Fresh
                    };
                    let due = match rc.policy {
                        RecalPolicy::Never => false,
                        RecalPolicy::Fixed { period_ps } => age >= period_ps,
                        RecalPolicy::Threshold { trigger } => proxy < trigger,
                    };
                    if due {
                        recal_pending[i] = true;
                    }
                }
                try_start_recal(
                    now,
                    rc,
                    &mut reps,
                    &mut counters,
                    &mut events,
                    &mut recal_active,
                    &mut recal_pending,
                    &mut recal_started_at,
                );
            }
            EvKind::RecalDone { r: ri } => {
                let Some(rc) = &cfg.recal else { continue };
                debug_assert_eq!(recal_active, Some(ri), "recal window not owned by {ri}");
                // Rejoin fresh: the reprogram resets the drift clock.
                reps[ri].programmed_at_ps = now;
                reps[ri].recals += 1;
                if reps[ri].acc == AccuracyHealth::Recalibrating {
                    reps[ri].acc = AccuracyHealth::Fresh;
                }
                reps[ri].draining = false;
                counters.recals += 1;
                recal_windows.push(RecalWindow {
                    replica: ri,
                    start_ps: recal_started_at[ri],
                    done_ps: now,
                });
                recal_active = None;
                maybe_launch(ri, now, cfg, &mut reps, &mut counters, &mut events);
                try_start_recal(
                    now,
                    rc,
                    &mut reps,
                    &mut counters,
                    &mut events,
                    &mut recal_active,
                    &mut recal_pending,
                    &mut recal_started_at,
                );
            }
        }
    }

    assert!(
        counters.conserved(),
        "serving conservation violated: served {} + shed {} + timed_out {} != offered {}",
        counters.served,
        counters.shed(),
        counters.timed_out,
        counters.offered
    );
    SimResult {
        counters,
        latencies,
        makespan_ps,
        per_replica_served: reps.iter().map(|r| r.served).collect(),
        rejoin_at_ps,
        recal_windows,
        min_available_replicas,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::backend::InstantMockBackend;

    fn mock() -> InstantMockBackend {
        InstantMockBackend::default() // batch_ps(b) = 10_000 + 1_000 b
    }

    fn base_cfg(backend: &InstantMockBackend) -> SimConfig<'_> {
        SimConfig {
            backend,
            replicas: 2,
            queue_cap: 32,
            deadline_ps: 200_000,
            batch_wait_ps: 10_000,
            max_retries: 3,
            backoff_base_ps: 1_000,
            repair_ps: 100_000,
            policy: RouterPolicy::LeastLoaded,
            fail: None,
            recal: None,
        }
    }

    /// Evenly spaced arrivals, one every `gap` ps starting at `gap`.
    fn uniform(n: usize, gap: u64) -> Vec<u64> {
        (1..=n as u64).map(|k| k * gap).collect()
    }

    #[test]
    fn trickle_serves_everything_within_deadline() {
        let b = mock();
        let cfg = base_cfg(&b);
        // One request per 50 us >> service time: no queueing at all.
        let res = simulate(&cfg, &uniform(20, 50_000_000));
        assert_eq!(res.counters.served, 20);
        assert_eq!(res.counters.shed(), 0);
        assert_eq!(res.counters.timed_out, 0);
        assert_eq!(res.counters.slo_violations, 0);
        assert!(res.counters.conserved());
        // Latency = batch_wait (no company arrives) + single service.
        assert_eq!(res.latencies.max_ps(), cfg.batch_wait_ps + b.batch_ps(1));
    }

    #[test]
    fn full_queue_batches_launch_immediately() {
        let b = mock();
        let cfg = SimConfig { replicas: 1, ..base_cfg(&b) };
        // 8 simultaneous arrivals == max_batch: launches with no wait.
        let res = simulate(&cfg, &vec![100; 8]);
        assert_eq!(res.counters.served, 8);
        assert_eq!(res.counters.batches, 1);
        assert_eq!(res.latencies.max_ps(), b.batch_ps(8));
    }

    #[test]
    fn deadline_pressure_launches_partial_batches_early() {
        let b = mock();
        // Deadline so tight the router cannot afford the full window.
        let cfg = SimConfig {
            replicas: 1,
            deadline_ps: b.batch_ps(1) + 2_000,
            batch_wait_ps: 1_000_000,
            ..base_cfg(&b)
        };
        let res = simulate(&cfg, &[100]);
        assert_eq!(res.counters.served, 1);
        assert_eq!(res.counters.slo_violations, 0, "SLO-aware launch must beat the deadline");
        // Launched at deadline - service, not after the 1 ms window.
        assert_eq!(res.latencies.max_ps(), 2_000 + b.batch_ps(1));
    }

    #[test]
    fn round_robin_rotates_and_affinity_pins() {
        let b = mock();
        let arrivals = uniform(8, 50_000_000);
        let rr = simulate(
            &SimConfig { policy: RouterPolicy::RoundRobin, ..base_cfg(&b) },
            &arrivals,
        );
        assert_eq!(rr.per_replica_served, vec![4, 4]);
        let aff = simulate(
            &SimConfig { policy: RouterPolicy::CacheAffinity, ..base_cfg(&b) },
            &arrivals,
        );
        // ids alternate 0/1 -> shards alternate too.
        assert_eq!(aff.per_replica_served, vec![4, 4]);
    }

    #[test]
    fn overload_sheds_typed_and_conserves() {
        let b = mock();
        let cfg = SimConfig { replicas: 1, queue_cap: 4, ..base_cfg(&b) };
        // 64 simultaneous arrivals into one replica with queue cap 4:
        // the queue fills, the rest shed at admission.
        let res = simulate(&cfg, &vec![100; 64]);
        assert!(res.counters.shed_queue_full > 0, "backpressure must shed");
        assert!(res.counters.conserved());
        assert_eq!(res.counters.shed_no_replica, 0);
    }

    #[test]
    fn failure_with_single_replica_sheds_no_healthy_until_rejoin() {
        let b = mock();
        let cfg = SimConfig {
            replicas: 1,
            fail: Some((0, 150)),
            repair_ps: 1_000_000,
            deadline_ps: 10_000_000,
            ..base_cfg(&b)
        };
        // First arrival is queued when the failure hits (it fails over,
        // finds no live replica, and sheds typed); the rest arrive while
        // the only replica is down.
        let arrivals = vec![100, 200_000, 300_000];
        let res = simulate(&cfg, &arrivals);
        assert!(res.counters.shed_no_replica > 0, "{:?}", res.counters);
        assert!(res.counters.conserved());
        assert_eq!(res.rejoin_at_ps, Some(150 + 1_000_000));
    }

    #[test]
    fn degraded_rejoin_serves_at_degraded_cost() {
        let b = mock();
        let cfg = SimConfig {
            replicas: 1,
            fail: Some((0, 10)),
            repair_ps: 1_000,
            deadline_ps: 10_000_000,
            batch_wait_ps: 0,
            max_retries: 3,
            ..base_cfg(&b)
        };
        // Arrives after the rejoin: served by the degraded replica.
        let res = simulate(&cfg, &[5_000]);
        assert_eq!(res.counters.served, 1);
        assert_eq!(res.latencies.max_ps(), b.degraded_batch_ps(1));
    }

    const S: u64 = 1_000_000_000_000; // 1 s in ps

    fn recal_cfg(policy: RecalPolicy, sensitive_permille: u32) -> RecalConfig {
        RecalConfig {
            // proxy = 1 - 0.001 * age_s: crosses 0.9 at age 100 s.
            model: crate::coordinator::serving::AccuracyModel::Linear { decay_per_s: 0.001 },
            slo: 0.9,
            degrade_at: 0.95,
            sensitive_permille,
            policy,
            check_period_ps: 50 * S,
            reprogram_ps: S,
        }
    }

    #[test]
    fn threshold_policy_recalibrates_staggered_and_conserves() {
        let b = mock();
        let cfg = SimConfig {
            recal: Some(recal_cfg(RecalPolicy::Threshold { trigger: 0.9 }, 0)),
            ..base_cfg(&b)
        };
        // One request every 10 s over ~400 s of virtual time.
        let arrivals: Vec<u64> = (1..=40u64).map(|k| k * 10 * S).collect();
        let res = simulate(&cfg, &arrivals);
        assert!(res.counters.conserved());
        assert!(res.counters.recals >= 2, "both replicas should refresh: {:?}", res.counters);
        assert_eq!(res.counters.recals as usize, res.recal_windows.len());
        // Staggered: never more than one replica out at a time.
        assert_eq!(res.min_available_replicas, 1);
        for w in res.recal_windows.windows(2) {
            assert!(w[0].done_ps <= w[1].start_ps, "windows overlap: {w:?}");
        }
        // Downtime ledger matches the windows (drain wait excluded).
        assert_eq!(res.counters.recal_downtime_ps, res.counters.recals * S);
        assert!(res.counters.served > 0);
    }

    #[test]
    fn never_policy_sheds_sensitive_requests_once_drifted() {
        let b = mock();
        let cfg = SimConfig {
            replicas: 1,
            deadline_ps: 10_000_000,
            recal: Some(recal_cfg(RecalPolicy::Never, 1000)),
            ..base_cfg(&b)
        };
        // Age 10 s: proxy 0.99 >= 0.9 -> served. Age 200 s: proxy 0.8
        // -> no compliant replica -> typed accuracy shed.
        let res = simulate(&cfg, &[10 * S, 200 * S]);
        assert_eq!(res.counters.served, 1);
        assert_eq!(res.counters.shed_accuracy_slo, 1);
        assert_eq!(res.counters.recals, 0);
        assert!(res.counters.conserved());
        // Non-sensitive traffic is still served, but on the ledger.
        let lax = SimConfig {
            replicas: 1,
            deadline_ps: 10_000_000,
            recal: Some(recal_cfg(RecalPolicy::Never, 0)),
            ..base_cfg(&b)
        };
        let res = simulate(&lax, &[10 * S, 200 * S]);
        assert_eq!(res.counters.served, 2);
        assert_eq!(res.counters.shed_accuracy_slo, 0);
        assert_eq!(res.counters.served_below_slo, 1, "stale answer must be counted");
    }

    #[test]
    fn fixed_policy_refreshes_and_sensitive_requests_pick_the_freshest() {
        let b = mock();
        // Refresh every 50 s of age, checked every 25 s: the worst-case
        // age at a refresh is ~75 s (proxy 0.925), comfortably over the
        // 0.9 SLO even while the sibling replica recalibrates.
        let mut rc = recal_cfg(RecalPolicy::Fixed { period_ps: 50 * S }, 1000);
        rc.check_period_ps = 25 * S;
        let cfg = SimConfig { deadline_ps: 10_000_000, recal: Some(rc), ..base_cfg(&b) };
        let arrivals: Vec<u64> = (1..=30u64).map(|k| k * 10 * S).collect();
        let res = simulate(&cfg, &arrivals);
        assert!(res.counters.conserved());
        assert!(res.counters.recals >= 2, "{:?}", res.counters);
        // Fixed refresh keeps every replica inside the SLO: nothing
        // sheds on accuracy and nothing is served stale.
        assert_eq!(res.counters.shed_accuracy_slo, 0);
        assert_eq!(res.counters.served_below_slo, 0);
        assert_eq!(res.min_available_replicas, 1);
    }
}
