//! The accuracy dimension of serving: how a replica's answer quality
//! decays with time since its analog tiles were programmed, and when
//! the router schedules a reprogramming (recalibration) window.
//!
//! The physics lives in `aimclib::faults` (`G(t) = G(t0) * (t/t0)^-nu`
//! plus log-time-growing per-device dispersion); this module reduces it
//! to a deterministic `age -> accuracy proxy` curve the router can
//! evaluate at every routing decision without re-running the checker.

use crate::aimclib::faults::DriftState;

/// Picoseconds per second.
const PS_PER_S: f64 = 1.0e12;

/// Deterministic accuracy-proxy curve over tile age. The proxy is the
/// top-1 agreement of `aimclib::faults::assess_mvm` (1.0 = answers
/// indistinguishable from a freshly programmed tile).
#[derive(Clone, Debug, PartialEq)]
pub enum AccuracyModel {
    /// No aging: the proxy is 1.0 forever (drift-free fleets).
    None,
    /// Closed-form test model: `proxy = 1 - decay_per_s * age_s`,
    /// floored at 0. Cheap and exactly analyzable — the serving
    /// minprops use it so expected shed counts are integer-checkable.
    Linear { decay_per_s: f64 },
    /// Sampled from the real checker at log-spaced ages, interpolated
    /// linearly in `ln(age)` (drift is a power law, so the proxy is
    /// near-linear on a log-time axis). Ages ascending, same length as
    /// `proxy`; clamps at both ends.
    Table { ages_ps: Vec<u64>, proxy: Vec<f64> },
}

impl AccuracyModel {
    /// The accuracy proxy of a tile `age_ps` after programming.
    pub fn proxy_at(&self, age_ps: u64) -> f64 {
        match self {
            AccuracyModel::None => 1.0,
            AccuracyModel::Linear { decay_per_s } => {
                (1.0 - decay_per_s * (age_ps as f64 / PS_PER_S)).clamp(0.0, 1.0)
            }
            AccuracyModel::Table { ages_ps, proxy } => {
                debug_assert_eq!(ages_ps.len(), proxy.len());
                if ages_ps.is_empty() {
                    return 1.0;
                }
                if age_ps <= ages_ps[0] {
                    return proxy[0];
                }
                if age_ps >= *ages_ps.last().unwrap() {
                    return *proxy.last().unwrap();
                }
                let i = ages_ps.partition_point(|&a| a <= age_ps);
                let (a0, a1) = (ages_ps[i - 1] as f64, ages_ps[i] as f64);
                let (p0, p1) = (proxy[i - 1], proxy[i]);
                // Interpolate on ln(age); ages are >= 1 ps here.
                let f = (age_ps as f64).ln() - a0.ln();
                let span = a1.ln() - a0.ln();
                if span <= 0.0 {
                    return p0;
                }
                p0 + (p1 - p0) * (f / span)
            }
        }
    }

    /// Sample the real checker's accuracy proxy for `drift` at `steps`
    /// log-spaced ages from 1 s to `horizon_s`, on a `rows x cols`
    /// probe layer over `tile_rows x tile_cols` tiles. Deterministic in
    /// the drift seed.
    #[allow(clippy::too_many_arguments)]
    pub fn table_from_drift(
        drift: &DriftState,
        horizon_s: f64,
        steps: usize,
        rows: usize,
        cols: usize,
        tile_rows: usize,
        tile_cols: usize,
        batch: usize,
    ) -> AccuracyModel {
        let steps = steps.max(2);
        let horizon_s = horizon_s.max(2.0);
        let probe = DriftState { programmed_at_ps: 0, ..*drift };
        let mut ages_ps = Vec::with_capacity(steps);
        let mut proxy = Vec::with_capacity(steps);
        let ln_hi = horizon_s.ln();
        for i in 0..steps {
            let age_s = (ln_hi * i as f64 / (steps - 1) as f64).exp();
            let age_ps = (age_s * PS_PER_S).round() as u64;
            let impact = probe.assess_at(age_ps, rows, cols, tile_rows, tile_cols, batch);
            ages_ps.push(age_ps);
            proxy.push(impact.top1_agreement);
        }
        AccuracyModel::Table { ages_ps, proxy }
    }
}

/// When does a replica get reprogrammed?
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RecalPolicy {
    /// Never: the fleet ages until the accuracy SLO bites.
    Never,
    /// Every `period_ps` of tile age, regardless of measured health.
    Fixed { period_ps: u64 },
    /// When a health check measures the proxy below `trigger`.
    Threshold { trigger: f64 },
}

impl RecalPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RecalPolicy::Never => "never",
            RecalPolicy::Fixed { .. } => "fixed",
            RecalPolicy::Threshold { .. } => "threshold",
        }
    }

    /// Parse `never`, `fixed:<seconds>`, or `threshold:<proxy>`.
    pub fn parse(s: &str) -> Result<RecalPolicy, String> {
        if s == "never" {
            return Ok(RecalPolicy::Never);
        }
        if let Some(v) = s.strip_prefix("fixed:") {
            let secs: f64 = v.parse().map_err(|_| format!("bad fixed period: {v}"))?;
            if secs <= 0.0 {
                return Err(format!("fixed period must be positive: {v}"));
            }
            return Ok(RecalPolicy::Fixed { period_ps: (secs * PS_PER_S).round() as u64 });
        }
        if let Some(v) = s.strip_prefix("threshold:") {
            let t: f64 = v.parse().map_err(|_| format!("bad threshold: {v}"))?;
            if !(0.0..=1.0).contains(&t) {
                return Err(format!("threshold must be in [0, 1]: {v}"));
            }
            return Ok(RecalPolicy::Threshold { trigger: t });
        }
        Err(format!("unknown recal policy: {s} (never | fixed:<s> | threshold:<proxy>)"))
    }
}

/// Drift-aware serving configuration: the accuracy model, the SLO the
/// router enforces for accuracy-sensitive traffic, and the
/// recalibration schedule.
#[derive(Clone, Debug)]
pub struct RecalConfig {
    /// `age -> proxy` curve shared by every replica of the fleet.
    pub model: AccuracyModel,
    /// The accuracy SLO: minimum proxy an accuracy-sensitive request
    /// may be served at. Below it the router sheds (`accuracy_slo`).
    pub slo: f64,
    /// Proxy below which a replica is *marked* `DriftDegraded` at
    /// health checks (routing preference; usually a bit above `slo`).
    pub degrade_at: f64,
    /// Requests with `id % 1000 < sensitive_permille` are
    /// accuracy-sensitive (deterministic in the request id; 1000 =
    /// every request, 0 = none).
    pub sensitive_permille: u32,
    /// Recalibration schedule.
    pub policy: RecalPolicy,
    /// Health-check cadence in virtual ps (drift evolves over seconds,
    /// so checks are far sparser than arrivals).
    pub check_period_ps: u64,
    /// Reprogram downtime of one recalibration window, ps (see
    /// `aimclib::faults::reprogram_cost`).
    pub reprogram_ps: u64,
}

impl RecalConfig {
    /// Is request `id` accuracy-sensitive under this config?
    pub fn sensitive(&self, id: u64) -> bool {
        id % 1000 < self.sensitive_permille as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000_000;

    #[test]
    fn linear_model_decays_and_floors() {
        let m = AccuracyModel::Linear { decay_per_s: 0.001 };
        assert_eq!(m.proxy_at(0), 1.0);
        assert!((m.proxy_at(100 * S) - 0.9).abs() < 1e-9);
        assert_eq!(m.proxy_at(2_000_000 * S), 0.0);
        assert_eq!(AccuracyModel::None.proxy_at(u64::MAX), 1.0);
    }

    #[test]
    fn table_model_interpolates_in_log_age_and_clamps() {
        let m = AccuracyModel::Table {
            ages_ps: vec![S, 100 * S, 10_000 * S],
            proxy: vec![1.0, 0.8, 0.4],
        };
        assert_eq!(m.proxy_at(0), 1.0);
        assert_eq!(m.proxy_at(S), 1.0);
        assert_eq!(m.proxy_at(100 * S), 0.8);
        assert_eq!(m.proxy_at(1_000_000 * S), 0.4);
        // ln-midpoint of [1 s, 100 s] is 10 s -> halfway proxy.
        assert!((m.proxy_at(10 * S) - 0.9).abs() < 1e-6);
        let mid = m.proxy_at(1_000 * S);
        assert!((mid - 0.6).abs() < 1e-6, "{mid}");
    }

    #[test]
    fn table_from_drift_is_monotone_enough_and_deterministic() {
        let d = DriftState::new(21, 0.05, 0.02);
        let m = AccuracyModel::table_from_drift(&d, 1.0e8, 6, 64, 32, 64, 32, 16);
        let m2 = AccuracyModel::table_from_drift(&d, 1.0e8, 6, 64, 32, 64, 32, 16);
        assert_eq!(m, m2);
        let AccuracyModel::Table { ages_ps, proxy } = &m else { panic!("not a table") };
        assert_eq!(ages_ps.len(), 6);
        assert_eq!(proxy[0], 1.0, "fresh tile must probe perfect");
        assert!(
            proxy.last().unwrap() < &0.95,
            "century-scale drift should visibly degrade top-1: {proxy:?}"
        );
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(RecalPolicy::parse("never").unwrap(), RecalPolicy::Never);
        assert_eq!(
            RecalPolicy::parse("fixed:100").unwrap(),
            RecalPolicy::Fixed { period_ps: 100 * S }
        );
        assert_eq!(
            RecalPolicy::parse("threshold:0.9").unwrap(),
            RecalPolicy::Threshold { trigger: 0.9 }
        );
        assert!(RecalPolicy::parse("sometimes").is_err());
        assert!(RecalPolicy::parse("fixed:-1").is_err());
        assert!(RecalPolicy::parse("threshold:1.5").is_err());
    }

    #[test]
    fn sensitivity_is_deterministic_in_the_id() {
        let cfg = RecalConfig {
            model: AccuracyModel::None,
            slo: 0.9,
            degrade_at: 0.95,
            sensitive_permille: 250,
            policy: RecalPolicy::Never,
            check_period_ps: S,
            reprogram_ps: S,
        };
        let n = (0..4000).filter(|&id| cfg.sensitive(id)).count();
        assert_eq!(n, 1000, "250 permille of 4000 ids");
        assert!(cfg.sensitive(0) && !cfg.sensitive(999));
    }
}
