//! The `alpine faults` scenario driver: sweep fault intensity from 0
//! (fault-free) to 1 and measure graceful degradation on both axes of
//! the model —
//!
//! * **accuracy**: a seed-driven [`FaultPlan`] (conductance noise,
//!   drift, stuck lines) applied to the checker's programmed weights,
//!   scored by [`assess_mvm`] against the fault-free checker;
//! * **timing/energy**: deterministic transient tile stalls
//!   ([`TileFaultModel`]) injected into every tile of the automap-best
//!   MLP pipeline, simulated end to end.
//!
//! With `--fail-tile T@C` a hard tile failure is injected at cycle `C`,
//! the typed [`RunError`] it surfaces is recorded, and the
//! graceful-degradation pass ([`automap::degrade_mapping`]) remaps the
//! failed tile's anchors to the digital CPU path and re-simulates —
//! reporting the degraded cycle/energy cost instead of crashing.
//!
//! Determinism: intensity points fan out over `util::parallel` in input
//! order, every point re-derives its own state from the scenario seed,
//! and the intensity-0 point runs the unmodified fault-free machine —
//! so reports are bit-identical at any `--jobs N` and the zero point is
//! bit-identical to a plain `run_workload` of the same mapping.

use crate::aimclib::faults::{assess_mvm, FaultPlan};
use crate::config::{SystemConfig, SystemKind};
use crate::nn::LayerGraph;
use crate::sim::{RunError, TileFaultModel};
use crate::util::parallel;
use crate::workload::automap::{self, SearchOptions, TopologyBudget};
use crate::workload::{compile, WorkloadError};

use super::{run_workload, CaseResult, RunOptions};

/// PCM drift exponent used by the sweep (Le Gallo et al., ~0.05).
pub const DRIFT_NU: f64 = 0.05;

/// Window of the deterministic transient-stall model (1 us).
pub const TRANSIENT_PERIOD_PS: u64 = 1_000_000;

/// Knobs of one fault sweep. Intensity `x` in `[0, 1]` scales every
/// `max_*` field linearly; `x = 0` is the bit-identical fault-free run.
#[derive(Clone, Copy, Debug)]
pub struct FaultScenarioOptions {
    pub system: SystemKind,
    pub seed: u64,
    /// Conductance-noise sigma at intensity 1 (`--noise`).
    pub max_noise_sigma: f32,
    /// Drift observation time, seconds, at intensity 1 (`--drift`).
    pub max_drift_t_s: f64,
    /// Stuck row/column rate at intensity 1.
    pub max_stuck_rate: f64,
    /// Transient-stall duty fraction of the window at intensity 1
    /// (kept below 1 so faulty runs still complete).
    pub max_stall_duty: f64,
    /// Intensity points on the curve (>= 2; includes 0 and 1).
    pub steps: usize,
    /// Inferences per simulated point.
    pub n_inf: u32,
    /// Worker threads for the intensity fan-out.
    pub jobs: usize,
    /// `--fail-tile T@C`: hard-fail tile `T` at core cycle `C`.
    pub fail_tile: Option<(usize, u64)>,
}

impl Default for FaultScenarioOptions {
    fn default() -> FaultScenarioOptions {
        FaultScenarioOptions {
            system: SystemKind::HighPower,
            seed: 0xA19E,
            max_noise_sigma: 0.1,
            max_drift_t_s: 1.0e6,
            max_stuck_rate: 0.05,
            max_stall_duty: 0.5,
            steps: 5,
            n_inf: 8,
            jobs: 1,
            fail_tile: None,
        }
    }
}

/// One point of the degradation curve.
#[derive(Clone, Debug)]
pub struct FaultCurvePoint {
    pub intensity: f64,
    /// The device fault plan this point scored accuracy under.
    pub plan: FaultPlan,
    /// Transient stall injected per tile-IO window, picoseconds.
    pub stall_ps: u64,
    /// Accuracy proxy: output MSE vs the fault-free checker.
    pub mse: f64,
    /// Accuracy proxy: top-1 agreement with the fault-free checker.
    pub top1_agreement: f64,
    /// Simulated ROI time under the transient stalls.
    pub time_s: f64,
    pub energy_j: f64,
}

/// Outcome of the injected hard tile failure + degradation remap.
#[derive(Clone, Debug)]
pub struct FailureOutcome {
    pub tile: usize,
    pub fail_at_ps: u64,
    /// The typed error the failing run surfaced (`None` when the run
    /// finished before ever touching the tile after the failure time).
    pub error: Option<RunError>,
    /// Descriptor of the degraded (remapped) candidate.
    pub degraded_desc: String,
    /// Chain-order MVM anchor indices moved to the digital CPU path.
    pub remapped_anchors: Vec<usize>,
    /// Fault-free run of the original mapping.
    pub healthy: CaseResult,
    /// Fault-free run of the degraded mapping.
    pub degraded: CaseResult,
}

impl FailureOutcome {
    /// Degraded-over-healthy runtime ratio (>= 1 in practice: the
    /// remapped anchors now run on the digital cores).
    pub fn slowdown(&self) -> f64 {
        self.degraded.time_s / self.healthy.time_s
    }
}

/// Full report of one `alpine faults` invocation.
pub struct FaultReport {
    pub system: SystemKind,
    /// Descriptor of the automap candidate the curve runs on.
    pub desc: String,
    /// Tiles the candidate occupies.
    pub tiles: usize,
    pub curve: Vec<FaultCurvePoint>,
    pub failure: Option<FailureOutcome>,
}

/// The pipeline the sweep degrades: the paper's 3-layer MLP shape,
/// mapped by the automap search under the target system's budget.
fn scenario_graph() -> LayerGraph {
    LayerGraph::mlp(&[256, 128, 64])
}

/// Run the fault sweep (and the optional hard-failure injection).
pub fn run_scenario(opts: &FaultScenarioOptions) -> Result<FaultReport, WorkloadError> {
    let cfg = SystemConfig::for_kind(opts.system);
    let graph = scenario_graph();
    let budget = TopologyBudget::for_config(&cfg);
    let out = automap::search_opts(
        &graph,
        &budget,
        &cfg,
        &SearchOptions { top_k: 4, jobs: opts.jobs, ..SearchOptions::default() },
    )?;
    let best = out.ranked.first().ok_or_else(|| {
        WorkloadError::InvalidMapping("automap found no feasible candidate".into())
    })?;
    let n_tiles = best.mapping.tiles.len();

    let steps = opts.steps.max(2);
    let duty = opts.max_stall_duty.clamp(0.0, 0.95);
    let xs: Vec<f64> = (0..steps).map(|i| i as f64 / (steps - 1) as f64).collect();
    let point = |x: f64| -> Result<FaultCurvePoint, WorkloadError> {
        let plan = if x <= 0.0 {
            FaultPlan::none()
        } else {
            FaultPlan {
                seed: opts.seed,
                noise_sigma: opts.max_noise_sigma * x as f32,
                drift_t_s: 1.0 + (opts.max_drift_t_s - 1.0).max(0.0) * x,
                drift_nu: DRIFT_NU,
                stuck_row_rate: opts.max_stuck_rate * x,
                stuck_col_rate: opts.max_stuck_rate * x,
            }
        };
        // Accuracy proxy on the pipeline's first (largest) dense layer.
        let impact = assess_mvm(
            &plan,
            256,
            128,
            cfg.aimc.tile_rows as usize,
            cfg.aimc.tile_cols as usize,
            32,
        );
        let stall_ps = (duty * x * TRANSIENT_PERIOD_PS as f64).round() as u64;
        let fault = TileFaultModel {
            hard_fail_at_ps: None,
            transient_stall_ps: stall_ps,
            transient_period_ps: TRANSIENT_PERIOD_PS,
        };
        let faults: Vec<(usize, TileFaultModel)> = if stall_ps == 0 {
            Vec::new() // intensity 0: the untouched fault-free machine
        } else {
            (0..n_tiles).map(|t| (t, fault)).collect()
        };
        let w = compile::compile(&graph, &best.mapping, opts.n_inf)?;
        let r = run_workload(opts.system, w, &RunOptions::with_faults(faults))?;
        Ok(FaultCurvePoint {
            intensity: x,
            plan,
            stall_ps,
            mse: impact.mse,
            top1_agreement: impact.top1_agreement,
            time_s: r.time_s,
            energy_j: r.energy.total_j(),
        })
    };
    let curve: Vec<FaultCurvePoint> = parallel::parallel_map(xs, opts.jobs, point)
        .into_iter()
        .collect::<Result<_, _>>()?;

    let failure = match opts.fail_tile {
        None => None,
        Some((tile, at_cycles)) => {
            if tile >= n_tiles {
                return Err(WorkloadError::InvalidMapping(format!(
                    "--fail-tile {tile}: candidate {} uses only {n_tiles} tile(s)",
                    best.desc
                )));
            }
            let fail_at_ps = cfg.cycles_to_ps(at_cycles);
            let healthy = run_workload(
                opts.system,
                compile::compile(&graph, &best.mapping, opts.n_inf)?,
                &RunOptions::default(),
            )?;
            // Run with the injected hard failure: the machine must surface
            // a typed error, never panic. (A run short enough to finish
            // before touching the tile again simply completes.)
            let hard = TileFaultModel {
                hard_fail_at_ps: Some(fail_at_ps),
                transient_stall_ps: 0,
                transient_period_ps: 0,
            };
            let w = compile::compile(&graph, &best.mapping, opts.n_inf)?;
            let error = run_workload(opts.system, w, &RunOptions::with_faults(vec![(tile, hard)])).err();
            // Graceful degradation: remap the tile's anchors to the
            // digital cores and re-simulate.
            let d = automap::degrade_mapping(&graph, &best.mapping, tile, &budget)?;
            let degraded = run_workload(
                opts.system,
                compile::compile(&graph, &d.mapping, opts.n_inf)?,
                &RunOptions::default(),
            )?;
            Some(FailureOutcome {
                tile,
                fail_at_ps,
                error,
                degraded_desc: d.desc,
                remapped_anchors: d.remapped_anchors,
                healthy,
                degraded,
            })
        }
    };

    Ok(FaultReport { system: opts.system, desc: best.desc.clone(), tiles: n_tiles, curve, failure })
}

/// Minimal JSON string escaping (error messages may quote identifiers).
fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Write the degradation curves as hand-rolled JSON (serde is not in
/// the offline vendor set), in the spirit of `benchkit::json_report`.
pub fn write_report(report: &FaultReport, path: &str) -> std::io::Result<()> {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"system\": \"{}\",\n", report.system.name()));
    s.push_str(&format!("  \"mapping\": \"{}\",\n", esc(&report.desc)));
    s.push_str(&format!("  \"tiles\": {},\n", report.tiles));
    s.push_str("  \"curve\": [\n");
    for (i, p) in report.curve.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"intensity\": {:.4}, \"noise_sigma\": {:.6}, \"drift_t_s\": {:.3}, \
             \"stuck_rate\": {:.6}, \"stall_ps\": {}, \"mse\": {:.6e}, \
             \"top1_agreement\": {:.4}, \"time_s\": {:.6e}, \"energy_j\": {:.6e}}}{}\n",
            p.intensity,
            p.plan.noise_sigma,
            p.plan.drift_t_s,
            p.plan.stuck_row_rate,
            p.stall_ps,
            p.mse,
            p.top1_agreement,
            p.time_s,
            p.energy_j,
            if i + 1 < report.curve.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    if let Some(f) = &report.failure {
        s.push_str(",\n  \"failure\": {\n");
        s.push_str(&format!("    \"tile\": {},\n", f.tile));
        s.push_str(&format!("    \"fail_at_ps\": {},\n", f.fail_at_ps));
        s.push_str(&format!(
            "    \"error\": {},\n",
            match &f.error {
                Some(e) => format!("\"{}\"", esc(&e.to_string())),
                None => "null".to_string(),
            }
        ));
        s.push_str(&format!("    \"degraded_mapping\": \"{}\",\n", esc(&f.degraded_desc)));
        s.push_str(&format!(
            "    \"remapped_anchors\": [{}],\n",
            f.remapped_anchors.iter().map(|a| a.to_string()).collect::<Vec<_>>().join(", ")
        ));
        s.push_str(&format!("    \"healthy_time_s\": {:.6e},\n", f.healthy.time_s));
        s.push_str(&format!("    \"degraded_time_s\": {:.6e},\n", f.degraded.time_s));
        s.push_str(&format!("    \"slowdown\": {:.4}\n", f.slowdown()));
        s.push_str("  }");
    }
    s.push_str("\n}\n");
    std::fs::write(path, s)?;
    println!(
        "faults: wrote {} curve point(s){} to {path}",
        report.curve.len(),
        if report.failure.is_some() { " + failure outcome" } else { "" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(fail: Option<(usize, u64)>) -> FaultScenarioOptions {
        FaultScenarioOptions {
            steps: 3,
            n_inf: 2,
            fail_tile: fail,
            ..FaultScenarioOptions::default()
        }
    }

    #[test]
    fn fault_free_endpoint_is_pristine_and_curve_degrades() {
        let report = run_scenario(&quick(None)).unwrap();
        assert_eq!(report.curve.len(), 3);
        assert!(report.tiles > 0, "best MLP candidate should be analog: {}", report.desc);
        let first = &report.curve[0];
        let last = &report.curve[report.curve.len() - 1];
        assert_eq!(first.intensity, 0.0);
        assert_eq!(first.mse, 0.0);
        assert_eq!(first.top1_agreement, 1.0);
        assert_eq!(first.stall_ps, 0);
        // Accuracy proxy decreases, degraded cycles increase (ISSUE-6
        // acceptance shape).
        assert!(last.mse > first.mse);
        assert!(last.top1_agreement <= first.top1_agreement);
        assert!(last.time_s > first.time_s, "{} !> {}", last.time_s, first.time_s);
        assert!(last.energy_j >= first.energy_j);
    }

    #[test]
    fn hard_failure_yields_typed_error_and_degraded_remap() {
        let report = run_scenario(&quick(Some((0, 0)))).unwrap();
        let f = report.failure.expect("failure outcome requested");
        assert_eq!(f.tile, 0);
        // Failing at cycle 0 is hit on the tile's very first IO op.
        assert!(
            matches!(f.error, Some(RunError::TileFailed { tile: 0, .. })),
            "expected TileFailed, got {:?}",
            f.error
        );
        assert!(!f.remapped_anchors.is_empty());
        assert!(f.slowdown() >= 1.0, "digital fallback should not be faster: {}", f.slowdown());
    }

    #[test]
    fn bad_fail_tile_is_a_clean_error() {
        assert!(matches!(
            run_scenario(&quick(Some((99, 0)))),
            Err(WorkloadError::InvalidMapping(_))
        ));
    }

    #[test]
    fn report_writes_parseable_json() {
        let report = run_scenario(&quick(Some((0, 0)))).unwrap();
        let dir = std::env::temp_dir().join("alpine_faults_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_faults.json");
        write_report(&report, path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('{'));
        assert!(text.trim_end().ends_with('}'));
        assert!(text.contains("\"curve\": ["));
        assert!(text.contains("\"top1_agreement\""));
        assert!(text.contains("\"failure\": {"));
        assert!(text.contains("\"degraded_mapping\""));
    }
}
