//! The `alpine reliability` scenario driver (ISSUE 10): sweep virtual
//! horizon x recalibration policy over the automap-best pipeline and
//! measure what conductance drift does to a serving fleet —
//!
//! * **accuracy-proxy timeline**: the fleet's worst replica proxy over
//!   virtual time, reconstructed from the drift model and the completed
//!   recalibration windows;
//! * **accuracy SLO**: typed `accuracy_slo` sheds and the
//!   `served_below_slo` known-stale ledger — a drifted fleet is never
//!   silently wrong;
//! * **availability**: the staggered recalibration floor
//!   (`min_available_replicas >= N-1`);
//! * **throughput cost**: achieved rps, recal count, and total
//!   reprogram downtime of each policy.
//!
//! Drift is a power law (`G(t) ~ t^-nu` with log-time dispersion), so
//! the age at which a tile crosses the SLO is roughly
//! `exp(f * ln(horizon))` for the crossing log-fraction `f` — refresh
//! cadence must track the *crossing age*, not a calendar fraction of
//! the horizon. The health-check period is derived from the sampled
//! model (half the SLO-crossing age) so the threshold policy can react
//! in time; the fixed policy defaults to the calendar period
//! `horizon / 8`, which demonstrates exactly why calendar-period
//! refresh is the wrong knob for power-law drift.
//!
//! Determinism: the accuracy model is sampled once from the seeded
//! [`DriftState`] checker, every (policy, horizon) cell re-derives its
//! arrival trace from the horizon alone, and cells fan out over
//! `util::parallel` in input order — reports are byte-identical at any
//! `--jobs N`.

use crate::aimclib::faults::{reprogram_cost, DriftState};
use crate::config::{SystemConfig, SystemKind};
use crate::coordinator::serving::{
    router, AccuracyModel, Backend, Counters, RecalConfig, RecalPolicy, RecalWindow,
    RouterPolicy, SimConfig, TraceMachineBackend,
};
use crate::util::parallel;
use crate::workload::WorkloadError;

use super::faults::DRIFT_NU;

/// Picoseconds per second.
const PS_PER_S: f64 = 1.0e12;

/// Knobs of one `alpine reliability` invocation.
#[derive(Clone, Debug)]
pub struct ReliabilityOptions {
    pub system: SystemKind,
    pub seed: u64,
    /// Sample count of the drift -> accuracy-proxy table.
    pub steps: usize,
    /// Virtual horizons swept, seconds.
    pub horizons_s: Vec<f64>,
    /// Requests per cell, spread uniformly over the horizon (ids span
    /// the permille space, so `sensitive_permille` bites exactly).
    pub requests: u64,
    pub replicas: usize,
    pub max_batch: usize,
    pub queue_cap: usize,
    /// Drift exponent (`faults::DRIFT_NU` by default).
    pub nu: f64,
    /// Log-time conductance-dispersion growth rate.
    pub nu_sigma: f64,
    /// Accuracy SLO; `None` derives it as the midpoint between the
    /// horizon-end proxy and 1.0, so the never policy provably crosses
    /// it whenever drift degrades the proxy at all.
    pub slo: Option<f64>,
    /// Threshold-policy trigger; `None` = the degrade threshold
    /// (midpoint between the SLO and 1.0).
    pub threshold: Option<f64>,
    /// Fixed-policy refresh period, seconds; `None` = horizon / 8.
    pub fixed_period_s: Option<f64>,
    /// Health-check period, seconds; `None` derives it from the
    /// SLO-crossing age of the sampled model.
    pub check_period_s: Option<f64>,
    pub sensitive_permille: u32,
    /// Samples of the reported accuracy-proxy timeline per cell.
    pub timeline: usize,
    /// MLP layer shape of the pipeline (also the accuracy probe dims).
    pub shape: Vec<u64>,
    pub jobs: usize,
}

impl Default for ReliabilityOptions {
    fn default() -> ReliabilityOptions {
        ReliabilityOptions {
            system: SystemKind::HighPower,
            seed: 0xD81F,
            steps: 9,
            horizons_s: vec![1.0e6, 1.0e8],
            requests: 1000,
            replicas: 2,
            max_batch: 8,
            queue_cap: 32,
            nu: DRIFT_NU,
            nu_sigma: 0.02,
            slo: None,
            threshold: None,
            fixed_period_s: None,
            check_period_s: None,
            sensitive_permille: 250,
            timeline: 9,
            shape: vec![256, 128, 64],
            jobs: 1,
        }
    }
}

/// One sample of a cell's accuracy-proxy timeline. `worst_proxy` is the
/// minimum proxy over replicas *not* inside a recalibration window at
/// `t_ps` (`None` when every replica is mid-window).
#[derive(Clone, Copy, Debug)]
pub struct TimelinePoint {
    pub t_ps: u64,
    pub worst_proxy: Option<f64>,
}

/// One (policy, horizon) cell of the sweep.
#[derive(Clone, Debug)]
pub struct ReliabilityCell {
    pub policy: RecalPolicy,
    pub horizon_s: f64,
    pub check_period_ps: u64,
    pub counters: Counters,
    /// Served / horizon (not makespan: comparable across policies).
    pub achieved_rps: f64,
    pub min_available_replicas: usize,
    /// Completed recalibration windows (count in JSON; the full list
    /// feeds the timeline reconstruction).
    pub recal_windows: Vec<RecalWindow>,
    pub timeline: Vec<TimelinePoint>,
    /// No accuracy-SLO sheds and no known-stale serves.
    pub slo_ok: bool,
}

impl ReliabilityCell {
    /// Requests that were refused or stale-served on accuracy grounds.
    pub fn slo_violations(&self) -> u64 {
        self.counters.shed_accuracy_slo + self.counters.served_below_slo
    }
}

/// Full report of one `alpine reliability` invocation.
#[derive(Clone, Debug)]
pub struct ReliabilityReport {
    pub system: SystemKind,
    pub backend_desc: String,
    pub seed: u64,
    pub replicas: usize,
    pub max_batch: usize,
    pub requests: u64,
    pub nu: f64,
    pub nu_sigma: f64,
    /// The (possibly derived) accuracy SLO the router enforced.
    pub slo: f64,
    pub degrade_at: f64,
    pub threshold_trigger: f64,
    pub sensitive_permille: u32,
    /// Reprogram downtime of one tile refresh, ps.
    pub reprogram_ps: u64,
    /// Age at which the sampled model first crosses the SLO (the
    /// longest horizon when it never does).
    pub slo_cross_ps: u64,
    /// The sampled `age -> proxy` model shared by every cell.
    pub model: AccuracyModel,
    /// Cells in sweep order: policy-major (never, fixed, threshold),
    /// horizon-minor.
    pub cells: Vec<ReliabilityCell>,
}

/// First log-grid age (1 s .. `horizon_s`) whose proxy is below `slo`;
/// the horizon itself when the model never crosses. A scan, not a
/// bisection — sampled tables need not be strictly monotone.
fn first_slo_cross_ps(model: &AccuracyModel, slo: f64, horizon_s: f64) -> u64 {
    const GRID: usize = 1024;
    let ln_hi = horizon_s.max(2.0).ln();
    for i in 0..GRID {
        let age_s = (ln_hi * i as f64 / (GRID - 1) as f64).exp();
        let age_ps = (age_s * PS_PER_S).round() as u64;
        if model.proxy_at(age_ps) < slo {
            return age_ps;
        }
    }
    (horizon_s * PS_PER_S).round() as u64
}

/// Reconstruct the fleet's worst accuracy proxy over the horizon from
/// the model and the completed recalibration windows.
fn timeline(
    model: &AccuracyModel,
    windows: &[RecalWindow],
    replicas: usize,
    horizon_ps: u64,
    samples: usize,
) -> Vec<TimelinePoint> {
    // Per-replica windows, in completion order (done_ps ascending).
    let mut per: Vec<Vec<RecalWindow>> = vec![Vec::new(); replicas];
    for w in windows {
        per[w.replica].push(*w);
    }
    let samples = samples.max(2);
    (0..samples)
        .map(|k| {
            let t = ((horizon_ps as u128 * k as u128) / (samples - 1) as u128) as u64;
            let mut worst: Option<f64> = None;
            for ws in &per {
                // Last window completed at or before t -> programming
                // timestamp; a replica mid-window is not serving.
                let idx = ws.partition_point(|w| w.done_ps <= t);
                if let Some(w) = ws.get(idx) {
                    if w.start_ps <= t && t < w.done_ps {
                        continue;
                    }
                }
                let programmed = if idx == 0 { 0 } else { ws[idx - 1].done_ps };
                let p = model.proxy_at(t.saturating_sub(programmed));
                worst = Some(match worst {
                    Some(m) => m.min(p),
                    None => p,
                });
            }
            TimelinePoint { t_ps: t, worst_proxy: worst }
        })
        .collect()
}

/// Run the sweep on an explicit backend (tests inject the instant
/// mock; `run_reliability` builds the trace backend).
pub fn run_reliability_on(
    opts: &ReliabilityOptions,
    backend: &dyn Backend,
) -> Result<ReliabilityReport, WorkloadError> {
    let bad = |m: String| WorkloadError::InvalidMapping(m);
    if opts.replicas == 0 {
        return Err(bad("reliability needs at least one replica".into()));
    }
    if opts.requests == 0 {
        return Err(bad("reliability needs at least one request per cell".into()));
    }
    if opts.horizons_s.is_empty() || opts.horizons_s.iter().any(|&h| h < 1.0) {
        return Err(bad("horizons must be at least 1 second (the drift t0)".into()));
    }
    if opts.shape.len() < 2 {
        return Err(bad("pipeline shape needs at least two layers".into()));
    }

    let cfg = SystemConfig::for_kind(opts.system);
    let tile_rows = cfg.aimc.tile_rows as usize;
    let tile_cols = cfg.aimc.tile_cols as usize;
    let horizon_max_s = opts.horizons_s.iter().copied().fold(0.0, f64::max);

    // One seeded drift state feeds the whole sweep: the model is the
    // checker's top-1 agreement over log-spaced ages.
    let drift = DriftState::new(opts.seed, opts.nu, opts.nu_sigma);
    let model = AccuracyModel::table_from_drift(
        &drift,
        horizon_max_s,
        opts.steps.max(2),
        opts.shape[0] as usize,
        opts.shape[1] as usize,
        tile_rows,
        tile_cols,
        32,
    );
    let p_end = model.proxy_at((horizon_max_s * PS_PER_S).round() as u64);
    let slo = opts.slo.unwrap_or(((p_end + 1.0) / 2.0).min(0.999));
    let degrade_at = ((slo + 1.0) / 2.0).min(0.9995);
    let trigger = opts.threshold.unwrap_or(degrade_at);
    let slo_cross_ps = first_slo_cross_ps(&model, slo, horizon_max_s);
    let rep_cost = reprogram_cost(tile_rows, tile_cols);
    let reprogram_ps = ((rep_cost.time_s * PS_PER_S).round() as u64).max(1);

    let bmax = backend.max_batch().max(1);
    let full_batch_ps = backend.batch_ps(bmax).max(1);
    let deadline_ps = (10 * full_batch_ps).max(1);

    // Policy-major sweep order, horizons minor.
    let kinds = ["never", "fixed", "threshold"];
    let mut items: Vec<(RecalPolicy, f64)> = Vec::new();
    for kind in kinds {
        for &h in &opts.horizons_s {
            let policy = match kind {
                "never" => RecalPolicy::Never,
                "fixed" => RecalPolicy::Fixed {
                    period_ps: ((opts.fixed_period_s.unwrap_or(h / 8.0) * PS_PER_S).round()
                        as u64)
                        .max(1),
                },
                _ => RecalPolicy::Threshold { trigger },
            };
            items.push((policy, h));
        }
    }

    let cells: Vec<ReliabilityCell> = parallel::parallel_map(items, opts.jobs, |(policy, h)| {
        let horizon_ps = (h * PS_PER_S).round() as u64;
        // Check cadence must track the SLO-crossing *age*, not the
        // horizon: half the crossing age, clamped to keep the event
        // count bounded on both sides.
        let check_period_ps = match opts.check_period_s {
            Some(s) => ((s * PS_PER_S).round() as u64).max(1),
            None => (slo_cross_ps / 2).clamp((horizon_ps / 100_000).max(1), horizon_ps / 8).max(1),
        };
        // Shared per-horizon arrival trace: uniform over the horizon,
        // identical for every policy at this horizon so the policy is
        // the only variable of a column.
        let gap = (horizon_ps / (opts.requests + 1)).max(1);
        let arrivals: Vec<u64> = (1..=opts.requests).map(|k| k * gap).collect();
        let sim_cfg = SimConfig {
            backend,
            replicas: opts.replicas,
            queue_cap: opts.queue_cap.max(1),
            deadline_ps,
            batch_wait_ps: full_batch_ps,
            max_retries: 3,
            backoff_base_ps: (backend.batch_ps(1) / 2).max(1),
            repair_ps: (10 * full_batch_ps).max(1),
            policy: RouterPolicy::LeastLoaded,
            fail: None,
            recal: Some(RecalConfig {
                model: model.clone(),
                slo,
                degrade_at,
                sensitive_permille: opts.sensitive_permille,
                policy,
                check_period_ps,
                reprogram_ps,
            }),
        };
        let res = router::simulate(&sim_cfg, &arrivals);
        let tl = timeline(&model, &res.recal_windows, opts.replicas, horizon_ps, opts.timeline);
        let slo_ok =
            res.counters.shed_accuracy_slo == 0 && res.counters.served_below_slo == 0;
        ReliabilityCell {
            policy,
            horizon_s: h,
            check_period_ps,
            achieved_rps: res.counters.served as f64 / h,
            min_available_replicas: res.min_available_replicas,
            recal_windows: res.recal_windows,
            timeline: tl,
            slo_ok,
            counters: res.counters,
        }
    });

    Ok(ReliabilityReport {
        system: opts.system,
        backend_desc: backend.label(),
        seed: opts.seed,
        replicas: opts.replicas,
        max_batch: bmax,
        requests: opts.requests,
        nu: opts.nu,
        nu_sigma: opts.nu_sigma,
        slo,
        degrade_at,
        threshold_trigger: trigger,
        sensitive_permille: opts.sensitive_permille,
        reprogram_ps,
        slo_cross_ps,
        model,
        cells,
    })
}

/// Build the trace-machine backend for `opts.shape` and run the sweep —
/// the `alpine reliability` entry point.
pub fn run_reliability(opts: &ReliabilityOptions) -> Result<ReliabilityReport, WorkloadError> {
    let backend = TraceMachineBackend::build_graph_degraded(
        &crate::nn::LayerGraph::mlp(&opts.shape),
        opts.system,
        opts.max_batch,
        opts.jobs,
        1,
    )?;
    run_reliability_on(opts, &backend)
}

/// Minimal JSON string escaping.
fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

impl ReliabilityReport {
    /// Hand-rolled JSON (serde is not in the offline vendor set); the
    /// `"scenario": "reliability"` marker keys `bench_compare.py`
    /// dispatch. Byte-identical for identical reports.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str("  \"scenario\": \"reliability\",\n");
        s.push_str(&format!("  \"system\": \"{}\",\n", self.system.name()));
        s.push_str(&format!("  \"backend\": \"{}\",\n", esc(&self.backend_desc)));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"replicas\": {},\n", self.replicas));
        s.push_str(&format!("  \"max_batch\": {},\n", self.max_batch));
        s.push_str(&format!("  \"requests_per_cell\": {},\n", self.requests));
        s.push_str(&format!("  \"nu\": {:.4},\n", self.nu));
        s.push_str(&format!("  \"nu_sigma\": {:.4},\n", self.nu_sigma));
        s.push_str(&format!("  \"slo\": {:.6},\n", self.slo));
        s.push_str(&format!("  \"degrade_at\": {:.6},\n", self.degrade_at));
        s.push_str(&format!("  \"threshold_trigger\": {:.6},\n", self.threshold_trigger));
        s.push_str(&format!("  \"sensitive_permille\": {},\n", self.sensitive_permille));
        s.push_str(&format!("  \"reprogram_ps\": {},\n", self.reprogram_ps));
        s.push_str(&format!("  \"slo_cross_ps\": {},\n", self.slo_cross_ps));
        if let AccuracyModel::Table { ages_ps, proxy } = &self.model {
            s.push_str(&format!(
                "  \"model_ages_ps\": [{}],\n",
                ages_ps.iter().map(|a| a.to_string()).collect::<Vec<_>>().join(", ")
            ));
            s.push_str(&format!(
                "  \"model_proxy\": [{}],\n",
                proxy.iter().map(|p| format!("{p:.6}")).collect::<Vec<_>>().join(", ")
            ));
        }
        s.push_str("  \"policies\": [\n");
        let kinds = ["never", "fixed", "threshold"];
        for (ki, kind) in kinds.iter().enumerate() {
            s.push_str(&format!("    {{\"policy\": \"{kind}\", \"cells\": [\n"));
            let cells: Vec<&ReliabilityCell> =
                self.cells.iter().filter(|c| c.policy.name() == *kind).collect();
            for (i, c) in cells.iter().enumerate() {
                let n = &c.counters;
                let tl = c
                    .timeline
                    .iter()
                    .map(|p| {
                        format!(
                            "{{\"t_ps\": {}, \"worst_proxy\": {}}}",
                            p.t_ps,
                            match p.worst_proxy {
                                Some(v) => format!("{v:.6}"),
                                None => "null".to_string(),
                            }
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                s.push_str(&format!(
                    "      {{\"horizon_s\": {:.3e}, \"check_period_ps\": {}, \
                     \"offered\": {}, \"served\": {}, \"shed_queue_full\": {}, \
                     \"shed_no_replica\": {}, \"shed_retries\": {}, \
                     \"shed_accuracy_slo\": {}, \"timed_out\": {}, \
                     \"served_below_slo\": {}, \"slo_violations\": {}, \
                     \"recals\": {}, \"recal_drained\": {}, \
                     \"recal_downtime_ps\": {}, \"min_available_replicas\": {}, \
                     \"achieved_rps\": {:.6e}, \"slo_ok\": {}, \
                     \"timeline\": [{}]}}{}\n",
                    c.horizon_s,
                    c.check_period_ps,
                    n.offered,
                    n.served,
                    n.shed_queue_full,
                    n.shed_no_replica,
                    n.shed_retries,
                    n.shed_accuracy_slo,
                    n.timed_out,
                    n.served_below_slo,
                    c.slo_violations(),
                    n.recals,
                    n.recal_drained,
                    n.recal_downtime_ps,
                    c.min_available_replicas,
                    c.achieved_rps,
                    c.slo_ok,
                    tl,
                    if i + 1 < cells.len() { "," } else { "" }
                ));
            }
            s.push_str(&format!(
                "    ]}}{}\n",
                if ki + 1 < kinds.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Persist the sweep as `BENCH_reliability.json` (or wherever `path`
/// says).
pub fn write_report(report: &ReliabilityReport, path: &str) -> std::io::Result<()> {
    std::fs::write(path, report.to_json())?;
    println!(
        "reliability: wrote {} cell(s) ({} policies) to {path}",
        report.cells.len(),
        3
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::InstantMockBackend;

    fn quick() -> ReliabilityOptions {
        ReliabilityOptions {
            steps: 6,
            horizons_s: vec![1.0e8],
            requests: 200,
            timeline: 5,
            shape: vec![64, 32],
            ..ReliabilityOptions::default()
        }
    }

    #[test]
    fn never_violates_threshold_maintains_with_bounded_cost() {
        let report = run_reliability_on(&quick(), &InstantMockBackend::default()).unwrap();
        assert_eq!(report.cells.len(), 3, "3 policies x 1 horizon");
        for c in &report.cells {
            assert!(c.counters.conserved(), "{:?}", c.counters);
        }
        let never = &report.cells[0];
        let threshold = &report.cells[2];
        assert_eq!(never.policy, RecalPolicy::Never);
        assert!(matches!(threshold.policy, RecalPolicy::Threshold { .. }));
        // The never policy ages past the derived SLO and violates it.
        assert_eq!(never.counters.recals, 0);
        assert!(!never.slo_ok, "never policy must cross the SLO: {:?}", never.counters);
        assert!(never.slo_violations() > 0);
        // Threshold-triggered recalibration keeps violations strictly
        // below never's, refreshes, and holds the availability floor.
        assert!(threshold.counters.recals > 0, "{:?}", threshold.counters);
        assert!(
            threshold.slo_violations() < never.slo_violations(),
            "threshold {} !< never {}",
            threshold.slo_violations(),
            never.slo_violations()
        );
        assert!(threshold.min_available_replicas >= report.replicas - 1);
        // Bounded throughput cost: downtime is a vanishing fraction of
        // the horizon.
        let horizon_ps = (threshold.horizon_s * 1.0e12) as u64;
        assert!(threshold.counters.recal_downtime_ps < horizon_ps / 100);
        // The timeline starts fresh and the never policy's end is the
        // aged proxy, below the SLO.
        assert_eq!(never.timeline.first().unwrap().worst_proxy, Some(1.0));
        let end = never.timeline.last().unwrap().worst_proxy.unwrap();
        assert!(end < report.slo, "aged proxy {end} !< slo {}", report.slo);
    }

    #[test]
    fn report_is_byte_identical_at_any_jobs_and_seed_matters() {
        let b = InstantMockBackend::default();
        let a = run_reliability_on(&ReliabilityOptions { jobs: 1, ..quick() }, &b)
            .unwrap()
            .to_json();
        let c = run_reliability_on(&ReliabilityOptions { jobs: 4, ..quick() }, &b)
            .unwrap()
            .to_json();
        assert_eq!(a, c, "reliability must be byte-identical across --jobs");
        let d = run_reliability_on(
            &ReliabilityOptions { seed: quick().seed + 1, ..quick() },
            &b,
        )
        .unwrap()
        .to_json();
        assert_ne!(a, d, "the seed must matter");
        assert!(a.contains("\"scenario\": \"reliability\""));
        assert!(a.contains("\"policies\": ["));
        assert!(a.contains("\"timeline\": ["));
    }

    #[test]
    fn bad_options_are_clean_errors() {
        let b = InstantMockBackend::default();
        let zero = ReliabilityOptions { replicas: 0, ..quick() };
        assert!(matches!(
            run_reliability_on(&zero, &b),
            Err(WorkloadError::InvalidMapping(_))
        ));
        let empty = ReliabilityOptions { horizons_s: Vec::new(), ..quick() };
        assert!(run_reliability_on(&empty, &b).is_err());
        let neg = ReliabilityOptions { horizons_s: vec![-1.0], ..quick() };
        assert!(run_reliability_on(&neg, &b).is_err());
    }
}
